//! Bench + regenerator for Fig 12: the roofline model.
use adaptor::accel::{platform, roofline, tiling::TileConfig};
use adaptor::analysis::report;
use adaptor::model::presets;
use adaptor::util::benchkit::{bench, run_suite};

fn main() {
    let (text, _) = report::fig12();
    println!("{text}");
    let p = platform::u55c();
    let t = TileConfig::paper_optimum();
    let workloads = [("bert", presets::bert_base(64), 30.0)];
    let cases = vec![bench("fig12/roofline_build", 10, 1000, || {
        std::hint::black_box(roofline::roofline(&p, &t, 200.0, 4, &workloads));
    })];
    run_suite("Fig 12 — roofline", cases);
}
