//! Bench + regenerator for Fig 8: performance & resources vs head count.
use adaptor::accel::platform;
use adaptor::analysis::{report, sweep};
use adaptor::model::quant::BitWidth;
use adaptor::model::TnnConfig;
use adaptor::util::benchkit::{bench, run_suite};

fn main() {
    let (text, _) = report::fig08();
    println!("{text}");
    let base = TnnConfig::encoder(64, 768, 8, 12);
    let p = platform::u55c();
    let cases = vec![bench("fig8/heads_sweep", 2, 50, || {
        std::hint::black_box(sweep::heads_sweep(&base, &p, BitWidth::Fixed16));
    })];
    run_suite("Fig 8 — head-count sweep", cases);
}
