//! Bench + regenerator for Fig 13: GOPS vs DSP utilization across tiles.
use adaptor::analysis::report;
use adaptor::util::benchkit::{bench, run_suite};

fn main() {
    let (text, _) = report::fig13();
    println!("{text}");
    let cases = vec![bench("fig13/regenerate", 2, 50, || {
        std::hint::black_box(report::fig13());
    })];
    run_suite("Fig 13 — DSP vs GOPS", cases);
}
