//! Bench + regenerator for Table 2: analytical vs cycle-level simulation,
//! timing both implementations (the sim is the expensive one).
use adaptor::accel::{latency, sim, tiling::TileConfig};
use adaptor::analysis::report;
use adaptor::model::TnnConfig;
use adaptor::util::benchkit::{bench, run_suite};

fn main() {
    let (text, _) = report::table2();
    println!("{text}");
    let cfg = TnnConfig::encoder(64, 768, 8, 12);
    let t = TileConfig::paper_optimum();
    let cases = vec![
        bench("table2/analytical_model", 10, 2000, || {
            std::hint::black_box(latency::model_latency(&cfg, &t));
        }),
        bench("table2/cycle_simulation", 5, 200, || {
            std::hint::black_box(sim::simulate(&cfg, &t));
        }),
    ];
    run_suite("Table 2 — model vs simulation cost", cases);
}
