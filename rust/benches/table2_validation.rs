//! Bench + regenerator for Table 2: analytical vs cycle-level simulation
//! vs schedule replay, timing all three (build+replay of the TileProgram
//! is the expensive one — which is why the engine caches it per topology).
use adaptor::accel::schedule::{AttentionMode, FabricConstants};
use adaptor::accel::sim::cycle;
use adaptor::accel::{latency, sim, tiling::TileConfig};
use adaptor::analysis::report;
use adaptor::model::TnnConfig;
use adaptor::util::benchkit::{bench, run_suite};

fn main() {
    let (text, _) = report::table2();
    println!("{text}");
    let cfg = TnnConfig::encoder(64, 768, 8, 12);
    let t = TileConfig::paper_optimum();
    // default fabric geometry, but the Table 2 rows run 8 heads (dk = 96)
    let fc = FabricConstants { dk: 96, ..FabricConstants::artifact_default() };
    let cases = vec![
        bench("table2/analytical_model", 10, 2000, || {
            std::hint::black_box(latency::model_latency(&cfg, &t));
        }),
        bench("table2/cycle_simulation", 5, 200, || {
            std::hint::black_box(sim::simulate(&cfg, &t));
        }),
        bench("table2/schedule_build_and_replay", 3, 50, || {
            std::hint::black_box(
                cycle::estimate(&cfg, &fc, AttentionMode::Split, false, false).unwrap(),
            );
        }),
    ];
    run_suite("Table 2 — model vs simulation vs schedule replay cost", cases);
}
