//! Bench + regenerator for Table 2: analytical vs cycle-level simulation
//! vs schedule replay, timing all three (build+replay of the TileProgram
//! is the expensive one — which is why the engine caches it per topology).
use adaptor::accel::schedule::{AttentionMode, FabricConstants, OptLevel};
use adaptor::accel::sim::cycle;
use adaptor::accel::{latency, sim, tiling::TileConfig};
use adaptor::analysis::report;
use adaptor::model::TnnConfig;
use adaptor::util::benchkit::{bench, run_suite};

fn main() {
    let (text, _) = report::table2();
    println!("{text}");
    let cfg = TnnConfig::encoder(64, 768, 8, 12);
    let t = TileConfig::paper_optimum();
    // default fabric geometry, but the Table 2 rows run 8 heads (dk = 96)
    let fc = FabricConstants { dk: 96, ..FabricConstants::artifact_default() };
    let mut cases = vec![
        bench("table2/analytical_model", 10, 2000, || {
            std::hint::black_box(latency::model_latency(&cfg, &t));
        }),
        bench("table2/cycle_simulation", 5, 200, || {
            std::hint::black_box(sim::simulate(&cfg, &t));
        }),
        bench("table2/schedule_build_and_replay", 3, 50, || {
            std::hint::black_box(
                cycle::estimate(&cfg, &fc, AttentionMode::Split, false, false).unwrap(),
            );
        }),
    ];
    // Per-bucket rows: what a request of 1/4, 1/2 and full seq_len pays
    // through the covering bucket's skippable program, against the dense
    // max-length replay every request used to pay.
    let dense = cycle::estimate(&cfg, &fc, AttentionMode::Split, false, false).unwrap();
    println!("length-adaptive request price (dense {} cycles):", dense.total_cycles);
    for rows in [cfg.seq_len / 4, cfg.seq_len / 2, cfg.seq_len] {
        let rep = cycle::estimate_adaptive(&cfg, &fc, rows, OptLevel::O1).unwrap();
        println!(
            "  {rows:>3} live rows -> {} cycles ({:.1}% recovered)",
            rep.total_cycles,
            100.0 * (1.0 - rep.total_cycles as f64 / dense.total_cycles as f64),
        );
        cases.push(bench(&format!("table2/adaptive_live{rows}_of{}", cfg.seq_len), 3, 50, || {
            std::hint::black_box(cycle::estimate_adaptive(&cfg, &fc, rows, OptLevel::O1).unwrap());
        }));
    }
    run_suite("Table 2 — model vs simulation vs schedule replay cost", cases);
}
