//! Cross-fabric shard-chain bench: 2- and 4-shard pipelines vs the
//! single-fabric oracle.
//!
//! An 8-layer encoder is lowered whole (the oracle) and as K∈{2,4}
//! contiguous shard chains (`coordinator::shard::lower_chain`), every
//! program priced by the cycle backend.  Stdout reports the pipeline
//! economics:
//!
//! * **fill latency** — the sum of stage cycles one request pays end to
//!   end, including each sender's link time (`LINK_BYTES_PER_CYCLE`);
//! * **bottleneck interval** — the slowest stage, which bounds
//!   steady-state throughput once K requests overlap in the pipeline;
//! * **link traffic** — `K−1` full padded-activation hops per request.
//!
//! `BENCH_shard.json` is **deliberately closed-form**: every tracked
//! field is a counter the partitioner and link protocol fix by
//! construction (layer splits, shard footprints, upload beats, hop
//! bytes at `LINK_BYTES_PER_CYCLE`) — bit-stable across machines and
//! PRs, and auditable by hand.  Cycle-sim totals print to stdout only,
//! like the residency bench's wall timings; the chain↔oracle numeric
//! equivalence itself is proved bit-for-bit in `integration_shard.rs`.

use adaptor::accel::schedule::{
    optimize, ArtifactInventory, FabricConstants, OptLevel, ScheduleBuilder, TileProgram,
};
use adaptor::accel::sim::cycle;
use adaptor::coordinator::residency::{upload_cycles, weight_footprint_bytes};
use adaptor::coordinator::shard::{self, ShardPlan};
use adaptor::model::TnnConfig;
use adaptor::util::benchkit::{bench, header};
use adaptor::util::json;

const JSON_PATH: &str = "BENCH_shard.json";
const LEVEL: OptLevel = OptLevel::O1;

fn fc() -> FabricConstants {
    FabricConstants::artifact_default()
}

/// The bench topology: deep enough that a 4-way split stays balanced
/// (2 layers per shard), small enough to lower in milliseconds.
fn topology() -> TnnConfig {
    TnnConfig::encoder(64, 256, 4, 8)
}

fn monolith(f: FabricConstants, cfg: TnnConfig, inv: &ArtifactInventory) -> TileProgram {
    let mut p = ScheduleBuilder::new(f, cfg).expect("bench topology fits the fabric").build();
    optimize(&mut p, LEVEL, inv).expect("optimize cannot fail on a built program");
    p
}

/// Closed-form chain counters — everything the committed JSON tracks.
struct ChainCounters {
    stage_layers: Vec<usize>,
    shard_bytes: Vec<u64>,
    max_shard_bytes: u64,
    upload_cycles_per_shard: Vec<u64>,
    activation_hops: u64,
    link_bytes: u64,
    link_cycles: u64,
}

fn chain_counters(plan: &ShardPlan, act_bytes: u64) -> ChainCounters {
    let k = plan.shards.len() as u64;
    ChainCounters {
        stage_layers: plan.shards.iter().map(shard::ShardSpec::layer_count).collect(),
        shard_bytes: plan.shards.iter().map(|s| s.bytes).collect(),
        max_shard_bytes: plan.max_shard_bytes(),
        upload_cycles_per_shard: plan.shards.iter().map(|s| upload_cycles(s.bytes)).collect(),
        activation_hops: k - 1,
        link_bytes: (k - 1) * act_bytes,
        link_cycles: (k - 1) * act_bytes.div_ceil(cycle::LINK_BYTES_PER_CYCLE),
    }
}

fn join<T: ToString>(v: &[T]) -> String {
    v.iter().map(T::to_string).collect::<Vec<_>>().join(", ")
}

fn chain_json(c: &ChainCounters) -> String {
    format!(
        concat!(
            "{{\"shards\": {}, \"stage_layers\": [{}], \"shard_bytes\": [{}], ",
            "\"max_shard_bytes\": {}, \"upload_cycles_per_shard\": [{}], ",
            "\"activation_hops\": {}, \"link_bytes\": {}, \"link_cycles\": {}}}"
        ),
        c.stage_layers.len(),
        join(&c.stage_layers),
        join(&c.shard_bytes),
        c.max_shard_bytes,
        join(&c.upload_cycles_per_shard),
        c.activation_hops,
        c.link_bytes,
        c.link_cycles,
    )
}

fn main() -> anyhow::Result<()> {
    let f = fc();
    let inv = ArtifactInventory::assume_all();
    let cfg = topology();

    let oracle = monolith(f, cfg, &inv);
    let o = cycle::replay_program(&oracle)?;
    println!("== shard-chain pipeline vs single-fabric oracle ({cfg}, {LEVEL:?}) ==");
    println!(
        "  oracle: {} cycles, {} dispatches, link untouched ({} hops)\n",
        o.total_cycles, o.dispatches, o.activation_hops
    );
    assert_eq!(o.activation_hops, 0, "the monolith must never touch the link");
    assert_eq!(o.link_bytes, 0);

    let act_bytes = (f.sl_max * f.dmodel_max * 4) as u64;
    let mut counters = Vec::new();
    for k in [2usize, 4] {
        let plan = ShardPlan::partition_k(&cfg, &f, k)?;
        let chain = shard::lower_chain(&plan, &f, LEVEL, &inv)?;
        let report = shard::verify_chain(&chain);
        assert!(
            report.is_clean(),
            "{k}-shard chain failed its contract: {:?}",
            report.errors().collect::<Vec<_>>()
        );
        let c = chain_counters(&plan, act_bytes);

        // Cycle-sim acceptance (stdout-only figures): the priced link
        // traffic matches the closed-form counters exactly, and every
        // stage's compute undercuts the oracle.
        let mut fill = 0u64;
        let mut bottleneck = 0u64;
        let (mut hops, mut bytes) = (0u64, 0u64);
        for (i, prog) in chain.iter().enumerate() {
            let r = cycle::replay_program(prog)?;
            fill += r.total_cycles;
            bottleneck = bottleneck.max(r.total_cycles);
            hops += r.activation_hops;
            bytes += r.link_bytes;
            let compute = r.total_cycles - r.link_cycles;
            assert!(
                compute < o.total_cycles,
                "stage {i} of {k} computes {compute} cycles, not under the oracle's {}",
                o.total_cycles
            );
        }
        assert_eq!(hops, c.activation_hops, "cycle sim disagrees with the hop count");
        assert_eq!(bytes, c.link_bytes, "cycle sim disagrees with the link bytes");
        println!(
            "  k={k}: fill {:>8} cycles, bottleneck {:>8} ({:.2}x steady-state), \
             {} hops / {} link bytes",
            fill,
            bottleneck,
            o.total_cycles as f64 / bottleneck as f64,
            hops,
            bytes
        );
        counters.push(c);
    }

    // Chain-lowering wall timings — stdout only, never in the JSON.
    println!("\n{}", header());
    let plan4 = ShardPlan::partition_k(&cfg, &f, 4)?;
    let r = bench("shard/lower_chain_k4", 5, 20, || {
        shard::lower_chain(&plan4, &f, LEVEL, &inv).expect("lowering cannot fail");
    });
    println!("{}", r.line());

    let json_text = format!(
        concat!(
            "{{\n",
            "  \"bench\": \"shard_pipeline\",\n",
            "  \"note\": \"closed-form counters only: layer splits, shard footprints, upload ",
            "beats and link traffic fixed by the partitioner and link protocol. cycle-sim ",
            "figures print to stdout; chain-vs-oracle equivalence is integration_shard.rs\",\n",
            "  \"workload\": {{\"topology\": \"{}\", \"opt_level\": \"{:?}\", \"layers\": {}, ",
            "\"activation_bytes_per_hop\": {}, \"link_bytes_per_cycle\": {}, ",
            "\"upload_bytes_per_cycle\": {}}},\n",
            "  \"oracle\": {{\"weight_bytes\": {}, \"upload_cycles\": {}, ",
            "\"activation_hops\": 0, \"link_bytes\": 0}},\n",
            "  \"chains\": [\n    {},\n    {}\n  ]\n",
            "}}\n"
        ),
        cfg,
        LEVEL,
        cfg.enc_layers,
        act_bytes,
        cycle::LINK_BYTES_PER_CYCLE,
        adaptor::coordinator::residency::UPLOAD_BYTES_PER_CYCLE,
        weight_footprint_bytes(&cfg, &f),
        upload_cycles(weight_footprint_bytes(&cfg, &f)),
        chain_json(&counters[0]),
        chain_json(&counters[1]),
    );
    json::parse(&json_text).map_err(|e| anyhow::anyhow!("bench JSON is malformed: {e}"))?;
    std::fs::write(JSON_PATH, &json_text)?;
    println!("\nwrote {JSON_PATH}");
    Ok(())
}
