//! Bench + regenerator for Fig 10: cross-platform power comparison.
use adaptor::accel::{platform, power, resources, tiling::TileConfig};
use adaptor::analysis::report;
use adaptor::model::quant::BitWidth;
use adaptor::model::TnnConfig;
use adaptor::util::benchkit::{bench, run_suite};

fn main() {
    let (text, _) = report::fig10();
    println!("{text}");
    let cfg = TnnConfig::encoder(64, 768, 8, 12);
    let p = platform::u55c();
    let r = resources::estimate(&cfg, &TileConfig::paper_optimum(), BitWidth::Fixed16, &p);
    let cases = vec![bench("fig10/power_model", 10, 1000, || {
        std::hint::black_box(power::total_power_w(&p, &r, 200.0));
    })];
    run_suite("Fig 10 — power model", cases);
}
