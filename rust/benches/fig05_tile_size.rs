//! Bench + regenerator for Fig 5: frequency & normalized latency across
//! the (Tiles_MHA x Tiles_FFN) grid.  Prints the paper's series and times
//! the DSE itself (per-point analytical cost).
use adaptor::accel::platform;
use adaptor::analysis::{report, sweep};
use adaptor::model::quant::BitWidth;
use adaptor::model::TnnConfig;
use adaptor::util::benchkit::{bench, run_suite};

fn main() {
    let (text, _) = report::fig05();
    println!("{text}");
    let cfg = TnnConfig::encoder(64, 768, 8, 12);
    let p = platform::u55c();
    let cases = vec![
        bench("fig5/full_tile_sweep", 2, 20, || {
            std::hint::black_box(sweep::tile_sweep(&cfg, &p, BitWidth::Fixed16));
        }),
        bench("fig5/single_design_point", 2, 200, || {
            let pts = sweep::tile_sweep(&cfg, &p, BitWidth::Fixed16);
            std::hint::black_box(sweep::best_by_latency(&pts).cloned());
        }),
    ];
    run_suite("Fig 5 — tile-size DSE", cases);
}
