//! Bench + regenerator for Fig 11: portability across three platforms.
use adaptor::analysis::report;
use adaptor::util::benchkit::{bench, run_suite};

fn main() {
    let (text, _) = report::fig11();
    println!("{text}");
    let cases = vec![bench("fig11/three_platform_eval", 2, 100, || {
        std::hint::black_box(report::fig11());
    })];
    run_suite("Fig 11 — portability evaluation", cases);
}
