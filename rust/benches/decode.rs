//! Generation-path bench: decoder program compilation, cycle-backend
//! prefill vs per-token pricing, and (with the AOT artifact set present)
//! real PJRT generation — prefill p50/p95/p99 plus per-token decode-step
//! latency through `TileEngine::generate`.
//!
//! Every run writes `BENCH_decode.json` (machine-readable summaries via
//! `util::benchkit::write_json`); without artifacts only the
//! compiler/cycle sections run, so the CI `cargo bench --no-run` job and
//! artifact-free environments still track the schedule-side numbers.

use adaptor::accel::schedule::{optimize, ArtifactInventory, FabricConstants, OptLevel, ScheduleBuilder};
use adaptor::accel::sim::cycle;
use adaptor::coordinator::router::ModelSpec;
use adaptor::coordinator::TileEngine;
use adaptor::model::{presets, weights};
use adaptor::runtime::{artifacts_available, default_artifact_dir, Manifest};
use adaptor::util::benchkit::{bench, header, write_json, BenchResult};
use adaptor::util::stats::summarize;

const JSON_PATH: &str = "BENCH_decode.json";

/// Compiler + cycle-backend section: runs without any artifact set.
fn bench_decoder_compiler(results: &mut Vec<BenchResult>) {
    let fc = FabricConstants::artifact_default();
    let cfg = presets::gpt_small(64, 4);

    println!("== decoder schedule compiler (artifact-free) ==");
    println!("{}", header());
    let r = bench("compile/build_prefill_4layer", 3, 50, || {
        std::hint::black_box(ScheduleBuilder::new(fc, cfg).unwrap().build_prefill());
    });
    println!("{}", r.line());
    results.push(r);
    let r = bench("compile/build_step_4layer", 3, 50, || {
        std::hint::black_box(ScheduleBuilder::new(fc, cfg).unwrap().build_step());
    });
    println!("{}", r.line());
    results.push(r);
    let r = bench("compile/optimize_step_o1", 3, 50, || {
        let mut p = ScheduleBuilder::new(fc, cfg).unwrap().build_step();
        optimize(&mut p, OptLevel::O1, &ArtifactInventory::assume_all()).unwrap();
        std::hint::black_box(p);
    });
    println!("{}", r.line());
    results.push(r);

    let pre = cycle::estimate_prefill(&cfg, &fc).unwrap();
    let step = cycle::estimate_step(&cfg, &fc).unwrap();
    println!(
        "\ncycle estimate ({cfg}): prefill {} cycles / {} dispatches, decode-step {} cycles / {} \
         dispatches ({:.2}% of prefill per token)\n",
        pre.total_cycles,
        pre.dispatches,
        step.total_cycles,
        step.dispatches,
        100.0 * step.total_cycles as f64 / pre.total_cycles as f64,
    );
}

/// PJRT generation section — needs the artifact set incl. decode
/// artifacts.
fn bench_pjrt_generation(results: &mut Vec<BenchResult>) -> anyhow::Result<()> {
    let cfg = presets::gpt_small(48, 2);
    let spec = ModelSpec::new("gpt", cfg, 42);
    let mut engine = TileEngine::new(default_artifact_dir())?;
    engine.program(&cfg)?;
    let stack = engine.prepare_model(&cfg, &spec.weights(), &spec.decoder_weights())?;
    let prompt = weights::init_input(7, 8, cfg.d_model);

    println!("== generation (PJRT) ==");
    println!("{}", header());

    // prefill-only: prompt through the decoder stack + cache population
    let r = bench("generate/prefill_8tok_2layer", 2, 20, || {
        std::hint::black_box(engine.decoder_prefill(&stack, &prompt, None).unwrap());
    });
    println!("{}", r.line());
    results.push(r);

    // per-token decode-step latency, sampled from real generations
    let mut step_samples = Vec::new();
    for i in 0..10 {
        let p = weights::init_input(100 + i, 8, cfg.d_model);
        let g = engine.generate(&stack, &p, None, 9)?;
        step_samples.extend(g.step_times.iter().map(|d| d.as_secs_f64()));
    }
    let summary = summarize(&step_samples);
    let r = BenchResult { name: "generate/decode_step_per_token".into(), summary };
    println!("{}", r.line());
    results.push(r);

    // whole-generation end to end (prefill + 9 steps)
    let r = bench("generate/e2e_10tok_2layer", 1, 10, || {
        std::hint::black_box(engine.generate(&stack, &prompt, None, 10).unwrap());
    });
    println!("{}", r.line());
    results.push(r);
    Ok(())
}

fn decode_artifacts_present() -> bool {
    artifacts_available()
        && Manifest::load(default_artifact_dir())
            .map(|m| m.artifacts.contains_key("kv_append"))
            .unwrap_or(false)
}

fn main() {
    let mut results = Vec::new();
    bench_decoder_compiler(&mut results);
    if decode_artifacts_present() {
        if let Err(e) = bench_pjrt_generation(&mut results) {
            eprintln!("PJRT generation section failed: {e:#}");
        }
    } else {
        println!("(artifacts/ without decode artifacts — skipping the PJRT generation section)");
    }
    if let Err(e) = write_json(JSON_PATH, &results) {
        eprintln!("could not write {JSON_PATH}: {e}");
    } else {
        println!("\nwrote {JSON_PATH} ({} benches)", results.len());
    }
}
