//! Generation-path bench: decoder program compilation, cycle-backend
//! prefill vs per-token pricing, continuous-batching throughput (K
//! interleaved sequences vs one-at-a-time on a single fabric, wave-priced
//! cycle backend), and (with the AOT artifact set present) real PJRT
//! generation — prefill p50/p95/p99 plus per-token decode-step latency
//! through `TileEngine::generate`.
//!
//! Every run writes `BENCH_decode.json` (machine-readable summaries via
//! `util::benchkit::write_json`); without artifacts only the
//! compiler/cycle sections run, so the CI `cargo bench --no-run` job and
//! artifact-free environments still track the schedule-side numbers.

use adaptor::accel::schedule::{optimize, ArtifactInventory, FabricConstants, OptLevel, ScheduleBuilder};
use adaptor::accel::sim::cycle;
use adaptor::coordinator::router::ModelSpec;
use adaptor::coordinator::TileEngine;
use adaptor::model::{presets, weights};
use adaptor::runtime::{artifacts_available, default_artifact_dir, Manifest};
use adaptor::util::benchkit::{bench, header, write_json, BenchResult};
use adaptor::util::stats::summarize;

const JSON_PATH: &str = "BENCH_decode.json";

/// Compiler + cycle-backend section: runs without any artifact set.
fn bench_decoder_compiler(results: &mut Vec<BenchResult>) {
    let fc = FabricConstants::artifact_default();
    let cfg = presets::gpt_small(64, 4);

    println!("== decoder schedule compiler (artifact-free) ==");
    println!("{}", header());
    let r = bench("compile/build_prefill_4layer", 3, 50, || {
        std::hint::black_box(ScheduleBuilder::new(fc, cfg).unwrap().build_prefill());
    });
    println!("{}", r.line());
    results.push(r);
    let r = bench("compile/build_step_4layer", 3, 50, || {
        std::hint::black_box(ScheduleBuilder::new(fc, cfg).unwrap().build_step());
    });
    println!("{}", r.line());
    results.push(r);
    let r = bench("compile/optimize_step_o1", 3, 50, || {
        let mut p = ScheduleBuilder::new(fc, cfg).unwrap().build_step();
        optimize(&mut p, OptLevel::O1, &ArtifactInventory::assume_all()).unwrap();
        std::hint::black_box(p);
    });
    println!("{}", r.line());
    results.push(r);

    let pre = cycle::estimate_prefill(&cfg, &fc).unwrap();
    let step = cycle::estimate_step(&cfg, &fc).unwrap();
    println!(
        "\ncycle estimate ({cfg}): prefill {} cycles / {} dispatches, decode-step {} cycles / {} \
         dispatches ({:.2}% of prefill per token)\n",
        pre.total_cycles,
        pre.dispatches,
        step.total_cycles,
        step.dispatches,
        100.0 * step.total_cycles as f64 / pre.total_cycles as f64,
    );
}

/// Continuous-batching section (artifact-free): price K interleaved
/// generations on ONE fabric with the wave-priced cycle backend against
/// serving the same K jobs one at a time.
///
/// The scheduler model mirrors `coordinator::server`'s sequence
/// scheduler.  One-at-a-time serving drains each job fully — the fabric
/// sees a *dependent* chain, every prefill and every decode step pays
/// its full latency.  Continuous batching exposes inter-sequence
/// independence at iteration granularity: the K admission prefills are
/// mutually independent, so back-to-back replays stream through the
/// module pipeline at the prefill program's initiation interval (its
/// slowest wave, `CycleReport::max_wave_cycles`), and each decode round
/// runs K independent step programs the same way — only consecutive
/// steps of the *same* sequence (token t feeds token t+1) pay the full
/// step latency between rounds.
fn bench_concurrent_generation(results: &mut Vec<BenchResult>) {
    const K: usize = 8; // concurrent sequences (the live-set size)
    const N: u64 = 56; // tokens per sequence (8-row prompt + 56 <= sl 64)
    const FREQ_MHZ: f64 = 200.0;
    let fc = FabricConstants::artifact_default();
    let cfg = presets::gpt_small(64, 4);

    let mut pre = ScheduleBuilder::new(fc, cfg).unwrap().build_prefill();
    optimize(&mut pre, OptLevel::O1, &ArtifactInventory::assume_all()).unwrap();
    let mut step = ScheduleBuilder::new(fc, cfg).unwrap().build_step();
    optimize(&mut step, OptLevel::O1, &ArtifactInventory::assume_all()).unwrap();
    let p = cycle::replay_decoder_program_waves(&pre).unwrap();
    let s = cycle::replay_decoder_program_waves(&step).unwrap();
    let (p_cy, s_cy) = (p.total_cycles as f64, s.total_cycles as f64);
    let (ii_p, ii_s) = (p.max_wave_cycles as f64, s.max_wave_cycles as f64);
    assert!(ii_p > 0.0 && ii_p < p_cy, "wave-scheduled prefill must pipeline");
    assert!(ii_s > 0.0 && ii_s < s_cy, "wave-scheduled step must pipeline");

    let k = K as f64;
    let n1 = (N - 1) as f64;
    // One at a time: K dependent chains of prefill + (N-1) full steps.
    let sequential = k * (p_cy + n1 * s_cy);
    // Continuous: pipelined admission burst, then N-1 decode rounds of
    // K independent steps each (first step full, the rest at the II).
    let concurrent = (p_cy + (k - 1.0) * ii_p) + n1 * (s_cy + (k - 1.0) * ii_s);
    let speedup = sequential / concurrent;

    let secs = |cy: f64| cy / (FREQ_MHZ * 1e6);
    let tokens = (K as u64 * N) as f64;
    let tput_seq = tokens / secs(sequential);
    let tput_conc = tokens / secs(concurrent);

    // TTFT per sequence: one-at-a-time holds job i behind i whole jobs;
    // continuous batching admits every prefill in the opening burst.
    let ttft_seq: Vec<f64> =
        (0..K).map(|i| secs(i as f64 * (p_cy + n1 * s_cy) + p_cy)).collect();
    let ttft_conc: Vec<f64> = (0..K).map(|i| secs(p_cy + i as f64 * ii_p)).collect();
    let mean = |xs: &[f64]| xs.iter().sum::<f64>() / xs.len() as f64;

    println!("== continuous batching (cycle backend, 1 fabric, K={K} x {N} tokens) ==");
    println!(
        "prefill {} cy (II {} cy), step {} cy (II {} cy, {:.1}% of step)",
        p.total_cycles,
        p.max_wave_cycles,
        s.total_cycles,
        s.max_wave_cycles,
        100.0 * ii_s / s_cy,
    );
    println!(
        "aggregate: one-at-a-time {tput_seq:.0} tok/s, continuous {tput_conc:.0} tok/s \
         ({speedup:.2}x)",
    );
    println!(
        "mean TTFT: one-at-a-time {:.2} ms, continuous {:.2} ms\n",
        1e3 * mean(&ttft_seq),
        1e3 * mean(&ttft_conc),
    );
    results.push(BenchResult {
        name: format!("concurrent/tokens_per_s_one_at_a_time_k{K}"),
        summary: summarize(&[tput_seq]),
    });
    results.push(BenchResult {
        name: format!("concurrent/tokens_per_s_continuous_k{K}"),
        summary: summarize(&[tput_conc]),
    });
    results.push(BenchResult {
        name: format!("concurrent/speedup_k{K}"),
        summary: summarize(&[speedup]),
    });
    results.push(BenchResult {
        name: format!("concurrent/ttft_one_at_a_time_k{K}"),
        summary: summarize(&ttft_seq),
    });
    results.push(BenchResult {
        name: format!("concurrent/ttft_continuous_k{K}"),
        summary: summarize(&ttft_conc),
    });

    // The PR's acceptance bar: iteration-level scheduling must at least
    // double aggregate tokens/sec over the one-at-a-time baseline.
    assert!(
        speedup >= 2.0,
        "continuous batching must reach >= 2x aggregate throughput (got {speedup:.2}x)"
    );
}

/// PJRT generation section — needs the artifact set incl. decode
/// artifacts.
fn bench_pjrt_generation(results: &mut Vec<BenchResult>) -> anyhow::Result<()> {
    let cfg = presets::gpt_small(48, 2);
    let spec = ModelSpec::new("gpt", cfg, 42);
    let mut engine = TileEngine::new(default_artifact_dir())?;
    engine.program(&cfg)?;
    let stack = engine.prepare_model(&cfg, &spec.weights(), &spec.decoder_weights())?;
    let prompt = weights::init_input(7, 8, cfg.d_model);

    println!("== generation (PJRT) ==");
    println!("{}", header());

    // prefill-only: prompt through the decoder stack + cache population
    let r = bench("generate/prefill_8tok_2layer", 2, 20, || {
        std::hint::black_box(engine.decoder_prefill(&stack, &prompt, None).unwrap());
    });
    println!("{}", r.line());
    results.push(r);

    // per-token decode-step latency, sampled from real generations
    let mut step_samples = Vec::new();
    for i in 0..10 {
        let p = weights::init_input(100 + i, 8, cfg.d_model);
        let g = engine.generate(&stack, &p, None, 9)?;
        step_samples.extend(g.step_times.iter().map(|d| d.as_secs_f64()));
    }
    let summary = summarize(&step_samples);
    let r = BenchResult { name: "generate/decode_step_per_token".into(), summary };
    println!("{}", r.line());
    results.push(r);

    // whole-generation end to end (prefill + 9 steps)
    let r = bench("generate/e2e_10tok_2layer", 1, 10, || {
        std::hint::black_box(engine.generate(&stack, &prompt, None, 10).unwrap());
    });
    println!("{}", r.line());
    results.push(r);
    Ok(())
}

fn decode_artifacts_present() -> bool {
    artifacts_available()
        && Manifest::load(default_artifact_dir())
            .map(|m| m.artifacts.contains_key("kv_append"))
            .unwrap_or(false)
}

fn main() {
    let mut results = Vec::new();
    bench_decoder_compiler(&mut results);
    bench_concurrent_generation(&mut results);
    if decode_artifacts_present() {
        if let Err(e) = bench_pjrt_generation(&mut results) {
            eprintln!("PJRT generation section failed: {e:#}");
        }
    } else {
        println!("(artifacts/ without decode artifacts — skipping the PJRT generation section)");
    }
    if let Err(e) = write_json(JSON_PATH, &results) {
        eprintln!("could not write {JSON_PATH}: {e}");
    } else {
        println!("\nwrote {JSON_PATH} ({} benches)", results.len());
    }
}
