//! Bench + regenerator for Fig 9: resource utilization vs tile sizes,
//! timing the analytical resource models (Eq 8 / Eq 25 / structural).
use adaptor::accel::{platform, resources, tiling::TileConfig};
use adaptor::analysis::report;
use adaptor::model::quant::BitWidth;
use adaptor::model::TnnConfig;
use adaptor::util::benchkit::{bench, run_suite};

fn main() {
    let (text, _) = report::fig09();
    println!("{text}");
    let cfg = TnnConfig::encoder(64, 768, 8, 12);
    let p = platform::u55c();
    let t = TileConfig::paper_optimum();
    let cases = vec![
        bench("fig9/eq8_dsps", 10, 1000, || {
            std::hint::black_box(resources::dsps_eq8(&cfg, &t));
        }),
        bench("fig9/eq25_brams", 10, 1000, || {
            std::hint::black_box(resources::brams_eq25(&cfg, &t, 32.0));
        }),
        bench("fig9/full_estimate", 10, 1000, || {
            std::hint::black_box(resources::estimate(&cfg, &t, BitWidth::Fixed16, &p));
        }),
    ];
    run_suite("Fig 9 — resource models", cases);
}
