//! Hot-path bench: the L3 request path over PJRT — tile dispatch cost,
//! per-layer cost, attention-mode ablation (split vs fused), optimized
//! (wave-scheduled/fused) vs raw TileProgram replay, tiled vs fused-layer
//! artifacts, and end-to-end inference.  This is the bench the §Perf
//! optimization loop iterates against (EXPERIMENTS.md §Perf).
//!
//! Every run — with or without the AOT artifact set — writes
//! `BENCH_hotpath.json` (machine-readable p50/p95/p99 per bench via
//! `util::benchkit::write_json`) so the perf trajectory is tracked across
//! PRs.  Without artifacts only the compiler/cycle-backend sections run.

use adaptor::accel::schedule::{
    optimize, ArtifactInventory, FabricConstants, OptLevel, ScheduleBuilder,
};
use adaptor::accel::sim::cycle;
use adaptor::coordinator::{AttentionMode, TileEngine};
use adaptor::model::{presets, weights, TnnConfig};
use adaptor::runtime::{artifacts_available, default_artifact_dir, Tensor};
use adaptor::util::benchkit::{bench, header, write_json, BenchResult};

const JSON_PATH: &str = "BENCH_hotpath.json";

/// Compiler + cycle-backend section: runs without any artifact set.
fn bench_schedule_compiler(results: &mut Vec<BenchResult>) {
    let fc = FabricConstants::artifact_default();
    let cfg = presets::small_encoder(64, 4);
    let build = || ScheduleBuilder::new(fc, cfg).unwrap().build();

    println!("== schedule compiler (artifact-free) ==");
    println!("{}", header());
    let r = bench("compile/build_program_4layer", 3, 50, || {
        std::hint::black_box(build());
    });
    println!("{}", r.line());
    results.push(r);
    let r = bench("compile/optimize_o2_4layer", 3, 50, || {
        let mut p = build();
        optimize(&mut p, OptLevel::O2, &ArtifactInventory::assume_all()).unwrap();
        std::hint::black_box(p);
    });
    println!("{}", r.line());
    results.push(r);

    let raw = build();
    let mut opt = build();
    let report = optimize(&mut opt, OptLevel::O2, &ArtifactInventory::assume_all()).unwrap();
    let r = bench("cycle/replay_raw_4layer", 3, 30, || {
        std::hint::black_box(cycle::replay_program(&raw).unwrap());
    });
    println!("{}", r.line());
    results.push(r);
    let r = bench("cycle/replay_waves_4layer", 3, 30, || {
        std::hint::black_box(cycle::replay_program_waves(&opt).unwrap());
    });
    println!("{}", r.line());
    results.push(r);

    let seq = cycle::replay_program(&raw).unwrap();
    let waved = cycle::replay_program_waves(&opt).unwrap();
    println!(
        "\nprogram opt ({}): dispatches+uploads {}+{} -> {}+{}, slots {} -> {}, {} waves (max {} concurrent dispatches)",
        report
            .applied
            .iter()
            .map(|(n, c)| format!("{n}:{c}"))
            .collect::<Vec<_>>()
            .join(" "),
        raw.dispatch_count(),
        raw.upload_count(),
        opt.dispatch_count(),
        opt.upload_count(),
        raw.n_slots,
        opt.n_slots,
        opt.wave_count(),
        opt.max_wave_dispatches(),
    );
    println!(
        "cycle estimate: sequential {} -> wave-priced {} predicted cycles ({:.1}% cut)\n",
        seq.total_cycles,
        waved.total_cycles,
        100.0 * (1.0 - waved.total_cycles as f64 / seq.total_cycles as f64),
    );

    // --- length-adaptive request price per bucket: the cycles a request
    // of 1/4, 1/2 and full seq_len pays through the covering bucket's
    // skippable program, against the dense max-length program every
    // request used to pay (the recovered padding waste, Table 2's
    // per-bucket rows).  Each row also lands in BENCH_hotpath.json.
    println!("== length-adaptive dispatch (artifact-free) ==");
    println!("{}", header());
    let dense_cycles = cycle::replay_program(&opt).unwrap().total_cycles;
    for rows in [cfg.seq_len / 4, cfg.seq_len / 2, cfg.seq_len] {
        let rep = cycle::estimate_adaptive(&cfg, &fc, rows, OptLevel::O2).unwrap();
        let r = bench(&format!("cycle/adaptive_live{rows}_of{}", cfg.seq_len), 3, 30, || {
            std::hint::black_box(cycle::estimate_adaptive(&cfg, &fc, rows, OptLevel::O2).unwrap());
        });
        println!("{}", r.line());
        results.push(r);
        println!(
            "    {rows:>3} live rows: {} cycles vs {} dense ({:.1}% recovered)",
            rep.total_cycles,
            dense_cycles,
            100.0 * (1.0 - rep.total_cycles as f64 / dense_cycles as f64),
        );
    }
    println!();
}

fn bench_pjrt(results: &mut Vec<BenchResult>) -> anyhow::Result<()> {
    let mut engine = TileEngine::new(default_artifact_dir())?;
    let exec_names =
        ["mm_qkv", "mm_ffn1", "mm_ffn2", "mm_ffn3", "qk_scores", "softmax", "sv", "attn_fused",
         "bias_add_dk", "bias_add_d", "bias_relu_h", "residual_ln"];
    engine.executor().warmup(&exec_names)?;

    println!("== hot path (PJRT) ==");
    println!("{}", header());

    // --- single tile dispatch (the innermost hot operation)
    {
        let x = Tensor::zeros(vec![128, 64]);
        let w = Tensor::zeros(vec![64, 64]);
        let acc = Tensor::zeros(vec![128, 64]);
        let e = engine.executor();
        let r = bench("dispatch/mm_qkv_tile", 20, 500, || {
            std::hint::black_box(e.run1("mm_qkv", &[&x, &w, &acc]).unwrap());
        });
        println!("{}", r.line());
        results.push(r);
    }
    {
        let x = Tensor::zeros(vec![128, 128]);
        let w = Tensor::zeros(vec![128, 512]);
        let acc = Tensor::zeros(vec![128, 512]);
        let e = engine.executor();
        let r = bench("dispatch/mm_ffn2_tile", 20, 500, || {
            std::hint::black_box(e.run1("mm_ffn2", &[&x, &w, &acc]).unwrap());
        });
        println!("{}", r.line());
        results.push(r);
    }
    {
        let q = Tensor::zeros(vec![128, 64]);
        let m = Tensor::zeros(vec![128, 128]);
        let s = Tensor::scalar1(0.125);
        let e = engine.executor();
        let r = bench("dispatch/attn_fused_head", 20, 500, || {
            std::hint::black_box(e.run1("attn_fused", &[&q, &q, &q, &m, &s]).unwrap());
        });
        println!("{}", r.line());
        results.push(r);
    }

    // --- full encoder layer: attention mode × opt level (the tentpole
    // comparison: raw replay vs the optimized program the pool serves)
    let cfg = presets::small_encoder(64, 1);
    let ws = weights::init_stack(1, cfg.d_model, cfg.heads, 1);
    engine.program(&cfg)?;
    let prepared = engine.prepare(&cfg, &ws)?;
    let x = weights::init_input(2, cfg.seq_len, cfg.d_model);
    for (mode, level) in [
        (AttentionMode::Split, OptLevel::O0),
        (AttentionMode::Split, OptLevel::O2),
        (AttentionMode::Fused, OptLevel::O0),
        (AttentionMode::Fused, OptLevel::O2),
    ] {
        engine.mode = mode;
        engine.opt_level = level;
        engine.run_encoder(&prepared, &x)?; // warm the program cache
        let s0 = engine.executor().stats();
        let name = format!("layer/small_encoder_{mode:?}_{level:?}");
        // no bench-warmup runs: the stats delta below must cover exactly
        // the 30 timed replays
        let r = bench(&name, 0, 30, || {
            std::hint::black_box(engine.run_encoder(&prepared, &x).unwrap());
        });
        let s1 = engine.executor().stats();
        println!("{}", r.line());
        results.push(r);
        let per = |a: u64, b: u64| (b - a) / 30;
        println!(
            "    ({} dispatches + {} uploads per replay)",
            per(s0.dispatches, s1.dispatches),
            per(s0.uploads, s1.uploads),
        );
    }
    engine.mode = AttentionMode::Split;
    engine.opt_level = OptLevel::O2;

    // --- tiled engine vs fused per-config artifact (adaptivity tax)
    {
        let r = bench("layer/fused_artifact_small", 2, 30, || {
            std::hint::black_box(engine.run_fused_stack("small_layer", &x, &ws).unwrap());
        });
        println!("{}", r.line());
        results.push(r);
    }

    // --- end-to-end 4-layer model
    let cfg4 = presets::small_encoder(64, 4);
    let ws4 = weights::init_stack(3, cfg4.d_model, cfg4.heads, 4);
    engine.program(&cfg4)?;
    let prep4 = engine.prepare(&cfg4, &ws4)?;
    let x4 = weights::init_input(4, cfg4.seq_len, cfg4.d_model);
    engine.mode = AttentionMode::Fused;
    let r = bench("e2e/small_encoder_4layer", 1, 10, || {
        std::hint::black_box(engine.run_encoder(&prep4, &x4).unwrap());
    });
    println!("{}", r.line());
    results.push(r);

    // --- bigger topology (BERT-ish single layer at runtime maxima)
    let cfg_b = TnnConfig::encoder(128, 768, 12, 1);
    let ws_b = weights::init_stack(5, cfg_b.d_model, cfg_b.heads, 1);
    engine.program(&cfg_b)?;
    let prep_b = engine.prepare(&cfg_b, &ws_b)?;
    let x_b = weights::init_input(6, cfg_b.seq_len, cfg_b.d_model);
    let r = bench("e2e/bert_like_1layer_sl128", 1, 5, || {
        std::hint::black_box(engine.run_encoder(&prep_b, &x_b).unwrap());
    });
    println!("{}", r.line());
    results.push(r);

    // --- schedule cache: the request path is "look up program, replay"
    {
        let (hits, misses) = engine.program_cache_stats();
        println!(
            "\nprogram cache: {hits} hits / {misses} misses (every post-warmup request replays a cached TileProgram)"
        );
        let (phits, pmisses) = engine.tensor_pool_stats();
        println!("host-scratch pool: {phits} hits / {pmisses} misses");
        let rep = engine.cycle_estimate(&cfg4)?;
        let waved = engine.cycle_estimate_waves(&cfg4)?;
        println!(
            "schedule replay (cycle backend, identical program): {} predicted cycles over {} dispatches; wave-priced: {}",
            rep.total_cycles, rep.dispatches, waved.total_cycles
        );
    }

    let st = engine.executor().stats();
    println!(
        "\ntotals: {} dispatches, {} uploads ({} zero-pool hits), {} fetches, {} compiles, {:.2}s inside PJRT execute",
        st.dispatches, st.uploads, st.pool_hits, st.fetches, st.compiles, st.execute_secs
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let mut results: Vec<BenchResult> = Vec::new();
    bench_schedule_compiler(&mut results);
    let pjrt = if artifacts_available() {
        bench_pjrt(&mut results)
    } else {
        println!("artifacts/ not present — skipping the PJRT sections (run `make artifacts`)");
        Ok(())
    };
    // Written even when the PJRT section errored: the artifact-free
    // results collected so far are still a tracked data point.
    write_json(JSON_PATH, &results)?;
    println!("\nwrote {JSON_PATH} ({} benches)", results.len());
    pjrt
}
