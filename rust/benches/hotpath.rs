//! Hot-path bench: the L3 request path over PJRT — tile dispatch cost,
//! per-layer cost, attention-mode ablation (split vs fused), tiled vs
//! fused-layer artifacts, and end-to-end inference.  This is the bench the
//! §Perf optimization loop iterates against (EXPERIMENTS.md §Perf).

use adaptor::coordinator::{AttentionMode, TileEngine};
use adaptor::model::{presets, weights, TnnConfig};
use adaptor::runtime::{default_artifact_dir, Tensor};
use adaptor::util::benchkit::{bench, header};

fn main() -> anyhow::Result<()> {
    let mut engine = TileEngine::new(default_artifact_dir())?;
    let exec_names =
        ["mm_qkv", "mm_ffn1", "mm_ffn2", "mm_ffn3", "qk_scores", "softmax", "sv", "attn_fused",
         "bias_add_dk", "bias_add_d", "bias_relu_h", "residual_ln"];
    engine.executor().warmup(&exec_names)?;

    println!("== hot path ==");
    println!("{}", header());

    // --- single tile dispatch (the innermost hot operation)
    {
        let x = Tensor::zeros(vec![128, 64]);
        let w = Tensor::zeros(vec![64, 64]);
        let acc = Tensor::zeros(vec![128, 64]);
        let e = engine.executor();
        let r = bench("dispatch/mm_qkv_tile", 20, 500, || {
            std::hint::black_box(e.run1("mm_qkv", &[&x, &w, &acc]).unwrap());
        });
        println!("{}", r.line());
    }
    {
        let x = Tensor::zeros(vec![128, 128]);
        let w = Tensor::zeros(vec![128, 512]);
        let acc = Tensor::zeros(vec![128, 512]);
        let e = engine.executor();
        let r = bench("dispatch/mm_ffn2_tile", 20, 500, || {
            std::hint::black_box(e.run1("mm_ffn2", &[&x, &w, &acc]).unwrap());
        });
        println!("{}", r.line());
    }
    {
        let q = Tensor::zeros(vec![128, 64]);
        let m = Tensor::zeros(vec![128, 128]);
        let s = Tensor::scalar1(0.125);
        let e = engine.executor();
        let r = bench("dispatch/attn_fused_head", 20, 500, || {
            std::hint::black_box(e.run1("attn_fused", &[&q, &q, &q, &m, &s]).unwrap());
        });
        println!("{}", r.line());
    }

    // --- full encoder layer, split vs fused attention (ablation)
    let cfg = presets::small_encoder(64, 1);
    let ws = weights::init_stack(1, cfg.d_model, cfg.heads, 1);
    engine.program(&cfg)?;
    let prepared = engine.prepare(&cfg, &ws)?;
    let x = weights::init_input(2, cfg.seq_len, cfg.d_model);
    for mode in [AttentionMode::Split, AttentionMode::Fused] {
        engine.mode = mode;
        let name = format!("layer/small_encoder_{mode:?}");
        let r = bench(&name, 2, 30, || {
            std::hint::black_box(engine.run_encoder(&prepared, &x).unwrap());
        });
        println!("{}", r.line());
    }

    // --- tiled engine vs fused per-config artifact (adaptivity tax)
    {
        let r = bench("layer/fused_artifact_small", 2, 30, || {
            std::hint::black_box(engine.run_fused_stack("small_layer", &x, &ws).unwrap());
        });
        println!("{}", r.line());
    }

    // --- end-to-end 4-layer model
    let cfg4 = presets::small_encoder(64, 4);
    let ws4 = weights::init_stack(3, cfg4.d_model, cfg4.heads, 4);
    engine.program(&cfg4)?;
    let prep4 = engine.prepare(&cfg4, &ws4)?;
    let x4 = weights::init_input(4, cfg4.seq_len, cfg4.d_model);
    engine.mode = AttentionMode::Fused;
    let r = bench("e2e/small_encoder_4layer", 1, 10, || {
        std::hint::black_box(engine.run_encoder(&prep4, &x4).unwrap());
    });
    println!("{}", r.line());

    // --- bigger topology (BERT-ish single layer at runtime maxima)
    let cfg_b = TnnConfig::encoder(128, 768, 12, 1);
    let ws_b = weights::init_stack(5, cfg_b.d_model, cfg_b.heads, 1);
    engine.program(&cfg_b)?;
    let prep_b = engine.prepare(&cfg_b, &ws_b)?;
    let x_b = weights::init_input(6, cfg_b.seq_len, cfg_b.d_model);
    let r = bench("e2e/bert_like_1layer_sl128", 1, 5, || {
        std::hint::black_box(engine.run_encoder(&prep_b, &x_b).unwrap());
    });
    println!("{}", r.line());

    // --- schedule cache: the request path is "look up program, replay"
    {
        let (hits, misses) = engine.program_cache_stats();
        println!(
            "\nprogram cache: {hits} hits / {misses} misses (every post-warmup request replays a cached TileProgram)"
        );
        let rep = engine.cycle_estimate(&cfg4)?;
        println!(
            "schedule replay (cycle backend, identical program): {} predicted cycles over {} dispatches for small_encoder_4layer",
            rep.total_cycles, rep.dispatches
        );
    }

    let st = engine.executor().stats();
    println!(
        "\ntotals: {} dispatches, {} uploads, {} fetches, {} compiles, {:.2}s inside PJRT execute",
        st.dispatches, st.uploads, st.fetches, st.compiles, st.execute_secs
    );
    Ok(())
}
