//! Multi-model churn bench: weight residency vs the paper's
//! reprogram-on-every-switch host loop.
//!
//! Three presets are round-robined over a 2-fabric pool whose per-fabric
//! weight memory holds only **two** of the three stacks (capacity = the
//! two largest footprints).  The managed run uses the serving default —
//! `SchedulePolicy::CostAware` placement over per-fabric
//! `WeightResidencyManager`s — so model↔fabric affinity emerges from
//! residency and the pool settles into a stable split after three
//! uploads.  The baseline run is `RoundRobin` + `ReprogramAlways`:
//! every dispatch re-uploads the whole stack, exactly as the paper's
//! host loop reprograms on every model switch.
//!
//! Outputs are modeled as a deterministic mix of (resident-stack
//! fingerprint, request index), so a stale or wrongly-evicted stack
//! would break the managed↔baseline checksum equality the bench
//! asserts.  `BENCH_residency.json` is **deliberately timing-free**:
//! every field is a deterministic counter or integer cycle derivation
//! (upload beats at 64 B/cycle, `residency::UPLOAD_BYTES_PER_CYCLE`),
//! so the tracked file is bit-stable across machines and PRs.  Wall
//! timings of the manager hot path print to stdout only.

use std::collections::VecDeque;

use adaptor::accel::schedule::FabricConstants;
use adaptor::coordinator::residency::{upload_cycles, weight_footprint_bytes};
use adaptor::coordinator::{
    PoolScheduler, ResidencyMode, ResidencyPolicy, SchedulePolicy, WeightResidencyManager,
};
use adaptor::model::presets;
use adaptor::util::benchkit::{bench, header};
use adaptor::util::json;

const JSON_PATH: &str = "BENCH_residency.json";
const PRESETS: [&str; 3] = ["gpt-small", "shallow", "custom-encoder-4l"];
const REQUESTS: usize = 300;
const POOL: usize = 2;
/// Dispatches kept in flight before the oldest completes — deep enough
/// to spread load across the pool, shallower than the upload penalty so
/// placement stays residency-sticky.
const WINDOW: usize = 4;
/// Constant reprogram penalty (queued-request equivalents) handed to the
/// cost-aware scorer.  The serve path prices this per model via
/// `residency::upload_penalty_requests`; the bench pins one value larger
/// than any in-flight gap so the placement trace — and with it the
/// committed JSON — is independent of the cycle backend.
const PENALTY: f64 = 8.0;

fn fnv64(s: &str) -> u64 {
    s.bytes().fold(0xcbf29ce484222325, |h, b| (h ^ b as u64).wrapping_mul(0x100000001b3))
}

/// Deterministic stand-in for one served request's output: a mix of the
/// resident stack's fingerprint and the request index.
fn output_token(stack: u64, request: u64) -> u64 {
    (stack ^ request.wrapping_mul(0x9e3779b97f4a7c15)).wrapping_mul(0x100000001b3)
}

struct RunStats {
    uploads: u64,
    hits: u64,
    evictions: u64,
    upload_cycles_total: u64,
    resident_bytes_peak: u64,
    checksum: u64,
}

/// One churn run: `REQUESTS` dispatches of the preset round-robin over a
/// `POOL`-fabric pool, driving the real `PoolScheduler` and one real
/// `WeightResidencyManager` per fabric exactly as the serve path does
/// (pick → acquire → residency snapshot back to the scheduler →
/// completion when the in-flight window slides).
fn run_churn(
    policy: SchedulePolicy,
    mode: ResidencyMode,
    models: &[(&str, u64)],
    capacity_bytes: u64,
) -> RunStats {
    let mut sched = PoolScheduler::new(policy, POOL);
    let rp = ResidencyPolicy { mode, capacity_bytes, ..ResidencyPolicy::default() };
    let mut mgrs: Vec<WeightResidencyManager<u64>> =
        (0..POOL).map(|_| WeightResidencyManager::new(rp)).collect();
    for (name, _) in models {
        sched.set_upload_penalty(name, PENALTY);
    }

    let mut inflight: VecDeque<usize> = VecDeque::new();
    let mut upload_cycles_total = 0u64;
    let mut checksum = 0u64;
    for r in 0..REQUESTS {
        let (name, bytes) = models[r % models.len()];
        let f = sched.pick(name, None, 1);
        let before = mgrs[f].stats().uploads;
        mgrs[f]
            .acquire_with(name, bytes, None, || Ok(fnv64(name)))
            .expect("in-memory loader cannot fail");
        if mgrs[f].stats().uploads > before {
            upload_cycles_total += upload_cycles(bytes);
        }
        sched.note_residency(f, &mgrs[f].resident_models());
        let stack = *mgrs[f].get(name).expect("just acquired");
        checksum = (checksum ^ output_token(stack, r as u64)).wrapping_mul(0x100000001b3);
        inflight.push_back(f);
        if inflight.len() >= WINDOW {
            let done = inflight.pop_front().expect("non-empty window");
            sched.complete(done, 1);
        }
    }

    let mut s = RunStats {
        uploads: 0,
        hits: 0,
        evictions: 0,
        upload_cycles_total,
        resident_bytes_peak: 0,
        checksum,
    };
    for m in &mgrs {
        let st = m.stats();
        s.uploads += st.uploads;
        s.hits += st.hits;
        s.evictions += st.evictions;
        s.resident_bytes_peak = s.resident_bytes_peak.max(st.resident_bytes_peak);
    }
    s
}

fn stats_json(s: &RunStats) -> String {
    format!(
        concat!(
            "{{\"uploads\": {}, \"hits\": {}, \"evictions\": {}, ",
            "\"upload_cycles_total\": {}, \"upload_cycles_per_request\": {:.2}, ",
            "\"resident_bytes_peak\": {}, \"outputs_checksum\": \"{:016x}\"}}"
        ),
        s.uploads,
        s.hits,
        s.evictions,
        s.upload_cycles_total,
        s.upload_cycles_total as f64 / REQUESTS as f64,
        s.resident_bytes_peak,
        s.checksum,
    )
}

fn main() -> anyhow::Result<()> {
    let fc = FabricConstants::artifact_default();
    let models: Vec<(&str, u64)> = PRESETS
        .iter()
        .map(|name| {
            let cfg = presets::by_name(name).expect("known preset");
            (*name, weight_footprint_bytes(&cfg, &fc))
        })
        .collect();
    // Per-fabric capacity = the two largest stacks: any two presets are
    // co-resident, all three are not.
    let mut sizes: Vec<u64> = models.iter().map(|(_, b)| *b).collect();
    sizes.sort_unstable();
    let capacity_bytes: u64 = sizes.iter().rev().take(2).sum();

    println!("== weight-residency churn ({REQUESTS} requests, {POOL} fabrics) ==");
    for (name, bytes) in &models {
        println!("  {name:<20} {bytes:>12} bytes ({} upload cycles)", upload_cycles(*bytes));
    }
    println!("  per-fabric weight memory: {capacity_bytes} bytes (two largest stacks)\n");

    let managed =
        run_churn(SchedulePolicy::CostAware, ResidencyMode::Managed, &models, capacity_bytes);
    let baseline = run_churn(
        SchedulePolicy::RoundRobin,
        ResidencyMode::ReprogramAlways,
        &models,
        capacity_bytes,
    );

    let fmt = |s: &RunStats, label: &str| {
        println!(
            "{label:<18} {:>7} uploads {:>7} hits {:>9} evictions {:>12} upload cycles",
            s.uploads, s.hits, s.evictions, s.upload_cycles_total
        );
    };
    fmt(&managed, "managed+costaware");
    fmt(&baseline, "reprogram-always");
    assert_eq!(
        managed.checksum, baseline.checksum,
        "residency caching changed the served outputs"
    );
    assert!(
        managed.uploads < baseline.uploads,
        "managed must upload strictly less than reprogram-always"
    );
    println!(
        "\nupload reduction: {}x fewer stack uploads, bit-identical outputs",
        baseline.uploads / managed.uploads
    );

    // Manager hot-path wall timings — stdout only, never in the JSON.
    println!("\n{}", header());
    let rp = ResidencyPolicy { capacity_bytes, ..ResidencyPolicy::default() };
    let mut m: WeightResidencyManager<u64> = WeightResidencyManager::new(rp);
    let r = bench("residency/acquire_hit", 10, 200, || {
        for (name, bytes) in &models[..2] {
            m.acquire_with(name, *bytes, None, || Ok(1)).unwrap();
        }
    });
    println!("{}", r.line());
    let mut m: WeightResidencyManager<u64> = WeightResidencyManager::new(ResidencyPolicy {
        capacity_bytes: sizes.iter().rev().take(1).sum(),
        ..ResidencyPolicy::default()
    });
    let r = bench("residency/evict_reload_churn", 10, 200, || {
        for (name, bytes) in &models {
            m.acquire_with(name, *bytes, None, || Ok(1)).unwrap();
        }
    });
    println!("{}", r.line());

    let json_text = format!(
        concat!(
            "{{\n",
            "  \"bench\": \"residency_churn\",\n",
            "  \"note\": \"deterministic counters and cycle derivations only; ",
            "no wall-clock fields\",\n",
            "  \"workload\": {{\"presets\": [{}], \"requests\": {}, \"pool\": {}, ",
            "\"window\": {}, \"capacity_bytes\": {}, \"upload_penalty_requests\": {:.1}}},\n",
            "  \"footprint_bytes\": {{{}}},\n",
            "  \"managed_costaware\": {},\n",
            "  \"reprogram_always\": {},\n",
            "  \"upload_reduction_factor\": {:.2},\n",
            "  \"bit_identical\": true\n",
            "}}\n"
        ),
        PRESETS.map(|p| format!("\"{p}\"")).join(", "),
        REQUESTS,
        POOL,
        WINDOW,
        capacity_bytes,
        PENALTY,
        models
            .iter()
            .map(|(n, b)| format!("\"{n}\": {b}"))
            .collect::<Vec<_>>()
            .join(", "),
        stats_json(&managed),
        stats_json(&baseline),
        baseline.uploads as f64 / managed.uploads as f64,
    );
    json::parse(&json_text).map_err(|e| anyhow::anyhow!("bench JSON is malformed: {e}"))?;
    std::fs::write(JSON_PATH, &json_text)?;
    println!("\nwrote {JSON_PATH}");
    Ok(())
}
