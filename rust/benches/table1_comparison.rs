//! Bench + regenerator for Table 1: FPGA-accelerator comparison (paper
//! rows + substrate-measured ADAPTOR rows), plus the end-to-end latency
//! model evaluation each substrate row depends on.
use adaptor::accel::{latency, tiling::TileConfig};
use adaptor::analysis::report;
use adaptor::model::presets;
use adaptor::util::benchkit::{bench, run_suite};

fn main() {
    let (text, _) = report::table1();
    println!("{text}");
    let t = TileConfig::paper_optimum();
    let bert = presets::bert_base(64);
    let shallow = presets::shallow_transformer();
    let cases = vec![
        bench("table1/bert_latency_model", 10, 2000, || {
            std::hint::black_box(latency::model_latency(&bert, &t));
        }),
        bench("table1/shallow_latency_model", 10, 2000, || {
            std::hint::black_box(latency::model_latency(&shallow, &t));
        }),
    ];
    run_suite("Table 1 — comparison inputs", cases);
}
