//! Optimizer equivalence: every `accel::schedule::opt` pass must preserve
//! replay semantics.  These tests run **without** the AOT artifact set by
//! replaying programs on a *pseudo-numeric* backend whose dispatch output
//! is a deterministic pure function of `(artifact, input values)` — if
//! the optimized program feeds every dispatch bit-identical operands in a
//! legal order, its replay output is bit-identical to the raw program's.
//! (The PJRT counterparts, gated on artifacts, live in
//! `integration_program.rs`.)

use std::collections::HashMap;

use adaptor::accel::schedule::{
    self, opt, optimize, ArtifactInventory, FabricConstants, OptLevel, ScheduleBuilder,
    TileProgram, WeightKind, WeightRef, WeightSource,
};
use adaptor::model::TnnConfig;
use adaptor::runtime::{FabricBackend, Tensor, TensorPool};

fn fc() -> FabricConstants {
    FabricConstants::artifact_default()
}

/// Topologies legal on the default fabric (seq_len, heads, width and
/// depth all vary — the property must hold across the space).
fn topology_sweep() -> Vec<TnnConfig> {
    vec![
        TnnConfig::encoder(16, 128, 2, 1),
        TnnConfig::encoder(32, 256, 4, 2),
        TnnConfig::encoder(48, 128, 2, 3),
        TnnConfig::encoder(64, 384, 6, 1),
        TnnConfig::encoder(128, 128, 2, 1),
    ]
}

fn fnv(s: &str) -> u32 {
    s.bytes().fold(2166136261u32, |h, b| (h ^ b as u32).wrapping_mul(16777619))
}

/// A backend whose buffers are host tensors and whose dispatch output is
/// a bounded, deterministic mix of its inputs.  Reordering independent
/// dispatches cannot change any output; feeding a different value (or the
/// same values in a different argument order) must.
struct HashBackend;

impl FabricBackend for HashBackend {
    type Buf = Tensor;

    fn upload(&self, t: &Tensor) -> anyhow::Result<Tensor> {
        Ok(t.clone())
    }

    fn dispatch(
        &self,
        artifact: &str,
        inputs: &[&Tensor],
        out_shape: &[usize],
    ) -> anyhow::Result<Tensor> {
        let n: usize = out_shape.iter().product();
        let mut data = vec![0.0f32; n];
        let mut h = fnv(artifact);
        for (k, t) in inputs.iter().enumerate() {
            let len = t.data.len().max(1);
            let w = ((h % 13) + k as u32 + 1) as f32 * 0.0625;
            for (j, v) in data.iter_mut().enumerate() {
                *v += t.data[(j + 7 * k) % len] * w;
            }
            h = h.wrapping_mul(16777619) ^ (k as u32 + 1);
        }
        // keep magnitudes bounded so deep programs never overflow
        for v in data.iter_mut() {
            *v = (*v * 0.25).sin();
        }
        Ok(Tensor::new(out_shape.to_vec(), data))
    }

    fn fetch(&self, b: &Tensor) -> anyhow::Result<Tensor> {
        Ok(b.clone())
    }
}

/// The fabric-fixed panel shape of a weight kind (mirrors the cycle
/// backend's `ShapeWeights`).
fn weight_shape(f: &FabricConstants, kind: WeightKind) -> Vec<usize> {
    match kind {
        WeightKind::Wq
        | WeightKind::Wk
        | WeightKind::Wv
        | WeightKind::CWq
        | WeightKind::CWk
        | WeightKind::CWv => vec![f.ts_mha, f.dk],
        WeightKind::QkvPacked => vec![f.ts_mha, 3 * f.dk],
        WeightKind::Bq
        | WeightKind::Bk
        | WeightKind::Bv
        | WeightKind::CBq
        | WeightKind::CBk
        | WeightKind::CBv => vec![f.dk],
        WeightKind::BQkvPacked => vec![3 * f.dk],
        WeightKind::Wo | WeightKind::CWo => vec![f.ts_ffn, f.ts_ffn],
        WeightKind::Bo
        | WeightKind::B2
        | WeightKind::G1
        | WeightKind::B1n
        | WeightKind::G2
        | WeightKind::B2n
        | WeightKind::CBo
        | WeightKind::CG
        | WeightKind::CBn => vec![f.dmodel_max],
        WeightKind::W1 => vec![f.ts_ffn, f.ffn_col],
        WeightKind::B1 => vec![f.hidden_max],
        WeightKind::W2 => vec![f.ffn_col, f.ts_ffn],
        WeightKind::DWq | WeightKind::DWk | WeightKind::DWv | WeightKind::DCWq => {
            vec![f.dmodel_max, f.dk]
        }
        WeightKind::DWo | WeightKind::DCWo => vec![f.dmodel_max, f.dmodel_max],
        WeightKind::DW1 => vec![f.dmodel_max, f.hidden_max],
        WeightKind::DW2 => vec![f.hidden_max, f.dmodel_max],
    }
}

/// Deterministic, per-reference-distinct weight stand-ins for every
/// `WeightRef` a program mentions.
struct HashWeights {
    map: HashMap<WeightRef, Tensor>,
}

impl HashWeights {
    fn for_program(prog: &TileProgram, f: &FabricConstants) -> Self {
        let mut map = HashMap::new();
        for step in &prog.steps {
            let schedule::Step::Dispatch { args, .. } = step else { continue };
            for arg in args {
                let schedule::Operand::Weight(r) = arg else { continue };
                map.entry(*r).or_insert_with(|| {
                    let shape = weight_shape(f, r.kind);
                    let seed =
                        fnv(&format!("{:?}/{}/{}/{}", r.kind, r.layer, r.row, r.col)) % 1000;
                    let n: usize = shape.iter().product();
                    let data =
                        (0..n).map(|i| ((seed as usize + i) as f32 * 0.137).sin()).collect();
                    Tensor::new(shape, data)
                });
            }
        }
        HashWeights { map }
    }
}

impl WeightSource<Tensor> for HashWeights {
    fn weight(&self, r: &WeightRef) -> anyhow::Result<&Tensor> {
        self.map.get(r).ok_or_else(|| anyhow::anyhow!("unseeded weight ref {r:?}"))
    }
}

/// Padded input with deterministic nonzero content in the valid prefix.
fn test_input(cfg: &TnnConfig, f: &FabricConstants) -> Tensor {
    let mut t = Tensor::zeros(vec![f.sl_max, f.dmodel_max]);
    for r in 0..cfg.seq_len {
        for c in 0..cfg.d_model {
            t.data[r * f.dmodel_max + c] = ((r * 31 + c) as f32 * 0.0917).sin();
        }
    }
    t
}

fn replay_on_hash(
    prog: &TileProgram,
    weights: &HashWeights,
    pool: Option<&TensorPool>,
) -> Tensor {
    let backend = HashBackend;
    let runtime = schedule::build_runtime(&backend, &prog.cfg, &prog.fabric).unwrap();
    let input = test_input(&prog.cfg, &prog.fabric);
    schedule::replay_with(prog, &backend, weights, &runtime, input, pool).unwrap()
}

#[test]
fn o1_replay_is_bit_identical_across_the_topology_sweep() {
    let f = fc();
    for cfg in topology_sweep() {
        let raw = ScheduleBuilder::new(f, cfg).unwrap().build();
        let mut optd = raw.clone();
        optimize(&mut optd, OptLevel::O1, &ArtifactInventory::assume_all()).unwrap();
        opt::validate_waves(&optd).unwrap();

        // O1 may only reorder and drop redundant transfers
        let mut before: Vec<&str> = raw.dispatch_sequence();
        let mut after: Vec<&str> = optd.dispatch_sequence();
        before.sort_unstable();
        after.sort_unstable();
        assert_eq!(before, after, "{cfg}: O1 changed the dispatch multiset");
        assert!(optd.upload_count() <= raw.upload_count(), "{cfg}");
        assert!(optd.wave_count() > 1, "{cfg}: no wave partition");

        let weights = HashWeights::for_program(&raw, &f);
        let a = replay_on_hash(&raw, &weights, None);
        let b = replay_on_hash(&optd, &weights, None);
        assert_eq!(a.shape, b.shape, "{cfg}");
        assert!(a.data == b.data, "{cfg}: optimized replay diverged bit-for-bit");
    }
}

#[test]
fn pooled_replay_is_bit_identical_and_recycles() {
    let f = fc();
    let cfg = TnnConfig::encoder(32, 256, 4, 2);
    let mut prog = ScheduleBuilder::new(f, cfg).unwrap().build();
    optimize(&mut prog, OptLevel::O1, &ArtifactInventory::assume_all()).unwrap();
    let weights = HashWeights::for_program(&prog, &f);
    let plain = replay_on_hash(&prog, &weights, None);
    let pool = TensorPool::new();
    let pooled1 = replay_on_hash(&prog, &weights, Some(&pool));
    assert!(plain.data == pooled1.data, "pooled replay must not change numerics");
    let (_, misses1) = pool.stats();
    let pooled2 = replay_on_hash(&prog, &weights, Some(&pool));
    assert!(plain.data == pooled2.data, "recycled buffers must not leak stale data");
    let (hits2, misses2) = pool.stats();
    assert!(hits2 > 0, "second replay must recycle");
    assert_eq!(misses1, misses2, "steady state allocates no new host scratch");
}

#[test]
fn quantized_o1_replay_is_bit_identical() {
    // CalibrateScale is the one data-dependent step: a reorder that
    // changed what the calibration sees would change the scale.
    let f = fc();
    let cfg = TnnConfig::encoder(32, 256, 4, 2);
    let raw = ScheduleBuilder::new(f, cfg).unwrap().quantized(true).build();
    let mut optd = raw.clone();
    optimize(&mut optd, OptLevel::O1, &ArtifactInventory::assume_all()).unwrap();
    let weights = HashWeights::for_program(&raw, &f);
    let a = replay_on_hash(&raw, &weights, None);
    let b = replay_on_hash(&optd, &weights, None);
    assert!(a.data == b.data, "quantized optimized replay diverged");
}

#[test]
fn o2_fused_program_replays_with_fewer_dispatches() {
    let f = fc();
    let cfg = TnnConfig::encoder(32, 256, 4, 2);
    let raw = ScheduleBuilder::new(f, cfg).unwrap().build();
    let mut optd = raw.clone();
    optimize(&mut optd, OptLevel::O2, &ArtifactInventory::assume_all()).unwrap();
    assert!(optd.dispatch_count() < raw.dispatch_count());
    assert!(
        optd.dispatch_count() + optd.upload_count()
            < raw.dispatch_count() + raw.upload_count(),
        "O2 must make the replay strictly cheaper in dispatches+uploads"
    );
    // The fused program must still replay end to end (operand wiring of
    // the fused dispatches is exercised by the hash backend).
    let weights = HashWeights::for_program(&raw, &f);
    let out = replay_on_hash(&optd, &weights, None);
    assert_eq!(out.shape, vec![f.sl_max, f.dmodel_max]);
    assert!(out.data.iter().all(|v| v.is_finite()));
}

#[test]
fn wave_partition_widths_track_head_parallelism() {
    let f = fc();
    let narrow = {
        let mut p = ScheduleBuilder::new(f, TnnConfig::encoder(32, 128, 2, 1)).unwrap().build();
        optimize(&mut p, OptLevel::O1, &ArtifactInventory::assume_all()).unwrap();
        p.max_wave_dispatches()
    };
    let wide = {
        let mut p = ScheduleBuilder::new(f, TnnConfig::encoder(32, 384, 6, 1)).unwrap().build();
        optimize(&mut p, OptLevel::O1, &ArtifactInventory::assume_all()).unwrap();
        p.max_wave_dispatches()
    };
    assert!(wide > narrow, "more heads must expose wider waves ({wide} vs {narrow})");
}

// ---- decode programs: opt-pass legality on prefill / decode-step ------

use adaptor::accel::decode;

/// Decoder topologies legal on the default fabric: decoder-only and
/// seq2seq, widths/depths varied.
fn decoder_sweep() -> Vec<TnnConfig> {
    let t = |seq_len, d_model, heads, enc, dec| TnnConfig {
        seq_len,
        heads,
        d_model,
        hidden: 4 * d_model,
        enc_layers: enc,
        dec_layers: dec,
    };
    vec![
        t(16, 128, 2, 0, 1),
        t(32, 256, 4, 0, 2),
        t(32, 256, 4, 1, 1),
        t(48, 128, 2, 2, 2),
        t(64, 384, 6, 1, 1),
    ]
}

/// Deterministic extern cache panels (the decode-step's K/V inputs).
fn extern_tensors(prog: &TileProgram) -> Vec<Tensor> {
    prog.extern_shapes
        .iter()
        .enumerate()
        .map(|(i, s)| {
            let n: usize = s.iter().product();
            let data = (0..n).map(|j| ((i * 977 + j) as f32 * 0.0531).sin()).collect();
            Tensor::new(s.clone(), data)
        })
        .collect()
}

/// Replay a prefill or decode-step program on the hash backend with
/// deterministic inputs/externs; returns (output, exports).
fn replay_decoder_on_hash(prog: &TileProgram, weights: &HashWeights) -> (Tensor, Vec<Tensor>) {
    let backend = HashBackend;
    let runtime = schedule::build_runtime(&backend, &prog.cfg, &prog.fabric).unwrap();
    let f = prog.fabric;
    let cfg = prog.cfg;
    let mut inputs = Vec::new();
    if prog.host_shapes[prog.input_host][0] == 1 {
        // decode-step: one token row + [mask row, position] aux inputs
        let mut row = Tensor::zeros(vec![1, f.dmodel_max]);
        for c in 0..cfg.d_model {
            row.data[c] = ((c * 13 + 5) as f32 * 0.113).sin();
        }
        inputs.push(row);
        let pos = cfg.seq_len / 2;
        inputs.push(decode::step_mask_row(f.sl_max, pos));
        inputs.push(decode::position_tensor(pos));
    } else {
        // prefill: the prompt + (for seq2seq) the encoder memory
        inputs.push(test_input(&cfg, &f));
        for h in &prog.aux_hosts {
            let shape = prog.host_shapes[*h].clone();
            let n: usize = shape.iter().product();
            let data = (0..n).map(|j| ((j * 7 + 3) as f32 * 0.0713).sin()).collect();
            inputs.push(Tensor::new(shape, data));
        }
    }
    let ext = extern_tensors(prog);
    let ext_refs: Vec<&Tensor> = ext.iter().collect();
    schedule::replay_full(prog, &backend, weights, &runtime, inputs, &ext_refs, None).unwrap()
}

#[test]
fn o1_prefill_and_step_replays_are_bit_identical_across_the_decoder_sweep() {
    // Satellite 3: DedupTransfers / ScheduleWaves / CompactSlots must stay
    // legal and bit-exact on decode programs, and every emitted partition
    // must validate.
    let f = fc();
    for cfg in decoder_sweep() {
        for kind in ["prefill", "step"] {
            let raw = {
                let b = ScheduleBuilder::new(f, cfg).unwrap();
                if kind == "prefill" {
                    b.build_prefill()
                } else {
                    b.build_step()
                }
            };
            let mut optd = raw.clone();
            optimize(&mut optd, OptLevel::O1, &ArtifactInventory::assume_all()).unwrap();
            opt::validate_waves(&optd).unwrap();
            assert!(optd.wave_count() > 1, "{cfg} {kind}: no wave partition");
            // the cache interface must survive optimization
            assert_eq!(optd.extern_shapes, raw.extern_shapes, "{cfg} {kind}");
            assert_eq!(optd.export_slots.len(), raw.export_slots.len(), "{cfg} {kind}");
            let mut before: Vec<&str> = raw.dispatch_sequence();
            let mut after: Vec<&str> = optd.dispatch_sequence();
            before.sort_unstable();
            after.sort_unstable();
            assert_eq!(before, after, "{cfg} {kind}: O1 changed the dispatch multiset");

            let weights = HashWeights::for_program(&raw, &f);
            let (a, ax) = replay_decoder_on_hash(&raw, &weights);
            let (b, bx) = replay_decoder_on_hash(&optd, &weights);
            assert!(a.data == b.data, "{cfg} {kind}: optimized replay diverged bit-for-bit");
            assert_eq!(ax.len(), bx.len(), "{cfg} {kind}");
            for (i, (ea, eb)) in ax.iter().zip(&bx).enumerate() {
                assert!(ea.data == eb.data, "{cfg} {kind}: export {i} diverged");
            }
        }
    }
}

#[test]
fn o2_keeps_the_causal_chain_split_but_fuses_the_cross_chain() {
    let f = fc();
    // seq2seq: self-attention is causal (must stay split), cross is not
    // (may fuse into attn_fused at O2).
    let cfg = decoder_sweep()[2];
    let mut p = ScheduleBuilder::new(f, cfg).unwrap().build_prefill();
    let qk_before = p.dispatch_sequence().iter().filter(|a| **a == "qk_scores").count();
    optimize(&mut p, OptLevel::O2, &ArtifactInventory::assume_all()).unwrap();
    let seq = p.dispatch_sequence();
    let qk_after = seq.iter().filter(|a| **a == "qk_scores").count();
    assert_eq!(qk_before, cfg.heads * 2, "self + cross chains per head");
    assert_eq!(qk_after, cfg.heads, "only the causal self chains survive as splits");
    assert_eq!(seq.iter().filter(|a| **a == "attn_fused").count(), cfg.heads);
    // the fused prefill still replays and exports the full cache
    let weights = HashWeights::for_program(&p, &f);
    let (out, exports) = replay_decoder_on_hash(&p, &weights);
    assert!(out.data.iter().all(|v| v.is_finite()));
    assert_eq!(exports.len(), decode::ExternLayout::of(&cfg).total());
    opt::validate_waves(&p).unwrap();
}

#[test]
fn decode_step_programs_never_fuse_their_row_chain() {
    let f = fc();
    let cfg = decoder_sweep()[1];
    let mut p = ScheduleBuilder::new(f, cfg).unwrap().build_step();
    let d0 = p.dispatch_count();
    optimize(&mut p, OptLevel::O2, &ArtifactInventory::assume_all()).unwrap();
    assert_eq!(p.dispatch_count(), d0, "row artifacts have no fusion targets");
    assert!(!p.dispatch_sequence().contains(&"attn_fused"));
    opt::validate_waves(&p).unwrap();
}

#[test]
fn decode_step_dispatches_strictly_less_than_prefill_across_the_sweep() {
    let f = fc();
    for cfg in decoder_sweep() {
        let pre = ScheduleBuilder::new(f, cfg).unwrap().build_prefill();
        let step = ScheduleBuilder::new(f, cfg).unwrap().build_step();
        assert!(
            step.dispatch_count() < pre.dispatch_count(),
            "{cfg}: step {} vs prefill {}",
            step.dispatch_count(),
            pre.dispatch_count()
        );
        assert!(step.upload_count() < pre.upload_count(), "{cfg}");
        assert_eq!(pre.export_slots.len(), decode::ExternLayout::of(&cfg).total(), "{cfg}");
        assert_eq!(step.extern_shapes.len(), pre.export_slots.len(), "{cfg}");
        assert_eq!(step.export_slots.len(), decode::ExternLayout::of(&cfg).step_exports(), "{cfg}");
    }
}

#[test]
fn step_replay_reads_the_extern_cache() {
    // Changing a cached K/V panel must change the step's output — the
    // extern wiring is live, not decorative.
    let f = fc();
    let cfg = decoder_sweep()[0];
    let prog = ScheduleBuilder::new(f, cfg).unwrap().build_step();
    let weights = HashWeights::for_program(&prog, &f);
    let (a, _) = replay_decoder_on_hash(&prog, &weights);
    // perturb one extern via a shifted seed: rebuild with a bumped layout
    let backend = HashBackend;
    let runtime = schedule::build_runtime(&backend, &cfg, &f).unwrap();
    let mut row = Tensor::zeros(vec![1, f.dmodel_max]);
    for c in 0..cfg.d_model {
        row.data[c] = ((c * 13 + 5) as f32 * 0.113).sin();
    }
    let pos = cfg.seq_len / 2;
    let inputs =
        vec![row, decode::step_mask_row(f.sl_max, pos), decode::position_tensor(pos)];
    let mut ext = extern_tensors(&prog);
    ext[0].data[0] += 1.0;
    let ext_refs: Vec<&Tensor> = ext.iter().collect();
    let (b, _) =
        schedule::replay_full(&prog, &backend, &weights, &runtime, inputs, &ext_refs, None)
            .unwrap();
    assert!(a.data != b.data, "perturbed cache panel did not reach the output");
}

#[test]
fn every_opt_level_keeps_the_program_interface() {
    let f = fc();
    let cfg = TnnConfig::encoder(32, 256, 4, 1);
    for level in [OptLevel::O0, OptLevel::O1, OptLevel::O2] {
        let mut p = ScheduleBuilder::new(f, cfg).unwrap().build();
        let (inp, outp) = (p.input_host, p.output_host);
        optimize(&mut p, level, &ArtifactInventory::assume_all()).unwrap();
        assert_eq!((p.input_host, p.output_host), (inp, outp), "{level:?}");
        let weights = HashWeights::for_program(&p, &f);
        let out = replay_on_hash(&p, &weights, None);
        assert_eq!(out.shape, vec![f.sl_max, f.dmodel_max], "{level:?}");
    }
}
