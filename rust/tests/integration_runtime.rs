//! Runtime integration: every AOT artifact loads, compiles and executes on
//! the PJRT CPU client with numerics matching the rust reference oracle —
//! the consumer half of the HLO-text interchange contract (the producer
//! half is python/tests/test_aot.py).

use adaptor::model::reference;
use adaptor::model::weights::{init_input, Mat};
use adaptor::runtime::{default_artifact_dir, Executor, Tensor};
use adaptor::util::rng::SplitMix64;

use adaptor::require_artifacts;

fn exec() -> Executor {
    Executor::new(default_artifact_dir()).expect("run `make artifacts` first")
}

fn rnd_tensor(seed: u64, shape: &[usize], scale: f32) -> Tensor {
    let mut rng = SplitMix64::new(seed);
    let n: usize = shape.iter().product();
    let mut data = vec![0.0f32; n];
    rng.fill_normal_f32(&mut data, scale);
    Tensor::new(shape.to_vec(), data)
}

fn assert_close(got: &Tensor, want: &Mat, tol: f32, what: &str) {
    let g = got.to_mat();
    let d = g.max_abs_diff(want);
    assert!(d < tol, "{what}: diff {d}");
}

#[test]
fn every_tile_primitive_compiles_and_runs() {
    require_artifacts!();
    let e = exec();
    let names: Vec<String> = e.manifest().artifacts.keys().cloned().collect();
    assert!(names.len() >= 13);
    for name in &names {
        let meta = e.manifest().artifact(name).unwrap().clone();
        let inputs: Vec<Tensor> = meta
            .inputs
            .iter()
            .enumerate()
            .map(|(i, s)| rnd_tensor(1000 + i as u64, s, 0.3))
            .collect();
        let refs: Vec<&Tensor> = inputs.iter().collect();
        let out = e.run(name, &refs).unwrap_or_else(|err| panic!("{name}: {err}"));
        assert_eq!(out.len(), meta.outputs.len(), "{name}");
        for (o, s) in out.iter().zip(&meta.outputs) {
            assert_eq!(&o.shape, s, "{name} output shape");
            assert!(o.data.iter().all(|v| v.is_finite()), "{name} produced non-finite values");
        }
    }
}

#[test]
fn mm_artifacts_match_reference_matmul() {
    require_artifacts!();
    let e = exec();
    for (name, m, k, n) in [
        ("mm_qkv", 128usize, 64usize, 64usize),
        ("mm_ffn1", 128, 128, 128),
        ("mm_ffn2", 128, 128, 512),
        ("mm_ffn3", 128, 512, 128),
    ] {
        let x = rnd_tensor(1, &[m, k], 0.5);
        let w = rnd_tensor(2, &[k, n], 0.5);
        let acc = rnd_tensor(3, &[m, n], 0.5);
        let got = e.run1(name, &[&x, &w, &acc]).unwrap();
        let mut want = reference::matmul(&x.to_mat(), &w.to_mat());
        for (wv, av) in want.data.iter_mut().zip(&acc.data) {
            *wv += av;
        }
        assert_close(&got, &want, 1e-3, name);
    }
}

#[test]
fn attention_chain_matches_reference() {
    require_artifacts!();
    let e = exec();
    let q = rnd_tensor(10, &[128, 64], 0.7);
    let k = rnd_tensor(11, &[128, 64], 0.7);
    let v = rnd_tensor(12, &[128, 64], 0.7);
    let sl_valid = 100;
    let mask_m = reference::attention_mask(128, sl_valid, false);
    let mask = Tensor::from_mat(&mask_m);
    let scale = Tensor::scalar1(0.125);

    // split chain
    let s = e.run1("qk_scores", &[&q, &k, &mask, &scale]).unwrap();
    let p = e.run1("softmax", &[&s]).unwrap();
    let o_split = e.run1("sv", &[&p, &v]).unwrap();
    // fused
    let o_fused = e.run1("attn_fused", &[&q, &k, &v, &mask, &scale]).unwrap();
    // oracle
    let want = reference::attention_head(&q.to_mat(), &k.to_mat(), &v.to_mat(), &mask_m, 0.125);

    let valid = |t: &Tensor| t.to_mat().block(0, 0, sl_valid, 64);
    let want_valid = want.block(0, 0, sl_valid, 64);
    assert!(valid(&o_split).max_abs_diff(&want_valid) < 1e-3);
    assert!(valid(&o_fused).max_abs_diff(&want_valid) < 1e-3);
    assert!(valid(&o_split).max_abs_diff(&valid(&o_fused)) < 1e-3);
}

#[test]
fn residual_ln_artifact_matches_reference_on_valid_prefix() {
    require_artifacts!();
    let e = exec();
    let d_valid = 512usize;
    let x = {
        let m = init_input(20, 128, d_valid).padded(128, 768);
        Tensor::from_mat(&m)
    };
    let r = {
        let m = init_input(21, 128, d_valid).padded(128, 768);
        Tensor::from_mat(&m)
    };
    let mut dm = vec![0.0f32; 768];
    dm[..d_valid].fill(1.0);
    let gamma = Tensor::new(vec![768], vec![1.0; 768]);
    let beta = Tensor::new(vec![768], vec![0.0; 768]);
    let dmask = Tensor::new(vec![768], dm);
    let count = Tensor::scalar1(d_valid as f32);
    let got = e.run1("residual_ln", &[&x, &r, &gamma, &beta, &dmask, &count]).unwrap();

    let want = reference::residual_ln(
        &x.to_mat().block(0, 0, 128, d_valid),
        &r.to_mat().block(0, 0, 128, d_valid),
        &vec![1.0; d_valid],
        &vec![0.0; d_valid],
    );
    let got_valid = got.to_mat().block(0, 0, 128, d_valid);
    assert!(got_valid.max_abs_diff(&want) < 2e-3, "{}", got_valid.max_abs_diff(&want));
    // padding stays exactly zero
    let g = got.to_mat();
    for rr in 0..128 {
        for cc in d_valid..768 {
            assert_eq!(g.at(rr, cc), 0.0);
        }
    }
}

#[test]
fn bias_and_relu_artifacts() {
    require_artifacts!();
    let e = exec();
    let x = rnd_tensor(30, &[128, 3072], 1.0);
    let b = rnd_tensor(31, &[3072], 1.0);
    let got = e.run1("bias_relu_h", &[&x, &b]).unwrap();
    for (i, v) in got.data.iter().enumerate() {
        let expect = (x.data[i] + b.data[i % 3072]).max(0.0);
        assert!((v - expect).abs() < 1e-5);
    }
}

#[test]
fn fused_layer_artifacts_execute() {
    require_artifacts!();
    let e = exec();
    for name in ["small_layer", "bert_layer"] {
        let fm = e.manifest().fused.get(name).unwrap().clone();
        let inputs: Vec<Tensor> = fm
            .meta
            .inputs
            .iter()
            .enumerate()
            .map(|(i, s)| rnd_tensor(500 + i as u64, s, 0.1))
            .collect();
        let refs: Vec<&Tensor> = inputs.iter().collect();
        let out = e.run1(name, &refs).unwrap_or_else(|err| panic!("{name}: {err}"));
        assert_eq!(out.shape, vec![fm.sl, fm.d_model]);
        assert!(out.data.iter().all(|v| v.is_finite()));
    }
}

#[test]
fn compile_cache_is_shared_across_runs() {
    require_artifacts!();
    let e = exec();
    let x = Tensor::zeros(vec![128, 128]);
    for _ in 0..5 {
        e.run1("softmax", &[&x]).unwrap();
    }
    let st = e.stats();
    assert_eq!(st.compiles, 1);
    assert_eq!(st.dispatches, 5);
    assert!(st.execute_secs > 0.0);
}

#[test]
fn quantize_artifact_error_bounded() {
    require_artifacts!();
    let e = exec();
    let x = rnd_tensor(40, &[128, 768], 0.3);
    let scale = 0.01f32;
    let q = e.run1("quantize", &[&x, &Tensor::scalar1(scale)]).unwrap();
    for (qv, xv) in q.data.iter().zip(&x.data) {
        if xv.abs() <= 127.0 * scale {
            assert!((qv - xv).abs() <= scale / 2.0 + 1e-6);
        } else {
            assert!(qv.abs() <= 127.0 * scale + 1e-6);
        }
    }
}
