//! Coordinator integration: the serving stack end to end — router,
//! batcher, fabric pool, register reprogramming — against the reference
//! oracle, including concurrent clients.

use std::time::Duration;

use adaptor::coordinator::batcher::BatchPolicy;
use adaptor::coordinator::router::ModelSpec;
use adaptor::coordinator::{AttentionMode, Server, ServerConfig, TileEngine};
use adaptor::model::weights::init_input;
use adaptor::model::{presets, reference, weights, TnnConfig};
use adaptor::runtime::default_artifact_dir;
use adaptor::serve::{QoS, Submission};

use adaptor::require_artifacts;

fn encode(model: &str, input: weights::Mat) -> Submission {
    Submission::Encode { model: model.into(), input }
}

fn policy() -> BatchPolicy {
    BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(2) }
}

#[test]
fn engine_matches_oracle_across_topologies() {
    require_artifacts!();
    let mut e = TileEngine::new(default_artifact_dir()).expect("make artifacts");
    for (cfg, seed) in [
        (TnnConfig::encoder(16, 128, 2, 1), 1u64),
        (TnnConfig::encoder(32, 256, 4, 2), 2),
        (TnnConfig::encoder(64, 384, 6, 1), 3),
        (TnnConfig::encoder(128, 128, 2, 1), 4),
    ] {
        let ws = weights::init_stack(seed, cfg.d_model, cfg.heads, cfg.enc_layers);
        e.program(&cfg).unwrap();
        let p = e.prepare(&cfg, &ws).unwrap();
        let x = init_input(seed + 100, cfg.seq_len, cfg.d_model);
        let got = e.run_encoder(&p, &x).unwrap();
        let mask = reference::attention_mask(cfg.seq_len, cfg.seq_len, false);
        let want = reference::encoder_stack(&x, &ws, &mask);
        let diff = got.max_abs_diff(&want);
        assert!(diff < 3e-3, "{cfg}: diff {diff}");
    }
}

#[test]
fn no_recompilation_across_full_model_zoo() {
    require_artifacts!();
    // run FOUR different topologies through one fabric; artifact compiles
    // must happen only on first use — the runtime-adaptivity headline.
    let mut e = TileEngine::new(default_artifact_dir()).unwrap();
    let zoo = [
        TnnConfig::encoder(16, 128, 2, 1),
        TnnConfig::encoder(32, 256, 4, 1),
        TnnConfig::encoder(48, 512, 8, 1),
        TnnConfig::encoder(96, 640, 10, 1),
    ];
    let mut compiled_after_first = None;
    for (i, cfg) in zoo.iter().enumerate() {
        let ws = weights::init_stack(i as u64, cfg.d_model, cfg.heads, 1);
        e.program(cfg).unwrap();
        let p = e.prepare(cfg, &ws).unwrap();
        let x = init_input(i as u64, cfg.seq_len, cfg.d_model);
        e.run_encoder(&p, &x).unwrap();
        match compiled_after_first {
            None => compiled_after_first = Some(e.executor().compiled_count()),
            Some(n) => assert_eq!(
                e.executor().compiled_count(),
                n,
                "model #{i} ({cfg}) triggered a re-synthesis"
            ),
        }
    }
}

#[test]
fn server_concurrent_clients_all_answered_correctly() {
    require_artifacts!();
    let spec_a = ModelSpec::new("a", presets::small_encoder(32, 1), 7);
    let spec_b = ModelSpec::new("b", TnnConfig::encoder(16, 128, 2, 1), 8);
    let mut cfg = ServerConfig::new(vec![spec_a.clone(), spec_b.clone()]);
    cfg.policy = policy();
    let server = std::sync::Arc::new(Server::start(cfg).expect("make artifacts"));

    let mut handles = Vec::new();
    for t in 0..4u64 {
        let s = server.clone();
        handles.push(std::thread::spawn(move || {
            for i in 0..3u64 {
                let (model, mcfg, seed) = if (t + i) % 2 == 0 {
                    ("a", presets::small_encoder(32, 1), 7u64)
                } else {
                    ("b", TnnConfig::encoder(16, 128, 2, 1), 8u64)
                };
                let x = init_input(t * 10 + i, mcfg.seq_len, mcfg.d_model);
                let out = s
                    .submit(encode(model, x.clone()), QoS::default())
                    .unwrap()
                    .wait()
                    .unwrap()
                    .into_encode()
                    .unwrap();
                let ws = weights::init_stack(seed, mcfg.d_model, mcfg.heads, mcfg.enc_layers);
                let mask = reference::attention_mask(mcfg.seq_len, mcfg.seq_len, false);
                let want = reference::encoder_stack(&x, &ws, &mask);
                assert!(out.output.max_abs_diff(&want) < 3e-3);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let server = std::sync::Arc::try_unwrap(server).ok().expect("sole owner");
    let m = server.shutdown().unwrap();
    assert_eq!(m.requests(), 12);
    assert!(m.reprograms >= 2);
    assert!(m.mean_batch() >= 1.0);
}

#[test]
fn attention_modes_agree_through_the_server() {
    require_artifacts!();
    let run = |mode: AttentionMode| {
        let spec = ModelSpec::new("m", presets::small_encoder(32, 1), 5);
        let mut cfg = ServerConfig::new(vec![spec]);
        cfg.policy = policy();
        cfg.attention = mode;
        let s = Server::start(cfg).unwrap();
        let x = init_input(1, 32, 256);
        let out = s
            .submit(encode("m", x), QoS::default())
            .unwrap()
            .wait()
            .unwrap()
            .into_encode()
            .unwrap()
            .output;
        s.shutdown().unwrap();
        out
    };
    let split = run(AttentionMode::Split);
    let fused = run(AttentionMode::Fused);
    assert!(split.max_abs_diff(&fused) < 1e-3);
}

#[test]
fn metrics_accumulate_latency_and_batches() {
    require_artifacts!();
    let spec = ModelSpec::new("m", presets::small_encoder(32, 1), 6);
    let mut cfg = ServerConfig::new(vec![spec]);
    cfg.policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) };
    let s = Server::start(cfg).unwrap();
    let mut handles = Vec::new();
    for i in 0..6 {
        let x = init_input(i, 32, 256);
        handles.push(s.submit(encode("m", x), QoS::default()).unwrap());
    }
    for h in handles {
        h.wait().unwrap();
    }
    let m = s.shutdown().unwrap();
    assert_eq!(m.requests(), 6);
    assert_eq!(m.failed, 0);
    let sum = m.latency_summary().unwrap();
    assert!(sum.p50 > 0.0 && sum.max >= sum.p50);
    // compute and queue are tracked separately and bounded by e2e
    let comp = m.compute_summary().unwrap();
    let q = m.queue_summary().unwrap();
    assert!(comp.max <= sum.max + 1e-9);
    assert!(q.max <= sum.max + 1e-9);
    assert!(m.throughput_rps() > 0.0);
    // single fabric: the aggregate carries exactly one per-fabric entry
    assert_eq!(m.per_fabric.len(), 1);
    assert_eq!(m.per_fabric[0].fabric, Some(0));
    assert_eq!(m.per_fabric[0].requests(), 6);
}
