//! Cross-module integration over the accelerator substrate: synthesis →
//! resources → frequency → latency → simulation → power, plus the
//! design-space machinery the figures are built from.

use adaptor::accel::{frequency, latency, power, resources, roofline, sim, Synthesis};
use adaptor::accel::platform;
use adaptor::accel::tiling::TileConfig;
use adaptor::analysis::sweep;
use adaptor::baselines::nonadaptive;
use adaptor::model::quant::BitWidth;
use adaptor::model::{ops, presets, TnnConfig};

#[test]
fn paper_default_synthesis_end_to_end() {
    let s = Synthesis::paper_default();
    let cfg = TnnConfig::encoder(64, 768, 8, 12); // Table 2 row 1
    let r = s.resources(&cfg);
    assert!(r.check_fit(&s.platform).is_ok());
    assert_eq!(r.dsp, 3612); // Table 2 experimental
    let f = s.frequency_mhz(&cfg);
    assert_eq!(f, 200.0);
    let lat = latency::model_latency(&cfg, &s.tiles);
    let watts = power::total_power_w(&s.platform, &r, f);
    assert!((watts - 11.8).abs() < 0.7, "{watts}");
    let gops = lat.gops_at(&cfg, f);
    assert!(gops > 15.0 && gops < 60.0, "{gops}");
}

#[test]
fn table2_all_rows_validate_under_3pct() {
    let p = platform::u55c();
    for (sl, d, tm, tf) in [(64, 768, 64, 128), (128, 768, 64, 128), (64, 512, 64, 128), (64, 768, 128, 192)]
    {
        let cfg = TnnConfig::encoder(sl, d, 8, 12);
        let tiles = TileConfig::for_fabric(tm, tf, 768);
        let row = sweep::validate(&cfg, &tiles, &p, BitWidth::Fixed16);
        assert!(
            row.max_latency_error() < 0.03,
            "(sl={sl}, d={d}, ts={tm}/{tf}): err {:.4}",
            row.max_latency_error()
        );
    }
}

#[test]
fn fig5_sweep_has_interior_latency_optimum() {
    let cfg = TnnConfig::encoder(64, 768, 8, 12);
    let pts = sweep::tile_sweep(&cfg, &platform::u55c(), BitWidth::Fixed16);
    assert!(pts.len() >= 10);
    let best = sweep::best_by_latency(&pts).unwrap();
    let most_dsp = pts.iter().max_by_key(|p| p.dsp).unwrap();
    let least_dsp = pts.iter().min_by_key(|p| p.dsp).unwrap();
    assert_ne!((best.ts_mha, best.ts_ffn), (most_dsp.ts_mha, most_dsp.ts_ffn));
    assert_ne!((best.ts_mha, best.ts_ffn), (least_dsp.ts_mha, least_dsp.ts_ffn));
}

#[test]
fn fig8_frequency_decays_with_heads_and_latency_has_interior_optimum() {
    let base = TnnConfig::encoder(64, 768, 8, 12);
    let pts = sweep::heads_sweep(&base, &platform::u55c(), BitWidth::Fixed16);
    let f_first = pts.first().unwrap().freq_mhz;
    let f_last = pts.last().unwrap().freq_mhz;
    assert!(f_last < f_first, "frequency must decay with head count");
}

#[test]
fn fig11_portability_order_u55c_fastest() {
    // the same custom encoder on three platforms with the paper's tiles
    let cfg = presets::custom_encoder();
    let eval = |p: &platform::Platform, tm: usize, tf: usize| {
        let tiles = TileConfig::for_fabric(tm, tf, cfg.d_model);
        let r = resources::estimate(&cfg, &tiles, BitWidth::Fixed16, p);
        assert!(r.check_fit(p).is_ok(), "{} doesn't fit", p.name);
        let f = frequency::fmax_mhz(p, &r);
        latency::model_latency(&cfg, &tiles).ms_at(f)
    };
    let u = eval(&platform::u55c(), 200, 200);
    let z = eval(&platform::zcu102(), 25, 50);
    let v = eval(&platform::vc707(), 50, 50);
    assert!(u < z && u < v, "U55C must be fastest: u={u} z={z} v={v}");
}

#[test]
fn fig12_roofline_brackets_attained() {
    let tiles = TileConfig::paper_optimum();
    let cfgs = [
        ("bert", presets::bert_base(64)),
        ("shallow", presets::shallow_transformer()),
        ("custom4l", presets::custom_encoder_4l()),
    ];
    let pts: Vec<(&str, TnnConfig, f64)> = cfgs
        .iter()
        .map(|(n, c)| (*n, *c, latency::model_latency(c, &tiles).gops_at(c, 200.0)))
        .collect();
    let r = roofline::roofline(&platform::u55c(), &tiles, 200.0, 4, &pts);
    assert!(r.peak_gops > 0.0 && r.stream_gbps > 0.0);
    for p in &r.points {
        // "All data points fall within the compute and memory bound
        // regions, meaning none of them fully utilize the available
        // resources" (paper, Fig 12 discussion)
        assert!(p.attained_gops <= p.bound_gops * 1.15, "{}: {} > {}", p.name, p.attained_gops, p.bound_gops);
    }
}

#[test]
fn fig13_gops_rises_then_falls_with_dsp_utilization() {
    let cfg = TnnConfig::encoder(64, 768, 8, 12);
    let mut pts = sweep::tile_sweep(&cfg, &platform::u55c(), BitWidth::Fixed16);
    pts.sort_by(|a, b| a.dsp_util.partial_cmp(&b.dsp_util).unwrap());
    let peak_idx = pts
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.gops.partial_cmp(&b.1.gops).unwrap())
        .map(|(i, _)| i)
        .unwrap();
    // the GOPS peak is interior: utilization beyond it loses frequency
    assert!(peak_idx > 0, "GOPS must first rise with DSP utilization");
    let last = pts.last().unwrap();
    let peak = &pts[peak_idx];
    assert!(last.gops <= peak.gops, "GOPS must fall at extreme utilization");
}

#[test]
fn adaptivity_ablation_favors_adaptor_on_deployment() {
    let models =
        vec![presets::bert_base(64), presets::shallow_transformer(), presets::small_encoder(64, 4)];
    let c = nonadaptive::deployment_cost(
        &models,
        &platform::u55c(),
        &TileConfig::paper_optimum(),
        BitWidth::Fixed16,
    );
    assert_eq!(c.adaptor_synthesis_hours, nonadaptive::SYNTHESIS_HOURS);
    assert!(c.nonadaptive_synthesis_hours >= 3.0 * nonadaptive::SYNTHESIS_HOURS);
}

#[test]
fn gops_accounting_consistent_between_ops_and_latency() {
    // gops_at must equal total_ops / time; sanity over several models
    for cfg in [presets::bert_base(64), presets::shallow_transformer(), presets::small_encoder(64, 4)] {
        let tiles = TileConfig::paper_optimum();
        let lat = latency::model_latency(&cfg, &tiles);
        let secs = lat.total_cycles as f64 / 200e6;
        let expect = ops::total_ops(&cfg) as f64 / secs / 1e9;
        let got = lat.gops_at(&cfg, 200.0);
        assert!((got - expect).abs() / expect < 1e-9);
    }
}

#[test]
fn simulation_trace_is_contiguous_and_ordered() {
    let cfg = presets::small_encoder(64, 4);
    let rep = sim::simulate(&cfg, &TileConfig::paper_optimum());
    let mut last_end = 0;
    for e in &rep.trace.events {
        assert!(e.start >= last_end || e.name == "load_inputs");
        last_end = last_end.max(e.end());
    }
    assert_eq!(last_end, rep.total_cycles);
}

#[test]
fn specialization_never_violates_fit() {
    for p in platform::all() {
        if let Some(s) =
            nonadaptive::specialize(&presets::shallow_transformer(), &p, BitWidth::Fixed16)
        {
            let r = resources::estimate(&presets::shallow_transformer(), &s.tiles, BitWidth::Fixed16, &p);
            assert!(r.check_fit(&p).is_ok(), "{}", p.name);
            assert!(s.freq_mhz >= frequency::FMAX_FLOOR_MHZ);
        }
    }
}
