//! End-to-end decoder execution over the PJRT fabric: prefill + KV-cached
//! decode steps against the dense CPU oracle, dispatch accounting, and
//! the generation serving path.
//!
//! Gated on the AOT artifact set AND its decode-step artifacts (an
//! artifact directory predating `accel::decode` self-skips, like the
//! plain `require_artifacts!` tests do when artifacts are absent).

use std::time::Duration;

use adaptor::coordinator::batcher::BatchPolicy;
use adaptor::coordinator::router::ModelSpec;
use adaptor::coordinator::{Server, ServerConfig, TileEngine};
use adaptor::model::{presets, reference, weights, TnnConfig};
use adaptor::runtime::{artifacts_available, default_artifact_dir, Manifest};
use adaptor::serve::{GenerateOutput, QoS, ServeError, Submission};

/// Skip when the artifact set is absent or predates the decode-step
/// artifacts (`make artifacts` regenerates them).
macro_rules! require_decode_artifacts {
    () => {
        if !artifacts_available() {
            eprintln!("skipping: artifacts/ not present (run `make artifacts`)");
            return;
        }
        match Manifest::load(default_artifact_dir()) {
            Ok(m) if m.artifacts.contains_key("kv_append") => {}
            _ => {
                eprintln!("skipping: artifact set predates decode artifacts (re-run `make artifacts`)");
                return;
            }
        }
    };
}

fn engine() -> TileEngine {
    TileEngine::new(default_artifact_dir()).expect("run `make artifacts` first")
}

/// Prepare a model's stacks the way the serving pool does.
fn prepared(e: &TileEngine, spec: &ModelSpec) -> adaptor::coordinator::PreparedStack {
    e.prepare_model(&spec.cfg, &spec.weights(), &spec.decoder_weights()).unwrap()
}

/// The engine-side oracle: reference greedy decode over the spec's
/// synthetic weights (memory = reference encoder output for seq2seq).
fn oracle(spec: &ModelSpec, prompt: &weights::Mat, source: Option<&weights::Mat>) -> reference::GreedyDecode {
    let mem = source.map(|s| {
        let mask = reference::attention_mask(spec.cfg.seq_len, spec.cfg.seq_len, false);
        reference::encoder_stack(s, &spec.weights(), &mask)
    });
    reference::greedy_decode(prompt, mem.as_ref(), &spec.decoder_weights(), 6)
}

#[test]
fn decoder_only_generation_matches_the_greedy_oracle_across_topologies() {
    require_decode_artifacts!();
    let mut e = engine();
    // >= 3 decoder topologies (seq len, width, heads, depth vary)
    let topologies = [
        presets::gpt_small(32, 2),
        presets::gpt_small(48, 1),
        TnnConfig { seq_len: 24, heads: 2, d_model: 128, hidden: 512, enc_layers: 0, dec_layers: 3 },
    ];
    for (i, cfg) in topologies.into_iter().enumerate() {
        let spec = ModelSpec::new("m", cfg, 100 + i as u64);
        e.program(&cfg).unwrap();
        let p = prepared(&e, &spec);
        let prompt = weights::init_input(200 + i as u64, 5, cfg.d_model);
        let got = e.generate(&p, &prompt, None, 6).unwrap();
        let want = oracle(&spec, &prompt, None);
        assert_eq!(got.tokens, want.tokens, "{cfg}: greedy token ids must match exactly");
        let diff = got.rows.max_abs_diff(&want.rows);
        assert!(diff < 5e-3, "{cfg}: generated rows vs oracle diff = {diff}");
        assert!(
            got.step_dispatches < got.prefill_dispatches,
            "{cfg}: step {} vs prefill {}",
            got.step_dispatches,
            got.prefill_dispatches
        );
    }
}

#[test]
fn seq2seq_preset_round_trips_prefill_plus_steps_against_the_oracle() {
    require_decode_artifacts!();
    let mut e = engine();
    let cfg = presets::seq2seq_small(32, 1, 1);
    let spec = ModelSpec::new("s2s", cfg, 77);
    e.program(&cfg).unwrap();
    let p = prepared(&e, &spec);
    let prompt = weights::init_input(300, 4, cfg.d_model);
    let source = weights::init_input(301, cfg.seq_len, cfg.d_model);
    let got = e.generate(&p, &prompt, Some(&source), 6).unwrap();
    let want = oracle(&spec, &prompt, Some(&source));
    assert_eq!(got.tokens, want.tokens, "seq2seq greedy ids must match the oracle exactly");
    let diff = got.rows.max_abs_diff(&want.rows);
    assert!(diff < 5e-3, "seq2seq rows vs oracle diff = {diff}");
    // prefill + steps must be deterministic bit-for-bit across runs
    let again = e.generate(&p, &prompt, Some(&source), 6).unwrap();
    assert_eq!(got.rows.data, again.rows.data, "replays must round-trip bit-for-bit");
    assert_eq!(got.tokens, again.tokens);
}

#[test]
fn decode_step_replay_dispatches_strictly_fewer_instructions_than_prefill() {
    require_decode_artifacts!();
    // The acceptance assertion via ExecStats: measure the actual dispatch
    // deltas of a prefill replay vs one decode-step replay.
    let mut e = engine();
    let cfg = presets::gpt_small(32, 2);
    let spec = ModelSpec::new("m", cfg, 11);
    e.program(&cfg).unwrap();
    let p = prepared(&e, &spec);
    let prompt = weights::init_input(12, 4, cfg.d_model);

    let s0 = e.executor().stats();
    let (out, mut cache) = e.decoder_prefill(&p, &prompt, None).unwrap();
    let s1 = e.executor().stats();
    let row: Vec<f32> = (0..cfg.d_model).map(|c| out.at(prompt.rows - 1, c)).collect();
    let _ = e.decode_step(&p, &mut cache, &row).unwrap();
    let s2 = e.executor().stats();

    let prefill_dispatches = s1.dispatches - s0.dispatches;
    let step_dispatches = s2.dispatches - s1.dispatches;
    assert!(
        step_dispatches < prefill_dispatches,
        "measured step dispatches {step_dispatches} must be < prefill {prefill_dispatches}"
    );
    // and the step re-uploads no cache panel (device residency): only the
    // token row + mask row + position scalar cross the AXI boundary.
    let step_uploads = s2.uploads - s1.uploads;
    assert_eq!(step_uploads, 3, "a cached step uploads exactly row+mask+pos");
    assert_eq!(cache.len, prompt.rows + 1, "the step advanced the cache");
}

/// Submit a generation on the v1 surface and wait for the transcript.
fn generate(
    server: &Server,
    model: &str,
    prompt: weights::Mat,
    source: Option<weights::Mat>,
    steps: usize,
) -> Result<GenerateOutput, ServeError> {
    server
        .submit(Submission::Generate { model: model.into(), prompt, source, steps }, QoS::default())?
        .wait()?
        .into_generate()
}

#[test]
fn generation_serves_through_the_pool_with_per_token_metrics() {
    require_decode_artifacts!();
    let gpt = ModelSpec::new("gpt", presets::gpt_small(32, 1), 21);
    let s2s = ModelSpec::new("s2s", presets::seq2seq_small(32, 1, 1), 22);
    let mut cfg = ServerConfig::new(vec![gpt.clone(), s2s.clone()]);
    cfg.policy = BatchPolicy { max_batch: 2, max_wait: Duration::from_millis(2) };
    let server = Server::start(cfg).unwrap();

    // decoder-only generation, checked against the oracle
    let prompt = weights::init_input(31, 4, 256);
    let resp = generate(&server, "gpt", prompt.clone(), None, 5).unwrap();
    let want = reference::greedy_decode(&prompt, None, &gpt.decoder_weights(), 5);
    assert_eq!(resp.tokens, want.tokens);
    assert_eq!(resp.step_times.len(), 4, "steps - 1 per-token samples");
    assert!(resp.timing.latency >= resp.timing.queue_wait);

    // seq2seq generation through the same pool
    let source = weights::init_input(32, 32, 256);
    let resp2 =
        generate(&server, "s2s", weights::init_input(33, 3, 256), Some(source), 4).unwrap();
    assert_eq!(resp2.tokens.len(), 4);

    // plain encode on a decoder model is an explicit typed error (the
    // old silent-truncation path)
    let err = server
        .submit(
            Submission::Encode { model: "gpt".into(), input: weights::init_input(34, 32, 256) },
            QoS::default(),
        )
        .unwrap_err();
    assert!(matches!(&err, ServeError::InvalidRequest(_)), "{err:?}");
    assert!(err.to_string().contains("decoder layers"), "{err}");

    let m = server.shutdown().unwrap();
    assert_eq!(m.generations, 2);
    assert_eq!(m.failed, 0);
    assert_eq!(m.prefills.len(), 2);
    assert_eq!(m.decode_steps.len(), 4 + 3, "per-token samples merged across generations");
    assert!(m.prefill_summary().unwrap().mean > 0.0);
    assert!(m.step_summary().unwrap().mean > 0.0);
}

#[test]
fn failed_generations_do_not_pollute_the_latency_samples() {
    require_decode_artifacts!();
    let gpt = ModelSpec::new("gpt", presets::gpt_small(32, 1), 41);
    let mut cfg = ServerConfig::new(vec![gpt]);
    cfg.policy = BatchPolicy { max_batch: 1, max_wait: Duration::from_millis(1) };
    cfg.fault.fail_program_for = Some("gpt".into());
    let server = Server::start(cfg).unwrap();
    let err = generate(&server, "gpt", weights::init_input(42, 4, 256), None, 4).unwrap_err();
    assert!(matches!(&err, ServeError::ProgramFailed(_)), "{err:?}");
    assert!(err.to_string().contains("programming registers"), "{err}");
    let m = server.shutdown().unwrap();
    assert_eq!(m.failed, 1);
    assert_eq!(m.generations, 0);
    assert!(m.prefills.is_empty(), "failed generation must not add prefill samples");
    assert!(m.decode_steps.is_empty());
}

#[test]
fn streamed_tokens_concatenate_bit_identically_to_the_transcript() {
    require_decode_artifacts!();
    let gpt = ModelSpec::new("gpt", presets::gpt_small(32, 1), 61);
    let server = Server::start(ServerConfig::new(vec![gpt.clone()])).unwrap();
    let prompt = weights::init_input(62, 4, 256);

    // non-streamed baseline transcript
    let base = generate(&server, "gpt", prompt.clone(), None, 6).unwrap();

    // streamed run: drain every token event, then take the transcript
    let mut handle = server
        .submit(
            Submission::Generate {
                model: "gpt".into(),
                prompt: prompt.clone(),
                source: None,
                steps: 6,
            },
            QoS::default(),
        )
        .unwrap();
    let mut tokens = Vec::new();
    let mut rows: Vec<f32> = Vec::new();
    while let Some(t) = handle.next_token() {
        assert_eq!(t.index, tokens.len(), "tokens arrive in step order");
        assert_eq!(t.row.len(), 256, "each event carries one d_model row");
        tokens.push(t.token);
        rows.extend_from_slice(&t.row);
    }
    let out = handle.wait().unwrap().into_generate().unwrap();

    // the stream concatenates bit-identically to the final transcript…
    assert_eq!(tokens, out.tokens);
    assert_eq!(rows, out.rows.data);
    // …which is bit-identical to the non-streamed replay of the same job
    assert_eq!(out.tokens, base.tokens);
    assert_eq!(out.rows.data, base.rows.data);
    // and matches the dense greedy oracle
    let want = reference::greedy_decode(&prompt, None, &gpt.decoder_weights(), 6);
    assert_eq!(out.tokens, want.tokens);

    let m = server.shutdown().unwrap();
    assert_eq!(m.generations, 2);
    assert_eq!(m.failed, 0);
}

#[test]
fn cancellation_mid_generation_stops_cleanly_and_pool_recovers() {
    require_decode_artifacts!();
    let gpt = ModelSpec::new("gpt", presets::gpt_small(32, 1), 71);
    let server = Server::start(ServerConfig::new(vec![gpt.clone()])).unwrap();
    let prompt = weights::init_input(72, 4, 256);

    // A long generation (24 of a possible 28 steps): cancel right after
    // the first streamed token; the worker observes the flag between
    // decode steps.
    let mut doomed = server
        .submit(
            Submission::Generate {
                model: "gpt".into(),
                prompt: prompt.clone(),
                source: None,
                steps: 24,
            },
            QoS::default(),
        )
        .unwrap();
    let first = doomed.next_token().expect("the first token streams out of the prefill");
    assert_eq!(first.index, 0);
    doomed.cancel();
    match doomed.wait() {
        Err(ServeError::Cancelled) => {}
        other => panic!("expected Cancelled, got {other:?}"),
    }

    // The pool serves correctly afterwards: KV cache/pool state from the
    // cancelled run leaks into nothing.
    let out = generate(&server, "gpt", prompt.clone(), None, 5).unwrap();
    let want = reference::greedy_decode(&prompt, None, &gpt.decoder_weights(), 5);
    assert_eq!(out.tokens, want.tokens, "post-cancel generation must match the oracle");
    assert!(out.rows.max_abs_diff(&want.rows) < 5e-3);

    let m = server.shutdown().unwrap();
    assert_eq!(m.cancelled, 1, "the cancellation must be counted");
    assert_eq!(m.generations, 1, "a cancelled generation is not a completed one");
    assert_eq!(m.prefills.len(), 1, "no partial generation pollutes the prefill samples");
    assert_eq!(m.decode_steps.len(), 4, "only the successful generation's steps are sampled");
    assert_eq!(m.requests(), 1, "cancelled generation records no e2e latency sample");
}
