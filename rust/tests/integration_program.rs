//! Program/backend equivalence: the PJRT executor and the cycle backend
//! must replay the *same* `TileProgram` with identical dispatch counts and
//! artifact sequences (one schedule, two substrates — the tentpole
//! contract), and the schedule cache must turn the request path into
//! "look up program, replay".

use adaptor::accel::schedule::{AttentionMode, OptLevel, ScheduleBuilder};
use adaptor::accel::sim::cycle;
use adaptor::coordinator::TileEngine;
use adaptor::model::{presets, reference, weights, TnnConfig};
use adaptor::runtime::default_artifact_dir;

use adaptor::require_artifacts;

fn engine() -> TileEngine {
    TileEngine::new(default_artifact_dir()).expect("run `make artifacts` first")
}

/// Sweep of topologies legal on the default fabric (seq_len, heads and
/// layer count all vary — the property the IR must hold across the space).
fn topology_sweep() -> Vec<TnnConfig> {
    vec![
        TnnConfig::encoder(16, 128, 2, 1),
        TnnConfig::encoder(32, 256, 4, 2),
        TnnConfig::encoder(48, 128, 2, 3),
        TnnConfig::encoder(64, 384, 6, 1),
        TnnConfig::encoder(128, 128, 2, 1),
    ]
}

#[test]
fn pjrt_and_cycle_backend_replay_identical_streams() {
    require_artifacts!();
    let mut e = engine();
    for cfg in topology_sweep() {
        let ws = weights::init_stack(77, cfg.d_model, cfg.heads, cfg.enc_layers);
        e.program(&cfg).unwrap();
        let p = e.prepare(&cfg, &ws).unwrap();
        let x = weights::init_input(78, cfg.seq_len, cfg.d_model);

        e.executor().trace_dispatches(true);
        e.run_encoder(&p, &x).unwrap();
        let pjrt_trace = e.executor().take_trace();

        let rep = e.cycle_estimate(&cfg).unwrap();
        assert_eq!(
            pjrt_trace.len(),
            rep.dispatches as usize,
            "{cfg}: dispatch counts diverge between backends"
        );
        assert_eq!(pjrt_trace, rep.trace, "{cfg}: artifact sequences diverge");

        // both must also agree with the program's own stream — at full
        // length only the top tier of the skippable program fires, so
        // compare against the live (fired) sequence, not the static one
        let prog = e.cached_program(&cfg).unwrap();
        assert_eq!(
            pjrt_trace,
            prog.program.live_dispatch_sequence(cfg.seq_len),
            "{cfg}: PJRT strayed from the program"
        );
    }
}

#[test]
fn equivalence_holds_across_modes_and_packing() {
    require_artifacts!();
    let mut e = engine();
    let cfg = presets::small_encoder(32, 1);
    let ws = weights::init_stack(79, cfg.d_model, cfg.heads, 1);
    e.program(&cfg).unwrap();
    let p = e.prepare(&cfg, &ws).unwrap();
    let x = weights::init_input(80, cfg.seq_len, cfg.d_model);
    for (mode, packed, quantized) in [
        (AttentionMode::Fused, false, false),
        (AttentionMode::Split, true, false),
        (AttentionMode::Split, false, true),
    ] {
        e.mode = mode;
        e.qkv_packed = packed;
        e.quantized = quantized;
        e.executor().trace_dispatches(true);
        e.run_encoder(&p, &x).unwrap();
        let pjrt_trace = e.executor().take_trace();
        let rep = e.cycle_estimate(&cfg).unwrap();
        assert_eq!(
            pjrt_trace, rep.trace,
            "mode={mode:?} packed={packed} quantized={quantized}"
        );
    }
}

#[test]
fn cached_replay_drops_per_request_transfers() {
    require_artifacts!();
    // The old engine re-uploaded the full padded x per layer plus the
    // mask/dmask/count/zero tensors per request.  The program does
    // neither: uploads per replay == the program's Upload/Calibrate steps,
    // and the formula below contains no full-x term beyond the input.
    // Pinned to O0: the closed-form counts describe the builder's raw
    // stream (the optimized stream is covered by the tests below).
    let mut e = engine();
    e.opt_level = OptLevel::O0;
    let cfg = presets::small_encoder(32, 3);
    let ws = weights::init_stack(81, cfg.d_model, cfg.heads, cfg.enc_layers);
    e.program(&cfg).unwrap();
    let p = e.prepare(&cfg, &ws).unwrap();
    let x = weights::init_input(82, cfg.seq_len, cfg.d_model);

    let s0 = e.executor().stats();
    e.run_encoder(&p, &x).unwrap(); // builds + uploads runtime tensors
    let s1 = e.executor().stats();
    e.run_encoder(&p, &x).unwrap(); // pure replay
    let s2 = e.executor().stats();

    let fc = e.fabric_constants();
    let t_m = cfg.d_model / fc.ts_mha;
    let t_f = cfg.d_model / fc.ts_ffn;
    let t_h = cfg.hidden / fc.ffn_col;
    let l = cfg.enc_layers;
    // 1 padded input + per-layer activation panels and assemblies — and
    // NOT the old l-1 extra full-x uploads nor the runtime tensors.
    let expected = (1 + l * (t_m + 2 * t_f + t_h + 3)) as u64;
    assert_eq!(s2.uploads - s1.uploads, expected, "replay upload count");
    // The per-topology runtime set is the 10 base tensors plus one
    // mask + causal-mask pair per non-top length tier.
    let tiers = adaptor::accel::schedule::length_tiers(cfg.seq_len).len() as u64;
    let runtime_set = 10 + 2 * (tiers - 1);
    assert_eq!(
        s1.uploads - s0.uploads,
        expected + runtime_set,
        "first request additionally uploads the per-topology runtime tensors"
    );
    let naive = expected + runtime_set + (l as u64 - 1); // what the loop-nest engine paid
    assert!(s2.uploads - s1.uploads < naive, "the transfer drop must be real");

    let prog = e.cached_program(&cfg).unwrap();
    assert_eq!(prog.program.upload_count() as u64, expected);
    assert_eq!(s2.fetches - s1.fetches, prog.program.fetch_count() as u64);
    // at full length only the fired (top-tier) dispatches execute
    assert_eq!(
        s2.dispatches - s1.dispatches,
        prog.program.live_dispatch_count(cfg.seq_len) as u64
    );
}

#[test]
fn cache_hit_on_repeated_requests_same_numerics() {
    require_artifacts!();
    let mut e = engine();
    let cfg = TnnConfig::encoder(48, 256, 4, 2);
    let ws = weights::init_stack(83, cfg.d_model, cfg.heads, 2);
    e.program(&cfg).unwrap();
    let p = e.prepare(&cfg, &ws).unwrap();
    let x = weights::init_input(84, cfg.seq_len, cfg.d_model);
    let a = e.run_encoder(&p, &x).unwrap();
    let b = e.run_encoder(&p, &x).unwrap();
    let c = e.run_encoder(&p, &x).unwrap();
    assert_eq!(e.program_cache_stats(), (2, 1), "(hits, misses)");
    assert!(a.max_abs_diff(&b) < 1e-6);
    assert!(b.max_abs_diff(&c) < 1e-6);
    // and the cached replay still matches the dense oracle
    let mask = reference::attention_mask(cfg.seq_len, cfg.seq_len, false);
    let want = reference::encoder_stack(&x, &ws, &mask);
    assert!(a.max_abs_diff(&want) < 3e-3);
}

#[test]
fn programs_for_shared_topology_are_shared_across_models() {
    require_artifacts!();
    // two different weight stacks, one topology: one cached program
    let mut e = engine();
    let cfg = presets::small_encoder(32, 1);
    let ws1 = weights::init_stack(85, cfg.d_model, cfg.heads, 1);
    let ws2 = weights::init_stack(86, cfg.d_model, cfg.heads, 1);
    e.program(&cfg).unwrap();
    let p1 = e.prepare(&cfg, &ws1).unwrap();
    let p2 = e.prepare(&cfg, &ws2).unwrap();
    let x = weights::init_input(87, cfg.seq_len, cfg.d_model);
    let o1 = e.run_encoder(&p1, &x).unwrap();
    let o2 = e.run_encoder(&p2, &x).unwrap();
    assert_eq!(e.program_cache_stats(), (1, 1), "second stack hits the same program");
    assert!(o1.max_abs_diff(&o2) > 1e-6, "different weights, different outputs");
}

#[test]
fn o1_optimized_replay_matches_raw_bit_for_bit_on_pjrt() {
    require_artifacts!();
    // O1 is pure reorder + transfer dedup: every dispatch still receives
    // bit-identical operands, so PJRT outputs are bit-identical too.
    let mut e = engine();
    for cfg in topology_sweep() {
        let ws = weights::init_stack(91, cfg.d_model, cfg.heads, cfg.enc_layers);
        e.program(&cfg).unwrap();
        let p = e.prepare(&cfg, &ws).unwrap();
        let x = weights::init_input(92, cfg.seq_len, cfg.d_model);
        e.opt_level = OptLevel::O0;
        let raw = e.run_encoder(&p, &x).unwrap();
        e.opt_level = OptLevel::O1;
        let optd = e.run_encoder(&p, &x).unwrap();
        assert_eq!(
            raw.max_abs_diff(&optd),
            0.0,
            "{cfg}: O1 replay must be bit-identical to the raw stream"
        );
    }
}

#[test]
fn o2_serving_path_is_strictly_cheaper_and_in_band() {
    require_artifacts!();
    // The acceptance gate: the optimized encoder-layer replay must
    // strictly reduce dispatches+uploads vs the unoptimized program,
    // measured from ExecStats on the real PJRT path.
    let mut e = engine();
    let cfg = presets::small_encoder(64, 2);
    let ws = weights::init_stack(93, cfg.d_model, cfg.heads, cfg.enc_layers);
    e.program(&cfg).unwrap();
    let p = e.prepare(&cfg, &ws).unwrap();
    let x = weights::init_input(94, cfg.seq_len, cfg.d_model);

    e.opt_level = OptLevel::O0;
    let raw_out = e.run_encoder(&p, &x).unwrap(); // warm O0 program
    let s0 = e.executor().stats();
    e.run_encoder(&p, &x).unwrap();
    let s1 = e.executor().stats();

    e.opt_level = OptLevel::O2;
    let opt_out = e.run_encoder(&p, &x).unwrap(); // warm O2 program
    let s2 = e.executor().stats();
    e.run_encoder(&p, &x).unwrap();
    let s3 = e.executor().stats();

    let (d0, u0) = (s1.dispatches - s0.dispatches, s1.uploads - s0.uploads);
    let (d2, u2) = (s3.dispatches - s2.dispatches, s3.uploads - s2.uploads);
    assert!(d2 < d0, "optimized replay must dispatch less ({d2} vs {d0})");
    assert!(u2 <= u0, "optimized replay must not upload more ({u2} vs {u0})");
    assert!(d2 + u2 < d0 + u0, "dispatches+uploads must strictly drop");
    // counts must agree with the cached programs themselves (live counts:
    // at full length only the top tier of the skippable program fires)
    let prog = e.cached_program(&cfg).unwrap();
    assert_eq!(d2, prog.program.live_dispatch_count(cfg.seq_len) as u64);
    assert_eq!(u2, prog.program.upload_count() as u64);
    // and numerics stay within the fused artifacts' band
    assert!(raw_out.max_abs_diff(&opt_out) < 1e-3);
    // the dispatch trace of the optimized replay is the optimized stream
    e.executor().trace_dispatches(true);
    e.run_encoder(&p, &x).unwrap();
    assert_eq!(e.executor().take_trace(), prog.program.live_dispatch_sequence(cfg.seq_len));
}

#[test]
fn cycle_estimate_needs_no_artifacts() {
    // the schedule-grounded estimate must work without the AOT set — the
    // design-space tools rely on it (this test intentionally does NOT
    // require_artifacts).
    let fc = adaptor::accel::schedule::FabricConstants::artifact_default();
    let cfg = TnnConfig::encoder(64, 512, 8, 6);
    let prog = ScheduleBuilder::new(fc, cfg).unwrap().build();
    let rep = cycle::replay_program(&prog).unwrap();
    assert_eq!(rep.dispatches as usize, prog.dispatch_count());
    assert!(rep.total_cycles > 0);
}
