//! Fabric-pool integration: a pool of ≥2 fabrics drains a mixed-model
//! workload correctly, the affinity scheduler beats round-robin on
//! register reprograms per request, and host-side failure paths fail
//! loudly (programming errors fail the batch; panics surface at
//! shutdown).

use std::time::Duration;

use adaptor::coordinator::batcher::BatchPolicy;
use adaptor::coordinator::router::ModelSpec;
use adaptor::coordinator::{SchedulePolicy, Server, ServerConfig};
use adaptor::model::weights::init_input;
use adaptor::model::{presets, reference, weights, TnnConfig};
use adaptor::serve::{Priority, QoS, ServeError, Submission};

use adaptor::require_artifacts;

fn encode(model: &str, input: weights::Mat) -> Submission {
    Submission::Encode { model: model.into(), input }
}

/// Submit-and-wait convenience on the v1 surface.
fn infer(server: &Server, model: &str, input: weights::Mat) -> Result<weights::Mat, ServeError> {
    Ok(server.submit(encode(model, input), QoS::default())?.wait()?.into_encode()?.output)
}

fn two_models() -> (ModelSpec, ModelSpec) {
    (
        ModelSpec::new("a", presets::small_encoder(32, 1), 7),
        ModelSpec::new("b", TnnConfig::encoder(16, 128, 2, 1), 8),
    )
}

fn pool_config(pool_size: usize, schedule: SchedulePolicy) -> ServerConfig {
    let (a, b) = two_models();
    let mut cfg = ServerConfig::new(vec![a, b]);
    cfg.policy = BatchPolicy { max_batch: 2, max_wait: Duration::from_millis(2) };
    cfg.pool_size = pool_size;
    cfg.schedule = schedule;
    cfg
}

#[test]
fn pool_drains_mixed_model_workload_across_fabrics() {
    require_artifacts!();
    let server = Server::start(pool_config(2, SchedulePolicy::Affinity)).expect("make artifacts");
    // submit everything up front so both fabrics get saturated
    let mut handles = Vec::new();
    for i in 0..12u64 {
        let (model, cfg) = if i % 3 == 0 {
            ("b", TnnConfig::encoder(16, 128, 2, 1))
        } else {
            ("a", presets::small_encoder(32, 1))
        };
        let x = init_input(i, cfg.seq_len, cfg.d_model);
        let h = server.submit(encode(model, x.clone()), QoS::default()).unwrap();
        handles.push((i, model, cfg, x, h));
    }
    for (i, model, cfg, x, h) in handles {
        let out = h
            .wait()
            .unwrap_or_else(|e| panic!("req {i} ({model}): {e}"))
            .into_encode()
            .unwrap();
        let seed = if model == "a" { 7 } else { 8 };
        let ws = weights::init_stack(seed, cfg.d_model, cfg.heads, cfg.enc_layers);
        let mask = reference::attention_mask(cfg.seq_len, cfg.seq_len, false);
        let want = reference::encoder_stack(&x, &ws, &mask);
        assert!(out.output.max_abs_diff(&want) < 3e-3, "req {i} wrong numerics");
    }
    let m = server.shutdown().unwrap();
    assert_eq!(m.requests(), 12);
    assert_eq!(m.failed, 0);
    assert_eq!(m.per_fabric.len(), 2, "aggregate must carry the per-fabric breakdown");
    let served: Vec<usize> = m.per_fabric.iter().map(|f| f.requests()).collect();
    assert_eq!(served.iter().sum::<usize>(), 12);
    assert!(
        served.iter().filter(|&&n| n > 0).count() >= 2,
        "work must spread across >=2 fabrics, got {served:?}"
    );
}

#[test]
fn affinity_scheduling_reprograms_less_than_round_robin() {
    require_artifacts!();
    // Serial [a, a, b] pattern with max_batch = 1: every request is its
    // own batch, dispatch order equals submit order, so the reprogram
    // counts are deterministic for both policies.
    let run = |schedule: SchedulePolicy| {
        let mut cfg = pool_config(2, schedule);
        cfg.policy = BatchPolicy { max_batch: 1, max_wait: Duration::from_millis(1) };
        let server = Server::start(cfg).unwrap();
        for round in 0..4u64 {
            for (j, model) in ["a", "a", "b"].into_iter().enumerate() {
                let c = if model == "a" {
                    presets::small_encoder(32, 1)
                } else {
                    TnnConfig::encoder(16, 128, 2, 1)
                };
                let x = init_input(round * 10 + j as u64, c.seq_len, c.d_model);
                infer(&server, model, x).unwrap();
            }
        }
        server.shutdown().unwrap()
    };
    let affinity = run(SchedulePolicy::Affinity);
    let round_robin = run(SchedulePolicy::RoundRobin);
    assert_eq!(affinity.requests(), 12);
    assert_eq!(round_robin.requests(), 12);
    // Affinity parks each model on one fabric: one programming per fabric.
    assert_eq!(affinity.reprograms, 2, "affinity must program each fabric once");
    assert!(
        round_robin.reprograms > affinity.reprograms,
        "round-robin ({}) must reprogram more than affinity ({})",
        round_robin.reprograms,
        affinity.reprograms
    );
    assert!(
        affinity.reprograms_per_request() < round_robin.reprograms_per_request(),
        "affinity must cost fewer reprograms per request"
    );
}

#[test]
fn router_affinity_hint_pins_model_to_fabric() {
    require_artifacts!();
    let (a, b) = two_models();
    let mut cfg = ServerConfig::new(vec![a.with_affinity(1), b]);
    cfg.policy = BatchPolicy { max_batch: 1, max_wait: Duration::from_millis(1) };
    cfg.pool_size = 2;
    let server = Server::start(cfg).unwrap();
    for i in 0..4u64 {
        let x = init_input(i, 32, 256);
        infer(&server, "a", x).unwrap();
    }
    let m = server.shutdown().unwrap();
    assert_eq!(m.requests(), 4);
    // every "a" request landed on the pinned fabric 1
    assert_eq!(m.per_fabric[1].requests(), 4, "{:?}", m.per_fabric.iter().map(|f| f.requests()).collect::<Vec<_>>());
    assert_eq!(m.per_fabric[0].requests(), 0);
}

#[test]
fn program_failure_fails_batch_and_pool_recovers() {
    require_artifacts!();
    let mut cfg = pool_config(2, SchedulePolicy::Affinity);
    cfg.fault.fail_program_for = Some("b".into());
    let server = Server::start(cfg).unwrap();
    // "a" requests serve normally on the pool
    for i in 0..3u64 {
        let x = init_input(i, 32, 256);
        assert!(infer(&server, "a", x).is_ok());
    }
    // every "b" request fails with the typed programming error — no
    // silent stale-register execution, no hung reply channel
    for i in 0..2u64 {
        let x = init_input(100 + i, 16, 128);
        let err = infer(&server, "b", x).unwrap_err();
        assert!(matches!(&err, ServeError::ProgramFailed(_)), "{err:?}");
        assert!(err.to_string().contains("programming registers"), "{err}");
    }
    // and "a" keeps serving afterwards
    let x = init_input(50, 32, 256);
    assert!(infer(&server, "a", x).is_ok());
    let m = server.shutdown().unwrap();
    assert_eq!(m.requests(), 4);
    assert_eq!(m.failed, 2);
}

#[test]
fn high_priority_jumps_the_queue_on_a_saturated_single_fabric() {
    require_artifacts!();
    // One slow-ish fabric, one request in flight at a time: priority
    // ordering is decided entirely in the batcher's ready queue.
    let spec = ModelSpec::new("m", presets::small_encoder(64, 4), 7);
    let mut cfg = ServerConfig::new(vec![spec]);
    cfg.policy = BatchPolicy { max_batch: 1, max_wait: Duration::from_millis(1) };
    cfg.pool_size = 1;
    cfg.queue_depth = 1;
    let server = Server::start(cfg).unwrap();
    let x = |i: u64| init_input(i, 64, 256);
    // Saturate: the warmup request occupies the fabric while the rest
    // queue behind it (submission takes µs, each compute takes ms).
    let warm = server.submit(encode("m", x(0)), QoS::default()).unwrap();
    let normals: Vec<_> =
        (1..=4).map(|i| server.submit(encode("m", x(i)), QoS::default()).unwrap()).collect();
    let highs: Vec<_> =
        (5..=6).map(|i| server.submit(encode("m", x(i)), QoS::high()).unwrap()).collect();
    warm.wait().unwrap();
    // The highs were submitted LAST but must start (and finish) before
    // every still-queued normal: their end-to-end latency is strictly
    // below the slowest normal's (all submits happened within µs of one
    // another, so latencies are directly comparable).
    let high_lat: Vec<Duration> =
        highs.into_iter().map(|h| h.wait().unwrap().timing().latency).collect();
    let normal_lat: Vec<Duration> =
        normals.into_iter().map(|h| h.wait().unwrap().timing().latency).collect();
    let worst_high = *high_lat.iter().max().unwrap();
    let worst_normal = *normal_lat.iter().max().unwrap();
    assert!(
        worst_high < worst_normal,
        "high-priority latencies {high_lat:?} must stay below the slowest normal {normal_lat:?}"
    );
    let m = server.shutdown().unwrap();
    assert_eq!(m.requests(), 7);
    assert_eq!(m.served_at(Priority::High), 2);
    assert_eq!(m.served_at(Priority::Normal), 5);
}

#[test]
fn queued_deadline_expiry_is_typed_and_counted() {
    require_artifacts!();
    // A request whose QoS deadline cannot be met while queued completes
    // with ServeError::DeadlineExceeded and is counted — not served
    // late, not dropped silently.
    let spec = ModelSpec::new("m", presets::small_encoder(64, 4), 7);
    let mut cfg = ServerConfig::new(vec![spec]);
    cfg.policy = BatchPolicy { max_batch: 1, max_wait: Duration::from_millis(1) };
    cfg.pool_size = 1;
    cfg.queue_depth = 1;
    let server = Server::start(cfg).unwrap();
    let x = |i: u64| init_input(i, 64, 256);
    let warm = server.submit(encode("m", x(0)), QoS::default()).unwrap();
    let fillers: Vec<_> =
        (1..=3).map(|i| server.submit(encode("m", x(i)), QoS::default()).unwrap()).collect();
    // Queued behind ~4 multi-millisecond computes with a 1ms deadline:
    // expires in the queue, swept out by the dispatcher.
    let doomed = server
        .submit(encode("m", x(9)), QoS::default().with_deadline(Duration::from_millis(1)))
        .unwrap();
    match doomed.wait() {
        Err(ServeError::DeadlineExceeded { waited }) => {
            assert!(waited >= Duration::from_millis(1), "waited {waited:?}")
        }
        other => panic!("expected DeadlineExceeded, got {other:?}"),
    }
    warm.wait().unwrap();
    for f in fillers {
        f.wait().unwrap();
    }
    let m = server.shutdown().unwrap();
    assert_eq!(m.expired, 1, "the expiry must be counted");
    assert_eq!(m.requests(), 4, "expired request must not count as served");
    assert_eq!(m.failed, 0, "deadline expiry is not an execution failure");
}

#[test]
fn cancelling_a_queued_job_completes_it_without_serving() {
    require_artifacts!();
    let spec = ModelSpec::new("m", presets::small_encoder(64, 4), 7);
    let mut cfg = ServerConfig::new(vec![spec]);
    cfg.policy = BatchPolicy { max_batch: 1, max_wait: Duration::from_millis(1) };
    cfg.pool_size = 1;
    cfg.queue_depth = 1;
    let server = Server::start(cfg).unwrap();
    let x = |i: u64| init_input(i, 64, 256);
    let warm = server.submit(encode("m", x(0)), QoS::default()).unwrap();
    // Low priority keeps it parked behind any other work while queued.
    let doomed = server.submit(encode("m", x(1)), QoS::low()).unwrap();
    doomed.cancel();
    match doomed.wait() {
        Err(ServeError::Cancelled) => {}
        other => panic!("expected Cancelled, got {other:?}"),
    }
    warm.wait().unwrap();
    let m = server.shutdown().unwrap();
    assert_eq!(m.cancelled, 1);
    assert_eq!(m.requests(), 1, "cancelled job must not be served");
}

#[test]
fn single_fabric_pool_matches_paper_host_semantics() {
    require_artifacts!();
    // pool_size = 1 must behave exactly like the paper's single-engine
    // host: same request count, reprogram-on-switch, one fabric entry.
    let server = Server::start(pool_config(1, SchedulePolicy::Affinity)).unwrap();
    for i in 0..3u64 {
        let xa = init_input(i, 32, 256);
        let xb = init_input(i + 10, 16, 128);
        assert!(infer(&server, "a", xa).is_ok());
        assert!(infer(&server, "b", xb).is_ok());
    }
    let m = server.shutdown().unwrap();
    assert_eq!(m.requests(), 6);
    assert_eq!(m.per_fabric.len(), 1);
    assert!(m.reprograms >= 5, "alternating models on one fabric reprogram every switch");
}
