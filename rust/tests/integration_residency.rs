//! Weight-residency invariants, artifact-free.  The residency manager in
//! `coordinator::residency` turns a fabric's weight memory into a
//! capacity-bounded cache of prepared stacks; its correctness contract is
//! that caching is *invisible* to the served numerics — an evicted and
//! re-uploaded stack must reproduce the never-evicted transcript bit for
//! bit, a model with live KV-cached generations must never lose its stack
//! to peer churn, and the whole point of the layer — strictly fewer
//! uploads than the paper's reprogram-on-every-switch host loop — must
//! hold on a real churn workload.  These tests pin that contract at the
//! replay level with the same pseudo-numeric backend as
//! `integration_scheduler.rs`: the manager's `S` is a full host-side
//! model stack (programs + deterministic weights + runtime buffers) and
//! every acquire serves an actual program replay.

use std::cell::Cell;
use std::collections::HashMap;

use adaptor::accel::decode::{self, KvCache};
use adaptor::accel::schedule::{
    self, optimize, ArtifactInventory, FabricConstants, OptLevel, ScheduleBuilder, TileProgram,
    WeightKind, WeightRef, WeightSource,
};
use adaptor::coordinator::residency::weight_footprint_bytes;
use adaptor::coordinator::{ResidencyMode, ResidencyPolicy, WeightResidencyManager};
use adaptor::model::TnnConfig;
use adaptor::runtime::{FabricBackend, Tensor};

fn fc() -> FabricConstants {
    FabricConstants::artifact_default()
}

/// Decoder-only topology with room for a prompt plus several decode
/// steps under `sl_max`.
fn gpt() -> TnnConfig {
    TnnConfig { seq_len: 32, heads: 4, d_model: 256, hidden: 1024, enc_layers: 0, dec_layers: 2 }
}

fn fnv(s: &str) -> u32 {
    s.bytes().fold(2166136261u32, |h, b| (h ^ b as u32).wrapping_mul(16777619))
}

/// Pseudo-numeric backend (same construction as `integration_scheduler`):
/// buffers are host tensors, dispatch output is a bounded deterministic
/// mix of `(artifact, inputs)`.  A stack rebuilt from the wrong weights —
/// or a stale panel surviving an eviction — changes some output
/// bit-for-bit.
struct HashBackend;

impl FabricBackend for HashBackend {
    type Buf = Tensor;

    fn upload(&self, t: &Tensor) -> anyhow::Result<Tensor> {
        Ok(t.clone())
    }

    fn dispatch(
        &self,
        artifact: &str,
        inputs: &[&Tensor],
        out_shape: &[usize],
    ) -> anyhow::Result<Tensor> {
        let n: usize = out_shape.iter().product();
        let mut data = vec![0.0f32; n];
        let mut h = fnv(artifact);
        for (k, t) in inputs.iter().enumerate() {
            let len = t.data.len().max(1);
            let w = ((h % 13) + k as u32 + 1) as f32 * 0.0625;
            for (j, v) in data.iter_mut().enumerate() {
                *v += t.data[(j + 7 * k) % len] * w;
            }
            h = h.wrapping_mul(16777619) ^ (k as u32 + 1);
        }
        for v in data.iter_mut() {
            *v = (*v * 0.25).sin();
        }
        Ok(Tensor::new(out_shape.to_vec(), data))
    }

    fn fetch(&self, b: &Tensor) -> anyhow::Result<Tensor> {
        Ok(b.clone())
    }
}

/// Fabric-fixed panel shape per weight kind (mirrors `integration_opt`).
fn weight_shape(f: &FabricConstants, kind: WeightKind) -> Vec<usize> {
    match kind {
        WeightKind::Wq
        | WeightKind::Wk
        | WeightKind::Wv
        | WeightKind::CWq
        | WeightKind::CWk
        | WeightKind::CWv => vec![f.ts_mha, f.dk],
        WeightKind::QkvPacked => vec![f.ts_mha, 3 * f.dk],
        WeightKind::Bq
        | WeightKind::Bk
        | WeightKind::Bv
        | WeightKind::CBq
        | WeightKind::CBk
        | WeightKind::CBv => vec![f.dk],
        WeightKind::BQkvPacked => vec![3 * f.dk],
        WeightKind::Wo | WeightKind::CWo => vec![f.ts_ffn, f.ts_ffn],
        WeightKind::Bo
        | WeightKind::B2
        | WeightKind::G1
        | WeightKind::B1n
        | WeightKind::G2
        | WeightKind::B2n
        | WeightKind::CBo
        | WeightKind::CG
        | WeightKind::CBn => vec![f.dmodel_max],
        WeightKind::W1 => vec![f.ts_ffn, f.ffn_col],
        WeightKind::B1 => vec![f.hidden_max],
        WeightKind::W2 => vec![f.ffn_col, f.ts_ffn],
        WeightKind::DWq | WeightKind::DWk | WeightKind::DWv | WeightKind::DCWq => {
            vec![f.dmodel_max, f.dk]
        }
        WeightKind::DWo | WeightKind::DCWo => vec![f.dmodel_max, f.dmodel_max],
        WeightKind::DW1 => vec![f.dmodel_max, f.hidden_max],
        WeightKind::DW2 => vec![f.hidden_max, f.dmodel_max],
    }
}

/// Deterministic weight stand-ins keyed by `WeightRef`, salted per model
/// name — re-preparing the same model reproduces the same tensors
/// bit-for-bit (the property the residency layer's lazy re-upload relies
/// on), while distinct models get distinct weights so any stale-stack
/// bug surfaces as a transcript mismatch.
struct HashWeights {
    map: HashMap<WeightRef, Tensor>,
}

impl HashWeights {
    fn for_program(prog: &TileProgram, f: &FabricConstants, salt: &str) -> Self {
        let mut map = HashMap::new();
        for step in &prog.steps {
            let schedule::Step::Dispatch { args, .. } = step else { continue };
            for arg in args {
                let schedule::Operand::Weight(r) = arg else { continue };
                map.entry(*r).or_insert_with(|| {
                    let shape = weight_shape(f, r.kind);
                    let seed =
                        fnv(&format!("{salt}/{:?}/{}/{}/{}", r.kind, r.layer, r.row, r.col))
                            % 1000;
                    let n: usize = shape.iter().product();
                    let data =
                        (0..n).map(|i| ((seed as usize + i) as f32 * 0.137).sin()).collect();
                    Tensor::new(shape, data)
                });
            }
        }
        HashWeights { map }
    }
}

impl WeightSource<Tensor> for HashWeights {
    fn weight(&self, r: &WeightRef) -> anyhow::Result<&Tensor> {
        self.map.get(r).ok_or_else(|| anyhow::anyhow!("unseeded weight ref {r:?}"))
    }
}

/// Everything `prepare_model` parks device-side for one model, as the
/// manager's cached `S`: the optimized programs, the (salted,
/// deterministic) weights and the per-topology runtime buffers.  Building
/// one IS the upload being counted.
struct ModelStack {
    pre: TileProgram,
    step: Option<TileProgram>,
    pw: HashWeights,
    sw: Option<HashWeights>,
    runtime: schedule::RuntimeBufs<Tensor>,
}

fn load_stack(name: &str, cfg: TnnConfig, f: FabricConstants) -> ModelStack {
    let inv = ArtifactInventory::assume_all();
    let backend = HashBackend;
    let runtime = schedule::build_runtime(&backend, &cfg, &f).unwrap();
    if cfg.dec_layers > 0 {
        let mut pre = ScheduleBuilder::new(f, cfg).unwrap().build_prefill();
        optimize(&mut pre, OptLevel::O1, &inv).unwrap();
        let mut step = ScheduleBuilder::new(f, cfg).unwrap().build_step();
        optimize(&mut step, OptLevel::O1, &inv).unwrap();
        let pw = HashWeights::for_program(&pre, &f, name);
        let sw = HashWeights::for_program(&step, &f, name);
        ModelStack { pre, step: Some(step), pw, sw: Some(sw), runtime }
    } else {
        let mut prog = ScheduleBuilder::new(f, cfg).unwrap().build();
        optimize(&mut prog, OptLevel::O1, &inv).unwrap();
        let pw = HashWeights::for_program(&prog, &f, name);
        ModelStack { pre: prog, step: None, pw, sw: None, runtime }
    }
}

/// A (model, footprint, upload-counter) triple driven through the
/// manager exactly as `fabric_thread::acquire_stack` drives the real one.
struct Tenant {
    name: &'static str,
    cfg: TnnConfig,
    bytes: u64,
    loads: Cell<u64>,
}

impl Tenant {
    fn new(name: &'static str, cfg: TnnConfig) -> Self {
        let bytes = weight_footprint_bytes(&cfg, &fc());
        Tenant { name, cfg, bytes, loads: Cell::new(0) }
    }

    fn acquire<'m>(&self, m: &'m mut WeightResidencyManager<ModelStack>) -> &'m ModelStack {
        m.acquire_with(self.name, self.bytes, None, || {
            self.loads.set(self.loads.get() + 1);
            Ok(load_stack(self.name, self.cfg, fc()))
        })
        .unwrap();
        m.get(self.name).unwrap()
    }
}

/// Per-sequence prompt: deterministic, distinct per `seed`.
fn prompt_input(cfg: &TnnConfig, f: &FabricConstants, seed: usize) -> Tensor {
    let mut t = Tensor::zeros(vec![f.sl_max, f.dmodel_max]);
    for r in 0..cfg.seq_len {
        for c in 0..cfg.d_model {
            t.data[r * f.dmodel_max + c] = ((r * 31 + c + seed * 101) as f32 * 0.0917).sin();
        }
    }
    t
}

/// One live generation: the feedback row, the sequence-private KV cache
/// (device memory *outside* the weight stack — it must survive the
/// stack's eviction), and the transcript of every step output.
struct Seq {
    row: Tensor,
    cache: KvCache<Tensor>,
    transcript: Vec<Vec<f32>>,
}

fn begin_seq(stack: &ModelStack, seed: usize) -> Seq {
    let backend = HashBackend;
    let pre = &stack.pre;
    let f = pre.fabric;
    let cfg = pre.cfg;
    let mut inputs = vec![prompt_input(&cfg, &f, seed)];
    for h in &pre.aux_hosts {
        let shape = pre.host_shapes[*h].clone();
        let n: usize = shape.iter().product();
        let data = (0..n).map(|j| ((j * 7 + 3) as f32 * 0.0713).sin()).collect();
        inputs.push(Tensor::new(shape, data));
    }
    let (out, exports) =
        schedule::replay_full(pre, &backend, &stack.pw, &stack.runtime, inputs, &[], None)
            .unwrap();
    let prompt_len = cfg.seq_len / 2;
    let cache = KvCache::from_prefill(&cfg, exports, prompt_len).unwrap();
    let row_start = (prompt_len - 1) * f.dmodel_max;
    let row = Tensor::new(
        vec![1, f.dmodel_max],
        out.data[row_start..row_start + f.dmodel_max].to_vec(),
    );
    Seq { row, cache, transcript: Vec::new() }
}

fn step_seq(stack: &ModelStack, seq: &mut Seq) {
    let backend = HashBackend;
    let step = stack.step.as_ref().expect("decoder stack");
    let sw = stack.sw.as_ref().expect("decoder stack");
    let f = step.fabric;
    let pos = seq.cache.len;
    let inputs = vec![
        seq.row.clone(),
        decode::step_mask_row(f.sl_max, pos),
        decode::position_tensor(pos),
    ];
    let ext = seq.cache.externs();
    let (out, exports) =
        schedule::replay_full(step, &backend, sw, &stack.runtime, inputs, &ext, None).unwrap();
    seq.cache.apply_step(exports).unwrap();
    seq.transcript.push(out.data.clone());
    seq.row = out;
}

/// One encode batch of an encoder-only tenant against its resident stack.
fn encode_once(stack: &ModelStack, seed: usize) -> Tensor {
    let backend = HashBackend;
    let input = prompt_input(&stack.pre.cfg, &stack.pre.fabric, seed);
    schedule::replay_with(&stack.pre, &backend, &stack.pw, &stack.runtime, input, None).unwrap()
}

fn policy(mode: ResidencyMode, capacity_bytes: u64) -> ResidencyPolicy {
    ResidencyPolicy { mode, capacity_bytes, ..ResidencyPolicy::default() }
}

/// (a) Evict-then-reload is bit-identical to never-evicted serving.
///
/// Under `ReprogramAlways` — the paper's host loop — a live generation's
/// stack is evicted on *every* peer batch and re-uploaded before its next
/// decode step (the `decode_round` re-acquire path).  The sequence's KV
/// cache lives outside the stack, so N rounds of evict/reload must
/// reproduce the undisturbed transcript exactly.
#[test]
fn evict_then_reload_is_bit_identical_to_never_evicted_serving() {
    const N: usize = 6;
    let gen = Tenant::new("gen", gpt());
    let enc = Tenant::new("enc", TnnConfig::encoder(32, 256, 4, 2));

    // Baseline: the generation served alone, stack never evicted.
    let baseline = {
        let stack = load_stack(gen.name, gen.cfg, fc());
        let mut s = begin_seq(&stack, 0);
        for _ in 0..N {
            step_seq(&stack, &mut s);
        }
        s.transcript
    };
    let enc_alone = {
        let stack = load_stack(enc.name, enc.cfg, fc());
        encode_once(&stack, 7)
    };

    // Churned: every round an encode batch of the peer model evicts the
    // generation's stack, which is re-uploaded for the decode step.
    let cap = gen.bytes + enc.bytes;
    let mut m = WeightResidencyManager::new(policy(ResidencyMode::ReprogramAlways, cap));
    let mut s = {
        let stack = gen.acquire(&mut m);
        begin_seq(stack, 0)
    };
    for round in 0..N {
        let e = encode_once(enc.acquire(&mut m), 7);
        assert!(e.data == enc_alone.data, "round {round}: churned encode batch diverged");
        assert!(!m.is_resident(gen.name), "reprogram-always must have evicted the generator");
        step_seq(gen.acquire(&mut m), &mut s);
    }
    assert!(s.transcript == baseline, "evict/reload changed the transcript");
    // The reloads really happened: initial upload + one per round.
    assert_eq!(gen.loads.get(), 1 + N as u64);
    let st = m.stats();
    assert_eq!(st.uploads, (1 + 2 * N) as u64);
    assert_eq!(st.evictions, 2 * N as u64);
    assert_eq!(st.hits, 0);
}

/// (b) A model with live generations is never evicted: its pin holds
/// through arbitrary peer churn, its stack uploads exactly once, and the
/// KV-cached transcript matches undisturbed serving.
#[test]
fn pinned_live_generation_survives_peer_churn() {
    const N: usize = 5;
    let gen = Tenant::new("gen", gpt());
    let peer_a = Tenant::new("peer-a", TnnConfig::encoder(32, 256, 4, 2));
    let peer_b = Tenant::new("peer-b", TnnConfig::encoder(32, 256, 4, 2));

    let baseline = {
        let stack = load_stack(gen.name, gen.cfg, fc());
        let mut s = begin_seq(&stack, 3);
        for _ in 0..N {
            step_seq(&stack, &mut s);
        }
        s.transcript
    };

    // Capacity holds the generator plus ONE peer: the peers must churn
    // against each other, never against the pinned generator.
    let cap = gen.bytes + peer_a.bytes.max(peer_b.bytes);
    let mut m = WeightResidencyManager::new(policy(ResidencyMode::Managed, cap));
    let mut s = {
        let stack = gen.acquire(&mut m);
        begin_seq(stack, 3)
    };
    m.set_pinned([gen.name]);
    for _ in 0..N {
        encode_once(peer_a.acquire(&mut m), 1);
        encode_once(peer_b.acquire(&mut m), 2);
        assert!(m.is_resident(gen.name), "pinned generator lost its stack to peer churn");
        step_seq(gen.acquire(&mut m), &mut s);
        m.set_pinned([gen.name]);
    }
    assert!(s.transcript == baseline, "peer churn perturbed the pinned generation");
    assert_eq!(gen.loads.get(), 1, "the pinned stack must upload exactly once");
    let st = m.stats();
    assert!(st.evictions >= (2 * N - 2) as u64, "the peers never churned: {st:?}");
    assert!(st.resident_bytes_peak <= cap, "pinning should not have forced over-budget");

    // The pin lapses with the last live sequence: a large incoming stack
    // may now evict the generator like any other tenant.
    m.set_pinned(std::iter::empty::<&str>());
    let big = Tenant::new("big", TnnConfig::encoder(32, 256, 4, 6));
    big.acquire(&mut m);
    assert!(!m.is_resident(gen.name), "unpinned generator must be evictable again");
}

/// (c) Two-model churn on one capacity-constrained fabric: the managed
/// cache does strictly fewer weight uploads than the reprogram-always
/// baseline, with bit-identical outputs.
#[test]
fn managed_churn_uploads_strictly_fewer_than_reprogram_always() {
    const ROUNDS: usize = 8;
    let run = |mode: ResidencyMode| {
        let a = Tenant::new("tenant-a", TnnConfig::encoder(32, 256, 4, 2));
        let b = Tenant::new("tenant-b", TnnConfig::encoder(32, 256, 4, 4));
        let mut m = WeightResidencyManager::new(policy(mode, a.bytes + b.bytes));
        let mut outputs: Vec<Vec<f32>> = Vec::new();
        for round in 0..ROUNDS {
            outputs.push(encode_once(a.acquire(&mut m), round).data);
            outputs.push(encode_once(b.acquire(&mut m), round).data);
        }
        (m.stats(), a.loads.get() + b.loads.get(), outputs)
    };

    let (managed, managed_loads, managed_out) = run(ResidencyMode::Managed);
    let (always, always_loads, always_out) = run(ResidencyMode::ReprogramAlways);

    assert!(managed_out == always_out, "residency caching changed the served numerics");
    assert!(
        managed.uploads < always.uploads,
        "managed ({}) must upload strictly less than reprogram-always ({})",
        managed.uploads,
        always.uploads
    );
    // Both stacks fit: the managed fabric uploads each exactly once and
    // serves every later switch from residency; the baseline re-uploads
    // on every one of the 2·ROUNDS dispatches.
    assert_eq!((managed.uploads, managed_loads), (2, 2));
    assert_eq!(managed.hits, (2 * ROUNDS - 2) as u64);
    assert_eq!(managed.evictions, 0);
    assert_eq!((always.uploads, always_loads), (2 * ROUNDS as u64, 2 * ROUNDS as u64));
    assert_eq!(always.evictions, (2 * ROUNDS - 1) as u64);
}
