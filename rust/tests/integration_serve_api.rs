//! Serving API v1 surface guard — runs WITHOUT artifacts, so CI can
//! never ship an accidental break of the public `adaptor::serve`
//! module.
//!
//! Two layers of protection:
//!
//! 1. **Signature snapshot** — every public entry point is assigned to
//!    an explicitly-typed `fn` pointer.  Changing a signature (or
//!    removing an item) fails compilation right here, which is the
//!    offline, no-network stand-in for `cargo semver-checks`.
//! 2. **Semantics snapshot** — error taxonomy `Display` strings, QoS
//!    defaults, priority ordering and the submit-side typed failures
//!    that need no fabric (config validation happens before any worker
//!    spawns).

#![allow(clippy::type_complexity)]

use std::time::Duration;

use adaptor::coordinator::metrics::Metrics;
use adaptor::coordinator::router::ModelSpec;
use adaptor::coordinator::{Server, ServerConfig};
use adaptor::model::presets;
use adaptor::model::weights::Mat;
use adaptor::serve::{
    CancelToken, EncodeOutput, GenerateOutput, JobHandle, JobOutput, OptLevel, Priority, QoS,
    ServeError, Submission, Timing, TokenEvent,
};

/// The compile-time API snapshot.  Every line pins one public
/// signature; a change here is a breaking change of Serving API v1 and
/// must be deliberate.
#[test]
fn public_api_snapshot() {
    // Server lifecycle
    let _start: fn(ServerConfig) -> Result<Server, ServeError> = Server::start;
    let _submit: fn(&Server, Submission, QoS) -> Result<JobHandle, ServeError> = Server::submit;
    let _metrics: fn(&Server) -> Metrics = Server::metrics;
    let _shutdown: fn(Server) -> Result<Metrics, ServeError> = Server::shutdown;

    // JobHandle
    let _wait: fn(JobHandle) -> Result<JobOutput, ServeError> = JobHandle::wait;
    let _poll: fn(&mut JobHandle) -> Option<&Result<JobOutput, ServeError>> = JobHandle::poll;
    let _next_token: fn(&mut JobHandle) -> Option<TokenEvent> = JobHandle::next_token;
    let _try_token: fn(&mut JobHandle) -> Option<TokenEvent> = JobHandle::try_token;
    let _cancel: fn(&JobHandle) = JobHandle::cancel;
    let _token: fn(&JobHandle) -> CancelToken = JobHandle::cancel_token;
    let _tok_cancel: fn(&CancelToken) = CancelToken::cancel;
    let _tok_query: fn(&CancelToken) -> bool = CancelToken::is_cancelled;

    // Outputs
    let _into_encode: fn(JobOutput) -> Result<EncodeOutput, ServeError> = JobOutput::into_encode;
    let _into_generate: fn(JobOutput) -> Result<GenerateOutput, ServeError> =
        JobOutput::into_generate;
    let _timing: fn(&JobOutput) -> Timing = JobOutput::timing;

    // QoS builders
    let _qos_high: fn() -> QoS = QoS::high;
    let _qos_low: fn() -> QoS = QoS::low;
    let _with_priority: fn(QoS, Priority) -> QoS = QoS::with_priority;
    let _with_deadline: fn(QoS, Duration) -> QoS = QoS::with_deadline;
    let _with_opt: fn(QoS, OptLevel) -> QoS = QoS::with_opt_level;

    // Submission accessors
    let _model: fn(&Submission) -> &str = Submission::model;

    // The typed taxonomy is exhaustive-matchable by downstream code:
    // adding a variant is intentional API evolution, caught here.
    let classify = |e: &ServeError| -> &'static str {
        match e {
            ServeError::UnknownModel(_) => "unknown-model",
            ServeError::InvalidRequest(_) => "invalid-request",
            ServeError::InvalidConfig(_) => "invalid-config",
            ServeError::AffinityOutOfRange { .. } => "affinity-out-of-range",
            ServeError::DeadlineExceeded { .. } => "deadline-exceeded",
            ServeError::Cancelled => "cancelled",
            ServeError::ProgramFailed(_) => "program-failed",
            ServeError::Engine(_) => "engine",
            ServeError::PoolLost(_) => "pool-lost",
        }
    };
    assert_eq!(classify(&ServeError::Cancelled), "cancelled");
}

#[test]
fn qos_defaults_and_priority_order_are_stable() {
    let q = QoS::default();
    assert_eq!(q.priority, Priority::Normal);
    assert_eq!(q.deadline, None);
    assert_eq!(q.opt_level, None);
    assert!(Priority::Low < Priority::Normal && Priority::Normal < Priority::High);
    assert_eq!(Priority::ALL, [Priority::Low, Priority::Normal, Priority::High]);
    assert_eq!(QoS::high().priority, Priority::High);
    let dl = QoS::default().with_deadline(Duration::from_millis(3));
    assert_eq!(dl.deadline, Some(Duration::from_millis(3)));
}

#[test]
fn serve_error_is_a_std_error_and_interops_with_anyhow() {
    // ServeError must stay a real std error so callers can `?` it into
    // anyhow (examples, main) without the coordinator depending on
    // anyhow at its boundary.
    fn takes_std_error(_: &(dyn std::error::Error + Send + Sync + 'static)) {}
    let e = ServeError::UnknownModel("m".into());
    takes_std_error(&e);
    let as_anyhow: anyhow::Error = e.into();
    assert!(as_anyhow.to_string().contains("unknown model 'm'"));
    // and the reverse direction flattens context chains into Engine
    let back: ServeError = anyhow::anyhow!("root cause").context("while replaying").into();
    assert_eq!(back, ServeError::Engine("while replaying: root cause".into()));
}

#[test]
fn config_failures_are_typed_without_any_fabric() {
    // These all fail before a worker (and thus the artifact set) is
    // touched, so this guard runs everywhere.
    let mut zero = ServerConfig::new(vec![]);
    zero.pool_size = 0;
    assert!(matches!(Server::start(zero), Err(ServeError::InvalidConfig(_))));

    let mut no_depth = ServerConfig::new(vec![]);
    no_depth.queue_depth = 0;
    assert!(matches!(Server::start(no_depth), Err(ServeError::InvalidConfig(_))));

    // a live set of zero could never admit a generation
    let mut no_seqs = ServerConfig::new(vec![]);
    no_seqs.max_seqs = 0;
    assert!(matches!(Server::start(no_seqs), Err(ServeError::InvalidConfig(_))));

    let pinned = ModelSpec::new("pinned", presets::small_encoder(32, 1), 1).with_affinity(5);
    let mut cfg = ServerConfig::new(vec![pinned]);
    cfg.pool_size = 2;
    match Server::start(cfg) {
        Err(ServeError::AffinityOutOfRange { model, fabric, pool_size }) => {
            assert_eq!((model.as_str(), fabric, pool_size), ("pinned", 5, 2));
        }
        Err(other) => panic!("expected AffinityOutOfRange, got {other:?}"),
        Ok(_) => panic!("expected AffinityOutOfRange, got a running server"),
    }

    let dup = vec![
        ModelSpec::new("m", presets::small_encoder(32, 1), 1),
        ModelSpec::new("m", presets::small_encoder(32, 1), 2),
    ];
    assert!(matches!(
        Server::start(ServerConfig::new(dup)),
        Err(ServeError::InvalidConfig(_))
    ));
}

#[test]
fn submission_carries_its_model_name() {
    let e = Submission::Encode { model: "enc".into(), input: Mat::zeros(1, 1) };
    let g = Submission::Generate {
        model: "gen".into(),
        prompt: Mat::zeros(1, 1),
        source: None,
        steps: 1,
    };
    assert_eq!(e.model(), "enc");
    assert_eq!(g.model(), "gen");
}

#[test]
fn error_messages_stay_operator_readable() {
    let msgs = [
        ServeError::UnknownModel("bert".into()).to_string(),
        ServeError::DeadlineExceeded { waited: Duration::from_millis(12) }.to_string(),
        ServeError::Cancelled.to_string(),
        ServeError::AffinityOutOfRange { model: "m".into(), fabric: 9, pool_size: 4 }.to_string(),
    ];
    assert_eq!(msgs[0], "unknown model 'bert'");
    assert!(msgs[1].starts_with("deadline exceeded"), "{}", msgs[1]);
    assert_eq!(msgs[2], "job cancelled");
    assert!(msgs[3].contains("fabric 9"), "{}", msgs[3]);
}
