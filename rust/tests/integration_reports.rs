//! Report-generation integration: `adaptor report all` must regenerate
//! every table/figure of the paper, write valid files, and the contents
//! must carry the paper's qualitative claims.

use adaptor::analysis::report;

fn tmpdir(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("adaptor-reports-{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

#[test]
fn write_all_emits_txt_and_csv_per_report() {
    let dir = tmpdir("all");
    let written = report::write_all(&dir).unwrap();
    assert_eq!(written.len(), 10);
    for name in &written {
        let txt = dir.join(format!("{name}.txt"));
        let csv = dir.join(format!("{name}.csv"));
        assert!(txt.exists(), "{name}.txt");
        assert!(csv.exists(), "{name}.csv");
        let csv_text = std::fs::read_to_string(&csv).unwrap();
        let mut lines = csv_text.lines();
        let header_cols = lines.next().unwrap().split(',').count();
        for l in lines {
            assert_eq!(l.split(',').count(), header_cols, "{name}.csv ragged row");
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn fig5_claims_interior_optimum() {
    let text = report::render("fig5").unwrap();
    assert!(text.contains("reproduced optimum"));
    assert!(text.contains("latency_norm"));
}

#[test]
fn fig10_includes_paper_ratio_claims() {
    let text = report::render("fig10").unwrap();
    assert!(text.contains("NVIDIA K80"));
    assert!(text.contains("i7-8700K"));
    assert!(text.contains("ratio-derived"), "derived points must be labeled");
    assert!(text.contains("ADAPTOR-RS (substrate)"));
}

#[test]
fn table2_reports_both_methods_per_config() {
    let text = report::render("table2").unwrap();
    let analytical = text.matches("analytical").count();
    let simulated = text.matches("simulated").count();
    assert!(analytical >= 4 && simulated >= 4);
}

#[test]
fn fig12_names_the_papers_bounds() {
    let text = report::render("fig12").unwrap();
    assert!(text.contains("compute bound"));
    assert!(text.contains("GOPS"));
    assert!(text.contains("ridge"));
}

#[test]
fn ablation_quantifies_resynthesis_cost() {
    let text = report::render("ablation").unwrap();
    assert!(text.contains("synthesis_hours"));
    assert!(text.contains("ADAPTOR (runtime registers)"));
    assert!(text.contains("per-model custom synthesis"));
}
