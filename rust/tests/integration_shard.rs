//! Cross-fabric shard-chain equivalence proof, artifact-free.  The
//! tentpole contract of pipeline sharding is that splitting a layer
//! stack into K contiguous shards and relaying the padded activation
//! over the inter-fabric link at each cut is **bit-identical** to
//! running the monolithic single-fabric program: every layer consumes
//! and produces the same `[SL_MAX, DMODEL_MAX]` activation, so a cut
//! between layers is exactly the inter-layer interface.
//!
//! These tests pin that with the same row-local, zero-preserving
//! pseudo-numeric backend as `integration_adaptive` (dead rows stay
//! exactly zero, attention is mask- and liveness-aware — the model of
//! the real fabric's zero-padded tiles).  The chain replays through
//! `coordinator::shard::replay_chain`, which resolves each shard's
//! 0-based weight references against the parent stack through
//! `OffsetWeights`; the monolith replays the dense program directly.
//! Outputs AND exported KV panels (a gpt prefill chain's cache seed)
//! must agree bit-for-bit across ≥3 topologies × K∈{2,3} × O0/O2, at
//! full length and at a partial live prefix.
//!
//! The same file carries the chain's static acceptance (every lowered
//! chain passes `verify_shard_chain` clean) and the cycle-model
//! acceptance: senders pay the link at `LINK_BYTES_PER_CYCLE`, receivers
//! ride free, and every stage prices below the monolith it replaces.

use adaptor::accel::schedule::{
    self, optimize, ArtifactInventory, FabricConstants, OptLevel, ScheduleBuilder, TileProgram,
    WeightKind, WeightRef, WeightSource,
};
use adaptor::accel::sim::cycle;
use adaptor::coordinator::shard::{self, ShardPlan};
use adaptor::model::reference::NEG_INF;
use adaptor::model::{presets, TnnConfig};
use adaptor::runtime::{FabricBackend, Tensor};

use std::collections::HashMap;

fn fc() -> FabricConstants {
    FabricConstants::artifact_default()
}

/// Scores at or below this are "fenced" — mirrors the mask's `NEG_INF`
/// with headroom for the bounded mix added on top.
const DEAD_FENCE: f32 = NEG_INF / 2.0;

fn dead(row: &[f32]) -> bool {
    row.iter().all(|v| *v == 0.0)
}

fn row(t: &Tensor, r: usize) -> &[f32] {
    let w = t.data.len() / t.shape[0];
    &t.data[r * w..(r + 1) * w]
}

/// Bounded deterministic stand-in for a q·k dot product.
fn mix(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for (c, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        acc += x * y * (((c % 7) + 1) as f32) * 0.0625;
    }
    (acc * 0.25).sin()
}

/// Pseudo-exp: zero past the fence (masked), bounded positive elsewhere,
/// and exactly `1.0` at a zero score — a dead key under an open mask
/// weights its all-zero value row by 1, contributing exactly `+0.0`.
fn pexp(x: f32) -> f32 {
    if x <= DEAD_FENCE {
        0.0
    } else {
        (0.5 * x).sin() * 0.5 + 1.0
    }
}

/// Row-local, zero-preserving pseudo-numeric backend (see module doc).
struct RowBackend;

impl RowBackend {
    fn qk(q: &Tensor, k: &Tensor, mask: &Tensor, scale: f32) -> Vec<f32> {
        let sl = mask.shape[0];
        let mut out = vec![0.0f32; sl * sl];
        for i in 0..sl {
            let qi = row(q, i);
            if dead(qi) {
                out[i * sl..(i + 1) * sl].fill(NEG_INF);
                continue;
            }
            for j in 0..sl {
                let kj = row(k, j);
                let s = if dead(kj) { 0.0 } else { mix(qi, kj) * scale };
                out[i * sl + j] = s + mask.data[i * sl + j];
            }
        }
        out
    }

    fn sv(p: &[f32], sl: usize, v: &Tensor) -> Vec<f32> {
        let dk = v.shape[1];
        let mut out = vec![0.0f32; sl * dk];
        for i in 0..sl {
            for c in 0..dk {
                let mut acc = 0.0f32;
                for j in 0..sl {
                    acc += p[i * sl + j] * v.data[j * dk + c];
                }
                out[i * dk + c] = (acc * 0.0625).sin();
            }
        }
        out
    }

    /// Generic row-local op: row `r` of the output mixes row `r` of every
    /// row-aligned input plus the global (weight/bias) inputs — gated on
    /// the first operand's row being live, which is the builder's
    /// activation-first convention.  Dead rows stay exactly zero.
    fn generic(artifact: &str, inputs: &[&Tensor], out_shape: &[usize]) -> Vec<f32> {
        let n = out_shape[0];
        let cols: usize = out_shape[1..].iter().product::<usize>().max(1);
        let h0 = artifact.bytes().fold(2166136261u32, |h, b| (h ^ b as u32).wrapping_mul(16777619));
        let mut data = vec![0.0f32; n * cols];
        for r in 0..n {
            let gate = inputs
                .first()
                .map(|t| t.shape.len() < 2 || t.shape[0] != n || !dead(row(t, r)))
                .unwrap_or(true);
            if !gate {
                continue;
            }
            let mut h = h0;
            for (k, t) in inputs.iter().enumerate() {
                let src: &[f32] =
                    if t.shape.len() == 2 && t.shape[0] == n { row(t, r) } else { &t.data };
                let len = src.len().max(1);
                let w = ((h % 13) + k as u32 + 1) as f32 * 0.0625;
                for c in 0..cols {
                    data[r * cols + c] += src[(c + 7 * k) % len] * w;
                }
                h = h.wrapping_mul(16777619) ^ (k as u32 + 1);
            }
            for c in 0..cols {
                data[r * cols + c] = (data[r * cols + c] * 0.25).sin();
            }
        }
        data
    }
}

impl FabricBackend for RowBackend {
    type Buf = Tensor;

    fn upload(&self, t: &Tensor) -> anyhow::Result<Tensor> {
        Ok(t.clone())
    }

    fn dispatch(
        &self,
        artifact: &str,
        inputs: &[&Tensor],
        out_shape: &[usize],
    ) -> anyhow::Result<Tensor> {
        let data = match artifact {
            "qk_scores" => {
                let (q, k, mask, scale) = (inputs[0], inputs[1], inputs[2], inputs[3]);
                Self::qk(q, k, mask, scale.data[0])
            }
            "softmax" => inputs[0].data.iter().map(|x| pexp(*x)).collect(),
            "sv" => Self::sv(&inputs[0].data, inputs[0].shape[0], inputs[1]),
            "attn_fused" => {
                let (q, k, v, mask, scale) =
                    (inputs[0], inputs[1], inputs[2], inputs[3], inputs[4]);
                let s = Self::qk(q, k, mask, scale.data[0]);
                let p: Vec<f32> = s.iter().map(|x| pexp(*x)).collect();
                Self::sv(&p, mask.shape[0], v)
            }
            _ => Self::generic(artifact, inputs, out_shape),
        };
        Ok(Tensor::new(out_shape.to_vec(), data))
    }

    fn fetch(&self, b: &Tensor) -> anyhow::Result<Tensor> {
        Ok(b.clone())
    }
}

/// Fabric-fixed panel shape per weight kind (same table as
/// `integration_adaptive` / `integration_scheduler`).
fn weight_shape(f: &FabricConstants, kind: WeightKind) -> Vec<usize> {
    match kind {
        WeightKind::Wq
        | WeightKind::Wk
        | WeightKind::Wv
        | WeightKind::CWq
        | WeightKind::CWk
        | WeightKind::CWv => vec![f.ts_mha, f.dk],
        WeightKind::QkvPacked => vec![f.ts_mha, 3 * f.dk],
        WeightKind::Bq
        | WeightKind::Bk
        | WeightKind::Bv
        | WeightKind::CBq
        | WeightKind::CBk
        | WeightKind::CBv => vec![f.dk],
        WeightKind::BQkvPacked => vec![3 * f.dk],
        WeightKind::Wo | WeightKind::CWo => vec![f.ts_ffn, f.ts_ffn],
        WeightKind::Bo
        | WeightKind::B2
        | WeightKind::G1
        | WeightKind::B1n
        | WeightKind::G2
        | WeightKind::B2n
        | WeightKind::CBo
        | WeightKind::CG
        | WeightKind::CBn => vec![f.dmodel_max],
        WeightKind::W1 => vec![f.ts_ffn, f.ffn_col],
        WeightKind::B1 => vec![f.hidden_max],
        WeightKind::W2 => vec![f.ffn_col, f.ts_ffn],
        WeightKind::DWq | WeightKind::DWk | WeightKind::DWv | WeightKind::DCWq => {
            vec![f.dmodel_max, f.dk]
        }
        WeightKind::DWo | WeightKind::DCWo => vec![f.dmodel_max, f.dmodel_max],
        WeightKind::DW1 => vec![f.dmodel_max, f.hidden_max],
        WeightKind::DW2 => vec![f.hidden_max, f.dmodel_max],
    }
}

/// Deterministic weight stand-ins keyed by **parent-absolute**
/// `WeightRef`.  Seeded from `(program, layer offset)` pairs — the dense
/// program at offset 0, each shard's program at its layer-range start —
/// so a shard's 0-based refs seed exactly the tensors the dense program
/// resolves for the same parent layer (the seed is ref-intrinsic).
struct RefWeights {
    map: HashMap<WeightRef, Tensor>,
}

impl RefWeights {
    fn for_offset_programs(progs: &[(&TileProgram, usize)], f: &FabricConstants) -> Self {
        let mut map = HashMap::new();
        for (prog, offset) in progs {
            for step in &prog.steps {
                let schedule::Step::Dispatch { args, .. } = step else { continue };
                for arg in args {
                    let schedule::Operand::Weight(r) = arg else { continue };
                    let r = WeightRef { layer: r.layer + offset, ..*r };
                    map.entry(r).or_insert_with(|| {
                        let shape = weight_shape(f, r.kind);
                        let seed = (r.layer * 7919 + r.row * 131 + r.col * 17) % 1000;
                        let n: usize = shape.iter().product();
                        let data =
                            (0..n).map(|i| ((seed + i) as f32 * 0.137).sin()).collect();
                        Tensor::new(shape, data)
                    });
                }
            }
        }
        RefWeights { map }
    }
}

impl WeightSource<Tensor> for RefWeights {
    fn weight(&self, r: &WeightRef) -> anyhow::Result<&Tensor> {
        self.map.get(r).ok_or_else(|| anyhow::anyhow!("unseeded weight ref {r:?}"))
    }
}

/// Padded input with deterministic nonzero content in the first `live`
/// rows and exact zeros everywhere else.
fn live_input(f: &FabricConstants, d_model: usize, live: usize) -> Tensor {
    let mut t = Tensor::zeros(vec![f.sl_max, f.dmodel_max]);
    for r in 0..live {
        for c in 0..d_model {
            t.data[r * f.dmodel_max + c] = ((r * 31 + c) as f32 * 0.0917).sin();
        }
    }
    t
}

/// Lower the monolithic single-fabric program for `cfg` — the oracle
/// every chain is measured against.
fn build_monolith(f: FabricConstants, cfg: TnnConfig, level: OptLevel) -> TileProgram {
    let inv = ArtifactInventory::assume_all();
    let b = ScheduleBuilder::new(f, cfg).unwrap();
    let mut p = if cfg.dec_layers > 0 { b.build_prefill() } else { b.build() };
    optimize(&mut p, level, &inv).unwrap();
    p
}

/// The proof for one topology × K × opt level: the chain verifies clean,
/// and for a full-length and a partial live prefix the chain's output
/// AND its concatenated exports (a gpt chain's KV panels) match the
/// monolith bit-for-bit — padding rows included.
fn assert_chain_equivalence(cfg: TnnConfig, k: usize, level: OptLevel) {
    let f = fc();
    let inv = ArtifactInventory::assume_all();
    let backend = RowBackend;

    let plan = ShardPlan::partition_k(&cfg, &f, k).unwrap();
    let chain = shard::lower_chain(&plan, &f, level, &inv).unwrap();
    let report = shard::verify_chain(&chain);
    assert!(
        report.is_clean(),
        "{cfg} {level:?} k={k}: chain contract failed: {:?}",
        report.errors().collect::<Vec<_>>()
    );

    let dense = build_monolith(f, cfg, level);
    let mut seeds: Vec<(&TileProgram, usize)> = vec![(&dense, 0)];
    for (p, s) in chain.iter().zip(&plan.shards) {
        seeds.push((p, s.offset()));
    }
    let weights = RefWeights::for_offset_programs(&seeds, &f);

    let mut rt = schedule::build_runtime(&backend, &cfg, &f).unwrap();
    schedule::upload_tier_masks(&backend, &mut rt, &cfg, &f, &dense.tier_mask_ids()).unwrap();
    for live in [cfg.seq_len, cfg.seq_len / 2 + 1] {
        let x = live_input(&f, cfg.d_model, live);
        let (want, want_ex) = schedule::replay_full_adaptive(
            &dense,
            &backend,
            &weights,
            &rt,
            vec![x.clone()],
            &[],
            None,
            live,
        )
        .unwrap();
        let (got, got_ex) =
            shard::replay_chain(&chain, &plan, &backend, &weights, x, live).unwrap();
        assert!(
            want.data == got.data,
            "{cfg} {level:?} k={k}: live={live} chain output diverged from the monolith"
        );
        assert_eq!(
            want_ex.len(),
            got_ex.len(),
            "{cfg} {level:?} k={k}: live={live} export count diverged"
        );
        for (i, (a, b)) in want_ex.iter().zip(&got_ex).enumerate() {
            assert!(
                a.data == b.data,
                "{cfg} {level:?} k={k}: live={live} KV export panel {i} diverged"
            );
        }
    }
}

/// ≥ 3 topologies: a 3-layer encoder (uneven 3-way split has a 1-layer
/// tail), a 4-layer encoder whose seq_len is not a power of two, and a
/// 4-layer gpt-style decoder stack (prefill chain with KV exports).
fn shard_sweep() -> Vec<TnnConfig> {
    vec![
        TnnConfig::encoder(64, 128, 2, 3),
        TnnConfig::encoder(48, 256, 4, 4),
        presets::gpt_small(64, 4),
    ]
}

#[test]
fn shard_chains_match_the_monolith_at_o0() {
    for cfg in shard_sweep() {
        for k in [2, 3] {
            assert_chain_equivalence(cfg, k, OptLevel::O0);
        }
    }
}

#[test]
fn shard_chains_match_the_monolith_at_o2() {
    for cfg in shard_sweep() {
        for k in [2, 3] {
            assert_chain_equivalence(cfg, k, OptLevel::O2);
        }
    }
}

/// Envelope-driven plans run through the same replay path: a synthetic
/// envelope holding ~1.5 layers forces a one-layer-per-shard chain (the
/// deepest pipeline the partitioner ever emits) and it must still match
/// the monolith exactly.
#[test]
fn envelope_forced_max_depth_chain_matches_the_monolith() {
    let f = fc();
    let cfg = TnnConfig::encoder(64, 128, 2, 3);
    let per_layer = adaptor::coordinator::residency::weight_footprint_bytes(&cfg, &f)
        / cfg.enc_layers as u64;
    let plan = ShardPlan::partition_for_envelope(&cfg, &f, per_layer + per_layer / 2).unwrap();
    assert_eq!(plan.shards.len(), cfg.enc_layers, "forced one layer per shard");
    assert_chain_equivalence(cfg, plan.shards.len(), OptLevel::O1);
}

/// The cycle model's link economics: every sender pays its boundary at
/// `LINK_BYTES_PER_CYCLE`, the tail (receive-only) pays nothing, and
/// each stage's *compute* (cycles net of the link) prices strictly
/// below the monolith it replaces — the per-stage latency win that
/// pipelining converts into throughput once requests overlap.
#[test]
fn chain_stages_price_the_link_at_senders_and_undercut_the_monolith() {
    let f = fc();
    let inv = ArtifactInventory::assume_all();
    let cfg = TnnConfig::encoder(64, 128, 2, 3);
    let plan = ShardPlan::partition_k(&cfg, &f, 3).unwrap();
    let chain = shard::lower_chain(&plan, &f, OptLevel::O1, &inv).unwrap();
    let dense = build_monolith(f, cfg, OptLevel::O1);
    let d = cycle::replay_program(&dense).unwrap();

    let reports: Vec<cycle::CycleReport> =
        chain.iter().map(|p| cycle::replay_program(p).unwrap()).collect();
    // head and middle each send one full padded activation; the
    // sender pays the wire time in whole
    for r in &reports[..2] {
        assert_eq!(r.activation_hops, 1);
        assert_eq!(r.link_bytes, (f.sl_max * f.dmodel_max * 4) as u64);
        assert_eq!(r.link_cycles, r.link_bytes.div_ceil(cycle::LINK_BYTES_PER_CYCLE));
    }
    assert_eq!(reports[2].activation_hops, 0, "a recv is free at the receiver");
    assert_eq!(reports[2].link_bytes, 0);
    for (i, r) in reports.iter().enumerate() {
        let compute = r.total_cycles - r.link_cycles;
        assert!(
            compute < d.total_cycles,
            "stage {i} computes {compute} cycles, not under the monolith's {}",
            d.total_cycles
        );
    }
    // the monolith itself never touches the link
    assert_eq!(d.activation_hops, 0);
    assert_eq!(d.link_bytes, 0);
}
