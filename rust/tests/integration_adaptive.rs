//! Length-adaptive equivalence proof, artifact-free.  The tentpole
//! contract of seq-bucketed specialization + skippable dispatch is that
//! serving a request of `live` rows through the covering bucket's
//! skippable program is **indistinguishable on the live rows** from
//! padding it into the dense max-length program.  These tests pin that
//! bit-for-bit with a *row-local, zero-preserving* pseudo-numeric
//! backend (a sharper construction than `integration_opt`'s row-global
//! hash backend, which cannot isolate live rows):
//!
//! - every non-attention dispatch maps row `r` of its activation input
//!   to row `r` of its output, and an all-zero (dead) row stays exactly
//!   zero — no bias leaks into padding;
//! - attention is mask- and liveness-aware: dead query rows score
//!   `NEG_INF` everywhere (their probability rows collapse to zero), and
//!   dead key rows carry zero values, so they contribute exactly `+0.0`
//!   to every live row whether the mask fences them (bucketed program)
//!   or not (dense program).
//!
//! Under those semantics — which model the real fabric's zero-padded
//! tiles — the dense replay and the bucketed/skipping replay agree
//! bit-for-bit on rows `[0, live)` across the topology × bucket sweep at
//! O0, O1 and O2, for encoders and decoder prefills (causal tiers are
//! exact for any live prefix; cross-attention is never tiered).  The
//! same file carries the artifact-free cycle acceptance: a request at
//! ≤ ¼ `seq_len` must price strictly below the dense maximum.

use adaptor::accel::schedule::{
    self, optimize, ArtifactInventory, FabricConstants, OptLevel, ScheduleBuilder, TileProgram,
    WeightKind, WeightRef, WeightSource,
};
use adaptor::accel::sim::cycle;
use adaptor::model::reference::NEG_INF;
use adaptor::model::{presets, TnnConfig};
use adaptor::runtime::{FabricBackend, Tensor};

use std::collections::HashMap;

fn fc() -> FabricConstants {
    FabricConstants::artifact_default()
}

/// Scores at or below this are "fenced" — mirrors the mask's `NEG_INF`
/// with headroom for the bounded mix added on top.
const DEAD_FENCE: f32 = NEG_INF / 2.0;

fn dead(row: &[f32]) -> bool {
    row.iter().all(|v| *v == 0.0)
}

fn row(t: &Tensor, r: usize) -> &[f32] {
    let w = t.data.len() / t.shape[0];
    &t.data[r * w..(r + 1) * w]
}

/// Bounded deterministic stand-in for a q·k dot product.
fn mix(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for (c, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        acc += x * y * (((c % 7) + 1) as f32) * 0.0625;
    }
    (acc * 0.25).sin()
}

/// Pseudo-exp: zero past the fence (masked), bounded positive elsewhere,
/// and exactly `1.0` at a zero score — so a dead key under an open mask
/// (dense program) weights its all-zero value row by 1, contributing the
/// same `+0.0` as the fenced bucketed program's weight of 0.
fn pexp(x: f32) -> f32 {
    if x <= DEAD_FENCE {
        0.0
    } else {
        (0.5 * x).sin() * 0.5 + 1.0
    }
}

/// Row-local, zero-preserving pseudo-numeric backend (see module doc).
struct RowBackend;

impl RowBackend {
    fn qk(q: &Tensor, k: &Tensor, mask: &Tensor, scale: f32) -> Vec<f32> {
        let sl = mask.shape[0];
        let mut out = vec![0.0f32; sl * sl];
        for i in 0..sl {
            let qi = row(q, i);
            if dead(qi) {
                out[i * sl..(i + 1) * sl].fill(NEG_INF);
                continue;
            }
            for j in 0..sl {
                let kj = row(k, j);
                let s = if dead(kj) { 0.0 } else { mix(qi, kj) * scale };
                out[i * sl + j] = s + mask.data[i * sl + j];
            }
        }
        out
    }

    fn sv(p: &[f32], sl: usize, v: &Tensor) -> Vec<f32> {
        let dk = v.shape[1];
        let mut out = vec![0.0f32; sl * dk];
        for i in 0..sl {
            for c in 0..dk {
                let mut acc = 0.0f32;
                for j in 0..sl {
                    acc += p[i * sl + j] * v.data[j * dk + c];
                }
                out[i * dk + c] = (acc * 0.0625).sin();
            }
        }
        out
    }

    /// Generic row-local op: row `r` of the output mixes row `r` of every
    /// row-aligned input plus the global (weight/bias) inputs — gated on
    /// the first operand's row being live, which is the builder's
    /// activation-first convention.  Dead rows stay exactly zero.
    fn generic(artifact: &str, inputs: &[&Tensor], out_shape: &[usize]) -> Vec<f32> {
        let n = out_shape[0];
        let cols: usize = out_shape[1..].iter().product::<usize>().max(1);
        let h0 = artifact.bytes().fold(2166136261u32, |h, b| (h ^ b as u32).wrapping_mul(16777619));
        let mut data = vec![0.0f32; n * cols];
        for r in 0..n {
            let gate = inputs
                .first()
                .map(|t| t.shape.len() < 2 || t.shape[0] != n || !dead(row(t, r)))
                .unwrap_or(true);
            if !gate {
                continue;
            }
            let mut h = h0;
            for (k, t) in inputs.iter().enumerate() {
                let src: &[f32] =
                    if t.shape.len() == 2 && t.shape[0] == n { row(t, r) } else { &t.data };
                let len = src.len().max(1);
                let w = ((h % 13) + k as u32 + 1) as f32 * 0.0625;
                for c in 0..cols {
                    data[r * cols + c] += src[(c + 7 * k) % len] * w;
                }
                h = h.wrapping_mul(16777619) ^ (k as u32 + 1);
            }
            for c in 0..cols {
                data[r * cols + c] = (data[r * cols + c] * 0.25).sin();
            }
        }
        data
    }
}

impl FabricBackend for RowBackend {
    type Buf = Tensor;

    fn upload(&self, t: &Tensor) -> anyhow::Result<Tensor> {
        Ok(t.clone())
    }

    fn dispatch(
        &self,
        artifact: &str,
        inputs: &[&Tensor],
        out_shape: &[usize],
    ) -> anyhow::Result<Tensor> {
        let data = match artifact {
            "qk_scores" => {
                let (q, k, mask, scale) = (inputs[0], inputs[1], inputs[2], inputs[3]);
                Self::qk(q, k, mask, scale.data[0])
            }
            "softmax" => inputs[0].data.iter().map(|x| pexp(*x)).collect(),
            "sv" => Self::sv(&inputs[0].data, inputs[0].shape[0], inputs[1]),
            "attn_fused" => {
                let (q, k, v, mask, scale) =
                    (inputs[0], inputs[1], inputs[2], inputs[3], inputs[4]);
                let s = Self::qk(q, k, mask, scale.data[0]);
                let p: Vec<f32> = s.iter().map(|x| pexp(*x)).collect();
                Self::sv(&p, mask.shape[0], v)
            }
            _ => Self::generic(artifact, inputs, out_shape),
        };
        Ok(Tensor::new(out_shape.to_vec(), data))
    }

    fn fetch(&self, b: &Tensor) -> anyhow::Result<Tensor> {
        Ok(b.clone())
    }
}

/// Fabric-fixed panel shape per weight kind (same table as
/// `integration_opt` / `integration_scheduler`).
fn weight_shape(f: &FabricConstants, kind: WeightKind) -> Vec<usize> {
    match kind {
        WeightKind::Wq
        | WeightKind::Wk
        | WeightKind::Wv
        | WeightKind::CWq
        | WeightKind::CWk
        | WeightKind::CWv => vec![f.ts_mha, f.dk],
        WeightKind::QkvPacked => vec![f.ts_mha, 3 * f.dk],
        WeightKind::Bq
        | WeightKind::Bk
        | WeightKind::Bv
        | WeightKind::CBq
        | WeightKind::CBk
        | WeightKind::CBv => vec![f.dk],
        WeightKind::BQkvPacked => vec![3 * f.dk],
        WeightKind::Wo | WeightKind::CWo => vec![f.ts_ffn, f.ts_ffn],
        WeightKind::Bo
        | WeightKind::B2
        | WeightKind::G1
        | WeightKind::B1n
        | WeightKind::G2
        | WeightKind::B2n
        | WeightKind::CBo
        | WeightKind::CG
        | WeightKind::CBn => vec![f.dmodel_max],
        WeightKind::W1 => vec![f.ts_ffn, f.ffn_col],
        WeightKind::B1 => vec![f.hidden_max],
        WeightKind::W2 => vec![f.ffn_col, f.ts_ffn],
        WeightKind::DWq | WeightKind::DWk | WeightKind::DWv | WeightKind::DCWq => {
            vec![f.dmodel_max, f.dk]
        }
        WeightKind::DWo | WeightKind::DCWo => vec![f.dmodel_max, f.dmodel_max],
        WeightKind::DW1 => vec![f.dmodel_max, f.hidden_max],
        WeightKind::DW2 => vec![f.hidden_max, f.dmodel_max],
    }
}

/// Deterministic weight stand-ins keyed by `WeightRef`, seeded from
/// every program in `progs` — the dense and bucketed programs of one
/// topology share refs, so they resolve identical tensors.
struct RefWeights {
    map: HashMap<WeightRef, Tensor>,
}

impl RefWeights {
    fn for_programs(progs: &[&TileProgram], f: &FabricConstants) -> Self {
        let mut map = HashMap::new();
        for prog in progs {
            for step in &prog.steps {
                let schedule::Step::Dispatch { args, .. } = step else { continue };
                for arg in args {
                    let schedule::Operand::Weight(r) = arg else { continue };
                    map.entry(*r).or_insert_with(|| {
                        let shape = weight_shape(f, r.kind);
                        let seed = (r.layer * 7919 + r.row * 131 + r.col * 17) % 1000;
                        let n: usize = shape.iter().product();
                        let data =
                            (0..n).map(|i| ((seed + i) as f32 * 0.137).sin()).collect();
                        Tensor::new(shape, data)
                    });
                }
            }
        }
        RefWeights { map }
    }
}

impl WeightSource<Tensor> for RefWeights {
    fn weight(&self, r: &WeightRef) -> anyhow::Result<&Tensor> {
        self.map.get(r).ok_or_else(|| anyhow::anyhow!("unseeded weight ref {r:?}"))
    }
}

/// Padded input with deterministic nonzero content in the first `live`
/// rows and exact zeros everywhere else.
fn live_input(f: &FabricConstants, d_model: usize, live: usize) -> Tensor {
    let mut t = Tensor::zeros(vec![f.sl_max, f.dmodel_max]);
    for r in 0..live {
        for c in 0..d_model {
            t.data[r * f.dmodel_max + c] = ((r * 31 + c) as f32 * 0.0917).sin();
        }
    }
    t
}

/// The live row counts worth probing for `seq_len`: every tier boundary
/// plus one interior point per tier (first row the tier covers).
fn live_sweep(seq_len: usize) -> Vec<usize> {
    let mut out = Vec::new();
    let mut lo = 0usize;
    for t in schedule::length_tiers(seq_len) {
        out.push(lo + 1);
        if t != lo + 1 {
            out.push(t);
        }
        lo = t;
    }
    out
}

fn build_encoder(f: FabricConstants, cfg: TnnConfig, skippable: bool, level: OptLevel) -> TileProgram {
    let inv = ArtifactInventory::assume_all();
    let mut p = ScheduleBuilder::new(f, cfg).unwrap().skippable(skippable).build();
    optimize(&mut p, level, &inv).unwrap();
    p
}

fn build_prefill(f: FabricConstants, cfg: TnnConfig, skippable: bool, level: OptLevel) -> TileProgram {
    let inv = ArtifactInventory::assume_all();
    let mut p = ScheduleBuilder::new(f, cfg).unwrap().skippable(skippable).build_prefill();
    optimize(&mut p, level, &inv).unwrap();
    p
}

/// The proof for one encoder topology at one opt level: for every live
/// row count, the covering bucket's skippable program must match the
/// dense max-length program bit-for-bit on rows `[0, live)` — and leave
/// its padding rows exactly zero.
fn assert_encoder_equivalence(cfg: TnnConfig, level: OptLevel) {
    let f = fc();
    let backend = RowBackend;
    let dense = build_encoder(f, cfg, false, level);
    let runtime_dense = schedule::build_runtime(&backend, &cfg, &f).unwrap();
    for live in live_sweep(cfg.seq_len) {
        let bucket = schedule::covering_bucket(live, cfg.seq_len);
        let cfg_b = TnnConfig { seq_len: bucket, ..cfg };
        let adaptive = build_encoder(f, cfg_b, true, level);
        let weights = RefWeights::for_programs(&[&dense, &adaptive], &f);
        let x = live_input(&f, cfg.d_model, live);
        let a =
            schedule::replay_with(&dense, &backend, &weights, &runtime_dense, x.clone(), None)
                .unwrap();
        let mut rt = schedule::build_runtime(&backend, &cfg_b, &f).unwrap();
        schedule::upload_tier_masks(&backend, &mut rt, &cfg_b, &f, &adaptive.tier_mask_ids())
            .unwrap();
        let b = schedule::replay_with_live(&adaptive, &backend, &weights, &rt, x, None, live)
            .unwrap();
        let n = live * f.dmodel_max;
        assert!(
            a.data[..n] == b.data[..n],
            "{cfg} {level:?}: live={live} bucket={bucket} diverged on live rows"
        );
        assert!(
            b.data[n..].iter().all(|v| *v == 0.0),
            "{cfg} {level:?}: live={live} bucket={bucket} leaked into padding rows"
        );
    }
}

/// The decoder-prefill proof: causal tiers are exact for any live
/// prefix, and the exported K/V panels (the KV-cache seed) must agree in
/// full — dead rows are zero on both sides.
fn assert_prefill_equivalence(cfg: TnnConfig, level: OptLevel, lives: &[usize]) {
    let f = fc();
    let backend = RowBackend;
    let dense = build_prefill(f, cfg, false, level);
    let runtime_dense = schedule::build_runtime(&backend, &cfg, &f).unwrap();
    for &live in lives {
        // seq2seq prefills keep the full-length bucket (the cross-attn
        // memory fence must stay at seq_len); decoder-only prompts drop
        // into their covering bucket — exactly the engine's policy.
        let bucket = if cfg.enc_layers == 0 {
            schedule::covering_bucket(live, cfg.seq_len)
        } else {
            cfg.seq_len
        };
        let cfg_b = TnnConfig { seq_len: bucket, ..cfg };
        let adaptive = build_prefill(f, cfg_b, true, level);
        let weights = RefWeights::for_programs(&[&dense, &adaptive], &f);

        let mut inputs = vec![live_input(&f, cfg.d_model, live)];
        for _ in 0..dense.aux_hosts.len() {
            // the encoder memory of a seq2seq prefill is full-length
            inputs.push(live_input(&f, cfg.d_model, cfg.seq_len));
        }
        let (a, ax) = schedule::replay_full(
            &dense,
            &backend,
            &weights,
            &runtime_dense,
            inputs.clone(),
            &[],
            None,
        )
        .unwrap();
        let mut rt = schedule::build_runtime(&backend, &cfg_b, &f).unwrap();
        schedule::upload_tier_masks(&backend, &mut rt, &cfg_b, &f, &adaptive.tier_mask_ids())
            .unwrap();
        let (b, bx) = schedule::replay_full_adaptive(
            &adaptive, &backend, &weights, &rt, inputs, &[], None, live,
        )
        .unwrap();
        let n = live * f.dmodel_max;
        assert!(
            a.data[..n] == b.data[..n],
            "{cfg} {level:?}: prefill live={live} bucket={bucket} diverged on live rows"
        );
        assert_eq!(ax.len(), bx.len(), "{cfg} {level:?}: export count diverged");
        for (i, (pa, pb)) in ax.iter().zip(&bx).enumerate() {
            assert!(
                pa.data == pb.data,
                "{cfg} {level:?}: prefill live={live} KV export panel {i} diverged"
            );
        }
    }
}

/// ≥ 3 encoder topologies: full tier ladder, a two-tier mid-size, and a
/// topology whose seq_len is not a power of two (uneven top tier).
fn encoder_sweep() -> Vec<TnnConfig> {
    vec![
        TnnConfig::encoder(128, 256, 4, 2),
        TnnConfig::encoder(64, 128, 2, 1),
        TnnConfig::encoder(48, 256, 4, 1),
    ]
}

#[test]
fn bucketed_encoders_match_dense_on_live_rows_at_o0() {
    for cfg in encoder_sweep() {
        assert_encoder_equivalence(cfg, OptLevel::O0);
    }
}

#[test]
fn bucketed_encoders_match_dense_on_live_rows_at_o1() {
    for cfg in encoder_sweep() {
        assert_encoder_equivalence(cfg, OptLevel::O1);
    }
}

#[test]
fn bucketed_encoders_match_dense_on_live_rows_at_o2() {
    for cfg in encoder_sweep() {
        assert_encoder_equivalence(cfg, OptLevel::O2);
    }
}

#[test]
fn bucketed_prefills_match_dense_on_live_rows_at_o0() {
    assert_prefill_equivalence(presets::gpt_small(64, 2), OptLevel::O0, &[4, 16, 33, 64]);
    assert_prefill_equivalence(presets::seq2seq_small(64, 1, 1), OptLevel::O0, &[4, 32]);
}

#[test]
fn bucketed_prefills_match_dense_on_live_rows_at_o1() {
    assert_prefill_equivalence(presets::gpt_small(64, 2), OptLevel::O1, &[4, 16, 33, 64]);
    assert_prefill_equivalence(presets::seq2seq_small(64, 1, 1), OptLevel::O1, &[4, 32]);
}

#[test]
fn bucketed_prefills_match_dense_on_live_rows_at_o2() {
    assert_prefill_equivalence(presets::gpt_small(64, 2), OptLevel::O2, &[4, 16, 33, 64]);
    assert_prefill_equivalence(presets::seq2seq_small(64, 1, 1), OptLevel::O2, &[4, 32]);
}

/// The ISSUE's cycle acceptance, artifact-free: a request at ≤ ¼ of the
/// topology's seq_len must price strictly below the dense maximum, at
/// every opt level.
#[test]
fn quarter_length_requests_price_strictly_below_dense() {
    let f = fc();
    for cfg in [
        TnnConfig::encoder(128, 256, 4, 2),
        TnnConfig::encoder(64, 128, 2, 1),
        TnnConfig::encoder(64, 512, 8, 4),
    ] {
        for level in [OptLevel::O0, OptLevel::O1, OptLevel::O2] {
            let dense = build_encoder(f, cfg, false, level);
            let d = cycle::replay_program(&dense).unwrap();
            let a = cycle::estimate_adaptive(&cfg, &f, cfg.seq_len / 4, level).unwrap();
            assert!(
                a.total_cycles < d.total_cycles,
                "{cfg} {level:?}: quarter-length {} !< dense {}",
                a.total_cycles,
                d.total_cycles
            );
        }
    }
}

/// Bucket dispatch of the whole ladder: the adaptive estimate is
/// monotone in request length and lands exactly on the dense estimate at
/// the top bucket.
#[test]
fn adaptive_estimates_are_monotone_and_close_the_ladder() {
    let f = fc();
    let cfg = TnnConfig::encoder(128, 256, 4, 2);
    let mut prev = 0u64;
    for rows in schedule::length_tiers(cfg.seq_len) {
        let rep = cycle::estimate_adaptive(&cfg, &f, rows, OptLevel::O1).unwrap();
        assert!(
            rep.total_cycles >= prev,
            "bucket {rows}: cycles {} regressed below {prev}",
            rep.total_cycles
        );
        prev = rep.total_cycles;
    }
    let dense = build_encoder(f, cfg, false, OptLevel::O1);
    let d = cycle::replay_program(&dense).unwrap();
    let top = cycle::estimate_adaptive(&cfg, &f, cfg.seq_len, OptLevel::O1).unwrap();
    assert_eq!(top.dispatches, d.dispatches, "top bucket must fire the dense stream");
    assert!(
        (top.total_cycles as i64 - d.total_cycles as i64).unsigned_abs() <= 2,
        "top bucket {} vs dense {} drifted past rounding",
        top.total_cycles,
        d.total_cycles
    );
}
