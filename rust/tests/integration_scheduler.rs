//! Sequence-scheduler invariants, artifact-free.  The continuous-batching
//! worker in `coordinator::server` interleaves decode steps of many live
//! sequences (and whole encode batches) on one fabric; its correctness
//! contract is that interleaving is *invisible* to each sequence — the
//! streamed transcript must be bit-identical to draining that sequence
//! alone, cancellation must not perturb survivors, and the shared scratch
//! pool must keep recycling.  These tests pin that contract at the replay
//! level with a pseudo-numeric backend (same construction as
//! `integration_opt.rs`): each sequence owns a `KvCache<Tensor>` fed by
//! the real prefill/decode-step programs, and a scheduler round is "one
//! step per live sequence" exactly as `decode_round` runs it.  The
//! PJRT/engine counterparts are gated on artifacts in
//! `integration_decode.rs` and the server tests.

use std::collections::HashMap;

use adaptor::accel::decode::{self, KvCache};
use adaptor::accel::schedule::{
    self, optimize, ArtifactInventory, FabricConstants, OptLevel, ScheduleBuilder,
    TileProgram, WeightKind, WeightRef, WeightSource,
};
use adaptor::model::TnnConfig;
use adaptor::runtime::{FabricBackend, Tensor, TensorPool};

fn fc() -> FabricConstants {
    FabricConstants::artifact_default()
}

/// Decoder-only topology with room for a prompt plus several decode
/// steps under `sl_max`.
fn gpt() -> TnnConfig {
    TnnConfig { seq_len: 32, heads: 4, d_model: 256, hidden: 1024, enc_layers: 0, dec_layers: 2 }
}

fn fnv(s: &str) -> u32 {
    s.bytes().fold(2166136261u32, |h, b| (h ^ b as u32).wrapping_mul(16777619))
}

/// Pseudo-numeric backend: buffers are host tensors, dispatch output is a
/// bounded deterministic mix of `(artifact, inputs)`.  Any cross-sequence
/// contamination — a stale pooled buffer, a cache panel from the wrong
/// sequence — changes some output bit-for-bit.
struct HashBackend;

impl FabricBackend for HashBackend {
    type Buf = Tensor;

    fn upload(&self, t: &Tensor) -> anyhow::Result<Tensor> {
        Ok(t.clone())
    }

    fn dispatch(
        &self,
        artifact: &str,
        inputs: &[&Tensor],
        out_shape: &[usize],
    ) -> anyhow::Result<Tensor> {
        let n: usize = out_shape.iter().product();
        let mut data = vec![0.0f32; n];
        let mut h = fnv(artifact);
        for (k, t) in inputs.iter().enumerate() {
            let len = t.data.len().max(1);
            let w = ((h % 13) + k as u32 + 1) as f32 * 0.0625;
            for (j, v) in data.iter_mut().enumerate() {
                *v += t.data[(j + 7 * k) % len] * w;
            }
            h = h.wrapping_mul(16777619) ^ (k as u32 + 1);
        }
        for v in data.iter_mut() {
            *v = (*v * 0.25).sin();
        }
        Ok(Tensor::new(out_shape.to_vec(), data))
    }

    fn fetch(&self, b: &Tensor) -> anyhow::Result<Tensor> {
        Ok(b.clone())
    }
}

/// Fabric-fixed panel shape per weight kind (mirrors `integration_opt`).
fn weight_shape(f: &FabricConstants, kind: WeightKind) -> Vec<usize> {
    match kind {
        WeightKind::Wq
        | WeightKind::Wk
        | WeightKind::Wv
        | WeightKind::CWq
        | WeightKind::CWk
        | WeightKind::CWv => vec![f.ts_mha, f.dk],
        WeightKind::QkvPacked => vec![f.ts_mha, 3 * f.dk],
        WeightKind::Bq
        | WeightKind::Bk
        | WeightKind::Bv
        | WeightKind::CBq
        | WeightKind::CBk
        | WeightKind::CBv => vec![f.dk],
        WeightKind::BQkvPacked => vec![3 * f.dk],
        WeightKind::Wo | WeightKind::CWo => vec![f.ts_ffn, f.ts_ffn],
        WeightKind::Bo
        | WeightKind::B2
        | WeightKind::G1
        | WeightKind::B1n
        | WeightKind::G2
        | WeightKind::B2n
        | WeightKind::CBo
        | WeightKind::CG
        | WeightKind::CBn => vec![f.dmodel_max],
        WeightKind::W1 => vec![f.ts_ffn, f.ffn_col],
        WeightKind::B1 => vec![f.hidden_max],
        WeightKind::W2 => vec![f.ffn_col, f.ts_ffn],
        WeightKind::DWq | WeightKind::DWk | WeightKind::DWv | WeightKind::DCWq => {
            vec![f.dmodel_max, f.dk]
        }
        WeightKind::DWo | WeightKind::DCWo => vec![f.dmodel_max, f.dmodel_max],
        WeightKind::DW1 => vec![f.dmodel_max, f.hidden_max],
        WeightKind::DW2 => vec![f.hidden_max, f.dmodel_max],
    }
}

/// Deterministic weight stand-ins keyed by `WeightRef` — the same ref
/// seeds the same tensor in every map, so prefill and step programs of
/// one model agree on shared weights.
struct HashWeights {
    map: HashMap<WeightRef, Tensor>,
}

impl HashWeights {
    fn for_program(prog: &TileProgram, f: &FabricConstants) -> Self {
        let mut map = HashMap::new();
        for step in &prog.steps {
            let schedule::Step::Dispatch { args, .. } = step else { continue };
            for arg in args {
                let schedule::Operand::Weight(r) = arg else { continue };
                map.entry(*r).or_insert_with(|| {
                    let shape = weight_shape(f, r.kind);
                    let seed =
                        fnv(&format!("{:?}/{}/{}/{}", r.kind, r.layer, r.row, r.col)) % 1000;
                    let n: usize = shape.iter().product();
                    let data =
                        (0..n).map(|i| ((seed as usize + i) as f32 * 0.137).sin()).collect();
                    Tensor::new(shape, data)
                });
            }
        }
        HashWeights { map }
    }
}

impl WeightSource<Tensor> for HashWeights {
    fn weight(&self, r: &WeightRef) -> anyhow::Result<&Tensor> {
        self.map.get(r).ok_or_else(|| anyhow::anyhow!("unseeded weight ref {r:?}"))
    }
}

/// Per-sequence prompt: deterministic, distinct per `seed` so any
/// cross-sequence leak shows up as a transcript mismatch.
fn prompt_input(cfg: &TnnConfig, f: &FabricConstants, seed: usize) -> Tensor {
    let mut t = Tensor::zeros(vec![f.sl_max, f.dmodel_max]);
    for r in 0..cfg.seq_len {
        for c in 0..cfg.d_model {
            t.data[r * f.dmodel_max + c] = ((r * 31 + c + seed * 101) as f32 * 0.0917).sin();
        }
    }
    t
}

/// One live generation as the scheduler holds it: the feedback row, the
/// sequence-private KV cache, and the transcript of every step output.
struct Seq {
    row: Tensor,
    cache: KvCache<Tensor>,
    transcript: Vec<Vec<f32>>,
}

/// Admission: run the prefill program, seed the KV cache from its
/// exports, and extract the last prompt row as the first step's input.
fn begin_seq(
    pre: &TileProgram,
    weights: &HashWeights,
    runtime: &schedule::RuntimeBufs<Tensor>,
    seed: usize,
    pool: Option<&TensorPool>,
) -> Seq {
    let backend = HashBackend;
    let f = pre.fabric;
    let cfg = pre.cfg;
    let mut inputs = vec![prompt_input(&cfg, &f, seed)];
    for h in &pre.aux_hosts {
        let shape = pre.host_shapes[*h].clone();
        let n: usize = shape.iter().product();
        let data = (0..n).map(|j| ((j * 7 + 3) as f32 * 0.0713).sin()).collect();
        inputs.push(Tensor::new(shape, data));
    }
    let (out, exports) =
        schedule::replay_full(pre, &backend, weights, runtime, inputs, &[], pool).unwrap();
    let prompt_len = cfg.seq_len / 2;
    let cache = KvCache::from_prefill(&cfg, exports, prompt_len).unwrap();
    let row_start = (prompt_len - 1) * f.dmodel_max;
    let row = Tensor::new(
        vec![1, f.dmodel_max],
        out.data[row_start..row_start + f.dmodel_max].to_vec(),
    );
    Seq { row, cache, transcript: Vec::new() }
}

/// One decode step of one sequence: replay the step program against the
/// sequence's cache, append the exported K/V row, feed the output row
/// back — exactly the engine's `step_once` dataflow.
fn step_seq(
    step: &TileProgram,
    weights: &HashWeights,
    runtime: &schedule::RuntimeBufs<Tensor>,
    seq: &mut Seq,
    pool: Option<&TensorPool>,
) {
    let backend = HashBackend;
    let f = step.fabric;
    let pos = seq.cache.len;
    let inputs = vec![
        seq.row.clone(),
        decode::step_mask_row(f.sl_max, pos),
        decode::position_tensor(pos),
    ];
    let ext = seq.cache.externs();
    let (out, exports) =
        schedule::replay_full(step, &backend, weights, runtime, inputs, &ext, pool).unwrap();
    seq.cache.apply_step(exports).unwrap();
    seq.transcript.push(out.data.clone());
    seq.row = out;
}

/// Build the O1-optimized prefill + step programs the serving path caches.
fn programs(f: FabricConstants, cfg: TnnConfig) -> (TileProgram, TileProgram) {
    let inv = ArtifactInventory::assume_all();
    let mut pre = ScheduleBuilder::new(f, cfg).unwrap().build_prefill();
    optimize(&mut pre, OptLevel::O1, &inv).unwrap();
    let mut step = ScheduleBuilder::new(f, cfg).unwrap().build_step();
    optimize(&mut step, OptLevel::O1, &inv).unwrap();
    (pre, step)
}

/// Baseline: each sequence admitted and drained to completion alone
/// (the pre-continuous-batching, one-job-at-a-time transcript).
fn sequential_transcripts(
    pre: &TileProgram,
    step: &TileProgram,
    pw: &HashWeights,
    sw: &HashWeights,
    runtime: &schedule::RuntimeBufs<Tensor>,
    k: usize,
    n: usize,
) -> Vec<Vec<Vec<f32>>> {
    (0..k)
        .map(|seed| {
            let mut s = begin_seq(pre, pw, runtime, seed, None);
            for _ in 0..n {
                step_seq(step, sw, runtime, &mut s, None);
            }
            s.transcript
        })
        .collect()
}

#[test]
fn interleaved_decode_rounds_are_bit_identical_to_sequential_serving() {
    const K: usize = 3;
    const N: usize = 6;
    let f = fc();
    let cfg = gpt();
    let (pre, step) = programs(f, cfg);
    let backend = HashBackend;
    let runtime = schedule::build_runtime(&backend, &cfg, &f).unwrap();
    let pw = HashWeights::for_program(&pre, &f);
    let sw = HashWeights::for_program(&step, &f);

    let sequential = sequential_transcripts(&pre, &step, &pw, &sw, &runtime, K, N);

    // Continuous batching: admit all K, then N scheduler rounds of one
    // step per live sequence, all sharing one scratch pool.
    let pool = TensorPool::new();
    let mut live: Vec<Seq> =
        (0..K).map(|seed| begin_seq(&pre, &pw, &runtime, seed, Some(&pool))).collect();
    for _ in 0..N {
        for s in live.iter_mut() {
            step_seq(&step, &sw, &runtime, s, Some(&pool));
        }
    }

    for (k, s) in live.iter().enumerate() {
        assert_eq!(s.transcript.len(), N, "sequence {k}");
        assert!(
            s.transcript == sequential[k],
            "sequence {k}: interleaving changed the transcript"
        );
    }
}

#[test]
fn encode_batches_interleave_without_perturbing_generations() {
    const K: usize = 2;
    const N: usize = 5;
    let f = fc();
    let cfg = gpt();
    let (pre, step) = programs(f, cfg);
    let backend = HashBackend;
    let dec_rt = schedule::build_runtime(&backend, &cfg, &f).unwrap();
    let pw = HashWeights::for_program(&pre, &f);
    let sw = HashWeights::for_program(&step, &f);

    // A second, encoder-only model sharing the fabric (the mixed
    // Encode+Generate case the dispatcher produces).
    let enc_cfg = TnnConfig::encoder(32, 256, 4, 2);
    let mut enc = ScheduleBuilder::new(f, enc_cfg).unwrap().build();
    optimize(&mut enc, OptLevel::O1, &ArtifactInventory::assume_all()).unwrap();
    let ew = HashWeights::for_program(&enc, &f);
    let enc_rt = schedule::build_runtime(&backend, &enc_cfg, &f).unwrap();
    let enc_in = prompt_input(&enc_cfg, &f, 7);
    let enc_alone =
        schedule::replay_with(&enc, &backend, &ew, &enc_rt, enc_in.clone(), None).unwrap();

    let sequential = sequential_transcripts(&pre, &step, &pw, &sw, &dec_rt, K, N);

    // Interleave: every scheduler round serves one encode batch between
    // decode steps, all on one pool.
    let pool = TensorPool::new();
    let mut live: Vec<Seq> =
        (0..K).map(|seed| begin_seq(&pre, &pw, &dec_rt, seed, Some(&pool))).collect();
    for round in 0..N {
        let e = schedule::replay_with(&enc, &backend, &ew, &enc_rt, enc_in.clone(), Some(&pool))
            .unwrap();
        assert!(
            e.data == enc_alone.data,
            "round {round}: live generations perturbed the encode batch"
        );
        for s in live.iter_mut() {
            step_seq(&step, &sw, &dec_rt, s, Some(&pool));
        }
    }
    for (k, s) in live.iter().enumerate() {
        assert!(
            s.transcript == sequential[k],
            "sequence {k}: encode batches perturbed the generation"
        );
    }
}

#[test]
fn cancelling_one_sequence_leaves_survivors_bit_identical_and_scratch_recycled() {
    const K: usize = 3;
    const N: usize = 8;
    const CANCEL_AT: usize = 3; // rounds the doomed sequence survives
    let f = fc();
    let cfg = gpt();
    let (pre, step) = programs(f, cfg);
    let backend = HashBackend;
    let runtime = schedule::build_runtime(&backend, &cfg, &f).unwrap();
    let pw = HashWeights::for_program(&pre, &f);
    let sw = HashWeights::for_program(&step, &f);

    let sequential = sequential_transcripts(&pre, &step, &pw, &sw, &runtime, K, N);

    let pool = TensorPool::new();
    let mut live: Vec<(usize, Seq)> =
        (0..K).map(|seed| (seed, begin_seq(&pre, &pw, &runtime, seed, Some(&pool)))).collect();
    let mut cancelled_prefix = None;
    let mut warm_misses = 0;
    for round in 0..N {
        if round == CANCEL_AT {
            // Mid-flight cancellation: the scheduler drops the LiveSeq,
            // which frees the sequence's KV cache immediately.
            let (_, doomed) = live.remove(1);
            cancelled_prefix = Some(doomed.transcript);
            let (_, misses) = pool.stats();
            warm_misses = misses;
        }
        for (_, s) in live.iter_mut() {
            step_seq(&step, &sw, &runtime, s, Some(&pool));
        }
    }

    // Survivors never saw the cancellation.
    for (seed, s) in &live {
        assert_eq!(s.transcript.len(), N, "sequence {seed}");
        assert!(
            s.transcript == sequential[*seed],
            "sequence {seed}: cancelling a peer changed the transcript"
        );
    }
    // The cancelled sequence's partial transcript matches its own prefix.
    let prefix = cancelled_prefix.unwrap();
    assert_eq!(prefix.len(), CANCEL_AT);
    assert!(prefix == sequential[1][..CANCEL_AT], "cancelled prefix diverged before the drop");
    // Scratch keeps recycling after the drop: warm steady state allocates
    // nothing new, and post-cancel rounds run on recycled buffers.
    let (hits, misses) = pool.stats();
    assert_eq!(misses, warm_misses, "cancellation leaked pool scratch");
    assert!(hits > 0, "post-cancel rounds must recycle scratch");
}
