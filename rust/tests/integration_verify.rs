//! Integration: the static verifier over the public schedule surface.
//!
//! Two halves:
//!
//! * a **sweep** — every preset topology executable on the default fabric
//!   × applicable `ProgramKind` × opt level must verify clean (the same
//!   matrix `adaptor verify-programs` and the CI job run);
//! * a **mutation corpus** — deliberate IR corruptions applied through
//!   the public program surface, each of which the verifier must reject
//!   with a diagnostic naming the offending step and rule.
//!
//! Artifact-free on purpose: the inventory is `assume_all()`, so the
//! manifest-signature rules (arity/shape vs the AOT interface) stay
//! quiet and everything here runs in CI without `make artifacts`.

use adaptor::accel::schedule::{
    optimize, verify, ArtifactInventory, FabricConstants, OptLevel, Operand, ProgramKind, Rule,
    ScheduleBuilder, Step, TileProgram,
};
use adaptor::model::presets;

fn fc() -> FabricConstants {
    FabricConstants::artifact_default()
}

fn inv() -> ArtifactInventory {
    ArtifactInventory::assume_all()
}

const LEVELS: [OptLevel; 3] = [OptLevel::O0, OptLevel::O1, OptLevel::O2];

#[test]
fn every_executable_preset_program_verifies_clean_at_all_levels() {
    let mut verified = 0usize;
    for (name, cfg) in presets::all() {
        if fc().check(&cfg).is_err() {
            continue; // analytical-only topologies (e.g. d_model % heads != 0)
        }
        let mut kinds = Vec::new();
        if cfg.enc_layers > 0 {
            kinds.push(ProgramKind::Encoder);
        }
        if cfg.dec_layers > 0 {
            kinds.extend([ProgramKind::Prefill, ProgramKind::DecodeStep]);
        }
        for kind in kinds {
            for level in LEVELS {
                let builder = ScheduleBuilder::new(fc(), cfg).unwrap();
                let mut p = match kind {
                    ProgramKind::Encoder => builder.build(),
                    ProgramKind::Prefill => builder.build_prefill(),
                    ProgramKind::DecodeStep => builder.build_step(),
                };
                optimize(&mut p, level, &inv()).unwrap();
                let report = verify::verify(&p, kind, &inv());
                assert!(
                    report.is_clean(),
                    "{name} {kind:?} {level:?}: {:?}",
                    report.errors().collect::<Vec<_>>()
                );
                verified += 1;
            }
        }
    }
    // 8 executable presets; decoder topologies contribute 2–3 kinds each.
    assert!(verified >= 30, "sweep shrank to {verified} programs");
}

#[test]
fn quantized_encoder_verifies_clean_at_all_levels() {
    for level in LEVELS {
        let mut p = ScheduleBuilder::new(fc(), presets::small_encoder(32, 2))
            .unwrap()
            .quantized(true)
            .build();
        optimize(&mut p, level, &inv()).unwrap();
        let report = verify::verify(&p, ProgramKind::Encoder, &inv());
        assert!(report.is_clean(), "{level:?}: {:?}", report.errors().collect::<Vec<_>>());
    }
}

// ---- the mutation corpus -------------------------------------------------

fn encoder(level: OptLevel) -> TileProgram {
    let mut p = ScheduleBuilder::new(fc(), presets::small_encoder(32, 2)).unwrap().build();
    optimize(&mut p, level, &inv()).unwrap();
    p
}

fn step_program() -> TileProgram {
    ScheduleBuilder::new(fc(), presets::gpt_small(32, 2)).unwrap().build_step()
}

/// Swapped slot operand: the first dispatch reads a slot only defined by
/// the *last* dispatch — dataflow must flag the forward reference.
#[test]
fn swapped_slot_operand_is_use_before_def() {
    let mut p = encoder(OptLevel::O0);
    let last_dst = p
        .steps
        .iter()
        .rev()
        .find_map(|s| match s {
            Step::Dispatch { dst, .. } => Some(*dst),
            _ => None,
        })
        .unwrap();
    let first_arg = p
        .steps
        .iter_mut()
        .find_map(|s| match s {
            Step::Dispatch { args, .. } => args.iter_mut().find_map(|a| match a {
                Operand::Slot(s) => Some(s),
                _ => None,
            }),
            _ => None,
        })
        .unwrap();
    assert_ne!(*first_arg, last_dst);
    *first_arg = last_dst;
    let report = verify::verify(&p, ProgramKind::Encoder, &inv());
    assert!(
        report.errors().any(|d| d.rule == Rule::UseBeforeDef && d.step.is_some()),
        "{:?}",
        report.diagnostics
    );
}

/// Dropped upload: the input transfer disappears, so every consumer of
/// its slot reads an undefined value.
#[test]
fn dropped_upload_is_use_before_def() {
    let mut p = encoder(OptLevel::O0);
    let i = p.steps.iter().position(|s| matches!(s, Step::Upload { .. })).unwrap();
    p.steps.remove(i);
    let report = verify::verify(&p, ProgramKind::Encoder, &inv());
    assert!(
        report.errors().any(|d| d.rule == Rule::UseBeforeDef && d.step.is_some()),
        "{:?}",
        report.diagnostics
    );
}

/// Wrong out_shape: a dispatch whose result is fetched records a bogus
/// output shape — the fetch target no longer matches its host.
#[test]
fn wrong_out_shape_is_a_shape_mismatch() {
    let mut p = encoder(OptLevel::O0);
    let fetched = p
        .steps
        .iter()
        .find_map(|s| match s {
            Step::Fetch { src, .. } => Some(*src),
            _ => None,
        })
        .unwrap();
    let corrupted = p.steps.iter_mut().any(|s| match s {
        Step::Dispatch { dst, out_shape, .. } if *dst == fetched => {
            *out_shape = vec![3, 3];
            true
        }
        _ => false,
    });
    assert!(corrupted, "no dispatch feeds the first fetch?");
    let report = verify::verify(&p, ProgramKind::Encoder, &inv());
    assert!(
        report.errors().any(|d| d.rule == Rule::ShapeMismatch && d.step.is_some()),
        "{:?}",
        report.diagnostics
    );
}

/// Stale export: the decode-step export table points at a slot no step
/// ever writes — replay would hand the cache a freed buffer.
#[test]
fn stale_export_slot_is_an_export_contract_violation() {
    let mut p = step_program();
    p.n_slots += 1;
    p.export_slots[0] = p.n_slots - 1;
    let report = verify::verify(&p, ProgramKind::DecodeStep, &inv());
    assert!(report.has_error(Rule::ExportContract), "{:?}", report.diagnostics);
}

/// An encoder program must not carry KV-cache plumbing.
#[test]
fn encoder_with_extern_buffers_is_rejected() {
    let mut p = encoder(OptLevel::O1);
    p.extern_shapes.push(vec![128, 64]);
    let report = verify::verify(&p, ProgramKind::Encoder, &inv());
    assert!(report.has_error(Rule::ExternContract), "{:?}", report.diagnostics);
}

/// The typed error renders every error diagnostic with step and rule.
#[test]
fn verify_program_returns_a_typed_rendered_error() {
    let mut p = encoder(OptLevel::O0);
    let i = p.steps.iter().position(|s| matches!(s, Step::Upload { .. })).unwrap();
    p.steps.remove(i);
    let err = verify::verify_program(&p, ProgramKind::Encoder, &inv()).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("program verification failed"), "{msg}");
    assert!(msg.contains("use-before-def"), "{msg}");
    assert!(msg.contains("step "), "{msg}");
}
