//! Property-based tests over the coordinator/substrate invariants.
//!
//! Offline build: no proptest — a seeded SplitMix64 case generator with
//! shrink-free random sweeps (100+ cases per property, deterministic seeds
//! so failures reproduce exactly).

use adaptor::accel::registers::{Reg, RegisterFile, SynthMaxima};
use adaptor::accel::tiling::{ffn_schedule, mha_schedule, TileConfig};
use adaptor::accel::{latency, resources, sim};
use adaptor::coordinator::batcher::{BatchPolicy, Batcher};
use adaptor::model::quant;
use adaptor::model::weights::Mat;
use adaptor::model::{ops, TnnConfig};
use adaptor::util::json;
use adaptor::util::rng::SplitMix64;
use std::time::{Duration, Instant};

const CASES: u64 = 120;

/// Random legal TnnConfig drawn from the fabric envelope.
fn arb_config(rng: &mut SplitMix64) -> TnnConfig {
    let heads = [1usize, 2, 4, 6, 8, 12][rng.below(6) as usize];
    let d_model = heads * 64;
    let seq_len = [8usize, 16, 32, 64, 100, 128][rng.below(6) as usize];
    let layers = 1 + rng.below(12) as usize;
    TnnConfig::encoder(seq_len, d_model, heads, layers)
}

fn arb_tiles(rng: &mut SplitMix64, d: usize) -> TileConfig {
    let divs: Vec<usize> = (1..=d).filter(|t| d % t == 0 && d / t >= 8 && d / t <= 384).collect();
    let tm = divs[rng.below(divs.len() as u64) as usize];
    let tf = divs[rng.below(divs.len() as u64) as usize];
    TileConfig::new(d / tm, d / tf)
}

#[test]
fn prop_latency_monotone_in_layers_and_positive() {
    let mut rng = SplitMix64::new(0xA11CE);
    for _ in 0..CASES {
        let cfg = arb_config(&mut rng);
        let tiles = arb_tiles(&mut rng, cfg.d_model);
        let lat = latency::model_latency(&cfg, &tiles);
        assert!(lat.total_cycles > 0);
        let more = TnnConfig { enc_layers: cfg.enc_layers + 1, ..cfg };
        let lat2 = latency::model_latency(&more, &tiles);
        assert!(lat2.total_cycles > lat.total_cycles, "{cfg} {tiles:?}");
    }
}

#[test]
fn prop_latency_monotone_in_seq_len() {
    let mut rng = SplitMix64::new(0xB0B);
    for _ in 0..CASES {
        let cfg = arb_config(&mut rng);
        if cfg.seq_len >= 128 {
            continue;
        }
        let tiles = arb_tiles(&mut rng, cfg.d_model);
        let longer = TnnConfig { seq_len: cfg.seq_len * 2, ..cfg };
        assert!(
            latency::model_latency(&longer, &tiles).total_cycles
                > latency::model_latency(&cfg, &tiles).total_cycles
        );
    }
}

#[test]
fn prop_sim_and_analytical_agree_within_8pct() {
    let mut rng = SplitMix64::new(0xC0FFEE);
    for _ in 0..40 {
        let cfg = arb_config(&mut rng);
        let tiles = arb_tiles(&mut rng, cfg.d_model);
        let a = latency::model_latency(&cfg, &tiles).total_cycles as f64;
        let s = sim::simulate(&cfg, &tiles).total_cycles as f64;
        let err = (a - s).abs() / a;
        assert!(err < 0.08, "{cfg} {tiles:?}: ana={a} sim={s} err={err:.4}");
    }
}

#[test]
fn prop_resources_monotone_in_tile_size() {
    // bigger tiles => at least as many DSPs (more parallel lanes)
    let mut rng = SplitMix64::new(0xD5f);
    for _ in 0..CASES {
        let cfg = arb_config(&mut rng);
        let d = cfg.d_model;
        let small = TileConfig::new(d.div_ceil(8), d.div_ceil(4));
        let big = TileConfig::new(d.div_ceil(2), d);
        assert!(
            resources::dsps_structural(&cfg, &big) >= resources::dsps_structural(&cfg, &small)
        );
    }
}

#[test]
fn prop_ops_scale_linearly_in_layers() {
    let mut rng = SplitMix64::new(0xE66);
    for _ in 0..CASES {
        let cfg = arb_config(&mut rng);
        let one = TnnConfig { enc_layers: 1, ..cfg };
        assert_eq!(ops::total_ops(&one) * cfg.enc_layers as u64, ops::total_ops(&cfg));
    }
}

#[test]
fn prop_mha_schedule_covers_each_tile_once() {
    let mut rng = SplitMix64::new(0xF00D);
    for _ in 0..CASES {
        let cfg = arb_config(&mut rng);
        let tiles = arb_tiles(&mut rng, cfg.d_model);
        let sched = mha_schedule(&tiles, cfg.d_model);
        let mut seen = vec![false; tiles.tiles_mha(cfg.d_model)];
        for v in &sched {
            assert!(!seen[v.row], "tile visited twice");
            seen[v.row] = true;
        }
        assert!(seen.iter().all(|&s| s), "tile never visited");
    }
}

#[test]
fn prop_ffn_schedule_is_exact_cover() {
    let mut rng = SplitMix64::new(0x5EED);
    for _ in 0..CASES {
        let rp = 1 + rng.below(8) as usize;
        let cp = 1 + rng.below(8) as usize;
        let sched = ffn_schedule(rp, cp);
        assert_eq!(sched.len(), rp * cp);
        let mut seen = vec![false; rp * cp];
        for v in &sched {
            let idx = v.col * rp + v.row;
            assert!(!seen[idx]);
            seen[idx] = true;
        }
        // Fig 4b order: within a column panel, rows (reduction) are inner
        for w in sched.windows(2) {
            if w[0].col == w[1].col {
                assert_eq!(w[1].row, w[0].row + 1);
            }
        }
    }
}

#[test]
fn prop_tile_accumulation_equals_full_matmul() {
    // the core Fig-4a invariant on the HOST side (mirrors the pallas test)
    let mut rng = SplitMix64::new(0xAB);
    for case in 0..30 {
        let d = 64 * (1 + rng.below(6) as usize);
        let ts = [16, 32, 64][rng.below(3) as usize];
        if d % ts != 0 {
            continue;
        }
        let rows = 8 + rng.below(24) as usize;
        let cols = 32;
        let mut data_rng = SplitMix64::new(1000 + case);
        let x = Mat::from_fn(rows, d, |_, _| data_rng.normal() as f32 * 0.5);
        let w = Mat::from_fn(d, cols, |_, _| data_rng.normal() as f32 * 0.5);
        let full = adaptor::model::reference::matmul(&x, &w);
        let mut acc = Mat::zeros(rows, cols);
        for t in 0..d / ts {
            let xp = x.block(0, t * ts, rows, ts);
            let wp = w.block(t * ts, 0, ts, cols);
            let partial = adaptor::model::reference::matmul(&xp, &wp);
            for (a, p) in acc.data.iter_mut().zip(&partial.data) {
                *a += p;
            }
        }
        assert!(acc.max_abs_diff(&full) < 1e-3);
    }
}

#[test]
fn prop_register_file_never_mutates_maxima_and_roundtrips() {
    let mut rng = SplitMix64::new(0x9e9e);
    for _ in 0..CASES {
        let mut rf = RegisterFile::new(SynthMaxima::artifact_default());
        let m0 = rf.maxima();
        for _ in 0..20 {
            let cfg = arb_config(&mut rng);
            if cfg.seq_len <= 128 && cfg.d_model <= 768 && cfg.hidden <= 3072 && cfg.heads <= 12 {
                rf.program(&cfg).unwrap();
                assert_eq!(rf.current_config(), cfg);
            } else {
                // at least one register write must fail; state may be
                // partially updated but maxima never move
                let _ = rf.program(&cfg);
            }
            let m = rf.maxima();
            assert_eq!(
                (m.seq_len, m.heads, m.d_model, m.hidden),
                (m0.seq_len, m0.heads, m0.d_model, m0.hidden)
            );
        }
    }
}

#[test]
fn prop_register_writes_out_of_range_rejected() {
    let mut rng = SplitMix64::new(0x77);
    let mut rf = RegisterFile::new(SynthMaxima::artifact_default());
    for _ in 0..CASES {
        let v = 129 + rng.below(10_000) as u32;
        assert!(rf.write(Reg::Sequence, v).is_err());
        assert!(rf.write(Reg::Embeddings, 769 + v).is_err());
    }
}

#[test]
fn prop_batcher_conserves_requests() {
    let mut rng = SplitMix64::new(0x8a8a);
    for _ in 0..CASES {
        let max_batch = 1 + rng.below(6) as usize;
        let mut b: Batcher<u64> =
            Batcher::new(BatchPolicy { max_batch, max_wait: Duration::from_secs(3600) });
        let n = rng.below(40);
        let models = ["a", "b", "c"];
        let mut pushed = Vec::new();
        for i in 0..n {
            let m = models[rng.below(3) as usize];
            b.push(m, i);
            pushed.push(i);
        }
        let mut popped = Vec::new();
        let now = Instant::now();
        while let Some((model, batch)) = b.pop_ready(now, true) {
            assert!(batch.len() <= max_batch);
            assert!(batch.iter().all(|p| p.model == model));
            popped.extend(batch.into_iter().map(|p| p.payload));
        }
        popped.sort();
        assert_eq!(popped, pushed, "requests lost or duplicated");
    }
}

#[test]
fn prop_quantize_roundtrip_bounds() {
    let mut rng = SplitMix64::new(0x1111);
    for _ in 0..CASES {
        let n = 16 + rng.below(512) as usize;
        let mut xs: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 3.0).collect();
        let s = quant::calibrate_scale(&xs);
        let orig = xs.clone();
        quant::quantize_dequantize(&mut xs, s);
        for (q, x) in xs.iter().zip(&orig) {
            assert!((q - x).abs() <= quant::max_inrange_error(s) + 1e-6);
            assert!(((q / s).round() - q / s).abs() < 1e-3);
        }
    }
}

#[test]
fn prop_json_parses_generated_documents() {
    let mut rng = SplitMix64::new(0x2222);
    for _ in 0..CASES {
        // generate a random nested doc and its serialization
        let n = 1 + rng.below(6) as usize;
        let mut body = Vec::new();
        for i in 0..n {
            let v = match rng.below(4) {
                0 => format!("{}", rng.below(1000)),
                1 => format!("{:.3}", rng.uniform(-5.0, 5.0)),
                2 => format!("\"s{}\"", rng.below(100)),
                _ => format!("[{}, {}]", rng.below(10), rng.below(10)),
            };
            body.push(format!("\"k{i}\": {v}"));
        }
        let doc = format!("{{{}}}", body.join(", "));
        let parsed = json::parse(&doc).unwrap_or_else(|e| panic!("{doc}: {e}"));
        assert_eq!(parsed.as_obj().unwrap().len(), n);
    }
}

#[test]
fn prop_mat_pad_preserves_content() {
    let mut rng = SplitMix64::new(0x3333);
    for _ in 0..CASES {
        let r = 1 + rng.below(20) as usize;
        let c = 1 + rng.below(20) as usize;
        let m = Mat::from_fn(r, c, |i, j| (i * 31 + j) as f32);
        let p = m.padded(r + rng.below(10) as usize, c + rng.below(10) as usize);
        assert_eq!(p.block(0, 0, r, c), m);
        // padding region is exactly zero
        let s: f32 = p.data.iter().sum();
        let s0: f32 = m.data.iter().sum();
        assert_eq!(s, s0);
    }
}

#[test]
fn prop_covering_bucket_is_smallest_covering_tier() {
    use adaptor::accel::schedule::{covering_bucket, length_tiers};
    let mut rng = SplitMix64::new(0x5EB0);
    for _ in 0..CASES {
        let seq_len = [8usize, 16, 24, 32, 48, 64, 100, 128][rng.below(8) as usize];
        let tiers = length_tiers(seq_len);
        // the ladder itself is sane: strictly increasing, topped by seq_len
        assert!(tiers.windows(2).all(|w| w[0] < w[1]), "{tiers:?}");
        assert_eq!(*tiers.last().unwrap(), seq_len);
        let rows = 1 + rng.below(seq_len as u64) as usize;
        let b = covering_bucket(rows, seq_len);
        assert!(tiers.contains(&b), "bucket {b} not a tier of {tiers:?}");
        assert!(b >= rows, "bucket {b} does not cover {rows}");
        // smallest: no tier below b also covers rows
        assert!(
            tiers.iter().all(|t| *t >= b || *t < rows),
            "rows={rows} seq_len={seq_len}: {b} is not the smallest covering tier of {tiers:?}"
        );
    }
}

#[test]
fn prop_live_dispatch_count_monotone_in_live_rows() {
    use adaptor::accel::schedule::{
        length_tiers, optimize, ArtifactInventory, FabricConstants, OptLevel, ScheduleBuilder,
    };
    // A longer request can never fire fewer dispatches: tier predicates
    // partition (0, seq_len] with per-tier chains of identical length, and
    // everything else is unpredicated.  Swept over random topologies and
    // opt levels rather than proved from the builder's structure.
    let mut rng = SplitMix64::new(0xD15C);
    let fc = FabricConstants::artifact_default();
    let inv = ArtifactInventory::assume_all();
    for _ in 0..24 {
        let heads = [2usize, 4, 6][rng.below(3) as usize];
        let seq_len = [16usize, 32, 48, 64, 128][rng.below(5) as usize];
        let layers = 1 + rng.below(3) as usize;
        let cfg = TnnConfig::encoder(seq_len, heads * 64, heads, layers);
        let level = [OptLevel::O0, OptLevel::O1, OptLevel::O2][rng.below(3) as usize];
        let mut prog = ScheduleBuilder::new(fc, cfg).unwrap().skippable(true).build();
        optimize(&mut prog, level, &inv).unwrap();
        let mut prev = 0usize;
        for live in 1..=seq_len {
            let n = prog.live_dispatch_count(live);
            assert!(
                n >= prev,
                "{cfg} {level:?}: live={live} fires {n} dispatches, fewer than {prev}"
            );
            prev = n;
        }
        // and the full-length replay fires the whole dense stream: the
        // static count minus the skipped lower tiers of each chain
        let tiers = length_tiers(seq_len).len();
        assert!(tiers >= 1);
        assert!(prog.live_dispatch_count(seq_len) <= prog.dispatch_count());
    }
}
