//! `adaptor` — the launcher (the paper's "host software", Algorithm 18).
//!
//! Subcommands:
//!   report <name|all> [--out DIR]      regenerate paper tables/figures
//!   simulate --model NAME [...]        analytical + cycle-sim latency
//!   serve --model NAME [--requests N]  threaded serving demo on PJRT
//!   sweep tiles|heads                  design-space sweeps (Fig 5/8)
//!   presets                            list model presets
//!   validate                           Table-2 style validation rows
//!   verify-programs                    static-verify preset programs
//!
//! Arg parsing is in-tree (offline build — no clap; see util/).

use adaptor::accel::{frequency, latency, power, resources, sim, tiling::TileConfig};
use adaptor::accel::platform;
use adaptor::analysis::report;
use adaptor::coordinator::router::ModelSpec;
use adaptor::coordinator::{OptLevel, ResidencyMode, Server, ServerConfig};
use adaptor::model::{presets, quant::BitWidth, weights};
use adaptor::serve::{Priority, QoS, Submission};

fn flag_value(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1).cloned())
}

fn usage() -> ! {
    eprintln!(
        "usage: adaptor <command>\n\
         \n  gantt --model <preset>\
         \n  report <fig5|fig8|fig9|fig10|fig11|fig12|fig13|table1|table2|ablation|all> [--out DIR]\
         \n  simulate --model <preset> [--ts-mha N] [--ts-ffn N] [--platform u55c|zcu102|vc707]\
         \n  serve --model <preset> [--requests N] [--batch N] [--pool N] [--max-seqs N]\
         \n        [--opt-level 0|1|2] [--priority low|normal|high] [--deadline-ms N]\
         \n        [--weight-mem-mb N] [--residency managed|always]\
         \n  generate --model <preset> [--steps N] [--prompt-len N] [--pool N] [--max-seqs N]\
         \n        [--stream] [--priority low|normal|high]\
         \n  sweep <tiles|heads>\
         \n  presets | list-models\
         \n  validate\
         \n  verify-programs [--model <preset>]"
    );
    std::process::exit(2);
}

/// Parse the shared `--priority` / `--deadline-ms` QoS flags.
fn parse_qos(args: &[String]) -> QoS {
    let mut qos = QoS::default();
    match flag_value(args, "--priority").as_deref() {
        None | Some("normal") => {}
        Some("low") => qos = qos.with_priority(Priority::Low),
        Some("high") => qos = qos.with_priority(Priority::High),
        Some(other) => {
            eprintln!("unknown priority '{other}' (want low, normal or high)");
            std::process::exit(2);
        }
    }
    if let Some(ms) = flag_value(args, "--deadline-ms") {
        match ms.parse::<u64>() {
            Ok(ms) => qos = qos.with_deadline(std::time::Duration::from_millis(ms)),
            Err(_) => {
                eprintln!("--deadline-ms wants a millisecond count, got '{ms}'");
                std::process::exit(2);
            }
        }
    }
    qos
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("report") => cmd_report(&args[1..]),
        Some("simulate") => cmd_simulate(&args[1..]),
        Some("serve") => cmd_serve(&args[1..]),
        Some("generate") => cmd_generate(&args[1..]),
        Some("sweep") => cmd_sweep(&args[1..]),
        Some("presets") | Some("list-models") => cmd_presets(),
        Some("validate") => cmd_validate(),
        Some("verify-programs") => cmd_verify_programs(&args[1..]),
        Some("gantt") => cmd_gantt(&args[1..]),
        _ => usage(),
    }
}

fn cmd_report(args: &[String]) -> anyhow::Result<()> {
    let name = args.first().map(String::as_str).unwrap_or("all");
    let out = flag_value(args, "--out");
    if name == "all" {
        let dir = out.unwrap_or_else(|| "reports".into());
        let written = report::write_all(&dir)?;
        println!("wrote {} reports to {dir}/: {}", written.len(), written.join(", "));
        return Ok(());
    }
    match report::render(name) {
        Some(text) => {
            println!("{text}");
            Ok(())
        }
        None => {
            eprintln!("unknown report '{name}'");
            std::process::exit(2);
        }
    }
}

fn cmd_simulate(args: &[String]) -> anyhow::Result<()> {
    let model = flag_value(args, "--model").unwrap_or_else(|| "bert-base".into());
    let cfg = presets::by_name(&model).unwrap_or_else(|| {
        eprintln!("unknown preset '{model}' (see `adaptor presets`)");
        std::process::exit(2);
    });
    let plat = flag_value(args, "--platform")
        .and_then(|n| platform::by_name(&n))
        .unwrap_or_else(platform::u55c);
    let ts_mha: usize = flag_value(args, "--ts-mha").and_then(|v| v.parse().ok()).unwrap_or(64);
    let ts_ffn: usize = flag_value(args, "--ts-ffn").and_then(|v| v.parse().ok()).unwrap_or(128);
    let tiles = TileConfig::for_fabric(ts_mha, ts_ffn, cfg.d_model.max(768));

    let r = resources::estimate(&cfg, &tiles, BitWidth::Fixed16, &plat);
    let f = frequency::fmax_mhz(&plat, &r);
    let ana = latency::model_latency(&cfg, &tiles);
    let s = sim::simulate(&cfg, &tiles);
    println!("model     : {cfg}");
    println!("platform  : {} ({})", plat.name, plat.part);
    println!("tiles     : TS_MHA={ts_mha} TS_FFN={ts_ffn}");
    println!("resources : {} DSP ({:.1}%), {} LUT ({:.1}%), {} BRAM18k ({:.1}%)",
        r.dsp, 100.0 * r.dsp_util, r.lut, 100.0 * r.lut_util, r.bram18k, 100.0 * r.bram_util);
    println!("fit       : {}", if r.check_fit(&plat).is_ok() { "ok" } else { "DOES NOT FIT" });
    println!("frequency : {f:.1} MHz");
    println!("analytical: {:.3} ms  ({:.1} GOPS)", ana.ms_at(f), ana.gops_at(&cfg, f));
    println!("simulated : {:.3} ms  (err {:.2}%)", s.ms_at(f),
        100.0 * (s.total_cycles as f64 - ana.total_cycles as f64).abs() / ana.total_cycles as f64);
    println!("power     : {:.1} W total", power::total_power_w(&plat, &r, f));
    Ok(())
}

fn cmd_serve(args: &[String]) -> anyhow::Result<()> {
    let model = flag_value(args, "--model").unwrap_or_else(|| "small".into());
    let cfg = presets::by_name(&model).unwrap_or_else(|| {
        eprintln!("unknown preset '{model}'");
        std::process::exit(2);
    });
    let n: usize = flag_value(args, "--requests").and_then(|v| v.parse().ok()).unwrap_or(16);
    let batch: usize = flag_value(args, "--batch").and_then(|v| v.parse().ok()).unwrap_or(4);
    let pool: usize = flag_value(args, "--pool").and_then(|v| v.parse().ok()).unwrap_or(1);

    let mut scfg = ServerConfig::new(vec![ModelSpec::new(&model, cfg, 42)]);
    scfg.policy.max_batch = batch;
    scfg.pool_size = pool;
    if let Some(n) = flag_value(args, "--max-seqs").and_then(|v| v.parse().ok()) {
        scfg.max_seqs = n;
    }
    scfg.opt_level = match flag_value(args, "--opt-level").as_deref() {
        Some("0") => OptLevel::O0,
        Some("1") => OptLevel::O1,
        Some("2") | None => OptLevel::O2,
        Some(other) => {
            eprintln!("unknown opt level '{other}' (want 0, 1 or 2)");
            std::process::exit(2);
        }
    };
    // Weight-residency knobs: a tight --weight-mem-mb exercises eviction
    // under churn; --residency always is the paper's reprogram-on-every-
    // switch host loop, kept as the measurable baseline.
    if let Some(mb) = flag_value(args, "--weight-mem-mb") {
        match mb.parse::<u64>() {
            Ok(mb) if mb > 0 => scfg.residency.capacity_bytes = mb * 1024 * 1024,
            _ => {
                eprintln!("--weight-mem-mb wants a positive megabyte count, got '{mb}'");
                std::process::exit(2);
            }
        }
    }
    match flag_value(args, "--residency").as_deref() {
        None | Some("managed") => {}
        Some("always") => scfg.residency.mode = ResidencyMode::ReprogramAlways,
        Some(other) => {
            eprintln!("unknown residency mode '{other}' (want managed or always)");
            std::process::exit(2);
        }
    }
    let qos = parse_qos(args);
    println!("starting {pool} fabric(s) for {cfg} (opt level {:?}) ...", scfg.opt_level);
    let server = Server::start(scfg)?;
    let mut handles = Vec::new();
    let t0 = std::time::Instant::now();
    for i in 0..n {
        let x = weights::init_input(i as u64, cfg.seq_len, cfg.d_model);
        handles.push(server.submit(Submission::Encode { model: model.clone(), input: x }, qos)?);
    }
    for (i, h) in handles.into_iter().enumerate() {
        match h.wait() {
            Ok(out) => {
                let t = out.timing();
                println!("req {i:>3}: e2e {:>7.2} ms (compute {:>6.2} ms, queue {:>6.2} ms)",
                    t.latency.as_secs_f64() * 1e3,
                    t.compute.as_secs_f64() * 1e3,
                    t.queue_wait.as_secs_f64() * 1e3);
            }
            Err(e) => println!("req {i:>3}: {e}"),
        }
    }
    println!("wall time: {:.2} ms for {n} requests", t0.elapsed().as_secs_f64() * 1e3);
    // Live snapshot before shutdown — no longer the only metrics exit.
    let live = server.metrics();
    println!("\nlive snapshot: {} served, {:.2} req/s", live.requests(), live.throughput_rps());
    let metrics = server.shutdown()?;
    println!("\n{}", metrics.report());
    Ok(())
}

/// Autoregressive generation demo: serve a decoder model through the
/// pool and greedy-decode a synthetic prompt, reporting the prefill vs
/// per-token latency split.  With `--stream`, tokens print as their
/// decode steps complete on the fabric.
fn cmd_generate(args: &[String]) -> anyhow::Result<()> {
    use std::io::Write as _;

    let model = flag_value(args, "--model").unwrap_or_else(|| "gpt-small".into());
    let cfg = presets::by_name(&model).unwrap_or_else(|| {
        eprintln!("unknown preset '{model}'");
        std::process::exit(2);
    });
    if cfg.dec_layers == 0 {
        eprintln!("preset '{model}' has no decoder layers; pick e.g. gpt-small or seq2seq-small");
        std::process::exit(2);
    }
    let prompt_len: usize =
        flag_value(args, "--prompt-len").and_then(|v| v.parse().ok()).unwrap_or(8);
    let steps: usize = flag_value(args, "--steps").and_then(|v| v.parse().ok()).unwrap_or(16);
    let pool: usize = flag_value(args, "--pool").and_then(|v| v.parse().ok()).unwrap_or(1);
    let stream = args.iter().any(|a| a == "--stream");
    let qos = parse_qos(args);

    let mut scfg = ServerConfig::new(vec![ModelSpec::new(&model, cfg, 42)]);
    scfg.pool_size = pool;
    if let Some(n) = flag_value(args, "--max-seqs").and_then(|v| v.parse().ok()) {
        scfg.max_seqs = n;
    }
    println!("starting {pool} fabric(s) for {cfg} ...");
    let server = Server::start(scfg)?;
    let prompt = weights::init_input(7, prompt_len, cfg.d_model);
    let source =
        (cfg.enc_layers > 0).then(|| weights::init_input(8, cfg.seq_len, cfg.d_model));
    let submission = Submission::Generate { model: model.clone(), prompt, source, steps };
    let mut handle = server.submit(submission, qos)?;
    if stream {
        // Tokens arrive as decode steps complete — not as a final
        // transcript.
        print!("tokens (streamed):");
        while let Some(t) = handle.next_token() {
            print!(" {}", t.token);
            std::io::stdout().flush()?;
        }
        println!();
    }
    let resp = handle.wait()?.into_generate()?;
    if !stream {
        println!("tokens: {:?}", resp.tokens);
    }
    println!(
        "prefill: {:.2} ms ({} prompt rows); {} decode steps, mean {:.2} ms/token",
        resp.prefill.as_secs_f64() * 1e3,
        prompt_len,
        resp.step_times.len(),
        resp.step_times.iter().map(|d| d.as_secs_f64()).sum::<f64>()
            / resp.step_times.len().max(1) as f64
            * 1e3,
    );
    println!(
        "e2e: {:.2} ms (queue {:.2} ms)",
        resp.timing.latency.as_secs_f64() * 1e3,
        resp.timing.queue_wait.as_secs_f64() * 1e3
    );
    let metrics = server.shutdown()?;
    println!("\n{}", metrics.report());
    Ok(())
}

fn cmd_sweep(args: &[String]) -> anyhow::Result<()> {
    match args.first().map(String::as_str) {
        Some("tiles") => println!("{}", report::render("fig5").unwrap()),
        Some("heads") => println!("{}", report::render("fig8").unwrap()),
        _ => usage(),
    }
    Ok(())
}

fn cmd_presets() -> anyhow::Result<()> {
    use adaptor::accel::schedule::FabricConstants;
    use adaptor::coordinator::residency::weight_footprint_bytes;
    use adaptor::coordinator::shard;

    // Residency-pressure view: each preset's device weight footprint
    // (prepared-stack bytes) against every platform's weight-memory
    // envelope.  Over 100% can never be fully resident on that part;
    // a large fraction means multi-tenant churn will evict it.
    let fc = FabricConstants::artifact_default();
    let plats = [platform::u55c(), platform::zcu102(), platform::vc707()];
    let mut oversize: Vec<String> = Vec::new();
    println!(
        "{:<20} {:>4} {:>6} {:>5} {:>7} {:>4} {:>4} {:>12} {:>12} {:>8} {:>8} {:>8}",
        "name", "sl", "d", "h", "hidden", "enc", "dec", "params", "wbytes", "%u55c", "%zcu102",
        "%vc707"
    );
    for (name, c) in presets::all() {
        let wb = weight_footprint_bytes(&c, &fc);
        let pct: Vec<String> = plats
            .iter()
            .map(|p| {
                format!("{:.1}", 100.0 * wb as f64 / resources::weight_memory_bytes(p) as f64)
            })
            .collect();
        println!(
            "{:<20} {:>4} {:>6} {:>5} {:>7} {:>4} {:>4} {:>12} {:>12} {:>8} {:>8} {:>8}",
            name,
            c.seq_len,
            c.d_model,
            c.heads,
            c.hidden,
            c.enc_layers,
            c.dec_layers,
            c.total_params(),
            wb,
            pct[0],
            pct[1],
            pct[2]
        );
        // Oversize on any platform → report the cross-fabric pipeline
        // cost: the minimum contiguous-shard count per platform (see
        // coordinator::shard).  "-" marks platforms the preset fits
        // whole; "never" marks a single layer exceeding the envelope.
        let needs: Vec<(String, Option<usize>)> = plats
            .iter()
            .filter(|p| wb > resources::weight_memory_bytes(p))
            .map(|p| (p.name.clone(), shard::min_shards(&c, &fc, resources::weight_memory_bytes(p))))
            .collect();
        if !needs.is_empty() {
            let detail: Vec<String> = needs
                .iter()
                .map(|(plat, k)| match k {
                    Some(k) => format!("{plat}: {k} shards"),
                    None => format!("{plat}: never (one layer exceeds the envelope)"),
                })
                .collect();
            oversize.push(format!("  {name:<20} {}", detail.join(", ")));
        }
    }
    if !oversize.is_empty() {
        println!("\noversize presets (need cross-fabric sharding to be served):");
        for line in &oversize {
            println!("{line}");
        }
    }
    Ok(())
}

fn cmd_validate() -> anyhow::Result<()> {
    println!("{}", report::render("table2").unwrap());
    Ok(())
}

/// Statically verify every executable preset topology × program kind ×
/// opt level with `accel::schedule::verify` — the CI sweep.  With a
/// loaded artifact manifest the dispatch interfaces are checked against
/// the real signatures; without one the artifact-free subset runs
/// (dataflow, waves, KV contracts — signature checks skip).
fn cmd_verify_programs(args: &[String]) -> anyhow::Result<()> {
    use adaptor::accel::schedule::{
        self, optimize, verify, ArtifactInventory, FabricConstants, ProgramKind, ScheduleBuilder,
    };
    use adaptor::runtime::Manifest;

    let only = flag_value(args, "--model");
    let (fc, inventory) = match Manifest::load(adaptor::runtime::default_artifact_dir()) {
        Ok(m) => {
            println!("artifact manifest loaded: dispatch signature checks on");
            (FabricConstants::from_manifest(&m), ArtifactInventory::from_manifest(&m))
        }
        Err(_) => {
            println!("no artifact set: running the artifact-free sweep (signature checks off)");
            (FabricConstants::artifact_default(), ArtifactInventory::assume_all())
        }
    };

    let levels = [OptLevel::O0, OptLevel::O1, OptLevel::O2];
    let (mut programs, mut errors, mut warnings) = (0usize, 0usize, 0usize);
    for (name, cfg) in presets::all() {
        if only.as_deref().is_some_and(|m| m != name) {
            continue;
        }
        if let Err(why) = fc.check(&cfg) {
            println!("{name:<20} skipped: {why}");
            continue;
        }
        let mut kinds: Vec<ProgramKind> = Vec::new();
        if cfg.enc_layers > 0 {
            kinds.push(ProgramKind::Encoder);
        }
        if cfg.dec_layers > 0 {
            kinds.extend([ProgramKind::Prefill, ProgramKind::DecodeStep]);
        }
        for kind in kinds {
            // The encoder stream has a quantized flavor; decoder lowering
            // is always the split f32 chain.
            let flavors: &[bool] =
                if kind == ProgramKind::Encoder { &[false, true] } else { &[false] };
            // Bucket sweep: every program the engine's length-adaptive
            // cache can serve for this topology — the dense max-length
            // program (bucket = None) plus one skippable program per
            // length tier.  Seq2seq prefills never re-bucket (the
            // cross-attention memory fence is the encoder's seq_len) and
            // the decode step is never skippable, so those sweep only
            // the full-length bucket / the dense program respectively.
            let mut buckets: Vec<Option<usize>> = vec![None];
            match kind {
                ProgramKind::Encoder => {
                    buckets.extend(schedule::length_tiers(cfg.seq_len).into_iter().map(Some));
                }
                ProgramKind::Prefill if cfg.enc_layers == 0 => {
                    buckets.extend(schedule::length_tiers(cfg.seq_len).into_iter().map(Some));
                }
                ProgramKind::Prefill => buckets.push(Some(cfg.seq_len)),
                ProgramKind::DecodeStep => {}
            }
            for &quantized in flavors {
                for level in levels {
                    for &bucket in &buckets {
                        let cfg_b = match bucket {
                            Some(b) => adaptor::model::TnnConfig { seq_len: b, ..cfg },
                            None => cfg,
                        };
                        let builder =
                            ScheduleBuilder::new(fc, cfg_b)?.skippable(bucket.is_some());
                        let mut p = match kind {
                            ProgramKind::Encoder => builder.quantized(quantized).build(),
                            ProgramKind::Prefill => builder.build_prefill(),
                            ProgramKind::DecodeStep => builder.build_step(),
                        };
                        optimize(&mut p, level, &inventory)?;
                        let report = verify::verify(&p, kind, &inventory);
                        programs += 1;
                        errors += report.error_count();
                        warnings += report.warning_count();
                        if !report.diagnostics.is_empty() {
                            let q = if quantized { " int8" } else { "" };
                            let b = match bucket {
                                Some(b) => format!(" bucket={b}"),
                                None => String::new(),
                            };
                            println!("{name} {kind:?} {level:?}{q}{b}:");
                            for d in &report.diagnostics {
                                println!("  {d}");
                            }
                        }
                    }
                }
            }
        }
    }
    // Sharded-chain sweep: every single-stack preset that can split,
    // lowered as a K-shard pipeline (coordinator::shard) and checked
    // both per shard program and as a chain (boundary coverage, peer
    // shape agreement — Rule::ShardContract).
    use adaptor::coordinator::shard;
    for (name, cfg) in presets::all() {
        if only.as_deref().is_some_and(|m| m != name) {
            continue;
        }
        if fc.check(&cfg).is_err() {
            continue;
        }
        let (stack_len, kind) = match (cfg.enc_layers, cfg.dec_layers) {
            (e, 0) if e >= 2 => (e, ProgramKind::Encoder),
            (0, d) if d >= 2 => (d, ProgramKind::Prefill),
            _ => continue, // seq2seq / single-layer stacks don't shard
        };
        for k in [2usize, 3] {
            if k > stack_len {
                continue;
            }
            let plan = match shard::ShardPlan::partition_k(&cfg, &fc, k) {
                Ok(plan) => plan,
                Err(why) => {
                    println!("{name:<20} {k}-shard skipped: {why}");
                    continue;
                }
            };
            for level in [OptLevel::O0, OptLevel::O2] {
                let chain = shard::lower_chain(&plan, &fc, level, &inventory)?;
                for (i, p) in chain.iter().enumerate() {
                    let report = verify::verify(p, kind, &inventory);
                    programs += 1;
                    errors += report.error_count();
                    warnings += report.warning_count();
                    if !report.diagnostics.is_empty() {
                        println!("{name} shard {i}/{k} {level:?}:");
                        for d in &report.diagnostics {
                            println!("  {d}");
                        }
                    }
                }
                let report = shard::verify_chain(&chain);
                errors += report.error_count();
                warnings += report.warning_count();
                if !report.diagnostics.is_empty() {
                    println!("{name} {k}-shard chain {level:?}:");
                    for d in &report.diagnostics {
                        println!("  {d}");
                    }
                }
            }
        }
    }

    println!("verified {programs} program(s): {errors} error(s), {warnings} warning(s)");
    if errors > 0 {
        std::process::exit(1);
    }
    Ok(())
}

/// Render the cycle-level simulator's module schedule as a text Gantt
/// chart (the substrate's view of the paper's module pipeline).
fn cmd_gantt(args: &[String]) -> anyhow::Result<()> {
    let model = flag_value(args, "--model").unwrap_or_else(|| "small".into());
    let cfg = presets::by_name(&model).unwrap_or_else(|| {
        eprintln!("unknown preset '{model}'");
        std::process::exit(2);
    });
    let rep = sim::simulate(&cfg, &TileConfig::paper_optimum());
    println!("{cfg} — {} cycles total\n", rep.total_cycles);
    println!("{}", rep.trace.gantt(64));
    Ok(())
}
