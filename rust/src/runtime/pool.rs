//! Buffer pooling for the request hot path.
//!
//! Two pools exist, mirroring the two memory spaces of the substrate:
//!
//! * [`TensorPool`] (here) — **host scratch** reuse.  A `TileProgram`
//!   replay materializes dozens of transient host tensors (panel
//!   extracts, zero-initialized assembly targets, fetch staging).  The
//!   pool recycles their backing `Vec<f32>` allocations by shape across
//!   steps *and across requests*, so a steady-state serving loop
//!   allocates no host scratch at all — the analog of the paper's
//!   statically-sized BRAM buffers, which exist once and are reused by
//!   every inference.
//! * the device **zero-buffer pool** inside `runtime::Executor` — the
//!   per-topology zero accumulators (`RuntimeId::Zero*`) are
//!   topology-independent (their shapes are synthesis constants), so one
//!   device-resident buffer per shape serves every programmed topology;
//!   see `Executor::shared_zeros` and `FabricBackend::upload_zeros`.
//!
//! The pool is deliberately `!Sync` (interior mutability via `RefCell`)
//! — it lives next to the engine on its fabric thread, like everything
//! else that touches PJRT.

use std::cell::{Cell, RefCell};
use std::collections::HashMap;

use super::executor::Tensor;

/// Free buffers kept per shape; beyond this they are simply dropped.
/// A replay's peak simultaneous scratch per shape is small (panel count
/// of one module chain), so the cap only guards pathological churn.
const PER_SHAPE_CAP: usize = 16;

/// A shape-keyed free list of host tensor allocations.
#[derive(Debug, Default)]
pub struct TensorPool {
    free: RefCell<HashMap<Vec<usize>, Vec<Vec<f32>>>>,
    hits: Cell<u64>,
    misses: Cell<u64>,
}

impl TensorPool {
    pub fn new() -> Self {
        Self::default()
    }

    /// A tensor of `shape` filled with zeros (recycled allocation when
    /// one of this shape is free).
    pub fn take_zeroed(&self, shape: &[usize]) -> Tensor {
        let mut t = self.take_uninit(shape);
        t.data.fill(0.0);
        t
    }

    /// A tensor of `shape` with **unspecified contents** (stale data from
    /// a previous user when recycled).  Callers must overwrite every
    /// element before reading.
    pub fn take_uninit(&self, shape: &[usize]) -> Tensor {
        if let Some(data) = self.free.borrow_mut().get_mut(shape).and_then(Vec::pop) {
            self.hits.set(self.hits.get() + 1);
            return Tensor::new(shape.to_vec(), data);
        }
        self.misses.set(self.misses.get() + 1);
        Tensor::zeros(shape.to_vec())
    }

    /// Return a tensor's allocation to the pool (empty tensors — the
    /// replay's placeholder slots — are ignored).
    pub fn put(&self, t: Tensor) {
        if t.data.is_empty() {
            return;
        }
        let mut free = self.free.borrow_mut();
        let list = free.entry(t.shape).or_default();
        if list.len() < PER_SHAPE_CAP {
            list.push(t.data);
        }
    }

    /// `(hits, misses)` of `take_*` calls — steady state is all hits.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits.get(), self.misses.get())
    }

    /// Bytes currently parked in the free lists.
    pub fn retained_bytes(&self) -> u64 {
        self.free
            .borrow()
            .values()
            .flat_map(|l| l.iter())
            .map(|b| (b.len() * std::mem::size_of::<f32>()) as u64)
            .sum()
    }

    /// Drop every free buffer, returning the bytes released.  The
    /// serving layer calls this (via `TileEngine::trim_scratch`) when a
    /// weight stack is evicted: multi-tenant model churn otherwise
    /// accumulates free lists for shapes only departed topologies
    /// replayed, and an eviction is the natural low-water moment to
    /// shed them.  Surviving models re-warm their shapes on the next
    /// replay (one allocation per shape, then steady state again).
    pub fn trim(&self) -> u64 {
        let bytes = self.retained_bytes();
        self.free.borrow_mut().clear();
        bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recycles_by_shape() {
        let p = TensorPool::new();
        let a = p.take_zeroed(&[4, 8]);
        assert_eq!(p.stats(), (0, 1));
        p.put(a);
        let b = p.take_zeroed(&[4, 8]);
        assert_eq!(p.stats(), (1, 1), "same shape must recycle");
        assert!(b.data.iter().all(|v| *v == 0.0));
        let _c = p.take_zeroed(&[8, 4]);
        assert_eq!(p.stats(), (1, 2), "different shape is a fresh allocation");
    }

    #[test]
    fn uninit_take_reuses_without_zeroing() {
        let p = TensorPool::new();
        let mut a = p.take_uninit(&[2, 2]);
        a.data.copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        p.put(a);
        let b = p.take_uninit(&[2, 2]);
        assert_eq!(b.data, vec![1.0, 2.0, 3.0, 4.0], "uninit take keeps stale contents");
        let c = p.take_zeroed(&[2, 2]);
        // b still holds the only recycled buffer, so c is fresh zeros
        assert!(c.data.iter().all(|v| *v == 0.0));
    }

    #[test]
    fn empty_tensors_are_not_pooled() {
        let p = TensorPool::new();
        p.put(Tensor::zeros(vec![0]));
        let _ = p.take_zeroed(&[0]);
        assert_eq!(p.stats(), (0, 1));
    }

    #[test]
    fn trim_releases_retained_scratch() {
        let p = TensorPool::new();
        p.put(Tensor::zeros(vec![4, 8]));
        p.put(Tensor::zeros(vec![16]));
        assert_eq!(p.retained_bytes(), (32 + 16) * 4);
        assert_eq!(p.trim(), (32 + 16) * 4);
        assert_eq!(p.retained_bytes(), 0);
        // the next take of a trimmed shape is a fresh allocation
        let _ = p.take_zeroed(&[4, 8]);
        assert_eq!(p.stats(), (0, 1));
    }

    #[test]
    fn per_shape_cap_bounds_memory() {
        let p = TensorPool::new();
        for _ in 0..40 {
            p.put(Tensor::zeros(vec![3]));
        }
        let held = p.free.borrow().get(&vec![3usize][..]).map(|v| v.len()).unwrap_or(0);
        assert!(held <= PER_SHAPE_CAP);
    }
}
