//! Artifact manifest: the contract between `python/compile/aot.py` (the
//! one-time "synthesis" step) and the rust request path.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context};

use crate::util::json::{self, Json};

/// One lowered program's interface.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub inputs: Vec<Vec<usize>>,
    pub outputs: Vec<Vec<usize>>,
}

/// A fused per-config layer artifact (the non-adaptive baseline path).
#[derive(Debug, Clone, PartialEq)]
pub struct FusedMeta {
    pub meta: ArtifactMeta,
    pub sl: usize,
    pub d_model: usize,
    pub heads: usize,
    pub quantized: bool,
}

/// The parsed manifest plus the synthesis-time fabric constants.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub digest: String,
    pub sl_max: usize,
    pub dk: usize,
    pub ts_mha: usize,
    pub ts_ffn: usize,
    pub ffn_col: usize,
    pub dmodel_max: usize,
    pub hidden_max: usize,
    pub artifacts: BTreeMap<String, ArtifactMeta>,
    pub fused: BTreeMap<String, FusedMeta>,
}

fn shapes(j: &Json, key: &str) -> anyhow::Result<Vec<Vec<usize>>> {
    j.get(key)
        .and_then(Json::as_shape_list)
        .ok_or_else(|| anyhow!("manifest entry missing '{key}' shape list"))
}

impl Manifest {
    /// Parse `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}; run `make artifacts` first"))?;
        let j = json::parse(&text).map_err(|e| anyhow!("manifest parse error: {e}"))?;
        let num = |key: &str| -> anyhow::Result<usize> {
            j.get(key).and_then(Json::as_usize).ok_or_else(|| anyhow!("manifest missing '{key}'"))
        };

        let mut artifacts = BTreeMap::new();
        for (name, entry) in
            j.get("artifacts").and_then(Json::as_obj).ok_or_else(|| anyhow!("no artifacts"))?
        {
            artifacts.insert(
                name.clone(),
                ArtifactMeta {
                    name: name.clone(),
                    file: entry
                        .get("file")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("artifact '{name}' missing file"))?
                        .to_string(),
                    inputs: shapes(entry, "inputs")?,
                    outputs: shapes(entry, "outputs")?,
                },
            );
        }

        let mut fused = BTreeMap::new();
        if let Some(fobj) = j.get("fused").and_then(Json::as_obj) {
            for (name, entry) in fobj {
                let cfg = entry.get("config").ok_or_else(|| anyhow!("fused '{name}': no config"))?;
                let get = |k: &str| {
                    cfg.get(k).and_then(Json::as_usize).ok_or_else(|| anyhow!("fused {name}.{k}"))
                };
                fused.insert(
                    name.clone(),
                    FusedMeta {
                        meta: ArtifactMeta {
                            name: name.clone(),
                            file: entry
                                .get("file")
                                .and_then(Json::as_str)
                                .ok_or_else(|| anyhow!("fused '{name}' missing file"))?
                                .to_string(),
                            inputs: shapes(entry, "inputs")?,
                            outputs: shapes(entry, "outputs")?,
                        },
                        sl: get("sl")?,
                        d_model: get("d_model")?,
                        heads: get("heads")?,
                        quantized: cfg
                            .get("quantized")
                            .map(|v| *v == Json::Bool(true))
                            .unwrap_or(false),
                    },
                );
            }
        }

        let m = Manifest {
            dir,
            digest: j
                .get("digest")
                .and_then(Json::as_str)
                .unwrap_or_default()
                .to_string(),
            sl_max: num("sl_max")?,
            dk: num("dk")?,
            ts_mha: num("ts_mha")?,
            ts_ffn: num("ts_ffn")?,
            ffn_col: num("ffn_col")?,
            dmodel_max: num("dmodel_max")?,
            hidden_max: num("hidden_max")?,
            artifacts,
            fused,
        };
        m.check_files()?;
        Ok(m)
    }

    /// Every referenced artifact file must exist.
    fn check_files(&self) -> anyhow::Result<()> {
        for a in self.artifacts.values().map(|a| &a.file).chain(self.fused.values().map(|f| &f.meta.file))
        {
            let p = self.dir.join(a);
            if !p.exists() {
                bail!("artifact file missing: {p:?} (stale manifest? run `make artifacts`)");
            }
        }
        Ok(())
    }

    pub fn artifact(&self, name: &str) -> anyhow::Result<&ArtifactMeta> {
        self.artifacts.get(name).ok_or_else(|| anyhow!("unknown artifact '{name}'"))
    }

    pub fn path_of(&self, meta: &ArtifactMeta) -> PathBuf {
        self.dir.join(&meta.file)
    }

    /// The synthesis maxima these artifacts were "synthesized" for — the
    /// register file validates against exactly this.
    pub fn synth_maxima(&self) -> crate::accel::registers::SynthMaxima {
        crate::accel::registers::SynthMaxima {
            seq_len: self.sl_max,
            heads: self.dmodel_max / self.dk,
            d_model: self.dmodel_max,
            hidden: self.hidden_max,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dir() -> PathBuf {
        crate::runtime::default_artifact_dir()
    }

    use crate::require_artifacts;


    #[test]
    fn loads_real_manifest() {
        require_artifacts!();
        let m = Manifest::load(dir()).expect("run `make artifacts` first");
        assert_eq!(m.sl_max, 128);
        assert_eq!((m.ts_mha, m.ts_ffn, m.dk), (64, 128, 64));
        assert!(m.artifacts.len() >= 13, "{}", m.artifacts.len());
        assert!(m.fused.contains_key("bert_layer"));
    }

    #[test]
    fn mm_qkv_interface_matches_fabric_constants() {
        require_artifacts!();
        let m = Manifest::load(dir()).unwrap();
        let a = m.artifact("mm_qkv").unwrap();
        assert_eq!(a.inputs, vec![vec![128, 64], vec![64, 64], vec![128, 64]]);
        assert_eq!(a.outputs, vec![vec![128, 64]]);
    }

    #[test]
    fn synth_maxima_match_artifact_set() {
        require_artifacts!();
        let m = Manifest::load(dir()).unwrap();
        let s = m.synth_maxima();
        assert_eq!((s.seq_len, s.d_model, s.hidden, s.heads), (128, 768, 3072, 12));
    }

    #[test]
    fn unknown_artifact_is_an_error() {
        require_artifacts!();
        let m = Manifest::load(dir()).unwrap();
        assert!(m.artifact("nonexistent").is_err());
    }

    #[test]
    fn missing_dir_is_a_clean_error() {
        let err = Manifest::load("/nonexistent-dir").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
