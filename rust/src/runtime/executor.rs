//! Compile-once PJRT execution of AOT artifacts.
//!
//! The executor mirrors the FPGA deployment lifecycle:
//!
//! * **synthesis** — `python/compile/aot.py` emitted the HLO text (once);
//! * **bitstream load** — [`Executor::new`] compiles each artifact on the
//!   PJRT CPU client the first time it is used and caches the executable
//!   for the life of the process;
//! * **runtime** — [`Executor::run`] feeds inputs and returns outputs; the
//!   runtime-adaptive contract is that *no* register reprogramming ever
//!   invalidates this cache (asserted by `compile_count` in tests).

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{bail, Context};

use super::artifact::{ArtifactMeta, Manifest};

/// A host tensor (row-major f32) moving across the PJRT boundary.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    pub fn scalar1(v: f32) -> Self {
        Tensor { shape: vec![1], data: vec![v] }
    }

    pub fn from_mat(m: &crate::model::weights::Mat) -> Self {
        Tensor { shape: vec![m.rows, m.cols], data: m.data.clone() }
    }

    pub fn to_mat(&self) -> crate::model::weights::Mat {
        assert_eq!(self.shape.len(), 2, "to_mat on non-2D tensor");
        crate::model::weights::Mat { rows: self.shape[0], cols: self.shape[1], data: self.data.clone() }
    }
}

/// A device-resident tensor (PJRT buffer + logical shape) — the substrate
/// analog of data parked in the fabric's BRAMs.  The buffer is `Rc`'d so
/// pooled constants (the shared zero accumulators) can hand the same
/// device memory to many holders; PJRT buffers are immutable once
/// written, so sharing is safe.
pub struct DeviceTensor {
    pub shape: Vec<usize>,
    pub(crate) buf: Rc<xla::PjRtBuffer>,
}

/// Execution statistics (the host-side AXI-timer analog).
#[derive(Debug, Clone, Copy, Default)]
pub struct ExecStats {
    /// HLO-text compiles performed (must stay flat across register writes).
    pub compiles: u64,
    /// Artifact executions dispatched.
    pub dispatches: u64,
    /// Host→device transfers (uploads; the AXI write-DMA analog).  The
    /// schedule-cache tests assert this drops once per-topology runtime
    /// tensors and layer activations stop being re-uploaded.
    pub uploads: u64,
    /// Device→host transfers (fetches; the AXI read-DMA analog).
    pub fetches: u64,
    /// Uploads *avoided* by the device zero-buffer pool (a request for a
    /// zero buffer whose shape was already device-resident).
    pub pool_hits: u64,
    /// Wall time spent inside PJRT execute, seconds.
    pub execute_secs: f64,
}

/// Compile-once executor over one artifact directory.
///
/// `PjRtLoadedExecutable` holds raw pointers (not `Send`); the coordinator
/// therefore owns the executor on a dedicated engine thread — exactly one
/// fabric, like the hardware.
pub struct Executor {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    stats: RefCell<ExecStats>,
    /// When `Some`, every dispatched artifact name is appended — the
    /// backend-equivalence tests compare this against the cycle backend's
    /// trace of the same program.  Names are interned (`interned`), so
    /// recording costs no allocation per dispatch.
    trace: RefCell<Option<Vec<&'static str>>>,
    /// Artifact-name intern table.  Bounded by the number of distinct
    /// artifacts in the manifest (the leaked allocation is one short
    /// string per artifact for the life of the process — the same
    /// lifetime as the compiled-executable cache).
    interned: RefCell<HashMap<String, &'static str>>,
    /// Device-resident all-zero buffers by shape: the zero accumulators
    /// every topology's runtime tensor set needs are shape constants of
    /// the fabric, so one immutable buffer per shape serves all of them.
    zeros: RefCell<HashMap<Vec<usize>, Rc<xla::PjRtBuffer>>>,
}

impl Executor {
    /// Create a CPU-PJRT executor over `dir` (compiles lazily).
    pub fn new(dir: impl AsRef<std::path::Path>) -> anyhow::Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Executor {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
            stats: RefCell::new(ExecStats::default()),
            trace: RefCell::new(None),
            interned: RefCell::new(HashMap::new()),
            zeros: RefCell::new(HashMap::new()),
        })
    }

    /// Start (`true`) or stop (`false`) recording the dispatch trace.
    /// Starting clears any previous recording.
    pub fn trace_dispatches(&self, on: bool) {
        *self.trace.borrow_mut() = if on { Some(Vec::new()) } else { None };
    }

    /// Take the recorded dispatch trace (artifact names in dispatch
    /// order), stopping the recording.
    pub fn take_trace(&self) -> Vec<&'static str> {
        self.trace.borrow_mut().take().unwrap_or_default()
    }

    /// Intern an artifact name: one `String` allocation the *first* time
    /// a name is seen, `&'static str` forever after — the dispatch hot
    /// path never allocates for tracing.
    fn intern(&self, name: &str) -> &'static str {
        if let Some(s) = self.interned.borrow().get(name) {
            return s;
        }
        let leaked: &'static str = Box::leak(name.to_string().into_boxed_str());
        self.interned.borrow_mut().insert(name.to_string(), leaked);
        leaked
    }

    fn record_dispatch(&self, name: &str) {
        // The borrow is taken twice on purpose: intern() needs the
        // interned map, not the trace, and only runs when tracing is on.
        if self.trace.borrow().is_some() {
            let s = self.intern(name);
            if let Some(t) = self.trace.borrow_mut().as_mut() {
                t.push(s);
            }
        }
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn stats(&self) -> ExecStats {
        *self.stats.borrow()
    }

    /// Resolve (compile-or-fetch) an executable by artifact name.
    fn executable(&self, name: &str) -> anyhow::Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let meta = self.lookup(name)?.clone();
        let path = self.manifest.path_of(&meta);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("PJRT compile of artifact '{name}'"))?;
        let exe = Rc::new(exe);
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        self.stats.borrow_mut().compiles += 1;
        Ok(exe)
    }

    fn lookup(&self, name: &str) -> anyhow::Result<&ArtifactMeta> {
        if let Some(a) = self.manifest.artifacts.get(name) {
            return Ok(a);
        }
        if let Some(f) = self.manifest.fused.get(name) {
            return Ok(&f.meta);
        }
        bail!("unknown artifact '{name}'")
    }

    /// Eagerly compile a set of artifacts (bitstream-load analog).
    pub fn warmup(&self, names: &[&str]) -> anyhow::Result<()> {
        for n in names {
            self.executable(n)?;
        }
        Ok(())
    }

    /// Execute artifact `name` with shape-checked inputs.  The manifest
    /// metadata is *borrowed* on this path — no per-dispatch clone of the
    /// nested shape vectors.
    pub fn run(&self, name: &str, inputs: &[&Tensor]) -> anyhow::Result<Vec<Tensor>> {
        let meta = self.lookup(name)?;
        if inputs.len() != meta.inputs.len() {
            bail!("artifact '{name}': {} inputs given, {} expected", inputs.len(), meta.inputs.len());
        }
        for (i, (t, want)) in inputs.iter().zip(&meta.inputs).enumerate() {
            if &t.shape != want {
                bail!("artifact '{name}' input {i}: shape {:?} != manifest {:?}", t.shape, want);
            }
        }
        let exe = self.executable(name)?;
        // Host -> device buffers (no Literal round-trip on the hot path).
        let mut bufs = Vec::with_capacity(inputs.len());
        for t in inputs {
            bufs.push(
                self.client
                    .buffer_from_host_buffer::<f32>(&t.data, &t.shape, None)
                    .context("host->device transfer")?,
            );
        }
        let t0 = std::time::Instant::now();
        let out = exe.execute_b(&bufs).with_context(|| format!("executing '{name}'"))?;
        {
            let mut s = self.stats.borrow_mut();
            s.dispatches += 1;
            s.uploads += inputs.len() as u64;
            s.fetches += 1;
            s.execute_secs += t0.elapsed().as_secs_f64();
        }
        self.record_dispatch(name);
        // aot.py lowers with return_tuple=False (§Perf iteration 2): the
        // output is a bare array buffer; tuple outputs (older artifact
        // sets) are still handled for compatibility.
        let lit = out[0][0].to_literal_sync()?;
        let parts = if lit.array_shape().is_ok() { vec![lit] } else { lit.to_tuple()? };
        if parts.len() != meta.outputs.len() {
            bail!("artifact '{name}': {} outputs, {} expected", parts.len(), meta.outputs.len());
        }
        let mut tensors = Vec::with_capacity(parts.len());
        for (p, shape) in parts.into_iter().zip(&meta.outputs) {
            let data = p.to_vec::<f32>()?;
            if data.len() != shape.iter().product::<usize>() {
                bail!("artifact '{name}': output element count mismatch");
            }
            tensors.push(Tensor::new(shape.clone(), data));
        }
        Ok(tensors)
    }

    /// Upload a host tensor to a device-resident buffer (the BRAM/weight-
    /// residency analog: weights go up once at prepare time, §Perf iter 2).
    pub fn to_device(&self, t: &Tensor) -> anyhow::Result<DeviceTensor> {
        let buf = self
            .client
            .buffer_from_host_buffer::<f32>(&t.data, &t.shape, None)
            .context("host->device transfer")?;
        self.stats.borrow_mut().uploads += 1;
        Ok(DeviceTensor { shape: t.shape.clone(), buf: Rc::new(buf) })
    }

    /// A device-resident all-zero buffer of `shape` from the zero pool:
    /// uploaded once per distinct shape for the life of the executor,
    /// shared (immutably) by every holder afterwards.  Pool hits count in
    /// `ExecStats::pool_hits` instead of `uploads`.
    pub fn shared_zeros(&self, shape: &[usize]) -> anyhow::Result<DeviceTensor> {
        if let Some(buf) = self.zeros.borrow().get(shape) {
            self.stats.borrow_mut().pool_hits += 1;
            return Ok(DeviceTensor { shape: shape.to_vec(), buf: buf.clone() });
        }
        let t = self.to_device(&Tensor::zeros(shape.to_vec()))?;
        self.zeros.borrow_mut().insert(shape.to_vec(), t.buf.clone());
        Ok(t)
    }

    /// Download a device tensor.
    pub fn fetch(&self, d: &DeviceTensor) -> anyhow::Result<Tensor> {
        let lit = d.buf.to_literal_sync()?;
        self.stats.borrow_mut().fetches += 1;
        Ok(Tensor::new(d.shape.clone(), lit.to_vec::<f32>()?))
    }

    /// Execute with device-resident inputs, returning a device-resident
    /// output (requires a non-tuple, single-output artifact — all of the
    /// v2 artifact set).  This is the hot path: no host round-trips, and
    /// the returned buffer can feed the next dispatch directly
    /// (accumulator chaining across the tile schedule).
    pub fn run_dev(&self, name: &str, inputs: &[&DeviceTensor]) -> anyhow::Result<DeviceTensor> {
        let meta = self.lookup(name)?;
        if inputs.len() != meta.inputs.len() {
            bail!("artifact '{name}': {} inputs given, {} expected", inputs.len(), meta.inputs.len());
        }
        for (i, (t, want)) in inputs.iter().zip(&meta.inputs).enumerate() {
            if &t.shape != want {
                bail!("artifact '{name}' input {i}: shape {:?} != manifest {:?}", t.shape, want);
            }
        }
        if meta.outputs.len() != 1 {
            bail!("run_dev needs a single-output artifact ('{name}' has {})", meta.outputs.len());
        }
        let exe = self.executable(name)?;
        let bufs: Vec<&xla::PjRtBuffer> = inputs.iter().map(|d| d.buf.as_ref()).collect();
        let t0 = std::time::Instant::now();
        let mut out = exe.execute_b(&bufs).with_context(|| format!("executing '{name}'"))?;
        {
            let mut s = self.stats.borrow_mut();
            s.dispatches += 1;
            s.execute_secs += t0.elapsed().as_secs_f64();
        }
        self.record_dispatch(name);
        Ok(DeviceTensor { shape: meta.outputs[0].clone(), buf: Rc::new(out[0].remove(0)) })
    }

    /// Single-output convenience.
    pub fn run1(&self, name: &str, inputs: &[&Tensor]) -> anyhow::Result<Tensor> {
        let mut out = self.run(name, inputs)?;
        if out.len() != 1 {
            bail!("artifact '{name}' returned {} outputs, expected 1", out.len());
        }
        Ok(out.pop().unwrap())
    }

    /// Number of distinct compiled artifacts (the no-resynthesis probe).
    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::default_artifact_dir;

    use crate::require_artifacts;

    fn exec() -> Executor {
        Executor::new(default_artifact_dir()).expect("run `make artifacts` first")
    }

    #[test]
    fn mm_qkv_computes_acc_plus_xw() {
        require_artifacts!();
        let e = exec();
        let x = Tensor::new(vec![128, 64], (0..128 * 64).map(|i| (i % 7) as f32 * 0.1).collect());
        let w = Tensor::new(vec![64, 64], (0..64 * 64).map(|i| (i % 5) as f32 * 0.01).collect());
        let acc = Tensor::new(vec![128, 64], vec![1.0; 128 * 64]);
        let out = e.run1("mm_qkv", &[&x, &w, &acc]).unwrap();
        // oracle via the reference matmul
        let xm = x.to_mat();
        let wm = w.to_mat();
        let mut want = crate::model::reference::matmul(&xm, &wm);
        for v in want.data.iter_mut() {
            *v += 1.0;
        }
        let got = out.to_mat();
        assert!(got.max_abs_diff(&want) < 1e-4, "{}", got.max_abs_diff(&want));
    }

    #[test]
    fn compile_cache_hits() {
        require_artifacts!();
        let e = exec();
        let x = Tensor::zeros(vec![128, 64]);
        let w = Tensor::zeros(vec![64, 64]);
        let acc = Tensor::zeros(vec![128, 64]);
        e.run1("mm_qkv", &[&x, &w, &acc]).unwrap();
        e.run1("mm_qkv", &[&x, &w, &acc]).unwrap();
        e.run1("mm_qkv", &[&x, &w, &acc]).unwrap();
        assert_eq!(e.stats().compiles, 1);
        assert_eq!(e.stats().dispatches, 3);
    }

    #[test]
    fn shape_mismatch_is_rejected() {
        require_artifacts!();
        let e = exec();
        let bad = Tensor::zeros(vec![64, 64]);
        let w = Tensor::zeros(vec![64, 64]);
        let acc = Tensor::zeros(vec![128, 64]);
        assert!(e.run1("mm_qkv", &[&bad, &w, &acc]).is_err());
        assert!(e.run1("mm_qkv", &[&w, &acc]).is_err());
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        require_artifacts!();
        let e = exec();
        let s = Tensor::new(vec![128, 128], (0..128 * 128).map(|i| ((i % 13) as f32) * 0.3).collect());
        let p = e.run1("softmax", &[&s]).unwrap();
        for r in 0..128 {
            let sum: f32 = p.data[r * 128..(r + 1) * 128].iter().sum();
            assert!((sum - 1.0).abs() < 1e-5, "row {r}: {sum}");
        }
    }

    #[test]
    fn trace_and_transfer_counters() {
        require_artifacts!();
        let e = exec();
        e.trace_dispatches(true);
        let x = Tensor::zeros(vec![128, 64]);
        let w = Tensor::zeros(vec![64, 64]);
        let acc = Tensor::zeros(vec![128, 64]);
        let xd = e.to_device(&x).unwrap();
        let wd = e.to_device(&w).unwrap();
        let ad = e.to_device(&acc).unwrap();
        let out = e.run_dev("mm_qkv", &[&xd, &wd, &ad]).unwrap();
        let _ = e.fetch(&out).unwrap();
        assert_eq!(e.take_trace(), vec!["mm_qkv"]);
        let st = e.stats();
        assert_eq!(st.uploads, 3);
        assert_eq!(st.fetches, 1);
        assert!(e.take_trace().is_empty(), "take_trace stops the recording");
    }

    #[test]
    fn quantize_lattice() {
        require_artifacts!();
        let e = exec();
        let x = Tensor::new(vec![128, 768], (0..128 * 768).map(|i| ((i % 101) as f32 - 50.0) * 0.01).collect());
        let s = Tensor::scalar1(0.05);
        let q = e.run1("quantize", &[&x, &s]).unwrap();
        for v in &q.data {
            let k = v / 0.05;
            assert!((k - k.round()).abs() < 1e-4);
        }
    }
}
