//! The execution-substrate abstraction a [`TileProgram`] replays against.
//!
//! A backend supplies the three primitive operations the tile schedule
//! needs — host→device transfer, fixed-shape artifact dispatch, and
//! device→host transfer — behind an associated buffer type.  Two
//! implementations exist:
//!
//! * [`Executor`] (here): the PJRT fabric — real numerics, `Buf` is a
//!   device-resident [`DeviceTensor`];
//! * `accel::sim::cycle::CycleBackend`: the cycle model — `Buf` is a bare
//!   shape, each dispatch accrues predicted cycles, and the dispatch trace
//!   is recorded for Table 2's analytical-vs-experimental comparison.
//!
//! [`TileProgram`]: crate::accel::schedule::TileProgram

use super::executor::{DeviceTensor, Executor, Tensor};

/// One fabric substrate: uploads, fixed-shape dispatches, downloads.
///
/// Methods take `&self` (backends use interior mutability for statistics,
/// mirroring [`Executor`]'s compile cache) so a replay can hold the
/// backend alongside slot borrows.
pub trait FabricBackend {
    /// The backend's device-resident value representation.
    type Buf;

    /// Host tensor → device buffer (AXI DMA write analog).
    fn upload(&self, t: &Tensor) -> anyhow::Result<Self::Buf>;

    /// Execute artifact `artifact` over `inputs`.  `out_shape` is the
    /// output shape recorded in the program at build time; backends with a
    /// manifest must reject a mismatch (program/artifact-set drift),
    /// shape-only backends construct their result from it.
    fn dispatch(
        &self,
        artifact: &str,
        inputs: &[&Self::Buf],
        out_shape: &[usize],
    ) -> anyhow::Result<Self::Buf>;

    /// Device buffer → host tensor (AXI DMA read analog).
    fn fetch(&self, buf: &Self::Buf) -> anyhow::Result<Tensor>;

    /// Upload an all-zeros tensor of `shape`.  Backends with a device
    /// buffer pool (the PJRT [`Executor`]) override this to hand out one
    /// shared immutable buffer per shape — the zero accumulators of
    /// `RuntimeId::Zero*` are synthesis constants, so every programmed
    /// topology can share them.
    fn upload_zeros(&self, shape: &[usize]) -> anyhow::Result<Self::Buf> {
        self.upload(&Tensor::zeros(shape.to_vec()))
    }

    /// [`FabricBackend::dispatch`] with a replay-time live-row bound:
    /// `rows` is `Some(t)` when the dispatch sits behind a fired length
    /// tier of `t` rows (a skippable attention dispatch), `None` for
    /// unpredicated dispatches.  Numeric backends ignore the bound — the
    /// per-tier masks already fence the dead rows — while pricing
    /// backends (`accel::sim::cycle::CycleBackend`) scale the dispatch
    /// cost to the live tier, which is where the recovered padding waste
    /// of length-adaptive programs shows up.  Default: plain dispatch.
    fn dispatch_rows(
        &self,
        artifact: &str,
        inputs: &[&Self::Buf],
        out_shape: &[usize],
        _rows: Option<usize>,
    ) -> anyhow::Result<Self::Buf> {
        self.dispatch(artifact, inputs, out_shape)
    }

    /// Wave-replay entry points: a wave-scheduled `TileProgram` brackets
    /// each wave of mutually independent instructions with
    /// `wave_begin(index, len)` / `wave_end()`.  Execution inside a wave
    /// stays sequential — the hooks exist so pricing backends
    /// (`accel::sim::cycle::CycleBackend`) can cost a wave as `max` over
    /// its members, the PE-array parallelism analog.  Default: no-ops.
    fn wave_begin(&self, _wave: usize, _steps: usize) {}
    fn wave_end(&self) {}

    /// Inter-fabric link hooks: a sharded program's `SendActivation` /
    /// `RecvActivation` steps call these when the replay crosses a shard
    /// boundary (`bytes` of activation over cut `boundary`).  The data
    /// itself moves through [`FabricBackend::fetch`] on the sending
    /// fabric and the peer replay's input on the receiving one, so
    /// numeric backends need nothing here; pricing backends
    /// (`accel::sim::cycle::CycleBackend`) charge the link's bandwidth
    /// and count the hop.  Defaults: no-ops.
    fn link_send(&self, _bytes: usize, _boundary: usize) {}
    fn link_recv(&self, _bytes: usize, _boundary: usize) {}
}

impl FabricBackend for Executor {
    type Buf = DeviceTensor;

    fn upload(&self, t: &Tensor) -> anyhow::Result<DeviceTensor> {
        self.to_device(t)
    }

    fn dispatch(
        &self,
        artifact: &str,
        inputs: &[&DeviceTensor],
        out_shape: &[usize],
    ) -> anyhow::Result<DeviceTensor> {
        let out = self.run_dev(artifact, inputs)?;
        if out.shape != out_shape {
            anyhow::bail!(
                "artifact '{artifact}' produced shape {:?} but the program recorded {:?} \
                 (program built against a different artifact set?)",
                out.shape,
                out_shape
            );
        }
        Ok(out)
    }

    fn fetch(&self, buf: &DeviceTensor) -> anyhow::Result<Tensor> {
        Executor::fetch(self, buf)
    }

    /// Zero buffers come from the executor's device pool: one immutable
    /// upload per shape for the process lifetime, shared by every
    /// topology's runtime tensor set.
    fn upload_zeros(&self, shape: &[usize]) -> anyhow::Result<DeviceTensor> {
        self.shared_zeros(shape)
    }
}
