//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`, lowered
//! once by `python/compile/aot.py`) and executes them on the CPU PJRT
//! client via the `xla` crate.  Python is never on this path.
//!
//! Interchange is HLO **text**: `HloModuleProto::from_text_file` re-parses
//! and re-numbers instruction ids, which is what makes jax ≥ 0.5 output
//! loadable by xla_extension 0.5.1 (see /opt/xla-example/README.md and
//! DESIGN.md).
//!
//! * [`artifact`] — manifest parsing, artifact inventory, staleness check.
//! * [`executor`] — compile-once executable cache + typed execution.
//! * [`backend`] — the [`FabricBackend`] substrate trait a lowered
//!   `TileProgram` replays against (PJRT here; the cycle model in
//!   `accel::sim::cycle`).

pub mod artifact;
pub mod backend;
pub mod executor;
pub mod pool;

pub use artifact::{ArtifactMeta, Manifest};
pub use backend::FabricBackend;
pub use executor::{DeviceTensor, Executor, Tensor};
pub use pool::TensorPool;

/// Default artifact directory relative to the repo root.
pub fn default_artifact_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
}

/// Whether the AOT artifact set has been generated (`make artifacts`).
/// Artifact-dependent tests skip themselves when it is absent so the
/// rust suite stays green without the Python lowering step (the CI job
/// relies on this).
pub fn artifacts_available() -> bool {
    default_artifact_dir().join("manifest.json").is_file()
}

/// Skip the current test when the AOT artifact set is absent.  Used by
/// every artifact-dependent test (unit and integration) so the skip
/// condition and message live in exactly one place.
#[macro_export]
macro_rules! require_artifacts {
    () => {
        if !$crate::runtime::artifacts_available() {
            eprintln!("skipping: artifacts/ not present (run `make artifacts`)");
            return;
        }
    };
}
