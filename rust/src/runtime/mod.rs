//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`, lowered
//! once by `python/compile/aot.py`) and executes them on the CPU PJRT
//! client via the `xla` crate.  Python is never on this path.
//!
//! Interchange is HLO **text**: `HloModuleProto::from_text_file` re-parses
//! and re-numbers instruction ids, which is what makes jax ≥ 0.5 output
//! loadable by xla_extension 0.5.1 (see /opt/xla-example/README.md and
//! DESIGN.md).
//!
//! * [`artifact`] — manifest parsing, artifact inventory, staleness check.
//! * [`executor`] — compile-once executable cache + typed execution.

pub mod artifact;
pub mod executor;

pub use artifact::{ArtifactMeta, Manifest};
pub use executor::{DeviceTensor, Executor, Tensor};

/// Default artifact directory relative to the repo root.
pub fn default_artifact_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
}
