//! Serving metrics — the substrate's AXI-timer (§4): per-request compute,
//! queue-wait and end-to-end latency, batch sizes, failures, throughput.
//!
//! One `Metrics` instance accumulates per fabric; the pool dispatcher
//! merges them into an aggregate whose `per_fabric` field keeps the
//! per-fabric breakdown for the report.

use std::time::Duration;

use super::api::Priority;
use crate::util::stats::{summarize, Summary};

/// Accumulated serving metrics (one fabric, or the pool aggregate).
#[derive(Debug, Default, Clone)]
pub struct Metrics {
    /// Which fabric these numbers belong to; `None` for the aggregate.
    pub fabric: Option<usize>,
    /// End-to-end request latencies (queue wait + compute), seconds.
    /// A **generation** is one request: it contributes a single sample
    /// here (its whole prefill + N steps), deliberately — throughput and
    /// failure accounting stay uniform across request kinds — while the
    /// `prefills`/`decode_steps` samples below break that one number
    /// down.  Read those (not this mixed histogram) when comparing
    /// encode vs generation latency shapes.
    pub latencies: Vec<f64>,
    /// Compute component (time on the fabric proper), seconds.
    pub computes: Vec<f64>,
    /// Queue-wait component (submit → start of execution, including
    /// in-batch wait behind earlier members), seconds.
    pub queue_waits: Vec<f64>,
    /// Batch sizes drained — recorded only for batches that were actually
    /// served (prepared model, registers programmed).
    pub batch_sizes: Vec<usize>,
    /// Generation prefill times (source encode + prompt prefill),
    /// seconds — recorded only for generations that **succeeded**, so a
    /// failed generation never pollutes the latency samples.
    pub prefills: Vec<f64>,
    /// Per-token decode-step times, seconds (each generation contributes
    /// `steps - 1` samples) — success-only, like `prefills`.  Under
    /// continuous batching this is the **inter-token latency**: the gap
    /// between a sequence's consecutive tokens includes the decode
    /// steps the scheduler ran for other live sequences in between.
    pub decode_steps: Vec<f64>,
    /// Time-to-first-token samples, seconds: submission → the first
    /// streamed `TokenEvent` (admission wait + prefill).  Success-only,
    /// like `prefills` — together with `decode_steps` this is the
    /// TTFT vs inter-token split continuous batching trades on.
    pub ttfts: Vec<f64>,
    /// Completed generations.
    pub generations: u64,
    /// Generation sequences admitted into a fabric's live set (each
    /// admission = one prefill executed under the per-round budget).
    pub admitted: u64,
    /// Continuous-batching scheduler rounds executed (each round runs
    /// one decode step per live sequence).
    pub decode_rounds: u64,
    /// Peak concurrently in-flight generation sequences observed on one
    /// fabric (aggregate: max across fabrics, not a sum — fabrics hold
    /// separate live sets).
    pub live_peak: u64,
    /// Register reprogramming events (model switches on the fabric).
    pub reprograms: u64,
    /// Full weight-stack uploads (`prepare_model` runs) — under the
    /// residency manager a model switch whose stack is still resident
    /// reprograms registers *without* re-uploading, so
    /// `reprograms - weight_uploads` is the traffic the cache saved.
    pub weight_uploads: u64,
    /// Acquires served from an already-resident weight stack.
    pub residency_hits: u64,
    /// Weight stacks evicted to make room for an incoming model.
    pub residency_evictions: u64,
    /// High-water mark of device-resident weight bytes on one fabric
    /// (aggregate: max across fabrics — each fabric has its own weight
    /// memory).  Exceeds the configured capacity only when in-flight
    /// pinning forced an over-budget admission.
    pub resident_bytes_peak: u64,
    /// Stacks uploaded off the dispatch path because a hot model's queue
    /// deepened (the residency prefetch trigger).
    pub prefetches: u64,
    /// Inter-fabric activation handoffs forwarded by shard-chain stages
    /// (one per activation a stage sent downstream; aggregate: sum —
    /// every hop crosses a link exactly once).
    pub activation_hops: u64,
    /// Activation bytes those handoffs moved across fabric links
    /// (aggregate: sum, like `activation_hops`).
    pub interfabric_bytes: u64,
    /// Largest single shard weight stack made device-resident on one
    /// fabric by the sharded serving path (aggregate: **max** across
    /// fabrics — each fabric homes its own shard, so the pool-wide
    /// figure is the worst per-fabric footprint, not a total).
    pub shard_resident_bytes_peak: u64,
    /// Requests that failed (programming errors, execution errors).
    pub failed: u64,
    /// Requests stopped short of completion without failing: an
    /// explicit `ServeError::Cancelled` (while queued or between decode
    /// steps), or a generation abandoned mid-flight because its
    /// `JobHandle` was dropped.  A cancelled/abandoned generation
    /// records no latency/prefill/step samples (no partial generation
    /// pollutes the summaries).
    pub cancelled: u64,
    /// Requests rejected with `ServeError::DeadlineExceeded` because
    /// their QoS deadline passed before they started executing.
    pub expired: u64,
    /// Sum of **live** rows actually requested across served encode /
    /// prefill requests (the request's true length).
    pub actual_rows: u64,
    /// Sum of rows the fabric was dispatched at for those same requests
    /// (the covering length bucket).  `padded_rows - actual_rows` is the
    /// padding the length-adaptive schedule recovered vs. always running
    /// at `seq_len`; the residual ratio is what bucketing still wastes.
    pub padded_rows: u64,
    /// Successfully served requests per [`Priority`] class, indexed by
    /// [`Priority::index`] (low, normal, high).
    pub by_priority: [u64; 3],
    /// Total wall time observed, seconds.
    pub elapsed: f64,
    /// Per-fabric breakdown (aggregate only; empty on a fabric's own
    /// metrics).
    pub per_fabric: Vec<Metrics>,
}

impl Metrics {
    /// Fresh metrics tagged with a fabric id.
    pub fn for_fabric(id: usize) -> Self {
        Metrics { fabric: Some(id), ..Metrics::default() }
    }

    /// Record one successfully served request.
    pub fn record(&mut self, compute: Duration, queue_wait: Duration, end_to_end: Duration) {
        self.computes.push(compute.as_secs_f64());
        self.queue_waits.push(queue_wait.as_secs_f64());
        self.latencies.push(end_to_end.as_secs_f64());
    }

    pub fn record_batch(&mut self, size: usize) {
        self.batch_sizes.push(size);
    }

    /// Count one successfully served request against its QoS class.
    pub fn record_priority(&mut self, p: Priority) {
        self.by_priority[p.index()] += 1;
    }

    /// Served requests of one QoS class.
    pub fn served_at(&self, p: Priority) -> u64 {
        self.by_priority[p.index()]
    }

    /// Record one **successful** generation's timing split.  Callers must
    /// not invoke this on failure — the failure path only bumps `failed`,
    /// keeping the prefill/per-token summaries clean.
    pub fn record_generation(&mut self, prefill: Duration, steps: &[Duration]) {
        self.generations += 1;
        self.prefills.push(prefill.as_secs_f64());
        self.decode_steps.extend(steps.iter().map(|d| d.as_secs_f64()));
    }

    /// Record one request's length-adaptive padding split: `actual` live
    /// rows dispatched inside a `padded`-row bucket.
    pub fn record_rows(&mut self, actual: usize, padded: usize) {
        self.actual_rows += actual as u64;
        self.padded_rows += padded as u64;
    }

    /// Fraction of dispatched rows that were bucket padding, 0.0 when no
    /// row counts were recorded.
    pub fn padding_waste(&self) -> f64 {
        if self.padded_rows == 0 {
            0.0
        } else {
            1.0 - self.actual_rows as f64 / self.padded_rows as f64
        }
    }

    /// Record a **successful** generation's time-to-first-token
    /// (submission → first streamed token).  Success-only, like
    /// [`Self::record_generation`].
    pub fn record_ttft(&mut self, ttft: Duration) {
        self.ttfts.push(ttft.as_secs_f64());
    }

    /// Prefill-time summary (None until a generation succeeded).
    pub fn prefill_summary(&self) -> Option<Summary> {
        (!self.prefills.is_empty()).then(|| summarize(&self.prefills))
    }

    /// Time-to-first-token summary (None until a generation succeeded).
    pub fn ttft_summary(&self) -> Option<Summary> {
        (!self.ttfts.is_empty()).then(|| summarize(&self.ttfts))
    }

    /// Per-token decode-step summary.
    pub fn step_summary(&self) -> Option<Summary> {
        (!self.decode_steps.is_empty()).then(|| summarize(&self.decode_steps))
    }

    /// Successfully served requests.
    pub fn requests(&self) -> usize {
        self.latencies.len()
    }

    /// End-to-end latency summary.
    pub fn latency_summary(&self) -> Option<Summary> {
        (!self.latencies.is_empty()).then(|| summarize(&self.latencies))
    }

    /// Compute-only latency summary.
    pub fn compute_summary(&self) -> Option<Summary> {
        (!self.computes.is_empty()).then(|| summarize(&self.computes))
    }

    /// Queue-wait summary.
    pub fn queue_summary(&self) -> Option<Summary> {
        (!self.queue_waits.is_empty()).then(|| summarize(&self.queue_waits))
    }

    pub fn throughput_rps(&self) -> f64 {
        if self.elapsed > 0.0 {
            self.requests() as f64 / self.elapsed
        } else {
            0.0
        }
    }

    pub fn mean_batch(&self) -> f64 {
        if self.batch_sizes.is_empty() {
            0.0
        } else {
            self.batch_sizes.iter().sum::<usize>() as f64 / self.batch_sizes.len() as f64
        }
    }

    /// Reprograms amortized over served requests (the affinity scheduler's
    /// figure of merit: lower = fewer register writes per inference).
    pub fn reprograms_per_request(&self) -> f64 {
        if self.requests() == 0 {
            0.0
        } else {
            self.reprograms as f64 / self.requests() as f64
        }
    }

    /// Fold another fabric's numbers into this one (samples are appended,
    /// counters added, elapsed takes the max — fabrics run concurrently).
    pub fn merge(&mut self, other: &Metrics) {
        self.latencies.extend_from_slice(&other.latencies);
        self.computes.extend_from_slice(&other.computes);
        self.queue_waits.extend_from_slice(&other.queue_waits);
        self.batch_sizes.extend_from_slice(&other.batch_sizes);
        self.prefills.extend_from_slice(&other.prefills);
        self.decode_steps.extend_from_slice(&other.decode_steps);
        self.ttfts.extend_from_slice(&other.ttfts);
        self.generations += other.generations;
        self.admitted += other.admitted;
        self.decode_rounds += other.decode_rounds;
        self.live_peak = self.live_peak.max(other.live_peak);
        self.reprograms += other.reprograms;
        self.weight_uploads += other.weight_uploads;
        self.residency_hits += other.residency_hits;
        self.residency_evictions += other.residency_evictions;
        self.resident_bytes_peak = self.resident_bytes_peak.max(other.resident_bytes_peak);
        self.prefetches += other.prefetches;
        self.activation_hops += other.activation_hops;
        self.interfabric_bytes += other.interfabric_bytes;
        self.shard_resident_bytes_peak =
            self.shard_resident_bytes_peak.max(other.shard_resident_bytes_peak);
        self.failed += other.failed;
        self.cancelled += other.cancelled;
        self.expired += other.expired;
        self.actual_rows += other.actual_rows;
        self.padded_rows += other.padded_rows;
        for (mine, theirs) in self.by_priority.iter_mut().zip(other.by_priority) {
            *mine += theirs;
        }
        self.elapsed = self.elapsed.max(other.elapsed);
    }

    /// Build the pool aggregate from per-fabric metrics, keeping the
    /// breakdown.
    pub fn aggregate(per_fabric: Vec<Metrics>) -> Metrics {
        let mut agg = Metrics::default();
        for m in &per_fabric {
            agg.merge(m);
        }
        agg.per_fabric = per_fabric;
        agg
    }

    /// Human-readable report block.
    pub fn report(&self) -> String {
        let mut out = match self.latency_summary() {
            None => {
                let mut s = "no requests served\n".to_string();
                if self.failed > 0 {
                    s.push_str(&format!("failed: {}\n", self.failed));
                }
                if self.cancelled > 0 {
                    s.push_str(&format!("cancelled: {}\n", self.cancelled));
                }
                if self.expired > 0 {
                    s.push_str(&format!("deadline-expired: {}\n", self.expired));
                }
                return s;
            }
            Some(s) => format!(
                "requests: {} (failed: {})\nthroughput: {:.2} req/s\ne2e ms: p50={:.2} p95={:.2} mean={:.2} max={:.2}\n",
                self.requests(),
                self.failed,
                self.throughput_rps(),
                s.p50 * 1e3,
                s.p95 * 1e3,
                s.mean * 1e3,
                s.max * 1e3,
            ),
        };
        if let Some(c) = self.compute_summary() {
            out.push_str(&format!(
                "compute ms: p50={:.2} p95={:.2} mean={:.2}\n",
                c.p50 * 1e3,
                c.p95 * 1e3,
                c.mean * 1e3
            ));
        }
        if let Some(q) = self.queue_summary() {
            out.push_str(&format!(
                "queue ms: p50={:.2} p95={:.2} mean={:.2}\n",
                q.p50 * 1e3,
                q.p95 * 1e3,
                q.mean * 1e3
            ));
        }
        if let Some(p) = self.prefill_summary() {
            out.push_str(&format!(
                "generations: {} | prefill ms: p50={:.2} p95={:.2} mean={:.2}\n",
                self.generations,
                p.p50 * 1e3,
                p.p95 * 1e3,
                p.mean * 1e3
            ));
        }
        if let Some(s) = self.step_summary() {
            out.push_str(&format!(
                "decode-step ms ({} tokens): p50={:.2} p95={:.2} mean={:.2}\n",
                self.decode_steps.len(),
                s.p50 * 1e3,
                s.p95 * 1e3,
                s.mean * 1e3
            ));
        }
        if let Some(t) = self.ttft_summary() {
            out.push_str(&format!(
                "time-to-first-token ms: p50={:.2} p95={:.2} mean={:.2}\n",
                t.p50 * 1e3,
                t.p95 * 1e3,
                t.mean * 1e3
            ));
        }
        if self.admitted > 0 {
            out.push_str(&format!(
                "continuous batching: {} admitted, {} decode rounds, in-flight peak {}\n",
                self.admitted, self.decode_rounds, self.live_peak
            ));
        }
        out.push_str(&format!(
            "mean batch: {:.2}\nreprograms: {} ({:.3} per request)\n",
            self.mean_batch(),
            self.reprograms,
            self.reprograms_per_request(),
        ));
        if self.weight_uploads > 0 || self.residency_hits > 0 {
            out.push_str(&format!(
                "weight residency: {} uploads, {} hits, {} evictions, {} prefetches, peak {} bytes\n",
                self.weight_uploads,
                self.residency_hits,
                self.residency_evictions,
                self.prefetches,
                self.resident_bytes_peak,
            ));
        }
        if self.activation_hops > 0 || self.shard_resident_bytes_peak > 0 {
            out.push_str(&format!(
                "shard chain: {} activation hops, {} inter-fabric bytes, shard peak {} bytes\n",
                self.activation_hops, self.interfabric_bytes, self.shard_resident_bytes_peak,
            ));
        }
        out.push_str(&format!(
            "priority served: high={} normal={} low={}\n",
            self.served_at(Priority::High),
            self.served_at(Priority::Normal),
            self.served_at(Priority::Low),
        ));
        if self.cancelled > 0 || self.expired > 0 {
            out.push_str(&format!(
                "cancelled: {} | deadline-expired: {}\n",
                self.cancelled, self.expired
            ));
        }
        if self.padded_rows > 0 {
            out.push_str(&format!(
                "rows: {} live / {} dispatched (padding waste {:.1}%)\n",
                self.actual_rows,
                self.padded_rows,
                self.padding_waste() * 100.0,
            ));
        }
        for f in &self.per_fabric {
            out.push_str(&format!(
                "  fabric {}: {} served, {} failed, {} reprograms, {:.2} req/s\n",
                f.fabric.map(|i| i.to_string()).unwrap_or_else(|| "?".into()),
                f.requests(),
                f.failed,
                f.reprograms,
                f.throughput_rps(),
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_summarize() {
        let mut m = Metrics::default();
        for i in 1..=10 {
            m.record(
                Duration::from_millis(i * 9),
                Duration::from_millis(i),
                Duration::from_millis(i * 10),
            );
        }
        m.record_batch(4);
        m.record_batch(2);
        m.elapsed = 1.0;
        assert_eq!(m.requests(), 10);
        assert_eq!(m.throughput_rps(), 10.0);
        assert_eq!(m.mean_batch(), 3.0);
        let s = m.latency_summary().unwrap();
        assert!(s.p50 >= 0.05 && s.p50 <= 0.06);
        let c = m.compute_summary().unwrap();
        let q = m.queue_summary().unwrap();
        // compute + queue == e2e by construction of the samples
        assert!((c.mean + q.mean - s.mean).abs() < 1e-9);
        assert!(m.report().contains("requests: 10"));
    }

    #[test]
    fn empty_metrics_report() {
        let m = Metrics::default();
        assert_eq!(m.report(), "no requests served\n");
        assert!(m.latency_summary().is_none());
        assert!(m.compute_summary().is_none());
    }

    #[test]
    fn merge_appends_samples_and_adds_counters() {
        let mut a = Metrics::for_fabric(0);
        a.record(Duration::from_millis(5), Duration::from_millis(1), Duration::from_millis(6));
        a.reprograms = 2;
        a.failed = 1;
        a.elapsed = 1.0;
        let mut b = Metrics::for_fabric(1);
        b.record(Duration::from_millis(7), Duration::from_millis(2), Duration::from_millis(9));
        b.record(Duration::from_millis(7), Duration::from_millis(2), Duration::from_millis(9));
        b.reprograms = 1;
        b.elapsed = 2.0;
        let agg = Metrics::aggregate(vec![a, b]);
        assert_eq!(agg.requests(), 3);
        assert_eq!(agg.reprograms, 3);
        assert_eq!(agg.failed, 1);
        assert_eq!(agg.elapsed, 2.0);
        assert_eq!(agg.per_fabric.len(), 2);
        assert_eq!(agg.per_fabric[0].fabric, Some(0));
        assert!(agg.report().contains("fabric 1"));
    }

    #[test]
    fn generation_split_merges_and_failures_stay_out_of_the_samples() {
        let mut a = Metrics::for_fabric(0);
        a.record_generation(
            Duration::from_millis(20),
            &[Duration::from_millis(2), Duration::from_millis(3)],
        );
        // A failed generation takes the failure path only: no
        // record_generation call, just the failure counter — the satellite
        // invariant that failures never pollute the latency samples.
        a.failed += 1;
        let mut b = Metrics::for_fabric(1);
        b.record_generation(Duration::from_millis(40), &[Duration::from_millis(4)]);
        let agg = Metrics::aggregate(vec![a, b]);
        assert_eq!(agg.generations, 2);
        assert_eq!(agg.failed, 1);
        assert_eq!(agg.prefills.len(), 2, "one prefill sample per SUCCESSFUL generation");
        assert_eq!(agg.decode_steps.len(), 3);
        let p = agg.prefill_summary().unwrap();
        assert!((p.mean - 0.030).abs() < 1e-9);
        let s = agg.step_summary().unwrap();
        assert!((s.mean - 0.003).abs() < 1e-9);
        let rep = agg.report();
        // record_generation only adds the prefill/step breakdown; the
        // serving loop separately records the generation's single e2e
        // sample via record() — so breakdown-only metrics report empty.
        assert!(rep.contains("no requests served"), "{rep}");
    }

    #[test]
    fn generation_summaries_render_in_the_report() {
        let mut m = Metrics::default();
        m.record(Duration::from_millis(9), Duration::from_millis(1), Duration::from_millis(10));
        m.record_generation(Duration::from_millis(20), &[Duration::from_millis(2)]);
        m.elapsed = 1.0;
        let rep = m.report();
        assert!(rep.contains("generations: 1"), "{rep}");
        assert!(rep.contains("decode-step ms (1 tokens)"), "{rep}");
        // empty metrics render no generation lines
        assert!(!Metrics::default().report().contains("prefill"));
    }

    #[test]
    fn qos_counters_merge_and_render() {
        let mut a = Metrics::for_fabric(0);
        a.record(Duration::from_millis(1), Duration::ZERO, Duration::from_millis(1));
        a.record_priority(Priority::High);
        a.cancelled = 1;
        let mut b = Metrics::for_fabric(1);
        b.record(Duration::from_millis(1), Duration::ZERO, Duration::from_millis(1));
        b.record(Duration::from_millis(1), Duration::ZERO, Duration::from_millis(1));
        b.record_priority(Priority::Normal);
        b.record_priority(Priority::High);
        b.expired = 2;
        let agg = Metrics::aggregate(vec![a, b]);
        assert_eq!(agg.served_at(Priority::High), 2);
        assert_eq!(agg.served_at(Priority::Normal), 1);
        assert_eq!(agg.served_at(Priority::Low), 0);
        assert_eq!(agg.cancelled, 1);
        assert_eq!(agg.expired, 2);
        let rep = agg.report();
        assert!(rep.contains("priority served: high=2 normal=1 low=0"), "{rep}");
        assert!(rep.contains("cancelled: 1 | deadline-expired: 2"), "{rep}");
        // a clean run renders no cancellation noise
        let mut clean = Metrics::default();
        clean.record(Duration::from_millis(1), Duration::ZERO, Duration::from_millis(1));
        assert!(!clean.report().contains("cancelled"));
    }

    #[test]
    fn continuous_batching_counters_merge_and_render() {
        let mut a = Metrics::for_fabric(0);
        a.record(Duration::from_millis(9), Duration::from_millis(1), Duration::from_millis(10));
        a.record_ttft(Duration::from_millis(10));
        a.admitted = 3;
        a.decode_rounds = 12;
        a.live_peak = 3;
        let mut b = Metrics::for_fabric(1);
        b.record_ttft(Duration::from_millis(30));
        b.admitted = 1;
        b.decode_rounds = 4;
        b.live_peak = 1;
        let agg = Metrics::aggregate(vec![a, b]);
        assert_eq!(agg.admitted, 4, "admissions add across fabrics");
        assert_eq!(agg.decode_rounds, 16);
        assert_eq!(agg.live_peak, 3, "in-flight peak is a max, fabrics hold separate live sets");
        assert_eq!(agg.ttfts.len(), 2);
        let t = agg.ttft_summary().unwrap();
        assert!((t.mean - 0.020).abs() < 1e-9);
        let rep = agg.report();
        assert!(rep.contains("time-to-first-token ms"), "{rep}");
        assert!(rep.contains("continuous batching: 4 admitted, 16 decode rounds, in-flight peak 3"), "{rep}");
        // encode-only runs render no continuous-batching noise
        let mut clean = Metrics::default();
        clean.record(Duration::from_millis(1), Duration::ZERO, Duration::from_millis(1));
        assert!(!clean.report().contains("continuous batching"));
        assert!(clean.ttft_summary().is_none());
    }

    #[test]
    fn padding_rows_merge_and_render_the_waste_ratio() {
        let mut a = Metrics::for_fabric(0);
        a.record(Duration::from_millis(1), Duration::ZERO, Duration::from_millis(1));
        a.record_rows(10, 16); // 10 live rows dispatched in a 16-row bucket
        let mut b = Metrics::for_fabric(1);
        b.record_rows(50, 64);
        let agg = Metrics::aggregate(vec![a, b]);
        assert_eq!(agg.actual_rows, 60);
        assert_eq!(agg.padded_rows, 80);
        assert!((agg.padding_waste() - 0.25).abs() < 1e-12);
        let rep = agg.report();
        assert!(rep.contains("rows: 60 live / 80 dispatched (padding waste 25.0%)"), "{rep}");
        // runs with no row accounting render no padding line
        let mut clean = Metrics::default();
        clean.record(Duration::from_millis(1), Duration::ZERO, Duration::from_millis(1));
        assert!(!clean.report().contains("padding"));
        assert_eq!(clean.padding_waste(), 0.0);
    }

    #[test]
    fn residency_counters_merge_and_render() {
        let mut a = Metrics::for_fabric(0);
        a.record(Duration::from_millis(1), Duration::ZERO, Duration::from_millis(1));
        a.weight_uploads = 2;
        a.residency_hits = 5;
        a.residency_evictions = 1;
        a.resident_bytes_peak = 4096;
        let mut b = Metrics::for_fabric(1);
        b.weight_uploads = 1;
        b.residency_hits = 3;
        b.resident_bytes_peak = 9000;
        b.prefetches = 1;
        let agg = Metrics::aggregate(vec![a, b]);
        assert_eq!(agg.weight_uploads, 3);
        assert_eq!(agg.residency_hits, 8);
        assert_eq!(agg.residency_evictions, 1);
        assert_eq!(agg.prefetches, 1);
        assert_eq!(
            agg.resident_bytes_peak, 9000,
            "peak is a max, fabrics have separate weight memories"
        );
        let rep = agg.report();
        assert!(
            rep.contains("weight residency: 3 uploads, 8 hits, 1 evictions"),
            "{rep}"
        );
        assert!(rep.contains("1 prefetches, peak 9000 bytes"), "{rep}");
        // runs that never touched the residency path render no line
        let mut clean = Metrics::default();
        clean.record(Duration::from_millis(1), Duration::ZERO, Duration::from_millis(1));
        assert!(!clean.report().contains("weight residency"));
    }

    #[test]
    fn shard_counters_merge_sums_traffic_and_maxes_the_peak() {
        // A 2-shard chain over fabrics 0 and 1: the head forwards every
        // activation (hops and bytes are per-link traffic, so they ADD
        // across fabrics), while each fabric homes a different shard
        // stack (the pool-wide shard footprint is a MAX, not a sum).
        let mut head = Metrics::for_fabric(0);
        head.activation_hops = 3;
        head.interfabric_bytes = 3 * 4096;
        head.shard_resident_bytes_peak = 2_000_000;
        let mut tail = Metrics::for_fabric(1);
        tail.record(Duration::from_millis(1), Duration::ZERO, Duration::from_millis(1));
        tail.shard_resident_bytes_peak = 3_000_000;
        let agg = Metrics::aggregate(vec![head, tail]);
        assert_eq!(agg.activation_hops, 3, "hops add: each crosses one link once");
        assert_eq!(agg.interfabric_bytes, 3 * 4096, "link bytes add like hops");
        assert_eq!(
            agg.shard_resident_bytes_peak, 3_000_000,
            "shard peak is a max: fabrics home different shards in separate memories"
        );
        let rep = agg.report();
        assert!(
            rep.contains("shard chain: 3 activation hops, 12288 inter-fabric bytes"),
            "{rep}"
        );
        assert!(rep.contains("shard peak 3000000 bytes"), "{rep}");
    }

    #[test]
    fn shard_counters_stay_silent_and_zero_on_unsharded_pools() {
        // Merging unsharded fabrics leaves every shard counter at zero
        // and keeps the report free of shard noise.
        let mut a = Metrics::for_fabric(0);
        a.record(Duration::from_millis(1), Duration::ZERO, Duration::from_millis(1));
        let b = Metrics::for_fabric(1);
        let agg = Metrics::aggregate(vec![a, b]);
        assert_eq!(agg.activation_hops, 0);
        assert_eq!(agg.interfabric_bytes, 0);
        assert_eq!(agg.shard_resident_bytes_peak, 0);
        assert!(!agg.report().contains("shard chain"), "{}", agg.report());
        // A tail-only chain fabric (receives but never forwards) still
        // renders: the resident shard peak alone must surface the line.
        let mut tail = Metrics::for_fabric(2);
        tail.record(Duration::from_millis(1), Duration::ZERO, Duration::from_millis(1));
        tail.shard_resident_bytes_peak = 7;
        assert!(tail.report().contains("shard peak 7 bytes"), "{}", tail.report());
    }

    #[test]
    fn reprograms_per_request_is_amortized() {
        let mut m = Metrics::default();
        assert_eq!(m.reprograms_per_request(), 0.0);
        for _ in 0..4 {
            m.record(Duration::from_millis(1), Duration::ZERO, Duration::from_millis(1));
        }
        m.reprograms = 2;
        assert!((m.reprograms_per_request() - 0.5).abs() < 1e-12);
    }
}
