//! Serving metrics — the substrate's AXI-timer (§4): per-request latency,
//! queue wait, batch sizes, throughput.

use std::time::Duration;

use crate::util::stats::{summarize, Summary};

/// Accumulated serving metrics.
#[derive(Debug, Default, Clone)]
pub struct Metrics {
    /// End-to-end request latencies, seconds.
    pub latencies: Vec<f64>,
    /// Queue-wait component, seconds.
    pub queue_waits: Vec<f64>,
    /// Batch sizes drained.
    pub batch_sizes: Vec<usize>,
    /// Register reprogramming events (model switches on the fabric).
    pub reprograms: u64,
    /// Total wall time observed, seconds.
    pub elapsed: f64,
}

impl Metrics {
    pub fn record(&mut self, latency: Duration, queue_wait: Duration) {
        self.latencies.push(latency.as_secs_f64());
        self.queue_waits.push(queue_wait.as_secs_f64());
    }

    pub fn record_batch(&mut self, size: usize) {
        self.batch_sizes.push(size);
    }

    pub fn requests(&self) -> usize {
        self.latencies.len()
    }

    pub fn latency_summary(&self) -> Option<Summary> {
        (!self.latencies.is_empty()).then(|| summarize(&self.latencies))
    }

    pub fn throughput_rps(&self) -> f64 {
        if self.elapsed > 0.0 {
            self.requests() as f64 / self.elapsed
        } else {
            0.0
        }
    }

    pub fn mean_batch(&self) -> f64 {
        if self.batch_sizes.is_empty() {
            0.0
        } else {
            self.batch_sizes.iter().sum::<usize>() as f64 / self.batch_sizes.len() as f64
        }
    }

    /// Human-readable report block (EXPERIMENTS.md format).
    pub fn report(&self) -> String {
        match self.latency_summary() {
            None => "no requests served\n".to_string(),
            Some(s) => format!(
                "requests: {}\nthroughput: {:.2} req/s\nlatency ms: p50={:.2} p95={:.2} mean={:.2} max={:.2}\nmean batch: {:.2}\nreprograms: {}\n",
                self.requests(),
                self.throughput_rps(),
                s.p50 * 1e3,
                s.p95 * 1e3,
                s.mean * 1e3,
                s.max * 1e3,
                self.mean_batch(),
                self.reprograms,
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_summarize() {
        let mut m = Metrics::default();
        for i in 1..=10 {
            m.record(Duration::from_millis(i * 10), Duration::from_millis(i));
        }
        m.record_batch(4);
        m.record_batch(2);
        m.elapsed = 1.0;
        assert_eq!(m.requests(), 10);
        assert_eq!(m.throughput_rps(), 10.0);
        assert_eq!(m.mean_batch(), 3.0);
        let s = m.latency_summary().unwrap();
        assert!(s.p50 >= 0.05 && s.p50 <= 0.06);
        assert!(m.report().contains("requests: 10"));
    }

    #[test]
    fn empty_metrics_report() {
        let m = Metrics::default();
        assert_eq!(m.report(), "no requests served\n");
        assert!(m.latency_summary().is_none());
    }
}
