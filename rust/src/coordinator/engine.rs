//! The tile-schedule engine — ADAPTOR's fabric, numerically.
//!
//! Executes a transformer encoder exactly the way the hardware does
//! (Fig 2/3, Algorithms 1–17): fixed-shape processing modules (the AOT
//! tile primitives) are invoked over the tile schedules of §3.9, partial
//! sums accumulate across column tiles (Fig 4a) and 2-D tiles (Fig 4b),
//! and every *runtime* parameter (sequence length, heads, embedding and
//! hidden dims, layer count) arrives through the configuration register
//! file — changing them re-bounds these rust loops and rewrites masks,
//! and NEVER recompiles an artifact (the `compiled_count` probe in tests).
//!
//! Padding contract: all fabric buffers are sized for the synthesis maxima
//! (SL_MAX × DMODEL_MAX etc.); a smaller runtime topology occupies a
//! prefix, the attention mask and the LayerNorm dmask/count inputs fence
//! off the rest — the exact analog of the paper's BRAM buffers + loop
//! bounds from the `Sequence`/`Embeddings` registers.

use anyhow::{anyhow, bail, Context};

use crate::accel::registers::{RegisterFile, SynthMaxima};
use crate::model::weights::{LayerWeights, Mat};
use crate::model::TnnConfig;
use crate::runtime::{DeviceTensor, Executor, Tensor};

/// Attention execution mode: `Split` mirrors the paper's module chain
/// (QK_PM → softmax → SV_PM); `Fused` is the single-pass perf path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AttentionMode {
    Split,
    Fused,
}

/// One layer's weights, pre-tiled into fabric-shaped panels and parked
/// **device-resident** (§Perf iteration 2) — the substrate analog of the
/// paper's weights living in BRAM: uploaded once at prepare time, never
/// re-transferred on the request path.
struct PreparedLayer {
    /// Per head, per MHA tile: `TS_MHA × DK` panels of W_q/W_k/W_v.
    wq: Vec<Vec<DeviceTensor>>,
    wk: Vec<Vec<DeviceTensor>>,
    wv: Vec<Vec<DeviceTensor>>,
    bq: Vec<DeviceTensor>,
    bk: Vec<DeviceTensor>,
    bv: Vec<DeviceTensor>,
    /// FFN1 (output projection) `TS_FFN × TS_FFN` panels, [row][col].
    wo: Vec<Vec<DeviceTensor>>,
    bo: DeviceTensor,
    /// FFN2 `TS_FFN × FFN_COL` panels, [row][col].
    w1: Vec<Vec<DeviceTensor>>,
    b1: DeviceTensor,
    /// FFN3 `FFN_COL × TS_FFN` panels, [row][col].
    w2: Vec<Vec<DeviceTensor>>,
    b2: DeviceTensor,
    g1: DeviceTensor,
    b1n: DeviceTensor,
    g2: DeviceTensor,
    b2n: DeviceTensor,
    /// Per head, per MHA tile: packed `TS_MHA x 3*DK` panels holding the
    /// head's Q|K|V columns side by side (Algorithm 9's simultaneous
    /// MACs; §Perf iteration 3 — the 3*DK width is fabric-fixed, so every
    /// runtime topology uses all lanes).
    w_qkv_packed: Vec<Vec<DeviceTensor>>,
    b_qkv_packed: Vec<DeviceTensor>,
    /// Raw weights kept for the fused path.
    raw: LayerWeights,
}

/// Reusable zero accumulator buffers (one per accumulator shape).
struct ZeroAccs {
    dk: DeviceTensor,
    ffn: DeviceTensor,
    col: DeviceTensor,
    qkv3: DeviceTensor,
}

/// A registered model: topology + prepared weight stack.
pub struct PreparedStack {
    pub cfg: TnnConfig,
    layers: Vec<PreparedLayer>,
}

/// The engine: one PJRT executor ("the fabric") + the register file.
pub struct TileEngine {
    exec: Executor,
    pub registers: RegisterFile,
    pub mode: AttentionMode,
    /// Project a head's Q/K/V in one packed dispatch per tile
    /// (Algorithm 9's three-MACs-per-cycle structure; §Perf iteration 3).
    /// Perf-neutral on this substrate (kept as an ablation: 2.6x fewer
    /// dispatches, same wall time — see EXPERIMENTS.md §Perf), so the
    /// per-head schedule stays the default.
    pub qkv_packed: bool,
    /// Fully-quantized mode (§1: the paper's fabric is fixed-point): runs
    /// the int8 QDQ artifact on the attention output, mirroring
    /// `model.encoder_layer(quantized=True)`'s activation quantization.
    pub quantized: bool,
    // fabric constants (from the manifest = the synthesized shapes)
    sl_max: usize,
    dk: usize,
    ts_mha: usize,
    ts_ffn: usize,
    ffn_col: usize,
    dmodel_max: usize,
    hidden_max: usize,
}

impl TileEngine {
    pub fn new(artifact_dir: impl AsRef<std::path::Path>) -> anyhow::Result<Self> {
        let exec = Executor::new(artifact_dir)?;
        let m = exec.manifest();
        let maxima = m.synth_maxima();
        Ok(TileEngine {
            sl_max: m.sl_max,
            dk: m.dk,
            ts_mha: m.ts_mha,
            ts_ffn: m.ts_ffn,
            ffn_col: m.ffn_col,
            dmodel_max: m.dmodel_max,
            hidden_max: m.hidden_max,
            exec,
            registers: RegisterFile::new(maxima),
            mode: AttentionMode::Split,
            qkv_packed: false,
            quantized: false,
        })
    }

    pub fn executor(&self) -> &Executor {
        &self.exec
    }

    pub fn synth_maxima(&self) -> SynthMaxima {
        self.exec.manifest().synth_maxima()
    }

    /// Fabric divisibility constraints for the tile engine (the FPGA's
    /// equivalents are the tile sizes baked at synthesis).
    pub fn check_runtime_config(&self, cfg: &TnnConfig) -> anyhow::Result<()> {
        cfg.validate_for_execution().map_err(|e| anyhow!(e))?;
        if cfg.seq_len > self.sl_max {
            bail!("seq_len {} > fabric SL_MAX {}", cfg.seq_len, self.sl_max);
        }
        if cfg.dk() != self.dk {
            bail!("d_model/heads = {} but the fabric's head width is {}", cfg.dk(), self.dk);
        }
        if cfg.d_model % self.ts_ffn != 0 {
            bail!("d_model {} not a multiple of TS_FFN {}", cfg.d_model, self.ts_ffn);
        }
        if cfg.hidden != 4 * cfg.d_model {
            bail!("fabric FFN panels assume hidden = 4·d_model (got {})", cfg.hidden);
        }
        if cfg.d_model > self.dmodel_max || cfg.hidden > self.hidden_max {
            bail!("topology exceeds synthesis maxima");
        }
        Ok(())
    }

    /// Program the register file for `cfg` (Algorithm 18 step 3).
    pub fn program(&mut self, cfg: &TnnConfig) -> anyhow::Result<()> {
        self.check_runtime_config(cfg)?;
        self.registers.program(cfg).map_err(|e| anyhow!(e))
    }

    /// The topology currently held in the register file, or `None` before
    /// the first successful `program()` (the registers reset to all-zero,
    /// which is not a valid topology).
    pub fn programmed_config(&self) -> Option<TnnConfig> {
        let cfg = self.registers.current_config();
        cfg.validate().ok().map(|_| cfg)
    }

    /// Whether the register file already holds exactly `cfg` — i.e. a
    /// dispatch for this topology needs no reprogram.  The pool scheduler
    /// uses this to count (and the affinity policy to avoid) register
    /// writes; two registered models with identical topologies share one
    /// programming, exactly as on the hardware.
    pub fn is_programmed_for(&self, cfg: &TnnConfig) -> bool {
        self.registers.current_config() == *cfg
    }

    /// Pre-tile a weight stack for the fabric (Algorithm 18 steps 7–9:
    /// "load weight axi master interface buffers").
    pub fn prepare(&self, cfg: &TnnConfig, stack: &[LayerWeights]) -> anyhow::Result<PreparedStack> {
        self.check_runtime_config(cfg)?;
        if stack.len() != cfg.enc_layers {
            bail!("{} weight layers for {} encoder layers", stack.len(), cfg.enc_layers);
        }
        let layers = stack.iter().map(|w| self.prepare_layer(cfg, w)).collect::<Result<_, _>>()?;
        Ok(PreparedStack { cfg: *cfg, layers })
    }

    fn prepare_layer(&self, cfg: &TnnConfig, w: &LayerWeights) -> anyhow::Result<PreparedLayer> {
        let d = cfg.d_model;
        let h = cfg.heads;
        let t_m = d / self.ts_mha;
        let t_f = d / self.ts_ffn;
        let t_h = cfg.hidden / self.ffn_col;
        let panel = |m: &Mat, r0: usize, c0: usize, rows: usize, cols: usize| {
            self.exec.to_device(&Tensor::from_mat(&m.block(r0, c0, rows, cols)))
        };
        let vec_pad = |v: &[f32], n: usize| {
            let mut data = v.to_vec();
            data.resize(n, 0.0);
            self.exec.to_device(&Tensor::new(vec![n], data))
        };
        let head_tiles = |ws: &[Mat]| -> anyhow::Result<Vec<Vec<DeviceTensor>>> {
            (0..h)
                .map(|hh| {
                    (0..t_m)
                        .map(|t| panel(&ws[hh], t * self.ts_mha, 0, self.ts_mha, self.dk))
                        .collect()
                })
                .collect()
        };
        let grid = |m: &Mat, rows: usize, cols: usize, rstep: usize, cstep: usize| -> anyhow::Result<Vec<Vec<DeviceTensor>>> {
            (0..rows)
                .map(|r| (0..cols).map(|c| panel(m, r * rstep, c * cstep, rstep, cstep)).collect())
                .collect()
        };
        // Per-head packed Q|K|V weight panels: columns [0,3*DK) hold the
        // head's [Q | K | V] tile side by side.
        let dk3 = 3 * self.dk;
        let w_qkv_packed = (0..h)
            .map(|hh| {
                (0..t_m)
                    .map(|t| {
                        let mut panel = Mat::zeros(self.ts_mha, dk3);
                        for (blk, ws) in [(0, &w.wq), (1, &w.wk), (2, &w.wv)] {
                            let src = ws[hh].block(t * self.ts_mha, 0, self.ts_mha, self.dk);
                            panel.set_block(0, blk * self.dk, &src);
                        }
                        self.exec.to_device(&Tensor::from_mat(&panel))
                    })
                    .collect::<anyhow::Result<Vec<_>>>()
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        let b_qkv_packed = (0..h)
            .map(|hh| {
                let mut b = vec![0.0f32; dk3];
                for (blk, bs) in [(0usize, &w.bq), (1, &w.bk), (2, &w.bv)] {
                    b[blk * self.dk..(blk + 1) * self.dk].copy_from_slice(&bs[hh]);
                }
                self.exec.to_device(&Tensor::new(vec![dk3], b))
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(PreparedLayer {
            w_qkv_packed,
            b_qkv_packed,
            wq: head_tiles(&w.wq)?,
            wk: head_tiles(&w.wk)?,
            wv: head_tiles(&w.wv)?,
            bq: w.bq.iter().map(|b| self.exec.to_device(&Tensor::new(vec![self.dk], b.clone()))).collect::<anyhow::Result<_>>()?,
            bk: w.bk.iter().map(|b| self.exec.to_device(&Tensor::new(vec![self.dk], b.clone()))).collect::<anyhow::Result<_>>()?,
            bv: w.bv.iter().map(|b| self.exec.to_device(&Tensor::new(vec![self.dk], b.clone()))).collect::<anyhow::Result<_>>()?,
            wo: grid(&w.wo, t_f, t_f, self.ts_ffn, self.ts_ffn)?,
            bo: vec_pad(&w.bo, self.dmodel_max)?,
            w1: grid(&w.w1, t_f, t_h, self.ts_ffn, self.ffn_col)?,
            b1: vec_pad(&w.b1, self.hidden_max)?,
            w2: grid(&w.w2, t_h, t_f, self.ffn_col, self.ts_ffn)?,
            b2: vec_pad(&w.b2, self.dmodel_max)?,
            g1: vec_pad(&w.g1, self.dmodel_max)?,
            b1n: vec_pad(&w.b1n, self.dmodel_max)?,
            g2: vec_pad(&w.g2, self.dmodel_max)?,
            b2n: vec_pad(&w.b2n, self.dmodel_max)?,
            raw: w.clone(),
        })
    }

    /// Additive attention mask for the programmed sequence length.
    fn mask_tensor(&self, sl: usize, causal: bool) -> Tensor {
        let m = crate::model::reference::attention_mask(self.sl_max, sl, causal);
        Tensor::from_mat(&m)
    }

    /// Column panel `[SL_MAX, width]` of a padded `[SL_MAX, cols]` tensor.
    fn col_panel(&self, x: &Tensor, c0: usize, width: usize) -> Tensor {
        let cols = x.shape[1];
        let mut data = Vec::with_capacity(self.sl_max * width);
        for r in 0..self.sl_max {
            data.extend_from_slice(&x.data[r * cols + c0..r * cols + c0 + width]);
        }
        Tensor::new(vec![self.sl_max, width], data)
    }

    /// Write `src` `[SL_MAX, width]` into columns `c0..` of `dst`.
    fn set_col_panel(&self, dst: &mut Tensor, src: &Tensor, c0: usize) {
        let cols = dst.shape[1];
        let width = src.shape[1];
        for r in 0..self.sl_max {
            dst.data[r * cols + c0..r * cols + c0 + width]
                .copy_from_slice(&src.data[r * width..(r + 1) * width]);
        }
    }

    /// Run the full encoder stack on `input` (`seq_len × d_model`),
    /// returning `seq_len × d_model`.  This is the request-path entry.
    pub fn run_encoder(&self, stack: &PreparedStack, input: &Mat) -> anyhow::Result<Mat> {
        let cfg = &stack.cfg;
        if self.registers.current_config() != *cfg {
            bail!("register file is programmed for a different topology (Algorithm 18 step 3 first)");
        }
        if (input.rows, input.cols) != (cfg.seq_len, cfg.d_model) {
            bail!("input is {}x{}, registers say {}x{}", input.rows, input.cols, cfg.seq_len, cfg.d_model);
        }
        let d = cfg.d_model;
        // Load inputs into the (padded) input BRAM — Algorithm 1.
        let mut x = Tensor::from_mat(&input.padded(self.sl_max, self.dmodel_max));
        // Shared runtime-register-derived inputs, uploaded once per request
        // (these are what the `Sequence`/`Embeddings` registers change).
        let mask = self.exec.to_device(&self.mask_tensor(cfg.seq_len, false))?;
        let scale = self.exec.to_device(&Tensor::scalar1(1.0 / (self.dk as f32).sqrt()))?;
        let dmask = {
            let mut v = vec![0.0f32; self.dmodel_max];
            v[..d].fill(1.0);
            self.exec.to_device(&Tensor::new(vec![self.dmodel_max], v))?
        };
        let count = self.exec.to_device(&Tensor::scalar1(d as f32))?;
        // Reusable zero accumulators (inputs are never donated, so one
        // buffer per shape serves every chain start).
        let zeros = ZeroAccs {
            dk: self.exec.to_device(&Tensor::zeros(vec![self.sl_max, self.dk]))?,
            ffn: self.exec.to_device(&Tensor::zeros(vec![self.sl_max, self.ts_ffn]))?,
            col: self.exec.to_device(&Tensor::zeros(vec![self.sl_max, self.ffn_col]))?,
            qkv3: self.exec.to_device(&Tensor::zeros(vec![self.sl_max, 3 * self.dk]))?,
        };

        for layer in &stack.layers {
            x = self.run_layer(cfg, layer, &x, &mask, &scale, &dmask, &count, &zeros)?;
        }
        let full = x.to_mat();
        Ok(full.block(0, 0, cfg.seq_len, d))
    }

    /// One encoder layer over the tile schedules, device-resident
    /// throughout (§Perf iteration 2): weights never leave the device,
    /// accumulators chain buffer-to-buffer, and activations only cross the
    /// PJRT boundary at panel (re)assembly points.
    #[allow(clippy::too_many_arguments)]
    fn run_layer(
        &self,
        cfg: &TnnConfig,
        lw: &PreparedLayer,
        x: &Tensor,
        mask: &DeviceTensor,
        scale: &DeviceTensor,
        dmask: &DeviceTensor,
        count: &DeviceTensor,
        zeros: &ZeroAccs,
    ) -> anyhow::Result<Tensor> {
        let d = cfg.d_model;
        let t_m = d / self.ts_mha;
        let t_f = d / self.ts_ffn;
        let t_h = cfg.hidden / self.ffn_col;
        let x_dev = self.exec.to_device(x)?;

        // ---- MHA (Fig 2): per-head QKV over column tiles (Fig 4a).
        // Input panels are shared across heads — extract + upload once.
        let x_panels: Vec<DeviceTensor> = (0..t_m)
            .map(|t| self.exec.to_device(&self.col_panel(x, t * self.ts_mha, self.ts_mha)))
            .collect::<anyhow::Result<_>>()?;
        let mut attn = Tensor::zeros(vec![self.sl_max, self.dmodel_max]);
        if self.qkv_packed {
            // §Perf iter 3: one dispatch per tile projects the head's
            // Q|K|V simultaneously (Algorithm 9's three MACs per cycle),
            // then attention reads the packed block on-device.
            for h in 0..cfg.heads {
                let tiles = &lw.w_qkv_packed[h];
                let mut acc =
                    self.exec.run_dev("mm_qkv_packed", &[&x_panels[0], &tiles[0], &zeros.qkv3])?;
                for t in 1..t_m {
                    acc = self.exec.run_dev("mm_qkv_packed", &[&x_panels[t], &tiles[t], &acc])?;
                }
                let qkv = self.exec.run_dev("bias_add_qkv", &[&acc, &lw.b_qkv_packed[h]])?;
                let o = self.exec.run_dev("attn_packed", &[&qkv, mask, scale])?;
                self.set_col_panel(&mut attn, &self.exec.fetch(&o)?, h * self.dk);
            }
        } else {
            for h in 0..cfg.heads {
                let project = |tiles: &Vec<DeviceTensor>, bias: &DeviceTensor| -> anyhow::Result<DeviceTensor> {
                    let mut acc = self.exec.run_dev("mm_qkv", &[&x_panels[0], &tiles[0], &zeros.dk])?;
                    for t in 1..t_m {
                        acc = self.exec.run_dev("mm_qkv", &[&x_panels[t], &tiles[t], &acc])?;
                    }
                    self.exec.run_dev("bias_add_dk", &[&acc, bias])
                };
                let q = project(&lw.wq[h], &lw.bq[h]).context("Q projection")?;
                let k = project(&lw.wk[h], &lw.bk[h]).context("K projection")?;
                let v = project(&lw.wv[h], &lw.bv[h]).context("V projection")?;
                let o = match self.mode {
                    AttentionMode::Fused => {
                        self.exec.run_dev("attn_fused", &[&q, &k, &v, mask, scale])?
                    }
                    AttentionMode::Split => {
                        let s = self.exec.run_dev("qk_scores", &[&q, &k, mask, scale])?;
                        let p = self.exec.run_dev("softmax", &[&s])?;
                        self.exec.run_dev("sv", &[&p, &v])?
                    }
                };
                self.set_col_panel(&mut attn, &self.exec.fetch(&o)?, h * self.dk);
            }
        }

        if self.quantized {
            // per-tensor symmetric int8 QDQ on the attention output
            let sc = crate::model::quant::calibrate_scale(&attn.data);
            let attn_dev = self.exec.to_device(&attn)?;
            let q = self
                .exec
                .run_dev("quantize", &[&attn_dev, &self.exec.to_device(&Tensor::scalar1(sc))?])?;
            attn = self.exec.fetch(&q)?;
        }

        // ---- FFN1_PM: output projection, 2-D tiles (Fig 4b).
        let a_panels: Vec<DeviceTensor> = (0..t_f)
            .map(|r| self.exec.to_device(&self.col_panel(&attn, r * self.ts_ffn, self.ts_ffn)))
            .collect::<anyhow::Result<_>>()?;
        let mut proj = Tensor::zeros(vec![self.sl_max, self.dmodel_max]);
        for c in 0..t_f {
            let mut acc = self.exec.run_dev("mm_ffn1", &[&a_panels[0], &lw.wo[0][c], &zeros.ffn])?;
            for r in 1..t_f {
                acc = self.exec.run_dev("mm_ffn1", &[&a_panels[r], &lw.wo[r][c], &acc])?;
            }
            self.set_col_panel(&mut proj, &self.exec.fetch(&acc)?, c * self.ts_ffn);
        }
        let proj_dev = self.exec.to_device(&proj)?;
        let proj_b = self.exec.run_dev("bias_add_d", &[&proj_dev, &lw.bo])?;
        let y_dev =
            self.exec.run_dev("residual_ln", &[&proj_b, &x_dev, &lw.g1, &lw.b1n, dmask, count])?;
        let y = self.exec.fetch(&y_dev)?;

        // ---- FFN2_PM: d -> hidden with ReLU.
        let y_panels: Vec<DeviceTensor> = (0..t_f)
            .map(|r| self.exec.to_device(&self.col_panel(&y, r * self.ts_ffn, self.ts_ffn)))
            .collect::<anyhow::Result<_>>()?;
        let mut hid = Tensor::zeros(vec![self.sl_max, self.hidden_max]);
        for c in 0..t_h {
            let mut acc = self.exec.run_dev("mm_ffn2", &[&y_panels[0], &lw.w1[0][c], &zeros.col])?;
            for r in 1..t_f {
                acc = self.exec.run_dev("mm_ffn2", &[&y_panels[r], &lw.w1[r][c], &acc])?;
            }
            self.set_col_panel(&mut hid, &self.exec.fetch(&acc)?, c * self.ffn_col);
        }
        let hid_dev = self.exec.to_device(&hid)?;
        let hid_r = self.exec.fetch(&self.exec.run_dev("bias_relu_h", &[&hid_dev, &lw.b1])?)?;

        // ---- FFN3_PM: hidden -> d.
        let h_panels: Vec<DeviceTensor> = (0..t_h)
            .map(|r| self.exec.to_device(&self.col_panel(&hid_r, r * self.ffn_col, self.ffn_col)))
            .collect::<anyhow::Result<_>>()?;
        let mut out = Tensor::zeros(vec![self.sl_max, self.dmodel_max]);
        for c in 0..t_f {
            let mut acc = self.exec.run_dev("mm_ffn3", &[&h_panels[0], &lw.w2[0][c], &zeros.ffn])?;
            for r in 1..t_h {
                acc = self.exec.run_dev("mm_ffn3", &[&h_panels[r], &lw.w2[r][c], &acc])?;
            }
            self.set_col_panel(&mut out, &self.exec.fetch(&acc)?, c * self.ts_ffn);
        }
        let out_dev = self.exec.to_device(&out)?;
        let out_b = self.exec.run_dev("bias_add_d", &[&out_dev, &lw.b2])?;
        let fin =
            self.exec.run_dev("residual_ln", &[&out_b, &y_dev, &lw.g2, &lw.b2n, dmask, count])?;
        self.exec.fetch(&fin)
    }

    /// Run one layer through a *fused* per-config artifact (the
    /// non-adaptive baseline path) — topology must match exactly.
    pub fn run_fused_layer(&self, name: &str, input: &Mat, w: &LayerWeights) -> anyhow::Result<Mat> {
        let fm = self
            .exec
            .manifest()
            .fused
            .get(name)
            .ok_or_else(|| anyhow!("no fused artifact '{name}'"))?
            .clone();
        if (input.rows, input.cols) != (fm.sl, fm.d_model) {
            bail!("fused '{name}' wants {}x{}", fm.sl, fm.d_model);
        }
        let h = fm.heads;
        let d = fm.d_model;
        let dk = d / h;
        let hid = 4 * d;
        let cat_heads = |ms: &[Mat]| {
            let mut data = Vec::with_capacity(h * d * dk);
            for m in ms {
                data.extend_from_slice(&m.data);
            }
            Tensor::new(vec![h, d, dk], data)
        };
        let cat_bias = |bs: &[Vec<f32>]| {
            Tensor::new(vec![h, dk], bs.iter().flat_map(|b| b.iter().copied()).collect())
        };
        let x = Tensor::from_mat(input);
        let mask = Tensor::from_mat(&crate::model::reference::attention_mask(fm.sl, fm.sl, false));
        let inputs: Vec<Tensor> = vec![
            x,
            mask,
            cat_heads(&w.wq),
            cat_heads(&w.wk),
            cat_heads(&w.wv),
            cat_bias(&w.bq),
            cat_bias(&w.bk),
            cat_bias(&w.bv),
            Tensor::new(vec![d, d], w.wo.data.clone()),
            Tensor::new(vec![d], w.bo.clone()),
            Tensor::new(vec![d, hid], w.w1.data.clone()),
            Tensor::new(vec![hid], w.b1.clone()),
            Tensor::new(vec![hid, d], w.w2.data.clone()),
            Tensor::new(vec![d], w.b2.clone()),
            Tensor::new(vec![d], w.g1.clone()),
            Tensor::new(vec![d], w.b1n.clone()),
            Tensor::new(vec![d], w.g2.clone()),
            Tensor::new(vec![d], w.b2n.clone()),
        ];
        let refs: Vec<&Tensor> = inputs.iter().collect();
        Ok(self.exec.run1(name, &refs)?.to_mat())
    }

    /// Fused full-stack convenience (for the ablation bench): chains the
    /// fused layer artifact across the stack.
    pub fn run_fused_stack(&self, name: &str, input: &Mat, stack: &[LayerWeights]) -> anyhow::Result<Mat> {
        let mut x = input.clone();
        for w in stack {
            x = self.run_fused_layer(name, &x, w)?;
        }
        Ok(x)
    }

    /// Access raw weights of a prepared layer (tests/fused comparisons).
    pub fn raw_weights<'a>(&self, stack: &'a PreparedStack) -> Vec<&'a LayerWeights> {
        stack.layers.iter().map(|l| &l.raw).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{presets, reference, weights};
    use crate::runtime::default_artifact_dir;

    use crate::require_artifacts;

    fn engine() -> TileEngine {
        TileEngine::new(default_artifact_dir()).expect("run `make artifacts` first")
    }

    fn oracle(cfg: &TnnConfig, stack: &[weights::LayerWeights], x: &Mat) -> Mat {
        let mask = reference::attention_mask(cfg.seq_len, cfg.seq_len, false);
        reference::encoder_stack(x, stack, &mask)
    }

    #[test]
    fn single_layer_matches_oracle() {
        require_artifacts!();
        let mut e = engine();
        let cfg = presets::small_encoder(32, 1);
        let ws = weights::init_stack(1, cfg.d_model, cfg.heads, 1);
        e.program(&cfg).unwrap();
        let prepared = e.prepare(&cfg, &ws).unwrap();
        let x = weights::init_input(3, cfg.seq_len, cfg.d_model);
        let got = e.run_encoder(&prepared, &x).unwrap();
        let want = oracle(&cfg, &ws, &x);
        let diff = got.max_abs_diff(&want);
        assert!(diff < 2e-3, "engine vs oracle diff = {diff}");
    }

    #[test]
    fn split_and_fused_attention_agree() {
        require_artifacts!();
        let mut e = engine();
        let cfg = presets::small_encoder(32, 1);
        let ws = weights::init_stack(2, cfg.d_model, cfg.heads, 1);
        e.program(&cfg).unwrap();
        let prepared = e.prepare(&cfg, &ws).unwrap();
        let x = weights::init_input(4, cfg.seq_len, cfg.d_model);
        e.mode = AttentionMode::Split;
        let a = e.run_encoder(&prepared, &x).unwrap();
        e.mode = AttentionMode::Fused;
        let b = e.run_encoder(&prepared, &x).unwrap();
        assert!(a.max_abs_diff(&b) < 1e-3, "{}", a.max_abs_diff(&b));
    }

    #[test]
    fn runtime_reconfiguration_without_recompilation() {
        require_artifacts!();
        // THE paper's contribution: switch topologies via registers only.
        let mut e = engine();

        let cfg1 = presets::small_encoder(32, 1);
        let ws1 = weights::init_stack(5, cfg1.d_model, cfg1.heads, 1);
        e.program(&cfg1).unwrap();
        let p1 = e.prepare(&cfg1, &ws1).unwrap();
        let x1 = weights::init_input(6, cfg1.seq_len, cfg1.d_model);
        let o1 = e.run_encoder(&p1, &x1).unwrap();
        assert!(o1.max_abs_diff(&oracle(&cfg1, &ws1, &x1)) < 2e-3);
        let compiled_after_first = e.executor().compiled_count();

        // different seq len, width, head count, depth — registers only
        let cfg2 = TnnConfig::encoder(48, 128, 2, 2);
        let ws2 = weights::init_stack(7, cfg2.d_model, cfg2.heads, 2);
        e.program(&cfg2).unwrap();
        let p2 = e.prepare(&cfg2, &ws2).unwrap();
        let x2 = weights::init_input(8, cfg2.seq_len, cfg2.d_model);
        let o2 = e.run_encoder(&p2, &x2).unwrap();
        assert!(o2.max_abs_diff(&oracle(&cfg2, &ws2, &x2)) < 2e-3);

        assert_eq!(
            e.executor().compiled_count(),
            compiled_after_first,
            "reprogramming registers must not compile anything new"
        );
    }

    #[test]
    fn packed_and_per_head_qkv_agree() {
        require_artifacts!();
        let mut e = engine();
        let cfg = presets::small_encoder(48, 1);
        let ws = weights::init_stack(31, cfg.d_model, cfg.heads, 1);
        e.program(&cfg).unwrap();
        let p = e.prepare(&cfg, &ws).unwrap();
        let x = weights::init_input(32, cfg.seq_len, cfg.d_model);
        e.qkv_packed = true;
        let a = e.run_encoder(&p, &x).unwrap();
        e.qkv_packed = false;
        let b = e.run_encoder(&p, &x).unwrap();
        assert!(a.max_abs_diff(&b) < 1e-4, "{}", a.max_abs_diff(&b));
    }

    #[test]
    fn programming_state_is_exposed() {
        require_artifacts!();
        let mut e = engine();
        assert!(e.programmed_config().is_none(), "fresh registers hold no topology");
        let cfg = presets::small_encoder(32, 1);
        assert!(!e.is_programmed_for(&cfg));
        e.program(&cfg).unwrap();
        assert_eq!(e.programmed_config(), Some(cfg));
        assert!(e.is_programmed_for(&cfg));
        let other = TnnConfig::encoder(48, 128, 2, 1);
        assert!(!e.is_programmed_for(&other));
        e.program(&other).unwrap();
        assert!(e.is_programmed_for(&other));
        assert!(!e.is_programmed_for(&cfg));
    }

    #[test]
    fn fabric_constraints_are_enforced() {
        require_artifacts!();
        let mut e = engine();
        // dk != 64
        assert!(e.program(&TnnConfig::encoder(32, 256, 8, 1)).is_err());
        // too long
        assert!(e.program(&TnnConfig::encoder(256, 256, 4, 1)).is_err());
        // too wide
        assert!(e.program(&TnnConfig::encoder(32, 1024, 16, 1)).is_err());
        // fine
        assert!(e.program(&presets::small_encoder(64, 2)).is_ok());
    }

    #[test]
    fn wrong_register_state_is_rejected() {
        require_artifacts!();
        let mut e = engine();
        let cfg = presets::small_encoder(32, 1);
        let ws = weights::init_stack(9, cfg.d_model, cfg.heads, 1);
        e.program(&cfg).unwrap();
        let p = e.prepare(&cfg, &ws).unwrap();
        // reprogram to a different topology, then run with stale prepared stack
        e.program(&TnnConfig::encoder(48, 128, 2, 1)).unwrap();
        let x = weights::init_input(10, cfg.seq_len, cfg.d_model);
        assert!(e.run_encoder(&p, &x).is_err());
    }

    #[test]
    fn quantized_mode_is_close_but_not_identical() {
        require_artifacts!();
        let mut e = engine();
        let cfg = presets::small_encoder(32, 1);
        let ws = weights::init_stack(41, cfg.d_model, cfg.heads, 1);
        e.program(&cfg).unwrap();
        let p = e.prepare(&cfg, &ws).unwrap();
        let x = weights::init_input(42, cfg.seq_len, cfg.d_model);
        let full = e.run_encoder(&p, &x).unwrap();
        e.quantized = true;
        let quant = e.run_encoder(&p, &x).unwrap();
        let diff = full.max_abs_diff(&quant);
        assert!(diff > 1e-6, "quantization must actually do something");
        assert!(diff < 0.35, "int8 QDQ error out of band: {diff}");
    }

    #[test]
    fn fused_layer_matches_tiled_layer() {
        require_artifacts!();
        let mut e = engine();
        let cfg = presets::small_encoder(64, 1); // matches fused_small_layer
        let ws = weights::init_stack(11, cfg.d_model, cfg.heads, 1);
        e.program(&cfg).unwrap();
        let p = e.prepare(&cfg, &ws).unwrap();
        let x = weights::init_input(12, cfg.seq_len, cfg.d_model);
        let tiled = e.run_encoder(&p, &x).unwrap();
        let fused = e.run_fused_stack("small_layer", &x, &ws).unwrap();
        let diff = tiled.max_abs_diff(&fused);
        assert!(diff < 2e-3, "tiled vs fused artifact diff = {diff}");
    }
}
