//! The tile-schedule engine — ADAPTOR's fabric, numerically.
//!
//! Executes a transformer encoder exactly the way the hardware does
//! (Fig 2/3, Algorithms 1–17), but no longer as imperative loop nests: the
//! schedule is lowered **once per programmed topology** into a
//! [`TileProgram`] (`accel::schedule`) and *replayed* per request through
//! the PJRT [`FabricBackend`].  Every *runtime* parameter (sequence
//! length, heads, embedding and hidden dims, layer count) arrives through
//! the configuration register file — changing them selects (or builds) a
//! different cached program, rewrites masks, and NEVER recompiles an
//! artifact (the `compiled_count` probe in tests).
//!
//! The request path is therefore "look up program, replay":
//!
//! * the program cache is keyed by `(topology, mode, qkv_packed,
//!   quantized)`; repeated requests for one topology replay the same
//!   instruction stream;
//! * the per-topology runtime tensors (attention mask, LayerNorm
//!   dmask/count, zero accumulators) are uploaded once when the program is
//!   built and reused by every replay — they used to be re-uploaded on
//!   each request;
//! * each layer's residual operand references the previous layer's
//!   device-resident output instead of re-uploading the full padded
//!   activation (the BRAM-residency analog);
//! * [`TileEngine::cycle_estimate`] replays the *identical* program
//!   through `accel::sim::cycle` for a schedule-grounded latency
//!   prediction (Table 2's experimental column from the same source of
//!   truth as execution).
//!
//! Padding contract: all fabric buffers are sized for the synthesis maxima
//! (SL_MAX × DMODEL_MAX etc.); a smaller runtime topology occupies a
//! prefix, the attention mask and the LayerNorm dmask/count inputs fence
//! off the rest — the exact analog of the paper's BRAM buffers + loop
//! bounds from the `Sequence`/`Embeddings` registers.
//!
//! [`FabricBackend`]: crate::runtime::FabricBackend

use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::rc::Rc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail};

use super::api::ServeError;
use crate::accel::decode::KvCache;
use crate::accel::registers::{RegisterFile, SynthMaxima};
use crate::accel::schedule::{
    self, ArtifactInventory, FabricConstants, RuntimeBufs, ScheduleBuilder, TileProgram,
    WeightKind, WeightRef, WeightSource,
};
use crate::accel::sim::cycle::{self, CycleReport};
use crate::accel::decode;
use crate::model::weights::{DecoderLayerWeights, LayerWeights, Mat};
use crate::model::TnnConfig;
use crate::runtime::{DeviceTensor, Executor, Tensor, TensorPool};

pub use crate::accel::schedule::{AttentionMode, OptLevel, ProgramKind};

/// One layer's weights, pre-tiled into fabric-shaped panels and parked
/// **device-resident** (§Perf iteration 2) — the substrate analog of the
/// paper's weights living in BRAM: uploaded once at prepare time, never
/// re-transferred on the request path.
struct PreparedLayer {
    /// Per head, per MHA tile: `TS_MHA × DK` panels of W_q/W_k/W_v.
    wq: Vec<Vec<DeviceTensor>>,
    wk: Vec<Vec<DeviceTensor>>,
    wv: Vec<Vec<DeviceTensor>>,
    bq: Vec<DeviceTensor>,
    bk: Vec<DeviceTensor>,
    bv: Vec<DeviceTensor>,
    /// FFN1 (output projection) `TS_FFN × TS_FFN` panels, [row][col].
    wo: Vec<Vec<DeviceTensor>>,
    bo: DeviceTensor,
    /// FFN2 `TS_FFN × FFN_COL` panels, [row][col].
    w1: Vec<Vec<DeviceTensor>>,
    b1: DeviceTensor,
    /// FFN3 `FFN_COL × TS_FFN` panels, [row][col].
    w2: Vec<Vec<DeviceTensor>>,
    b2: DeviceTensor,
    g1: DeviceTensor,
    b1n: DeviceTensor,
    g2: DeviceTensor,
    b2n: DeviceTensor,
    /// Per head, per MHA tile: packed `TS_MHA x 3*DK` panels holding the
    /// head's Q|K|V columns side by side (Algorithm 9's simultaneous
    /// MACs; §Perf iteration 3 — the 3*DK width is fabric-fixed, so every
    /// runtime topology uses all lanes).
    w_qkv_packed: Vec<Vec<DeviceTensor>>,
    b_qkv_packed: Vec<DeviceTensor>,
    /// Raw weights kept for the fused path.
    raw: LayerWeights,
}

/// One decoder layer's cross-attention block, device-resident: prefill
/// panels (tiled like the encoder's MHA/FFN1 weights) plus the
/// decode-step full-width row weights.
struct PreparedCross {
    /// Per head, per MHA tile: `TS_MHA × DK` panels of the cross Q/K/V.
    cwq: Vec<Vec<DeviceTensor>>,
    cwk: Vec<Vec<DeviceTensor>>,
    cwv: Vec<Vec<DeviceTensor>>,
    cbq: Vec<DeviceTensor>,
    cbk: Vec<DeviceTensor>,
    cbv: Vec<DeviceTensor>,
    /// Cross output-projection grid, `TS_FFN × TS_FFN` panels.
    cwo: Vec<Vec<DeviceTensor>>,
    cbo: DeviceTensor,
    cg: DeviceTensor,
    cbn: DeviceTensor,
    /// Decode-step row weights: per-head `[DMODEL_MAX, DK]` query
    /// projection and the full `[DMODEL_MAX, DMODEL_MAX]` output
    /// projection (cross K/V need no row weights — they are cached).
    dcwq: Vec<DeviceTensor>,
    dcwo: DeviceTensor,
}

/// One decoder layer: the self-attention + FFN half reuses the encoder
/// layer's prefill panels (`base`); the decode-step path additionally
/// parks the full (fabric-padded) matrices the single-row datapath
/// streams in one dispatch each.
struct PreparedDecoderLayer {
    base: PreparedLayer,
    /// Per head `[DMODEL_MAX, DK]` full projections (decode-step).
    dwq: Vec<DeviceTensor>,
    dwk: Vec<DeviceTensor>,
    dwv: Vec<DeviceTensor>,
    /// `[DMODEL_MAX, DMODEL_MAX]` output projection (decode-step).
    dwo: DeviceTensor,
    /// `[DMODEL_MAX, HIDDEN_MAX]` / `[HIDDEN_MAX, DMODEL_MAX]` FFN pair.
    dw1: DeviceTensor,
    dw2: DeviceTensor,
    /// `None` for GPT-style decoder-only layers.
    cross: Option<PreparedCross>,
}

/// A registered model: topology + prepared weight stacks (encoder layers
/// and, for `dec_layers > 0` topologies, decoder layers).
pub struct PreparedStack {
    pub cfg: TnnConfig,
    layers: Vec<PreparedLayer>,
    dec: Vec<PreparedDecoderLayer>,
}

/// Resolve the encoder-program weight kinds against one prepared layer.
fn encoder_layer_weight<'a>(
    l: &'a PreparedLayer,
    r: &WeightRef,
) -> anyhow::Result<&'a DeviceTensor> {
    Ok(match r.kind {
        WeightKind::Wq => &l.wq[r.row][r.col],
        WeightKind::Wk => &l.wk[r.row][r.col],
        WeightKind::Wv => &l.wv[r.row][r.col],
        WeightKind::Bq => &l.bq[r.row],
        WeightKind::Bk => &l.bk[r.row],
        WeightKind::Bv => &l.bv[r.row],
        WeightKind::Wo => &l.wo[r.row][r.col],
        WeightKind::Bo => &l.bo,
        WeightKind::W1 => &l.w1[r.row][r.col],
        WeightKind::B1 => &l.b1,
        WeightKind::W2 => &l.w2[r.row][r.col],
        WeightKind::B2 => &l.b2,
        WeightKind::G1 => &l.g1,
        WeightKind::B1n => &l.b1n,
        WeightKind::G2 => &l.g2,
        WeightKind::B2n => &l.b2n,
        WeightKind::QkvPacked => &l.w_qkv_packed[r.row][r.col],
        WeightKind::BQkvPacked => &l.b_qkv_packed[r.row],
        other => bail!("weight kind {other:?} is only valid in decoder programs"),
    })
}

/// A prepared stack resolves the program's symbolic weight references to
/// its device-resident panels — one program serves every stack with the
/// same topology.  This impl serves **encoder** programs (`WeightRef.layer`
/// indexes the encoder stack); decoder programs resolve through
/// [`DecoderStackView`].
impl WeightSource<DeviceTensor> for PreparedStack {
    fn weight(&self, r: &WeightRef) -> anyhow::Result<&DeviceTensor> {
        let l = self
            .layers
            .get(r.layer)
            .ok_or_else(|| anyhow!("program references layer {} of a {}-layer stack", r.layer, self.layers.len()))?;
        encoder_layer_weight(l, r)
    }
}

/// The decoder-side weight view of a prepared stack: `WeightRef.layer`
/// indexes the **decoder** stack; base kinds (self-attention, FFN, the
/// first/last LayerNorm pair) resolve into the layer's `base` panels,
/// cross and decode-row kinds into the decoder-specific tensors.
pub struct DecoderStackView<'a>(pub &'a PreparedStack);

impl WeightSource<DeviceTensor> for DecoderStackView<'_> {
    fn weight(&self, r: &WeightRef) -> anyhow::Result<&DeviceTensor> {
        let l = self.0.dec.get(r.layer).ok_or_else(|| {
            anyhow!(
                "program references decoder layer {} of a {}-layer decoder stack",
                r.layer,
                self.0.dec.len()
            )
        })?;
        use WeightKind as K;
        let cross = || {
            l.cross
                .as_ref()
                .ok_or_else(|| anyhow!("decoder-only layer {} has no cross-attention weights", r.layer))
        };
        Ok(match r.kind {
            K::DWq => &l.dwq[r.row],
            K::DWk => &l.dwk[r.row],
            K::DWv => &l.dwv[r.row],
            K::DWo => &l.dwo,
            K::DW1 => &l.dw1,
            K::DW2 => &l.dw2,
            K::CWq => &cross()?.cwq[r.row][r.col],
            K::CWk => &cross()?.cwk[r.row][r.col],
            K::CWv => &cross()?.cwv[r.row][r.col],
            K::CBq => &cross()?.cbq[r.row],
            K::CBk => &cross()?.cbk[r.row],
            K::CBv => &cross()?.cbv[r.row],
            K::CWo => &cross()?.cwo[r.row][r.col],
            K::CBo => &cross()?.cbo,
            K::CG => &cross()?.cg,
            K::CBn => &cross()?.cbn,
            K::DCWq => &cross()?.dcwq[r.row],
            K::DCWo => &cross()?.dcwo,
            _ => encoder_layer_weight(&l.base, r)?,
        })
    }
}

/// What a [`TileEngine::generate_streamed`] observer tells the step
/// loop after each produced token: keep decoding, or stop before the
/// next decode step (the serving layer's cancellation hook — a
/// cancelled generation stops within one decode step).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepControl {
    Continue,
    Stop,
}

/// What one greedy generation produced, plus the timing/dispatch split
/// the serving metrics and the acceptance tests consume.
#[derive(Debug, Clone)]
pub struct Generated {
    /// Generated activation rows, `steps × d_model` (continuous greedy
    /// feed-back — see `model::reference::greedy_decode`).
    pub rows: Mat,
    /// Per-step greedy token ids (argmax feature index of each row).
    pub tokens: Vec<usize>,
    /// Source encode (seq2seq) + prompt prefill wall time.
    pub prefill: Duration,
    /// Per-token decode-step wall times (`steps - 1` entries: the first
    /// token falls out of the prefill).
    pub step_times: Vec<Duration>,
    /// Instructions one prefill replay dispatches.
    pub prefill_dispatches: usize,
    /// Instructions one decode-step replay dispatches (strictly fewer —
    /// asserted by the regression tests via `ExecStats`).
    pub step_dispatches: usize,
}

/// One resumable in-flight generation: the per-sequence state a
/// scheduler needs to drive decoding **round-robin** across many
/// sequences on one fabric.  Produced by
/// [`TileEngine::begin_generation`] (validation + optional source
/// encode + prompt prefill + the first token), advanced one token at a
/// time by [`TileEngine::step_once`], and finished into a [`Generated`]
/// by [`TileEngine::finish_generation`].
///
/// The session owns the sequence's [`KvCache`] (device-resident K/V
/// panels) — dropping an unfinished session frees the cache buffers
/// immediately, which is exactly how the serving layer retires a
/// cancelled or expired sequence mid-flight.
pub struct GenSession {
    rows: Mat,
    tokens: Vec<usize>,
    /// The activation row fed to the next decode step (greedy feedback).
    next: Vec<f32>,
    /// Tokens produced so far (>= 1: the first falls out of the prefill).
    produced: usize,
    /// Target token count.
    steps: usize,
    cache: KvCache<DeviceTensor>,
    prefill: Duration,
    step_times: Vec<Duration>,
}

impl GenSession {
    /// Tokens produced so far (always >= 1).
    pub fn produced(&self) -> usize {
        self.produced
    }

    /// Target token count for this generation.
    pub fn steps(&self) -> usize {
        self.steps
    }

    /// Whether the generation has produced all requested tokens.
    pub fn is_done(&self) -> bool {
        self.produced == self.steps
    }

    /// The most recently produced token id.
    pub fn last_token(&self) -> usize {
        self.tokens[self.produced - 1]
    }

    /// The most recently produced activation row (`d_model` values).
    pub fn last_row(&self) -> &[f32] {
        &self.next
    }

    /// Source encode (seq2seq) + prompt prefill wall time.
    pub fn prefill_time(&self) -> Duration {
        self.prefill
    }
}

/// A built program plus its per-topology runtime tensors: the runtime
/// tensors (mask, dmask, count, zero accumulators) are uploaded exactly
/// once per *topology* and shared by every replay — including across
/// programs that differ only in execution flags (mode/packed/quantized).
pub struct CachedProgram {
    pub program: TileProgram,
    runtime: Rc<RuntimeBufs<DeviceTensor>>,
}

/// Topology-only cache key for the shared runtime tensor sets (the
/// register-file-derived tensors don't depend on the execution flags).
/// `bucket` is the **sequence bucket** the set was materialized at — the
/// attention masks of a bucket-specialized program fence at the bucket,
/// not at the model's full `seq_len`, so each bucket owns its own set.
/// Non-bucketed programs use `bucket == seq_len`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct TopologyKey {
    seq_len: usize,
    heads: usize,
    d_model: usize,
    hidden: usize,
    enc_layers: usize,
    dec_layers: usize,
    bucket: usize,
}

impl TopologyKey {
    fn new(cfg: &TnnConfig, bucket: usize) -> Self {
        TopologyKey {
            seq_len: cfg.seq_len,
            heads: cfg.heads,
            d_model: cfg.d_model,
            hidden: cfg.hidden,
            enc_layers: cfg.enc_layers,
            dec_layers: cfg.dec_layers,
            bucket,
        }
    }
}

/// Program cache key: the programmed topology plus the engine's execution
/// flags (each flag selects a genuinely different instruction stream), the
/// optimization level (each level a different *optimized* stream), the
/// program kind (encoder / prefill / decode-step) and the **sequence
/// bucket** the program was lowered at (a bucket-specialized program is a
/// different instruction stream from the full-length one; non-bucketed
/// kinds use `bucket == seq_len`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct ProgramKey {
    seq_len: usize,
    heads: usize,
    d_model: usize,
    hidden: usize,
    enc_layers: usize,
    dec_layers: usize,
    mode: AttentionMode,
    qkv_packed: bool,
    quantized: bool,
    opt_level: OptLevel,
    kind: ProgramKind,
    bucket: usize,
    /// `(index, count)` when the program is one pipeline shard of a
    /// K-shard chain (`coordinator::shard`): the index decides the
    /// send/recv roles, so each shard is its own instruction stream even
    /// when two shards share a sub-topology.  `None` for monolithic
    /// programs.
    shard: Option<(u16, u16)>,
}

impl ProgramKey {
    #[allow(clippy::too_many_arguments)]
    fn new(
        cfg: &TnnConfig,
        mode: AttentionMode,
        qkv_packed: bool,
        quantized: bool,
        opt_level: OptLevel,
        kind: ProgramKind,
        bucket: usize,
        shard: Option<(u16, u16)>,
    ) -> Self {
        // Decoder lowering always uses the split chain (see
        // `ScheduleBuilder::build_prefill`); normalize the flags so the
        // cache never holds duplicate decoder programs.
        let (mode, qkv_packed, quantized) = match kind {
            ProgramKind::Encoder => (mode, qkv_packed, quantized),
            _ => (AttentionMode::Split, false, false),
        };
        ProgramKey {
            seq_len: cfg.seq_len,
            heads: cfg.heads,
            d_model: cfg.d_model,
            hidden: cfg.hidden,
            enc_layers: cfg.enc_layers,
            dec_layers: cfg.dec_layers,
            mode,
            qkv_packed,
            quantized,
            opt_level,
            kind,
            bucket,
            shard,
        }
    }
}

/// Cap on cached programs per engine.  Far above any realistic model zoo
/// on one fabric, but bounds device memory: each entry pins ~10 runtime
/// device tensors, and without a cap a long-lived pool serving an
/// unbounded stream of distinct topologies would grow forever.
const PROGRAM_CACHE_CAP: usize = 64;

/// The engine: one PJRT executor ("the fabric") + the register file + the
/// per-topology schedule cache.
pub struct TileEngine {
    exec: Executor,
    pub registers: RegisterFile,
    pub mode: AttentionMode,
    /// Project a head's Q/K/V in one packed dispatch per tile
    /// (Algorithm 9's three-MACs-per-cycle structure; §Perf iteration 3).
    /// Perf-neutral on this substrate (kept as an ablation: 2.6x fewer
    /// dispatches, same wall time — see EXPERIMENTS.md §Perf), so the
    /// per-head schedule stays the default.
    pub qkv_packed: bool,
    /// Fully-quantized mode (§1: the paper's fabric is fixed-point): runs
    /// the int8 QDQ artifact on the attention output, mirroring
    /// `model.encoder_layer(quantized=True)`'s activation quantization.
    pub quantized: bool,
    /// Optimization level the pass pipeline (`accel::schedule::opt`) runs
    /// at before a program is cached.  Part of the cache key; the serving
    /// default is `O2` (dedup + fusion into whatever fused artifacts the
    /// manifest provides + wave scheduling + slot compaction).  `O0`
    /// replays the builder's raw stream — the oracle the equivalence
    /// tests compare optimized replays against.
    pub opt_level: OptLevel,
    /// Fabric constants (from the manifest = the synthesized shapes).
    fc: FabricConstants,
    /// Artifact names this fabric provides — fusion never rewrites into
    /// an artifact the manifest lacks.
    inventory: ArtifactInventory,
    /// Host-scratch pool shared by every replay on this engine (panel
    /// extracts, zero-initialized assembly hosts, the padded input).
    pool: TensorPool,
    /// Built programs by `(topology, flags)` — the serving pool's request
    /// path is "look up program, replay".
    programs: RefCell<HashMap<ProgramKey, Rc<CachedProgram>>>,
    /// Uploaded runtime tensor sets by topology, shared across the flag
    /// variants of a topology's programs.
    runtimes: RefCell<HashMap<TopologyKey, Rc<RuntimeBufs<DeviceTensor>>>>,
    cache_hits: Cell<u64>,
    cache_misses: Cell<u64>,
}

impl TileEngine {
    pub fn new(artifact_dir: impl AsRef<std::path::Path>) -> Result<Self, ServeError> {
        let exec = Executor::new(artifact_dir)?;
        let m = exec.manifest();
        let maxima = m.synth_maxima();
        let fc = FabricConstants::from_manifest(m);
        let inventory = ArtifactInventory::from_manifest(m);
        Ok(TileEngine {
            fc,
            inventory,
            exec,
            registers: RegisterFile::new(maxima),
            mode: AttentionMode::Split,
            qkv_packed: false,
            quantized: false,
            opt_level: OptLevel::O2,
            pool: TensorPool::new(),
            programs: RefCell::new(HashMap::new()),
            runtimes: RefCell::new(HashMap::new()),
            cache_hits: Cell::new(0),
            cache_misses: Cell::new(0),
        })
    }

    pub fn executor(&self) -> &Executor {
        &self.exec
    }

    pub fn synth_maxima(&self) -> SynthMaxima {
        self.exec.manifest().synth_maxima()
    }

    /// The synthesized shape constants this fabric was built with.
    pub fn fabric_constants(&self) -> FabricConstants {
        self.fc
    }

    /// Drop the host scratch pool's free lists, returning the bytes
    /// released (see `TensorPool::trim`).  The serving layer calls this
    /// after a weight-stack eviction so host scratch tracks the
    /// resident working set instead of every topology ever served.
    pub fn trim_scratch(&self) -> u64 {
        self.pool.trim()
    }

    /// Fabric divisibility constraints for the tile engine (the FPGA's
    /// equivalents are the tile sizes baked at synthesis).
    pub fn check_runtime_config(&self, cfg: &TnnConfig) -> Result<(), ServeError> {
        self.fc.check(cfg).map_err(ServeError::Engine)
    }

    /// Program the register file for `cfg` (Algorithm 18 step 3).
    pub fn program(&mut self, cfg: &TnnConfig) -> Result<(), ServeError> {
        self.check_runtime_config(cfg)?;
        self.registers.program(cfg).map_err(ServeError::ProgramFailed)
    }

    /// The topology currently held in the register file, or `None` before
    /// the first successful `program()` (the registers reset to all-zero,
    /// which is not a valid topology).
    pub fn programmed_config(&self) -> Option<TnnConfig> {
        let cfg = self.registers.current_config();
        cfg.validate().ok().map(|_| cfg)
    }

    /// Whether the register file already holds exactly `cfg` — i.e. a
    /// dispatch for this topology needs no reprogram.  The pool scheduler
    /// uses this to count (and the affinity policy to avoid) register
    /// writes; two registered models with identical topologies share one
    /// programming, exactly as on the hardware.
    pub fn is_programmed_for(&self, cfg: &TnnConfig) -> bool {
        self.registers.current_config() == *cfg
    }

    /// The cached encoder program for `cfg` under the engine's current
    /// execution flags and opt level, building + optimizing (and
    /// uploading the runtime tensor set) on first use.
    pub fn cached_program(&self, cfg: &TnnConfig) -> Result<Rc<CachedProgram>, ServeError> {
        self.cached_program_kind(cfg, ProgramKind::Encoder)
    }

    /// [`Self::cached_program_kind`] generalized over the **sequence
    /// bucket**: the program is lowered at `seq_len = bucket` with
    /// skippable attention tiers (Encoder/Prefill kinds), so a short
    /// request replays a schedule sized for its covering bucket instead
    /// of the model's full length.  `bucket` must be a tier of
    /// [`schedule::length_tiers`]`(cfg.seq_len)`; callers derive it via
    /// [`schedule::covering_bucket`] from the request's actual row count.
    pub fn cached_program_bucket(
        &self,
        cfg: &TnnConfig,
        kind: ProgramKind,
        bucket: usize,
    ) -> Result<Rc<CachedProgram>, ServeError> {
        self.cached_shard_program_bucket(cfg, kind, bucket, None)
    }

    /// [`Self::cached_program_bucket`] for one **pipeline shard**: `cfg`
    /// is the shard's sub-topology (its own layer count) and
    /// `shard = Some((index, count))` selects the transfer roles — every
    /// shard but the head gets a `RecvActivation` of boundary
    /// `index - 1`, every shard but the tail a `SendActivation` of
    /// boundary `index`.  `None` is exactly the monolithic path.  Decode
    /// steps never shard (KV locality pins a generating sequence to one
    /// fabric), so `DecodeStep` with a shard role is refused.
    pub fn cached_shard_program_bucket(
        &self,
        cfg: &TnnConfig,
        kind: ProgramKind,
        bucket: usize,
        shard: Option<(u16, u16)>,
    ) -> Result<Rc<CachedProgram>, ServeError> {
        if let Some((index, count)) = shard {
            if count < 2 || index >= count {
                return Err(ServeError::invalid(format!(
                    "shard {index} of {count} is not a valid chain position"
                )));
            }
            if matches!(kind, ProgramKind::DecodeStep) {
                return Err(ServeError::invalid(
                    "decode-step programs never shard — KV locality pins generation to one fabric",
                ));
            }
        }
        let key = ProgramKey::new(
            cfg,
            self.mode,
            self.qkv_packed,
            self.quantized,
            self.opt_level,
            kind,
            bucket,
            shard,
        );
        if let Some(p) = self.programs.borrow().get(&key) {
            self.cache_hits.set(self.cache_hits.get() + 1);
            return Ok(p.clone());
        }
        self.cache_misses.set(self.cache_misses.get() + 1);
        if !matches!(kind, ProgramKind::Encoder) && cfg.dec_layers == 0 {
            return Err(ServeError::invalid(format!(
                "topology {cfg} has no decoder layers to lower a {kind:?} program for"
            )));
        }
        if !schedule::length_tiers(cfg.seq_len).contains(&bucket) {
            return Err(ServeError::invalid(format!(
                "bucket {bucket} is not a length tier of seq_len {}",
                cfg.seq_len
            )));
        }
        // Lower at the bucket's row count: the builder sees a topology
        // whose seq_len IS the bucket, so masks, loop trips and cycle
        // costs all shrink to it.  Decode-step programs are single-row
        // and never bucketed (callers pass bucket == seq_len).
        let cfg_b = TnnConfig { seq_len: bucket, ..*cfg };
        let mut builder = ScheduleBuilder::new(self.fc, cfg_b)?;
        if let Some((index, count)) = shard {
            if index > 0 {
                builder = builder.recv_activation(index as usize - 1);
            }
            if index + 1 < count {
                builder = builder.send_activation(index as usize);
            }
        }
        let mut program = match kind {
            ProgramKind::Encoder => builder
                .mode(self.mode)
                .qkv_packed(self.qkv_packed)
                .quantized(self.quantized)
                .skippable(true)
                .build(),
            ProgramKind::Prefill => builder.skippable(true).build_prefill(),
            ProgramKind::DecodeStep => builder.build_step(),
        };
        // Run the pass pipeline once; every replay gets the optimized
        // stream (fusion is gated on the manifest's actual inventory).
        // A validation failure fails this one request, not the fabric.
        schedule::optimize(&mut program, self.opt_level, &self.inventory)?;
        // Static verification gates cache insertion: a malformed program
        // (builder bug, bad opt pass, IR drift) fails here as a typed
        // `ProgramFailed` before first dispatch, at zero per-request cost.
        schedule::verify::verify_program(&program, kind, &self.inventory)?;
        let runtime = self.runtime_for(cfg, bucket)?;
        let cached = Rc::new(CachedProgram { program, runtime });
        let mut programs = self.programs.borrow_mut();
        if programs.len() >= PROGRAM_CACHE_CAP {
            // Arbitrary eviction is fine this far above the working set; a
            // re-miss just rebuilds the program and re-uploads the runtime
            // tensor set (10 + the bucket's tier masks).
            if let Some(evict) = programs.keys().next().copied() {
                programs.remove(&evict);
            }
        }
        programs.insert(key, cached.clone());
        Ok(cached)
    }

    /// [`Self::cached_program`] generalized over the program kind —
    /// decoder topologies cache two extra flavors per topology: the
    /// prefill and the decode-step stream.
    pub fn cached_program_kind(
        &self,
        cfg: &TnnConfig,
        kind: ProgramKind,
    ) -> Result<Rc<CachedProgram>, ServeError> {
        self.cached_program_bucket(cfg, kind, cfg.seq_len)
    }

    /// The shared runtime tensor set for `cfg`'s topology at `bucket`,
    /// uploading it on first use: the base 10 register-file-derived
    /// tensors (materialized at the bucket's fence) plus both mask
    /// families for every non-top tier of the bucket — the union every
    /// program flavor of this `(topology, bucket)` pair can reference, so
    /// the set stays shareable across flag variants.
    fn runtime_for(
        &self,
        cfg: &TnnConfig,
        bucket: usize,
    ) -> anyhow::Result<Rc<RuntimeBufs<DeviceTensor>>> {
        let tkey = TopologyKey::new(cfg, bucket);
        if let Some(r) = self.runtimes.borrow().get(&tkey) {
            return Ok(r.clone());
        }
        let cfg_b = TnnConfig { seq_len: bucket, ..*cfg };
        let mut bufs = schedule::build_runtime(&self.exec, &cfg_b, &self.fc)?;
        let tiers = schedule::length_tiers(bucket);
        let ids: Vec<schedule::RuntimeId> = tiers[..tiers.len() - 1]
            .iter()
            .flat_map(|&t| {
                [
                    schedule::RuntimeId::TierMask(t as u16),
                    schedule::RuntimeId::TierCausalMask(t as u16),
                ]
            })
            .collect();
        schedule::upload_tier_masks(&self.exec, &mut bufs, &cfg_b, &self.fc, &ids)?;
        let r = Rc::new(bufs);
        let mut runtimes = self.runtimes.borrow_mut();
        if runtimes.len() >= PROGRAM_CACHE_CAP {
            // Drop only sets no cached program still pins (count == 1 means
            // the map holds the sole Rc) — evicting a pinned set would let
            // a later flag-variant re-upload a duplicate, breaking the
            // shared-per-topology invariant.  The bound is soft: pinned
            // sets are bounded by the program cache's own cap.
            runtimes.retain(|_, v| Rc::strong_count(v) > 1);
        }
        runtimes.insert(tkey, r.clone());
        Ok(r)
    }

    /// `(hits, misses)` of the per-topology program cache.
    pub fn program_cache_stats(&self) -> (u64, u64) {
        (self.cache_hits.get(), self.cache_misses.get())
    }

    /// Schedule-grounded cycle prediction: replays the *identical* cached
    /// program through the cycle backend (`accel::sim::cycle`), so the
    /// Table 2 "experimental" number and the executed schedule cannot
    /// drift apart.  Sequential (`sum`) pricing — invariant across opt
    /// levels by construction (fused artifacts cost the sum of their
    /// parts, reorders commute under addition).
    pub fn cycle_estimate(&self, cfg: &TnnConfig) -> Result<CycleReport, ServeError> {
        let cached = self.cached_program(cfg)?;
        Ok(cycle::replay_program(&cached.program)?)
    }

    /// [`Self::cycle_estimate`] for a request of `rows` actual rows: the
    /// price of the bucket-specialized program the engine would replay
    /// for it, at the live row count.  For `rows == seq_len` this is
    /// exactly [`Self::cycle_estimate`]; for shorter requests it is
    /// strictly lower — the recovered padding waste.
    pub fn cycle_estimate_rows(
        &self,
        cfg: &TnnConfig,
        rows: usize,
    ) -> Result<CycleReport, ServeError> {
        let rows = rows.clamp(1, cfg.seq_len);
        let bucket = schedule::covering_bucket(rows, cfg.seq_len);
        let cached = self.cached_program_bucket(cfg, ProgramKind::Encoder, bucket)?;
        Ok(cycle::replay_program_live(&cached.program, rows)?)
    }

    /// [`Self::cycle_estimate`] with wave pricing: each wave of the
    /// cached (wave-scheduled) program costs `max` over its members —
    /// the utilization-adjusted latency the optimizer's parallelism is
    /// worth on a fabric that runs independent modules concurrently.
    pub fn cycle_estimate_waves(&self, cfg: &TnnConfig) -> Result<CycleReport, ServeError> {
        let cached = self.cached_program(cfg)?;
        Ok(cycle::replay_program_waves(&cached.program)?)
    }

    /// `(hits, misses)` of the host-scratch tensor pool.
    pub fn tensor_pool_stats(&self) -> (u64, u64) {
        self.pool.stats()
    }

    /// Pre-tile an encoder weight stack for the fabric (Algorithm 18
    /// steps 7–9: "load weight axi master interface buffers").  For
    /// `dec_layers > 0` topologies use [`Self::prepare_model`].
    pub fn prepare(
        &self,
        cfg: &TnnConfig,
        stack: &[LayerWeights],
    ) -> Result<PreparedStack, ServeError> {
        if cfg.dec_layers > 0 {
            return Err(ServeError::invalid(format!(
                "topology {cfg} has decoder layers; prepare_model() wants their weights too"
            )));
        }
        self.prepare_model(cfg, stack, &[])
    }

    /// Pre-tile a full model — encoder layers plus decoder layers (self,
    /// cross and decode-row weights) — parking everything device-resident.
    pub fn prepare_model(
        &self,
        cfg: &TnnConfig,
        enc: &[LayerWeights],
        dec: &[DecoderLayerWeights],
    ) -> Result<PreparedStack, ServeError> {
        self.check_runtime_config(cfg)?;
        if enc.len() != cfg.enc_layers {
            return Err(ServeError::invalid(format!(
                "{} weight layers for {} encoder layers",
                enc.len(),
                cfg.enc_layers
            )));
        }
        if dec.len() != cfg.dec_layers {
            return Err(ServeError::invalid(format!(
                "{} decoder weight layers for {} decoder layers",
                dec.len(),
                cfg.dec_layers
            )));
        }
        for (i, w) in dec.iter().enumerate() {
            if w.cross.is_some() != (cfg.enc_layers > 0) {
                return Err(ServeError::invalid(format!(
                    "decoder layer {i}: cross-attention weights {} but enc_layers = {}",
                    if w.cross.is_some() { "present" } else { "absent" },
                    cfg.enc_layers
                )));
            }
        }
        let layers = enc.iter().map(|w| self.prepare_layer(cfg, w)).collect::<Result<_, _>>()?;
        let dec =
            dec.iter().map(|w| self.prepare_decoder_layer(cfg, w)).collect::<Result<_, _>>()?;
        Ok(PreparedStack { cfg: *cfg, layers, dec })
    }

    fn prepare_decoder_layer(
        &self,
        cfg: &TnnConfig,
        w: &DecoderLayerWeights,
    ) -> anyhow::Result<PreparedDecoderLayer> {
        let base = self.prepare_layer(cfg, &w.base)?;
        let fc = self.fc;
        // Decode-step row weights: the full matrices, zero-padded to the
        // fabric maxima (padded rows/cols multiply the zero-padded tail of
        // the activation row, so the valid prefix is untouched).
        let pad_full = |m: &Mat, rows: usize, cols: usize| {
            self.exec.to_device(&Tensor::from_mat(&m.padded(rows, cols)))
        };
        let row_heads = |ws: &[Mat]| -> anyhow::Result<Vec<DeviceTensor>> {
            ws.iter().map(|m| pad_full(m, fc.dmodel_max, fc.dk)).collect()
        };
        let cross = match &w.cross {
            None => None,
            Some(c) => {
                let d = cfg.d_model;
                let h = cfg.heads;
                let t_m = d / fc.ts_mha;
                let t_f = d / fc.ts_ffn;
                let panel = |m: &Mat, r0: usize, c0: usize, rows: usize, cols: usize| {
                    self.exec.to_device(&Tensor::from_mat(&m.block(r0, c0, rows, cols)))
                };
                let head_tiles = |ws: &[Mat]| -> anyhow::Result<Vec<Vec<DeviceTensor>>> {
                    (0..h)
                        .map(|hh| {
                            (0..t_m)
                                .map(|t| panel(&ws[hh], t * fc.ts_mha, 0, fc.ts_mha, fc.dk))
                                .collect()
                        })
                        .collect()
                };
                let vec_pad = |v: &[f32], n: usize| {
                    let mut data = v.to_vec();
                    data.resize(n, 0.0);
                    self.exec.to_device(&Tensor::new(vec![n], data))
                };
                let bias_heads = |bs: &[Vec<f32>]| -> anyhow::Result<Vec<DeviceTensor>> {
                    bs.iter()
                        .map(|b| self.exec.to_device(&Tensor::new(vec![fc.dk], b.clone())))
                        .collect()
                };
                Some(PreparedCross {
                    cwq: head_tiles(&c.wq)?,
                    cwk: head_tiles(&c.wk)?,
                    cwv: head_tiles(&c.wv)?,
                    cbq: bias_heads(&c.bq)?,
                    cbk: bias_heads(&c.bk)?,
                    cbv: bias_heads(&c.bv)?,
                    cwo: (0..t_f)
                        .map(|r| {
                            (0..t_f)
                                .map(|cc| {
                                    panel(&c.wo, r * fc.ts_ffn, cc * fc.ts_ffn, fc.ts_ffn, fc.ts_ffn)
                                })
                                .collect()
                        })
                        .collect::<anyhow::Result<_>>()?,
                    cbo: vec_pad(&c.bo, fc.dmodel_max)?,
                    cg: vec_pad(&c.g, fc.dmodel_max)?,
                    cbn: vec_pad(&c.bn, fc.dmodel_max)?,
                    dcwq: row_heads(&c.wq)?,
                    dcwo: pad_full(&c.wo, fc.dmodel_max, fc.dmodel_max)?,
                })
            }
        };
        Ok(PreparedDecoderLayer {
            dwq: row_heads(&w.base.wq)?,
            dwk: row_heads(&w.base.wk)?,
            dwv: row_heads(&w.base.wv)?,
            dwo: pad_full(&w.base.wo, fc.dmodel_max, fc.dmodel_max)?,
            dw1: pad_full(&w.base.w1, fc.dmodel_max, fc.hidden_max)?,
            dw2: pad_full(&w.base.w2, fc.hidden_max, fc.dmodel_max)?,
            cross,
            base,
        })
    }

    fn prepare_layer(&self, cfg: &TnnConfig, w: &LayerWeights) -> anyhow::Result<PreparedLayer> {
        let d = cfg.d_model;
        let h = cfg.heads;
        let t_m = d / self.fc.ts_mha;
        let t_f = d / self.fc.ts_ffn;
        let t_h = cfg.hidden / self.fc.ffn_col;
        let panel = |m: &Mat, r0: usize, c0: usize, rows: usize, cols: usize| {
            self.exec.to_device(&Tensor::from_mat(&m.block(r0, c0, rows, cols)))
        };
        let vec_pad = |v: &[f32], n: usize| {
            let mut data = v.to_vec();
            data.resize(n, 0.0);
            self.exec.to_device(&Tensor::new(vec![n], data))
        };
        let head_tiles = |ws: &[Mat]| -> anyhow::Result<Vec<Vec<DeviceTensor>>> {
            (0..h)
                .map(|hh| {
                    (0..t_m)
                        .map(|t| panel(&ws[hh], t * self.fc.ts_mha, 0, self.fc.ts_mha, self.fc.dk))
                        .collect()
                })
                .collect()
        };
        let grid = |m: &Mat, rows: usize, cols: usize, rstep: usize, cstep: usize| -> anyhow::Result<Vec<Vec<DeviceTensor>>> {
            (0..rows)
                .map(|r| (0..cols).map(|c| panel(m, r * rstep, c * cstep, rstep, cstep)).collect())
                .collect()
        };
        // Per-head packed Q|K|V weight panels: columns [0,3*DK) hold the
        // head's [Q | K | V] tile side by side.
        let dk3 = 3 * self.fc.dk;
        let w_qkv_packed = (0..h)
            .map(|hh| {
                (0..t_m)
                    .map(|t| {
                        let mut panel = Mat::zeros(self.fc.ts_mha, dk3);
                        for (blk, ws) in [(0, &w.wq), (1, &w.wk), (2, &w.wv)] {
                            let src = ws[hh].block(t * self.fc.ts_mha, 0, self.fc.ts_mha, self.fc.dk);
                            panel.set_block(0, blk * self.fc.dk, &src);
                        }
                        self.exec.to_device(&Tensor::from_mat(&panel))
                    })
                    .collect::<anyhow::Result<Vec<_>>>()
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        let b_qkv_packed = (0..h)
            .map(|hh| {
                let mut b = vec![0.0f32; dk3];
                for (blk, bs) in [(0usize, &w.bq), (1, &w.bk), (2, &w.bv)] {
                    b[blk * self.fc.dk..(blk + 1) * self.fc.dk].copy_from_slice(&bs[hh]);
                }
                self.exec.to_device(&Tensor::new(vec![dk3], b))
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(PreparedLayer {
            w_qkv_packed,
            b_qkv_packed,
            wq: head_tiles(&w.wq)?,
            wk: head_tiles(&w.wk)?,
            wv: head_tiles(&w.wv)?,
            bq: w.bq.iter().map(|b| self.exec.to_device(&Tensor::new(vec![self.fc.dk], b.clone()))).collect::<anyhow::Result<_>>()?,
            bk: w.bk.iter().map(|b| self.exec.to_device(&Tensor::new(vec![self.fc.dk], b.clone()))).collect::<anyhow::Result<_>>()?,
            bv: w.bv.iter().map(|b| self.exec.to_device(&Tensor::new(vec![self.fc.dk], b.clone()))).collect::<anyhow::Result<_>>()?,
            wo: grid(&w.wo, t_f, t_f, self.fc.ts_ffn, self.fc.ts_ffn)?,
            bo: vec_pad(&w.bo, self.fc.dmodel_max)?,
            w1: grid(&w.w1, t_f, t_h, self.fc.ts_ffn, self.fc.ffn_col)?,
            b1: vec_pad(&w.b1, self.fc.hidden_max)?,
            w2: grid(&w.w2, t_h, t_f, self.fc.ffn_col, self.fc.ts_ffn)?,
            b2: vec_pad(&w.b2, self.fc.dmodel_max)?,
            g1: vec_pad(&w.g1, self.fc.dmodel_max)?,
            b1n: vec_pad(&w.b1n, self.fc.dmodel_max)?,
            g2: vec_pad(&w.g2, self.fc.dmodel_max)?,
            b2n: vec_pad(&w.b2n, self.fc.dmodel_max)?,
            raw: w.clone(),
        })
    }

    /// Run the full encoder stack on `input` (`rows <= seq_len` rows of
    /// `d_model` columns), returning `rows × d_model`.  This is the
    /// request-path entry: pick the smallest length bucket covering the
    /// request's **actual** row count, look up (or build) the
    /// bucket-specialized program, pad the input into the bucket and
    /// replay at the live row count — short requests execute a schedule
    /// sized for their bucket, not the model's full `seq_len`.  Inputs
    /// longer than `seq_len` are a typed [`ServeError::InvalidRequest`].
    pub fn run_encoder(&self, stack: &PreparedStack, input: &Mat) -> Result<Mat, ServeError> {
        let cfg = &stack.cfg;
        if self.registers.current_config() != *cfg {
            return Err(ServeError::invalid(
                "register file is programmed for a different topology (Algorithm 18 step 3 first)",
            ));
        }
        if input.cols != cfg.d_model || input.rows == 0 || input.rows > cfg.seq_len {
            return Err(ServeError::invalid(format!(
                "input is {}x{}, want 1..={} rows of {} columns",
                input.rows, input.cols, cfg.seq_len, cfg.d_model
            )));
        }
        let bucket = schedule::covering_bucket(input.rows, cfg.seq_len);
        let cached = self.cached_program_bucket(cfg, ProgramKind::Encoder, bucket)?;
        // Load inputs into the (padded) input BRAM — Algorithm 1.  The
        // padded staging tensor comes from the engine's scratch pool, so
        // steady-state requests allocate no host memory for it; the
        // replay returns it to the pool when the input host is dropped.
        let mut padded = self.pool.take_zeroed(&[self.fc.sl_max, self.fc.dmodel_max]);
        schedule::pad_into(input, &mut padded);
        let out = schedule::replay_with_live(
            &cached.program,
            &self.exec,
            stack,
            &cached.runtime,
            padded,
            Some(&self.pool),
            input.rows,
        )?;
        // Crop to the request's live rows without the to_mat round trip,
        // then recycle the padded output buffer.
        let result = schedule::crop_to_mat(&out, input.rows, cfg.d_model);
        self.pool.put(out);
        Ok(result)
    }

    /// One stage of a **sharded** encoder chain (`coordinator::shard`):
    /// replay shard `(index, count)` of the chain against this fabric's
    /// prepared sub-stack.  `stack.cfg` is the shard's sub-topology, and
    /// `activation` is the full padded `[SL_MAX, DMODEL_MAX]` activation —
    /// the caller's padded request for the head stage
    /// ([`Self::pad_stage_input`]) or the relay tensor the previous stage
    /// returned.  The return value is the padded output activation: for
    /// every stage but the tail it is exactly what `SendActivation`
    /// shipped over the link, and the tail's caller crops it with
    /// [`Self::crop_stage_output`].
    pub fn run_encoder_stage(
        &self,
        stack: &PreparedStack,
        shard: (u16, u16),
        activation: Tensor,
        live: usize,
    ) -> Result<Tensor, ServeError> {
        let cfg = &stack.cfg;
        if self.registers.current_config() != *cfg {
            return Err(ServeError::invalid(
                "register file is programmed for a different topology (Algorithm 18 step 3 first)",
            ));
        }
        if live == 0 || live > cfg.seq_len {
            return Err(ServeError::invalid(format!(
                "stage live rows {live}, want 1..={}",
                cfg.seq_len
            )));
        }
        if activation.shape != [self.fc.sl_max, self.fc.dmodel_max] {
            return Err(ServeError::invalid(format!(
                "stage activation is {:?}, want the padded [{}, {}]",
                activation.shape, self.fc.sl_max, self.fc.dmodel_max
            )));
        }
        let bucket = schedule::covering_bucket(live, cfg.seq_len);
        let cached =
            self.cached_shard_program_bucket(cfg, ProgramKind::Encoder, bucket, Some(shard))?;
        let out = schedule::replay_with_live(
            &cached.program,
            &self.exec,
            stack,
            &cached.runtime,
            activation,
            Some(&self.pool),
            live,
        )?;
        Ok(out)
    }

    /// Pad a request into the fabric's `[SL_MAX, DMODEL_MAX]` staging
    /// tensor (from the engine's scratch pool) — the head-stage input of
    /// [`Self::run_encoder_stage`].
    pub fn pad_stage_input(&self, input: &Mat) -> Tensor {
        let mut padded = self.pool.take_zeroed(&[self.fc.sl_max, self.fc.dmodel_max]);
        schedule::pad_into(input, &mut padded);
        padded
    }

    /// Crop a tail stage's padded output activation to the request's live
    /// rows, recycling the padded buffer into the scratch pool.
    pub fn crop_stage_output(&self, out: Tensor, live: usize, d_model: usize) -> Mat {
        let result = schedule::crop_to_mat(&out, live, d_model);
        self.pool.put(out);
        result
    }

    /// Decoder **prefill**: run the whole prompt (`rows <= seq_len` of
    /// `d_model` columns) through the decoder stack, returning the output
    /// rows for the prompt and the populated device-resident [`KvCache`].
    /// Seq2seq topologies additionally take the encoder memory
    /// (`seq_len × d_model`, usually from [`Self::run_encoder`]).
    pub fn decoder_prefill(
        &self,
        stack: &PreparedStack,
        prompt: &Mat,
        memory: Option<&Mat>,
    ) -> Result<(Mat, KvCache<DeviceTensor>), ServeError> {
        let cfg = &stack.cfg;
        if self.registers.current_config() != *cfg {
            return Err(ServeError::invalid(
                "register file is programmed for a different topology (Algorithm 18 step 3 first)",
            ));
        }
        if cfg.dec_layers == 0 {
            return Err(ServeError::invalid(format!("topology {cfg} has no decoder layers")));
        }
        if prompt.cols != cfg.d_model || prompt.rows == 0 || prompt.rows > cfg.seq_len {
            return Err(ServeError::invalid(format!(
                "prompt is {}x{}, want 1..={} rows of {} columns",
                prompt.rows, prompt.cols, cfg.seq_len, cfg.d_model
            )));
        }
        // Length-adaptive prefill: decoder-only topologies lower the
        // program at the prompt's covering bucket (causal chains are
        // exact at any live prefix).  Seq2seq prefill keeps the
        // full-length program — the cross-attention memory fence must
        // stay at the encoder's seq_len regardless of the prompt length —
        // but still tier-skips its causal self-attention at the live row
        // count.
        let bucket = if cfg.enc_layers == 0 {
            schedule::covering_bucket(prompt.rows, cfg.seq_len)
        } else {
            cfg.seq_len
        };
        let cached = self.cached_program_bucket(cfg, ProgramKind::Prefill, bucket)?;
        let mut padded = self.pool.take_zeroed(&[self.fc.sl_max, self.fc.dmodel_max]);
        schedule::pad_into(prompt, &mut padded);
        let mut inputs = vec![padded];
        if cfg.enc_layers > 0 {
            let mem = memory
                .ok_or_else(|| ServeError::invalid("seq2seq topology needs an encoder memory"))?;
            if (mem.rows, mem.cols) != (cfg.seq_len, cfg.d_model) {
                return Err(ServeError::invalid(format!(
                    "encoder memory is {}x{}, registers say {}x{}",
                    mem.rows, mem.cols, cfg.seq_len, cfg.d_model
                )));
            }
            let mut mp = self.pool.take_zeroed(&[self.fc.sl_max, self.fc.dmodel_max]);
            schedule::pad_into(mem, &mut mp);
            inputs.push(mp);
        } else if memory.is_some() {
            return Err(ServeError::invalid("decoder-only topology takes no encoder memory"));
        }
        let (out, exports) = schedule::replay_full_adaptive(
            &cached.program,
            &self.exec,
            &DecoderStackView(stack),
            &cached.runtime,
            inputs,
            &[],
            Some(&self.pool),
            prompt.rows,
        )?;
        let result = schedule::crop_to_mat(&out, prompt.rows, cfg.d_model);
        self.pool.put(out);
        let cache = KvCache::from_prefill(cfg, exports, prompt.rows)?;
        Ok((result, cache))
    }

    /// One KV-cached decode step: feed the token row for position
    /// `cache.len`, append its K/V on-device, and return the output row
    /// (the activation of the *next* position).  Dispatches strictly
    /// fewer instructions than a prefill replay — the whole point of the
    /// cache.
    pub fn decode_step(
        &self,
        stack: &PreparedStack,
        cache: &mut KvCache<DeviceTensor>,
        row: &[f32],
    ) -> Result<Vec<f32>, ServeError> {
        let cfg = &stack.cfg;
        if self.registers.current_config() != *cfg {
            return Err(ServeError::invalid(
                "register file is programmed for a different topology (Algorithm 18 step 3 first)",
            ));
        }
        if row.len() != cfg.d_model {
            return Err(ServeError::invalid(format!(
                "step row has {} features, registers say {}",
                row.len(),
                cfg.d_model
            )));
        }
        let pos = cache.len;
        if pos >= cfg.seq_len {
            return Err(ServeError::invalid(format!(
                "sequence budget exhausted ({} of {} positions)",
                pos, cfg.seq_len
            )));
        }
        let cached = self.cached_program_kind(cfg, ProgramKind::DecodeStep)?;
        let mut input = self.pool.take_zeroed(&[1, self.fc.dmodel_max]);
        input.data[..cfg.d_model].copy_from_slice(row);
        let inputs =
            vec![input, decode::step_mask_row(self.fc.sl_max, pos), decode::position_tensor(pos)];
        let externs = cache.externs();
        let (out, exports) = schedule::replay_full(
            &cached.program,
            &self.exec,
            &DecoderStackView(stack),
            &cached.runtime,
            inputs,
            &externs,
            Some(&self.pool),
        )?;
        drop(externs);
        cache.apply_step(exports)?;
        let result = out.data[..cfg.d_model].to_vec();
        self.pool.put(out);
        Ok(result)
    }

    /// Greedy autoregressive generation: (optionally) encode the source
    /// into a memory, prefill the prompt, then replay the decode-step
    /// program once per remaining token against the pooled KV cache.
    /// Matches `model::reference::greedy_decode` on the f32 path.
    pub fn generate(
        &self,
        stack: &PreparedStack,
        prompt: &Mat,
        source: Option<&Mat>,
        steps: usize,
    ) -> Result<Generated, ServeError> {
        self.generate_streamed(stack, prompt, source, steps, &mut |_, _, _| {
            StepControl::Continue
        })?
        .ok_or(ServeError::Cancelled)
    }

    /// [`Self::generate`] with a per-token observer — the serving
    /// layer's streaming and **cancellation hook**.  `on_token(index,
    /// token_id, row)` is called after every produced token (index 0
    /// falls out of the prefill); returning [`StepControl::Stop`] ends
    /// the generation before the next decode step and yields
    /// `Ok(None)`.  Stopping is clean by construction: the KV cache is
    /// device-resident per-call state that drops here, and every pooled
    /// scratch buffer was already returned by the completed steps —
    /// the engine is immediately ready for the next request.
    pub fn generate_streamed(
        &self,
        stack: &PreparedStack,
        prompt: &Mat,
        source: Option<&Mat>,
        steps: usize,
        on_token: &mut dyn FnMut(usize, usize, &[f32]) -> StepControl,
    ) -> Result<Option<Generated>, ServeError> {
        let mut session = self.begin_generation(stack, prompt, source, steps)?;
        if on_token(0, session.last_token(), session.last_row()) == StepControl::Stop {
            return Ok(None);
        }
        while !session.is_done() {
            let (i, token) = self.step_once(stack, &mut session)?;
            if on_token(i, token, session.last_row()) == StepControl::Stop {
                return Ok(None);
            }
        }
        Ok(Some(self.finish_generation(stack, session)?))
    }

    /// Start a resumable generation: validate the request, (optionally)
    /// encode the source, prefill the prompt, and produce the first
    /// token (which falls out of the prefill's last output row).  The
    /// returned [`GenSession`] is then advanced one token per
    /// [`Self::step_once`] call — the continuous-batching scheduler
    /// holds one session per in-flight sequence and drives them
    /// round-robin against the shared cached step program.
    pub fn begin_generation(
        &self,
        stack: &PreparedStack,
        prompt: &Mat,
        source: Option<&Mat>,
        steps: usize,
    ) -> Result<GenSession, ServeError> {
        let cfg = &stack.cfg;
        if steps == 0 {
            return Err(ServeError::invalid("generation needs at least one step"));
        }
        if prompt.rows + steps > cfg.seq_len {
            return Err(ServeError::invalid(format!(
                "prompt ({}) + steps ({steps}) exceed the sequence budget {}",
                prompt.rows, cfg.seq_len
            )));
        }
        let t0 = Instant::now();
        let memory_mat;
        let memory = if cfg.enc_layers > 0 {
            let src = source
                .ok_or_else(|| ServeError::invalid("seq2seq topology needs a source to encode"))?;
            memory_mat = self.run_encoder(stack, src)?;
            Some(&memory_mat)
        } else {
            if source.is_some() {
                return Err(ServeError::invalid("decoder-only topology takes no source input"));
            }
            None
        };
        let (pre_out, cache) = self.decoder_prefill(stack, prompt, memory)?;
        let prefill = t0.elapsed();
        let d = cfg.d_model;
        let mut rows = Mat::zeros(steps, d);
        let mut tokens = Vec::with_capacity(steps);
        // The prompt's last output row is the first generated token.
        let next: Vec<f32> = (0..d).map(|c| pre_out.at(prompt.rows - 1, c)).collect();
        tokens.push(crate::model::reference::argmax_token(&next));
        rows.data[..d].copy_from_slice(&next);
        Ok(GenSession {
            rows,
            tokens,
            next,
            produced: 1,
            steps,
            cache,
            prefill,
            step_times: Vec::with_capacity(steps.saturating_sub(1)),
        })
    }

    /// Advance a [`GenSession`] by exactly one decode step and return
    /// `(token_index, token_id)` for the newly produced token (its
    /// activation row is [`GenSession::last_row`]).  The engine must be
    /// programmed for the session's topology — the scheduler reprograms
    /// the register file when it switches models between sequences; the
    /// session's KV cache is plain device memory and survives register
    /// reprogramming untouched.
    pub fn step_once(
        &self,
        stack: &PreparedStack,
        session: &mut GenSession,
    ) -> Result<(usize, usize), ServeError> {
        if session.is_done() {
            return Err(ServeError::invalid("generation already produced all requested tokens"));
        }
        let d = stack.cfg.d_model;
        let t = Instant::now();
        session.next = self.decode_step(stack, &mut session.cache, &session.next)?;
        session.step_times.push(t.elapsed());
        let i = session.produced;
        let token = crate::model::reference::argmax_token(&session.next);
        session.tokens.push(token);
        session.rows.data[i * d..(i + 1) * d].copy_from_slice(&session.next);
        session.produced += 1;
        Ok((i, token))
    }

    /// Close out a completed [`GenSession`] into the [`Generated`]
    /// result the serving layer reports (dropping the KV cache).
    pub fn finish_generation(
        &self,
        stack: &PreparedStack,
        session: GenSession,
    ) -> Result<Generated, ServeError> {
        if !session.is_done() {
            return Err(ServeError::invalid(format!(
                "generation finished early ({} of {} tokens)",
                session.produced, session.steps
            )));
        }
        let cfg = &stack.cfg;
        Ok(Generated {
            rows: session.rows,
            tokens: session.tokens,
            prefill: session.prefill,
            step_times: session.step_times,
            prefill_dispatches: self
                .cached_program_kind(cfg, ProgramKind::Prefill)?
                .program
                .dispatch_count(),
            step_dispatches: self
                .cached_program_kind(cfg, ProgramKind::DecodeStep)?
                .program
                .dispatch_count(),
        })
    }

    /// Run one layer through a *fused* per-config artifact (the
    /// non-adaptive baseline path) — topology must match exactly.
    pub fn run_fused_layer(
        &self,
        name: &str,
        input: &Mat,
        w: &LayerWeights,
    ) -> Result<Mat, ServeError> {
        let fm = self
            .exec
            .manifest()
            .fused
            .get(name)
            .ok_or_else(|| ServeError::engine(format!("no fused artifact '{name}'")))?
            .clone();
        if (input.rows, input.cols) != (fm.sl, fm.d_model) {
            return Err(ServeError::invalid(format!(
                "fused '{name}' wants {}x{}",
                fm.sl, fm.d_model
            )));
        }
        let h = fm.heads;
        let d = fm.d_model;
        let dk = d / h;
        let hid = 4 * d;
        let cat_heads = |ms: &[Mat]| {
            let mut data = Vec::with_capacity(h * d * dk);
            for m in ms {
                data.extend_from_slice(&m.data);
            }
            Tensor::new(vec![h, d, dk], data)
        };
        let cat_bias = |bs: &[Vec<f32>]| {
            Tensor::new(vec![h, dk], bs.iter().flat_map(|b| b.iter().copied()).collect())
        };
        let x = Tensor::from_mat(input);
        let mask = Tensor::from_mat(&crate::model::reference::attention_mask(fm.sl, fm.sl, false));
        let inputs: Vec<Tensor> = vec![
            x,
            mask,
            cat_heads(&w.wq),
            cat_heads(&w.wk),
            cat_heads(&w.wv),
            cat_bias(&w.bq),
            cat_bias(&w.bk),
            cat_bias(&w.bv),
            Tensor::new(vec![d, d], w.wo.data.clone()),
            Tensor::new(vec![d], w.bo.clone()),
            Tensor::new(vec![d, hid], w.w1.data.clone()),
            Tensor::new(vec![hid], w.b1.clone()),
            Tensor::new(vec![hid, d], w.w2.data.clone()),
            Tensor::new(vec![d], w.b2.clone()),
            Tensor::new(vec![d], w.g1.clone()),
            Tensor::new(vec![d], w.b1n.clone()),
            Tensor::new(vec![d], w.g2.clone()),
            Tensor::new(vec![d], w.b2n.clone()),
        ];
        let refs: Vec<&Tensor> = inputs.iter().collect();
        Ok(self.exec.run1(name, &refs)?.to_mat())
    }

    /// Fused full-stack convenience (for the ablation bench): chains the
    /// fused layer artifact across the stack.
    pub fn run_fused_stack(
        &self,
        name: &str,
        input: &Mat,
        stack: &[LayerWeights],
    ) -> Result<Mat, ServeError> {
        let mut x = input.clone();
        for w in stack {
            x = self.run_fused_layer(name, &x, w)?;
        }
        Ok(x)
    }

    /// Access raw weights of a prepared layer (tests/fused comparisons).
    pub fn raw_weights<'a>(&self, stack: &'a PreparedStack) -> Vec<&'a LayerWeights> {
        stack.layers.iter().map(|l| &l.raw).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{presets, reference, weights};
    use crate::runtime::default_artifact_dir;

    use crate::require_artifacts;

    fn engine() -> TileEngine {
        TileEngine::new(default_artifact_dir()).expect("run `make artifacts` first")
    }

    fn oracle(cfg: &TnnConfig, stack: &[weights::LayerWeights], x: &Mat) -> Mat {
        let mask = reference::attention_mask(cfg.seq_len, cfg.seq_len, false);
        reference::encoder_stack(x, stack, &mask)
    }

    #[test]
    fn single_layer_matches_oracle() {
        require_artifacts!();
        let mut e = engine();
        let cfg = presets::small_encoder(32, 1);
        let ws = weights::init_stack(1, cfg.d_model, cfg.heads, 1);
        e.program(&cfg).unwrap();
        let prepared = e.prepare(&cfg, &ws).unwrap();
        let x = weights::init_input(3, cfg.seq_len, cfg.d_model);
        let got = e.run_encoder(&prepared, &x).unwrap();
        let want = oracle(&cfg, &ws, &x);
        let diff = got.max_abs_diff(&want);
        // O2 (the default) may dispatch the fused attention artifact, so
        // the band is the fused path's, not the split chain's.
        assert!(diff < 3e-3, "engine vs oracle diff = {diff}");
        // The raw O0 stream must stay in the original band too.
        e.opt_level = OptLevel::O0;
        let raw = e.run_encoder(&prepared, &x).unwrap();
        let diff0 = raw.max_abs_diff(&want);
        assert!(diff0 < 2e-3, "raw engine vs oracle diff = {diff0}");
    }

    #[test]
    fn split_and_fused_attention_agree() {
        require_artifacts!();
        let mut e = engine();
        let cfg = presets::small_encoder(32, 1);
        let ws = weights::init_stack(2, cfg.d_model, cfg.heads, 1);
        e.program(&cfg).unwrap();
        let prepared = e.prepare(&cfg, &ws).unwrap();
        let x = weights::init_input(4, cfg.seq_len, cfg.d_model);
        e.mode = AttentionMode::Split;
        let a = e.run_encoder(&prepared, &x).unwrap();
        e.mode = AttentionMode::Fused;
        let b = e.run_encoder(&prepared, &x).unwrap();
        assert!(a.max_abs_diff(&b) < 1e-3, "{}", a.max_abs_diff(&b));
    }

    #[test]
    fn runtime_reconfiguration_without_recompilation() {
        require_artifacts!();
        // THE paper's contribution: switch topologies via registers only.
        let mut e = engine();

        let cfg1 = presets::small_encoder(32, 1);
        let ws1 = weights::init_stack(5, cfg1.d_model, cfg1.heads, 1);
        e.program(&cfg1).unwrap();
        let p1 = e.prepare(&cfg1, &ws1).unwrap();
        let x1 = weights::init_input(6, cfg1.seq_len, cfg1.d_model);
        let o1 = e.run_encoder(&p1, &x1).unwrap();
        assert!(o1.max_abs_diff(&oracle(&cfg1, &ws1, &x1)) < 2e-3);
        let compiled_after_first = e.executor().compiled_count();

        // different seq len, width, head count, depth — registers only
        let cfg2 = TnnConfig::encoder(48, 128, 2, 2);
        let ws2 = weights::init_stack(7, cfg2.d_model, cfg2.heads, 2);
        e.program(&cfg2).unwrap();
        let p2 = e.prepare(&cfg2, &ws2).unwrap();
        let x2 = weights::init_input(8, cfg2.seq_len, cfg2.d_model);
        let o2 = e.run_encoder(&p2, &x2).unwrap();
        assert!(o2.max_abs_diff(&oracle(&cfg2, &ws2, &x2)) < 2e-3);

        assert_eq!(
            e.executor().compiled_count(),
            compiled_after_first,
            "reprogramming registers must not compile anything new"
        );
        // two topologies -> two cached programs, no hits yet
        assert_eq!(e.program_cache_stats(), (0, 2));
    }

    #[test]
    fn packed_and_per_head_qkv_agree() {
        require_artifacts!();
        let mut e = engine();
        let cfg = presets::small_encoder(48, 1);
        let ws = weights::init_stack(31, cfg.d_model, cfg.heads, 1);
        e.program(&cfg).unwrap();
        let p = e.prepare(&cfg, &ws).unwrap();
        let x = weights::init_input(32, cfg.seq_len, cfg.d_model);
        e.qkv_packed = true;
        let a = e.run_encoder(&p, &x).unwrap();
        e.qkv_packed = false;
        let b = e.run_encoder(&p, &x).unwrap();
        // At O2 the per-head path may run attn_fused while packed runs
        // attn_packed — fused-kernel band, not bit-level agreement.
        assert!(a.max_abs_diff(&b) < 1e-3, "{}", a.max_abs_diff(&b));
    }

    #[test]
    fn programming_state_is_exposed() {
        require_artifacts!();
        let mut e = engine();
        assert!(e.programmed_config().is_none(), "fresh registers hold no topology");
        let cfg = presets::small_encoder(32, 1);
        assert!(!e.is_programmed_for(&cfg));
        e.program(&cfg).unwrap();
        assert_eq!(e.programmed_config(), Some(cfg));
        assert!(e.is_programmed_for(&cfg));
        let other = TnnConfig::encoder(48, 128, 2, 1);
        assert!(!e.is_programmed_for(&other));
        e.program(&other).unwrap();
        assert!(e.is_programmed_for(&other));
        assert!(!e.is_programmed_for(&cfg));
    }

    #[test]
    fn fabric_constraints_are_enforced() {
        require_artifacts!();
        let mut e = engine();
        // dk != 64
        assert!(e.program(&TnnConfig::encoder(32, 256, 8, 1)).is_err());
        // too long
        assert!(e.program(&TnnConfig::encoder(256, 256, 4, 1)).is_err());
        // too wide
        assert!(e.program(&TnnConfig::encoder(32, 1024, 16, 1)).is_err());
        // fine
        assert!(e.program(&presets::small_encoder(64, 2)).is_ok());
    }

    #[test]
    fn wrong_register_state_is_rejected() {
        require_artifacts!();
        let mut e = engine();
        let cfg = presets::small_encoder(32, 1);
        let ws = weights::init_stack(9, cfg.d_model, cfg.heads, 1);
        e.program(&cfg).unwrap();
        let p = e.prepare(&cfg, &ws).unwrap();
        // reprogram to a different topology, then run with stale prepared stack
        e.program(&TnnConfig::encoder(48, 128, 2, 1)).unwrap();
        let x = weights::init_input(10, cfg.seq_len, cfg.d_model);
        assert!(e.run_encoder(&p, &x).is_err());
    }

    #[test]
    fn quantized_mode_is_close_but_not_identical() {
        require_artifacts!();
        let mut e = engine();
        let cfg = presets::small_encoder(32, 1);
        let ws = weights::init_stack(41, cfg.d_model, cfg.heads, 1);
        e.program(&cfg).unwrap();
        let p = e.prepare(&cfg, &ws).unwrap();
        let x = weights::init_input(42, cfg.seq_len, cfg.d_model);
        let full = e.run_encoder(&p, &x).unwrap();
        e.quantized = true;
        let quant = e.run_encoder(&p, &x).unwrap();
        let diff = full.max_abs_diff(&quant);
        assert!(diff > 1e-6, "quantization must actually do something");
        assert!(diff < 0.35, "int8 QDQ error out of band: {diff}");
    }

    #[test]
    fn fused_layer_matches_tiled_layer() {
        require_artifacts!();
        let mut e = engine();
        let cfg = presets::small_encoder(64, 1); // matches fused_small_layer
        let ws = weights::init_stack(11, cfg.d_model, cfg.heads, 1);
        e.program(&cfg).unwrap();
        let p = e.prepare(&cfg, &ws).unwrap();
        let x = weights::init_input(12, cfg.seq_len, cfg.d_model);
        let tiled = e.run_encoder(&p, &x).unwrap();
        let fused = e.run_fused_stack("small_layer", &x, &ws).unwrap();
        let diff = tiled.max_abs_diff(&fused);
        assert!(diff < 2e-3, "tiled vs fused artifact diff = {diff}");
    }

    #[test]
    fn program_cache_hits_and_reuses_runtime_tensors() {
        require_artifacts!();
        let mut e = engine();
        let cfg = presets::small_encoder(32, 2);
        let ws = weights::init_stack(55, cfg.d_model, cfg.heads, 2);
        e.program(&cfg).unwrap();
        let p = e.prepare(&cfg, &ws).unwrap();
        let x = weights::init_input(56, cfg.seq_len, cfg.d_model);
        let s0 = e.executor().stats();
        let a = e.run_encoder(&p, &x).unwrap();
        let s1 = e.executor().stats();
        let b = e.run_encoder(&p, &x).unwrap();
        let s2 = e.executor().stats();
        // first request builds the program (miss), second replays it (hit)
        assert_eq!(e.program_cache_stats(), (1, 1));
        assert!(a.max_abs_diff(&b) < 1e-6, "replays must be deterministic");
        let per_replay = e.cached_program(&cfg).unwrap().program.upload_count() as u64;
        // A miss uploads the 10 base runtime tensors plus both mask
        // families for every non-top length tier of the bucket, once.
        let runtime_set = 10 + 2 * (schedule::length_tiers(cfg.seq_len).len() as u64 - 1);
        assert_eq!(
            s1.uploads - s0.uploads,
            per_replay + runtime_set,
            "a miss uploads the per-topology runtime tensor set once"
        );
        assert_eq!(
            s2.uploads - s1.uploads,
            per_replay,
            "a hit re-uploads only the activation panels"
        );
        // identical dispatch count per replay
        assert_eq!(s2.dispatches - s1.dispatches, s1.dispatches - s0.dispatches);
    }

    #[test]
    fn opt_levels_cache_separately_and_o2_cuts_dispatches() {
        require_artifacts!();
        let mut e = engine();
        let cfg = presets::small_encoder(32, 2);
        let ws = weights::init_stack(61, cfg.d_model, cfg.heads, 2);
        e.program(&cfg).unwrap();
        let p = e.prepare(&cfg, &ws).unwrap();
        let x = weights::init_input(62, cfg.seq_len, cfg.d_model);

        e.opt_level = OptLevel::O0;
        e.run_encoder(&p, &x).unwrap(); // warm the O0 program
        let s0 = e.executor().stats();
        e.run_encoder(&p, &x).unwrap();
        let s1 = e.executor().stats();

        e.opt_level = OptLevel::O2;
        e.run_encoder(&p, &x).unwrap(); // warm the O2 program
        let s2 = e.executor().stats();
        e.run_encoder(&p, &x).unwrap();
        let s3 = e.executor().stats();

        assert_eq!(e.program_cache_stats().1, 2, "one miss per opt level");
        let (d0, u0) = (s1.dispatches - s0.dispatches, s1.uploads - s0.uploads);
        let (d2, u2) = (s3.dispatches - s2.dispatches, s3.uploads - s2.uploads);
        assert!(d2 < d0, "O2 must dispatch less ({d2} vs {d0})");
        assert!(u2 <= u0, "O2 must not upload more ({u2} vs {u0})");
        assert!(d2 + u2 < d0 + u0, "the optimized replay must be strictly cheaper");
        // The wave-scheduled program must expose real parallelism.
        let prog = e.cached_program(&cfg).unwrap();
        assert!(prog.program.wave_count() > 1);
        assert!(prog.program.max_wave_dispatches() >= cfg.heads);
    }

    #[test]
    fn zero_pool_shares_device_buffers_across_topologies() {
        require_artifacts!();
        let mut e = engine();
        let cfg1 = presets::small_encoder(32, 1);
        e.program(&cfg1).unwrap();
        e.cached_program(&cfg1).unwrap();
        assert_eq!(e.executor().stats().pool_hits, 0, "first topology misses the pool");
        let cfg2 = TnnConfig::encoder(48, 128, 2, 1);
        e.program(&cfg2).unwrap();
        e.cached_program(&cfg2).unwrap();
        assert_eq!(
            e.executor().stats().pool_hits,
            4,
            "the 4 zero accumulators are fabric constants shared by every topology"
        );
    }

    #[test]
    fn host_scratch_pool_recycles_across_requests() {
        require_artifacts!();
        let mut e = engine();
        let cfg = presets::small_encoder(32, 1);
        let ws = weights::init_stack(63, cfg.d_model, cfg.heads, 1);
        e.program(&cfg).unwrap();
        let p = e.prepare(&cfg, &ws).unwrap();
        let x = weights::init_input(64, cfg.seq_len, cfg.d_model);
        e.run_encoder(&p, &x).unwrap();
        let (_, misses_after_first) = e.tensor_pool_stats();
        e.run_encoder(&p, &x).unwrap();
        let (hits, misses) = e.tensor_pool_stats();
        assert_eq!(
            misses, misses_after_first,
            "steady state must allocate no new host scratch"
        );
        assert!(hits > 0, "the second request must recycle the first's buffers");
    }

    #[test]
    fn short_encoder_requests_run_in_their_bucket() {
        require_artifacts!();
        let mut e = engine();
        let cfg = presets::small_encoder(64, 2);
        let ws = weights::init_stack(71, cfg.d_model, cfg.heads, 2);
        e.program(&cfg).unwrap();
        let p = e.prepare(&cfg, &ws).unwrap();
        // rows = 16 picks the bucket-16 program: attention fences at the
        // bucket, so the oracle is a 16-length encoder run.
        let x = weights::init_input(72, 16, cfg.d_model);
        let got = e.run_encoder(&p, &x).unwrap();
        assert_eq!((got.rows, got.cols), (16, cfg.d_model));
        let cfg16 = TnnConfig { seq_len: 16, ..cfg };
        let want = oracle(&cfg16, &ws, &x);
        let diff = got.max_abs_diff(&want);
        assert!(diff < 3e-3, "bucketed engine vs 16-length oracle diff = {diff}");
        // Edge: exactly seq_len rows still runs (top bucket)…
        let full = weights::init_input(73, cfg.seq_len, cfg.d_model);
        assert!(e.run_encoder(&p, &full).is_ok());
        // …and one row over is a typed InvalidRequest, not a panic.
        let over = weights::init_input(74, cfg.seq_len + 1, cfg.d_model);
        assert!(matches!(e.run_encoder(&p, &over), Err(ServeError::InvalidRequest(_))));
        // Distinct buckets cache distinct programs (16 + 64), both below
        // the model's full length only when the request is short.
        assert_eq!(e.program_cache_stats().1, 2, "one miss per touched bucket");
    }

    #[test]
    fn short_requests_cost_fewer_cycles_than_the_dense_program() {
        require_artifacts!();
        let mut e = engine();
        let cfg = presets::small_encoder(64, 2);
        e.program(&cfg).unwrap();
        let dense = e.cycle_estimate(&cfg).unwrap();
        // The ISSUE acceptance bound: a request at ≤ seq_len/4 prices
        // strictly below the dense max-length program.
        let quarter = e.cycle_estimate_rows(&cfg, cfg.seq_len / 4).unwrap();
        assert!(
            quarter.total_cycles < dense.total_cycles,
            "quarter={} dense={}",
            quarter.total_cycles,
            dense.total_cycles
        );
        // Full-length requests price exactly as the dense estimate.
        let full = e.cycle_estimate_rows(&cfg, cfg.seq_len).unwrap();
        assert_eq!(full.total_cycles, dense.total_cycles);
    }

    #[test]
    fn cycle_estimate_replays_the_cached_program_within_band() {
        require_artifacts!();
        let mut e = engine();
        let cfg = presets::small_encoder(64, 2);
        e.program(&cfg).unwrap();
        let rep = e.cycle_estimate(&cfg).unwrap();
        let cached = e.cached_program(&cfg).unwrap();
        // A skippable program carries every tier; a full-length replay
        // dispatches exactly the live (top-tier) subset.
        assert_eq!(rep.dispatches as usize, cached.program.live_dispatch_count(cfg.seq_len));
        let tiles = e.fabric_constants().tile_config();
        let ana = crate::accel::latency::model_latency(&cfg, &tiles);
        let err = (rep.total_cycles as f64 - ana.total_cycles as f64).abs()
            / ana.total_cycles as f64;
        assert!(err < 0.06, "schedule replay vs closed form err = {err:.4}");
    }
}
