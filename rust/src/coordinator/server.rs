//! The serving loop — a pool of ADAPTOR fabrics behind one dispatcher,
//! fronted by the Serving API v1 typed job surface ([`super::api`]).
//!
//! `PjRtLoadedExecutable` is not `Send`, so every fabric is a dedicated
//! **worker thread** that constructs its own `TileEngine` locally and
//! drains batches from a per-fabric mpsc queue.  A single **dispatcher**
//! thread owns the batcher (per-model, QoS-ordered ready queues) and
//! assigns ready batches to fabrics under a [`SchedulePolicy`]: with
//! `CostAware` (the default) each candidate fabric is scored by queue
//! depth **plus the predicted upload cost of the model's weight stack
//! when it is not resident there**, so model↔fabric affinity emerges
//! from weight residency; with `Affinity` a batch is routed to a fabric
//! already programmed for its model (avoiding a register reprogram),
//! falling back to the least-loaded fabric; with `RoundRobin` fabrics
//! are cycled regardless of programming state (the baseline the
//! affinity tests compare against).
//!
//! Each fabric worker owns a [`WeightResidencyManager`]
//! ([`super::residency`]): weight stacks upload *lazily* on first
//! dispatch and live in device weight memory as a capacity-bounded,
//! traffic-weighted-LRU cache, pinned while the model has live
//! generations in flight.  Workers report their resident set back on
//! every completion event, correcting the dispatcher's placement
//! belief, and a hot model whose queue deepens past
//! [`ResidencyPolicy::prefetch_depth`] gets its stack prefetched to a
//! second fabric off the dispatch path.
//!
//! Each fabric worker is split in two along the job-kind axis
//! (**continuous batching**; see DESIGN.md):
//!
//! * the **batch executor** serves model-homogeneous *encode* batches
//!   whole, exactly as before;
//! * the **sequence scheduler** keeps a live set of up to
//!   [`ServerConfig::max_seqs`] in-flight *generations* (one
//!   [`GenSession`] — KV cache + position — per sequence, all sharing
//!   the cached step program) and each round (1) admits new prefills
//!   under the capacity budget shared with encode batches, (2) runs
//!   **one decode step per live sequence** in QoS order, streaming its
//!   token and observing its `CancelToken` and deadline between steps,
//!   (3) retires finished / cancelled / expired sequences immediately,
//!   freeing their KV cache, and backfills from the batcher.
//!
//! Generations are acked to the dispatcher **at admission**, so for
//! them [`ServerConfig::queue_depth`] meters *per-round admissions*
//! into the live set — not whole jobs held to completion.
//!
//! Serving API v1 semantics on top of the pool:
//!
//! * **one submission path** — [`Server::submit`] takes a
//!   [`Submission`] (encode or generation) plus [`QoS`] and returns a
//!   [`JobHandle`];
//! * **QoS flows end to end** — priority orders the ready queues,
//!   deadlines are swept while queued (typed
//!   [`ServeError::DeadlineExceeded`], counted in metrics), re-checked
//!   at execution start, and — for in-flight generations — enforced
//!   **between decode rounds**; dispatch is **capacity-gated**
//!   ([`ServerConfig::queue_depth`] batches outstanding per fabric) so
//!   priority is decided in the queue, not in a deep fabric FIFO;
//! * **cancellation** — observed while queued, before execution, and
//!   between decode rounds; a cancelled generation stops within one
//!   decode step, leaves the KV cache and pools clean, and records no
//!   partial samples;
//! * **streaming** — generation tokens are delivered on the handle as
//!   decode steps complete; their concatenation is bit-identical to the
//!   final transcript, and to the one-job-at-a-time transcript even
//!   when sequences interleave;
//! * **live metrics** — [`Server::metrics`] snapshots the running pool
//!   (including in-flight occupancy and time-to-first-token);
//!   [`Server::shutdown`] is no longer the only metrics exit.
//!
//! **Cross-fabric sharding** ([`super::shard`]): a model whose weight
//! footprint exceeds ONE fabric's envelope
//! ([`ResidencyPolicy::capacity_bytes`]) is admitted anyway when its
//! contiguous layer-range chain fits the *pool* — [`Server::start`]
//! partitions it with [`ShardPlan::partition_for_envelope`] and refuses
//! only chains longer than the pool.  The dispatcher co-places each
//! round on `K` distinct live fabrics ([`PoolScheduler::place_chain`],
//! preferring fabrics already homing a stage's shard stack) and wires
//! the stages with mpsc **activation handoff channels**; each stage
//! worker streams relays — run the stage, forward the activation —
//! so a `K`-shard encode overlaps `K` in-flight requests (stage *i*
//! computes request *r* while stage *i+1* computes *r−1*).  Sharded
//! serving is encode-only: KV locality pins generation to one fabric.
//!
//! `pool_size = 1` reproduces the paper's host software exactly: one
//! fabric, one register file, reprograms on every model switch — the
//! paper-reproduction path is unchanged.  Clients submit from any
//! thread.
//!
//! Failure semantics (each was a silent failure in a predecessor):
//! * a failed `engine.program()` fails the **whole batch** with
//!   [`ServeError::ProgramFailed`] — requests are never run against the
//!   previous model's register state;
//! * batches are counted in metrics only once actually served;
//! * an out-of-range [`ModelSpec::with_affinity`] hint is refused at
//!   [`Server::start`] ([`ServeError::AffinityOutOfRange`]) instead of
//!   being silently ignored at dispatch;
//! * a request whose deadline expired while queued completes with
//!   [`ServeError::DeadlineExceeded`] and is counted, never served late
//!   or dropped silently;
//! * `shutdown()` surfaces worker panics instead of returning empty
//!   metrics as if the run were clean.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::api::{
    CancelToken, EncodeOutput, GenerateOutput, JobEvent, JobHandle, JobOutput, Priority, QoS,
    ServeError, Submission, Timing, TokenEvent,
};
use super::batcher::{BatchPolicy, Batcher, Pending};
use super::engine::{AttentionMode, GenSession, OptLevel, PreparedStack, TileEngine};
use super::metrics::Metrics;
use super::residency::{self, ResidencyMode, ResidencyPolicy, WeightResidencyManager};
use super::router::{ModelSpec, Router};
use super::shard::{self, ShardPlan};
use crate::accel::schedule;
use crate::model::weights::Mat;
use crate::runtime::Tensor;

/// One inference request (v0 surface; see [`Submission::Encode`]).
#[derive(Debug, Clone)]
pub struct Request {
    pub model: String,
    pub input: Mat,
}

/// The v0 encode response shape, produced by the [`Server::infer`] shim.
#[derive(Debug)]
pub struct Response {
    pub output: Mat,
    /// End-to-end latency: submit → response ready (queue + compute).
    pub latency: Duration,
    /// Time spent executing on the fabric.
    pub compute: Duration,
    /// Time between submit and this request *starting to execute* —
    /// includes batching delay, dispatch, any register reprogram, and
    /// (for the 2nd..Nth members of a batch) the compute time of
    /// earlier members, so `latency == queue_wait + compute` holds.
    pub queue_wait: Duration,
}

/// One generation request (v0 surface; see [`Submission::Generate`]).
#[derive(Debug, Clone)]
pub struct GenerateRequest {
    pub model: String,
    pub prompt: Mat,
    pub source: Option<Mat>,
    pub steps: usize,
}

/// The v0 generation response shape, produced by the
/// [`Server::generate`] shim.
#[derive(Debug)]
pub struct GenerateResponse {
    /// Generated activation rows, `steps × d_model`.
    pub rows: Mat,
    /// Greedy token ids, one per step.
    pub tokens: Vec<usize>,
    /// End-to-end latency (queue + compute).
    pub latency: Duration,
    pub queue_wait: Duration,
    /// Source encode + prompt prefill (cache population) time.
    pub prefill: Duration,
    /// Per-token decode-step times (`steps - 1` entries).
    pub step_times: Vec<Duration>,
}

/// How the dispatcher assigns ready batches to pool fabrics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulePolicy {
    /// Route to a fabric already programmed for the batch's model; fall
    /// back to an unprogrammed or least-loaded fabric.  Router affinity
    /// hints ([`ModelSpec::with_affinity`]) take precedence.
    Affinity,
    /// Cycle through fabrics regardless of programming state (baseline
    /// scheduler; maximizes reprograms under mixed-model load).
    RoundRobin,
    /// Score each candidate fabric by queue depth **plus a predicted
    /// reprogram penalty** — the upload cost of the model's weight
    /// stack when it is not device-resident there, priced in queued
    /// request equivalents by [`residency::upload_penalty_requests`] —
    /// so model↔fabric affinity emerges from weight residency instead
    /// of static programming state.  Router hints still pin absolutely.
    /// The serving default.
    CostAware,
}

/// Fault injection for failure-path regression tests.  Inert by default;
/// production configs never set it.
#[derive(Debug, Clone, Default)]
pub struct FaultInjection {
    /// Treat `engine.program()` as failing for this model name, exercising
    /// the batch-fails-on-programming-error path.
    pub fail_program_for: Option<String>,
}

/// Server construction parameters.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub artifact_dir: std::path::PathBuf,
    pub models: Vec<ModelSpec>,
    pub policy: BatchPolicy,
    pub attention: AttentionMode,
    /// TileProgram optimization level every fabric serves at by default
    /// (the pass pipeline of `accel::schedule::opt`; `O2` — dedup,
    /// dispatch fusion, wave scheduling, slot compaction — is the
    /// serving default).  [`QoS::opt_level`] overrides it per request.
    pub opt_level: OptLevel,
    /// Number of fabric workers.  `1` (the default) is the paper's
    /// single-fabric host software.
    pub pool_size: usize,
    pub schedule: SchedulePolicy,
    /// Batches outstanding on a fabric before the dispatcher holds that
    /// fabric's ready work back in the (QoS-ordered) queue — gated per
    /// target fabric, so a hot affinity fabric can never grow an
    /// unbounded FIFO.  `2` double-buffers: one batch executes while the
    /// next is staged, and priority still decides everything behind
    /// those.  `1` gives the strictest priority ordering at a small
    /// utilization cost; `0` is refused at [`Server::start`].
    pub queue_depth: usize,
    /// In-flight generation sequences a fabric's sequence scheduler
    /// keeps live at once (continuous batching).  Each live sequence
    /// holds one KV cache on the device pool; a decode round runs one
    /// step per live sequence, so `max_seqs` bounds both pool pressure
    /// and the worst-case inter-token latency of any one sequence.
    /// `1` serializes generations (the paper's one-at-a-time host
    /// loop); `0` is refused at [`Server::start`].
    pub max_seqs: usize,
    /// How each fabric worker manages its device weight memory (the
    /// [`WeightResidencyManager`] it runs): capacity envelope, EWMA
    /// decay, prefetch trigger depth, and the managed-vs-reprogram-
    /// always mode switch.
    pub residency: ResidencyPolicy,
    pub fault: FaultInjection,
}

impl ServerConfig {
    pub fn new(models: Vec<ModelSpec>) -> Self {
        ServerConfig {
            artifact_dir: crate::runtime::default_artifact_dir(),
            models,
            policy: BatchPolicy::default(),
            attention: AttentionMode::Fused,
            opt_level: OptLevel::O2,
            pool_size: 1,
            schedule: SchedulePolicy::CostAware,
            queue_depth: 2,
            max_seqs: 4,
            residency: ResidencyPolicy::default(),
            fault: FaultInjection::default(),
        }
    }
}

/// Lock that survives a poisoning panic on another thread — the panic
/// itself is surfaced by `shutdown()`'s join; metrics reads must not
/// double-panic on the way there.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// One submitted job in flight through the pool.
struct JobState {
    submission: Submission,
    qos: QoS,
    events: Sender<JobEvent>,
    cancel: CancelToken,
}

impl JobState {
    fn model(&self) -> &str {
        self.submission.model()
    }

    /// Terminate the job with `err` (its handle observes `Failed`).
    fn fail(self, err: ServeError) {
        let _ = self.events.send(JobEvent::Failed(err));
    }
}

/// A job as the fabric worker receives it: payload + queue timestamps.
struct WorkItem {
    job: JobState,
    arrived: Instant,
    deadline: Option<Instant>,
}

/// Client → dispatcher messages.
enum Msg {
    Work { job: JobState, arrived: Instant, deadline: Option<Instant> },
    Shutdown { reply: Sender<Result<(), ServeError>> },
}

/// Dispatcher → fabric messages (ordered per fabric: a `Shutdown` sent
/// after a `Batch` is processed after it).
enum FabricMsg {
    Batch {
        model: String,
        items: Vec<WorkItem>,
        /// The dispatcher's arrival-rate EWMA for the model, seeding the
        /// worker's traffic-weighted LRU heat (see `residency`).
        rate: f64,
    },
    /// Stage `model`'s weight stack between batches (no work attached):
    /// a later dispatch then hits residency instead of paying the
    /// upload inline.  Best-effort — a failure costs nothing that the
    /// next dispatch would not have paid anyway.
    Prefetch { model: String, rate: f64 },
    /// One stage of a sharded encode round: this fabric runs shard
    /// `shard.0` of `shard.1` of `model`'s chain (see [`super::shard`]).
    /// The head stage (`upstream == None`) owns the batch `items` and
    /// pads them into stage activations; every other stage drains
    /// `upstream` until the peer closes it; every stage but the tail
    /// forwards on `downstream`.  `expected` sizes the round for
    /// capacity accounting — a stage acks that many served even when
    /// upstream cancellations shrank what actually arrived, so the
    /// dispatcher's in-flight belief stays balanced.
    ShardStage {
        model: String,
        shard: (u16, u16),
        rate: f64,
        items: Vec<WorkItem>,
        upstream: Option<Receiver<ShardRelay>>,
        downstream: Option<Sender<ShardRelay>>,
        expected: usize,
    },
    Shutdown { reply: Sender<()> },
}

/// One encode request travelling a shard chain between fabric workers:
/// the job rides with its padded `[SL_MAX, DMODEL_MAX]` stage
/// activation so any stage can fail it typed and the tail can reply on
/// the job's own event channel.
struct ShardRelay {
    job: JobState,
    arrived: Instant,
    deadline: Option<Instant>,
    /// When the head stage started executing — the queue-wait/compute
    /// boundary for the whole chain's [`Timing`].
    exec_start: Instant,
    /// Live rows of the original request (the tail's crop height).
    live: usize,
    activation: Tensor,
}

/// Fabric → dispatcher completion events, one per batch (separate
/// channel so the dispatcher can still detect all *clients*
/// disconnecting on the main channel).  `died` marks the worker's
/// death notice (sent from a panic-unwind guard) so the capacity gate
/// never waits on a fabric that will not complete anything again.
struct FabricEvent {
    fabric: usize,
    served: usize,
    died: bool,
    /// The event acks a dispatched batch, freeing one capacity slot.
    /// Prefetch acks and death notices leave capacity accounting alone.
    batch: bool,
    /// Authoritative resident-model snapshot from the worker's residency
    /// manager; corrects the dispatcher's optimistic placement belief.
    resident: Option<Vec<String>>,
}

/// Panic-unwind guard a fabric worker arms after warmup: dropping it
/// with `armed` still set (i.e. unwinding) tells the dispatcher the
/// fabric is gone, so its queued work fails with a typed error instead
/// of hanging behind a capacity slot that can never free.
struct DeathNotice {
    fabric: usize,
    events: Sender<FabricEvent>,
    armed: bool,
}

impl Drop for DeathNotice {
    fn drop(&mut self) {
        if self.armed {
            let _ = self.events.send(FabricEvent {
                fabric: self.fabric,
                served: 0,
                died: true,
                batch: false,
                resident: None,
            });
        }
    }
}

/// Per-fabric programming/load state tracked by the dispatcher.  This is
/// the dispatcher's *belief* (programming happens on the worker), which is
/// exact under normal operation and conservative under failures.
#[derive(Debug, Default, Clone)]
struct FabricState {
    current_model: Option<String>,
    inflight: usize,
    /// Batches dispatched but not yet completed — the unit the
    /// capacity gate ([`ServerConfig::queue_depth`]) meters.
    batches: usize,
    /// The worker sent its death notice: never place work here again.
    dead: bool,
    /// Models believed device-resident on the fabric: inserted
    /// optimistically at [`PoolScheduler::pick_within_depth`], replaced
    /// by the worker's authoritative snapshot on every completion
    /// event.  [`SchedulePolicy::CostAware`] scores against this set.
    resident: BTreeSet<String>,
}

/// Pure batch→fabric assignment logic (unit-testable without artifacts).
#[derive(Debug)]
pub struct PoolScheduler {
    policy: SchedulePolicy,
    states: Vec<FabricState>,
    rr_next: usize,
    /// Per-model reprogram penalty in queued-request equivalents
    /// ([`residency::upload_penalty_requests`]); consulted by
    /// [`SchedulePolicy::CostAware`] when a model is not believed
    /// resident on a candidate fabric.
    penalties: BTreeMap<String, f64>,
}

impl PoolScheduler {
    pub fn new(policy: SchedulePolicy, fabrics: usize) -> Self {
        assert!(fabrics > 0, "a pool needs at least one fabric");
        PoolScheduler {
            policy,
            states: vec![FabricState::default(); fabrics],
            rr_next: 0,
            penalties: BTreeMap::new(),
        }
    }

    /// Register `model`'s predicted upload cost (in queued-request
    /// equivalents) for cost-aware scoring.  Unpriced models default to
    /// 1.0 — one request's worth.
    pub fn set_upload_penalty(&mut self, model: &str, penalty: f64) {
        self.penalties.insert(model.to_string(), penalty);
    }

    /// Replace the resident-set belief for `fabric` with the worker's
    /// authoritative snapshot (carried on every completion event).
    pub fn note_residency(&mut self, fabric: usize, resident: &[String]) {
        if let Some(s) = self.states.get_mut(fabric) {
            s.resident = resident.iter().cloned().collect();
        }
    }

    /// Cost-aware placement score: queue depth plus the predicted
    /// reprogram penalty when the stack would have to be uploaded.
    fn place_cost(&self, s: &FabricState, model: &str) -> f64 {
        let penalty = if s.resident.contains(model) {
            0.0
        } else {
            self.penalties.get(model).copied().unwrap_or(1.0)
        };
        s.inflight as f64 + penalty
    }

    /// The fabric to stage a hot `model` on *in addition to* where it
    /// already lives — `Some` only when the model is believed resident
    /// on exactly one live fabric (zero means normal dispatch will
    /// upload it anyway; two or more means it is already spread).
    /// Commits the belief so the trigger does not re-fire every round.
    pub fn prefetch_target(&mut self, model: &str) -> Option<usize> {
        let copies =
            self.states.iter().filter(|s| !s.dead && s.resident.contains(model)).count();
        if copies != 1 {
            return None;
        }
        let target = self
            .states
            .iter()
            .enumerate()
            .filter(|(_, s)| !s.dead && !s.resident.contains(model))
            .min_by_key(|(i, s)| (s.inflight, *i))
            .map(|(i, _)| i)?;
        self.states[target].resident.insert(model.to_string());
        Some(target)
    }

    /// The fabric [`Self::pick`] would choose for `model` among those
    /// below `depth` outstanding batches, **without committing** the
    /// assignment.  `None` when no eligible fabric has room: a *pinned*
    /// model waits for its pinned fabric (that is what pinning means);
    /// an affinity model falls back past full fabrics to any fabric
    /// with room (queueing behind a different model costs a reprogram
    /// but beats an unbounded FIFO); round-robin scans forward from the
    /// cursor to the first fabric with room.
    fn choose_within_depth(&self, model: &str, hint: Option<usize>, depth: usize) -> Option<usize> {
        let n = self.states.len();
        let fits = |i: usize| !self.states[i].dead && self.states[i].batches < depth;
        match self.policy {
            SchedulePolicy::RoundRobin => {
                (0..n).map(|k| (self.rr_next + k) % n).find(|&i| fits(i))
            }
            SchedulePolicy::Affinity => {
                if let Some(h) = hint.filter(|h| *h < n) {
                    return fits(h).then_some(h);
                }
                if let Some(i) = self
                    .states
                    .iter()
                    .enumerate()
                    .filter(|(i, s)| fits(*i) && s.current_model.as_deref() == Some(model))
                    .min_by_key(|(_, s)| s.inflight)
                    .map(|(i, _)| i)
                {
                    return Some(i);
                }
                // Least-loaded fallback among fabrics with room; among
                // equals prefer a fabric with nothing programmed yet over
                // evicting a resident model, then the lowest index.
                self.states
                    .iter()
                    .enumerate()
                    .filter(|(i, _)| fits(*i))
                    .min_by_key(|(i, s)| (s.inflight, s.current_model.is_some(), *i))
                    .map(|(i, _)| i)
            }
            SchedulePolicy::CostAware => {
                if let Some(h) = hint.filter(|h| *h < n) {
                    return fits(h).then_some(h);
                }
                // Queue depth + predicted upload cost; among equal
                // scores prefer the fabric already holding the stack,
                // then the lowest index (determinism).
                self.states
                    .iter()
                    .enumerate()
                    .filter(|(i, _)| fits(*i))
                    .min_by(|(i, a), (j, b)| {
                        self.place_cost(a, model)
                            .total_cmp(&self.place_cost(b, model))
                            .then_with(|| {
                                b.resident.contains(model).cmp(&a.resident.contains(model))
                            })
                            .then_with(|| i.cmp(j))
                    })
                    .map(|(i, _)| i)
            }
        }
    }

    /// Whether a batch of `model` could be placed right now under the
    /// per-fabric `depth` gate (the dispatcher's pre-pop check).
    pub fn can_place(&self, model: &str, hint: Option<usize>, depth: usize) -> bool {
        self.choose_within_depth(model, hint, depth).is_some()
    }

    /// Whether a batch of `model` could EVER be placed — false when
    /// every eligible fabric (the pinned one, or the whole live pool)
    /// is dead, in which case queued work must fail instead of waiting
    /// on a capacity slot that will never free.
    pub fn can_place_ever(&self, model: &str, hint: Option<usize>) -> bool {
        self.choose_within_depth(model, hint, usize::MAX).is_some()
    }

    /// The `k` **distinct** live fabrics a shard chain of `model` would
    /// occupy under the per-fabric `depth` gate — stage `i` runs on the
    /// `i`-th entry.  Stages greedily prefer a fabric already believed
    /// to hold their shard stack (keyed by [`shard::residency_key`]),
    /// then the least-loaded, then the lowest index, so a warmed chain
    /// reuses its homes round after round instead of re-uploading
    /// shards.  Pure; commits nothing.  `None` when fewer than `k`
    /// distinct live fabrics have room.
    fn choose_chain(&self, model: &str, k: usize, depth: usize) -> Option<Vec<usize>> {
        let n = self.states.len();
        let mut chain: Vec<usize> = Vec::with_capacity(k);
        for stage in 0..k {
            let key = shard::residency_key(model, stage, k);
            let pick = (0..n)
                .filter(|i| {
                    let s = &self.states[*i];
                    !s.dead && s.batches < depth && !chain.contains(i)
                })
                .min_by_key(|i| {
                    let s = &self.states[*i];
                    (!s.resident.contains(&key), s.inflight, *i)
                })?;
            chain.push(pick);
        }
        Some(chain)
    }

    /// Whether a `k`-stage shard chain of `model` could be co-placed
    /// right now under the `depth` gate (the dispatcher's pre-pop check
    /// for sharded models, the chain analog of [`Self::can_place`]).
    pub fn can_place_chain(&self, model: &str, k: usize, depth: usize) -> bool {
        self.choose_chain(model, k, depth).is_some()
    }

    /// Whether a `k`-stage chain could EVER be placed: a chain needs
    /// `k` *distinct* live fabrics, so once deaths shrink the pool
    /// below `k` the model's queued work must fail typed instead of
    /// waiting on fabrics that will never come back.
    pub fn can_place_chain_ever(&self, k: usize) -> bool {
        self.states.iter().filter(|s| !s.dead).count() >= k
    }

    /// Co-place one sharded round of `model` on a `k`-fabric chain and
    /// account for it: every chain fabric takes one batch slot and
    /// `batch_len` in-flight requests — each request visits every
    /// stage, and each stage acks its own completion event.  Commits
    /// the optimistic per-stage shard-residency belief exactly as
    /// [`Self::pick_within_depth`] does for whole models; the workers'
    /// authoritative snapshots correct it.  `None` when
    /// [`Self::can_place_chain`] would be false.
    pub fn place_chain(
        &mut self,
        model: &str,
        k: usize,
        batch_len: usize,
        depth: usize,
    ) -> Option<Vec<usize>> {
        let chain = self.choose_chain(model, k, depth)?;
        for (stage, &f) in chain.iter().enumerate() {
            let s = &mut self.states[f];
            s.current_model = Some(model.to_string());
            s.resident.insert(shard::residency_key(model, stage, k));
            s.inflight += batch_len;
            s.batches += 1;
        }
        Some(chain)
    }

    /// Record a worker's death notice: the fabric takes no further
    /// work, and its stuck capacity accounting is released.
    pub fn mark_dead(&mut self, fabric: usize) {
        if let Some(s) = self.states.get_mut(fabric) {
            s.dead = true;
            s.batches = 0;
            s.inflight = 0;
        }
    }

    /// The fabric [`Self::pick`] would choose, ignoring capacity —
    /// pure; commits nothing.
    pub fn preview(&self, model: &str, hint: Option<usize>) -> usize {
        self.choose_within_depth(model, hint, usize::MAX).expect("a live fabric exists")
    }

    /// Choose the fabric for a ready batch of `model` under the
    /// per-fabric `depth` gate and account for it (`batch_len` requests
    /// become in-flight on the chosen fabric).  `None` when
    /// [`Self::can_place`] would be false.
    pub fn pick_within_depth(
        &mut self,
        model: &str,
        hint: Option<usize>,
        batch_len: usize,
        depth: usize,
    ) -> Option<usize> {
        let chosen = self.choose_within_depth(model, hint, depth)?;
        if self.policy == SchedulePolicy::RoundRobin {
            self.rr_next = (chosen + 1) % self.states.len();
        }
        let s = &mut self.states[chosen];
        s.current_model = Some(model.to_string());
        // Optimistic residency belief: the worker will make this stack
        // resident before serving; the snapshot on its completion event
        // corrects any divergence (e.g. under `ReprogramAlways`).
        s.resident.insert(model.to_string());
        s.inflight += batch_len;
        s.batches += 1;
        Some(chosen)
    }

    /// Choose the fabric for a ready batch of `model` and account for it
    /// (`batch_len` requests become in-flight on the chosen fabric).
    pub fn pick(&mut self, model: &str, hint: Option<usize>, batch_len: usize) -> usize {
        self.pick_within_depth(model, hint, batch_len, usize::MAX).expect("pool is non-empty")
    }

    /// A fabric reported one batch of `served` requests finished.
    pub fn complete(&mut self, fabric: usize, served: usize) {
        if let Some(s) = self.states.get_mut(fabric) {
            s.inflight = s.inflight.saturating_sub(served);
            s.batches = s.batches.saturating_sub(1);
        }
    }

    /// The model the scheduler believes `fabric` is programmed for.
    pub fn current_model(&self, fabric: usize) -> Option<&str> {
        self.states.get(fabric).and_then(|s| s.current_model.as_deref())
    }

    pub fn inflight(&self, fabric: usize) -> usize {
        self.states.get(fabric).map(|s| s.inflight).unwrap_or(0)
    }
}

/// Handle to the running server.
pub struct Server {
    tx: Sender<Msg>,
    router: Router,
    dispatcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    fabric_metrics: Vec<Arc<Mutex<Metrics>>>,
    queue_metrics: Arc<Mutex<Metrics>>,
    started: Instant,
}

impl Server {
    /// Start the fabric pool; blocks until every fabric is warmed up
    /// (artifacts compiled and every model validated against the
    /// fabric constraints) or fails.  Weight stacks are **not**
    /// uploaded here: each worker's residency manager uploads them
    /// lazily on first dispatch, within its capacity envelope.
    pub fn start(cfg: ServerConfig) -> Result<Self, ServeError> {
        if cfg.pool_size == 0 {
            return Err(ServeError::config("pool_size must be >= 1"));
        }
        if cfg.queue_depth == 0 {
            return Err(ServeError::config(
                "queue_depth must be >= 1 (batches outstanding per fabric)",
            ));
        }
        if cfg.max_seqs == 0 {
            return Err(ServeError::config(
                "max_seqs must be >= 1 (in-flight generations per fabric)",
            ));
        }
        // Affinity hints are validated against the actual pool here —
        // an out-of-range hint used to be silently dropped at dispatch
        // (`filter(|h| *h < n)`), turning a pinning misconfiguration
        // into an invisible scheduling change.
        for spec in &cfg.models {
            if let Some(f) = spec.preferred_fabric {
                if f >= cfg.pool_size {
                    return Err(ServeError::AffinityOutOfRange {
                        model: spec.name.clone(),
                        fabric: f,
                        pool_size: cfg.pool_size,
                    });
                }
            }
        }
        // Router lives on the submit side for fail-fast validation.
        let mut router = Router::new(crate::accel::registers::SynthMaxima::artifact_default());
        for spec in &cfg.models {
            router.register(spec.clone())?;
        }

        // Host-side fabric constants (manifest-backed when artifacts
        // exist, synth defaults otherwise) — shared by the pool-fit
        // admission below and the upload-penalty pricing after spawn.
        let fc = match crate::runtime::Manifest::load(&cfg.artifact_dir) {
            Ok(m) => schedule::FabricConstants::from_manifest(&m),
            Err(_) => schedule::FabricConstants::artifact_default(),
        };
        // Pool-fit admission: a model bigger than ONE fabric's weight
        // envelope is partitioned into a contiguous layer-range shard
        // chain and served across that many fabrics — refused only when
        // the chain cannot fit the *pool*.  Oversize generation models
        // have no sharded path (KV locality pins generation to one
        // fabric), and a pinned chain is a contradiction (it spans
        // distinct fabrics by construction): both refuse typed here,
        // at start, not per-request mid-traffic.
        for spec in &cfg.models {
            let bytes = residency::weight_footprint_bytes(&spec.cfg, &fc);
            if bytes <= cfg.residency.capacity_bytes {
                continue;
            }
            if spec.cfg.dec_layers > 0 {
                return Err(ServeError::config(format!(
                    "model '{}' needs {bytes} B of weight memory, over the fabric envelope \
                     of {} B, and has decoder layers — sharded serving is encode-only \
                     (KV locality pins generation to one fabric)",
                    spec.name, cfg.residency.capacity_bytes
                )));
            }
            let plan =
                ShardPlan::partition_for_envelope(&spec.cfg, &fc, cfg.residency.capacity_bytes)?;
            let k = plan.shards.len();
            if k > cfg.pool_size {
                return Err(ServeError::config(format!(
                    "model '{}' needs a {k}-shard chain under the {} B fabric envelope but \
                     the pool has only {} fabrics — it fits neither one fabric nor the pool",
                    spec.name, cfg.residency.capacity_bytes, cfg.pool_size
                )));
            }
            if spec.preferred_fabric.is_some() {
                return Err(ServeError::config(format!(
                    "model '{}' is pinned to one fabric but needs a {k}-shard chain \
                     spanning {k} distinct fabrics — drop the affinity hint",
                    spec.name
                )));
            }
        }
        let plans = shard_plans(&cfg, &fc);

        let (tx, rx) = mpsc::channel::<Msg>();
        let (etx, erx) = mpsc::channel::<FabricEvent>();

        let mut fabric_txs = Vec::with_capacity(cfg.pool_size);
        let mut workers = Vec::with_capacity(cfg.pool_size);
        let mut readys = Vec::with_capacity(cfg.pool_size);
        let mut fabric_metrics = Vec::with_capacity(cfg.pool_size);
        for id in 0..cfg.pool_size {
            let (ftx, frx) = mpsc::channel::<FabricMsg>();
            let (ready_tx, ready_rx) = mpsc::channel::<Result<(), ServeError>>();
            let events = etx.clone();
            let fcfg = cfg.clone();
            let metrics = Arc::new(Mutex::new(Metrics::for_fabric(id)));
            let worker_metrics = metrics.clone();
            let worker = std::thread::Builder::new()
                .name(format!("adaptor-fabric-{id}"))
                .spawn(move || fabric_thread(id, fcfg, frx, ready_tx, events, worker_metrics))
                .expect("spawning fabric thread");
            fabric_txs.push(ftx);
            workers.push(worker);
            readys.push((id, ready_rx));
            fabric_metrics.push(metrics);
        }
        drop(etx); // dispatcher holds the receiver; fabrics hold the clones
        for (id, ready_rx) in readys {
            ready_rx
                .recv()
                .map_err(|_| ServeError::pool_lost(format!("fabric {id} died during warmup")))??;
        }

        let hints: BTreeMap<String, usize> = cfg
            .models
            .iter()
            .filter_map(|s| s.preferred_fabric.map(|f| (s.name.clone(), f)))
            .collect();
        let queue_metrics = Arc::new(Mutex::new(Metrics::default()));
        // Price every model's upload penalty once so cost-aware
        // placement can weigh a predicted reprogram against queue depth
        // without touching an engine.
        let mut sched = PoolScheduler::new(cfg.schedule, cfg.pool_size);
        for spec in &cfg.models {
            let penalty = residency::upload_penalty_requests(&spec.cfg, &fc);
            sched.set_upload_penalty(&spec.name, penalty);
        }
        let ctx = DispatchCtx {
            policy: cfg.policy,
            queue_depth: cfg.queue_depth,
            residency: cfg.residency,
            rx,
            events: erx,
            fabrics: fabric_txs,
            sched,
            hints,
            plans,
            queue_metrics: queue_metrics.clone(),
        };
        let dispatcher = std::thread::Builder::new()
            .name("adaptor-dispatch".into())
            .spawn(move || dispatcher_thread(ctx))
            .expect("spawning dispatcher thread");

        Ok(Server {
            tx,
            router,
            dispatcher: Some(dispatcher),
            workers,
            fabric_metrics,
            queue_metrics,
            started: Instant::now(),
        })
    }

    pub fn models(&self) -> Vec<&str> {
        self.router.names()
    }

    /// Serving API v1: the single submission path.  Validates the
    /// submission against the registry fail-fast, enqueues it with its
    /// [`QoS`], and returns the [`JobHandle`] to stream/poll/wait/cancel.
    pub fn submit(&self, submission: Submission, qos: QoS) -> Result<JobHandle, ServeError> {
        match &submission {
            Submission::Encode { model, input } => {
                self.router.route(model, input.rows, input.cols)?;
            }
            Submission::Generate { model, prompt, source, steps } => {
                self.router.route_generate(
                    model,
                    (prompt.rows, prompt.cols),
                    source.as_ref().map(|s| (s.rows, s.cols)),
                    *steps,
                )?;
            }
        }
        let arrived = Instant::now();
        let deadline = qos.deadline.map(|d| arrived + d);
        let (events, event_rx) = mpsc::channel();
        let cancel = CancelToken::new();
        let job = JobState { submission, qos, events, cancel: cancel.clone() };
        self.tx
            .send(Msg::Work { job, arrived, deadline })
            .map_err(|_| ServeError::pool_lost("dispatcher is gone"))?;
        Ok(JobHandle::new(event_rx, cancel))
    }

    /// Live metrics snapshot of the running pool: aggregate over the
    /// per-fabric accumulators plus the dispatcher's queue counters
    /// (deadline expiries, queued cancellations).  Does not drain or
    /// disturb the pool — `shutdown()` is no longer the only metrics
    /// exit.
    pub fn metrics(&self) -> Metrics {
        let elapsed = self.started.elapsed().as_secs_f64();
        let mut per_fabric: Vec<Metrics> =
            self.fabric_metrics.iter().map(|m| lock(m).clone()).collect();
        for m in &mut per_fabric {
            if m.elapsed == 0.0 {
                m.elapsed = elapsed;
            }
        }
        let mut agg = Metrics::aggregate(per_fabric);
        agg.merge(&lock(&self.queue_metrics));
        agg.elapsed = elapsed;
        agg
    }

    /// v0 entry point: submit an encode request and wait.
    #[deprecated(
        since = "0.3.0",
        note = "use Server::submit(Submission::Encode { .. }, QoS::default()) + JobHandle::wait"
    )]
    pub fn infer(&self, req: Request) -> Result<Response, ServeError> {
        let handle =
            self.submit(Submission::Encode { model: req.model, input: req.input }, QoS::default())?;
        let out = handle.wait()?.into_encode()?;
        Ok(Response {
            output: out.output,
            latency: out.timing.latency,
            compute: out.timing.compute,
            queue_wait: out.timing.queue_wait,
        })
    }

    /// v0 entry point: submit a generation request, returning its handle.
    #[deprecated(
        since = "0.3.0",
        note = "use Server::submit(Submission::Generate { .. }, QoS::default())"
    )]
    pub fn submit_generate(&self, req: GenerateRequest) -> Result<JobHandle, ServeError> {
        self.submit(
            Submission::Generate {
                model: req.model,
                prompt: req.prompt,
                source: req.source,
                steps: req.steps,
            },
            QoS::default(),
        )
    }

    /// v0 entry point: submit a generation request and wait.
    #[deprecated(
        since = "0.3.0",
        note = "use Server::submit(Submission::Generate { .. }, QoS::default()) + JobHandle::wait"
    )]
    pub fn generate(&self, req: GenerateRequest) -> Result<GenerateResponse, ServeError> {
        #[allow(deprecated)]
        let handle = self.submit_generate(req)?;
        let out = handle.wait()?.into_generate()?;
        Ok(GenerateResponse {
            rows: out.rows,
            tokens: out.tokens,
            latency: out.timing.latency,
            queue_wait: out.timing.queue_wait,
            prefill: out.prefill,
            step_times: out.step_times,
        })
    }

    /// Stop the pool and collect final metrics (aggregate with per-fabric
    /// breakdown).  A worker or dispatcher panic is propagated as an error
    /// rather than masked with empty metrics.
    pub fn shutdown(mut self) -> Result<Metrics, ServeError> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Shutdown { reply })
            .map_err(|_| ServeError::pool_lost("dispatcher is gone (did it panic?)"))?;
        let drained = rx.recv().map_err(|_| {
            ServeError::pool_lost("dispatcher exited without confirming the drain (panic?)")
        });
        let mut panicked = Vec::new();
        if let Some(h) = self.dispatcher.take() {
            if h.join().is_err() {
                panicked.push("dispatcher".to_string());
            }
        }
        for (i, h) in self.workers.drain(..).enumerate() {
            if h.join().is_err() {
                panicked.push(format!("fabric {i}"));
            }
        }
        if !panicked.is_empty() {
            return Err(ServeError::pool_lost(format!(
                "serving threads panicked: {}",
                panicked.join(", ")
            )));
        }
        drained??;
        Ok(self.metrics())
    }
}

/// Everything the dispatcher thread owns (bundled so the spawn site
/// stays readable).
struct DispatchCtx {
    policy: BatchPolicy,
    queue_depth: usize,
    residency: ResidencyPolicy,
    rx: Receiver<Msg>,
    events: Receiver<FabricEvent>,
    fabrics: Vec<Sender<FabricMsg>>,
    sched: PoolScheduler,
    hints: BTreeMap<String, usize>,
    /// Shard chains this pool serves, one per admitted model whose
    /// weight footprint exceeds a single fabric's envelope (validated
    /// at [`Server::start`]; workers recompute the identical plans).
    plans: BTreeMap<String, ShardPlan>,
    queue_metrics: Arc<Mutex<Metrics>>,
}

/// The shard plans a pool serves under: one per model whose weight
/// footprint exceeds a single fabric's envelope.  Pure arithmetic over
/// the server config — [`Server::start`] validated every partition, so
/// the dispatcher and each worker recompute identical plans instead of
/// shipping them across threads.
fn shard_plans(
    cfg: &ServerConfig,
    fc: &schedule::FabricConstants,
) -> BTreeMap<String, ShardPlan> {
    let mut plans = BTreeMap::new();
    for spec in &cfg.models {
        if residency::weight_footprint_bytes(&spec.cfg, fc) <= cfg.residency.capacity_bytes {
            continue;
        }
        if let Ok(plan) =
            ShardPlan::partition_for_envelope(&spec.cfg, fc, cfg.residency.capacity_bytes)
        {
            plans.insert(spec.name.clone(), plan);
        }
    }
    plans
}

fn dispatcher_thread(ctx: DispatchCtx) {
    let DispatchCtx {
        policy,
        queue_depth,
        residency,
        rx,
        events,
        fabrics,
        mut sched,
        hints,
        plans,
        queue_metrics,
    } = ctx;
    // Fold one worker event into the scheduler: death retires the
    // fabric; a batch ack frees its capacity slot; a residency snapshot
    // (batch or prefetch ack) replaces the placement belief.
    fn fold_event(sched: &mut PoolScheduler, ev: FabricEvent) {
        if ev.died {
            sched.mark_dead(ev.fabric);
            return;
        }
        if ev.batch {
            sched.complete(ev.fabric, ev.served);
        }
        if let Some(resident) = ev.resident {
            sched.note_residency(ev.fabric, &resident);
        }
    }
    // Decayed per-model arrival rate at logical tick `now` (one tick per
    // submission) — the dispatcher-side half of the traffic-weighted
    // LRU: it seeds worker-side entry heat and ranks prefetch urgency.
    fn rate_now(rates: &BTreeMap<String, (f64, u64)>, decay: f64, now: u64, model: &str) -> f64 {
        rates.get(model).map_or(0.0, |&(r, t)| r * decay.powi(now.saturating_sub(t) as i32))
    }
    let mut batcher: Batcher<JobState> = Batcher::new(policy);
    let mut shutdown_reply: Option<Sender<Result<(), ServeError>>> = None;
    // Per-model arrival-rate EWMAs over a logical tick clock (one tick
    // per submission), same recurrence as the residency manager's.
    let mut arrivals: u64 = 0;
    let mut rates: BTreeMap<String, (f64, u64)> = BTreeMap::new();
    // Ready work was held back by the capacity gate last iteration: poll
    // completions briskly instead of sleeping a full batching deadline.
    let mut gated = false;

    'outer: loop {
        let timeout = if gated {
            // All dispatchable work is out and the rest waits on fabric
            // capacity: block on the completion channel (a completion is
            // the only thing that can unblock dispatch) instead of
            // spinning, then poll the client channel without sleeping.
            match events.recv_timeout(Duration::from_millis(5)) {
                Ok(ev) => {
                    fold_event(&mut sched, ev);
                    Duration::ZERO
                }
                Err(RecvTimeoutError::Timeout) => Duration::ZERO,
                // Every worker is gone: nothing will ever complete, so
                // wait on the client channel instead of spinning.
                Err(RecvTimeoutError::Disconnected) => Duration::from_millis(5),
            }
        } else {
            batcher
                .next_deadline()
                .map(|d| d.saturating_duration_since(Instant::now()))
                .unwrap_or(Duration::from_millis(50))
        };
        match rx.recv_timeout(timeout) {
            Ok(Msg::Work { job, arrived, deadline }) => {
                let model = job.model().to_string();
                let priority = job.qos.priority;
                arrivals += 1;
                let slot = rates.entry(model.clone()).or_insert((0.0, arrivals));
                let gap = (arrivals - slot.1) as i32;
                slot.0 = slot.0 * residency.decay.powi(gap) + (1.0 - residency.decay);
                slot.1 = arrivals;
                batcher.push_qos(&model, job, arrived, priority, deadline);
            }
            Ok(Msg::Shutdown { reply }) => {
                shutdown_reply = Some(reply);
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => break 'outer,
        }
        // Fold in completion events so load tracking stays fresh; death
        // notices retire a fabric from placement entirely.
        while let Ok(ev) = events.try_recv() {
            fold_event(&mut sched, ev);
        }
        // QoS sweep: cancelled or deadline-expired while queued complete
        // *now* with a typed error — never served late, never dropped.
        // Cheap scan first; the queue rebuild only runs when something
        // actually needs sweeping.
        let now = Instant::now();
        let sweep = |p: &Pending<JobState>| p.payload.cancel.is_cancelled() || p.expired(now);
        if batcher.any_where(sweep) {
            let mut qm = lock(&queue_metrics);
            for p in batcher.take_where(sweep) {
                if p.payload.cancel.is_cancelled() {
                    qm.cancelled += 1;
                    p.payload.fail(ServeError::Cancelled);
                } else {
                    qm.expired += 1;
                    p.payload.fail(ServeError::DeadlineExceeded {
                        waited: now.duration_since(p.arrived),
                    });
                }
            }
        }
        let draining = shutdown_reply.is_some();
        gated = false;
        // Models whose eligible fabrics are all at depth this round: set
        // aside (they stay in the QoS-ordered queue, where priority
        // still applies) while other models keep draining to fabrics
        // with room — per-target gating without head-of-line blocking.
        let mut blocked: Vec<String> = Vec::new();
        loop {
            let now = Instant::now();
            let Some(model) =
                batcher.peek_ready_excluding(now, draining, &blocked).map(|m| m.to_string())
            else {
                break;
            };
            let hint = hints.get(&model).copied();
            // Sharded models dispatch as a chain: one round occupies K
            // distinct fabrics at once, wired with handoff channels.
            if let Some(k) = plans.get(&model).map(|p| p.shards.len()) {
                if !sched.can_place_chain(&model, k, queue_depth) {
                    if !sched.can_place_chain_ever(k) {
                        // The pool shrank below the chain length: no
                        // future completion can ever free enough
                        // distinct fabrics — fail the queue typed now.
                        let lost = batcher.take_where(|p| p.model == model);
                        lock(&queue_metrics).failed += lost.len() as u64;
                        for p in lost {
                            p.payload.fail(ServeError::pool_lost(format!(
                                "model '{model}' needs a {k}-fabric shard chain but fewer \
                                 than {k} live fabrics remain"
                            )));
                        }
                        continue;
                    }
                    gated = true;
                    blocked.push(model);
                    continue;
                }
                // Sharded models are encode-only (admission enforces
                // it), so the whole ready batch pops at once.
                let Some((model, batch)) = batcher.pop_model(&model) else {
                    break;
                };
                let items: Vec<WorkItem> = batch
                    .into_iter()
                    .map(|p: Pending<JobState>| WorkItem {
                        job: p.payload,
                        arrived: p.arrived,
                        deadline: p.deadline,
                    })
                    .collect();
                let rate = rate_now(&rates, residency.decay, arrivals, &model);
                let chain = sched
                    .place_chain(&model, k, items.len(), queue_depth)
                    .expect("can_place_chain just found a chain");
                dispatch_chain(&fabrics, &mut sched, &model, &chain, items, rate);
                continue;
            }
            if !sched.can_place(&model, hint, queue_depth) {
                if !sched.can_place_ever(&model, hint) {
                    // Every fabric this model could run on is dead —
                    // fail its queued jobs now instead of waiting on a
                    // capacity slot that will never free (this also
                    // keeps the shutdown drain from hanging).
                    let lost = batcher.take_where(|p| p.model == model);
                    lock(&queue_metrics).failed += lost.len() as u64;
                    for p in lost {
                        p.payload.fail(ServeError::pool_lost(format!(
                            "no live fabric can serve model '{model}' (worker died)"
                        )));
                    }
                    continue;
                }
                gated = true;
                blocked.push(model);
                continue;
            }
            // Generations dispatch one sequence at a time: the fabric's
            // sequence scheduler interleaves them at decode-step
            // granularity and acks each at admission, so popping singly
            // keeps the per-round admission decision (and its QoS
            // ordering) in the queue instead of committing a whole
            // batch to one fabric up front.  Model queues are
            // kind-homogeneous (the router refuses encodes on decoder
            // models), so the front item decides for the queue.
            let single = matches!(
                batcher.front(&model).map(|p| &p.payload.submission),
                Some(Submission::Generate { .. })
            );
            let popped =
                if single { batcher.pop_model_n(&model, 1) } else { batcher.pop_model(&model) };
            let Some((model, batch)) = popped else {
                break;
            };
            let fabric = sched
                .pick_within_depth(&model, hint, batch.len(), queue_depth)
                .expect("can_place just found a fabric with room");
            let items: Vec<WorkItem> = batch
                .into_iter()
                .map(|p: Pending<JobState>| WorkItem {
                    job: p.payload,
                    arrived: p.arrived,
                    deadline: p.deadline,
                })
                .collect();
            let n = items.len();
            let rate = rate_now(&rates, residency.decay, arrivals, &model);
            if let Err(mpsc::SendError(lost)) =
                fabrics[fabric].send(FabricMsg::Batch { model, items, rate })
            {
                // The worker thread is gone: fail the batch loudly instead
                // of dropping the reply channels.
                if let FabricMsg::Batch { items, .. } = lost {
                    for it in items {
                        it.job.fail(ServeError::pool_lost(format!(
                            "fabric {fabric} is gone (worker died)"
                        )));
                    }
                }
                sched.complete(fabric, n);
            }
        }
        // Prefetch trigger: a hot model whose queue is deepening
        // (>= prefetch_depth waiting, typically because its resident
        // fabric is at the capacity gate) gets its stack staged on a
        // second fabric off the dispatch path, so the next burst can
        // split across fabrics without paying the upload inline.
        if residency.mode == ResidencyMode::Managed && fabrics.len() > 1 && !draining {
            let hot: Vec<String> = batcher
                .queued_models()
                .filter(|m| batcher.model_len(m) >= residency.prefetch_depth)
                // Chains prefetch nothing: place_chain already steers
                // every stage toward its shard's resident fabric, and a
                // whole-model stack would not fit one fabric anyway.
                .filter(|m| !plans.contains_key(*m))
                .map(str::to_string)
                .collect();
            for model in hot {
                if let Some(f) = sched.prefetch_target(&model) {
                    let rate = rate_now(&rates, residency.decay, arrivals, &model);
                    // Guard the staging path against a worker that died
                    // between its last event and this trigger: a failed
                    // send retires the fabric in the scheduler (which
                    // just committed the resident belief to it) so no
                    // further staging lands on a dead worker's queue.
                    if fabrics[f].send(FabricMsg::Prefetch { model, rate }).is_err() {
                        sched.mark_dead(f);
                    }
                }
            }
        }
        if draining && batcher.is_empty() {
            break 'outer;
        }
    }

    // The server handle was dropped (or the drain finished): anything
    // still queued can never be served.
    for p in batcher.take_where(|_| true) {
        p.payload.fail(ServeError::pool_lost("server shut down before the job was dispatched"));
    }

    // Quiesce the fabrics; per-fabric channel order guarantees all
    // dispatched batches are served (and recorded) before the Shutdown
    // ack.
    let mut failure: Option<ServeError> = None;
    for (id, ftx) in fabrics.iter().enumerate() {
        let (ack_tx, ack_rx) = mpsc::channel();
        if ftx.send(FabricMsg::Shutdown { reply: ack_tx }).is_err() {
            failure.get_or_insert_with(|| {
                ServeError::pool_lost(format!("fabric {id} terminated abnormally"))
            });
            continue;
        }
        if ack_rx.recv().is_err() {
            failure.get_or_insert_with(|| {
                ServeError::pool_lost(format!("fabric {id} died during shutdown"))
            });
        }
    }
    if let Some(reply) = shutdown_reply {
        let _ = reply.send(match failure {
            Some(e) => Err(e),
            None => Ok(()),
        });
    }
}

/// Send one sharded encode round down its chain: `K` [`FabricMsg::ShardStage`]
/// messages wired stage-to-stage with fresh relay channels.  Stages go
/// out **tail-first** so a dead fabric is discovered while the
/// dispatcher still owns the head's items — the round then fails typed
/// instead of entering a chain that cannot finish.  Stages already sent
/// see their upstream close, drain empty, and still ack `expected`
/// served on their own; the failed and unsent stages are completed
/// here, keeping the capacity accounting balanced either way.
fn dispatch_chain(
    fabrics: &[Sender<FabricMsg>],
    sched: &mut PoolScheduler,
    model: &str,
    chain: &[usize],
    items: Vec<WorkItem>,
    rate: f64,
) {
    let k = chain.len();
    let n = items.len();
    // Handoff channels: boundary b carries stage b's output activations
    // into stage b + 1.
    let mut ups: Vec<Option<Receiver<ShardRelay>>> = Vec::with_capacity(k);
    let mut downs: Vec<Option<Sender<ShardRelay>>> = Vec::with_capacity(k);
    ups.push(None);
    for _ in 0..k - 1 {
        let (btx, brx) = mpsc::channel::<ShardRelay>();
        downs.push(Some(btx));
        ups.push(Some(brx));
    }
    downs.push(None);
    let mut items = Some(items);
    for stage in (0..k).rev() {
        let msg = FabricMsg::ShardStage {
            model: model.to_string(),
            shard: (stage as u16, k as u16),
            rate,
            items: if stage == 0 { items.take().unwrap_or_default() } else { Vec::new() },
            upstream: ups[stage].take(),
            downstream: downs[stage].take(),
            expected: n,
        };
        if let Err(mpsc::SendError(lost)) = fabrics[chain[stage]].send(msg) {
            // This stage's worker died before its notice folded: fail
            // the round's jobs typed (they live in the head's items —
            // either still owned here or returned inside `lost`).
            if let FabricMsg::ShardStage { items: lost_items, .. } = lost {
                for it in lost_items.into_iter().chain(items.take().unwrap_or_default()) {
                    it.job.fail(ServeError::pool_lost(format!(
                        "fabric {} died mid-chain for model '{model}'",
                        chain[stage]
                    )));
                }
            }
            for &f in &chain[..=stage] {
                sched.complete(f, n);
            }
            return;
        }
    }
}

fn fabric_thread(
    id: usize,
    cfg: ServerConfig,
    rx: Receiver<FabricMsg>,
    ready: Sender<Result<(), ServeError>>,
    events: Sender<FabricEvent>,
    metrics: Arc<Mutex<Metrics>>,
) {
    // Build the fabric locally (not Send).
    let mut engine = match TileEngine::new(&cfg.artifact_dir) {
        Ok(e) => e,
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    engine.mode = cfg.attention;
    engine.opt_level = cfg.opt_level;

    // Validate every registered model against the fabric's synthesized
    // constraints up front — a model that can never execute here fails
    // at warmup, not mid-traffic.  Weight uploads themselves are
    // *lazy*: the residency manager below performs them on first
    // dispatch (Algorithm 18, 4–12) and keeps device weight memory
    // within its capacity envelope thereafter.
    let fc = engine.fabric_constants();
    // Sharded models validate per shard sub-config: only a shard's
    // layer slice ever programs this fabric's registers, and the full
    // stack deliberately exceeds what one fabric can hold.
    let plans = shard_plans(&cfg, &fc);
    for spec in &cfg.models {
        let fits = match plans.get(&spec.name) {
            Some(plan) => {
                plan.shards.iter().try_for_each(|s| engine.check_runtime_config(&s.cfg))
            }
            None => engine.check_runtime_config(&spec.cfg),
        };
        if let Err(e) = fits {
            let _ = ready.send(Err(ServeError::engine(format!(
                "fabric {id}: model '{}' cannot run on this fabric: {e}",
                spec.name
            ))));
            return;
        }
    }
    let mut resmgr: WeightResidencyManager<PreparedStack> =
        WeightResidencyManager::new(cfg.residency);
    // Warm the executable cache so first requests are not compile-bound.
    let mut names: Vec<&str> = vec![
        "mm_qkv", "mm_ffn1", "mm_ffn2", "mm_ffn3", "bias_add_dk", "bias_add_d", "bias_relu_h",
        "residual_ln", "qk_scores", "softmax", "sv", "attn_fused",
    ];
    if cfg.models.iter().any(|m| m.cfg.dec_layers > 0) {
        // Generation models need the decode-step row artifacts too; an
        // artifact set predating them fails here, at warmup, with the
        // missing names — not per-request mid-generation.
        names.extend([
            "dec_qkv_row", "qk_row", "softmax_row", "sv_row", "kv_append", "dec_proj_row",
            "dec_ffn1_row", "dec_ffn2_row", "residual_ln_row",
        ]);
    }
    if let Err(e) = engine.executor().warmup(&names) {
        let _ = ready.send(Err(e.into()));
        return;
    }
    let _ = ready.send(Ok(()));

    // From here on, an unwinding panic must tell the dispatcher this
    // fabric is gone — otherwise its queued work waits forever on a
    // capacity slot that can never free.
    let mut notice = DeathNotice { fabric: id, events: events.clone(), armed: true };
    let started = Instant::now();
    // The sequence scheduler's live set: in-flight generations, one
    // resumable GenSession (KV cache + position) each.
    let mut live: Vec<LiveSeq> = Vec::new();
    loop {
        // Work acquisition: block when idle, poll (without stalling the
        // decode rounds) while sequences are live, and stop pulling
        // entirely once the live set is at capacity — queued work then
        // waits behind the max_seqs budget, not in a deeper FIFO.
        let msg = if live.is_empty() {
            match rx.recv() {
                Ok(m) => Some(m),
                Err(_) => break,
            }
        } else if live.len() < cfg.max_seqs {
            match rx.try_recv() {
                Ok(m) => Some(m),
                Err(mpsc::TryRecvError::Empty) => None,
                Err(mpsc::TryRecvError::Disconnected) => break,
            }
        } else {
            None
        };
        match msg {
            Some(FabricMsg::Batch { model, items, rate }) => {
                let served = items.len();
                // Kind split: encode batches run whole on the batch
                // executor; generations are admitted into the live set.
                // (Model queues are kind-homogeneous, so one side is
                // always empty — the partition is belt-and-braces.)
                let (gens, encs): (Vec<_>, Vec<_>) = items
                    .into_iter()
                    .partition(|it| matches!(it.job.submission, Submission::Generate { .. }));
                // Make the model's weight stack device-resident (a hit
                // reuses it; a miss evicts cold peers and uploads).
                match acquire_stack(&mut resmgr, &engine, &cfg, &fc, &metrics, &model, Some(rate))
                {
                    Ok(stack) => {
                        if !encs.is_empty() {
                            serve_batch(&mut engine, &cfg, stack, &metrics, &model, encs);
                        }
                        if !gens.is_empty() {
                            admit_generations(
                                &mut engine,
                                &cfg,
                                stack,
                                &metrics,
                                &model,
                                gens,
                                &mut live,
                            );
                        }
                    }
                    Err(e) => {
                        lock(&metrics).failed += served as u64;
                        for it in gens.into_iter().chain(encs) {
                            it.job.fail(ServeError::engine(format!(
                                "fabric {id}: weights for model '{model}': {e}"
                            )));
                        }
                    }
                }
                // Pinning tracks the live set: a model with in-flight
                // KV-cached generations is never evicted mid-flight.
                resmgr.set_pinned(live.iter().map(|s| s.model.as_str()));
                // Ack at admission: a generation frees its capacity slot
                // as soon as it joins the live set, so queue_depth meters
                // per-round admissions — not whole jobs held to
                // completion.  The resident snapshot corrects the
                // dispatcher's placement belief.
                let _ = events.send(FabricEvent {
                    fabric: id,
                    served,
                    died: false,
                    batch: true,
                    resident: Some(resmgr.resident_models()),
                });
            }
            Some(FabricMsg::ShardStage {
                model,
                shard,
                rate,
                items,
                upstream,
                downstream,
                expected,
            }) => {
                serve_shard_stage(
                    &mut engine,
                    &cfg,
                    &mut resmgr,
                    &plans,
                    &metrics,
                    &model,
                    shard,
                    rate,
                    items,
                    upstream,
                    downstream,
                );
                // Ack the dispatched round size (not what survived the
                // chain): the dispatcher committed `expected` in-flight
                // on this fabric at placement, and upstream
                // cancellations must not strand the difference.
                let _ = events.send(FabricEvent {
                    fabric: id,
                    served: expected,
                    died: false,
                    batch: true,
                    resident: Some(resmgr.resident_models()),
                });
            }
            Some(FabricMsg::Prefetch { model, rate }) => {
                // Stage the stack between batches; best-effort — on
                // failure the next dispatch pays the upload inline,
                // exactly as it would have without the prefetch.
                let was_resident = resmgr.is_resident(&model);
                let staged =
                    acquire_stack(&mut resmgr, &engine, &cfg, &fc, &metrics, &model, Some(rate));
                if staged.is_ok() && !was_resident {
                    lock(&metrics).prefetches += 1;
                }
                let _ = events.send(FabricEvent {
                    fabric: id,
                    served: 0,
                    died: false,
                    batch: false,
                    resident: Some(resmgr.resident_models()),
                });
            }
            Some(FabricMsg::Shutdown { reply }) => {
                // Drain the live set before acking — dispatched work is
                // always served (or typed-failed) before shutdown.
                while !live.is_empty() {
                    decode_round(&mut engine, &cfg, &mut resmgr, &fc, &metrics, &mut live);
                    resmgr.set_pinned(live.iter().map(|s| s.model.as_str()));
                }
                lock(&metrics).elapsed = started.elapsed().as_secs_f64();
                notice.armed = false;
                let _ = reply.send(());
                return;
            }
            None => {}
        }
        if !live.is_empty() {
            decode_round(&mut engine, &cfg, &mut resmgr, &fc, &metrics, &mut live);
            resmgr.set_pinned(live.iter().map(|s| s.model.as_str()));
        }
    }
    // Dispatcher hung up without a shutdown (server dropped): finish
    // the live sequences — their handles may still be held — then exit.
    while !live.is_empty() {
        decode_round(&mut engine, &cfg, &mut resmgr, &fc, &metrics, &mut live);
        resmgr.set_pinned(live.iter().map(|s| s.model.as_str()));
    }
    notice.armed = false;
}

/// Look up `model`'s spec and make its prepared stack device-resident
/// through the fabric's residency manager — a hit reuses the resident
/// stack, a miss evicts by traffic-weighted LRU and uploads via
/// `prepare_model` — then mirror the manager's counters into the
/// fabric metrics.
fn acquire_stack<'m>(
    resmgr: &'m mut WeightResidencyManager<PreparedStack>,
    engine: &TileEngine,
    cfg: &ServerConfig,
    fc: &schedule::FabricConstants,
    metrics: &Mutex<Metrics>,
    model: &str,
    rate: Option<f64>,
) -> Result<&'m PreparedStack, ServeError> {
    let Some(spec) = cfg.models.iter().find(|s| s.name == model) else {
        return Err(ServeError::engine(format!("model '{model}' is not registered")));
    };
    let bytes = residency::weight_footprint_bytes(&spec.cfg, fc);
    let evictions_before = resmgr.stats().evictions;
    resmgr.acquire_with(model, bytes, rate, || {
        engine.prepare_model(&spec.cfg, &spec.weights(), &spec.decoder_weights())
    })?;
    let s = resmgr.stats();
    if s.evictions > evictions_before {
        // Low-water moment: shed host scratch shapes that may belong
        // only to the topology just evicted.
        engine.trim_scratch();
    }
    {
        let mut m = lock(metrics);
        m.weight_uploads = s.uploads;
        m.residency_hits = s.hits;
        m.residency_evictions = s.evictions;
        m.resident_bytes_peak = m.resident_bytes_peak.max(s.resident_bytes_peak);
    }
    Ok(resmgr.get(model).expect("the stack was just made resident"))
}

/// Abandon a whole shard-stage round with one typed error: every head
/// item and every relay still arriving on the upstream channel fails.
/// Returning (and thereby dropping the stage's downstream sender)
/// closes the rest of the chain, which drains empty and acks on its
/// own — the failure surfaces on the jobs, never as a stuck chain.
fn drain_round(
    head: std::vec::IntoIter<WorkItem>,
    upstream: Option<Receiver<ShardRelay>>,
    metrics: &Mutex<Metrics>,
    msg: &str,
) {
    for job in
        head.map(|it| it.job).chain(upstream.into_iter().flatten().map(|relay| relay.job))
    {
        lock(metrics).failed += 1;
        job.fail(ServeError::engine(msg.to_string()));
    }
}

/// Serve one stage of a sharded encode round (see [`super::shard`]):
/// make the stage's shard stack device-resident under its own
/// [`shard::residency_key`] (shards cache independently, sized by
/// their own bytes), program the shard sub-topology, then stream
/// relays through [`TileEngine::run_encoder_stage`] — the head pads
/// each batch item into the fabric's staging shape, inner stages block
/// on the upstream handoff until the peer closes it — forwarding each
/// output activation downstream, or cropping and replying at the tail.
///
/// The streaming IS the pipeline: this stage computes relay *i* while
/// the downstream fabric computes relay *i − 1*, so a `K`-shard chain
/// overlaps `K` in-flight requests.
#[allow(clippy::too_many_arguments)]
fn serve_shard_stage(
    engine: &mut TileEngine,
    cfg: &ServerConfig,
    resmgr: &mut WeightResidencyManager<PreparedStack>,
    plans: &BTreeMap<String, ShardPlan>,
    metrics: &Mutex<Metrics>,
    model: &str,
    shard_id: (u16, u16),
    rate: f64,
    items: Vec<WorkItem>,
    upstream: Option<Receiver<ShardRelay>>,
    downstream: Option<Sender<ShardRelay>>,
) {
    let (index, count) = (shard_id.0 as usize, shard_id.1 as usize);
    let head = items.into_iter();
    // The stage's shard spec: plans are deterministic arithmetic over
    // the shared config, so a mismatch with the dispatcher is an
    // internal invariant break, not a user error.
    let spec = match plans
        .get(model)
        .and_then(|p| p.shards.get(index))
        .filter(|s| s.count == count)
    {
        Some(s) => s,
        None => {
            return drain_round(
                head,
                upstream,
                metrics,
                &format!("no shard {index}/{count} plan for model '{model}' on this fabric"),
            );
        }
    };
    let Some(mspec) = cfg.models.iter().find(|s| s.name == model) else {
        return drain_round(
            head,
            upstream,
            metrics,
            &format!("model '{model}' is not registered"),
        );
    };
    // Make the shard stack resident.  The stack is the parent's layer
    // slice prepared under the shard sub-config — weight references
    // inside the shard's programs are 0-based, so the slice IS the
    // stack (no offsetting; see `shard::OffsetWeights` for the other
    // direction).
    let key = shard::residency_key(model, index, count);
    let evictions_before = resmgr.stats().evictions;
    if let Err(e) = resmgr.acquire_with(&key, spec.bytes, Some(rate), || {
        engine.prepare_model(&spec.cfg, &mspec.weights()[spec.layers.clone()], &[])
    }) {
        return drain_round(
            head,
            upstream,
            metrics,
            &format!("weights for shard {index}/{count} of model '{model}': {e}"),
        );
    }
    let s = resmgr.stats();
    if s.evictions > evictions_before {
        engine.trim_scratch();
    }
    {
        let mut m = lock(metrics);
        m.weight_uploads = s.uploads;
        m.residency_hits = s.hits;
        m.residency_evictions = s.evictions;
        m.resident_bytes_peak = m.resident_bytes_peak.max(s.resident_bytes_peak);
        m.shard_resident_bytes_peak = m.shard_resident_bytes_peak.max(spec.bytes);
    }
    // Program the shard sub-topology — chains interleave with other
    // models' batches on this fabric, so the register file may hold
    // anything between rounds.
    if !engine.is_programmed_for(&spec.cfg) {
        match engine.program(&spec.cfg) {
            Ok(()) => lock(metrics).reprograms += 1,
            Err(e) => {
                return drain_round(
                    head,
                    upstream,
                    metrics,
                    &format!(
                        "programming registers for shard {index}/{count} of model \
                         '{model}': {e}"
                    ),
                );
            }
        }
    }
    let stack = resmgr.get(&key).expect("the shard stack was just made resident");
    let d_model = spec.cfg.d_model;
    let mut head = head;
    let mut attempted = 0usize;
    loop {
        // Intake: inner stages block on the handoff until the peer
        // closes it (that blocking is the pipeline hand-over); the head
        // pads its next batch item into a fresh stage activation.
        let relay = match &upstream {
            Some(rx) => match rx.recv() {
                Ok(relay) => relay,
                Err(_) => break,
            },
            None => match head.next() {
                Some(WorkItem { job, arrived, deadline }) => {
                    let exec_start = Instant::now();
                    let (live, activation) = match &job.submission {
                        Submission::Encode { input, .. } => {
                            (input.rows, engine.pad_stage_input(input))
                        }
                        Submission::Generate { .. } => {
                            unreachable!("sharded serving is encode-only (admission enforces it)")
                        }
                    };
                    ShardRelay { job, arrived, deadline, exec_start, live, activation }
                }
                None => break,
            },
        };
        let ShardRelay { job, arrived, deadline, exec_start, live, activation } = relay;
        // Last-line QoS at every stage: a cancelled or expired request
        // stops travelling the chain here (downstream simply sees one
        // fewer relay — intake is drain-until-close, not a count).
        let now = Instant::now();
        if job.cancel.is_cancelled() {
            lock(metrics).cancelled += 1;
            job.fail(ServeError::Cancelled);
            continue;
        }
        if deadline.map_or(false, |d| d <= now) {
            lock(metrics).expired += 1;
            job.fail(ServeError::DeadlineExceeded { waited: now.duration_since(arrived) });
            continue;
        }
        attempted += 1;
        engine.opt_level = job.qos.opt_level.unwrap_or(cfg.opt_level);
        match engine.run_encoder_stage(stack, shard_id, activation, live) {
            Ok(out) => match &downstream {
                Some(tx) => {
                    {
                        let mut m = lock(metrics);
                        m.activation_hops += 1;
                        m.interfabric_bytes += (out.data.len() * 4) as u64;
                    }
                    let onward =
                        ShardRelay { job, arrived, deadline, exec_start, live, activation: out };
                    if let Err(mpsc::SendError(lost)) = tx.send(onward) {
                        lock(metrics).failed += 1;
                        lost.job.fail(ServeError::pool_lost(format!(
                            "stage {}/{count} of model '{model}' is gone (worker died)",
                            index + 1
                        )));
                    }
                }
                None => {
                    let output = engine.crop_stage_output(out, live, d_model);
                    let timing = Timing {
                        compute: exec_start.elapsed(),
                        queue_wait: exec_start.duration_since(arrived),
                        latency: arrived.elapsed(),
                    };
                    let priority = job.qos.priority;
                    {
                        let mut m = lock(metrics);
                        m.record(timing.compute, timing.queue_wait, timing.latency);
                        m.record_priority(priority);
                        m.record_rows(live, schedule::covering_bucket(live, spec.cfg.seq_len));
                    }
                    let _ = job.events.send(JobEvent::Done(Box::new(JobOutput::Encode(
                        EncodeOutput { output, timing },
                    ))));
                }
            },
            Err(e) => {
                lock(metrics).failed += 1;
                job.fail(e);
            }
        }
    }
    if attempted > 0 {
        lock(metrics).record_batch(attempted);
    }
}

/// One in-flight generation in a fabric's sequence scheduler.  Owns the
/// job's event channel and its [`GenSession`] (KV cache + position);
/// dropping a `LiveSeq` without finishing it releases the KV cache and
/// its pool buffers immediately — that *is* the cancellation path.
struct LiveSeq {
    model: String,
    arrived: Instant,
    deadline: Option<Instant>,
    priority: Priority,
    opt_level: Option<OptLevel>,
    events: Sender<JobEvent>,
    cancel: CancelToken,
    /// When the sequence was admitted (prefill start) — the boundary
    /// between `queue_wait` and `compute` in the final [`Timing`].
    exec_start: Instant,
    /// Submit → first streamed token (prefill included), recorded into
    /// the metrics TTFT summary on success.
    ttft: Duration,
    session: GenSession,
}

/// Round order for the sequence scheduler: priority first (QoS leads),
/// then model — grouping same-model sequences so a round pays at most
/// one reprogram per *model*, not per sequence — then arrival (FIFO
/// fairness within a model).
fn seq_round_order(
    a: (Priority, &str, Instant),
    b: (Priority, &str, Instant),
) -> std::cmp::Ordering {
    b.0.cmp(&a.0).then_with(|| a.1.cmp(b.1)).then_with(|| a.2.cmp(&b.2))
}

/// Admit generation jobs into the fabric's live set: re-check QoS at
/// the last line, run each prompt's prefill (token 0 streams here —
/// the time-to-first-token edge), and park the resumable session for
/// the scheduler's decode rounds.
fn admit_generations(
    engine: &mut TileEngine,
    cfg: &ServerConfig,
    stack: &PreparedStack,
    metrics: &Mutex<Metrics>,
    model: &str,
    items: Vec<WorkItem>,
    live: &mut Vec<LiveSeq>,
) {
    let mut attempted = 0usize;
    for item in items {
        let WorkItem { job, arrived, deadline } = item;
        let now = Instant::now();
        if job.cancel.is_cancelled() {
            lock(metrics).cancelled += 1;
            job.fail(ServeError::Cancelled);
            continue;
        }
        if deadline.map_or(false, |d| d <= now) {
            lock(metrics).expired += 1;
            job.fail(ServeError::DeadlineExceeded { waited: now.duration_since(arrived) });
            continue;
        }
        // Re-checked per admission: decode rounds for other live models
        // may have left a different topology in the register file.
        if !engine.is_programmed_for(&stack.cfg) {
            let programmed = if cfg.fault.fail_program_for.as_deref() == Some(model) {
                Err(ServeError::ProgramFailed("injected register-programming fault".into()))
            } else {
                engine.program(&stack.cfg)
            };
            match programmed {
                Ok(()) => lock(metrics).reprograms += 1,
                Err(e) => {
                    lock(metrics).failed += 1;
                    job.fail(ServeError::ProgramFailed(format!(
                        "programming registers for model '{model}': {e}"
                    )));
                    continue;
                }
            }
        }
        attempted += 1;
        engine.opt_level = job.qos.opt_level.unwrap_or(cfg.opt_level);
        let exec_start = Instant::now();
        let JobState { submission, qos, events, cancel } = job;
        let (prompt, source, steps) = match submission {
            Submission::Generate { prompt, source, steps, .. } => (prompt, source, steps),
            Submission::Encode { .. } => unreachable!("admission receives only generations"),
        };
        match engine.begin_generation(stack, &prompt, source.as_ref(), steps) {
            Ok(session) => {
                let ttft = arrived.elapsed();
                let delivered = events
                    .send(JobEvent::Token(TokenEvent {
                        index: 0,
                        token: session.last_token(),
                        row: session.last_row().to_vec(),
                    }))
                    .is_ok();
                if cancel.is_cancelled() || !delivered {
                    // Cancelled during prefill, or the handle is gone —
                    // dropping the session frees the KV cache now.
                    lock(metrics).cancelled += 1;
                    let _ = events.send(JobEvent::Failed(ServeError::Cancelled));
                    continue;
                }
                let seq = LiveSeq {
                    model: model.to_string(),
                    arrived,
                    deadline,
                    priority: qos.priority,
                    opt_level: qos.opt_level,
                    events,
                    cancel,
                    exec_start,
                    ttft,
                    session,
                };
                lock(metrics).admitted += 1;
                if seq.session.is_done() {
                    // steps == 1: the prefill token was the whole job.
                    retire_done(engine, stack, metrics, seq);
                } else {
                    live.push(seq);
                }
            }
            Err(e) => {
                lock(metrics).failed += 1;
                let _ = events.send(JobEvent::Failed(e));
            }
        }
    }
    if attempted > 0 {
        let mut m = lock(metrics);
        m.record_batch(attempted);
        m.live_peak = m.live_peak.max(live.len() as u64);
    }
}

/// One scheduler round: a single decode step for every live sequence in
/// [`seq_round_order`], streaming each token and observing cancellation
/// and deadlines between steps.  Finished, cancelled, expired, and
/// failed sequences retire immediately (their KV caches free with the
/// session); survivors stay for the next round.
fn decode_round(
    engine: &mut TileEngine,
    cfg: &ServerConfig,
    resmgr: &mut WeightResidencyManager<PreparedStack>,
    fc: &schedule::FabricConstants,
    metrics: &Mutex<Metrics>,
    live: &mut Vec<LiveSeq>,
) {
    live.sort_by(|a, b| {
        seq_round_order(
            (a.priority, a.model.as_str(), a.arrived),
            (b.priority, b.model.as_str(), b.arrived),
        )
    });
    let mut i = 0;
    while i < live.len() {
        // Between-step QoS: cancellation and deadlines bind
        // mid-generation, at decode-round granularity.
        if live[i].cancel.is_cancelled() {
            let seq = live.remove(i);
            lock(metrics).cancelled += 1;
            let _ = seq.events.send(JobEvent::Failed(ServeError::Cancelled));
            continue;
        }
        let now = Instant::now();
        if live[i].deadline.map_or(false, |d| d <= now) {
            let seq = live.remove(i);
            lock(metrics).expired += 1;
            let waited = now.duration_since(seq.arrived);
            let _ = seq.events.send(JobEvent::Failed(ServeError::DeadlineExceeded { waited }));
            continue;
        }
        // Pinning keeps a live model's stack resident under `Managed`;
        // under `ReprogramAlways` a peer model's batch may have evicted
        // it between rounds — re-upload before stepping.  (KV caches
        // are separate device memory: they survive both register
        // reprogramming and weight eviction.)
        if !resmgr.is_resident(&live[i].model) {
            let model = live[i].model.clone();
            if let Err(e) = acquire_stack(resmgr, engine, cfg, fc, metrics, &model, None) {
                let seq = live.remove(i);
                lock(metrics).failed += 1;
                let _ = seq.events.send(JobEvent::Failed(e));
                continue;
            }
        }
        let stack = resmgr.get(&live[i].model).expect("resident or just acquired");
        // KV caches are plain device memory — they survive register
        // reprogramming, so interleaving models costs a program(), not
        // a re-prefill.
        if !engine.is_programmed_for(&stack.cfg) {
            match engine.program(&stack.cfg) {
                Ok(()) => lock(metrics).reprograms += 1,
                Err(e) => {
                    let seq = live.remove(i);
                    lock(metrics).failed += 1;
                    let _ = seq.events.send(JobEvent::Failed(ServeError::ProgramFailed(format!(
                        "programming registers for model '{}': {e}",
                        seq.model
                    ))));
                    continue;
                }
            }
        }
        engine.opt_level = live[i].opt_level.unwrap_or(cfg.opt_level);
        let seq = &mut live[i];
        match engine.step_once(stack, &mut seq.session) {
            Ok((index, token)) => {
                let delivered = seq
                    .events
                    .send(JobEvent::Token(TokenEvent {
                        index,
                        token,
                        row: seq.session.last_row().to_vec(),
                    }))
                    .is_ok();
                if !delivered {
                    // The JobHandle is gone: nobody can observe the
                    // result, so stop burning decode steps on it.
                    live.remove(i);
                    lock(metrics).cancelled += 1;
                    continue;
                }
                if seq.session.is_done() {
                    let seq = live.remove(i);
                    retire_done(engine, stack, metrics, seq);
                    continue;
                }
                i += 1;
            }
            Err(e) => {
                let seq = live.remove(i);
                lock(metrics).failed += 1;
                let _ = seq.events.send(JobEvent::Failed(e));
            }
        }
    }
    lock(metrics).decode_rounds += 1;
}

/// Retire a finished sequence: close out its transcript and timing,
/// record success-only samples, and deliver the final output.
fn retire_done(
    engine: &TileEngine,
    stack: &PreparedStack,
    metrics: &Mutex<Metrics>,
    seq: LiveSeq,
) {
    let LiveSeq { arrived, priority, events, exec_start, ttft, session, .. } = seq;
    match engine.finish_generation(stack, session) {
        Ok(g) => {
            // `compute` spans admission → completion, so under
            // interleaving it includes rounds spent on *other* live
            // sequences — the wall-clock this sequence was held live.
            let timing = Timing {
                compute: exec_start.elapsed(),
                queue_wait: exec_start.duration_since(arrived),
                latency: arrived.elapsed(),
            };
            {
                let mut m = lock(metrics);
                m.record_generation(g.prefill, &g.step_times);
                m.record(timing.compute, timing.queue_wait, timing.latency);
                m.record_priority(priority);
                m.record_ttft(ttft);
            }
            let _ = events.send(JobEvent::Done(Box::new(JobOutput::Generate(GenerateOutput {
                rows: g.rows,
                tokens: g.tokens,
                timing,
                prefill: g.prefill,
                step_times: g.step_times,
            }))));
        }
        Err(e) => {
            lock(metrics).failed += 1;
            let _ = events.send(JobEvent::Failed(e));
        }
    }
}

/// The batch executor: serve one model-homogeneous *encode* batch
/// whole.  Generations never reach here — the fabric loop routes them
/// to [`admit_generations`] and the sequence scheduler.
fn serve_batch(
    engine: &mut TileEngine,
    cfg: &ServerConfig,
    stack: &PreparedStack,
    metrics: &Mutex<Metrics>,
    model: &str,
    items: Vec<WorkItem>,
) {
    // Reprogram only when the register file holds a different topology.
    if !engine.is_programmed_for(&stack.cfg) {
        let programmed = if cfg.fault.fail_program_for.as_deref() == Some(model) {
            Err(ServeError::ProgramFailed("injected register-programming fault".into()))
        } else {
            engine.program(&stack.cfg)
        };
        match programmed {
            Ok(()) => lock(metrics).reprograms += 1,
            Err(e) => {
                // A failed program() fails the whole batch: running against
                // the previous model's register state would silently return
                // wrong numerics.
                lock(metrics).failed += items.len() as u64;
                for it in items {
                    it.job.fail(ServeError::ProgramFailed(format!(
                        "programming registers for model '{model}': {e}"
                    )));
                }
                return;
            }
        }
    }
    // The batch is recorded only after the loop, sized by the items
    // that actually started executing — cancel/deadline skips must not
    // inflate the served-batch statistics ("batches are counted only
    // once actually served").
    let mut attempted = 0usize;
    for item in items {
        let WorkItem { job, arrived, deadline } = item;
        let now = Instant::now();
        // Last-line QoS checks at execution start: cancellation and the
        // queued-deadline contract hold even for requests that expired
        // or were cancelled after dispatch (inside a staged batch).
        if job.cancel.is_cancelled() {
            lock(metrics).cancelled += 1;
            job.fail(ServeError::Cancelled);
            continue;
        }
        if deadline.map_or(false, |d| d <= now) {
            lock(metrics).expired += 1;
            job.fail(ServeError::DeadlineExceeded { waited: now.duration_since(arrived) });
            continue;
        }
        attempted += 1;
        // Per-request opt-level override (cache-keyed: a lookup after
        // first use, never a recompile).
        engine.opt_level = job.qos.opt_level.unwrap_or(cfg.opt_level);
        let priority = job.qos.priority;
        let queue_wait = arrived.elapsed();
        let t0 = Instant::now();
        let JobState { submission, events, .. } = job;
        let input = match submission {
            Submission::Encode { input, .. } => input,
            Submission::Generate { .. } => unreachable!("the fabric loop admits generations"),
        };
        match engine.run_encoder(stack, &input) {
            Ok(output) => {
                let timing =
                    Timing { compute: t0.elapsed(), queue_wait, latency: arrived.elapsed() };
                {
                    let mut m = lock(metrics);
                    m.record(timing.compute, timing.queue_wait, timing.latency);
                    m.record_priority(priority);
                    // Length-adaptive accounting: live rows vs the bucket
                    // the engine actually dispatched them in.
                    m.record_rows(
                        input.rows,
                        schedule::covering_bucket(input.rows, stack.cfg.seq_len),
                    );
                }
                let _ = events
                    .send(JobEvent::Done(Box::new(JobOutput::Encode(EncodeOutput {
                        output,
                        timing,
                    }))));
            }
            Err(e) => {
                lock(metrics).failed += 1;
                let _ = events.send(JobEvent::Failed(e));
            }
        }
    }
    if attempted > 0 {
        lock(metrics).record_batch(attempted);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{presets, reference, weights};

    use crate::require_artifacts;

    fn server(models: Vec<ModelSpec>) -> Server {
        let mut cfg = ServerConfig::new(models);
        cfg.policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(2) };
        Server::start(cfg).expect("run `make artifacts` first")
    }

    fn encode(model: &str, input: Mat) -> Submission {
        Submission::Encode { model: model.into(), input }
    }

    #[test]
    fn serves_correct_outputs() {
        require_artifacts!();
        let spec = ModelSpec::new("small", presets::small_encoder(32, 1), 21);
        let s = server(vec![spec.clone()]);
        let x = weights::init_input(1, 32, 256);
        let out = s
            .submit(encode("small", x.clone()), QoS::default())
            .unwrap()
            .wait()
            .unwrap()
            .into_encode()
            .unwrap();
        let mask = reference::attention_mask(32, 32, false);
        let want = reference::encoder_stack(&x, &spec.weights(), &mask);
        assert!(out.output.max_abs_diff(&want) < 2e-3);
        // timing decomposition: e2e covers queue + compute
        assert!(out.timing.latency >= out.timing.compute);
        assert!(out.timing.latency >= out.timing.queue_wait);
        let m = s.shutdown().unwrap();
        assert_eq!(m.requests(), 1);
        assert_eq!(m.failed, 0);
        assert_eq!(m.served_at(crate::coordinator::api::Priority::Normal), 1);
    }

    #[test]
    fn short_requests_serve_in_their_bucket_and_record_padding() {
        require_artifacts!();
        let spec = ModelSpec::new("small", presets::small_encoder(32, 1), 21);
        let s = server(vec![spec.clone()]);
        // A 16-row request lands exactly on the 16-row bucket: the served
        // output must match a native seq_len=16 encoder within the band.
        let x = weights::init_input(5, 16, 256);
        let out = s
            .submit(encode("small", x.clone()), QoS::default())
            .unwrap()
            .wait()
            .unwrap()
            .into_encode()
            .unwrap();
        assert_eq!((out.output.rows, out.output.cols), (16, 256));
        let mask = reference::attention_mask(16, 16, false);
        let want = reference::encoder_stack(&x, &spec.weights(), &mask);
        assert!(out.output.max_abs_diff(&want) < 2e-3);
        // A 10-row request pads into the same 16-row bucket and is
        // cropped back to its live rows on the way out.
        let y = weights::init_input(6, 10, 256);
        let out = s
            .submit(encode("small", y), QoS::default())
            .unwrap()
            .wait()
            .unwrap()
            .into_encode()
            .unwrap();
        assert_eq!((out.output.rows, out.output.cols), (10, 256));
        let m = s.shutdown().unwrap();
        assert_eq!(m.requests(), 2);
        assert_eq!(m.actual_rows, 16 + 10, "live rows as requested");
        assert_eq!(m.padded_rows, 16 + 16, "both requests dispatch in the 16-row bucket");
        assert!(m.report().contains("padding waste"), "{}", m.report());
    }

    #[test]
    fn multi_model_serving_reprograms_between_models() {
        require_artifacts!();
        let a = ModelSpec::new("a", presets::small_encoder(32, 1), 1);
        let b = ModelSpec::new("b", crate::model::TnnConfig::encoder(48, 128, 2, 1), 2);
        let s = server(vec![a, b]);
        for i in 0..3 {
            let xa = weights::init_input(i, 32, 256);
            let xb = weights::init_input(i + 10, 48, 128);
            assert!(s.submit(encode("a", xa), QoS::default()).unwrap().wait().is_ok());
            assert!(s.submit(encode("b", xb), QoS::default()).unwrap().wait().is_ok());
        }
        let m = s.shutdown().unwrap();
        assert_eq!(m.requests(), 6);
        assert!(m.reprograms >= 2, "model switches must reprogram registers");
    }

    #[test]
    fn live_metrics_snapshot_while_serving() {
        require_artifacts!();
        let s = server(vec![ModelSpec::new("small", presets::small_encoder(32, 1), 9)]);
        assert_eq!(s.metrics().requests(), 0, "nothing served yet");
        let x = weights::init_input(2, 32, 256);
        s.submit(encode("small", x), QoS::default()).unwrap().wait().unwrap();
        // the pool is still running — shutdown() is not the only exit
        let live = s.metrics();
        assert_eq!(live.requests(), 1);
        assert_eq!(live.per_fabric.len(), 1);
        assert!(live.elapsed > 0.0);
        assert!(live.throughput_rps() > 0.0);
        let end = s.shutdown().unwrap();
        assert_eq!(end.requests(), 1);
    }

    #[test]
    fn rejects_bad_requests_fast() {
        require_artifacts!();
        let s = server(vec![ModelSpec::new("small", presets::small_encoder(32, 1), 3)]);
        // Short inputs now route (length-adaptive); only over-long rows
        // and wrong widths are refused at submission time.
        let too_long = weights::init_input(0, 40, 256);
        assert!(matches!(
            s.submit(encode("small", too_long), QoS::default()),
            Err(ServeError::InvalidRequest(_))
        ));
        let wrong_width = weights::init_input(0, 16, 128);
        assert!(matches!(
            s.submit(encode("small", wrong_width), QoS::default()),
            Err(ServeError::InvalidRequest(_))
        ));
        let unknown = weights::init_input(0, 32, 256);
        assert!(matches!(
            s.submit(encode("nope", unknown), QoS::default()),
            Err(ServeError::UnknownModel(_))
        ));
        s.shutdown().unwrap();
    }

    #[test]
    fn deprecated_v0_shims_still_serve() {
        require_artifacts!();
        let spec = ModelSpec::new("small", presets::small_encoder(32, 1), 21);
        let s = server(vec![spec.clone()]);
        let x = weights::init_input(1, 32, 256);
        #[allow(deprecated)]
        let resp = s.infer(Request { model: "small".into(), input: x.clone() }).unwrap();
        let mask = reference::attention_mask(32, 32, false);
        let want = reference::encoder_stack(&x, &spec.weights(), &mask);
        assert!(resp.output.max_abs_diff(&want) < 2e-3);
        assert!(resp.latency >= resp.compute);
        s.shutdown().unwrap();
    }

    #[test]
    fn zero_pool_size_is_refused() {
        let mut cfg = ServerConfig::new(vec![]);
        cfg.pool_size = 0;
        assert!(matches!(Server::start(cfg), Err(ServeError::InvalidConfig(_))));
    }

    #[test]
    fn out_of_range_affinity_is_refused_at_start() {
        // No artifacts needed: validation runs before any fabric spawns.
        let spec = ModelSpec::new("pinned", presets::small_encoder(32, 1), 1).with_affinity(3);
        let mut cfg = ServerConfig::new(vec![spec]);
        cfg.pool_size = 2;
        match Server::start(cfg) {
            Err(ServeError::AffinityOutOfRange { model, fabric, pool_size }) => {
                assert_eq!(model, "pinned");
                assert_eq!(fabric, 3);
                assert_eq!(pool_size, 2);
            }
            Err(other) => panic!("expected AffinityOutOfRange, got {other:?}"),
            Ok(_) => panic!("expected AffinityOutOfRange, got a running server"),
        }
    }

    // ---- PoolScheduler unit tests (no artifacts needed) ----

    #[test]
    fn affinity_keeps_a_model_on_its_fabric() {
        let mut s = PoolScheduler::new(SchedulePolicy::Affinity, 2);
        assert_eq!(s.pick("a", None, 1), 0);
        s.complete(0, 1);
        // fabric 0 is idle but programmed for "a"; "b" must prefer the
        // unprogrammed fabric 1 over evicting "a".
        assert_eq!(s.pick("b", None, 1), 1);
        s.complete(1, 1);
        // both idle: each model sticks to its programmed fabric.
        assert_eq!(s.pick("a", None, 1), 0);
        assert_eq!(s.pick("b", None, 1), 1);
        assert_eq!(s.current_model(0), Some("a"));
        assert_eq!(s.current_model(1), Some("b"));
    }

    #[test]
    fn affinity_falls_back_to_least_loaded() {
        let mut s = PoolScheduler::new(SchedulePolicy::Affinity, 3);
        assert_eq!(s.pick("a", None, 4), 0);
        assert_eq!(s.pick("b", None, 2), 1);
        assert_eq!(s.pick("c", None, 1), 2);
        // new model "d": all fabrics programmed, least-loaded is fabric 2.
        assert_eq!(s.pick("d", None, 1), 2);
        // "a" again: its fabric is the busiest, but affinity still wins
        // (a reprogram costs more than queueing behind the same model).
        assert_eq!(s.pick("a", None, 1), 0);
        assert_eq!(s.inflight(0), 5);
    }

    #[test]
    fn round_robin_cycles_regardless_of_programming() {
        let mut s = PoolScheduler::new(SchedulePolicy::RoundRobin, 2);
        assert_eq!(s.pick("a", None, 1), 0);
        assert_eq!(s.pick("a", None, 1), 1);
        assert_eq!(s.pick("a", None, 1), 0);
        assert_eq!(s.pick("b", None, 1), 1);
    }

    #[test]
    fn router_hint_pins_a_model() {
        let mut s = PoolScheduler::new(SchedulePolicy::Affinity, 3);
        assert_eq!(s.pick("pinned", Some(2), 1), 2);
        assert_eq!(s.pick("pinned", Some(2), 1), 2);
        // out-of-range hints are ignored at this layer, falling back to
        // the heuristic (Server::start refuses them before they get here)
        assert_eq!(s.pick("other", Some(9), 1), 0);
    }

    #[test]
    fn complete_decrements_and_saturates() {
        let mut s = PoolScheduler::new(SchedulePolicy::Affinity, 1);
        s.pick("a", None, 3);
        assert_eq!(s.inflight(0), 3);
        s.complete(0, 2);
        assert_eq!(s.inflight(0), 1);
        s.complete(0, 5); // over-completion saturates at zero
        assert_eq!(s.inflight(0), 0);
        s.complete(7, 1); // unknown fabric is ignored
    }

    #[test]
    fn capacity_gate_meters_outstanding_batches() {
        let mut s = PoolScheduler::new(SchedulePolicy::Affinity, 2);
        assert!(s.can_place("a", None, 1));
        s.pick("a", None, 4); // one batch on fabric 0
        // "a"'s affinity fabric is full at depth 1, but another fabric
        // has room — affinity falls back rather than queue-blocking.
        assert!(s.can_place("a", None, 1));
        assert_eq!(s.pick_within_depth("a", None, 1, 1), Some(1), "falls back past the full fabric");
        assert!(!s.can_place("b", None, 1), "both fabrics hold a batch");
        assert!(s.can_place("b", None, 2), "depth 2 double-buffers");
        s.complete(0, 4);
        assert!(s.can_place("b", None, 1), "completion frees the slot");
        assert_eq!(s.pick_within_depth("b", None, 1, 1), Some(0));
    }

    #[test]
    fn pinned_models_wait_for_their_pinned_fabric() {
        let mut s = PoolScheduler::new(SchedulePolicy::Affinity, 2);
        s.pick("a", Some(0), 1);
        // fabric 0 (the pin target) is full at depth 1; fabric 1 is idle,
        // but a pin means THAT fabric — the batch waits in the queue.
        assert!(!s.can_place("a", Some(0), 1));
        assert_eq!(s.pick_within_depth("a", Some(0), 1, 1), None);
        assert!(s.can_place("a", Some(0), 2));
        s.complete(0, 1);
        assert_eq!(s.pick_within_depth("a", Some(0), 1, 1), Some(0));
    }

    #[test]
    fn round_robin_scans_past_full_fabrics() {
        let mut s = PoolScheduler::new(SchedulePolicy::RoundRobin, 3);
        assert_eq!(s.pick_within_depth("a", None, 1, 1), Some(0));
        // cursor is at 1; all of 1, 2 free → next pick lands on 1
        assert_eq!(s.pick_within_depth("a", None, 1, 1), Some(1));
        // cursor at 2; fill it too, then the pool is saturated at depth 1
        assert_eq!(s.pick_within_depth("a", None, 1, 1), Some(2));
        assert!(!s.can_place("a", None, 1));
        // freeing fabric 1 lets the scan skip still-full fabric 0
        s.complete(1, 1);
        assert_eq!(s.pick_within_depth("a", None, 1, 1), Some(1), "scan skips full fabrics");
    }

    #[test]
    fn dead_fabrics_are_never_placed_and_release_capacity() {
        let mut s = PoolScheduler::new(SchedulePolicy::Affinity, 2);
        s.pick("a", None, 2); // fabric 0 busy with "a"
        s.mark_dead(0);
        // the dead fabric's stuck capacity no longer gates anything and
        // placement skips it entirely
        assert!(s.can_place("a", None, 1));
        assert_eq!(s.pick_within_depth("a", None, 1, 1), Some(1));
        // a model pinned to the dead fabric can never be placed — the
        // dispatcher fails its queued jobs instead of hanging
        assert!(!s.can_place_ever("pinned", Some(0)));
        assert!(s.can_place_ever("a", None));
        // a fully dead pool can place nothing
        let mut all = PoolScheduler::new(SchedulePolicy::Affinity, 1);
        all.mark_dead(0);
        assert!(!all.can_place_ever("x", None));
    }

    #[test]
    fn preview_matches_pick_and_does_not_commit() {
        let mut s = PoolScheduler::new(SchedulePolicy::Affinity, 2);
        let previewed = s.preview("a", None);
        assert_eq!(s.pick("a", None, 1), previewed);
        // preview is pure: repeated calls agree and nothing is accounted
        assert_eq!(s.preview("a", None), 0, "affinity sticks to the programmed fabric");
        assert_eq!(s.preview("a", None), 0);
        assert_eq!(s.preview("b", None), 1, "new model previews the unprogrammed fabric");
        assert_eq!(s.inflight(1), 0, "preview must not account in-flight work");

        // round-robin preview shows the next target without advancing
        let mut r = PoolScheduler::new(SchedulePolicy::RoundRobin, 2);
        assert_eq!(r.preview("a", None), 0);
        assert_eq!(r.preview("a", None), 0, "preview must not advance the cursor");
        assert_eq!(r.pick("a", None, 1), 0);
        assert_eq!(r.preview("a", None), 1);
    }

    #[test]
    fn zero_queue_depth_is_refused() {
        let mut cfg = ServerConfig::new(vec![]);
        cfg.queue_depth = 0;
        assert!(matches!(Server::start(cfg), Err(ServeError::InvalidConfig(_))));
    }

    #[test]
    fn zero_max_seqs_is_refused() {
        let mut cfg = ServerConfig::new(vec![]);
        cfg.max_seqs = 0;
        assert!(matches!(Server::start(cfg), Err(ServeError::InvalidConfig(_))));
    }

    #[test]
    fn scheduler_round_order_is_priority_model_arrival() {
        // The sequence scheduler's per-round order: QoS priority leads,
        // same-model sequences group (one reprogram per model per
        // round), FIFO within a model.
        let t0 = Instant::now();
        let t1 = t0 + Duration::from_millis(1);
        let mut seqs = vec![
            ("n-b-late", Priority::Normal, "b", t1),
            ("n-a-late", Priority::Normal, "a", t1),
            ("h-b", Priority::High, "b", t0),
            ("n-a-early", Priority::Normal, "a", t0),
            ("l-a", Priority::Low, "a", t0),
            ("h-a", Priority::High, "a", t1),
        ];
        seqs.sort_by(|a, b| seq_round_order((a.1, a.2, a.3), (b.1, b.2, b.3)));
        let order: Vec<&str> = seqs.iter().map(|s| s.0).collect();
        assert_eq!(order, ["h-a", "h-b", "n-a-early", "n-a-late", "n-b-late", "l-a"]);
    }

    #[test]
    fn cost_aware_prefers_the_resident_fabric() {
        let mut s = PoolScheduler::new(SchedulePolicy::CostAware, 2);
        s.set_upload_penalty("a", 2.0);
        s.note_residency(1, &["a".to_string()]);
        // fabric 0 is idle but cold (cost 0 + 2.0); fabric 1 holds the
        // stack (cost 0 + 0.0) — affinity emerges from residency.
        assert_eq!(s.pick("a", None, 1), 1);
        assert_eq!(s.pick("a", None, 1), 1, "stays put while its queue is shallow");
    }

    #[test]
    fn cost_aware_spills_when_queue_cost_exceeds_the_upload_penalty() {
        let mut s = PoolScheduler::new(SchedulePolicy::CostAware, 2);
        s.set_upload_penalty("a", 1.5);
        s.note_residency(0, &["a".to_string()]);
        assert_eq!(s.pick("a", None, 1), 0);
        assert_eq!(s.pick("a", None, 1), 0, "inflight 1 < penalty 1.5 keeps affinity");
        // Two requests deep, the queue now outweighs a 1.5-request
        // upload: the batch spills to the cold fabric, which will
        // upload the stack and share the load from here on.
        assert_eq!(s.pick("a", None, 1), 1);
    }

    #[test]
    fn cost_aware_hint_still_pins() {
        let mut s = PoolScheduler::new(SchedulePolicy::CostAware, 3);
        s.set_upload_penalty("p", 10.0);
        s.note_residency(1, &["p".to_string()]);
        assert_eq!(s.pick("p", Some(2), 1), 2, "an operator pin beats residency scoring");
    }

    #[test]
    fn prefetch_stages_a_hot_model_on_exactly_one_extra_fabric() {
        let mut s = PoolScheduler::new(SchedulePolicy::CostAware, 3);
        assert_eq!(s.prefetch_target("a"), None, "not resident anywhere: dispatch uploads it");
        s.note_residency(0, &["a".to_string()]);
        assert_eq!(s.prefetch_target("a"), Some(1), "least-loaded cold fabric");
        assert_eq!(s.prefetch_target("a"), None, "already staged on a second fabric");
    }

    #[test]
    fn residency_snapshots_replace_the_belief() {
        let mut s = PoolScheduler::new(SchedulePolicy::CostAware, 2);
        s.set_upload_penalty("a", 3.0);
        // Equal cost everywhere: deterministic lowest index, and the
        // pick optimistically marks fabric 0 resident.
        assert_eq!(s.pick("a", None, 1), 0);
        s.complete(0, 1);
        // The worker's snapshot says the stack was evicted on 0 and
        // lives on 1 — the belief is replaced, not merged.
        s.note_residency(0, &[]);
        s.note_residency(1, &["a".to_string()]);
        assert_eq!(s.pick("a", None, 1), 1);
    }

    #[test]
    fn scheduler_reprogram_proxy_affinity_vs_round_robin() {
        // Count model switches per fabric under the [a, a, b] request
        // pattern — the pure-logic version of the pool integration test.
        let switches = |policy: SchedulePolicy| {
            let mut s = PoolScheduler::new(policy, 2);
            let mut programmed: Vec<Option<String>> = vec![None; 2];
            let mut switches = 0;
            for _round in 0..4 {
                for model in ["a", "a", "b"] {
                    let f = s.pick(model, None, 1);
                    if programmed[f].as_deref() != Some(model) {
                        switches += 1;
                        programmed[f] = Some(model.to_string());
                    }
                    s.complete(f, 1);
                }
            }
            switches
        };
        let affinity = switches(SchedulePolicy::Affinity);
        let rr = switches(SchedulePolicy::RoundRobin);
        assert_eq!(affinity, 2, "affinity programs each fabric exactly once");
        assert!(rr > affinity, "round-robin ({rr}) must reprogram more than affinity ({affinity})");
    }

    #[test]
    fn place_chain_spreads_stages_over_distinct_fabrics() {
        let mut s = PoolScheduler::new(SchedulePolicy::Affinity, 3);
        let chain = s.place_chain("big", 3, 2, 1).expect("3 live fabrics fit a 3-stage chain");
        assert_eq!(chain.len(), 3);
        let mut sorted = chain.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 3, "a fabric may host at most one stage of a chain");
        // every chain fabric carries the round's accounting
        for &f in &chain {
            assert_eq!(s.inflight(f), 2);
        }
        for &f in &chain {
            s.complete(f, 2);
            assert_eq!(s.inflight(f), 0, "stage acks release the chain capacity");
        }
    }

    #[test]
    fn place_chain_prefers_shard_resident_fabrics() {
        let mut s = PoolScheduler::new(SchedulePolicy::Affinity, 3);
        // the worker snapshots say stage 0 lives on fabric 2, stage 1 on 0
        s.note_residency(2, &[shard::residency_key("big", 0, 2)]);
        s.note_residency(0, &[shard::residency_key("big", 1, 2)]);
        let chain = s.place_chain("big", 2, 1, 1).unwrap();
        assert_eq!(chain, vec![2, 0], "each stage lands where its shard is already resident");
        // a stale key for the wrong shard count must not attract a stage
        let mut t = PoolScheduler::new(SchedulePolicy::Affinity, 2);
        t.note_residency(1, &[shard::residency_key("big", 0, 3)]);
        assert_eq!(t.place_chain("big", 2, 1, 1).unwrap(), vec![0, 1], "3-way keys don't match a 2-way chain");
    }

    #[test]
    fn chain_capacity_gate_respects_queue_depth() {
        let mut s = PoolScheduler::new(SchedulePolicy::Affinity, 2);
        assert!(s.can_place_chain("big", 2, 1));
        let chain = s.place_chain("big", 2, 4, 1).unwrap();
        // every fabric now holds a batch: a depth-1 pool is saturated,
        // for chains and singles alike
        assert!(!s.can_place_chain("big", 2, 1));
        assert!(!s.can_place("other", None, 1));
        assert!(s.can_place_chain("big", 2, 2), "depth 2 double-buffers the pipeline");
        for &f in &chain {
            s.complete(f, 4);
        }
        assert!(s.can_place_chain("big", 2, 1), "acks reopen the gate");
    }

    #[test]
    fn chains_need_k_live_fabrics_forever_not_just_now() {
        let mut s = PoolScheduler::new(SchedulePolicy::Affinity, 3);
        assert!(s.can_place_chain_ever(3));
        s.mark_dead(1);
        // two live fabrics can still host a 2-chain, never a 3-chain
        assert!(s.can_place_chain_ever(2));
        assert!(!s.can_place_chain_ever(3), "a dead fabric shrinks the pool for good");
        let chain = s.place_chain("big", 2, 1, 1).unwrap();
        assert!(!chain.contains(&1), "dead fabrics never host a stage");
        s.mark_dead(0);
        assert!(!s.can_place_chain_ever(2));
    }

    #[test]
    fn program_failure_fails_the_batch_not_silently() {
        require_artifacts!();
        let a = ModelSpec::new("a", presets::small_encoder(32, 1), 1);
        let b = ModelSpec::new("b", crate::model::TnnConfig::encoder(48, 128, 2, 1), 2);
        let mut cfg = ServerConfig::new(vec![a, b]);
        cfg.policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(2) };
        cfg.fault.fail_program_for = Some("b".into());
        let s = Server::start(cfg).unwrap();
        // "a" serves fine
        let xa = weights::init_input(1, 32, 256);
        assert!(s.submit(encode("a", xa.clone()), QoS::default()).unwrap().wait().is_ok());
        // "b" must fail with the typed programming error — not run on
        // stale registers
        let xb = weights::init_input(2, 48, 128);
        let err = s.submit(encode("b", xb), QoS::default()).unwrap().wait().unwrap_err();
        match &err {
            ServeError::ProgramFailed(msg) => {
                assert!(msg.contains("programming registers"), "{msg}")
            }
            other => panic!("expected ProgramFailed, got {other:?}"),
        }
        // the fabric recovers: "a" still serves afterwards
        assert!(s.submit(encode("a", xa), QoS::default()).unwrap().wait().is_ok());
        let m = s.shutdown().unwrap();
        assert_eq!(m.requests(), 2, "failed request must not count as served");
        assert_eq!(m.failed, 1);
        assert_eq!(m.batch_sizes.len(), 2, "unserved batch must not be recorded");
    }
}
