//! The serving loop — a pool of ADAPTOR fabrics behind one dispatcher.
//!
//! `PjRtLoadedExecutable` is not `Send`, so every fabric is a dedicated
//! **worker thread** that constructs its own `TileEngine` locally and
//! drains batches from a per-fabric mpsc queue.  A single **dispatcher**
//! thread owns the batcher (per-model ready queues) and assigns ready
//! batches to fabrics under a [`SchedulePolicy`]: with `Affinity` a batch
//! is routed to a fabric already programmed for its model (avoiding a
//! register reprogram), falling back to the least-loaded fabric; with
//! `RoundRobin` fabrics are cycled regardless of programming state (the
//! baseline the affinity tests compare against).
//!
//! `pool_size = 1` reproduces the paper's host software exactly: one
//! fabric, one register file, reprograms on every model switch — the
//! paper-reproduction path is unchanged.  Clients submit from any thread
//! and receive their response over a per-request channel.
//!
//! Failure semantics (each was a silent failure in the single-fabric
//! predecessor):
//! * a failed `engine.program()` fails the **whole batch** with the
//!   programming error — requests are never run against the previous
//!   model's register state;
//! * batches are counted in metrics only once actually served;
//! * `Response` reports `compute`, `queue_wait` and end-to-end `latency`
//!   separately;
//! * `shutdown()` returns `anyhow::Result<Metrics>` and surfaces worker
//!   panics instead of returning empty metrics as if the run were clean.

use std::collections::BTreeMap;
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail};

use super::batcher::{BatchPolicy, Batcher};
use super::engine::{AttentionMode, OptLevel, PreparedStack, TileEngine};
use super::metrics::Metrics;
use super::router::{ModelSpec, Router};
use crate::model::weights::Mat;

/// One inference request: model name + input activations.
#[derive(Debug, Clone)]
pub struct Request {
    pub model: String,
    pub input: Mat,
}

/// The response: output activations + timing breakdown.
#[derive(Debug)]
pub struct Response {
    pub output: Mat,
    /// End-to-end latency: submit → response ready (queue + compute).
    pub latency: Duration,
    /// Time spent executing on the fabric.
    pub compute: Duration,
    /// Time between submit and this request *starting to execute* —
    /// includes batching delay, dispatch, any register reprogram, and
    /// (for the 2nd..Nth members of a batch) the compute time of
    /// earlier members, so `latency == queue_wait + compute` holds.
    pub queue_wait: Duration,
}

/// One generation request: greedy-decode `steps` tokens from `prompt`
/// (rows of `d_model` activations) on a `dec_layers > 0` model; seq2seq
/// models additionally encode `source` into the cross-attention memory.
#[derive(Debug, Clone)]
pub struct GenerateRequest {
    pub model: String,
    pub prompt: Mat,
    pub source: Option<Mat>,
    pub steps: usize,
}

/// A generation's response: the produced rows/token ids plus the
/// per-token timing split the metrics aggregate.
#[derive(Debug)]
pub struct GenerateResponse {
    /// Generated activation rows, `steps × d_model`.
    pub rows: Mat,
    /// Greedy token ids, one per step.
    pub tokens: Vec<usize>,
    /// End-to-end latency (queue + compute).
    pub latency: Duration,
    pub queue_wait: Duration,
    /// Source encode + prompt prefill (cache population) time.
    pub prefill: Duration,
    /// Per-token decode-step times (`steps - 1` entries).
    pub step_times: Vec<Duration>,
}

/// How the dispatcher assigns ready batches to pool fabrics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulePolicy {
    /// Route to a fabric already programmed for the batch's model; fall
    /// back to an unprogrammed or least-loaded fabric.  Router affinity
    /// hints ([`ModelSpec::with_affinity`]) take precedence.
    Affinity,
    /// Cycle through fabrics regardless of programming state (baseline
    /// scheduler; maximizes reprograms under mixed-model load).
    RoundRobin,
}

/// Fault injection for failure-path regression tests.  Inert by default;
/// production configs never set it.
#[derive(Debug, Clone, Default)]
pub struct FaultInjection {
    /// Treat `engine.program()` as failing for this model name, exercising
    /// the batch-fails-on-programming-error path.
    pub fail_program_for: Option<String>,
}

/// Server construction parameters.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub artifact_dir: std::path::PathBuf,
    pub models: Vec<ModelSpec>,
    pub policy: BatchPolicy,
    pub attention: AttentionMode,
    /// TileProgram optimization level every fabric serves at (the pass
    /// pipeline of `accel::schedule::opt`; `O2` — dedup, dispatch fusion,
    /// wave scheduling, slot compaction — is the serving default).
    pub opt_level: OptLevel,
    /// Number of fabric workers.  `1` (the default) is the paper's
    /// single-fabric host software.
    pub pool_size: usize,
    pub schedule: SchedulePolicy,
    pub fault: FaultInjection,
}

impl ServerConfig {
    pub fn new(models: Vec<ModelSpec>) -> Self {
        ServerConfig {
            artifact_dir: crate::runtime::default_artifact_dir(),
            models,
            policy: BatchPolicy::default(),
            attention: AttentionMode::Fused,
            opt_level: OptLevel::O2,
            pool_size: 1,
            schedule: SchedulePolicy::Affinity,
            fault: FaultInjection::default(),
        }
    }
}

type ReplyTx = Sender<anyhow::Result<Response>>;
type GenReplyTx = Sender<anyhow::Result<GenerateResponse>>;

/// One unit of fabric work: an encode request or a generation, each with
/// its own reply channel.  Both kinds ride the same per-model batcher
/// queues (same register programming, same weight residency).
enum Job {
    Infer { req: Request, reply: ReplyTx },
    Generate { req: GenerateRequest, reply: GenReplyTx },
}

impl Job {
    fn model(&self) -> &str {
        match self {
            Job::Infer { req, .. } => &req.model,
            Job::Generate { req, .. } => &req.model,
        }
    }

    /// Fail the job with `msg` (worker lost, programming error, …).
    fn fail(self, msg: String) {
        match self {
            Job::Infer { reply, .. } => {
                let _ = reply.send(Err(anyhow!(msg)));
            }
            Job::Generate { reply, .. } => {
                let _ = reply.send(Err(anyhow!(msg)));
            }
        }
    }
}

/// A request in flight: payload + submit instant.
type WorkItem = (Job, Instant);

/// Client → dispatcher messages.
enum Msg {
    Work { job: Job, enqueued: Instant },
    Shutdown { reply: Sender<anyhow::Result<Metrics>> },
}

/// Dispatcher → fabric messages (ordered per fabric: a `Shutdown` sent
/// after a `Batch` is processed after it).
enum FabricMsg {
    Batch { model: String, items: Vec<WorkItem> },
    Shutdown { reply: Sender<Metrics> },
}

/// Fabric → dispatcher completion events (separate channel so the
/// dispatcher can still detect all *clients* disconnecting).
struct FabricEvent {
    fabric: usize,
    served: usize,
}

/// Per-fabric programming/load state tracked by the dispatcher.  This is
/// the dispatcher's *belief* (programming happens on the worker), which is
/// exact under normal operation and conservative under failures.
#[derive(Debug, Default, Clone)]
struct FabricState {
    current_model: Option<String>,
    inflight: usize,
}

/// Pure batch→fabric assignment logic (unit-testable without artifacts).
#[derive(Debug)]
pub struct PoolScheduler {
    policy: SchedulePolicy,
    states: Vec<FabricState>,
    rr_next: usize,
}

impl PoolScheduler {
    pub fn new(policy: SchedulePolicy, fabrics: usize) -> Self {
        assert!(fabrics > 0, "a pool needs at least one fabric");
        PoolScheduler { policy, states: vec![FabricState::default(); fabrics], rr_next: 0 }
    }

    /// Choose the fabric for a ready batch of `model` and account for it
    /// (`batch_len` requests become in-flight on the chosen fabric).
    pub fn pick(&mut self, model: &str, hint: Option<usize>, batch_len: usize) -> usize {
        let n = self.states.len();
        let chosen = match self.policy {
            SchedulePolicy::RoundRobin => {
                let i = self.rr_next;
                self.rr_next = (self.rr_next + 1) % n;
                i
            }
            SchedulePolicy::Affinity => {
                if let Some(h) = hint.filter(|h| *h < n) {
                    h
                } else if let Some(i) = self
                    .states
                    .iter()
                    .enumerate()
                    .filter(|(_, s)| s.current_model.as_deref() == Some(model))
                    .min_by_key(|(_, s)| s.inflight)
                    .map(|(i, _)| i)
                {
                    i
                } else {
                    // Least-loaded fallback; among equals prefer a fabric
                    // with nothing programmed yet over evicting a resident
                    // model, then the lowest index.
                    self.states
                        .iter()
                        .enumerate()
                        .min_by_key(|(i, s)| (s.inflight, s.current_model.is_some(), *i))
                        .map(|(i, _)| i)
                        .expect("pool is non-empty")
                }
            }
        };
        let s = &mut self.states[chosen];
        s.current_model = Some(model.to_string());
        s.inflight += batch_len;
        chosen
    }

    /// A fabric reported `served` requests finished.
    pub fn complete(&mut self, fabric: usize, served: usize) {
        if let Some(s) = self.states.get_mut(fabric) {
            s.inflight = s.inflight.saturating_sub(served);
        }
    }

    /// The model the scheduler believes `fabric` is programmed for.
    pub fn current_model(&self, fabric: usize) -> Option<&str> {
        self.states.get(fabric).and_then(|s| s.current_model.as_deref())
    }

    pub fn inflight(&self, fabric: usize) -> usize {
        self.states.get(fabric).map(|s| s.inflight).unwrap_or(0)
    }
}

/// Handle to the running server.
pub struct Server {
    tx: Sender<Msg>,
    router: Router,
    dispatcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl Server {
    /// Start the fabric pool; blocks until every fabric is warmed up (all
    /// models prepared and artifacts compiled) or fails.
    pub fn start(cfg: ServerConfig) -> anyhow::Result<Self> {
        if cfg.pool_size == 0 {
            bail!("pool_size must be >= 1");
        }
        // Router lives on the submit side for fail-fast validation.
        let mut router = Router::new(crate::accel::registers::SynthMaxima::artifact_default());
        for spec in &cfg.models {
            router.register(spec.clone())?;
        }

        let (tx, rx) = mpsc::channel::<Msg>();
        let (etx, erx) = mpsc::channel::<FabricEvent>();

        let mut fabric_txs = Vec::with_capacity(cfg.pool_size);
        let mut workers = Vec::with_capacity(cfg.pool_size);
        let mut readys = Vec::with_capacity(cfg.pool_size);
        for id in 0..cfg.pool_size {
            let (ftx, frx) = mpsc::channel::<FabricMsg>();
            let (ready_tx, ready_rx) = mpsc::channel::<anyhow::Result<()>>();
            let events = etx.clone();
            let fcfg = cfg.clone();
            let worker = std::thread::Builder::new()
                .name(format!("adaptor-fabric-{id}"))
                .spawn(move || fabric_thread(id, fcfg, frx, ready_tx, events))
                .expect("spawning fabric thread");
            fabric_txs.push(ftx);
            workers.push(worker);
            readys.push((id, ready_rx));
        }
        drop(etx); // dispatcher holds the receiver; fabrics hold the clones
        for (id, ready_rx) in readys {
            ready_rx.recv().map_err(|_| anyhow!("fabric {id} died during warmup"))??;
        }

        let hints: BTreeMap<String, usize> = cfg
            .models
            .iter()
            .filter_map(|s| s.preferred_fabric.map(|f| (s.name.clone(), f)))
            .collect();
        let scheduler = PoolScheduler::new(cfg.schedule, cfg.pool_size);
        let policy = cfg.policy;
        let dispatcher = std::thread::Builder::new()
            .name("adaptor-dispatch".into())
            .spawn(move || dispatcher_thread(policy, rx, erx, fabric_txs, scheduler, hints))
            .expect("spawning dispatcher thread");

        Ok(Server { tx, router, dispatcher: Some(dispatcher), workers })
    }

    pub fn models(&self) -> Vec<&str> {
        self.router.names()
    }

    /// Submit a request; returns the channel the response will arrive on.
    pub fn submit(&self, req: Request) -> anyhow::Result<Receiver<anyhow::Result<Response>>> {
        self.router.route(&req.model, req.input.rows, req.input.cols)?;
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Work { job: Job::Infer { req, reply }, enqueued: Instant::now() })
            .map_err(|_| anyhow!("dispatcher is gone"))?;
        Ok(rx)
    }

    /// Convenience: submit and wait.
    pub fn infer(&self, req: Request) -> anyhow::Result<Response> {
        self.submit(req)?.recv().map_err(|_| anyhow!("pool dropped the request"))?
    }

    /// Submit a generation request (fail-fast validated on the submit
    /// side, like [`Self::submit`]); returns its reply channel.
    pub fn submit_generate(
        &self,
        req: GenerateRequest,
    ) -> anyhow::Result<Receiver<anyhow::Result<GenerateResponse>>> {
        self.router.route_generate(
            &req.model,
            (req.prompt.rows, req.prompt.cols),
            req.source.as_ref().map(|s| (s.rows, s.cols)),
            req.steps,
        )?;
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Work { job: Job::Generate { req, reply }, enqueued: Instant::now() })
            .map_err(|_| anyhow!("dispatcher is gone"))?;
        Ok(rx)
    }

    /// Convenience: submit a generation and wait.
    pub fn generate(&self, req: GenerateRequest) -> anyhow::Result<GenerateResponse> {
        self.submit_generate(req)?.recv().map_err(|_| anyhow!("pool dropped the request"))?
    }

    /// Stop the pool and collect final metrics (aggregate with per-fabric
    /// breakdown).  A worker or dispatcher panic is propagated as an error
    /// rather than masked with empty metrics.
    pub fn shutdown(mut self) -> anyhow::Result<Metrics> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Shutdown { reply })
            .map_err(|_| anyhow!("dispatcher is gone (did it panic?)"))?;
        let result = rx
            .recv()
            .map_err(|_| anyhow!("dispatcher exited without reporting metrics (panic?)"));
        let mut panicked = Vec::new();
        if let Some(h) = self.dispatcher.take() {
            if h.join().is_err() {
                panicked.push("dispatcher".to_string());
            }
        }
        for (i, h) in self.workers.drain(..).enumerate() {
            if h.join().is_err() {
                panicked.push(format!("fabric {i}"));
            }
        }
        if !panicked.is_empty() {
            bail!("serving threads panicked: {}", panicked.join(", "));
        }
        result?
    }
}

fn dispatcher_thread(
    policy: BatchPolicy,
    rx: Receiver<Msg>,
    erx: Receiver<FabricEvent>,
    fabrics: Vec<Sender<FabricMsg>>,
    mut sched: PoolScheduler,
    hints: BTreeMap<String, usize>,
) {
    let mut batcher: Batcher<WorkItem> = Batcher::new(policy);
    let started = Instant::now();
    let mut shutdown_reply: Option<Sender<anyhow::Result<Metrics>>> = None;

    'outer: loop {
        // Wait for work, bounded by the oldest batch deadline.
        let timeout = batcher
            .next_deadline()
            .map(|d| d.saturating_duration_since(Instant::now()))
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(timeout) {
            Ok(Msg::Work { job, enqueued }) => {
                let model = job.model().to_string();
                batcher.push_at(&model, (job, enqueued), enqueued);
            }
            Ok(Msg::Shutdown { reply }) => {
                shutdown_reply = Some(reply);
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => break 'outer,
        }
        // Fold in completion events so load tracking stays fresh.
        while let Ok(ev) = erx.try_recv() {
            sched.complete(ev.fabric, ev.served);
        }
        let draining = shutdown_reply.is_some();
        while let Some((model, batch)) = batcher.pop_ready(Instant::now(), draining) {
            let fabric = sched.pick(&model, hints.get(&model).copied(), batch.len());
            let items: Vec<WorkItem> = batch.into_iter().map(|p| p.payload).collect();
            let n = items.len();
            if let Err(mpsc::SendError(lost)) =
                fabrics[fabric].send(FabricMsg::Batch { model, items })
            {
                // The worker thread is gone: fail the batch loudly instead
                // of dropping the reply channels.
                if let FabricMsg::Batch { items, .. } = lost {
                    for (job, _) in items {
                        job.fail(format!("fabric {fabric} is gone (worker died)"));
                    }
                }
                sched.complete(fabric, n);
            }
        }
        if draining && batcher.is_empty() {
            break 'outer;
        }
    }

    // Collect per-fabric metrics; per-fabric channel order guarantees all
    // dispatched batches are served before the Shutdown is processed.
    let mut per_fabric = Vec::with_capacity(fabrics.len());
    let mut failure: Option<anyhow::Error> = None;
    for (id, ftx) in fabrics.iter().enumerate() {
        let (mtx, mrx) = mpsc::channel();
        if ftx.send(FabricMsg::Shutdown { reply: mtx }).is_err() {
            failure.get_or_insert_with(|| anyhow!("fabric {id} terminated abnormally"));
            continue;
        }
        match mrx.recv() {
            Ok(m) => per_fabric.push(m),
            Err(_) => {
                failure
                    .get_or_insert_with(|| anyhow!("fabric {id} died during shutdown (metrics lost)"));
            }
        }
    }
    let result = match failure {
        Some(e) => Err(e),
        None => {
            let mut agg = Metrics::aggregate(per_fabric);
            agg.elapsed = started.elapsed().as_secs_f64();
            Ok(agg)
        }
    };
    if let Some(reply) = shutdown_reply {
        let _ = reply.send(result);
    }
}

fn fabric_thread(
    id: usize,
    cfg: ServerConfig,
    rx: Receiver<FabricMsg>,
    ready: Sender<anyhow::Result<()>>,
    events: Sender<FabricEvent>,
) {
    // Build the fabric locally (not Send).
    let mut engine = match TileEngine::new(&cfg.artifact_dir) {
        Ok(e) => e,
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    engine.mode = cfg.attention;
    engine.opt_level = cfg.opt_level;

    // Prepare every registered model's weights once (Algorithm 18, 4–12).
    let mut prepared: Vec<(String, PreparedStack)> = Vec::new();
    for spec in &cfg.models {
        match engine.prepare_model(&spec.cfg, &spec.weights(), &spec.decoder_weights()) {
            Ok(p) => prepared.push((spec.name.clone(), p)),
            Err(e) => {
                let _ = ready
                    .send(Err(e.context(format!("fabric {id}: preparing model '{}'", spec.name))));
                return;
            }
        }
    }
    // Warm the executable cache so first requests are not compile-bound.
    let mut names: Vec<&str> = vec![
        "mm_qkv", "mm_ffn1", "mm_ffn2", "mm_ffn3", "bias_add_dk", "bias_add_d", "bias_relu_h",
        "residual_ln", "qk_scores", "softmax", "sv", "attn_fused",
    ];
    if cfg.models.iter().any(|m| m.cfg.dec_layers > 0) {
        // Generation models need the decode-step row artifacts too; an
        // artifact set predating them fails here, at warmup, with the
        // missing names — not per-request mid-generation.
        names.extend([
            "dec_qkv_row", "qk_row", "softmax_row", "sv_row", "kv_append", "dec_proj_row",
            "dec_ffn1_row", "dec_ffn2_row", "residual_ln_row",
        ]);
    }
    if let Err(e) = engine.executor().warmup(&names) {
        let _ = ready.send(Err(e));
        return;
    }
    let _ = ready.send(Ok(()));

    let mut metrics = Metrics::for_fabric(id);
    let started = Instant::now();
    while let Ok(msg) = rx.recv() {
        match msg {
            FabricMsg::Batch { model, items } => {
                let served = items.len();
                serve_batch(&mut engine, &cfg.fault, &prepared, &mut metrics, &model, items);
                let _ = events.send(FabricEvent { fabric: id, served });
            }
            FabricMsg::Shutdown { reply } => {
                metrics.elapsed = started.elapsed().as_secs_f64();
                let _ = reply.send(metrics);
                return;
            }
        }
    }
    // Dispatcher hung up without a shutdown (server dropped): just exit.
}

/// Serve one model-homogeneous batch on a fabric.
fn serve_batch(
    engine: &mut TileEngine,
    fault: &FaultInjection,
    prepared: &[(String, PreparedStack)],
    metrics: &mut Metrics,
    model: &str,
    items: Vec<WorkItem>,
) {
    let Some((_, stack)) = prepared.iter().find(|(n, _)| n == model) else {
        metrics.failed += items.len() as u64;
        for (job, _) in items {
            job.fail(format!("model '{model}' not prepared on this fabric"));
        }
        return;
    };
    // Reprogram only when the register file holds a different topology.
    if !engine.is_programmed_for(&stack.cfg) {
        let programmed = if fault.fail_program_for.as_deref() == Some(model) {
            Err(anyhow!("injected register-programming fault"))
        } else {
            engine.program(&stack.cfg)
        };
        match programmed {
            Ok(()) => metrics.reprograms += 1,
            Err(e) => {
                // A failed program() fails the whole batch: running against
                // the previous model's register state would silently return
                // wrong numerics.
                let msg = format!("{e:#}");
                metrics.failed += items.len() as u64;
                for (job, _) in items {
                    job.fail(format!("programming registers for model '{model}': {msg}"));
                }
                return;
            }
        }
    }
    // Count the batch only once the model is prepared AND programmed.
    metrics.record_batch(items.len());
    for (job, enqueued) in items {
        let queue_wait = enqueued.elapsed();
        let t0 = Instant::now();
        match job {
            Job::Infer { req, reply } => {
                let result = engine.run_encoder(stack, &req.input).map(|output| Response {
                    output,
                    compute: t0.elapsed(),
                    queue_wait,
                    latency: enqueued.elapsed(),
                });
                match &result {
                    Ok(r) => metrics.record(r.compute, r.queue_wait, r.latency),
                    Err(_) => metrics.failed += 1,
                }
                let _ = reply.send(result);
            }
            Job::Generate { req, reply } => {
                let result = engine
                    .generate(stack, &req.prompt, req.source.as_ref(), req.steps)
                    .map(|g| GenerateResponse {
                        rows: g.rows,
                        tokens: g.tokens,
                        latency: enqueued.elapsed(),
                        queue_wait,
                        prefill: g.prefill,
                        step_times: g.step_times,
                    });
                match &result {
                    Ok(r) => {
                        // Success-only sampling: a failed generation must
                        // never pollute the prefill/per-token summaries.
                        metrics.record_generation(r.prefill, &r.step_times);
                        metrics.record(t0.elapsed(), r.queue_wait, r.latency);
                    }
                    Err(_) => metrics.failed += 1,
                }
                let _ = reply.send(result);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{presets, reference, weights};

    use crate::require_artifacts;

    fn server(models: Vec<ModelSpec>) -> Server {
        let mut cfg = ServerConfig::new(models);
        cfg.policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(2) };
        Server::start(cfg).expect("run `make artifacts` first")
    }

    #[test]
    fn serves_correct_outputs() {
        require_artifacts!();
        let spec = ModelSpec::new("small", presets::small_encoder(32, 1), 21);
        let s = server(vec![spec.clone()]);
        let x = weights::init_input(1, 32, 256);
        let resp = s.infer(Request { model: "small".into(), input: x.clone() }).unwrap();
        let mask = reference::attention_mask(32, 32, false);
        let want = reference::encoder_stack(&x, &spec.weights(), &mask);
        assert!(resp.output.max_abs_diff(&want) < 2e-3);
        // timing decomposition: e2e covers queue + compute
        assert!(resp.latency >= resp.compute);
        assert!(resp.latency >= resp.queue_wait);
        let m = s.shutdown().unwrap();
        assert_eq!(m.requests(), 1);
        assert_eq!(m.failed, 0);
    }

    #[test]
    fn multi_model_serving_reprograms_between_models() {
        require_artifacts!();
        let a = ModelSpec::new("a", presets::small_encoder(32, 1), 1);
        let b = ModelSpec::new("b", crate::model::TnnConfig::encoder(48, 128, 2, 1), 2);
        let s = server(vec![a, b]);
        for i in 0..3 {
            let xa = weights::init_input(i, 32, 256);
            let xb = weights::init_input(i + 10, 48, 128);
            assert!(s.infer(Request { model: "a".into(), input: xa }).is_ok());
            assert!(s.infer(Request { model: "b".into(), input: xb }).is_ok());
        }
        let m = s.shutdown().unwrap();
        assert_eq!(m.requests(), 6);
        assert!(m.reprograms >= 2, "model switches must reprogram registers");
    }

    #[test]
    fn rejects_bad_requests_fast() {
        require_artifacts!();
        let s = server(vec![ModelSpec::new("small", presets::small_encoder(32, 1), 3)]);
        let wrong_shape = weights::init_input(0, 16, 256);
        assert!(s.submit(Request { model: "small".into(), input: wrong_shape }).is_err());
        let unknown = weights::init_input(0, 32, 256);
        assert!(s.submit(Request { model: "nope".into(), input: unknown }).is_err());
        s.shutdown().unwrap();
    }

    #[test]
    fn zero_pool_size_is_refused() {
        let mut cfg = ServerConfig::new(vec![]);
        cfg.pool_size = 0;
        assert!(Server::start(cfg).is_err());
    }

    // ---- PoolScheduler unit tests (no artifacts needed) ----

    #[test]
    fn affinity_keeps_a_model_on_its_fabric() {
        let mut s = PoolScheduler::new(SchedulePolicy::Affinity, 2);
        assert_eq!(s.pick("a", None, 1), 0);
        s.complete(0, 1);
        // fabric 0 is idle but programmed for "a"; "b" must prefer the
        // unprogrammed fabric 1 over evicting "a".
        assert_eq!(s.pick("b", None, 1), 1);
        s.complete(1, 1);
        // both idle: each model sticks to its programmed fabric.
        assert_eq!(s.pick("a", None, 1), 0);
        assert_eq!(s.pick("b", None, 1), 1);
        assert_eq!(s.current_model(0), Some("a"));
        assert_eq!(s.current_model(1), Some("b"));
    }

    #[test]
    fn affinity_falls_back_to_least_loaded() {
        let mut s = PoolScheduler::new(SchedulePolicy::Affinity, 3);
        assert_eq!(s.pick("a", None, 4), 0);
        assert_eq!(s.pick("b", None, 2), 1);
        assert_eq!(s.pick("c", None, 1), 2);
        // new model "d": all fabrics programmed, least-loaded is fabric 2.
        assert_eq!(s.pick("d", None, 1), 2);
        // "a" again: its fabric is the busiest, but affinity still wins
        // (a reprogram costs more than queueing behind the same model).
        assert_eq!(s.pick("a", None, 1), 0);
        assert_eq!(s.inflight(0), 5);
    }

    #[test]
    fn round_robin_cycles_regardless_of_programming() {
        let mut s = PoolScheduler::new(SchedulePolicy::RoundRobin, 2);
        assert_eq!(s.pick("a", None, 1), 0);
        assert_eq!(s.pick("a", None, 1), 1);
        assert_eq!(s.pick("a", None, 1), 0);
        assert_eq!(s.pick("b", None, 1), 1);
    }

    #[test]
    fn router_hint_pins_a_model() {
        let mut s = PoolScheduler::new(SchedulePolicy::Affinity, 3);
        assert_eq!(s.pick("pinned", Some(2), 1), 2);
        assert_eq!(s.pick("pinned", Some(2), 1), 2);
        // out-of-range hints are ignored, falling back to the heuristic
        assert_eq!(s.pick("other", Some(9), 1), 0);
    }

    #[test]
    fn complete_decrements_and_saturates() {
        let mut s = PoolScheduler::new(SchedulePolicy::Affinity, 1);
        s.pick("a", None, 3);
        assert_eq!(s.inflight(0), 3);
        s.complete(0, 2);
        assert_eq!(s.inflight(0), 1);
        s.complete(0, 5); // over-completion saturates at zero
        assert_eq!(s.inflight(0), 0);
        s.complete(7, 1); // unknown fabric is ignored
    }

    #[test]
    fn scheduler_reprogram_proxy_affinity_vs_round_robin() {
        // Count model switches per fabric under the [a, a, b] request
        // pattern — the pure-logic version of the pool integration test.
        let switches = |policy: SchedulePolicy| {
            let mut s = PoolScheduler::new(policy, 2);
            let mut programmed: Vec<Option<String>> = vec![None; 2];
            let mut switches = 0;
            for _round in 0..4 {
                for model in ["a", "a", "b"] {
                    let f = s.pick(model, None, 1);
                    if programmed[f].as_deref() != Some(model) {
                        switches += 1;
                        programmed[f] = Some(model.to_string());
                    }
                    s.complete(f, 1);
                }
            }
            switches
        };
        let affinity = switches(SchedulePolicy::Affinity);
        let rr = switches(SchedulePolicy::RoundRobin);
        assert_eq!(affinity, 2, "affinity programs each fabric exactly once");
        assert!(rr > affinity, "round-robin ({rr}) must reprogram more than affinity ({affinity})");
    }

    #[test]
    fn program_failure_fails_the_batch_not_silently() {
        require_artifacts!();
        let a = ModelSpec::new("a", presets::small_encoder(32, 1), 1);
        let b = ModelSpec::new("b", crate::model::TnnConfig::encoder(48, 128, 2, 1), 2);
        let mut cfg = ServerConfig::new(vec![a, b]);
        cfg.policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(2) };
        cfg.fault.fail_program_for = Some("b".into());
        let s = Server::start(cfg).unwrap();
        // "a" serves fine
        let xa = weights::init_input(1, 32, 256);
        assert!(s.infer(Request { model: "a".into(), input: xa.clone() }).is_ok());
        // "b" must fail with the programming error — not run on stale registers
        let xb = weights::init_input(2, 48, 128);
        let err = s.infer(Request { model: "b".into(), input: xb }).unwrap_err();
        assert!(err.to_string().contains("programming registers"), "{err}");
        // the fabric recovers: "a" still serves afterwards
        assert!(s.infer(Request { model: "a".into(), input: xa }).is_ok());
        let m = s.shutdown().unwrap();
        assert_eq!(m.requests(), 2, "failed request must not count as served");
        assert_eq!(m.failed, 1);
        assert_eq!(m.batch_sizes.len(), 2, "unserved batch must not be recorded");
    }
}
