//! The serving loop.
//!
//! `PjRtLoadedExecutable` is not `Send`, and the paper's system has exactly
//! one fabric — so the server owns a dedicated **engine thread** that
//! constructs the `TileEngine` locally and drains batches from an mpsc
//! queue.  Clients submit from any thread and receive their response over
//! a per-request channel.  Model switches reprogram the register file
//! (counted in metrics: that is the runtime-adaptivity event).

use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::anyhow;

use super::batcher::{BatchPolicy, Batcher};
use super::engine::{AttentionMode, PreparedStack, TileEngine};
use super::metrics::Metrics;
use super::router::{ModelSpec, Router};
use crate::model::weights::Mat;

/// One inference request: model name + input activations.
#[derive(Debug, Clone)]
pub struct Request {
    pub model: String,
    pub input: Mat,
}

/// The response: output activations + timing.
#[derive(Debug)]
pub struct Response {
    pub output: Mat,
    pub latency: Duration,
    pub queue_wait: Duration,
}

/// Server construction parameters.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub artifact_dir: std::path::PathBuf,
    pub models: Vec<ModelSpec>,
    pub policy: BatchPolicy,
    pub attention: AttentionMode,
}

impl ServerConfig {
    pub fn new(models: Vec<ModelSpec>) -> Self {
        ServerConfig {
            artifact_dir: crate::runtime::default_artifact_dir(),
            models,
            policy: BatchPolicy::default(),
            attention: AttentionMode::Fused,
        }
    }
}

enum Msg {
    Work { req: Request, enqueued: Instant, reply: Sender<anyhow::Result<Response>> },
    Shutdown { reply: Sender<Metrics> },
}

/// Handle to the running server.
pub struct Server {
    tx: Sender<Msg>,
    router: Router,
    worker: Option<JoinHandle<()>>,
}

impl Server {
    /// Start the engine thread; blocks until the fabric is warmed up (all
    /// models prepared and artifacts compiled) or fails.
    pub fn start(cfg: ServerConfig) -> anyhow::Result<Self> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<anyhow::Result<()>>();

        // Router lives on the submit side for fail-fast validation.
        let mut router = Router::new(crate::accel::registers::SynthMaxima::artifact_default());
        for spec in &cfg.models {
            router.register(spec.clone())?;
        }

        let worker = std::thread::Builder::new()
            .name("adaptor-fabric".into())
            .spawn(move || engine_thread(cfg, rx, ready_tx))
            .expect("spawning engine thread");
        ready_rx
            .recv()
            .map_err(|_| anyhow!("engine thread died during warmup"))??;
        Ok(Server { tx, router, worker: Some(worker) })
    }

    pub fn models(&self) -> Vec<&str> {
        self.router.names()
    }

    /// Submit a request; returns the channel the response will arrive on.
    pub fn submit(&self, req: Request) -> anyhow::Result<Receiver<anyhow::Result<Response>>> {
        self.router.route(&req.model, req.input.rows, req.input.cols)?;
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Work { req, enqueued: Instant::now(), reply })
            .map_err(|_| anyhow!("engine thread is gone"))?;
        Ok(rx)
    }

    /// Convenience: submit and wait.
    pub fn infer(&self, req: Request) -> anyhow::Result<Response> {
        self.submit(req)?.recv().map_err(|_| anyhow!("engine dropped the request"))?
    }

    /// Stop the engine thread and collect final metrics.
    pub fn shutdown(mut self) -> Metrics {
        let (reply, rx) = mpsc::channel();
        let _ = self.tx.send(Msg::Shutdown { reply });
        let m = rx.recv().unwrap_or_default();
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
        m
    }
}

fn engine_thread(cfg: ServerConfig, rx: Receiver<Msg>, ready: Sender<anyhow::Result<()>>) {
    // Build the fabric locally (not Send).
    let mut engine = match TileEngine::new(&cfg.artifact_dir) {
        Ok(e) => e,
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    engine.mode = cfg.attention;

    // Prepare every registered model's weights once (Algorithm 18, 4–12).
    let mut prepared: Vec<(String, PreparedStack)> = Vec::new();
    for spec in &cfg.models {
        match engine.prepare(&spec.cfg, &spec.weights()) {
            Ok(p) => prepared.push((spec.name.clone(), p)),
            Err(e) => {
                let _ = ready.send(Err(e.context(format!("preparing model '{}'", spec.name))));
                return;
            }
        }
    }
    // Warm the executable cache so first requests are not compile-bound.
    let names: Vec<&str> = [
        "mm_qkv", "mm_ffn1", "mm_ffn2", "mm_ffn3", "bias_add_dk", "bias_add_d", "bias_relu_h",
        "residual_ln", "qk_scores", "softmax", "sv", "attn_fused",
    ]
    .into();
    if let Err(e) = engine.executor().warmup(&names) {
        let _ = ready.send(Err(e));
        return;
    }
    let _ = ready.send(Ok(()));

    let mut batcher: Batcher<(Request, Instant, Sender<anyhow::Result<Response>>)> =
        Batcher::new(cfg.policy);
    let mut metrics = Metrics::default();
    let started = Instant::now();
    let mut current_model = String::new();
    let mut shutdown_reply: Option<Sender<Metrics>> = None;

    'outer: loop {
        // Wait for work, bounded by the oldest batch deadline.
        let timeout = batcher
            .next_deadline()
            .map(|d| d.saturating_duration_since(Instant::now()))
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(timeout) {
            Ok(Msg::Work { req, enqueued, reply }) => {
                let model = req.model.clone();
                batcher.push(&model, (req, enqueued, reply));
            }
            Ok(Msg::Shutdown { reply }) => {
                shutdown_reply = Some(reply);
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => break 'outer,
        }
        let draining = shutdown_reply.is_some();
        while let Some((model, batch)) = batcher.pop_ready(Instant::now(), draining) {
            metrics.record_batch(batch.len());
            let stack = prepared.iter().find(|(n, _)| *n == model);
            // Reprogram the registers only on model switch.
            if current_model != model {
                if let Some((_, p)) = stack {
                    if engine.program(&p.cfg).is_ok() {
                        metrics.reprograms += 1;
                        current_model = model.clone();
                    }
                }
            }
            for (req, enqueued, reply) in batch.into_iter().map(|p| p.payload) {
                let queue_wait = enqueued.elapsed();
                let result = match stack {
                    None => Err(anyhow!("model '{model}' not prepared")),
                    Some((_, p)) => {
                        let t0 = Instant::now();
                        engine.run_encoder(p, &req.input).map(|output| Response {
                            output,
                            latency: t0.elapsed() + queue_wait,
                            queue_wait,
                        })
                    }
                };
                if let Ok(r) = &result {
                    metrics.record(r.latency, r.queue_wait);
                }
                let _ = reply.send(result);
            }
        }
        if draining && batcher.is_empty() {
            break 'outer;
        }
    }
    metrics.elapsed = started.elapsed().as_secs_f64();
    if let Some(reply) = shutdown_reply {
        let _ = reply.send(metrics);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{presets, reference, weights};

    fn server(models: Vec<ModelSpec>) -> Server {
        let mut cfg = ServerConfig::new(models);
        cfg.policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(2) };
        Server::start(cfg).expect("run `make artifacts` first")
    }

    #[test]
    fn serves_correct_outputs() {
        let spec = ModelSpec::new("small", presets::small_encoder(32, 1), 21);
        let s = server(vec![spec.clone()]);
        let x = weights::init_input(1, 32, 256);
        let resp = s.infer(Request { model: "small".into(), input: x.clone() }).unwrap();
        let mask = reference::attention_mask(32, 32, false);
        let want = reference::encoder_stack(&x, &spec.weights(), &mask);
        assert!(resp.output.max_abs_diff(&want) < 2e-3);
        let m = s.shutdown();
        assert_eq!(m.requests(), 1);
    }

    #[test]
    fn multi_model_serving_reprograms_between_models() {
        let a = ModelSpec::new("a", presets::small_encoder(32, 1), 1);
        let b = ModelSpec::new("b", crate::model::TnnConfig::encoder(48, 128, 2, 1), 2);
        let s = server(vec![a, b]);
        for i in 0..3 {
            let xa = weights::init_input(i, 32, 256);
            let xb = weights::init_input(i + 10, 48, 128);
            assert!(s.infer(Request { model: "a".into(), input: xa }).is_ok());
            assert!(s.infer(Request { model: "b".into(), input: xb }).is_ok());
        }
        let m = s.shutdown();
        assert_eq!(m.requests(), 6);
        assert!(m.reprograms >= 2, "model switches must reprogram registers");
    }

    #[test]
    fn rejects_bad_requests_fast() {
        let s = server(vec![ModelSpec::new("small", presets::small_encoder(32, 1), 3)]);
        let wrong_shape = weights::init_input(0, 16, 256);
        assert!(s.submit(Request { model: "small".into(), input: wrong_shape }).is_err());
        let unknown = weights::init_input(0, 32, 256);
        assert!(s.submit(Request { model: "nope".into(), input: unknown }).is_err());
        s.shutdown();
    }
}
