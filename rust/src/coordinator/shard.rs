//! Cross-fabric pipeline sharding: serve models bigger than any single
//! fabric.
//!
//! ADAPTOR's runtime adaptability stops at the single-fabric boundary —
//! a topology whose weight footprint exceeds one platform's weight-memory
//! envelope (`accel::resources::weight_memory_bytes`) cannot be served
//! even when the pool has idle fabrics.  The FTRANS-style fix is to
//! pipeline the layer stack: split it into K **contiguous layer-range
//! shards**, park each shard's weights as a pinnable resident stack on
//! its home fabric, and relay the full padded activation over the
//! inter-fabric link at each cut.  Because every layer consumes and
//! produces the same `[SL_MAX, DMODEL_MAX]` padded activation, the cut
//! interface is exactly the inter-layer interface — a K-shard chain is
//! bit-identical to the monolithic program by construction (proved
//! against the pseudo-numeric backend in `integration_shard.rs`).
//!
//! The pieces:
//!
//! * [`ShardPlan`] — the partitioner: balanced contiguous K-way splits
//!   ([`ShardPlan::partition_k`]) and envelope-driven splits
//!   ([`ShardPlan::partition_for_envelope`]), plus the pure-arithmetic
//!   [`min_shards`] every topology (including seq2seq) can answer;
//! * [`lower_chain`] — one [`TileProgram`] per shard, the head/tail
//!   getting `SendActivation`/`RecvActivation` roles from the builder
//!   and the whole chain checked by
//!   `accel::schedule::verify::verify_shard_chain` ([`verify_chain`]);
//! * [`replay_chain`] + [`OffsetWeights`] — the sequential chain driver
//!   for artifact-free backends (tests, cycle pricing): each shard's
//!   0-based weight references resolve against the parent model's stack
//!   shifted by the shard's layer offset;
//! * [`residency_key`] — the per-shard resident-stack identity the
//!   serving pool registers with `coordinator::residency`.
//!
//! Execution sharding covers **single-stack** topologies: encoder-only
//! stacks and decoder-only (gpt-style) stacks.  Seq2seq topologies are
//! refused with a typed error — every decoder layer's cross-attention
//! reads the *encoder's* output, so a contiguous layer range does not
//! have the single-activation interface the link protocol relays — but
//! [`min_shards`] still prices them, so the CLI can report how many
//! fabrics a hypothetical split would need.  Decode steps never shard:
//! KV locality pins a generating sequence to one fabric.

use std::ops::Range;

use crate::accel::schedule::{
    self, FabricConstants, OptLevel, ScheduleBuilder, TileProgram, VerifyReport, WeightRef,
    WeightSource,
};
use crate::model::TnnConfig;
use crate::runtime::{backend::FabricBackend, Tensor};

use super::api::ServeError;
use super::residency::{decoder_layer_bytes, encoder_layer_bytes};

/// One contiguous layer-range shard of a parent topology.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardSpec {
    /// Position in the chain, `0..count` (0 = head, takes the caller's
    /// input; `count - 1` = tail, returns to the caller).
    pub index: usize,
    /// Chain length K.
    pub count: usize,
    /// The parent stack's layer range this shard executes.  Weight
    /// references inside the shard's program are 0-based; add
    /// `layers.start` to reach the parent layer (see [`OffsetWeights`]).
    pub layers: Range<usize>,
    /// The shard's sub-topology: the parent config with this shard's
    /// layer count in the sharded stack and zero in the other.  This is
    /// what the home fabric's register file programs and what its
    /// prepared weight stack is keyed by.
    pub cfg: TnnConfig,
    /// Device weight-memory footprint of this shard's stack in bytes.
    pub bytes: u64,
}

impl ShardSpec {
    /// Layers this shard executes.
    pub fn layer_count(&self) -> usize {
        self.layers.len()
    }

    /// The parent-stack index of this shard's first layer — the offset
    /// [`OffsetWeights`] shifts by.
    pub fn offset(&self) -> usize {
        self.layers.start
    }
}

/// A complete contiguous partition of one topology's layer stack.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardPlan {
    /// The parent topology the shards reassemble.
    pub cfg: TnnConfig,
    /// The shards in chain order; layer ranges tile `0..stack_len`.
    pub shards: Vec<ShardSpec>,
}

/// `(stack length, per-layer bytes, is_decoder)` of the single stack a
/// topology shards over, or the typed refusal for stackless / seq2seq
/// configs.
fn stack_shape(cfg: &TnnConfig, fc: &FabricConstants) -> Result<(usize, u64, bool), ServeError> {
    match (cfg.enc_layers, cfg.dec_layers) {
        (0, 0) => Err(ServeError::invalid(format!("topology {cfg} has no layers to shard"))),
        (e, 0) => Ok((e, encoder_layer_bytes(cfg, fc), false)),
        (0, d) => Ok((d, decoder_layer_bytes(cfg, fc), true)),
        _ => Err(ServeError::invalid(format!(
            "seq2seq topology {cfg} does not shard: every decoder layer's cross-attention reads \
             the encoder output, so a contiguous layer range has no single-activation interface \
             for the link to relay"
        ))),
    }
}

impl ShardPlan {
    /// Balanced contiguous K-way partition of `cfg`'s layer stack:
    /// shard sizes differ by at most one layer, earlier shards taking
    /// the extra (the head also pays the input upload, so the tail-heavy
    /// alternative would stack both imbalances on one fabric).
    pub fn partition_k(
        cfg: &TnnConfig,
        fc: &FabricConstants,
        k: usize,
    ) -> Result<ShardPlan, ServeError> {
        let (stack_len, per_layer, is_dec) = stack_shape(cfg, fc)?;
        if k == 0 || k > stack_len {
            return Err(ServeError::invalid(format!(
                "cannot split {stack_len} layers into {k} non-empty contiguous shards"
            )));
        }
        let base = stack_len / k;
        let extra = stack_len % k;
        let mut shards = Vec::with_capacity(k);
        let mut start = 0usize;
        for index in 0..k {
            let len = base + usize::from(index < extra);
            let sub = if is_dec {
                TnnConfig { enc_layers: 0, dec_layers: len, ..*cfg }
            } else {
                TnnConfig { enc_layers: len, dec_layers: 0, ..*cfg }
            };
            shards.push(ShardSpec {
                index,
                count: k,
                layers: start..start + len,
                cfg: sub,
                bytes: per_layer * len as u64,
            });
            start += len;
        }
        Ok(ShardPlan { cfg: *cfg, shards })
    }

    /// Partition `cfg` so every shard's weight stack fits a fabric with
    /// `envelope` bytes of weight memory — the admission path's "model
    /// too big" → placement decision.  A topology that fits whole comes
    /// back as one shard; a single layer exceeding the envelope is a
    /// typed refusal (no contiguous split can help).
    pub fn partition_for_envelope(
        cfg: &TnnConfig,
        fc: &FabricConstants,
        envelope: u64,
    ) -> Result<ShardPlan, ServeError> {
        let (stack_len, per_layer, _) = stack_shape(cfg, fc)?;
        if per_layer == 0 || per_layer > envelope {
            return Err(ServeError::invalid(format!(
                "one layer of {cfg} needs {per_layer} B of weight memory, over the fabric's \
                 {envelope} B envelope — no contiguous split fits"
            )));
        }
        let layers_per_shard = (envelope / per_layer) as usize;
        let k = stack_len.div_ceil(layers_per_shard).max(1);
        // ceil(stack_len / k) <= layers_per_shard, so the balanced split
        // respects the envelope.
        Self::partition_k(cfg, fc, k)
    }

    /// Total weight bytes across the chain — equals the parent model's
    /// `residency::weight_footprint_bytes` by construction.
    pub fn total_bytes(&self) -> u64 {
        self.shards.iter().map(|s| s.bytes).sum()
    }

    /// The largest single shard — what the tightest fabric must hold.
    pub fn max_shard_bytes(&self) -> u64 {
        self.shards.iter().map(|s| s.bytes).max().unwrap_or(0)
    }
}

/// Minimum number of fabrics with `envelope` bytes of weight memory
/// needed to hold `cfg`'s full weight stack as contiguous layer ranges.
/// Pure arithmetic over the per-layer byte sequence — answers for
/// *every* topology, including the seq2seq configs execution sharding
/// refuses — so `adaptor list-models` can flag oversize presets with a
/// concrete shard count.  `None` when a single layer exceeds the
/// envelope (no contiguous split can serve the model).
pub fn min_shards(cfg: &TnnConfig, fc: &FabricConstants, envelope: u64) -> Option<usize> {
    if envelope == 0 {
        return None;
    }
    let enc = encoder_layer_bytes(cfg, fc);
    let dec = decoder_layer_bytes(cfg, fc);
    let layers = std::iter::repeat(enc)
        .take(cfg.enc_layers)
        .chain(std::iter::repeat(dec).take(cfg.dec_layers));
    let mut bins = 0usize;
    let mut cur = 0u64;
    for bytes in layers {
        if bytes > envelope {
            return None;
        }
        if cur + bytes > envelope {
            bins += 1;
            cur = 0;
        }
        cur += bytes;
    }
    Some(if cur > 0 || bins == 0 { bins + 1 } else { bins })
}

/// The resident-stack identity of one shard in the serving pool's
/// `coordinator::residency` manager: shards of one model are distinct
/// stacks (they live on distinct fabrics), so each gets its own key.
pub fn residency_key(model: &str, index: usize, count: usize) -> String {
    format!("{model}::shard{index}/{count}")
}

/// Lower one [`TileProgram`] per shard of `plan` at the schedule level
/// (no engine, no cache — the CLI sweep, the cycle bench and the
/// artifact-free equivalence tests).  Encoder stacks lower with
/// `build()`; decoder-only stacks with `build_prefill()`, the
/// KV-exporting whole-prompt pass, so a chain's concatenated exports
/// line up with the monolithic prefill's (shard order = layer order).
/// Every shard but the head receives boundary `index - 1`; every shard
/// but the tail sends boundary `index`.
pub fn lower_chain(
    plan: &ShardPlan,
    fc: &FabricConstants,
    level: OptLevel,
    inventory: &schedule::ArtifactInventory,
) -> anyhow::Result<Vec<TileProgram>> {
    let mut chain = Vec::with_capacity(plan.shards.len());
    for s in &plan.shards {
        let mut b = ScheduleBuilder::new(*fc, s.cfg)?;
        if s.index > 0 {
            b = b.recv_activation(s.index - 1);
        }
        if s.index + 1 < s.count {
            b = b.send_activation(s.index);
        }
        let mut p = if s.cfg.dec_layers > 0 { b.build_prefill() } else { b.build() };
        schedule::optimize(&mut p, level, inventory)?;
        chain.push(p);
    }
    Ok(chain)
}

/// Run `accel::schedule::verify`'s chain contract over a lowered chain:
/// every boundary covered exactly once, head never receives, tail never
/// sends, peer activation shapes agree.
pub fn verify_chain(chain: &[TileProgram]) -> VerifyReport {
    let refs: Vec<&TileProgram> = chain.iter().collect();
    schedule::verify::verify_shard_chain(&refs)
}

/// A [`WeightSource`] view that shifts every reference's layer by a
/// shard's offset: shard programs index their layers 0-based, the parent
/// model's stack indexes them absolutely, and this adapter is the whole
/// difference — sharding never re-tiles a weight panel.
pub struct OffsetWeights<'a, Buf> {
    pub inner: &'a dyn WeightSource<Buf>,
    pub offset: usize,
}

impl<Buf> WeightSource<Buf> for OffsetWeights<'_, Buf> {
    fn weight(&self, r: &WeightRef) -> anyhow::Result<&Buf> {
        let shifted =
            WeightRef { layer: r.layer + self.offset, kind: r.kind, row: r.row, col: r.col };
        self.inner.weight(&shifted)
    }
}

/// Drive a lowered chain **sequentially on one backend** — the
/// single-process stand-in for the pipelined multi-fabric execution,
/// numerically identical to it (stage order is the only difference, and
/// stages are data-dependent within one request anyway).  This is what
/// the artifact-free equivalence tests and the cycle bench run.
///
/// `weights` is the **parent** model's weight source; each shard
/// resolves its 0-based references through an [`OffsetWeights`] shifted
/// to its layer range.  Returns the final activation and the
/// concatenated exports of every stage (a gpt prefill chain's KV panels,
/// in the monolithic program's order).
pub fn replay_chain<B: FabricBackend>(
    chain: &[TileProgram],
    plan: &ShardPlan,
    backend: &B,
    weights: &dyn WeightSource<B::Buf>,
    input: Tensor,
    live: usize,
) -> anyhow::Result<(Tensor, Vec<B::Buf>)> {
    anyhow::ensure!(
        chain.len() == plan.shards.len() && !chain.is_empty(),
        "chain has {} programs for {} shards",
        chain.len(),
        plan.shards.len()
    );
    let mut act = input;
    let mut exports = Vec::new();
    for (prog, spec) in chain.iter().zip(&plan.shards) {
        anyhow::ensure!(
            prog.aux_hosts.is_empty(),
            "shard {} takes {} aux inputs — sharded replay relays a single activation",
            spec.index,
            prog.aux_hosts.len()
        );
        let mut runtime = schedule::build_runtime(backend, &prog.cfg, &prog.fabric)?;
        schedule::upload_tier_masks(
            backend,
            &mut runtime,
            &prog.cfg,
            &prog.fabric,
            &prog.tier_mask_ids(),
        )?;
        let shifted = OffsetWeights { inner: weights, offset: spec.offset() };
        let (out, ex) = schedule::replay_full_adaptive(
            prog, backend, &shifted, &runtime, vec![act], &[], None, live,
        )?;
        exports.extend(ex);
        act = out;
    }
    Ok((act, exports))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::schedule::{ArtifactInventory, Rule};
    use crate::coordinator::residency::weight_footprint_bytes;
    use crate::model::presets;

    fn fc() -> FabricConstants {
        FabricConstants::artifact_default()
    }

    #[test]
    fn partition_tiles_the_stack_contiguously_and_balanced() {
        let cfg = presets::by_name("custom-encoder-4l").unwrap();
        for k in 1..=4 {
            let plan = ShardPlan::partition_k(&cfg, &fc(), k).unwrap();
            assert_eq!(plan.shards.len(), k);
            let mut next = 0usize;
            for (i, s) in plan.shards.iter().enumerate() {
                assert_eq!(s.index, i);
                assert_eq!(s.count, k);
                assert_eq!(s.layers.start, next, "shard {i} is not contiguous");
                assert!(s.layer_count() >= 1);
                assert_eq!(s.cfg.enc_layers, s.layer_count());
                assert_eq!(s.cfg.dec_layers, 0);
                next = s.layers.end;
            }
            assert_eq!(next, cfg.enc_layers);
            let sizes: Vec<usize> = plan.shards.iter().map(ShardSpec::layer_count).collect();
            let (min, max) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(max - min <= 1, "unbalanced split {sizes:?}");
            assert_eq!(plan.total_bytes(), weight_footprint_bytes(&cfg, &fc()));
        }
    }

    #[test]
    fn decoder_only_stacks_shard_over_dec_layers() {
        let cfg = presets::gpt_small(64, 4);
        let plan = ShardPlan::partition_k(&cfg, &fc(), 2).unwrap();
        for s in &plan.shards {
            assert_eq!(s.cfg.enc_layers, 0);
            assert_eq!(s.cfg.dec_layers, s.layer_count());
        }
        assert_eq!(plan.total_bytes(), weight_footprint_bytes(&cfg, &fc()));
    }

    #[test]
    fn invalid_partitions_are_refused() {
        let cfg = presets::small_encoder(32, 2);
        assert!(ShardPlan::partition_k(&cfg, &fc(), 0).is_err());
        assert!(ShardPlan::partition_k(&cfg, &fc(), 3).is_err());
        let s2s = presets::seq2seq_small(32, 2, 2);
        assert!(ShardPlan::partition_k(&s2s, &fc(), 2).is_err());
    }

    #[test]
    fn envelope_partition_matches_min_shards_and_respects_the_envelope() {
        let f = fc();
        for cfg in [presets::gpt_small(64, 4), presets::small_encoder(64, 4)] {
            let per_layer = weight_footprint_bytes(&cfg, &f)
                / (cfg.enc_layers + cfg.dec_layers) as u64;
            // An envelope holding ~1.5 layers forces one layer per shard.
            let envelope = per_layer + per_layer / 2;
            let plan = ShardPlan::partition_for_envelope(&cfg, &f, envelope).unwrap();
            assert_eq!(Some(plan.shards.len()), min_shards(&cfg, &f, envelope));
            assert!(plan.max_shard_bytes() <= envelope);
            // A roomy envelope keeps the model whole.
            let whole = ShardPlan::partition_for_envelope(&cfg, &f, u64::MAX).unwrap();
            assert_eq!(whole.shards.len(), 1);
            assert_eq!(min_shards(&cfg, &f, u64::MAX), Some(1));
        }
    }

    #[test]
    fn min_shards_handles_every_topology_and_the_impossible_envelope() {
        let f = fc();
        let s2s = presets::seq2seq_small(32, 2, 2);
        // seq2seq still gets the arithmetic answer...
        assert!(min_shards(&s2s, &f, u64::MAX) == Some(1));
        // ...while a sub-layer envelope is unservable for anyone.
        assert_eq!(min_shards(&s2s, &f, 1), None);
        assert_eq!(min_shards(&presets::gpt_small(32, 2), &f, 0), None);
    }

    #[test]
    fn lowered_chains_verify_clean_per_program_and_as_a_chain() {
        let f = fc();
        let inv = ArtifactInventory::assume_all();
        for (cfg, kind) in [
            (presets::small_encoder(32, 2), schedule::ProgramKind::Encoder),
            (presets::gpt_small(32, 2), schedule::ProgramKind::Prefill),
        ] {
            let plan = ShardPlan::partition_k(&cfg, &f, 2).unwrap();
            let chain = lower_chain(&plan, &f, OptLevel::O2, &inv).unwrap();
            for (i, p) in chain.iter().enumerate() {
                let report = schedule::verify::verify(p, kind, &inv);
                assert!(
                    report.is_clean(),
                    "shard {i}: {:?}",
                    report.errors().collect::<Vec<_>>()
                );
            }
            let report = verify_chain(&chain);
            assert!(report.is_clean(), "{:?}", report.errors().collect::<Vec<_>>());
            assert_eq!(chain[0].send_boundaries(), vec![0]);
            assert_eq!(chain[1].recv_boundaries(), vec![0]);
            assert!(chain[0].recv_boundaries().is_empty());
            assert!(chain[1].send_boundaries().is_empty());
        }
    }

    #[test]
    fn a_forged_chain_fails_the_chain_contract() {
        let f = fc();
        let inv = ArtifactInventory::assume_all();
        let cfg = presets::small_encoder(32, 2);
        let plan = ShardPlan::partition_k(&cfg, &f, 2).unwrap();
        let chain = lower_chain(&plan, &f, OptLevel::O0, &inv).unwrap();
        // Reversed chain: the receiver leads and the sender trails.
        let reversed: Vec<TileProgram> = chain.iter().rev().cloned().collect();
        assert!(verify_chain(&reversed).has_error(Rule::ShardContract));
    }

    #[test]
    fn residency_keys_are_unique_per_shard() {
        let a = residency_key("bert-base", 0, 2);
        let b = residency_key("bert-base", 1, 2);
        assert_ne!(a, b);
        assert!(a.starts_with("bert-base::shard"));
    }
}
