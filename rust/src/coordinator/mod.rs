//! The host-software half of ADAPTOR (paper §3.11, §4, Algorithm 18) and
//! the serving layer around it.
//!
//! * [`engine`] — the tile-schedule engine: lowers the paper's
//!   Algorithms 1–17 into a cached `TileProgram` (`accel::schedule`) per
//!   programmed topology and replays it per request over fixed-shape AOT
//!   tile primitives on the PJRT runtime, under the control of the
//!   configuration registers.  This is the numeric twin of the FPGA
//!   fabric.
//! * [`batcher`] — dynamic request batching (per-model ready queues,
//!   size/deadline policy).
//! * [`router`] — model registry + request routing, with pool-affinity
//!   hints.
//! * [`server`] — the threaded serving loop: clients submit encode
//!   requests or **generation requests** (greedy decode over the
//!   prefill/KV-cached-step programs); a dispatcher assigns
//!   model-homogeneous batches to a **pool** of fabric worker threads
//!   (each owning one engine, like one piece of hardware) under an
//!   affinity or round-robin schedule.  `pool_size = 1` is the paper's
//!   single-fabric host software.
//! * [`metrics`] — compute/queue/end-to-end latency and throughput
//!   accounting (AXI-timer analog), per fabric and aggregated.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod router;
pub mod server;

pub use engine::{
    AttentionMode, DecoderStackView, Generated, OptLevel, PreparedStack, ProgramKind, TileEngine,
};
pub use server::{
    FaultInjection, GenerateRequest, GenerateResponse, PoolScheduler, Request, Response,
    SchedulePolicy, Server, ServerConfig,
};
