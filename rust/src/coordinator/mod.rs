//! The host-software half of ADAPTOR (paper §3.11, §4, Algorithm 18) and
//! the serving layer around it.
//!
//! * [`engine`] — the tile-schedule engine: executes the paper's
//!   Algorithms 1–17 as a dataflow of fixed-shape AOT tile primitives on
//!   the PJRT runtime, under the control of the configuration registers.
//!   This is the numeric twin of the FPGA fabric.
//! * [`batcher`] — dynamic request batching (size/deadline policy).
//! * [`router`] — model registry + request routing to the fabric.
//! * [`server`] — the threaded serving loop: clients submit token
//!   sequences, a dedicated engine thread (exactly one fabric, like the
//!   hardware) drains batches.
//! * [`metrics`] — latency/throughput accounting (AXI-timer analog).

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod router;
pub mod server;

pub use engine::{AttentionMode, PreparedStack, TileEngine};
pub use server::{Request, Response, Server, ServerConfig};
