//! The host-software half of ADAPTOR (paper §3.11, §4, Algorithm 18) and
//! the serving layer around it.
//!
//! * [`engine`] — the tile-schedule engine: lowers the paper's
//!   Algorithms 1–17 into a cached `TileProgram` (`accel::schedule`) per
//!   programmed topology and replays it per request over fixed-shape AOT
//!   tile primitives on the PJRT runtime, under the control of the
//!   configuration registers.  This is the numeric twin of the FPGA
//!   fabric.
//! * [`batcher`] — dynamic request batching (per-model ready queues,
//!   size/deadline policy).
//! * [`router`] — model registry + request routing, with pool-affinity
//!   hints.
//! * [`api`] — **Serving API v1** (re-exported as
//!   [`adaptor::serve`](crate::serve)): the single typed job surface —
//!   `Submission` (encode / generation), per-request `QoS` (priority,
//!   deadline, opt-level override), `JobHandle` (wait / poll / streamed
//!   tokens / cancellation) and the `ServeError` taxonomy that replaced
//!   `anyhow` across the public boundary.
//! * [`server`] — the threaded serving loop behind the API: a
//!   dispatcher assigns model-homogeneous batches to a **pool** of
//!   fabric worker threads (each owning one engine, like one piece of
//!   hardware) under an affinity or round-robin schedule, with
//!   capacity-gated, QoS-ordered dispatch.  `pool_size = 1` is the
//!   paper's single-fabric host software.
//! * [`residency`] — device weight memory as a traffic-aware cache: a
//!   per-fabric residency manager (capacity from `accel::resources`,
//!   traffic-weighted-LRU eviction, in-flight pinning) plus the
//!   footprint/upload-cost model the cost-aware placement policy and the
//!   dispatcher's prefetch trigger price reprogramming with.
//! * [`metrics`] — compute/queue/end-to-end latency and throughput
//!   accounting (AXI-timer analog), per fabric and aggregated, with
//!   per-priority / cancellation / deadline counters — readable live
//!   via `Server::metrics()`, not only at shutdown.
//! * [`shard`] — cross-fabric pipeline sharding: a layer-range
//!   partitioner sized to each fabric's weight-memory envelope, chain
//!   lowering with `SendActivation`/`RecvActivation` transfer roles, and
//!   the sequential chain driver — "model too big" becomes a placement
//!   decision instead of a refusal.

pub mod api;
pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod residency;
pub mod router;
pub mod server;
pub mod shard;

pub use api::{
    CancelToken, EncodeOutput, GenerateOutput, JobEvent, JobHandle, JobOutput, Priority, QoS,
    ServeError, Submission, Timing, TokenEvent,
};
pub use engine::{
    AttentionMode, DecoderStackView, GenSession, Generated, OptLevel, PreparedStack, ProgramKind,
    StepControl, TileEngine,
};
pub use residency::{ResidencyMode, ResidencyPolicy, ResidencyStats, WeightResidencyManager};
pub use shard::{min_shards, ShardPlan, ShardSpec};
pub use server::{
    FaultInjection, GenerateRequest, GenerateResponse, PoolScheduler, Request, Response,
    SchedulePolicy, Server, ServerConfig,
};
