//! Serving API v1 — the single typed job surface of the coordinator
//! (re-exported at the crate root as [`adaptor::serve`](crate::serve)).
//!
//! The paper's headline property is *runtime adaptability*: one
//! programmed fabric serves many model shapes without resynthesis.  The
//! serving surface mirrors that with one uniform request interface over
//! the fabric pool (the NPE-style "one instruction surface over a fixed
//! overlay" — see PAPERS.md):
//!
//! * [`Submission`] — every workload kind (encode, generation, future
//!   additions) enters through **one** `Server::submit`;
//! * [`QoS`] — per-request deadline, [`Priority`] and an optional
//!   per-request [`OptLevel`] override, flowing router → batcher
//!   (priority-and-deadline-aware ready-queue ordering) → fabric worker;
//! * [`JobHandle`] — blocking wait, non-blocking poll, **cancellation**
//!   (observed between decode steps on the fabric), and — for
//!   generation — a **streamed token channel** ([`TokenEvent`]s arrive
//!   as decode steps complete, not only as a final transcript);
//! * [`ServeError`] — the typed error taxonomy of the whole public
//!   coordinator boundary (no `anyhow` in any `pub` signature).
//!
//! Job lifecycle:
//!
//! ```text
//! submit ──► queued (batcher: priority ► arrival; deadline sweeps)
//!    │           │
//!    │           ├─ deadline passes ──► Failed(DeadlineExceeded)
//!    │           ├─ cancel() ─────────► Failed(Cancelled)
//!    │           ▼
//!    │        dispatched (capacity-gated, affinity-scheduled)
//!    │           │
//!    │           ├─ Encode ──────────────────────► Done(Encode)
//!    │           └─ Generate ─ Token(0) ─ Token(1) ─ … ─► Done(Generate)
//!    │                   ├─ cancel() between steps ───► Failed(Cancelled)
//!    │                   └─ deadline between rounds ──► Failed(DeadlineExceeded)
//!    ▼
//! JobHandle: next_token() / poll() / wait() / cancel()
//! ```

use std::collections::VecDeque;
use std::fmt;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, TryRecvError};
use std::sync::Arc;
use std::time::Duration;

use crate::model::weights::Mat;

pub use super::engine::OptLevel;

/// Request priority class.  Orders the batcher's ready queues: among
/// queued work for one model, `High` drains before `Normal` before
/// `Low`; ties break by arrival order (FIFO).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    Low,
    #[default]
    Normal,
    High,
}

impl Priority {
    /// All classes, lowest first (indexable via [`Self::index`]).
    pub const ALL: [Priority; 3] = [Priority::Low, Priority::Normal, Priority::High];

    /// Stable index for per-priority accounting arrays.
    pub fn index(self) -> usize {
        match self {
            Priority::Low => 0,
            Priority::Normal => 1,
            Priority::High => 2,
        }
    }
}

impl fmt::Display for Priority {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Priority::Low => "low",
            Priority::Normal => "normal",
            Priority::High => "high",
        })
    }
}

/// Per-request quality-of-service knobs.  `QoS::default()` is a
/// `Normal`-priority request with no deadline at the server's
/// configured optimization level.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct QoS {
    pub priority: Priority,
    /// Give up this much time after submission.  A request whose
    /// deadline passes while queued completes with
    /// [`ServeError::DeadlineExceeded`] instead of being served late
    /// (or dropped silently).  An in-flight **generation** is also
    /// checked between scheduler decode rounds: a sequence whose
    /// deadline passes mid-generation retires with `DeadlineExceeded`
    /// (counted in `Metrics::expired`), freeing its KV cache and its
    /// live-set slot immediately.  An `Encode` already on the fabric is
    /// never preempted — it has no between-step boundary to stop at.
    pub deadline: Option<Duration>,
    /// Per-request override of the fabric's TileProgram optimization
    /// level (the engine caches programs per opt level, so switching is
    /// a cache lookup, not a rebuild after first use).
    pub opt_level: Option<OptLevel>,
}

impl QoS {
    pub fn high() -> Self {
        QoS { priority: Priority::High, ..QoS::default() }
    }

    pub fn low() -> Self {
        QoS { priority: Priority::Low, ..QoS::default() }
    }

    pub fn with_priority(mut self, p: Priority) -> Self {
        self.priority = p;
        self
    }

    pub fn with_deadline(mut self, d: Duration) -> Self {
        self.deadline = Some(d);
        self
    }

    pub fn with_opt_level(mut self, l: OptLevel) -> Self {
        self.opt_level = Some(l);
        self
    }
}

/// One unit of work for the pool — every workload kind goes through the
/// same `Server::submit` and the same queues (adding a workload means
/// adding a variant here, not a third API fork).
#[derive(Debug, Clone)]
pub enum Submission {
    /// Run `input` (`seq_len × d_model`) through `model`'s encoder
    /// stack.
    Encode { model: String, input: Mat },
    /// Greedy-decode `steps` tokens from `prompt` on a `dec_layers > 0`
    /// model; seq2seq models additionally encode `source` into the
    /// cross-attention memory.
    Generate { model: String, prompt: Mat, source: Option<Mat>, steps: usize },
}

impl Submission {
    /// The registered model this submission targets.
    pub fn model(&self) -> &str {
        match self {
            Submission::Encode { model, .. } => model,
            Submission::Generate { model, .. } => model,
        }
    }
}

/// The typed error taxonomy of the public serving boundary.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// No model with that name is registered.
    UnknownModel(String),
    /// The submission does not fit its model (shape, sequence budget,
    /// missing/superfluous source, zero steps, wrong request kind).
    InvalidRequest(String),
    /// The server configuration is unusable (zero pool, model exceeding
    /// synthesis maxima, duplicate registration, …).
    InvalidConfig(String),
    /// A [`ModelSpec::with_affinity`](super::router::ModelSpec::with_affinity)
    /// hint points at a fabric the pool does not have — refused at
    /// `Server::start` instead of being silently ignored at dispatch.
    AffinityOutOfRange { model: String, fabric: usize, pool_size: usize },
    /// The request's QoS deadline passed — while queued, or (for a
    /// generation) between decode rounds mid-flight.
    DeadlineExceeded { waited: Duration },
    /// The job was cancelled via [`JobHandle::cancel`].
    Cancelled,
    /// Programming the configuration registers for the job's model
    /// failed; the whole batch fails rather than running on stale
    /// register state.
    ProgramFailed(String),
    /// The engine rejected or failed the work (artifact/runtime errors,
    /// internal invariant violations).
    Engine(String),
    /// The serving infrastructure is gone (worker/dispatcher died,
    /// thread panicked, channel closed before completion).
    PoolLost(String),
}

impl ServeError {
    pub fn invalid(msg: impl Into<String>) -> Self {
        ServeError::InvalidRequest(msg.into())
    }

    pub fn config(msg: impl Into<String>) -> Self {
        ServeError::InvalidConfig(msg.into())
    }

    pub fn engine(msg: impl Into<String>) -> Self {
        ServeError::Engine(msg.into())
    }

    pub fn pool_lost(msg: impl Into<String>) -> Self {
        ServeError::PoolLost(msg.into())
    }
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::UnknownModel(name) => write!(f, "unknown model '{name}'"),
            ServeError::InvalidRequest(msg) => write!(f, "{msg}"),
            ServeError::InvalidConfig(msg) => write!(f, "{msg}"),
            ServeError::AffinityOutOfRange { model, fabric, pool_size } => write!(
                f,
                "model '{model}' is pinned to fabric {fabric}, but the pool has only \
                 {pool_size} fabric(s) (indices 0..{pool_size})"
            ),
            ServeError::DeadlineExceeded { waited } => write!(
                f,
                "deadline exceeded: request waited {:.2} ms without starting",
                waited.as_secs_f64() * 1e3
            ),
            ServeError::Cancelled => write!(f, "job cancelled"),
            ServeError::ProgramFailed(msg) => write!(f, "{msg}"),
            ServeError::Engine(msg) => write!(f, "{msg}"),
            ServeError::PoolLost(msg) => write!(f, "{msg}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// Internal boundary adapter: engine internals keep rich `anyhow`
/// chains; the chain is flattened into the typed taxonomy exactly once,
/// at the public signature.
impl From<anyhow::Error> for ServeError {
    fn from(e: anyhow::Error) -> Self {
        ServeError::Engine(format!("{e:#}"))
    }
}

/// A program that fails static verification at cache-insertion time is a
/// programming failure: the fabric never sees it, the one request that
/// forced the build fails typed.
impl From<crate::accel::schedule::VerifyError> for ServeError {
    fn from(e: crate::accel::schedule::VerifyError) -> Self {
        ServeError::ProgramFailed(e.to_string())
    }
}

/// Wall-clock decomposition every completed job reports:
/// `latency == queue_wait + compute` by construction.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Timing {
    /// End-to-end: submit → result ready.
    pub latency: Duration,
    /// Submit → start of execution on the fabric (batching delay,
    /// dispatch, register reprogram, earlier batch members).
    pub queue_wait: Duration,
    /// Time on the fabric proper.
    pub compute: Duration,
}

/// One streamed generation token, delivered as its decode step
/// completes.  `index` 0 is the token that falls out of the prefill;
/// the concatenation of `row`s in index order is bit-identical to the
/// final [`GenerateOutput::rows`].
#[derive(Debug, Clone, PartialEq)]
pub struct TokenEvent {
    /// Position in the generated sequence, starting at 0.
    pub index: usize,
    /// Greedy token id (argmax feature index of the row).
    pub token: usize,
    /// The generated activation row (`d_model` values).
    pub row: Vec<f32>,
}

/// A completed encode job.
#[derive(Debug, Clone)]
pub struct EncodeOutput {
    /// Output activations, `seq_len × d_model`.
    pub output: Mat,
    pub timing: Timing,
}

/// A completed generation job (the full transcript; the same rows were
/// also streamed incrementally as [`TokenEvent`]s).
#[derive(Debug, Clone)]
pub struct GenerateOutput {
    /// Generated activation rows, `steps × d_model`.
    pub rows: Mat,
    /// Greedy token ids, one per step.
    pub tokens: Vec<usize>,
    pub timing: Timing,
    /// Source encode (seq2seq) + prompt prefill time.
    pub prefill: Duration,
    /// Per-token decode-step times (`steps - 1` entries; the first
    /// token falls out of the prefill).
    pub step_times: Vec<Duration>,
}

/// What a finished job produced — one variant per [`Submission`] kind.
#[derive(Debug, Clone)]
pub enum JobOutput {
    Encode(EncodeOutput),
    Generate(GenerateOutput),
}

impl JobOutput {
    pub fn timing(&self) -> Timing {
        match self {
            JobOutput::Encode(o) => o.timing,
            JobOutput::Generate(o) => o.timing,
        }
    }

    /// Unwrap an encode result; a generation output is an
    /// [`ServeError::InvalidRequest`] (the caller mixed up its handles).
    pub fn into_encode(self) -> Result<EncodeOutput, ServeError> {
        match self {
            JobOutput::Encode(o) => Ok(o),
            JobOutput::Generate(_) => {
                Err(ServeError::invalid("job completed as a generation, not an encode"))
            }
        }
    }

    /// Unwrap a generation result; see [`Self::into_encode`].
    pub fn into_generate(self) -> Result<GenerateOutput, ServeError> {
        match self {
            JobOutput::Generate(o) => Ok(o),
            JobOutput::Encode(_) => {
                Err(ServeError::invalid("job completed as an encode, not a generation"))
            }
        }
    }
}

/// Everything the server reports back about one job, in delivery order:
/// zero or more `Token`s (generation only), then exactly one terminal
/// `Done`/`Failed`.
#[derive(Debug)]
pub enum JobEvent {
    Token(TokenEvent),
    Done(Box<JobOutput>),
    Failed(ServeError),
}

/// Clonable cancellation token for a submitted job — lets another
/// thread cancel while the owner blocks in [`JobHandle::wait`].
#[derive(Debug, Clone, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    pub fn new() -> Self {
        CancelToken::default()
    }

    /// Request cancellation.  Observed by the dispatcher while the job
    /// is queued and by the fabric worker **between decode steps**; the
    /// job then completes with [`ServeError::Cancelled`].  Idempotent;
    /// a job that already finished is unaffected.
    pub fn cancel(&self) {
        self.0.store(true, Ordering::SeqCst);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::SeqCst)
    }
}

/// Handle to one submitted job: stream tokens, poll, block, or cancel.
#[derive(Debug)]
pub struct JobHandle {
    events: Receiver<JobEvent>,
    cancel: CancelToken,
    /// Tokens received but not yet handed to the caller.
    pending: VecDeque<TokenEvent>,
    /// The terminal event, once received.
    terminal: Option<Result<JobOutput, ServeError>>,
}

impl JobHandle {
    pub(crate) fn new(events: Receiver<JobEvent>, cancel: CancelToken) -> Self {
        JobHandle { events, cancel, pending: VecDeque::new(), terminal: None }
    }

    /// Request cancellation (see [`CancelToken::cancel`]).
    pub fn cancel(&self) {
        self.cancel.cancel();
    }

    /// A clonable token for cancelling from another thread.
    pub fn cancel_token(&self) -> CancelToken {
        self.cancel.clone()
    }

    fn absorb(&mut self, ev: JobEvent) {
        match ev {
            JobEvent::Token(t) => self.pending.push_back(t),
            JobEvent::Done(out) => self.terminal = Some(Ok(*out)),
            JobEvent::Failed(e) => self.terminal = Some(Err(e)),
        }
    }

    fn channel_lost() -> ServeError {
        ServeError::pool_lost("job channel closed before completion (server dropped?)")
    }

    /// Block until the next streamed token, or `None` once the job has
    /// reached its terminal state (retrieve it with [`Self::wait`] /
    /// [`Self::poll`]).  Encode jobs stream no tokens.
    pub fn next_token(&mut self) -> Option<TokenEvent> {
        loop {
            if let Some(t) = self.pending.pop_front() {
                return Some(t);
            }
            if self.terminal.is_some() {
                return None;
            }
            match self.events.recv() {
                Ok(ev) => self.absorb(ev),
                Err(_) => {
                    self.terminal = Some(Err(Self::channel_lost()));
                    return None;
                }
            }
        }
    }

    /// Non-blocking [`Self::next_token`].
    pub fn try_token(&mut self) -> Option<TokenEvent> {
        self.drain_available();
        self.pending.pop_front()
    }

    /// Non-blocking completion check: drains available events and
    /// returns the terminal result once the job finished.  Streamed
    /// tokens drained here stay readable via [`Self::next_token`].
    pub fn poll(&mut self) -> Option<&Result<JobOutput, ServeError>> {
        self.drain_available();
        self.terminal.as_ref()
    }

    fn drain_available(&mut self) {
        loop {
            match self.events.try_recv() {
                Ok(ev) => self.absorb(ev),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    if self.terminal.is_none() {
                        self.terminal = Some(Err(Self::channel_lost()));
                    }
                    break;
                }
            }
        }
    }

    /// Block until the job finishes, discarding any unread streamed
    /// tokens (the full transcript is in the output anyway).
    pub fn wait(mut self) -> Result<JobOutput, ServeError> {
        loop {
            if let Some(t) = self.terminal.take() {
                return t;
            }
            match self.events.recv() {
                Ok(ev) => self.absorb(ev),
                Err(_) => return Err(Self::channel_lost()),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    fn handle() -> (mpsc::Sender<JobEvent>, JobHandle) {
        let (tx, rx) = mpsc::channel();
        (tx, JobHandle::new(rx, CancelToken::new()))
    }

    fn tok(i: usize) -> TokenEvent {
        TokenEvent { index: i, token: i * 10, row: vec![i as f32] }
    }

    #[test]
    fn priority_orders_low_to_high() {
        assert!(Priority::Low < Priority::Normal);
        assert!(Priority::Normal < Priority::High);
        assert_eq!(Priority::default(), Priority::Normal);
        for (i, p) in Priority::ALL.into_iter().enumerate() {
            assert_eq!(p.index(), i);
        }
    }

    #[test]
    fn qos_builders_compose() {
        let q = QoS::high().with_deadline(Duration::from_millis(5)).with_opt_level(OptLevel::O0);
        assert_eq!(q.priority, Priority::High);
        assert_eq!(q.deadline, Some(Duration::from_millis(5)));
        assert_eq!(q.opt_level, Some(OptLevel::O0));
        assert_eq!(QoS::default().priority, Priority::Normal);
        assert_eq!(QoS::default().deadline, None);
        assert_eq!(QoS::low().priority, Priority::Low);
    }

    #[test]
    fn handle_streams_tokens_then_terminal() {
        let (tx, mut h) = handle();
        tx.send(JobEvent::Token(tok(0))).unwrap();
        tx.send(JobEvent::Token(tok(1))).unwrap();
        tx.send(JobEvent::Failed(ServeError::Cancelled)).unwrap();
        assert_eq!(h.next_token().unwrap().index, 0);
        assert_eq!(h.next_token().unwrap().token, 10);
        assert!(h.next_token().is_none(), "terminal reached");
        assert!(matches!(h.wait(), Err(ServeError::Cancelled)));
    }

    #[test]
    fn poll_buffers_tokens_for_later_streaming() {
        let (tx, mut h) = handle();
        assert!(h.poll().is_none(), "nothing arrived yet");
        tx.send(JobEvent::Token(tok(0))).unwrap();
        tx.send(JobEvent::Failed(ServeError::Cancelled)).unwrap();
        // poll sees the terminal but must not eat the streamed token
        while h.poll().is_none() {}
        assert_eq!(h.next_token().unwrap().index, 0);
        assert!(h.next_token().is_none());
    }

    #[test]
    fn dropped_channel_is_a_typed_pool_loss() {
        let (tx, mut h) = handle();
        drop(tx);
        assert!(h.next_token().is_none());
        assert!(matches!(h.wait(), Err(ServeError::PoolLost(_))));
    }

    #[test]
    fn cancel_token_round_trips() {
        let (_tx, h) = handle();
        let t = h.cancel_token();
        assert!(!t.is_cancelled());
        h.cancel();
        assert!(t.is_cancelled());
    }

    #[test]
    fn error_display_taxonomy_is_stable() {
        assert_eq!(ServeError::UnknownModel("m".into()).to_string(), "unknown model 'm'");
        assert_eq!(ServeError::Cancelled.to_string(), "job cancelled");
        assert!(ServeError::DeadlineExceeded { waited: Duration::from_millis(3) }
            .to_string()
            .contains("deadline exceeded"));
        let aff =
            ServeError::AffinityOutOfRange { model: "m".into(), fabric: 4, pool_size: 2 }.to_string();
        assert!(aff.contains("fabric 4") && aff.contains("2 fabric(s)"), "{aff}");
        // anyhow chains flatten into the Engine variant at the boundary
        let e: ServeError = anyhow::anyhow!("inner").context("outer").into();
        assert_eq!(e, ServeError::Engine("outer: inner".into()));
    }
}
