//! Model registry + routing.
//!
//! Several *registered models* (topology + weights) share one fabric —
//! ADAPTOR's whole point.  The router validates requests against the
//! registry and the synthesis maxima before they reach the engine thread,
//! so misconfigured requests fail fast outside the serving loop.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail};

use crate::accel::registers::SynthMaxima;
use crate::model::weights::{init_stack, LayerWeights};
use crate::model::TnnConfig;

/// A deployable model: name, topology, deterministic weight seed.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub name: String,
    pub cfg: TnnConfig,
    pub seed: u64,
    /// Scheduling hint for the fabric pool: pin this model's batches to a
    /// specific fabric index when one is configured (overrides the
    /// programmed-model affinity heuristic).  Ignored when the index is
    /// out of range for the running pool.
    pub preferred_fabric: Option<usize>,
}

impl ModelSpec {
    pub fn new(name: &str, cfg: TnnConfig, seed: u64) -> Self {
        ModelSpec { name: name.to_string(), cfg, seed, preferred_fabric: None }
    }

    /// Pin this model to a pool fabric (affinity hint).
    pub fn with_affinity(mut self, fabric: usize) -> Self {
        self.preferred_fabric = Some(fabric);
        self
    }

    /// Materialize the synthetic weight stack (DESIGN.md §Substitutions).
    pub fn weights(&self) -> Vec<LayerWeights> {
        init_stack(self.seed, self.cfg.d_model, self.cfg.heads, self.cfg.enc_layers)
    }
}

/// The registry the router consults.
#[derive(Debug, Default)]
pub struct Router {
    models: BTreeMap<String, ModelSpec>,
    maxima: Option<SynthMaxima>,
}

impl Router {
    pub fn new(maxima: SynthMaxima) -> Self {
        Router { models: BTreeMap::new(), maxima: Some(maxima) }
    }

    /// Register a model; refuses topologies the fabric cannot hold, naming
    /// every register that exceeds its synthesis maximum.
    pub fn register(&mut self, spec: ModelSpec) -> anyhow::Result<()> {
        spec.cfg.validate_for_execution().map_err(|e| anyhow!(e))?;
        if let Some(m) = &self.maxima {
            let mut over = Vec::new();
            if spec.cfg.seq_len > m.seq_len {
                over.push(format!("seq_len {} > {}", spec.cfg.seq_len, m.seq_len));
            }
            if spec.cfg.heads > m.heads {
                over.push(format!("heads {} > {}", spec.cfg.heads, m.heads));
            }
            if spec.cfg.d_model > m.d_model {
                over.push(format!("d_model {} > {}", spec.cfg.d_model, m.d_model));
            }
            if spec.cfg.hidden > m.hidden {
                over.push(format!("hidden {} > {}", spec.cfg.hidden, m.hidden));
            }
            if !over.is_empty() {
                bail!(
                    "model '{}' exceeds the synthesis maxima: {} (re-synthesis required)",
                    spec.name,
                    over.join(", ")
                );
            }
        }
        if self.models.contains_key(&spec.name) {
            bail!("model '{}' already registered", spec.name);
        }
        self.models.insert(spec.name.clone(), spec);
        Ok(())
    }

    pub fn lookup(&self, name: &str) -> anyhow::Result<&ModelSpec> {
        self.models.get(name).ok_or_else(|| anyhow!("unknown model '{name}'"))
    }

    /// Validate a request's input shape against its model.
    pub fn route(&self, model: &str, rows: usize, cols: usize) -> anyhow::Result<&ModelSpec> {
        let spec = self.lookup(model)?;
        if rows != spec.cfg.seq_len || cols != spec.cfg.d_model {
            bail!(
                "request for '{model}' is {rows}x{cols}, expected {}x{}",
                spec.cfg.seq_len,
                spec.cfg.d_model
            );
        }
        Ok(spec)
    }

    /// The pool-affinity hint registered for `model`, if any.
    pub fn affinity_hint(&self, model: &str) -> Option<usize> {
        self.models.get(model).and_then(|s| s.preferred_fabric)
    }

    pub fn names(&self) -> Vec<&str> {
        self.models.keys().map(String::as_str).collect()
    }

    pub fn specs(&self) -> impl Iterator<Item = &ModelSpec> {
        self.models.values()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::registers::SynthMaxima;
    use crate::model::presets;

    fn router() -> Router {
        Router::new(SynthMaxima::artifact_default())
    }

    #[test]
    fn register_and_route() {
        let mut r = router();
        r.register(ModelSpec::new("small", presets::small_encoder(64, 2), 1)).unwrap();
        assert!(r.route("small", 64, 256).is_ok());
        assert!(r.route("small", 32, 256).is_err());
        assert!(r.route("missing", 64, 256).is_err());
    }

    #[test]
    fn oversize_model_is_refused() {
        let mut r = router();
        let big = TnnConfig::encoder(64, 1024, 16, 2);
        let err = r.register(ModelSpec::new("big", big, 1)).unwrap_err().to_string();
        assert!(err.contains("d_model 1024 > 768"), "{err}");
        assert!(err.contains("heads 16 > 12"), "{err}");
        let long = presets::small_encoder(256, 2);
        let err = r.register(ModelSpec::new("long", long, 1)).unwrap_err().to_string();
        assert!(err.contains("seq_len 256 > 128"), "{err}");
    }

    #[test]
    fn too_many_heads_is_refused_even_when_dims_fit() {
        // 16 heads at d_model 512 divides evenly and fits every dimension
        // register except Heads — registration must still refuse it.
        let mut r = router();
        let cfg = TnnConfig::encoder(64, 512, 16, 1);
        let err = r.register(ModelSpec::new("heady", cfg, 1)).unwrap_err().to_string();
        assert!(err.contains("heads 16 > 12"), "{err}");
    }

    #[test]
    fn affinity_hint_round_trips_through_the_registry() {
        let mut r = router();
        r.register(ModelSpec::new("pinned", presets::small_encoder(64, 1), 1).with_affinity(2))
            .unwrap();
        r.register(ModelSpec::new("free", presets::small_encoder(64, 1), 2)).unwrap();
        assert_eq!(r.affinity_hint("pinned"), Some(2));
        assert_eq!(r.affinity_hint("free"), None);
        assert_eq!(r.affinity_hint("missing"), None);
    }

    #[test]
    fn duplicate_names_are_refused() {
        let mut r = router();
        r.register(ModelSpec::new("m", presets::small_encoder(64, 1), 1)).unwrap();
        assert!(r.register(ModelSpec::new("m", presets::small_encoder(64, 1), 2)).is_err());
    }

    #[test]
    fn weights_are_deterministic_per_seed() {
        let a = ModelSpec::new("m", presets::small_encoder(64, 1), 42).weights();
        let b = ModelSpec::new("m", presets::small_encoder(64, 1), 42).weights();
        assert_eq!(a[0].wo, b[0].wo);
    }
}
