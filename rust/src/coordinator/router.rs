//! Model registry + routing.
//!
//! Several *registered models* (topology + weights) share one fabric —
//! ADAPTOR's whole point.  The router validates requests against the
//! registry and the synthesis maxima before they reach the engine thread,
//! so misconfigured requests fail fast outside the serving loop.

use std::collections::BTreeMap;

use super::api::ServeError;
use crate::accel::registers::SynthMaxima;
use crate::model::weights::{init_decoder_stack, init_stack, DecoderLayerWeights, LayerWeights};
use crate::model::TnnConfig;

/// A deployable model: name, topology, deterministic weight seed.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub name: String,
    pub cfg: TnnConfig,
    pub seed: u64,
    /// Scheduling hint for the fabric pool: pin this model's batches to a
    /// specific fabric index when one is configured (overrides the
    /// programmed-model affinity heuristic).  Ignored when the index is
    /// out of range for the running pool.
    pub preferred_fabric: Option<usize>,
}

impl ModelSpec {
    pub fn new(name: &str, cfg: TnnConfig, seed: u64) -> Self {
        ModelSpec { name: name.to_string(), cfg, seed, preferred_fabric: None }
    }

    /// Pin this model to a pool fabric (affinity hint).
    pub fn with_affinity(mut self, fabric: usize) -> Self {
        self.preferred_fabric = Some(fabric);
        self
    }

    /// Materialize the synthetic encoder weight stack (DESIGN.md
    /// §Substitutions).  Empty for decoder-only models.
    pub fn weights(&self) -> Vec<LayerWeights> {
        init_stack(self.seed, self.cfg.d_model, self.cfg.heads, self.cfg.enc_layers)
    }

    /// Materialize the synthetic decoder weight stack (self-attention +
    /// FFN per layer; a cross-attention block iff the model also has an
    /// encoder stack).  Empty for encoder-only models.  The seed stream
    /// is offset from the encoder's so the stacks never share values.
    pub fn decoder_weights(&self) -> Vec<DecoderLayerWeights> {
        init_decoder_stack(
            self.seed ^ 0x5eed_dec0,
            self.cfg.d_model,
            self.cfg.heads,
            self.cfg.dec_layers,
            self.cfg.enc_layers > 0,
        )
    }
}

/// The registry the router consults.
#[derive(Debug, Default)]
pub struct Router {
    models: BTreeMap<String, ModelSpec>,
    maxima: Option<SynthMaxima>,
}

impl Router {
    pub fn new(maxima: SynthMaxima) -> Self {
        Router { models: BTreeMap::new(), maxima: Some(maxima) }
    }

    /// Register a model; refuses topologies the fabric cannot hold, naming
    /// every register that exceeds its synthesis maximum.
    pub fn register(&mut self, spec: ModelSpec) -> Result<(), ServeError> {
        spec.cfg.validate_for_execution().map_err(|e| ServeError::InvalidConfig(e.to_string()))?;
        if let Some(m) = &self.maxima {
            let mut over = Vec::new();
            if spec.cfg.seq_len > m.seq_len {
                over.push(format!("seq_len {} > {}", spec.cfg.seq_len, m.seq_len));
            }
            if spec.cfg.heads > m.heads {
                over.push(format!("heads {} > {}", spec.cfg.heads, m.heads));
            }
            if spec.cfg.d_model > m.d_model {
                over.push(format!("d_model {} > {}", spec.cfg.d_model, m.d_model));
            }
            if spec.cfg.hidden > m.hidden {
                over.push(format!("hidden {} > {}", spec.cfg.hidden, m.hidden));
            }
            if !over.is_empty() {
                return Err(ServeError::config(format!(
                    "model '{}' exceeds the synthesis maxima: {} (re-synthesis required)",
                    spec.name,
                    over.join(", ")
                )));
            }
        }
        if self.models.contains_key(&spec.name) {
            return Err(ServeError::config(format!("model '{}' already registered", spec.name)));
        }
        self.models.insert(spec.name.clone(), spec);
        Ok(())
    }

    pub fn lookup(&self, name: &str) -> Result<&ModelSpec, ServeError> {
        self.models.get(name).ok_or_else(|| ServeError::UnknownModel(name.to_string()))
    }

    /// Validate an encode request's input shape against its model.
    /// Models with decoder layers are **refused** here: the encode path
    /// would silently execute only the encoder stack (the truncation bug
    /// this explicit error replaces) — generation requests go through
    /// [`Self::route_generate`].
    pub fn route(&self, model: &str, rows: usize, cols: usize) -> Result<&ModelSpec, ServeError> {
        let spec = self.lookup(model)?;
        if spec.cfg.dec_layers > 0 {
            return Err(ServeError::invalid(format!(
                "model '{model}' has {} decoder layers; the encode path would silently drop \
                 them — submit a generation request instead",
                spec.cfg.dec_layers
            )));
        }
        // Length-adaptive serving: any live prefix of the sequence budget
        // is a valid request (the engine pads into the covering bucket);
        // only empty, over-long, or wrong-width inputs are refused.
        if rows == 0 || rows > spec.cfg.seq_len || cols != spec.cfg.d_model {
            return Err(ServeError::invalid(format!(
                "request for '{model}' is {rows}x{cols}, expected 1..={} rows of {} columns",
                spec.cfg.seq_len, spec.cfg.d_model
            )));
        }
        Ok(spec)
    }

    /// Validate a generation request: the model must carry decoder
    /// layers, the prompt must fit the sequence budget with `steps` to
    /// spare, and a source is required exactly when the model has an
    /// encoder stack to run it through.
    pub fn route_generate(
        &self,
        model: &str,
        prompt: (usize, usize),
        source: Option<(usize, usize)>,
        steps: usize,
    ) -> Result<&ModelSpec, ServeError> {
        let spec = self.lookup(model)?;
        let cfg = &spec.cfg;
        if cfg.dec_layers == 0 {
            return Err(ServeError::invalid(format!(
                "model '{model}' has no decoder layers; submit a plain encode request"
            )));
        }
        if steps == 0 {
            return Err(ServeError::invalid(format!("generation for '{model}' needs steps >= 1")));
        }
        let (rows, cols) = prompt;
        if cols != cfg.d_model || rows == 0 {
            return Err(ServeError::invalid(format!(
                "prompt for '{model}' is {rows}x{cols}, want >=1 rows of {}",
                cfg.d_model
            )));
        }
        if rows + steps > cfg.seq_len {
            return Err(ServeError::invalid(format!(
                "prompt ({rows}) + steps ({steps}) exceed '{model}'s sequence budget {}",
                cfg.seq_len
            )));
        }
        match (cfg.enc_layers > 0, source) {
            (true, None) => {
                return Err(ServeError::invalid(format!(
                    "seq2seq model '{model}' needs a source input to encode"
                )))
            }
            (true, Some((sr, sc))) if (sr, sc) != (cfg.seq_len, cfg.d_model) => {
                return Err(ServeError::invalid(format!(
                    "source for '{model}' is {sr}x{sc}, expected {}x{}",
                    cfg.seq_len, cfg.d_model
                )))
            }
            (false, Some(_)) => {
                return Err(ServeError::invalid(format!(
                    "decoder-only model '{model}' takes no source input"
                )))
            }
            _ => {}
        }
        Ok(spec)
    }

    /// The pool-affinity hint registered for `model`, if any.
    pub fn affinity_hint(&self, model: &str) -> Option<usize> {
        self.models.get(model).and_then(|s| s.preferred_fabric)
    }

    pub fn names(&self) -> Vec<&str> {
        self.models.keys().map(String::as_str).collect()
    }

    pub fn specs(&self) -> impl Iterator<Item = &ModelSpec> {
        self.models.values()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::registers::SynthMaxima;
    use crate::model::presets;

    fn router() -> Router {
        Router::new(SynthMaxima::artifact_default())
    }

    #[test]
    fn register_and_route() {
        let mut r = router();
        r.register(ModelSpec::new("small", presets::small_encoder(64, 2), 1)).unwrap();
        assert!(r.route("small", 64, 256).is_ok());
        // any live prefix of the sequence budget routes (length-adaptive)
        assert!(r.route("small", 32, 256).is_ok());
        assert!(r.route("small", 1, 256).is_ok());
        // empty, over-long, and wrong-width inputs are still refused
        assert!(r.route("small", 0, 256).is_err());
        assert!(r.route("small", 65, 256).is_err());
        assert!(r.route("small", 64, 128).is_err());
        assert!(r.route("missing", 64, 256).is_err());
    }

    #[test]
    fn oversize_model_is_refused() {
        let mut r = router();
        let big = TnnConfig::encoder(64, 1024, 16, 2);
        let err = r.register(ModelSpec::new("big", big, 1)).unwrap_err().to_string();
        assert!(err.contains("d_model 1024 > 768"), "{err}");
        assert!(err.contains("heads 16 > 12"), "{err}");
        let long = presets::small_encoder(256, 2);
        let err = r.register(ModelSpec::new("long", long, 1)).unwrap_err().to_string();
        assert!(err.contains("seq_len 256 > 128"), "{err}");
    }

    #[test]
    fn too_many_heads_is_refused_even_when_dims_fit() {
        // 16 heads at d_model 512 divides evenly and fits every dimension
        // register except Heads — registration must still refuse it.
        let mut r = router();
        let cfg = TnnConfig::encoder(64, 512, 16, 1);
        let err = r.register(ModelSpec::new("heady", cfg, 1)).unwrap_err().to_string();
        assert!(err.contains("heads 16 > 12"), "{err}");
    }

    #[test]
    fn affinity_hint_round_trips_through_the_registry() {
        let mut r = router();
        r.register(ModelSpec::new("pinned", presets::small_encoder(64, 1), 1).with_affinity(2))
            .unwrap();
        r.register(ModelSpec::new("free", presets::small_encoder(64, 1), 2)).unwrap();
        assert_eq!(r.affinity_hint("pinned"), Some(2));
        assert_eq!(r.affinity_hint("free"), None);
        assert_eq!(r.affinity_hint("missing"), None);
    }

    #[test]
    fn decoder_models_register_and_route_through_generation_only() {
        // Satellite regression: dec_layers > 0 used to be silently served
        // as an encoder — now the encode route is an explicit error and
        // the generation route validates shape + budget.
        let mut r = router();
        let gpt = presets::gpt_small(64, 2);
        r.register(ModelSpec::new("gpt", gpt, 7)).unwrap();
        let err = r.route("gpt", 64, 256).unwrap_err().to_string();
        assert!(err.contains("decoder layers"), "{err}");
        assert!(r.route_generate("gpt", (4, 256), None, 8).is_ok());
        // budget, shape, and source-mismatch failures are explicit
        assert!(r.route_generate("gpt", (60, 256), None, 8).is_err());
        assert!(r.route_generate("gpt", (4, 128), None, 8).is_err());
        assert!(r.route_generate("gpt", (4, 256), Some((64, 256)), 8).is_err());
        assert!(r.route_generate("gpt", (4, 256), None, 0).is_err());

        let s2s = presets::seq2seq_small(64, 2, 2);
        r.register(ModelSpec::new("s2s", s2s, 8)).unwrap();
        assert!(r.route_generate("s2s", (4, 256), Some((64, 256)), 8).is_ok());
        assert!(r.route_generate("s2s", (4, 256), None, 8).is_err());
        assert!(r.route_generate("s2s", (4, 256), Some((32, 256)), 8).is_err());
        // encoder-only models refuse the generation route
        r.register(ModelSpec::new("enc", presets::small_encoder(64, 1), 9)).unwrap();
        assert!(r.route_generate("enc", (4, 256), None, 8).is_err());
    }

    #[test]
    fn decoder_weight_stacks_match_the_topology() {
        let gpt = ModelSpec::new("gpt", presets::gpt_small(64, 3), 5);
        let dw = gpt.decoder_weights();
        assert_eq!(dw.len(), 3);
        assert!(dw.iter().all(|w| w.cross.is_none()));
        assert!(gpt.weights().is_empty());
        let s2s = ModelSpec::new("s2s", presets::seq2seq_small(64, 2, 2), 5);
        let dw = s2s.decoder_weights();
        assert_eq!(dw.len(), 2);
        assert!(dw.iter().all(|w| w.cross.is_some()));
        assert_eq!(s2s.weights().len(), 2);
        // deterministic and decoupled from the encoder stream
        assert_eq!(
            s2s.decoder_weights()[0].base.wo,
            ModelSpec::new("x", presets::seq2seq_small(64, 2, 2), 5).decoder_weights()[0].base.wo
        );
        assert_ne!(s2s.decoder_weights()[0].base.wo, s2s.weights()[0].wo);
    }

    #[test]
    fn duplicate_names_are_refused() {
        let mut r = router();
        r.register(ModelSpec::new("m", presets::small_encoder(64, 1), 1)).unwrap();
        assert!(r.register(ModelSpec::new("m", presets::small_encoder(64, 1), 2)).is_err());
    }

    #[test]
    fn routing_failures_are_typed() {
        // Serving API v1: every routing failure is a ServeError variant
        // callers can match on, not an opaque string.
        let mut r = router();
        r.register(ModelSpec::new("small", presets::small_encoder(64, 2), 1)).unwrap();
        assert!(matches!(r.route("missing", 64, 256), Err(ServeError::UnknownModel(_))));
        assert!(matches!(r.lookup("missing"), Err(ServeError::UnknownModel(_))));
        assert!(matches!(r.route("small", 100, 256), Err(ServeError::InvalidRequest(_))));
        assert!(matches!(
            r.route_generate("small", (4, 256), None, 4),
            Err(ServeError::InvalidRequest(_))
        ));
        assert!(matches!(
            r.register(ModelSpec::new("small", presets::small_encoder(64, 2), 1)),
            Err(ServeError::InvalidConfig(_))
        ));
        let big = TnnConfig::encoder(64, 1024, 16, 2);
        assert!(matches!(
            r.register(ModelSpec::new("big", big, 1)),
            Err(ServeError::InvalidConfig(_))
        ));
    }

    #[test]
    fn weights_are_deterministic_per_seed() {
        let a = ModelSpec::new("m", presets::small_encoder(64, 1), 42).weights();
        let b = ModelSpec::new("m", presets::small_encoder(64, 1), 42).weights();
        assert_eq!(a[0].wo, b[0].wo);
    }
}
