//! Model registry + routing.
//!
//! Several *registered models* (topology + weights) share one fabric —
//! ADAPTOR's whole point.  The router validates requests against the
//! registry and the synthesis maxima before they reach the engine thread,
//! so misconfigured requests fail fast outside the serving loop.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail};

use crate::accel::registers::SynthMaxima;
use crate::model::weights::{init_stack, LayerWeights};
use crate::model::TnnConfig;

/// A deployable model: name, topology, deterministic weight seed.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub name: String,
    pub cfg: TnnConfig,
    pub seed: u64,
}

impl ModelSpec {
    pub fn new(name: &str, cfg: TnnConfig, seed: u64) -> Self {
        ModelSpec { name: name.to_string(), cfg, seed }
    }

    /// Materialize the synthetic weight stack (DESIGN.md §Substitutions).
    pub fn weights(&self) -> Vec<LayerWeights> {
        init_stack(self.seed, self.cfg.d_model, self.cfg.heads, self.cfg.enc_layers)
    }
}

/// The registry the router consults.
#[derive(Debug, Default)]
pub struct Router {
    models: BTreeMap<String, ModelSpec>,
    maxima: Option<SynthMaxima>,
}

impl Router {
    pub fn new(maxima: SynthMaxima) -> Self {
        Router { models: BTreeMap::new(), maxima: Some(maxima) }
    }

    /// Register a model; refuses topologies the fabric cannot hold.
    pub fn register(&mut self, spec: ModelSpec) -> anyhow::Result<()> {
        spec.cfg.validate_for_execution().map_err(|e| anyhow!(e))?;
        if let Some(m) = &self.maxima {
            if spec.cfg.seq_len > m.seq_len
                || spec.cfg.d_model > m.d_model
                || spec.cfg.hidden > m.hidden
            {
                bail!(
                    "model '{}' exceeds synthesis maxima (sl {} d {} hid {})",
                    spec.name,
                    m.seq_len,
                    m.d_model,
                    m.hidden
                );
            }
        }
        if self.models.contains_key(&spec.name) {
            bail!("model '{}' already registered", spec.name);
        }
        self.models.insert(spec.name.clone(), spec);
        Ok(())
    }

    pub fn lookup(&self, name: &str) -> anyhow::Result<&ModelSpec> {
        self.models.get(name).ok_or_else(|| anyhow!("unknown model '{name}'"))
    }

    /// Validate a request's input shape against its model.
    pub fn route(&self, model: &str, rows: usize, cols: usize) -> anyhow::Result<&ModelSpec> {
        let spec = self.lookup(model)?;
        if rows != spec.cfg.seq_len || cols != spec.cfg.d_model {
            bail!(
                "request for '{model}' is {rows}x{cols}, expected {}x{}",
                spec.cfg.seq_len,
                spec.cfg.d_model
            );
        }
        Ok(spec)
    }

    pub fn names(&self) -> Vec<&str> {
        self.models.keys().map(String::as_str).collect()
    }

    pub fn specs(&self) -> impl Iterator<Item = &ModelSpec> {
        self.models.values()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::registers::SynthMaxima;
    use crate::model::presets;

    fn router() -> Router {
        Router::new(SynthMaxima::artifact_default())
    }

    #[test]
    fn register_and_route() {
        let mut r = router();
        r.register(ModelSpec::new("small", presets::small_encoder(64, 2), 1)).unwrap();
        assert!(r.route("small", 64, 256).is_ok());
        assert!(r.route("small", 32, 256).is_err());
        assert!(r.route("missing", 64, 256).is_err());
    }

    #[test]
    fn oversize_model_is_refused() {
        let mut r = router();
        let big = TnnConfig::encoder(64, 1024, 16, 2);
        assert!(r.register(ModelSpec::new("big", big, 1)).is_err());
        let long = presets::small_encoder(256, 2);
        assert!(r.register(ModelSpec::new("long", long, 1)).is_err());
    }

    #[test]
    fn duplicate_names_are_refused() {
        let mut r = router();
        r.register(ModelSpec::new("m", presets::small_encoder(64, 1), 1)).unwrap();
        assert!(r.register(ModelSpec::new("m", presets::small_encoder(64, 1), 2)).is_err());
    }

    #[test]
    fn weights_are_deterministic_per_seed() {
        let a = ModelSpec::new("m", presets::small_encoder(64, 1), 42).weights();
        let b = ModelSpec::new("m", presets::small_encoder(64, 1), 42).weights();
        assert_eq!(a[0].wo, b[0].wo);
    }
}
