//! Weight residency: device weight memory as a traffic-aware cache.
//!
//! ADAPTOR's runtime adaptivity means one synthesized fabric serves many
//! topologies — but until this layer existed, the pool re-uploaded a
//! fabric's **entire weight stack on every model switch**, the way the
//! paper's host loop does (Algorithm 18 steps 7–9).  NPE keeps one fixed
//! overlay serving many NLP models by managing on-device memory as a
//! resource, and FTRANS shows weight memory is the binding constraint
//! when several transformer stacks contend for one FPGA's BRAM/URAM
//! (PAPERS.md).  This module is that discipline for the pool:
//!
//! * [`WeightResidencyManager`] — a per-fabric, capacity-bounded cache of
//!   device-resident model stacks (encoder panels, decoder/cross stacks,
//!   decode-row weights), keyed by model name and sized from the platform
//!   envelope ([`resources::weight_memory_bytes`]).  A hit replays the
//!   cached program against already-resident weights; a miss evicts by
//!   **traffic-weighted LRU** until the incoming stack fits, then uploads.
//! * **Pinning** — a model with live KV-cached generations on a fabric is
//!   never evicted mid-flight; the worker recomputes the pin set from its
//!   live-sequence list after every admission and decode round.
//! * **Cost model** — [`weight_footprint_bytes`] prices a topology's
//!   device stack from the same tiling arithmetic `prepare_model` uses,
//!   and [`upload_penalty_requests`] converts it into the scheduler
//!   currency (equivalent queued requests) so placement can weigh a
//!   reprogram against a deeper queue (`SchedulePolicy::CostAware` in
//!   [`super::server`]).
//!
//! ### Traffic-weighted LRU
//!
//! Recency alone thrashes under multi-tenant churn: a burst of one-off
//! models evicts the steady tenant everyone is about to hit again.  Each
//! entry therefore carries an arrival-rate EWMA over a **logical tick**
//! clock (one tick per acquire on the fabric — deterministic, no wall
//! time).  On an access at tick `t` of an entry last touched at `t₀`:
//!
//! ```text
//! rate ← rate · decay^(t − t₀) + (1 − decay)
//! ```
//!
//! and the eviction heat of an idle entry at tick `now` is its rate
//! decayed to the present, `H = rate · decay^(now − t₀)`.  The victim is
//! always the unpinned entry with minimal `H` — least recently *and*
//! least frequently needed.  The dispatcher's own per-model arrival EWMA
//! rides along as `rate_hint` so a fabric seeing a model for the first
//! time still knows it is hot.
//!
//! Capacity is best-effort, never availability-limiting: if every
//! resident entry is pinned, the incoming stack is admitted over budget
//! (the substrate can — real hardware would stall the upload) and the
//! overshoot is visible as `resident_bytes_peak` in metrics.

use std::collections::BTreeSet;

use crate::accel::schedule::{AttentionMode, FabricConstants};
use crate::accel::sim::cycle;
use crate::accel::{platform, resources};
use crate::coordinator::api::ServeError;
use crate::model::TnnConfig;

/// How a fabric treats its weight memory.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ResidencyMode {
    /// Capacity-bounded cache with traffic-weighted-LRU eviction and
    /// in-flight pinning — the managed default.
    Managed,
    /// The paper's host-loop behavior: at most one stack resident, every
    /// model switch evicts and re-uploads.  Kept as the measurable
    /// baseline (`BENCH_residency.json`) and as a debugging escape hatch.
    ReprogramAlways,
}

/// Policy knobs for one fabric's [`WeightResidencyManager`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ResidencyPolicy {
    pub mode: ResidencyMode,
    /// Device weight-memory budget in bytes.  Defaults to the U55C
    /// envelope from [`resources::weight_memory_bytes`].
    pub capacity_bytes: u64,
    /// Per-tick EWMA decay of the arrival-rate estimate, in (0, 1);
    /// higher keeps history longer.
    pub decay: f64,
    /// Queue depth at which the dispatcher prefetches a hot model's stack
    /// to a second fabric (see `coordinator::server`).
    pub prefetch_depth: usize,
}

impl Default for ResidencyPolicy {
    fn default() -> Self {
        ResidencyPolicy {
            mode: ResidencyMode::Managed,
            capacity_bytes: resources::weight_memory_bytes(&platform::u55c()),
            decay: 0.85,
            prefetch_depth: 3,
        }
    }
}

/// Counters one manager accumulates; mirrored into `Metrics` by the
/// fabric worker after every acquire.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ResidencyStats {
    /// Acquires served from an already-resident stack.
    pub hits: u64,
    /// Full weight-stack uploads (`prepare_model` calls).
    pub uploads: u64,
    /// Stacks evicted to make room.
    pub evictions: u64,
    /// Bytes currently resident.
    pub resident_bytes: u64,
    /// High-water mark of `resident_bytes` — exceeds `capacity_bytes`
    /// only when pinning forced an over-budget admission.
    pub resident_bytes_peak: u64,
}

struct Entry<S> {
    model: String,
    stack: S,
    bytes: u64,
    /// Live KV-cached generations reference this stack — not evictable.
    pinned: bool,
    /// Arrival-rate EWMA at `last_tick` (see module docs).
    rate: f64,
    last_tick: u64,
}

/// One fabric's weight memory, managed as a cache of prepared stacks.
///
/// Generic over the stack type so the serving path (`PreparedStack`) and
/// the artifact-free tests/benches (plain host-side stand-ins) share the
/// exact eviction/pinning logic being proven.
pub struct WeightResidencyManager<S> {
    policy: ResidencyPolicy,
    entries: Vec<Entry<S>>,
    tick: u64,
    stats: ResidencyStats,
}

fn heat<S>(e: &Entry<S>, decay: f64, now: u64) -> f64 {
    e.rate * decay.powi(now.saturating_sub(e.last_tick) as i32)
}

impl<S> WeightResidencyManager<S> {
    pub fn new(policy: ResidencyPolicy) -> Self {
        WeightResidencyManager {
            policy,
            entries: Vec::new(),
            tick: 0,
            stats: ResidencyStats::default(),
        }
    }

    pub fn policy(&self) -> &ResidencyPolicy {
        &self.policy
    }

    /// The acquire path: return `model`'s resident stack, uploading via
    /// `load` on a miss after evicting enough unpinned cold entries.
    /// `bytes` is the stack's device footprint
    /// ([`weight_footprint_bytes`]); `rate_hint` is the dispatcher's
    /// arrival-rate estimate, folded into the entry's own EWMA.
    ///
    /// Eviction never touches pinned entries; if the victims run out the
    /// stack is admitted over budget (recorded in `resident_bytes_peak`)
    /// rather than failing the batch.
    pub fn acquire_with<F>(
        &mut self,
        model: &str,
        bytes: u64,
        rate_hint: Option<f64>,
        load: F,
    ) -> Result<&S, ServeError>
    where
        F: FnOnce() -> Result<S, ServeError>,
    {
        self.tick += 1;
        let now = self.tick;
        let decay = self.policy.decay;
        if let Some(i) = self.entries.iter().position(|e| e.model == model) {
            let e = &mut self.entries[i];
            e.rate = e.rate * decay.powi(now.saturating_sub(e.last_tick) as i32) + (1.0 - decay);
            if let Some(h) = rate_hint {
                e.rate = e.rate.max(h);
            }
            e.last_tick = now;
            self.stats.hits += 1;
            return Ok(&self.entries[i].stack);
        }
        match self.policy.mode {
            ResidencyMode::ReprogramAlways => {
                // The baseline fabric holds one stack: any switch evicts.
                self.stats.evictions += self.entries.len() as u64;
                self.entries.clear();
            }
            ResidencyMode::Managed => {
                while self.resident_bytes() + bytes > self.policy.capacity_bytes {
                    let victim = self
                        .entries
                        .iter()
                        .enumerate()
                        .filter(|(_, e)| !e.pinned)
                        .min_by(|(_, a), (_, b)| {
                            heat(a, decay, now).total_cmp(&heat(b, decay, now))
                        })
                        .map(|(i, _)| i);
                    match victim {
                        Some(i) => {
                            self.entries.remove(i);
                            self.stats.evictions += 1;
                        }
                        // Everything left is pinned by live generations —
                        // admit over budget rather than stall the batch.
                        None => break,
                    }
                }
            }
        }
        let stack = load()?;
        self.entries.push(Entry {
            model: model.to_string(),
            stack,
            bytes,
            pinned: false,
            rate: rate_hint.unwrap_or(1.0 - decay),
            last_tick: now,
        });
        self.stats.uploads += 1;
        let resident = self.resident_bytes();
        self.stats.resident_bytes_peak = self.stats.resident_bytes_peak.max(resident);
        let i = self.entries.len() - 1;
        Ok(&self.entries[i].stack)
    }

    /// Non-ticking peek — the decode-round path, which must not distort
    /// the traffic estimate (one generation is one arrival, not one
    /// arrival per emitted token).
    pub fn get(&self, model: &str) -> Option<&S> {
        self.entries.iter().find(|e| e.model == model).map(|e| &e.stack)
    }

    pub fn is_resident(&self, model: &str) -> bool {
        self.entries.iter().any(|e| e.model == model)
    }

    /// Recompute the pin set wholesale from the models with live
    /// generations on this fabric.  Called after every admission and
    /// decode round; a pin lapses the moment its last sequence retires.
    pub fn set_pinned<'a, I>(&mut self, live: I)
    where
        I: IntoIterator<Item = &'a str>,
    {
        let live: BTreeSet<&str> = live.into_iter().collect();
        for e in &mut self.entries {
            e.pinned = live.contains(e.model.as_str());
        }
    }

    /// Resident model names, for the dispatcher's placement belief
    /// (carried back on every fabric completion event).
    pub fn resident_models(&self) -> Vec<String> {
        self.entries.iter().map(|e| e.model.clone()).collect()
    }

    fn resident_bytes(&self) -> u64 {
        self.entries.iter().map(|e| e.bytes).sum()
    }

    /// Counter snapshot with `resident_bytes` refreshed.
    pub fn stats(&self) -> ResidencyStats {
        ResidencyStats { resident_bytes: self.resident_bytes(), ..self.stats }
    }
}

/// Bytes per fabric cycle the weight AXI masters move during a stack
/// upload: one 512-bit beat per cycle (§4's m_axi ports are 512-bit).
pub const UPLOAD_BYTES_PER_CYCLE: u64 = 64;

/// Fabric cycles to upload `bytes` of weights at the AXI beat rate.
pub fn upload_cycles(bytes: u64) -> u64 {
    bytes.div_ceil(UPLOAD_BYTES_PER_CYCLE)
}

/// Device weight-memory footprint of `cfg`'s prepared stack in bytes —
/// the same panel inventory `TileEngine::prepare_model` parks
/// device-resident, priced without touching a device:
///
/// * per **encoder layer**: per-head Q/K/V tile panels plus their packed
///   Q|K|V variant, the FFN1/FFN2/FFN3 panel grids, and the bias/LN
///   vectors (padded to fabric maxima);
/// * per **decoder layer**: one encoder layer (the self-attention + FFN
///   prefill half) plus the full-width decode-row matrices, and — for
///   seq2seq topologies — the cross-attention prefill panels and
///   decode-row projections.
///
/// All panels are f32 on the device (quantization happens inside the
/// fabric datapath, §5.2).
pub fn weight_footprint_bytes(cfg: &TnnConfig, fc: &FabricConstants) -> u64 {
    cfg.enc_layers as u64 * encoder_layer_bytes(cfg, fc)
        + cfg.dec_layers as u64 * decoder_layer_bytes(cfg, fc)
}

/// Weight-memory bytes of **one encoder layer** of `cfg` on `fc` — the
/// per-layer `enc` term of [`weight_footprint_bytes`], exposed as the
/// unit the shard partitioner (`coordinator::shard`) packs into fabric
/// envelopes.
pub fn encoder_layer_bytes(cfg: &TnnConfig, fc: &FabricConstants) -> u64 {
    layer_elems(cfg, fc).0 * 4
}

/// Weight-memory bytes of **one decoder layer** of `cfg` on `fc`: its
/// encoder-shaped prefill half plus the decode-row matrices, and the
/// cross-attention block for seq2seq topologies.
pub fn decoder_layer_bytes(cfg: &TnnConfig, fc: &FabricConstants) -> u64 {
    layer_elems(cfg, fc).1 * 4
}

/// `(encoder layer, decoder layer)` footprints of `cfg` in f32 elements.
fn layer_elems(cfg: &TnnConfig, fc: &FabricConstants) -> (u64, u64) {
    let d = cfg.d_model as u64;
    let h = cfg.heads as u64;
    let hidden = cfg.hidden as u64;
    let dk = fc.dk as u64;
    let ts_mha = fc.ts_mha as u64;
    let ts_ffn = fc.ts_ffn as u64;
    let ffn_col = fc.ffn_col as u64;
    let dmax = fc.dmodel_max as u64;
    let hmax = fc.hidden_max as u64;
    let t_m = d / ts_mha;
    let t_f = d / ts_ffn;
    let t_h = hidden / ffn_col;

    // One encoder layer, in f32 elements.
    let enc = h * t_m * ts_mha * 3 * dk // packed Q|K|V panels
        + h * 3 * dk                    // packed biases
        + 3 * h * t_m * ts_mha * dk     // unpacked W_q/W_k/W_v panels
        + 3 * h * dk                    // b_q/b_k/b_v
        + t_f * t_f * ts_ffn * ts_ffn   // FFN1 (output projection) grid
        + t_f * t_h * ts_ffn * ffn_col  // FFN2 grid
        + t_h * t_f * ffn_col * ts_ffn  // FFN3 grid
        + 6 * dmax                      // b_o, b_2, LN gains/biases
        + hmax; // b_1

    // Decode-row extras of one decoder layer (on top of its `enc` half).
    let dec_rows = 3 * h * dmax * dk // per-head full Q/K/V projections
        + dmax * dmax                // output projection
        + dmax * hmax                // FFN up
        + hmax * dmax; // FFN down

    // Cross-attention block (present iff the topology has an encoder).
    let cross = if cfg.enc_layers > 0 {
        3 * h * t_m * ts_mha * dk       // cross Q/K/V prefill panels
            + 3 * h * dk                // cross biases
            + t_f * t_f * ts_ffn * ts_ffn // cross output-projection grid
            + 3 * dmax                  // cb_o, LN gain/bias
            + h * dmax * dk             // decode-row cross query
            + dmax * dmax // decode-row cross output projection
    } else {
        0
    };

    (enc, enc + dec_rows + cross)
}

/// The reprogram penalty in scheduler currency: uploading `cfg`'s stack
/// costs this many *queued requests* of the same model.  Upload cycles
/// come from [`upload_cycles`] over the stack footprint; request cycles
/// from the artifact-free cycle backend (whole-prompt prefill for
/// decoder topologies, one encoder pass otherwise).  Falls back to 1.0 —
/// "one request's worth" — if the topology can't be priced.
pub fn upload_penalty_requests(cfg: &TnnConfig, fc: &FabricConstants) -> f64 {
    let up = upload_cycles(weight_footprint_bytes(cfg, fc)) as f64;
    let req = if cfg.dec_layers > 0 {
        cycle::estimate_prefill(cfg, fc).map(|r| r.total_cycles)
    } else {
        cycle::estimate(cfg, fc, AttentionMode::Fused, false, false).map(|r| r.total_cycles)
    };
    match req {
        Ok(c) if c > 0 => up / c as f64,
        _ => 1.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::presets;

    fn policy(capacity_bytes: u64) -> ResidencyPolicy {
        ResidencyPolicy { capacity_bytes, ..ResidencyPolicy::default() }
    }

    fn acquire(m: &mut WeightResidencyManager<String>, model: &str, bytes: u64) {
        m.acquire_with(model, bytes, None, || Ok(model.to_uppercase())).unwrap();
    }

    #[test]
    fn hit_skips_the_loader() {
        let mut m = WeightResidencyManager::new(policy(100));
        acquire(&mut m, "a", 10);
        let loaded =
            m.acquire_with("a", 10, None, || -> Result<String, ServeError> {
                panic!("resident stack must not reload")
            });
        assert_eq!(loaded.unwrap(), "A");
        let s = m.stats();
        assert_eq!((s.hits, s.uploads, s.evictions), (1, 1, 0));
        assert_eq!(s.resident_bytes, 10);
    }

    #[test]
    fn traffic_weighted_lru_evicts_the_cold_entry() {
        // Capacity for two stacks; "hot" is touched repeatedly, "cold"
        // was loaded more recently but only once. Plain LRU would evict
        // "hot"'s older last-touch; the traffic weighting keeps it.
        let mut m = WeightResidencyManager::new(policy(20));
        acquire(&mut m, "hot", 10);
        acquire(&mut m, "cold", 10);
        for _ in 0..5 {
            acquire(&mut m, "hot", 10);
        }
        acquire(&mut m, "cold", 10); // cold's last touch is most recent
        acquire(&mut m, "new", 10);
        assert!(m.is_resident("hot"));
        assert!(!m.is_resident("cold"));
        assert_eq!(m.stats().evictions, 1);
    }

    #[test]
    fn pinned_entries_survive_and_admit_over_budget() {
        let mut m = WeightResidencyManager::new(policy(10));
        acquire(&mut m, "live", 10);
        m.set_pinned(["live"]);
        acquire(&mut m, "peer", 10); // nothing evictable: over-budget admit
        assert!(m.is_resident("live"));
        assert!(m.is_resident("peer"));
        let s = m.stats();
        assert_eq!(s.evictions, 0);
        assert_eq!(s.resident_bytes, 20);
        assert_eq!(s.resident_bytes_peak, 20);
        // Unpinning makes "live" evictable again.
        m.set_pinned(std::iter::empty::<&str>());
        acquire(&mut m, "third", 10);
        assert_eq!(m.stats().evictions, 2);
    }

    #[test]
    fn reprogram_always_holds_one_stack() {
        let mut m = WeightResidencyManager::new(ResidencyPolicy {
            mode: ResidencyMode::ReprogramAlways,
            ..ResidencyPolicy::default()
        });
        acquire(&mut m, "a", 10);
        acquire(&mut m, "b", 10);
        acquire(&mut m, "a", 10);
        let s = m.stats();
        assert_eq!((s.hits, s.uploads, s.evictions), (0, 3, 2));
        assert_eq!(s.resident_bytes, 10);
        assert!(m.is_resident("a") && !m.is_resident("b"));
    }

    #[test]
    fn rate_hint_seeds_a_new_entrys_heat() {
        let mut m = WeightResidencyManager::new(policy(20));
        acquire(&mut m, "old", 10);
        // A brand-new model arrives with a hot dispatcher rate; the cold
        // steady entry loses the next eviction despite being resident
        // longer.
        m.acquire_with("burst", 10, Some(5.0), || Ok(String::new())).unwrap();
        acquire(&mut m, "third", 10);
        assert!(m.is_resident("burst"));
        assert!(!m.is_resident("old"));
    }

    #[test]
    fn footprint_scales_with_depth_and_decoder() {
        let fc = FabricConstants::artifact_default();
        let enc2 = presets::by_name("shallow").unwrap();
        let enc4 = presets::by_name("custom-encoder-4l").unwrap();
        let b2 = weight_footprint_bytes(&enc2, &fc);
        let b4 = weight_footprint_bytes(&enc4, &fc);
        assert_eq!(b4, 2 * b2, "same topology at 2x depth is 2x bytes");
        // A decoder layer strictly outweighs an encoder layer (row
        // matrices ride along), and seq2seq cross blocks add more still.
        let gpt = presets::by_name("gpt-small").unwrap();
        assert!(weight_footprint_bytes(&gpt, &fc) > 0);
        let s2s = presets::by_name("seq2seq-small").unwrap();
        let dec_only = TnnConfig { enc_layers: 0, ..s2s };
        assert!(weight_footprint_bytes(&s2s, &fc) > weight_footprint_bytes(&dec_only, &fc));
    }

    #[test]
    fn upload_penalty_is_finite_and_positive() {
        let fc = FabricConstants::artifact_default();
        for (name, cfg) in presets::all() {
            let pen = upload_penalty_requests(&cfg, &fc);
            assert!(pen.is_finite() && pen > 0.0, "{name}: {pen}");
        }
    }
}
