//! Dynamic request batching.
//!
//! The fabric processes one sequence at a time (like the FPGA), so a batch
//! is a *drain schedule*: the batcher groups compatible requests (same
//! registered model → same register programming) to amortize register
//! writes and weight residency, and closes a batch on size or deadline —
//! the standard serving tradeoff between throughput and tail latency.

use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Close a batch at this many requests.
    pub max_batch: usize,
    /// ... or when the oldest member has waited this long.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5) }
    }
}

/// One queued item.
#[derive(Debug)]
pub struct Pending<T> {
    pub model: String,
    pub arrived: Instant,
    pub payload: T,
}

/// Accumulates pending requests per model and emits ready batches.
#[derive(Debug)]
pub struct Batcher<T> {
    policy: BatchPolicy,
    queue: Vec<Pending<T>>,
}

impl<T> Batcher<T> {
    pub fn new(policy: BatchPolicy) -> Self {
        Batcher { policy, queue: Vec::new() }
    }

    pub fn push(&mut self, model: &str, payload: T) {
        self.queue.push(Pending { model: model.to_string(), arrived: Instant::now(), payload });
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Earliest deadline among queued items (for the drain loop's sleep).
    pub fn next_deadline(&self) -> Option<Instant> {
        self.queue.iter().map(|p| p.arrived + self.policy.max_wait).min()
    }

    /// Pop a ready batch: all queued items for the model of the *oldest*
    /// request, if that model's group hit `max_batch` or its oldest member
    /// timed out (or `force` is set).  Model grouping amortizes register
    /// reprogramming, FIFO-by-oldest preserves fairness across models.
    pub fn pop_ready(&mut self, now: Instant, force: bool) -> Option<(String, Vec<Pending<T>>)> {
        let oldest = self.queue.iter().min_by_key(|p| p.arrived)?;
        let model = oldest.model.clone();
        let group: Vec<usize> = self
            .queue
            .iter()
            .enumerate()
            .filter(|(_, p)| p.model == model)
            .map(|(i, _)| i)
            .take(self.policy.max_batch)
            .collect();
        let timed_out = now.duration_since(oldest.arrived) >= self.policy.max_wait;
        if !force && group.len() < self.policy.max_batch && !timed_out {
            return None;
        }
        let mut batch = Vec::with_capacity(group.len());
        for i in group.into_iter().rev() {
            batch.push(self.queue.remove(i));
        }
        batch.reverse();
        Some((model, batch))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk() -> Batcher<u32> {
        Batcher::new(BatchPolicy { max_batch: 3, max_wait: Duration::from_millis(50) })
    }

    #[test]
    fn batch_closes_on_size() {
        let mut b = mk();
        b.push("m", 1);
        b.push("m", 2);
        assert!(b.pop_ready(Instant::now(), false).is_none());
        b.push("m", 3);
        let (model, batch) = b.pop_ready(Instant::now(), false).unwrap();
        assert_eq!(model, "m");
        assert_eq!(batch.iter().map(|p| p.payload).collect::<Vec<_>>(), vec![1, 2, 3]);
        assert!(b.is_empty());
    }

    #[test]
    fn batch_closes_on_deadline() {
        let mut b = mk();
        b.push("m", 1);
        assert!(b.pop_ready(Instant::now(), false).is_none());
        let later = Instant::now() + Duration::from_millis(60);
        let (_, batch) = b.pop_ready(later, false).unwrap();
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn force_drains_immediately() {
        let mut b = mk();
        b.push("m", 9);
        let (_, batch) = b.pop_ready(Instant::now(), true).unwrap();
        assert_eq!(batch[0].payload, 9);
    }

    #[test]
    fn groups_by_model_fifo_fairness() {
        let mut b = mk();
        b.push("a", 1);
        b.push("b", 2);
        b.push("a", 3);
        b.push("a", 4); // "a" reaches max_batch = 3
        let (model, batch) = b.pop_ready(Instant::now(), false).unwrap();
        assert_eq!(model, "a");
        assert_eq!(batch.iter().map(|p| p.payload).collect::<Vec<_>>(), vec![1, 3, 4]);
        assert_eq!(b.len(), 1); // "b" still queued
        // b's batch opens on timeout, not size
        let later = Instant::now() + Duration::from_millis(60);
        let (model, batch) = b.pop_ready(later, false).unwrap();
        assert_eq!(model, "b");
        assert_eq!(batch[0].payload, 2);
    }

    #[test]
    fn oversize_group_splits_at_max_batch() {
        let mut b = mk();
        for i in 0..7 {
            b.push("m", i);
        }
        let (_, first) = b.pop_ready(Instant::now(), false).unwrap();
        assert_eq!(first.len(), 3);
        assert_eq!(b.len(), 4);
    }

    #[test]
    fn next_deadline_tracks_oldest() {
        let mut b = mk();
        assert!(b.next_deadline().is_none());
        b.push("m", 1);
        let d1 = b.next_deadline().unwrap();
        b.push("m", 2);
        assert_eq!(b.next_deadline().unwrap(), d1);
    }
}
