//! Dynamic request batching.
//!
//! Each fabric processes one sequence at a time (like the FPGA), so a
//! batch is a *drain schedule*: the batcher groups compatible requests
//! (same registered model → same register programming) to amortize
//! register writes and weight residency, and closes a batch on size or
//! deadline — the standard serving tradeoff between throughput and tail
//! latency.
//!
//! Requests are held in **per-model ready queues** (one FIFO per model)
//! rather than one flat scan: `pop_ready` is O(models) instead of
//! O(requests), and a ready batch of any model can be drained even while
//! another model's oldest request is still inside its deadline.  Fairness
//! is preserved by always draining the ready group whose *oldest* member
//! arrived first, so a lone request for model B cannot starve behind a
//! steady stream of full model-A batches.

use std::collections::{BTreeMap, VecDeque};
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Close a batch at this many requests.
    pub max_batch: usize,
    /// ... or when the oldest member has waited this long.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5) }
    }
}

/// One queued item.
#[derive(Debug)]
pub struct Pending<T> {
    pub model: String,
    pub arrived: Instant,
    pub payload: T,
}

/// Accumulates pending requests per model and emits ready batches.
#[derive(Debug)]
pub struct Batcher<T> {
    policy: BatchPolicy,
    queues: BTreeMap<String, VecDeque<Pending<T>>>,
    len: usize,
}

impl<T> Batcher<T> {
    pub fn new(policy: BatchPolicy) -> Self {
        Batcher { policy, queues: BTreeMap::new(), len: 0 }
    }

    pub fn push(&mut self, model: &str, payload: T) {
        self.push_at(model, payload, Instant::now());
    }

    /// Queue a request with an explicit arrival time (the server passes the
    /// submit-side enqueue instant so deadlines cover the channel hop too).
    pub fn push_at(&mut self, model: &str, payload: T, arrived: Instant) {
        let q = self.queues.entry(model.to_string()).or_default();
        // The front-is-oldest invariant must survive concurrent submitters:
        // the arrival stamp is taken before the channel send, so messages
        // can reach us out of stamp order.  Walk back from the tail —
        // O(1) amortized for the common in-order case.
        let mut idx = q.len();
        while idx > 0 && q[idx - 1].arrived > arrived {
            idx -= 1;
        }
        q.insert(idx, Pending { model: model.to_string(), arrived, payload });
        self.len += 1;
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Models with queued work, in name order.
    pub fn queued_models(&self) -> impl Iterator<Item = &str> {
        self.queues.keys().map(String::as_str)
    }

    /// Earliest deadline among queued items (for the drain loop's sleep).
    /// Each per-model queue is FIFO, so its front is its oldest member.
    pub fn next_deadline(&self) -> Option<Instant> {
        self.queues
            .values()
            .filter_map(|q| q.front())
            .map(|p| p.arrived + self.policy.max_wait)
            .min()
    }

    /// Pop a ready batch.  A model's group is *ready* when it reached
    /// `max_batch`, its oldest member timed out, or `force` is set; among
    /// ready groups the one whose oldest member arrived first is drained
    /// (FIFO-by-oldest preserves fairness across models), up to
    /// `max_batch` requests in arrival order.
    pub fn pop_ready(&mut self, now: Instant, force: bool) -> Option<(String, Vec<Pending<T>>)> {
        let mut best: Option<(&str, Instant)> = None;
        for (model, q) in &self.queues {
            let front = match q.front() {
                Some(p) => p,
                None => continue,
            };
            let ready = force
                || q.len() >= self.policy.max_batch
                || now.duration_since(front.arrived) >= self.policy.max_wait;
            if !ready {
                continue;
            }
            if best.map_or(true, |(_, t)| front.arrived < t) {
                best = Some((model, front.arrived));
            }
        }
        let model = best?.0.to_string();
        let q = self.queues.get_mut(&model).expect("ready model is queued");
        let n = q.len().min(self.policy.max_batch);
        let batch: Vec<Pending<T>> = q.drain(..n).collect();
        if q.is_empty() {
            self.queues.remove(&model);
        }
        self.len -= batch.len();
        Some((model, batch))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk() -> Batcher<u32> {
        Batcher::new(BatchPolicy { max_batch: 3, max_wait: Duration::from_millis(50) })
    }

    #[test]
    fn batch_closes_on_size() {
        let mut b = mk();
        b.push("m", 1);
        b.push("m", 2);
        assert!(b.pop_ready(Instant::now(), false).is_none());
        b.push("m", 3);
        let (model, batch) = b.pop_ready(Instant::now(), false).unwrap();
        assert_eq!(model, "m");
        assert_eq!(batch.iter().map(|p| p.payload).collect::<Vec<_>>(), vec![1, 2, 3]);
        assert!(b.is_empty());
    }

    #[test]
    fn batch_closes_on_deadline() {
        let mut b = mk();
        b.push("m", 1);
        assert!(b.pop_ready(Instant::now(), false).is_none());
        let later = Instant::now() + Duration::from_millis(60);
        let (_, batch) = b.pop_ready(later, false).unwrap();
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn force_drains_immediately() {
        let mut b = mk();
        b.push("m", 9);
        let (_, batch) = b.pop_ready(Instant::now(), true).unwrap();
        assert_eq!(batch[0].payload, 9);
    }

    #[test]
    fn groups_by_model_fifo_fairness() {
        let mut b = mk();
        b.push("a", 1);
        b.push("b", 2);
        b.push("a", 3);
        b.push("a", 4); // "a" reaches max_batch = 3
        let (model, batch) = b.pop_ready(Instant::now(), false).unwrap();
        assert_eq!(model, "a");
        assert_eq!(batch.iter().map(|p| p.payload).collect::<Vec<_>>(), vec![1, 3, 4]);
        assert_eq!(b.len(), 1); // "b" still queued
        // b's batch opens on timeout, not size
        let later = Instant::now() + Duration::from_millis(60);
        let (model, batch) = b.pop_ready(later, false).unwrap();
        assert_eq!(model, "b");
        assert_eq!(batch[0].payload, 2);
    }

    #[test]
    fn oversize_group_splits_at_max_batch() {
        let mut b = mk();
        for i in 0..7 {
            b.push("m", i);
        }
        let (_, first) = b.pop_ready(Instant::now(), false).unwrap();
        assert_eq!(first.len(), 3);
        assert_eq!(b.len(), 4);
    }

    #[test]
    fn next_deadline_tracks_oldest() {
        let mut b = mk();
        assert!(b.next_deadline().is_none());
        b.push("m", 1);
        let d1 = b.next_deadline().unwrap();
        b.push("m", 2);
        assert_eq!(b.next_deadline().unwrap(), d1);
    }

    #[test]
    fn full_batch_drains_even_when_another_models_oldest_is_younger_still() {
        // A full group of "a" must not be held hostage by a not-yet-ready
        // lone "b" that happens to be globally oldest (the old flat-scan
        // batcher returned None here).
        let mut b = mk();
        let t0 = Instant::now();
        b.push_at("b", 0, t0);
        b.push_at("a", 1, t0 + Duration::from_millis(1));
        b.push_at("a", 2, t0 + Duration::from_millis(1));
        b.push_at("a", 3, t0 + Duration::from_millis(1));
        // 10ms in: "b" has not timed out, but "a" is full and must drain.
        let (model, batch) = b.pop_ready(t0 + Duration::from_millis(10), false).unwrap();
        assert_eq!(model, "a");
        assert_eq!(batch.len(), 3);
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn queued_model_b_is_not_starved_by_sustained_model_a_load() {
        // Satellite regression: a lone request for model B queued behind a
        // steady stream of full model-A batches must be served as soon as
        // its deadline passes — ahead of further A batches.
        let mut b: Batcher<u32> =
            Batcher::new(BatchPolicy { max_batch: 2, max_wait: Duration::from_millis(50) });
        let t0 = Instant::now();
        b.push_at("b", 999, t0);
        let mut popped_b_at_round = None;
        for round in 0..10u32 {
            // sustained model-A pressure: a full batch arrives every 10ms
            let now = t0 + Duration::from_millis(10 * (round as u64 + 1));
            b.push_at("a", round * 2, now - Duration::from_millis(1));
            b.push_at("a", round * 2 + 1, now - Duration::from_millis(1));
            while let Some((model, batch)) = b.pop_ready(now, false) {
                if model == "b" {
                    assert_eq!(batch[0].payload, 999);
                    popped_b_at_round = Some(round);
                }
            }
            if popped_b_at_round.is_some() {
                break;
            }
        }
        // b's 50ms deadline passes during round 4 (t0+50ms); it must have
        // been drained then despite "a" staying saturated.
        let round = popped_b_at_round.expect("model b starved behind model a");
        assert!(round <= 4, "b served only at round {round}");
    }

    #[test]
    fn deadline_ready_oldest_wins_over_full_younger_group() {
        // Once B *has* timed out it outranks a younger full A group.
        let mut b = mk();
        let t0 = Instant::now();
        b.push_at("b", 7, t0);
        b.push_at("a", 1, t0 + Duration::from_millis(5));
        b.push_at("a", 2, t0 + Duration::from_millis(5));
        b.push_at("a", 3, t0 + Duration::from_millis(5));
        let (model, _) = b.pop_ready(t0 + Duration::from_millis(60), false).unwrap();
        assert_eq!(model, "b");
        let (model, _) = b.pop_ready(t0 + Duration::from_millis(60), false).unwrap();
        assert_eq!(model, "a");
    }

    #[test]
    fn out_of_order_arrivals_keep_front_oldest() {
        // Concurrent submitters can deliver a younger stamp first; the
        // queue must re-establish arrival order so deadlines and fairness
        // key off the true oldest member.
        let mut b = mk();
        let t0 = Instant::now();
        b.push_at("m", 2, t0 + Duration::from_millis(2));
        b.push_at("m", 1, t0); // older, arrives second
        b.push_at("m", 3, t0 + Duration::from_millis(3));
        assert_eq!(b.next_deadline().unwrap(), t0 + Duration::from_millis(50));
        let (_, batch) = b.pop_ready(t0 + Duration::from_millis(60), false).unwrap();
        assert_eq!(batch.iter().map(|p| p.payload).collect::<Vec<_>>(), vec![1, 2, 3]);
    }

    #[test]
    fn queued_models_lists_pending_groups() {
        let mut b = mk();
        b.push("x", 1);
        b.push("y", 2);
        assert_eq!(b.queued_models().collect::<Vec<_>>(), vec!["x", "y"]);
        let _ = b.pop_ready(Instant::now(), true);
        assert_eq!(b.queued_models().count(), 1);
    }
}
