//! Dynamic request batching.
//!
//! Each fabric processes one sequence at a time (like the FPGA), so a
//! batch is a *drain schedule*: the batcher groups compatible requests
//! (same registered model → same register programming) to amortize
//! register writes and weight residency, and closes a batch on size or
//! deadline — the standard serving tradeoff between throughput and tail
//! latency.
//!
//! Requests are held in **per-model ready queues** (one queue per model)
//! rather than one flat scan, and the per-model selection key (front
//! priority, oldest arrival) is memoized — kept current in O(1) on
//! push, invalidated on pop/sweep — so ready-group selection costs
//! O(live models) per round instead of O(queued requests).  That
//! matters under continuous batching, where the dispatcher re-runs the
//! selection every scheduler round, not once per drained batch.
//!
//! Serving API v1 made the queues **QoS-aware**: each pending request
//! carries a [`Priority`] and an optional absolute deadline.  Within a
//! model's queue, requests order by (priority ▼, arrival ▲); among
//! *ready* groups the one with the highest-priority front drains first,
//! ties broken by the group's oldest member (so, at equal priority, a
//! lone request for model B cannot starve behind a steady stream of
//! full model-A batches — the original fairness rule).  Deadlines feed
//! [`Batcher::next_deadline`] so the dispatcher wakes in time to sweep
//! expired requests out with a typed error ([`Batcher::take_where`])
//! instead of serving them late or dropping them silently.

use std::cell::{Cell, RefCell};
use std::collections::{BTreeMap, HashMap, VecDeque};
use std::time::{Duration, Instant};

use super::api::Priority;

/// Batching policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Close a batch at this many requests.
    pub max_batch: usize,
    /// ... or when the oldest member has waited this long.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5) }
    }
}

/// One queued item.
#[derive(Debug)]
pub struct Pending<T> {
    pub model: String,
    pub arrived: Instant,
    /// QoS class: orders the queue ahead of arrival time.
    pub priority: Priority,
    /// Absolute give-up instant; an item still queued past it is swept
    /// out by [`Batcher::take_where`], never served late.
    pub deadline: Option<Instant>,
    pub payload: T,
}

impl<T> Pending<T> {
    /// Whether this item's deadline has passed at `now`.
    pub fn expired(&self, now: Instant) -> bool {
        self.deadline.map_or(false, |d| d <= now)
    }
}

/// Accumulates pending requests per model and emits ready batches.
#[derive(Debug)]
pub struct Batcher<T> {
    policy: BatchPolicy,
    queues: BTreeMap<String, VecDeque<Pending<T>>>,
    len: usize,
    /// Memoized per-model selection key `(front priority, oldest
    /// arrival)`.  Kept current in O(1) by `push_qos` (an insertion can
    /// only raise the front's priority and lower the oldest stamp),
    /// dropped by `pop_model_n` / `take_where` and lazily recomputed on
    /// the next selection — so a steady-state selection round touches
    /// each live model once, not each queued request.
    fronts: RefCell<HashMap<String, (Priority, Instant)>>,
    /// Queue elements visited while recomputing selection keys —
    /// instrumentation for the O(live models) regression test.
    scan_cost: Cell<u64>,
}

impl<T> Batcher<T> {
    pub fn new(policy: BatchPolicy) -> Self {
        Batcher {
            policy,
            queues: BTreeMap::new(),
            len: 0,
            fronts: RefCell::new(HashMap::new()),
            scan_cost: Cell::new(0),
        }
    }

    pub fn push(&mut self, model: &str, payload: T) {
        self.push_at(model, payload, Instant::now());
    }

    /// Queue a request with an explicit arrival time (the server passes the
    /// submit-side enqueue instant so deadlines cover the channel hop too).
    pub fn push_at(&mut self, model: &str, payload: T, arrived: Instant) {
        self.push_qos(model, payload, arrived, Priority::Normal, None);
    }

    /// Queue a request with its full QoS: the queue orders by
    /// (priority ▼, arrival ▲).  The arrival stamp is taken before the
    /// channel send, so messages can reach us out of stamp order; the
    /// insertion walk re-establishes the order — O(1) amortized for the
    /// common in-order case.
    pub fn push_qos(
        &mut self,
        model: &str,
        payload: T,
        arrived: Instant,
        priority: Priority,
        deadline: Option<Instant>,
    ) {
        let q = self.queues.entry(model.to_string()).or_default();
        let mut idx = q.len();
        while idx > 0
            && (q[idx - 1].priority < priority
                || (q[idx - 1].priority == priority && q[idx - 1].arrived > arrived))
        {
            idx -= 1;
        }
        q.insert(idx, Pending { model: model.to_string(), arrived, priority, deadline, payload });
        self.len += 1;
        // Maintain the memoized selection key without a rescan: the new
        // front is O(1) to read, and an insertion can only lower the
        // oldest arrival.
        let front = (q.front().expect("just inserted").priority, arrived);
        let mut fronts = self.fronts.borrow_mut();
        if q.len() == 1 {
            fronts.insert(model.to_string(), front);
        } else if let Some(e) = fronts.get_mut(model) {
            e.0 = front.0;
            e.1 = e.1.min(arrived);
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Models with queued work, in name order.
    pub fn queued_models(&self) -> impl Iterator<Item = &str> {
        self.queues.keys().map(String::as_str)
    }

    /// Requests queued for one model — the dispatcher's prefetch
    /// trigger reads this as its queue-deepening signal.
    pub fn model_len(&self, model: &str) -> usize {
        self.queues.get(model).map_or(0, |q| q.len())
    }

    /// Earliest wake-up instant among queued items (for the drain loop's
    /// sleep): the soonest batching deadline (oldest arrival + max_wait)
    /// or QoS give-up deadline, whichever comes first.
    pub fn next_deadline(&self) -> Option<Instant> {
        self.queues
            .values()
            .flat_map(|q| {
                let batching =
                    q.iter().map(|p| p.arrived).min().map(|oldest| oldest + self.policy.max_wait);
                let qos = q.iter().filter_map(|p| p.deadline).min();
                batching.into_iter().chain(qos)
            })
            .min()
    }

    /// Oldest arrival in a (priority-ordered) queue.
    fn oldest(q: &VecDeque<Pending<T>>) -> Option<Instant> {
        q.iter().map(|p| p.arrived).min()
    }

    /// The model [`Self::pop_ready`] would drain right now, without
    /// draining it — the dispatcher previews the target fabric's
    /// capacity before committing the pop.
    pub fn peek_ready(&self, now: Instant, force: bool) -> Option<&str> {
        self.select_ready(now, force, &[])
    }

    /// [`Self::peek_ready`] skipping the named models — the dispatcher
    /// sets a model aside when its target fabric is at capacity and
    /// keeps draining other models' ready work to idle fabrics (no
    /// head-of-line blocking across models).
    pub fn peek_ready_excluding(
        &self,
        now: Instant,
        force: bool,
        excluded: &[String],
    ) -> Option<&str> {
        self.select_ready(now, force, excluded)
    }

    /// Whether any queued item matches `pred` — a cheap pre-check so the
    /// dispatcher only pays for a [`Self::take_where`] queue rebuild
    /// when a sweep would actually remove something.
    pub fn any_where(&self, mut pred: impl FnMut(&Pending<T>) -> bool) -> bool {
        self.queues.values().flatten().any(|p| pred(p))
    }

    /// Remove and return every queued item matching `pred`, preserving
    /// queue order among survivors.  The dispatcher sweeps out
    /// deadline-expired and cancelled requests with this so they
    /// complete with a typed error instead of being served late.
    pub fn take_where(&mut self, mut pred: impl FnMut(&Pending<T>) -> bool) -> Vec<Pending<T>> {
        let mut taken = Vec::new();
        for q in self.queues.values_mut() {
            let drained = std::mem::take(q);
            for p in drained {
                if pred(&p) {
                    taken.push(p);
                } else {
                    q.push_back(p);
                }
            }
        }
        self.queues.retain(|_, q| !q.is_empty());
        self.len -= taken.len();
        // A sweep can remove any member, so every memoized selection key
        // is suspect; recompute lazily on the next selection round.
        self.fronts.borrow_mut().clear();
        taken
    }

    /// Convenience sweep: every item whose QoS deadline passed at `now`.
    pub fn take_expired(&mut self, now: Instant) -> Vec<Pending<T>> {
        self.take_where(|p| p.expired(now))
    }

    /// The shared selection scan behind [`Self::pop_ready`] /
    /// [`Self::peek_ready`]: the ready group with the highest-priority
    /// front, ties to the oldest member; `excluded` models are skipped.
    fn select_ready(&self, now: Instant, force: bool, excluded: &[String]) -> Option<&str> {
        let mut best: Option<(&str, Priority, Instant)> = None;
        let mut fronts = self.fronts.borrow_mut();
        for (model, q) in &self.queues {
            if excluded.iter().any(|m| m == model) {
                continue;
            }
            let front = match q.front() {
                Some(p) => p,
                None => continue,
            };
            let (priority, oldest) = match fronts.get(model.as_str()) {
                Some(&k) => k,
                None => {
                    self.scan_cost.set(self.scan_cost.get() + q.len() as u64);
                    let k = (
                        front.priority,
                        Self::oldest(q).expect("non-empty queue has an oldest member"),
                    );
                    fronts.insert(model.clone(), k);
                    k
                }
            };
            let ready = force
                || q.len() >= self.policy.max_batch
                || now.duration_since(oldest) >= self.policy.max_wait;
            if !ready {
                continue;
            }
            let better = match best {
                None => true,
                Some((_, bp, bo)) => priority > bp || (priority == bp && oldest < bo),
            };
            if better {
                best = Some((model, priority, oldest));
            }
        }
        best.map(|(model, _, _)| model)
    }

    /// Queue elements visited recomputing memoized selection keys since
    /// construction.  Instrumentation for the regression test pinning
    /// selection at O(live models) per round on a deep queue.
    pub fn selection_scan_cost(&self) -> u64 {
        self.scan_cost.get()
    }

    /// The item [`Self::pop_model`] would drain first, without draining
    /// it — the dispatcher inspects a ready group's front to decide
    /// whether to drain a whole encode batch or yield a **single
    /// generation** for the continuous-batching scheduler round.
    pub fn front(&self, model: &str) -> Option<&Pending<T>> {
        self.queues.get(model)?.front()
    }

    /// Pop a ready batch.  A model's group is *ready* when it reached
    /// `max_batch`, its oldest member timed out, or `force` is set.
    /// Among ready groups the one whose **front has the highest
    /// priority** drains first; at equal priority the group whose oldest
    /// member arrived first wins (FIFO-by-oldest preserves fairness
    /// across models).  Up to `max_batch` requests drain in queue order
    /// (priority ▼, arrival ▲).
    pub fn pop_ready(&mut self, now: Instant, force: bool) -> Option<(String, Vec<Pending<T>>)> {
        let model = self.select_ready(now, force, &[])?.to_string();
        self.pop_model(&model)
    }

    /// Drain up to `max_batch` queued requests of one specific model
    /// (the one a prior [`Self::peek_ready_excluding`] selected), in
    /// queue order.  `None` if the model has nothing queued.
    pub fn pop_model(&mut self, model: &str) -> Option<(String, Vec<Pending<T>>)> {
        self.pop_model_n(model, self.policy.max_batch)
    }

    /// [`Self::pop_model`] with an explicit batch-size cap.  The
    /// continuous-batching dispatcher pops generations with `max = 1`
    /// so the batcher yields individual sequences between scheduler
    /// rounds — each round's admission re-runs the QoS selection
    /// instead of committing a whole drained batch up front.
    pub fn pop_model_n(&mut self, model: &str, max: usize) -> Option<(String, Vec<Pending<T>>)> {
        let q = self.queues.get_mut(model)?;
        let n = q.len().min(max);
        let batch: Vec<Pending<T>> = q.drain(..n).collect();
        if q.is_empty() {
            self.queues.remove(model);
        }
        // The drain removed the front (and possibly the oldest member);
        // recompute this model's selection key lazily.
        self.fronts.borrow_mut().remove(model);
        self.len -= batch.len();
        if batch.is_empty() {
            None
        } else {
            Some((model.to_string(), batch))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk() -> Batcher<u32> {
        Batcher::new(BatchPolicy { max_batch: 3, max_wait: Duration::from_millis(50) })
    }

    #[test]
    fn batch_closes_on_size() {
        let mut b = mk();
        b.push("m", 1);
        b.push("m", 2);
        assert!(b.pop_ready(Instant::now(), false).is_none());
        b.push("m", 3);
        let (model, batch) = b.pop_ready(Instant::now(), false).unwrap();
        assert_eq!(model, "m");
        assert_eq!(batch.iter().map(|p| p.payload).collect::<Vec<_>>(), vec![1, 2, 3]);
        assert!(b.is_empty());
    }

    #[test]
    fn batch_closes_on_deadline() {
        let mut b = mk();
        b.push("m", 1);
        assert!(b.pop_ready(Instant::now(), false).is_none());
        let later = Instant::now() + Duration::from_millis(60);
        let (_, batch) = b.pop_ready(later, false).unwrap();
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn force_drains_immediately() {
        let mut b = mk();
        b.push("m", 9);
        let (_, batch) = b.pop_ready(Instant::now(), true).unwrap();
        assert_eq!(batch[0].payload, 9);
    }

    #[test]
    fn groups_by_model_fifo_fairness() {
        let mut b = mk();
        b.push("a", 1);
        b.push("b", 2);
        b.push("a", 3);
        b.push("a", 4); // "a" reaches max_batch = 3
        let (model, batch) = b.pop_ready(Instant::now(), false).unwrap();
        assert_eq!(model, "a");
        assert_eq!(batch.iter().map(|p| p.payload).collect::<Vec<_>>(), vec![1, 3, 4]);
        assert_eq!(b.len(), 1); // "b" still queued
        // b's batch opens on timeout, not size
        let later = Instant::now() + Duration::from_millis(60);
        let (model, batch) = b.pop_ready(later, false).unwrap();
        assert_eq!(model, "b");
        assert_eq!(batch[0].payload, 2);
    }

    #[test]
    fn oversize_group_splits_at_max_batch() {
        let mut b = mk();
        for i in 0..7 {
            b.push("m", i);
        }
        let (_, first) = b.pop_ready(Instant::now(), false).unwrap();
        assert_eq!(first.len(), 3);
        assert_eq!(b.len(), 4);
    }

    #[test]
    fn next_deadline_tracks_oldest() {
        let mut b = mk();
        assert!(b.next_deadline().is_none());
        b.push("m", 1);
        let d1 = b.next_deadline().unwrap();
        b.push("m", 2);
        assert_eq!(b.next_deadline().unwrap(), d1);
    }

    #[test]
    fn full_batch_drains_even_when_another_models_oldest_is_younger_still() {
        // A full group of "a" must not be held hostage by a not-yet-ready
        // lone "b" that happens to be globally oldest (the old flat-scan
        // batcher returned None here).
        let mut b = mk();
        let t0 = Instant::now();
        b.push_at("b", 0, t0);
        b.push_at("a", 1, t0 + Duration::from_millis(1));
        b.push_at("a", 2, t0 + Duration::from_millis(1));
        b.push_at("a", 3, t0 + Duration::from_millis(1));
        // 10ms in: "b" has not timed out, but "a" is full and must drain.
        let (model, batch) = b.pop_ready(t0 + Duration::from_millis(10), false).unwrap();
        assert_eq!(model, "a");
        assert_eq!(batch.len(), 3);
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn queued_model_b_is_not_starved_by_sustained_model_a_load() {
        // Satellite regression: a lone request for model B queued behind a
        // steady stream of full model-A batches must be served as soon as
        // its deadline passes — ahead of further A batches.
        let mut b: Batcher<u32> =
            Batcher::new(BatchPolicy { max_batch: 2, max_wait: Duration::from_millis(50) });
        let t0 = Instant::now();
        b.push_at("b", 999, t0);
        let mut popped_b_at_round = None;
        for round in 0..10u32 {
            // sustained model-A pressure: a full batch arrives every 10ms
            let now = t0 + Duration::from_millis(10 * (round as u64 + 1));
            b.push_at("a", round * 2, now - Duration::from_millis(1));
            b.push_at("a", round * 2 + 1, now - Duration::from_millis(1));
            while let Some((model, batch)) = b.pop_ready(now, false) {
                if model == "b" {
                    assert_eq!(batch[0].payload, 999);
                    popped_b_at_round = Some(round);
                }
            }
            if popped_b_at_round.is_some() {
                break;
            }
        }
        // b's 50ms deadline passes during round 4 (t0+50ms); it must have
        // been drained then despite "a" staying saturated.
        let round = popped_b_at_round.expect("model b starved behind model a");
        assert!(round <= 4, "b served only at round {round}");
    }

    #[test]
    fn deadline_ready_oldest_wins_over_full_younger_group() {
        // Once B *has* timed out it outranks a younger full A group.
        let mut b = mk();
        let t0 = Instant::now();
        b.push_at("b", 7, t0);
        b.push_at("a", 1, t0 + Duration::from_millis(5));
        b.push_at("a", 2, t0 + Duration::from_millis(5));
        b.push_at("a", 3, t0 + Duration::from_millis(5));
        let (model, _) = b.pop_ready(t0 + Duration::from_millis(60), false).unwrap();
        assert_eq!(model, "b");
        let (model, _) = b.pop_ready(t0 + Duration::from_millis(60), false).unwrap();
        assert_eq!(model, "a");
    }

    #[test]
    fn out_of_order_arrivals_keep_front_oldest() {
        // Concurrent submitters can deliver a younger stamp first; the
        // queue must re-establish arrival order so deadlines and fairness
        // key off the true oldest member.
        let mut b = mk();
        let t0 = Instant::now();
        b.push_at("m", 2, t0 + Duration::from_millis(2));
        b.push_at("m", 1, t0); // older, arrives second
        b.push_at("m", 3, t0 + Duration::from_millis(3));
        assert_eq!(b.next_deadline().unwrap(), t0 + Duration::from_millis(50));
        let (_, batch) = b.pop_ready(t0 + Duration::from_millis(60), false).unwrap();
        assert_eq!(batch.iter().map(|p| p.payload).collect::<Vec<_>>(), vec![1, 2, 3]);
    }

    #[test]
    fn priority_orders_within_a_model_queue() {
        // 3 normals then 2 highs, all inside max_wait: the drained batch
        // leads with the highs (arrival order within each class).
        let mut b = mk();
        let t0 = Instant::now();
        for (i, ms) in [(1u32, 0u64), (2, 1), (3, 2)] {
            b.push_qos("m", i, t0 + Duration::from_millis(ms), Priority::Normal, None);
        }
        b.push_qos("m", 10, t0 + Duration::from_millis(3), Priority::High, None);
        b.push_qos("m", 11, t0 + Duration::from_millis(4), Priority::High, None);
        let (_, batch) = b.pop_ready(t0 + Duration::from_millis(60), false).unwrap();
        assert_eq!(batch.iter().map(|p| p.payload).collect::<Vec<_>>(), vec![10, 11, 1]);
        let (_, batch) = b.pop_ready(t0 + Duration::from_millis(60), false).unwrap();
        assert_eq!(batch.iter().map(|p| p.payload).collect::<Vec<_>>(), vec![2, 3]);
    }

    #[test]
    fn high_priority_group_outranks_an_older_normal_group() {
        let mut b = mk();
        let t0 = Instant::now();
        b.push_qos("old-normal", 1, t0, Priority::Normal, None);
        b.push_qos("young-high", 2, t0 + Duration::from_millis(5), Priority::High, None);
        // both groups are deadline-ready: priority outranks age…
        let (model, _) = b.pop_ready(t0 + Duration::from_millis(60), false).unwrap();
        assert_eq!(model, "young-high");
        // …then the normal drains.
        let (model, _) = b.pop_ready(t0 + Duration::from_millis(60), false).unwrap();
        assert_eq!(model, "old-normal");
    }

    #[test]
    fn low_priority_yields_to_normal() {
        let mut b = mk();
        let t0 = Instant::now();
        b.push_qos("m", 1, t0, Priority::Low, None);
        b.push_qos("m", 2, t0 + Duration::from_millis(1), Priority::Normal, None);
        let (_, batch) = b.pop_ready(t0 + Duration::from_millis(60), false).unwrap();
        assert_eq!(batch.iter().map(|p| p.payload).collect::<Vec<_>>(), vec![2, 1]);
    }

    #[test]
    fn take_expired_sweeps_only_past_deadline_items() {
        let mut b = mk();
        let t0 = Instant::now();
        b.push_qos("m", 1, t0, Priority::Normal, Some(t0 + Duration::from_millis(10)));
        b.push_qos("m", 2, t0, Priority::Normal, Some(t0 + Duration::from_millis(100)));
        b.push_qos("m", 3, t0, Priority::Normal, None);
        assert!(b.take_expired(t0 + Duration::from_millis(5)).is_empty());
        let expired = b.take_expired(t0 + Duration::from_millis(20));
        assert_eq!(expired.len(), 1);
        assert_eq!(expired[0].payload, 1);
        assert!(expired[0].expired(t0 + Duration::from_millis(20)));
        assert_eq!(b.len(), 2, "survivors stay queued");
        let (_, batch) = b.pop_ready(t0 + Duration::from_millis(60), true).unwrap();
        assert_eq!(batch.iter().map(|p| p.payload).collect::<Vec<_>>(), vec![2, 3]);
    }

    #[test]
    fn qos_deadline_feeds_next_deadline() {
        let mut b = mk(); // max_wait = 50ms
        let t0 = Instant::now();
        b.push_qos("m", 1, t0, Priority::Normal, Some(t0 + Duration::from_millis(7)));
        // the QoS give-up (7ms) is sooner than the batching deadline (50ms)
        assert_eq!(b.next_deadline().unwrap(), t0 + Duration::from_millis(7));
        b.push_qos("m", 2, t0 + Duration::from_millis(1), Priority::Normal, None);
        assert_eq!(b.next_deadline().unwrap(), t0 + Duration::from_millis(7));
    }

    #[test]
    fn take_where_removes_by_predicate_and_updates_len() {
        let mut b = mk();
        b.push("a", 1);
        b.push("a", 2);
        b.push("b", 3);
        let odd = b.take_where(|p| p.payload % 2 == 1);
        assert_eq!(odd.len(), 2);
        assert_eq!(b.len(), 1);
        assert_eq!(b.queued_models().collect::<Vec<_>>(), vec!["a"]);
    }

    #[test]
    fn peek_ready_mirrors_pop_ready_without_draining() {
        let mut b = mk(); // max_batch = 3
        let t0 = Instant::now();
        b.push_at("m", 1, t0);
        assert!(b.peek_ready(t0, false).is_none(), "one item inside max_wait is not ready");
        assert_eq!(b.peek_ready(t0, true), Some("m"), "force makes anything ready");
        assert_eq!(b.peek_ready(t0 + Duration::from_millis(60), false), Some("m"));
        assert_eq!(b.len(), 1, "peeking drains nothing");
        b.push_at("m", 2, t0);
        b.push_at("m", 3, t0);
        assert_eq!(b.peek_ready(t0, false), Some("m"), "full group is ready");
    }

    #[test]
    fn excluded_models_are_skipped_and_pop_model_drains_in_order() {
        let mut b = mk();
        let t0 = Instant::now();
        b.push_at("a", 1, t0);
        b.push_at("b", 2, t0 + Duration::from_millis(1));
        let later = t0 + Duration::from_millis(60);
        // "a" is the global pick; excluding it surfaces "b" instead of
        // head-of-line blocking the whole queue.
        assert_eq!(b.peek_ready(later, false), Some("a"));
        assert_eq!(b.peek_ready_excluding(later, false, &["a".to_string()]), Some("b"));
        assert!(b
            .peek_ready_excluding(later, false, &["a".to_string(), "b".to_string()])
            .is_none());
        let (model, batch) = b.pop_model("b").unwrap();
        assert_eq!(model, "b");
        assert_eq!(batch[0].payload, 2);
        assert_eq!(b.len(), 1);
        assert!(b.pop_model("b").is_none(), "drained model is gone");
    }

    #[test]
    fn selection_cost_stays_flat_on_a_deep_queue() {
        // Satellite bugfix regression: ready-group selection used to
        // rescan every queued request per round (O(queue)); with the
        // memoized per-model front it must stay O(live models) — deep
        // queues cost nothing extra once their key is known.
        let mut b: Batcher<u32> =
            Batcher::new(BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) });
        let t0 = Instant::now();
        for i in 0..10_000 {
            b.push_at("m", i, t0 + Duration::from_micros(i as u64));
        }
        assert_eq!(b.selection_scan_cost(), 0, "in-order pushes maintain the memo in O(1)");
        let now = t0 + Duration::from_millis(60);
        for _ in 0..1_000 {
            assert_eq!(b.peek_ready(now, false), Some("m"));
        }
        assert_eq!(b.selection_scan_cost(), 0, "admission rounds reuse the memo");
        // A pop invalidates exactly this model's key; the next round
        // recomputes it once and the rounds after that are free again.
        let (_, batch) = b.pop_model("m").unwrap();
        assert_eq!(batch.len(), 4);
        assert_eq!(b.peek_ready(now, false), Some("m"));
        let after_pop = b.selection_scan_cost();
        assert_eq!(after_pop, 9_996, "one recompute scans the queue once");
        for _ in 0..1_000 {
            assert_eq!(b.peek_ready(now, false), Some("m"));
        }
        assert_eq!(b.selection_scan_cost(), after_pop);
    }

    #[test]
    fn memoized_selection_stays_correct_across_push_pop_and_sweep() {
        // The memo must never change *what* is selected — only how fast.
        let mut b = mk(); // max_batch = 3
        let t0 = Instant::now();
        let later = t0 + Duration::from_millis(60);
        b.push_qos("a", 1, t0, Priority::Normal, None);
        b.push_qos("b", 2, t0 + Duration::from_millis(1), Priority::Normal, None);
        assert_eq!(b.peek_ready(later, false), Some("a"));
        // a High push re-fronts "b" past the older "a" (memo updated on push)
        b.push_qos("b", 3, t0 + Duration::from_millis(2), Priority::High, None);
        assert_eq!(b.peek_ready(later, false), Some("b"));
        // draining "b" invalidates its key; "a" wins again
        let (_, batch) = b.pop_model("b").unwrap();
        assert_eq!(batch.iter().map(|p| p.payload).collect::<Vec<_>>(), vec![3, 2]);
        assert_eq!(b.peek_ready(later, false), Some("a"));
        // a sweep that removes "a"'s only member clears the stale key
        let taken = b.take_where(|p| p.payload == 1);
        assert_eq!(taken.len(), 1);
        assert!(b.peek_ready(later, false).is_none());
        b.push_at("c", 9, t0);
        assert_eq!(b.peek_ready(later, false), Some("c"));
    }

    #[test]
    fn front_peeks_and_pop_model_n_drains_exactly_n() {
        let mut b = mk(); // max_batch = 3
        let t0 = Instant::now();
        b.push_at("m", 1, t0);
        b.push_at("m", 2, t0 + Duration::from_millis(1));
        assert_eq!(b.front("m").unwrap().payload, 1);
        assert!(b.front("ghost").is_none());
        let (model, batch) = b.pop_model_n("m", 1).unwrap();
        assert_eq!(model, "m");
        assert_eq!(batch.iter().map(|p| p.payload).collect::<Vec<_>>(), vec![1]);
        assert_eq!(b.len(), 1);
        assert_eq!(b.front("m").unwrap().payload, 2, "front advanced");
        let (_, batch) = b.pop_model_n("m", 5).unwrap();
        assert_eq!(batch.iter().map(|p| p.payload).collect::<Vec<_>>(), vec![2]);
        assert!(b.is_empty());
    }

    #[test]
    fn queued_models_lists_pending_groups() {
        let mut b = mk();
        b.push("x", 1);
        b.push("y", 2);
        assert_eq!(b.queued_models().collect::<Vec<_>>(), vec!["x", "y"]);
        let _ = b.pop_ready(Instant::now(), true);
        assert_eq!(b.queued_models().count(), 1);
    }
}
