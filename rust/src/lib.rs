//! # ADAPTOR-RS
//!
//! Reproduction of *"A Runtime-Adaptive Transformer Neural Network
//! Accelerator on FPGAs"* (Kabir et al., 2024) as a three-layer
//! rust + JAX + Pallas stack with AOT interchange via XLA/PJRT.
//!
//! The crate is organized the way the paper's system is:
//!
//! * [`model`] — transformer topology descriptions, presets and exact
//!   operation/byte accounting (the paper's workloads).
//! * [`accel`] — the FPGA fabric substitute: platform resource databases,
//!   the paper's analytical models (Eqs 8–39), a cycle-level simulator,
//!   post-route frequency and power models, tiling geometry, the
//!   runtime-adaptive configuration register file, the roofline model,
//!   and `accel::schedule` — the **TileProgram IR** that lowers the §3.9
//!   tile schedules (Algorithms 1–17) into a flat instruction stream once
//!   per topology (encoder, decoder **prefill**, and single-token
//!   **decode-step** flavors), plus `accel::schedule::opt` — the pass
//!   pipeline (transfer dedup, dispatch fusion, wave scheduling, slot
//!   compaction) the engine runs before caching a program,
//!   `accel::schedule::verify` — the static verifier (def-before-use
//!   dataflow, manifest shape/arity checks, intra-wave race detection,
//!   KV extern/export contracts) gating program-cache insertion and the
//!   `adaptor verify-programs` CI sweep — and `accel::decode` — the
//!   device-resident KV cache behind KV-cached autoregressive
//!   generation.
//! * [`runtime`] — PJRT execution of the AOT artifacts (`artifacts/*.hlo.txt`
//!   lowered once by `python/compile/aot.py`; Python is never on the
//!   request path), plus the `FabricBackend` trait a `TileProgram` replays
//!   against (PJRT for numerics; `accel::sim::cycle` for predicted
//!   cycles — one schedule, two substrates).
//! * [`coordinator`] — the host-software half (paper §3.11, §4,
//!   Algorithm 18): register programming, the tile-schedule engine that
//!   builds/caches a `TileProgram` per programmed topology and replays it
//!   per request — including `TileEngine::generate` (prefill + KV-cached
//!   decode steps) — a request router + QoS-ordered dynamic batcher, a
//!   multi-fabric serving pool, and metrics with a prefill/per-token
//!   timing split.
//! * [`serve`] — **Serving API v1** (`coordinator::api`): the single
//!   typed job surface over the pool — `Submission::{Encode,Generate}`
//!   through one `Server::submit` → `JobHandle` with blocking wait,
//!   polling, cancellation and streamed generation tokens; per-request
//!   `QoS { priority, deadline, opt_level }`; a typed `ServeError`
//!   taxonomy (no `anyhow` on the public boundary); live
//!   `Server::metrics()`.
//! * [`baselines`] — literature datapoints (Table 1 / Fig 10 comparators)
//!   and executable baselines (dense CPU oracle, non-adaptive accelerator).
//! * [`analysis`] — design-space sweeps and the table/figure renderers that
//!   regenerate every evaluation artifact of the paper.
//!
//! See DESIGN.md for the paper → substrate substitution table and the
//! serving-pool architecture.

pub mod accel;
pub mod analysis;
pub mod baselines;
pub mod coordinator;
pub mod model;
pub mod runtime;
pub mod util;

/// Serving API v1 — the public typed job surface (`coordinator::api`).
pub use coordinator::api as serve;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
