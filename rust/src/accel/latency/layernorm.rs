//! LayerNorm-unit latency (paper §5.5, Eqs 26–29).
//!
//! Four row passes (mean, variance, normalize, affine) plus the residual
//! connection (Eq 28), each a pipelined loop over `d_model` per row.

use super::depths::*;
use super::{pll, total};
use crate::model::TnnConfig;

/// Eq 26/27 — LN weight/bias loads (not tiled, loaded once).
pub fn load_weights(cfg: &TnnConfig) -> u64 {
    pll(PD_L, 1, cfg.d_model as u64)
}

/// Eq 28 — residual connection: `RC = [(d − 1) + PD_BA] · SL`.
pub fn residual(cfg: &TnnConfig) -> u64 {
    total(pll(PD_BA, 1, cfg.d_model as u64), cfg.seq_len as u64)
}

/// Eq 29 — the four LN passes.  Mean and variance passes carry II = 2
/// (accumulation dependency), normalize includes the divide and
/// float→fixed conversion (§5.5: 3 cc), affine is load+mul+add+store.
pub fn layer_norm(cfg: &TnnConfig) -> u64 {
    let d = cfg.d_model as u64;
    let sl = cfg.seq_len as u64;
    let mean = total(pll(LOAD + 1 + STORE, 2, d), sl);
    let variance = total(pll(LOAD + 2 + STORE, 2, d), sl);
    let normalize = total(pll(LOAD + 1 + 1 + STORE + DIV + 3, 1, d), sl);
    let affine = total(pll(LOAD + 2 + 1 + STORE, 1, d), sl);
    mean + variance + normalize + affine
}

/// Full LN-unit occupancy for one use (residual + 4 passes; weight loads
/// hidden behind the preceding module's compute, §5.5).
pub fn cycles(cfg: &TnnConfig) -> u64 {
    residual(cfg) + layer_norm(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ln_scales_linearly_with_rows_and_width() {
        let base = cycles(&TnnConfig::encoder(64, 768, 8, 1));
        let wide = cycles(&TnnConfig::encoder(64, 1536, 8, 1));
        let tall = cycles(&TnnConfig::encoder(128, 768, 8, 1));
        assert!((wide as f64 / base as f64 - 2.0).abs() < 0.05);
        assert!((tall as f64 / base as f64 - 2.0).abs() < 0.01);
    }

    #[test]
    fn mean_var_passes_dominate() {
        // II=2 on the two accumulation passes makes them ≥ half the unit.
        let cfg = TnnConfig::encoder(64, 768, 8, 1);
        let d = 768u64;
        let sl = 64u64;
        let mean_var = ((2 * (d - 1) + 3) + (2 * (d - 1) + 4)) * sl;
        assert!(mean_var > layer_norm(&cfg) / 2);
    }

    #[test]
    fn weight_load_is_one_shot() {
        let cfg = TnnConfig::encoder(64, 768, 8, 1);
        assert!(load_weights(&cfg) < residual(&cfg));
    }
}
