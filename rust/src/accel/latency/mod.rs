//! The paper's analytical latency model (§5, Eqs 9–39).
//!
//! Everything is built from the two generalized HLS timing laws:
//!
//! * Eq 9:  `PLL = PD + II·(TC − 1)`  (pipelined-loop latency)
//! * Eq 10: `TL  = PLL · outer_trip_count`
//!
//! Pipeline-depth constants are taken from §5.2 where stated (AXI setup
//! 7 cc, addr 1, load 1, store 1, float→fixed 3, exp 4, div 14) and
//! calibrated against Table 2 where the paper leaves them implicit; the
//! calibration (documented per constant below) reproduces every latency
//! cell of Table 2 within ~4 %:
//!
//! * `PD_MHA = TS_MHA + 3` — the unrolled tile-width accumulation chain
//!   (nails SA = 0.052/0.103/0.042/0.11 ms across all four rows);
//! * `II_FFN = 2` — dual-port BRAM conflict on the FFN weight panel
//!   (nails FFN1 = 0.082/0.165/0.055/0.18 ms);
//! * `PD_L = 16` — §5.2's 13 cc plus 3 AXI beats
//!   (nails LWA = 0.037/0.037/0.025/0.1 ms, with the trailing `×SL` of
//!   Eq 13 read as `×TS_MHA`, the only reading consistent with LWA being
//!   independent of SL in Table 2 rows 1–2).

pub mod attention;
pub mod ffn;
pub mod layernorm;

use super::tiling::TileConfig;
use crate::model::TnnConfig;

/// §5.2 and calibrated pipeline-depth constants.
pub mod depths {
    /// Load-unit pipeline depth (AXI setup 7 + addr 1 + load 1 + store 1 +
    /// float→fixed 3 = 13 per §5.2, +3 AXI beats calibrated on Table 2).
    pub const PD_L: u64 = 16;
    /// Bias-add pipeline: load + add + store.
    pub const PD_BA: u64 = 3;
    /// MHA MAC chain beyond the tile width (load + 2·mul + add + store−2).
    pub const PD_MHA_EXTRA: u64 = 3;
    /// FFN initiation interval (weight-panel port conflict).
    pub const II_FFN: u64 = 2;
    /// FFN pipeline depth.
    pub const PD_FFN: u64 = 2;
    /// Softmax exponential (§5.2: 4 cc).
    pub const EXP: u64 = 4;
    /// Softmax divide (§5.2: 14 cc).
    pub const DIV: u64 = 14;
    /// Generic load/store within a module.
    pub const LOAD: u64 = 1;
    pub const STORE: u64 = 1;
}

/// Eq 9: pipelined-loop latency.
#[inline]
pub fn pll(pipeline_depth: u64, ii: u64, trip_count: u64) -> u64 {
    pipeline_depth + ii * trip_count.saturating_sub(1)
}

/// Eq 10: nested total.
#[inline]
pub fn total(pll_cycles: u64, outer_trip_count: u64) -> u64 {
    pll_cycles * outer_trip_count
}

/// A module's load and compute cycle counts; ADAPTOR overlaps loading with
/// computation (§6: "data loading time is overlapped with computation"),
/// so the occupied time is the max.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ModuleCycles {
    pub load: u64,
    pub compute: u64,
}

impl ModuleCycles {
    pub fn occupied(&self) -> u64 {
        self.load.max(self.compute)
    }
}

/// Cycle breakdown for one encoder layer.
#[derive(Debug, Clone, Copy, Default)]
pub struct LayerCycles {
    /// QKV_PM across all tiles (per head, heads in parallel), load+compute.
    pub qkv: ModuleCycles,
    /// Bias add on Q, K, V (Eq 16).
    pub bias_qkv: u64,
    /// QK_PM score (Eq 17).
    pub score: u64,
    /// Softmax (Eq 19).
    pub softmax: u64,
    /// SV_PM (Eq 18).
    pub sv: u64,
    /// FFN1 across its (d/TS)² visits.
    pub ffn1: ModuleCycles,
    pub bias_ffn1: u64,
    /// First LayerNorm (incl. residual, Eq 29 + 28).
    pub ln1: u64,
    /// FFN2 across its visits.
    pub ffn2: ModuleCycles,
    pub bias_ffn2: u64,
    /// FFN3 across its visits.
    pub ffn3: ModuleCycles,
    pub bias_ffn3: u64,
    pub ln2: u64,
}

impl LayerCycles {
    /// Total occupied cycles for the layer, module chain serialized,
    /// loads overlapped within each module.
    pub fn total(&self) -> u64 {
        self.qkv.occupied()
            + self.bias_qkv
            + self.score
            + self.softmax
            + self.sv
            + self.ffn1.occupied()
            + self.bias_ffn1
            + self.ln1
            + self.ffn2.occupied()
            + self.bias_ffn2
            + self.ffn3.occupied()
            + self.bias_ffn3
            + self.ln2
    }

    /// Attention sub-total (MHA fraction of §1: 38–64 %).
    pub fn attention(&self) -> u64 {
        self.qkv.occupied() + self.bias_qkv + self.score + self.softmax + self.sv
    }
}

/// Full-model latency summary.
#[derive(Debug, Clone)]
pub struct ModelLatency {
    /// One-time input load (Eq 11; the input BRAM is reused between layers).
    pub load_inputs: u64,
    pub per_layer: LayerCycles,
    pub layers: usize,
    pub total_cycles: u64,
}

impl ModelLatency {
    pub fn ms_at(&self, freq_mhz: f64) -> f64 {
        self.total_cycles as f64 / (freq_mhz * 1e3)
    }

    pub fn gops_at(&self, cfg: &TnnConfig, freq_mhz: f64) -> f64 {
        let ops = crate::model::ops::total_ops(cfg) as f64;
        ops / (self.total_cycles as f64 / (freq_mhz * 1e6)) / 1e9
    }
}

/// Analytical latency for a full forward pass of `cfg` on the fabric
/// `tiles` (decoder layers charged as 1.6× an encoder layer: the extra
/// cross-attention block).
pub fn model_latency(cfg: &TnnConfig, tiles: &TileConfig) -> ModelLatency {
    let per_layer = layer_cycles(cfg, tiles);
    let li = attention::load_inputs(cfg);
    let enc = per_layer.total() * cfg.enc_layers as u64;
    let dec = (per_layer.total() as f64 * 1.6) as u64 * cfg.dec_layers as u64;
    ModelLatency {
        load_inputs: li,
        per_layer,
        layers: cfg.layers(),
        total_cycles: li + enc + dec,
    }
}

/// Cycle breakdown for one encoder layer.
pub fn layer_cycles(cfg: &TnnConfig, tiles: &TileConfig) -> LayerCycles {
    let a = attention::cycles(cfg, tiles);
    let f = ffn::cycles(cfg, tiles);
    let ln = layernorm::cycles(cfg);
    LayerCycles {
        qkv: a.qkv,
        bias_qkv: a.bias,
        score: a.score,
        softmax: a.softmax,
        sv: a.sv,
        ffn1: f.ffn1,
        bias_ffn1: f.bias_ffn1,
        ln1: ln,
        ffn2: f.ffn2,
        bias_ffn2: f.bias_ffn2,
        ffn3: f.ffn3,
        bias_ffn3: f.bias_ffn3,
        ln2: ln,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::presets;

    #[test]
    fn pll_matches_eq9() {
        assert_eq!(pll(5, 1, 10), 14);
        assert_eq!(pll(3, 2, 1), 3);
        assert_eq!(pll(7, 1, 0), 7); // degenerate trip count saturates
    }

    #[test]
    fn layer_total_is_sum_of_parts() {
        let cfg = presets::paper_default();
        let t = TileConfig::paper_optimum();
        let l = layer_cycles(&cfg, &t);
        assert!(l.total() >= l.attention());
        assert!(l.total() > 0);
    }

    #[test]
    fn model_scales_with_layers() {
        let t = TileConfig::paper_optimum();
        let c1 = TnnConfig::encoder(64, 768, 8, 1);
        let c12 = TnnConfig::encoder(64, 768, 8, 12);
        let m1 = model_latency(&c1, &t);
        let m12 = model_latency(&c12, &t);
        let per1 = m1.total_cycles - m1.load_inputs;
        let per12 = m12.total_cycles - m12.load_inputs;
        assert_eq!(per12, 12 * per1);
    }

    #[test]
    fn decoder_layers_cost_more() {
        let t = TileConfig::paper_optimum();
        let enc = model_latency(&TnnConfig::encoder(64, 512, 8, 2), &t);
        let mut cfg = TnnConfig::encoder(64, 512, 8, 0);
        cfg.dec_layers = 2;
        let dec = model_latency(&cfg, &t);
        assert!(dec.total_cycles > enc.total_cycles);
    }

    #[test]
    fn bert_gops_in_paper_ballpark() {
        // Table 1 Network #3: ADAPTOR reaches 40 GOPS on BERT @ 200 MHz.
        let cfg = presets::bert_base(64);
        let t = TileConfig::paper_optimum();
        let m = model_latency(&cfg, &t);
        let gops = m.gops_at(&cfg, 200.0);
        assert!(gops > 15.0 && gops < 60.0, "gops = {gops}");
    }

    #[test]
    fn attention_fraction_grows_with_sequence_length() {
        // §1: the MHA share grows with token count (38–64% on the paper's
        // compute-bound testbed; lower here because this fabric is
        // weight-stream-bound — see EXPERIMENTS.md §Deviations).
        let t = TileConfig::paper_optimum();
        let frac = |sl: usize| {
            let l = layer_cycles(&presets::bert_base(sl), &t);
            l.attention() as f64 / l.total() as f64
        };
        assert!(frac(512) > 2.0 * frac(64), "{} vs {}", frac(512), frac(64));
        assert!(frac(512) < 0.75);
    }
}
