//! Attention-module latency (paper §5.2, Eqs 11–19).
//!
//! Heads run in parallel (one QKV/QK/SV module set per head, Fig 2), so all
//! per-head quantities below are wall-clock for the whole MHA block.

use super::depths::*;
use super::{pll, total, ModuleCycles};
use crate::accel::tiling::TileConfig;
use crate::model::TnnConfig;

/// Eq 11 — one-time load of all inputs into the input BRAM:
/// `LI = [(d_model − 1)·1 + PD_L] · SL`.
pub fn load_inputs(cfg: &TnnConfig) -> u64 {
    total(pll(PD_L, 1, cfg.d_model as u64), cfg.seq_len as u64)
}

/// Eq 14 — per-tile load of the head's input panel:
/// `LIA = [(d/T_mha − 1)·1 + PD_L] · SL`.
pub fn load_inputs_head_tile(cfg: &TnnConfig, tiles: &TileConfig) -> u64 {
    let width = (cfg.d_model / tiles.tiles_mha(cfg.d_model)).max(1) as u64;
    total(pll(PD_L, 1, width), cfg.seq_len as u64)
}

/// Eq 13 — per-tile load of the head's weight panels:
/// `LWA = [(d/h − 1)·1 + PD_L] · TS_mha` (trailing factor read as the tile
/// width; see module docs — this is the only reading consistent with LWA
/// being SL-independent across Table 2 rows 1–2).
pub fn load_weights_head_tile(cfg: &TnnConfig, tiles: &TileConfig) -> u64 {
    total(pll(PD_L, 1, cfg.dk() as u64), tiles.ts_mha as u64)
}

/// Eq 12 — bias load for one head: `LBA = (d/h − 1)·1 + PD_L`.
pub fn load_biases_head(cfg: &TnnConfig) -> u64 {
    pll(PD_L, 1, cfg.dk() as u64)
}

/// Eq 15 — QKV compute for ONE tile visit:
/// `SA = [(d/h − 1)·1 + PD_MHA] · SL` with `PD_MHA = TS_mha + 3`
/// (the unrolled accumulation chain across the tile width).
pub fn qkv_tile(cfg: &TnnConfig, tiles: &TileConfig) -> u64 {
    let pd_mha = tiles.ts_mha as u64 + PD_MHA_EXTRA;
    total(pll(pd_mha, 1, cfg.dk() as u64), cfg.seq_len as u64)
}

/// Eq 16 — bias add on Q/K/V: `BA = [(d/h − 1)·1 + PD_BA] · SL`.
pub fn bias_add(cfg: &TnnConfig) -> u64 {
    total(pll(PD_BA, 1, cfg.dk() as u64), cfg.seq_len as u64)
}

/// Eq 17 — score: `S = [(SL − 1)·1 + PD_S] · SL`, `PD_S = d/h`.
pub fn score(cfg: &TnnConfig) -> u64 {
    total(pll(cfg.dk() as u64, 1, cfg.seq_len as u64), cfg.seq_len as u64)
}

/// Eq 19 — softmax: three SL×SL passes (max, exp+sum, normalize) with the
/// §5.2 exponentiation (4 cc) and division (14 cc) depths.
pub fn softmax(cfg: &TnnConfig) -> u64 {
    let sl = cfg.seq_len as u64;
    let max_pass = total(pll(LOAD + STORE, 1, sl), sl);
    let exp_pass = total(pll(EXP + LOAD + STORE, 1, sl), sl);
    let div_pass = total(pll(DIV + LOAD + STORE, 1, sl), sl);
    max_pass + exp_pass + div_pass
}

/// Eq 18 — SV: `SV = [(d/h − 1)·1 + PD_SV] · SL`, `PD_SV = SL`.
pub fn sv(cfg: &TnnConfig) -> u64 {
    total(pll(cfg.seq_len as u64, 1, cfg.dk() as u64), cfg.seq_len as u64)
}

/// Aggregated attention block cycles.
#[derive(Debug, Clone, Copy, Default)]
pub struct AttentionCycles {
    pub qkv: ModuleCycles,
    pub bias: u64,
    pub score: u64,
    pub softmax: u64,
    pub sv: u64,
}

impl AttentionCycles {
    pub fn occupied(&self) -> u64 {
        self.qkv.occupied() + self.bias + self.score + self.softmax + self.sv
    }
}

/// Whole MHA block for one layer: QKV iterates over the tile schedule with
/// per-tile load/compute overlap (double-buffered, §3.6.1: biases stream
/// while the PEs compute); score→softmax→SV chain follows.
pub fn cycles(cfg: &TnnConfig, tiles: &TileConfig) -> AttentionCycles {
    let visits = tiles.mha_tile_visits(cfg) as u64;
    let per_tile_load = load_inputs_head_tile(cfg, tiles) + load_weights_head_tile(cfg, tiles);
    let per_tile_compute = qkv_tile(cfg, tiles);
    // Double-buffered pipeline: first load exposed, last compute exposed,
    // steady state runs at max(load, compute) per visit.
    let qkv = ModuleCycles {
        load: per_tile_load * visits,
        compute: per_tile_load
            + per_tile_compute
            + per_tile_compute.max(per_tile_load) * visits.saturating_sub(1),
    };
    AttentionCycles {
        qkv,
        bias: bias_add(cfg),
        score: score(cfg),
        softmax: softmax(cfg),
        sv: sv(cfg),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Table 2 rows (SL, d_model, h, TS_MHA, TS_FFN, freq, SA_ms, LWA_ms).
    const TABLE2: &[(usize, usize, usize, usize, usize, f64, f64, f64)] = &[
        (64, 768, 8, 64, 128, 200.0, 0.052, 0.037),
        (128, 768, 8, 64, 128, 200.0, 0.103, 0.037),
        (64, 512, 8, 64, 128, 200.0, 0.042, 0.025),
        (64, 768, 8, 128, 192, 135.0, 0.11, 0.10),
    ];

    fn ms(cc: u64, f: f64) -> f64 {
        cc as f64 / (f * 1e3)
    }

    #[test]
    fn sa_matches_table2_within_5pct() {
        for &(sl, d, h, tm, tf, f, sa_ms, _) in TABLE2 {
            let cfg = TnnConfig::encoder(sl, d, h, 12);
            let t = TileConfig::new(tm, tf);
            let got = ms(qkv_tile(&cfg, &t), f);
            let err = (got - sa_ms).abs() / sa_ms;
            assert!(err < 0.05, "SA {got:.4} vs {sa_ms} (sl={sl} d={d} ts={tm}) err={err:.3}");
        }
    }

    #[test]
    fn lwa_matches_table2_within_6pct() {
        for &(sl, d, h, tm, tf, f, _, lwa_ms) in TABLE2 {
            let cfg = TnnConfig::encoder(sl, d, h, 12);
            let t = TileConfig::new(tm, tf);
            let got = ms(load_weights_head_tile(&cfg, &t), f);
            let err = (got - lwa_ms).abs() / lwa_ms;
            assert!(err < 0.06, "LWA {got:.4} vs {lwa_ms} (sl={sl} d={d}) err={err:.3}");
        }
    }

    #[test]
    fn softmax_includes_exp_and_div_depths() {
        let cfg = TnnConfig::encoder(64, 768, 8, 1);
        let sm = softmax(&cfg);
        // three passes, each ≥ SL² cycles
        assert!(sm >= 3 * 64 * 64);
        assert!(sm < 4 * 64 * 64 + 3 * 64 * 20);
    }

    #[test]
    fn score_and_sv_scale_quadratically_with_sl() {
        let t = TileConfig::paper_optimum();
        let _ = t;
        let c64 = TnnConfig::encoder(64, 768, 8, 1);
        let c128 = TnnConfig::encoder(128, 768, 8, 1);
        assert!(score(&c128) as f64 > 2.5 * score(&c64) as f64);
        assert!(sv(&c128) > 2 * sv(&c64)); // (dk-major outer) superlinear
    }

    #[test]
    fn qkv_load_hidden_behind_compute_when_compute_bound() {
        let cfg = TnnConfig::encoder(64, 768, 8, 1);
        let t = TileConfig::paper_optimum();
        let a = cycles(&cfg, &t);
        // compute per tile (10.4k cc) exceeds load per tile (~8.1k cc), so
        // occupied ≈ first-load + visits·compute.
        assert!(a.qkv.occupied() < a.qkv.load + a.qkv.compute);
    }
}
