//! Feed-forward-network latency (paper §5.3, §5.6, §5.7; Eqs 20–24, 30–39).
//!
//! FFN weight panels are 2-D tiled (Fig 4b): FFN1 runs `(d/TS)²` visits,
//! FFN2/FFN3 `(hidden/d)·(d/TS)²` visits (§3.9).  Within a visit the
//! pipelined middle loop runs at `II_FFN = 2` (weight-panel port conflict —
//! the calibration that reproduces Table 2's FFN1 column, see latency/mod).

use super::depths::*;
use super::{pll, total, ModuleCycles};
use crate::accel::tiling::TileConfig;
use crate::model::TnnConfig;

/// Eq 20 — FFN1 input-panel load per visit.
pub fn load_inputs_ffn1(cfg: &TnnConfig, tiles: &TileConfig) -> u64 {
    let w = (cfg.d_model / tiles.tiles_ffn(cfg.d_model)).max(1) as u64;
    total(pll(PD_L, 1, w), cfg.seq_len as u64)
}

/// Eq 21 — FFN1 weight-panel load per visit:
/// `[(d/T_ffn − 1) + PD_L] · d/T_ffn`.
pub fn load_weights_ffn1(cfg: &TnnConfig, tiles: &TileConfig) -> u64 {
    let w = (cfg.d_model / tiles.tiles_ffn(cfg.d_model)).max(1) as u64;
    total(pll(PD_L, 1, w), w)
}

/// Eq 22/32/37 — bias loads: `(d − 1) + PD_L` (hidden-width for FFN2).
pub fn load_biases(width: usize) -> u64 {
    pll(PD_L, 1, width as u64)
}

/// Eq 24 — FFN1 compute per visit:
/// `[(d/T_ffn − 1)·II_FFN + PD_FFN] · SL`.
pub fn ffn1_visit(cfg: &TnnConfig, tiles: &TileConfig) -> u64 {
    let w = (cfg.d_model / tiles.tiles_ffn(cfg.d_model)).max(1) as u64;
    total(pll(PD_FFN, II_FFN, w), cfg.seq_len as u64)
}

/// Eq 33 — FFN2 compute per visit: output width `hidden/T_ffn`.
pub fn ffn2_visit(cfg: &TnnConfig, tiles: &TileConfig) -> u64 {
    let w = (cfg.hidden / tiles.tiles_ffn(cfg.d_model)).max(1) as u64;
    total(pll(PD_FFN, II_FFN, w), cfg.seq_len as u64)
}

/// Eq 38/Alg 10 — FFN3 compute per visit: output width `d/T_ffn`, reduction
/// across `hidden/T_ffn` handled by the unrolled inner loop.
pub fn ffn3_visit(cfg: &TnnConfig, tiles: &TileConfig) -> u64 {
    let w = (cfg.d_model / tiles.tiles_ffn(cfg.d_model)).max(1) as u64;
    total(pll(PD_FFN, II_FFN, w), cfg.seq_len as u64)
}

/// Eq 30/35 — FFN2/FFN3 input loads per visit.
pub fn load_inputs_ffn23(cfg: &TnnConfig, tiles: &TileConfig, hidden_side: bool) -> u64 {
    let dim = if hidden_side { cfg.hidden } else { cfg.d_model };
    let w = (dim / tiles.tiles_ffn(cfg.d_model)).max(1) as u64;
    total(pll(PD_L, 1, w), cfg.seq_len as u64)
}

/// Eq 31/36 — FFN2/FFN3 weight-panel loads per visit (panel is
/// `d/T × hidden/T` elements, streamed at 1/cc).
pub fn load_weights_ffn23(cfg: &TnnConfig, tiles: &TileConfig) -> u64 {
    let t = tiles.tiles_ffn(cfg.d_model) as u64;
    let rows = (cfg.d_model as u64 / t).max(1);
    let cols = (cfg.hidden as u64 / t).max(1);
    total(pll(PD_L, 1, cols), rows)
}

/// Eq 23/34/39 — bias add over the output row: `[(w − 1) + PD_BA]·SL`.
pub fn bias_add(cfg: &TnnConfig, width: usize) -> u64 {
    total(pll(PD_BA, 1, width as u64), cfg.seq_len as u64)
}

#[derive(Debug, Clone, Copy, Default)]
pub struct FfnCycles {
    pub ffn1: ModuleCycles,
    pub bias_ffn1: u64,
    pub ffn2: ModuleCycles,
    pub bias_ffn2: u64,
    pub ffn3: ModuleCycles,
    pub bias_ffn3: u64,
}

/// Whole FFN chain for one layer, visits iterated with double-buffered
/// load/compute overlap per module (first load exposed, rest hidden under
/// `max(load, compute)`).
pub fn cycles(cfg: &TnnConfig, tiles: &TileConfig) -> FfnCycles {
    let v1 = tiles.ffn1_visits(cfg) as u64;
    let v23 = tiles.ffn23_visits(cfg) as u64;

    // Same double-buffered composition as the attention block: first load
    // and last compute exposed, steady state at max(load, compute)/visit.
    let pipe = |l: u64, c: u64, v: u64| ModuleCycles {
        load: l * v,
        compute: l + c + c.max(l) * v.saturating_sub(1),
    };
    let l1 = load_inputs_ffn1(cfg, tiles) + load_weights_ffn1(cfg, tiles);
    let ffn1 = pipe(l1, ffn1_visit(cfg, tiles), v1);

    let l2 = load_inputs_ffn23(cfg, tiles, false) + load_weights_ffn23(cfg, tiles);
    let ffn2 = pipe(l2, ffn2_visit(cfg, tiles), v23);

    let l3 = load_inputs_ffn23(cfg, tiles, true) + load_weights_ffn23(cfg, tiles);
    let ffn3 = pipe(l3, ffn3_visit(cfg, tiles), v23);

    FfnCycles {
        ffn1,
        bias_ffn1: bias_add(cfg, cfg.d_model),
        ffn2,
        bias_ffn2: bias_add(cfg, cfg.hidden),
        ffn3,
        bias_ffn3: bias_add(cfg, cfg.d_model),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Table 2 FFN1 column: (SL, d, TS_FFN via (TS_MHA,TS_FFN), freq, ms).
    const TABLE2_FFN1: &[(usize, usize, usize, usize, f64, f64)] = &[
        (64, 768, 64, 128, 200.0, 0.082),
        (128, 768, 64, 128, 200.0, 0.165),
        (64, 512, 64, 128, 200.0, 0.055),
        (64, 768, 128, 192, 135.0, 0.18),
    ];

    #[test]
    fn ffn1_visit_matches_table2_within_6pct() {
        for &(sl, d, tm, tf, f, want) in TABLE2_FFN1 {
            let cfg = TnnConfig::encoder(sl, d, 8, 12);
            // the fabric is synthesized for d=768 maxima in every Table 2 row
            let t = TileConfig::for_fabric(tm, tf, 768);
            let got = ffn1_visit(&cfg, &t) as f64 / (f * 1e3);
            let err = (got - want).abs() / want;
            assert!(err < 0.06, "FFN {got:.4} vs {want} (sl={sl} d={d}) err={err:.2}");
        }
    }

    #[test]
    fn visit_counts_multiply_total() {
        let cfg = TnnConfig::encoder(64, 768, 8, 1);
        let t = TileConfig::paper_optimum();
        let f = cycles(&cfg, &t);
        // FFN2 moves 4·d² weights at ~1/cc minimum
        let w2 = 4 * 768 * 768;
        assert!(f.ffn2.load as f64 > 0.9 * w2 as f64, "{} vs {}", f.ffn2.load, w2);
    }

    #[test]
    fn ffn_is_load_bound_for_bert() {
        // the paper's BERT GOPS (≈40) implies weight streaming dominates.
        let cfg = TnnConfig::encoder(64, 768, 8, 1);
        let t = TileConfig::paper_optimum();
        let f = cycles(&cfg, &t);
        assert!(f.ffn2.load >= f.ffn2.compute / 2);
    }

    #[test]
    fn bigger_ffn_tiles_reduce_fill_overhead() {
        let cfg = TnnConfig::encoder(64, 768, 8, 1);
        let small = cycles(&cfg, &TileConfig::new(64, 96));
        let big = cycles(&cfg, &TileConfig::new(64, 384));
        assert!(big.ffn2.occupied() < small.ffn2.occupied());
    }

    #[test]
    fn bias_widths() {
        let cfg = TnnConfig::encoder(64, 768, 8, 1);
        assert!(bias_add(&cfg, cfg.hidden) > bias_add(&cfg, cfg.d_model));
    }
}
