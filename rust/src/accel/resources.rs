//! Resource-utilization models (paper §5.1, §5.4).
//!
//! Two models per resource, mirroring Table 2's two columns:
//!
//! * **analytical** — Eq 8 (DSPs) and Eq 25 (BRAMs) implemented verbatim.
//!   With the paper's larger-tile configuration (TS_MHA=128, TS_FFN=192,
//!   h=8) Eq 8 reproduces the paper's 6272 DSPs exactly; with the default
//!   configuration it yields 4352 where Table 2 prints 3784 — a
//!   self-inconsistency of the paper we document rather than hide
//!   (DESIGN.md §5).
//! * **structural** — what synthesis actually emits: bias/LN datapaths and
//!   the QK division retarget to LUTs (§3.6.2 "the division ... is executed
//!   ... using LUTs"), small array partitions become LUTRAM instead of
//!   BRAM ("LUTRAMs were used more than BRAMs to maintain high frequency",
//!   §6), and HLS packs imperfectly.  Calibrated to Table 2's experimental
//!   column (3612 DSPs / 2246 BRAM18k) and Table 1's 391 k LUTs.

use super::platform::Platform;
use super::tiling::TileConfig;
use crate::model::quant::BitWidth;
use crate::model::TnnConfig;

/// BRAM18k geometry used by Eq 25 ("BRAM_w = 36 and BRAM_d = 1024 for most
/// FPGAs").
pub const BRAM_W: f64 = 36.0;
pub const BRAM_D: f64 = 1024.0;

/// Eq 8, verbatim:
/// `3·h·d/T_mha + h·(d/h + SL) + 6·d/T_ffn + d`.
pub fn dsps_eq8(cfg: &TnnConfig, tiles: &TileConfig) -> f64 {
    let d = cfg.d_model as f64;
    let h = cfg.heads as f64;
    let sl = cfg.seq_len as f64;
    let t_mha = tiles.tiles_mha(cfg.d_model) as f64;
    let t_ffn = tiles.tiles_ffn(cfg.d_model) as f64;
    3.0 * h * d / t_mha + h * (d / h + sl) + 6.0 * d / t_ffn + d
}

/// Structural (post-synthesis) DSP count: Eq 8 minus the `d_model` term —
/// the element-wise bias/LN lane that synthesis maps onto LUT fabric —
/// plus a small constant for AXI/DMA address arithmetic.  Reproduces
/// Table 2's experimental 3612 for the default build.
pub fn dsps_structural(cfg: &TnnConfig, tiles: &TileConfig) -> u64 {
    const AXI_DSP: f64 = 28.0;
    (dsps_eq8(cfg, tiles) - cfg.d_model as f64 + AXI_DSP).round().max(0.0) as u64
}

/// Eq 25, verbatim (including the doubled FFN weight term the paper
/// prints).  `Bit_w` follows the float-side buffer width (the AXI loaders
/// convert float->fixed on the way in, §5.2), i.e. 32 by default —
/// reproduces Table 2's 2375 within 4 %.
pub fn brams_eq25(cfg: &TnnConfig, tiles: &TileConfig, bit_w: f64) -> f64 {
    let d = cfg.d_model as f64;
    let h = cfg.heads as f64;
    let sl = cfg.seq_len as f64;
    let t_mha = tiles.tiles_mha(cfg.d_model) as f64;
    let t_ffn = tiles.tiles_ffn(cfg.d_model) as f64;
    let u = bit_w / (BRAM_W * BRAM_D); // BRAM18k units per element-bit
    let t1 = 10.0 * sl * d * u;
    let t2 = sl * (0.5f64).max(sl * u);
    let t3 = (0.5f64).max(sl * d * u);
    let t4 = h * sl * d * u;
    let t5 = (0.5f64).max(d * u);
    let t6 = sl * t_mha * u;
    let t7a = 8.0 * d * d * u / t_ffn;
    let t7b = 8.0 * d * d * u / t_ffn;
    let t8 = 3.0 * d * d * u / t_ffn;
    t1 + t2 + t3 + t4 + t5 + t6 + t7a + t7b + t8
}

/// LUTRAM-eligibility threshold: HLS maps array partitions smaller than
/// this (in bits) to distributed RAM instead of BRAM.
const LUTRAM_THRESHOLD_BITS: f64 = 4096.0;
/// HLS BRAM packing efficiency (two logical arrays often share a true
/// dual-port BRAM18 pair).
const BRAM_PACKING: f64 = 0.80;

/// Structural BRAM model: the Eq 25 array inventory with (a) per-group
/// LUTRAM substitution for small partitions and (b) packing efficiency.
/// Returns `(bram18k, lutram_bits)`.
pub fn brams_structural(cfg: &TnnConfig, tiles: &TileConfig, bit_w: f64) -> (u64, u64) {
    let d = cfg.d_model as f64;
    let h = cfg.heads as f64;
    let sl = cfg.seq_len as f64;
    let t_mha = tiles.tiles_mha(cfg.d_model) as f64;
    let t_ffn = tiles.tiles_ffn(cfg.d_model) as f64;
    let ts_ffn = tiles.ts_ffn as f64;

    // (total_bits, partitions) per array group, from §3.1–3.8.
    let groups: Vec<(f64, f64)> = vec![
        // 10 SL×d intermediate/output buffers, partitioned per head-ish lane
        (10.0 * sl * d * bit_w, 10.0 * 8.0),
        // per-head score matrices S (SL×SL), partitioned by SL (SV_PM unroll)
        (h * sl * sl * bit_w, h * sl),
        // input BRAM SL×d partitioned across heads
        (sl * d * bit_w, h),
        // per-head Q,K,V buffers (h · SL · d/h each ×3 ≈ h·SL·d total)
        (h * sl * d * bit_w, h * 24.0),
        // LN weight/bias buffers
        (2.0 * d * bit_w, 2.0),
        // per-head x tile buffers SL×TS_MHA, double-buffered
        (2.0 * sl * t_mha * tiles.ts_mha as f64 * bit_w, h * t_mha),
        // FFN weight panels (double-buffered ping-pong): 2·(8+8+3)/19 → the
        // eq25 coefficients 8,8,3 over t_ffn, partitioned by TS_FFN columns
        (8.0 * d * d * bit_w / t_ffn, ts_ffn),
        (8.0 * d * d * bit_w / t_ffn, ts_ffn),
        (3.0 * d * d * bit_w / t_ffn, ts_ffn),
    ];

    let mut bram = 0.0;
    let mut lutram_bits = 0.0;
    for (bits, parts) in groups {
        let parts = parts.max(1.0);
        let per_part = bits / parts;
        if per_part < LUTRAM_THRESHOLD_BITS {
            lutram_bits += bits;
        } else {
            bram += parts * (per_part / (BRAM_W * BRAM_D)).ceil();
        }
    }
    ((bram * BRAM_PACKING).round() as u64, lutram_bits as u64)
}

/// Structural LUT model, calibrated against Table 1 (391 k at the default
/// build).  Components follow §3: PE glue per DSP, the QK division (LUTs),
/// softmax exp/div units, LN datapath, bias/ReLU lanes (the Eq 8 `d` term
/// retargeted to fabric), AXI/control, and LUTRAM storage (64 bits/LUT).
pub fn luts_structural(cfg: &TnnConfig, tiles: &TileConfig, bit_w: f64) -> u64 {
    const LUT_PER_DSP_PE: f64 = 52.0;
    const LUT_PER_DIV: f64 = 900.0; // 32-bit pipelined divider
    const LUT_PER_EXP: f64 = 2200.0;
    const LUT_LN_UNIT: f64 = 11_000.0;
    const LUT_BIAS_LANE: f64 = 36.0; // per element-lane of the d_model bias/LN path
    const LUT_AXI_CTRL: f64 = 58_000.0;
    const LUTRAM_BITS_PER_LUT: f64 = 64.0;

    let dsps = dsps_structural(cfg, tiles) as f64;
    let (_, lutram_bits) = brams_structural(cfg, tiles, bit_w);
    let h = cfg.heads as f64;
    let sl = cfg.seq_len as f64;
    let d = cfg.d_model as f64;

    let pe_glue = LUT_PER_DSP_PE * dsps;
    let dividers = h * sl.min(64.0) * LUT_PER_DIV / 8.0; // QK_PM divisions, shared 8:1
    let softmax = h * LUT_PER_EXP;
    let ln = 2.0 * LUT_LN_UNIT;
    let bias = d * LUT_BIAS_LANE;
    let lutram = lutram_bits as f64 / LUTRAM_BITS_PER_LUT;
    (pe_glue + dividers + softmax + ln + bias + LUT_AXI_CTRL + lutram).round() as u64
}

/// Device weight-memory envelope for `p`, in bytes: the capacity budget
/// the residency manager ([`crate::coordinator::residency`]) treats as a
/// cache of model weight stacks.
///
/// URAM (when the part has it — U55C's 960 blocks of 288 Kib) is the
/// natural weight store; of the BRAM18k pool, half is budgeted for
/// weights, the other half staying with activations, KV panels and the
/// AXI/stream FIFOs the structural model above accounts for.
pub fn weight_memory_bytes(p: &Platform) -> u64 {
    const URAM_BITS: u64 = 288 * 1024;
    p.uram_total * URAM_BITS / 8 + p.bram_bytes() / 2
}

/// Combined estimate for one synthesis.
#[derive(Debug, Clone, Copy)]
pub struct ResourceEstimate {
    /// Eq 8, verbatim.
    pub dsp_analytical: f64,
    /// Post-synthesis DSP count (Table 2 "experimental").
    pub dsp: u64,
    /// Eq 25, verbatim.
    pub bram18k_analytical: f64,
    /// Post-synthesis BRAM18k count.
    pub bram18k: u64,
    /// Bits of distributed LUTRAM storage.
    pub lutram_bits: u64,
    /// Post-synthesis logic LUTs (incl. LUTRAM).
    pub lut: u64,
    /// Flip-flops (≈ 1.35 per LUT in this design family).
    pub ff: u64,
    /// Utilization fractions against the target platform.
    pub dsp_util: f64,
    pub lut_util: f64,
    pub bram_util: f64,
}

impl ResourceEstimate {
    pub fn check_fit(&self, p: &Platform) -> std::result::Result<(), String> {
        if self.dsp > p.dsp_total {
            return Err(format!("DSPs {} exceed {} on {}", self.dsp, p.dsp_total, p.name));
        }
        if self.lut > p.lut_total {
            return Err(format!("LUTs {} exceed {} on {}", self.lut, p.lut_total, p.name));
        }
        if self.bram18k > p.bram18k_total {
            return Err(format!(
                "BRAM18k {} exceed {} on {}",
                self.bram18k, p.bram18k_total, p.name
            ));
        }
        Ok(())
    }
}

/// Full resource estimate for `cfg` under `tiles` on `platform`.
pub fn estimate(
    cfg: &TnnConfig,
    tiles: &TileConfig,
    bit_width: BitWidth,
    platform: &Platform,
) -> ResourceEstimate {
    // Eq 25's Bit_w tracks the float-side buffer width (see brams_eq25).
    let bit_w = (bit_width.bits() as f64).max(32.0);
    let dsp_analytical = dsps_eq8(cfg, tiles);
    let dsp = dsps_structural(cfg, tiles);
    let bram18k_analytical = brams_eq25(cfg, tiles, bit_w);
    let (bram18k, lutram_bits) = brams_structural(cfg, tiles, bit_w);
    let lut = luts_structural(cfg, tiles, bit_w);
    ResourceEstimate {
        dsp_analytical,
        dsp,
        bram18k_analytical,
        bram18k,
        lutram_bits,
        lut,
        ff: (lut as f64 * 1.35) as u64,
        dsp_util: dsp as f64 / platform.dsp_total as f64,
        lut_util: lut as f64 / platform.lut_total as f64,
        bram_util: bram18k as f64 / platform.bram18k_total as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::platform;
    use crate::model::presets;

    fn default_cfg() -> TnnConfig {
        // Table 2 rows use h = 8 (not the register default 12).
        TnnConfig::encoder(64, 768, 8, 12)
    }

    #[test]
    fn eq8_reproduces_large_tile_row_exactly() {
        // Table 2 last row: SL=64 d=768 h=8 TS=(128,192) -> 6272 DSPs.
        let cfg = default_cfg();
        let t = TileConfig::new(128, 192);
        assert_eq!(dsps_eq8(&cfg, &t).round() as u64, 6272);
    }

    #[test]
    fn eq8_default_documented_discrepancy() {
        // Eq 8 verbatim gives 4352 where the paper prints 3784 (DESIGN.md §5).
        let cfg = default_cfg();
        let t = TileConfig::paper_optimum();
        assert_eq!(dsps_eq8(&cfg, &t).round() as u64, 4352);
    }

    #[test]
    fn structural_dsps_match_table2_experimental() {
        let cfg = default_cfg();
        let t = TileConfig::paper_optimum();
        assert_eq!(dsps_structural(&cfg, &t), 3612);
    }

    #[test]
    fn eq25_within_5pct_of_table2() {
        let cfg = default_cfg();
        let t = TileConfig::paper_optimum();
        let b = brams_eq25(&cfg, &t, 32.0);
        let err = (b - 2375.0).abs() / 2375.0;
        assert!(err < 0.05, "eq25 = {b}, err = {err}");
    }

    #[test]
    fn structural_brams_near_table2_experimental() {
        let cfg = default_cfg();
        let t = TileConfig::paper_optimum();
        let (b, _) = brams_structural(&cfg, &t, 32.0);
        let err = (b as f64 - 2246.0).abs() / 2246.0;
        assert!(err < 0.10, "structural = {b}, err = {err}");
    }

    #[test]
    fn luts_near_table1() {
        let cfg = default_cfg();
        let t = TileConfig::paper_optimum();
        let l = luts_structural(&cfg, &t, 32.0);
        let err = (l as f64 - 391_000.0).abs() / 391_000.0;
        assert!(err < 0.10, "luts = {l}, err = {err}");
    }

    #[test]
    fn bigger_tiles_use_more_dsps_fewer_loads() {
        let cfg = default_cfg();
        let small = dsps_structural(&cfg, &TileConfig::new(32, 64));
        let big = dsps_structural(&cfg, &TileConfig::new(128, 256));
        assert!(big > small);
    }

    #[test]
    fn bram_deviation_grows_with_tile_size() {
        // Table 2 note: "higher deviation ... for larger tile sizes occurred
        // because LUTRAMs were used more than BRAMs".
        let cfg = default_cfg();
        let small_t = TileConfig::paper_optimum();
        let big_t = TileConfig::new(128, 192);
        let dev = |t: &TileConfig| {
            let a = brams_eq25(&cfg, t, 32.0);
            let (s, _) = brams_structural(&cfg, t, 32.0);
            (a - s as f64).abs() / a
        };
        assert!(dev(&big_t) >= dev(&small_t) * 0.9, "{} vs {}", dev(&big_t), dev(&small_t));
    }

    #[test]
    fn estimate_fits_u55c_and_not_zcu102() {
        let cfg = default_cfg();
        let t = TileConfig::paper_optimum();
        let u = platform::u55c();
        let e = estimate(&cfg, &t, BitWidth::Fixed16, &u);
        assert!(e.check_fit(&u).is_ok());
        // the same synthesis drowns a ZCU102 (Fig 11 forces tiny tiles there)
        let z = platform::zcu102();
        let ez = estimate(&cfg, &t, BitWidth::Fixed16, &z);
        assert!(ez.check_fit(&z).is_err());
    }

    #[test]
    fn utilization_fractions_match_table1() {
        let cfg = default_cfg();
        let t = TileConfig::paper_optimum();
        let e = estimate(&cfg, &t, BitWidth::Fixed16, &platform::u55c());
        assert!((e.dsp_util - 0.40).abs() < 0.02, "{}", e.dsp_util);
        assert!((e.lut_util - 0.30).abs() < 0.03, "{}", e.lut_util);
    }

    #[test]
    fn weight_memory_envelope_orders_platforms() {
        // U55C's URAM dwarfs the pure-BRAM parts: ~38 MB vs ~2 MB.
        let u = weight_memory_bytes(&platform::u55c());
        let v = weight_memory_bytes(&platform::vc707());
        let z = weight_memory_bytes(&platform::zcu102());
        assert_eq!(u, 960 * 288 * 1024 / 8 + platform::u55c().bram_bytes() / 2);
        assert!(u > 10 * v, "{u} vs {v}");
        assert!(v > z, "{v} vs {z}");
        // no-URAM parts budget exactly half their BRAM for weights
        assert_eq!(v, platform::vc707().bram_bytes() / 2);
    }

    #[test]
    fn shallow_transformer_uses_same_fabric() {
        // runtime adaptivity: resources are a function of the synthesis,
        // dominated by tile sizes — a smaller model on the same fabric must
        // not *increase* resources.
        let t = TileConfig::paper_optimum();
        let big = estimate(&default_cfg(), &t, BitWidth::Fixed16, &platform::u55c());
        let small =
            estimate(&presets::shallow_transformer(), &t, BitWidth::Fixed16, &platform::u55c());
        assert!(small.dsp <= big.dsp);
    }
}
