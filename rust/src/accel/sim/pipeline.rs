//! Iteration-level simulation of HLS pipelined loop nests.
//!
//! Unlike the closed-form model (`accel::latency`, Eq 9/10), this executes
//! the loop nest: every outer iteration is issued individually, inner
//! pipeline issue/drain is tracked per iteration, and the non-pipelined
//! outer levels pay the loop entry/exit control cycles HLS actually emits.
//! The small systematic difference between this and the closed form is
//! exactly what Table 2 calls analytical-vs-experimental error.

/// One pipelined (innermost-pipelined) loop: `trip` iterations at
/// initiation interval `ii`, pipeline register depth `depth`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PipelinedLoop {
    pub depth: u64,
    pub ii: u64,
    pub trip: u64,
}

impl PipelinedLoop {
    /// Cycles until the last iteration drains.  Closed form of the
    /// per-iteration issue walk (`(trip−1)·II + depth`, i.e. Eq 9 — the
    /// iterative and closed forms are equivalence-tested below): these
    /// loops sit inside every design-space sweep, so the O(trip) walk was
    /// pure overhead.
    pub fn run(&self) -> u64 {
        if self.trip == 0 {
            return 0;
        }
        self.ii * (self.trip - 1) + self.depth
    }

    /// The original iteration-by-iteration walk, kept as the oracle for
    /// the closed-form equivalence tests.
    #[cfg(test)]
    fn run_iterative(&self) -> u64 {
        if self.trip == 0 {
            return 0;
        }
        let mut issue = 0u64;
        for i in 0..self.trip {
            if i > 0 {
                issue += self.ii;
            }
        }
        issue + self.depth
    }
}

/// A non-pipelined outer loop wrapping a body: HLS re-enters the body each
/// iteration and pays `ENTRY_EXIT` control cycles (the `pipeline off`
/// pragma on every outer loop in Algorithms 1–17).
pub const ENTRY_EXIT: u64 = 2;

/// Run `outer` iterations of `body_cycles`, paying loop control each time.
/// Closed form of the accumulation loop (equivalence-tested below).
pub fn outer_loop(outer: u64, body_cycles: u64) -> u64 {
    outer * (ENTRY_EXIT + body_cycles)
}

/// The original iterative accumulation, kept as the oracle for the
/// closed-form equivalence tests.
#[cfg(test)]
fn outer_loop_iterative(outer: u64, body_cycles: u64) -> u64 {
    let mut t = 0u64;
    for _ in 0..outer {
        t += ENTRY_EXIT + body_cycles;
    }
    t
}

/// A two-deep nest: outer non-pipelined, inner pipelined (the universal
/// shape of the paper's algorithms).
pub fn nest(outer: u64, inner: PipelinedLoop) -> u64 {
    let body = inner.run();
    outer_loop(outer, body)
}

/// Double-buffered producer/consumer timeline: `visits` rounds where round
/// v's load may proceed as soon as (a) the load engine is free and (b) the
/// buffer it writes was consumed (2 buffers → round v-2's compute done);
/// compute for round v starts when its load is done and the compute engine
/// is free.  Returns (total_cycles, load_busy, compute_busy).
pub fn double_buffered(visits: u64, load_cycles: u64, compute_cycles: u64) -> (u64, u64, u64) {
    let mut load_free = 0u64;
    let mut compute_free = 0u64;
    let mut compute_done = vec![0u64; visits as usize];
    for v in 0..visits as usize {
        let gate = if v >= 2 { compute_done[v - 2] } else { 0 };
        let l_done = load_free.max(gate) + load_cycles;
        load_free = l_done;
        let c_done = compute_free.max(l_done) + compute_cycles;
        compute_free = c_done;
        compute_done[v] = c_done;
    }
    (compute_free, visits * load_cycles, visits * compute_cycles)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pipelined_loop_matches_eq9_for_ii1() {
        // PLL = PD + II·(TC−1)
        let l = PipelinedLoop { depth: 5, ii: 1, trip: 10 };
        assert_eq!(l.run(), 5 + 9);
        let l2 = PipelinedLoop { depth: 3, ii: 2, trip: 4 };
        assert_eq!(l2.run(), 3 + 6);
    }

    #[test]
    fn zero_trip_is_free() {
        assert_eq!(PipelinedLoop { depth: 9, ii: 1, trip: 0 }.run(), 0);
    }

    #[test]
    fn outer_loop_pays_control_overhead() {
        // this overhead is the analytical-vs-experimental gap's source
        assert_eq!(outer_loop(10, 100), 10 * 102);
    }

    #[test]
    fn closed_form_pipelined_loop_matches_iterative_oracle() {
        for depth in [0u64, 1, 2, 5, 16, 129] {
            for ii in [1u64, 2, 3, 7] {
                for trip in [0u64, 1, 2, 3, 64, 767, 4096] {
                    let l = PipelinedLoop { depth, ii, trip };
                    assert_eq!(
                        l.run(),
                        l.run_iterative(),
                        "depth={depth} ii={ii} trip={trip}"
                    );
                }
            }
        }
    }

    #[test]
    fn closed_form_outer_loop_matches_iterative_oracle() {
        for outer in [0u64, 1, 2, 13, 144, 10_000] {
            for body in [0u64, 1, 99, 1023] {
                assert_eq!(
                    outer_loop(outer, body),
                    outer_loop_iterative(outer, body),
                    "outer={outer} body={body}"
                );
            }
        }
    }

    #[test]
    fn nest_is_within_2pct_of_closed_form_for_long_inner() {
        let inner = PipelinedLoop { depth: 16, ii: 1, trip: 768 };
        let sim = nest(64, inner);
        let analytical = (16 + 767) * 64;
        let err = (sim as f64 - analytical as f64).abs() / analytical as f64;
        assert!(err < 0.02, "err = {err}");
    }

    #[test]
    fn double_buffer_hides_loads_when_compute_dominates() {
        let (total, load_busy, _) = double_buffered(10, 50, 100);
        // first load exposed, rest hidden: ≈ 50 + 10·100
        assert!(total >= 1050 && total <= 1100, "{total}");
        assert_eq!(load_busy, 500);
    }

    #[test]
    fn double_buffer_degrades_to_load_bound() {
        let (total, ..) = double_buffered(10, 100, 10);
        // load engine is the bottleneck: ≈ 10·100 + last compute
        assert!(total >= 1000 && total <= 1120, "{total}");
    }

    #[test]
    fn single_visit_serializes() {
        let (total, ..) = double_buffered(1, 30, 70);
        assert_eq!(total, 100);
    }
}
