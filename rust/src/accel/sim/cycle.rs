//! The cycle backend: replays a [`TileProgram`] to predict fabric cycles.
//!
//! This is the AccelTran discipline — drive the cycle model from the
//! *same* instruction stream the real datapath executes — applied to
//! Table 2: instead of a second hand-maintained schedule inside
//! [`super::simulate`], the backend walks the program the PJRT executor
//! replays and prices every dispatch with the iteration-level loop-nest
//! models of [`super::pipeline`].
//!
//! Pricing maps substrate dispatches back onto hardware module timelines:
//! heads run in parallel on the fabric (one head's timeline is the
//! block's), so the `h` per-head dispatches of one module share that
//! module's cycles; weight-panel loads double-buffer against compute
//! ([`super::pipeline::double_buffered`]); and the host↔device shuffles of
//! the software substrate (panel re-assembly) cost nothing — on the
//! hardware those moves happen inside BRAM.  The one-time input load
//! (Algorithm 1) is charged per replay, not per upload.
//!
//! Buffers are bare shapes; numerics never happen here, which is what lets
//! cycle estimation run without an artifact set.

use std::cell::RefCell;
use std::collections::{BTreeMap, HashMap};

use anyhow::bail;

use crate::accel::latency::depths::{LOAD, STORE};
use crate::accel::schedule::{
    self, AttentionMode, FabricConstants, ScheduleBuilder, TileProgram, WeightKind, WeightRef,
    WeightSource,
};
use crate::model::TnnConfig;
use crate::runtime::{backend::FabricBackend, Tensor};

use super::pipeline::{nest, PipelinedLoop};

/// Per-artifact accounting for one replay.
#[derive(Debug, Clone, Copy, Default)]
pub struct ArtifactCycles {
    pub count: u64,
    pub cycles: f64,
}

/// Inter-fabric link bandwidth: bytes of activation per fabric cycle.
/// The board-to-board serial link is far narrower than the on-board AXI
/// DMA (`coordinator::residency::UPLOAD_BYTES_PER_CYCLE`, 64 B/cycle), so
/// a shard handoff prices at 16 B/cycle — the cost a partitioner trades
/// against weight-upload savings when it cuts a stack.
pub const LINK_BYTES_PER_CYCLE: u64 = 16;

/// The outcome of replaying a program through the cycle backend.
#[derive(Debug, Clone)]
pub struct CycleReport {
    /// Predicted fabric cycles for one request (input load + layer stack,
    /// decoder layers charged at the simulator's 1.6× encoder rate).
    pub total_cycles: u64,
    pub dispatches: u64,
    pub uploads: u64,
    pub fetches: u64,
    /// Waves priced as `max` over their members (0 when wave pricing was
    /// off or the program was unscheduled).
    pub waves: u64,
    /// The slowest single wave of the replay (0 unless wave pricing ran
    /// on a wave-scheduled program).  This is the program's
    /// **initiation-interval bound**: waves are the pipeline stages of
    /// one replay, so back-to-back *independent* replays (decode steps
    /// of different sequences under continuous batching) can be admitted
    /// every `max_wave_cycles` — the slowest stage gates the stream —
    /// while a single sequence must wait the full `total_cycles` between
    /// its own (data-dependent) steps.
    pub max_wave_cycles: u64,
    /// Artifact names in dispatch order — compared against the PJRT
    /// executor's trace of the identical program in the equivalence tests.
    /// Interned: the names are the cost table's `&'static` keys, so
    /// tracing allocates nothing per dispatch.
    pub trace: Vec<&'static str>,
    /// Per-artifact **work** (sequential-equivalent cycles), independent
    /// of the pricing mode.  Under wave pricing these deliberately do NOT
    /// sum to `total_cycles`: the total counts each wave at its slowest
    /// member while this table counts every member's full cost — the gap
    /// between the two is exactly the concurrency the schedule exposed.
    pub per_artifact: BTreeMap<&'static str, ArtifactCycles>,
    /// Shard-boundary crossings this replay sent (`SendActivation` steps
    /// that reached the backend).  0 for any monolithic program.
    pub activation_hops: u64,
    /// Activation bytes pushed over the inter-fabric link by those hops.
    pub link_bytes: u64,
    /// Cycles charged for the link traffic at [`LINK_BYTES_PER_CYCLE`]
    /// (already included in `total_cycles`; the sender pays the full
    /// transfer, a recv is free — its buffer was written by the peer).
    pub link_cycles: u64,
}

impl CycleReport {
    pub fn ms_at(&self, freq_mhz: f64) -> f64 {
        self.total_cycles as f64 / (freq_mhz * 1e3)
    }
}

#[derive(Debug, Default)]
struct CycleState {
    cycles: f64,
    dispatches: u64,
    uploads: u64,
    fetches: u64,
    waves: u64,
    /// Inside a wave (wave pricing on): the running max member cost,
    /// folded into `cycles` at `wave_end`.
    in_wave: bool,
    wave_max: f64,
    /// Max over all completed waves' `wave_max` — the slowest stage.
    max_wave: f64,
    trace: Vec<&'static str>,
    per_artifact: BTreeMap<&'static str, ArtifactCycles>,
    activation_hops: u64,
    link_bytes: u64,
    link_cycles: f64,
}

/// A [`FabricBackend`] whose buffers are bare shapes and whose dispatches
/// accrue predicted cycles from a per-artifact cost table derived from the
/// iteration-level simulator for one `(topology, fabric)` pair.
///
/// **Wave pricing** (off by default): when enabled, the dispatches of one
/// wave of a wave-scheduled program cost `max` instead of `sum` — every
/// member could occupy its own processing module concurrently, so the
/// wave's latency is its slowest member's.  This is the utilization upper
/// bound the paper's PE-array parallelism targets; the default `sum`
/// pricing remains the strictly-sequential Table 2 baseline (and is what
/// the <6% analytical-agreement tests pin down).
pub struct CycleBackend {
    costs: HashMap<&'static str, f64>,
    load_inputs: u64,
    /// Decoder-stack surcharge (1.6× an encoder layer, as in
    /// [`super::simulate`]), fixed at construction.
    dec_cycles: f64,
    /// Price waves as `max` over members (requires a wave-scheduled
    /// program to have any effect).
    wave_pricing: bool,
    /// The programmed sequence length — the denominator of the per-tier
    /// attention scaling in [`FabricBackend::dispatch_rows`].
    seq_len: usize,
    state: RefCell<CycleState>,
}

/// The artifacts whose cost grows quadratically with the sequence length
/// (both matrix dimensions of the score/probability tile are `seq_len`),
/// which is exactly the set the skippable attention tiers dispatch.  A
/// fired tier of `t` rows does `t²`-proportional work where the cost
/// table charged `seq_len²`, so [`CycleBackend::dispatch_rows`] scales by
/// `(t / seq_len)²`.  Everything else (projections, FFN, LN) is linear in
/// rows and is never tier-predicated, so it keeps its table price.
const TIER_SCALED: [&str; 5] = ["qk_scores", "softmax", "sv", "attn_fused", "attn_packed"];

impl CycleBackend {
    pub fn new(cfg: &TnnConfig, fc: &FabricConstants) -> Self {
        let tiles = fc.tile_config();
        let sim = super::simulate(cfg, &tiles);
        let l = &sim.layer;
        let h = cfg.heads as f64;
        let sl = cfg.seq_len as f64;
        let t_m = (cfg.d_model / fc.ts_mha) as f64;
        let t_f = (cfg.d_model / fc.ts_ffn) as f64;
        let t_h = (cfg.hidden / fc.ffn_col) as f64;
        let attn_tail = (l.score + l.softmax + l.sv) as f64;
        // int8 QDQ pass over the valid embedding prefix (not part of the
        // paper's fp16 timeline; only the quantized mode dispatches it).
        let qdq = nest(
            cfg.seq_len as u64,
            PipelinedLoop { depth: LOAD + 3 + STORE, ii: 1, trip: cfg.d_model as u64 },
        ) as f64;
        let costs = HashMap::from([
            ("mm_qkv", l.qkv_total as f64 / (3.0 * h * t_m)),
            ("mm_qkv_packed", l.qkv_total as f64 / (h * t_m)),
            ("bias_add_dk", l.bias_qkv as f64 / (3.0 * h)),
            ("bias_add_qkv", l.bias_qkv as f64 / h),
            ("qk_scores", l.score as f64 / h),
            ("softmax", l.softmax as f64 / h),
            ("sv", l.sv as f64 / h),
            ("attn_fused", attn_tail / h),
            ("attn_packed", attn_tail / h),
            ("mm_ffn1", l.ffn1_total as f64 / (t_f * t_f)),
            ("mm_ffn2", l.ffn2_total as f64 / (t_f * t_h)),
            ("mm_ffn3", l.ffn3_total as f64 / (t_f * t_h)),
            ("bias_add_d", l.bias_ffn1 as f64),
            ("bias_relu_h", l.bias_ffn2 as f64),
            ("residual_ln", l.ln1 as f64),
            // The fused bias+LN artifact (`opt::FuseBiasLn` target) costs
            // exactly the sum of its parts, so dispatch fusion leaves the
            // sequential total invariant — only wave pricing changes it.
            ("bias_residual_ln", l.bias_ffn1 as f64 + l.ln1 as f64),
            ("quantize", qdq),
            // ---- decode-step row artifacts: the single-token datapath
            // streams one row where the prefill path streams seq_len, so
            // each row dispatch is its full-height analog over seq_len.
            // dec_qkv_row covers a head's whole projection (all tiles)
            // plus its bias in one dispatch.
            ("dec_qkv_row", (l.qkv_total as f64 / (3.0 * h) + l.bias_qkv as f64 / (3.0 * h)) / sl),
            ("qk_row", l.score as f64 / h / sl),
            ("softmax_row", l.softmax as f64 / h / sl),
            ("sv_row", l.sv as f64 / h / sl),
            // One K/V row written into the cache BRAM.
            ("kv_append", nest(1, PipelinedLoop { depth: LOAD + STORE, ii: 1, trip: cfg.dk() as u64 }) as f64),
            ("dec_proj_row", (l.ffn1_total as f64 + l.bias_ffn1 as f64) / sl),
            ("dec_ffn1_row", (l.ffn2_total as f64 + l.bias_ffn2 as f64) / sl),
            ("dec_ffn2_row", (l.ffn3_total as f64 + l.bias_ffn3 as f64) / sl),
            ("residual_ln_row", l.ln1 as f64 / sl),
        ]);
        CycleBackend {
            costs,
            load_inputs: sim.load_inputs,
            dec_cycles: l.total() as f64 * 1.6 * cfg.dec_layers as f64,
            wave_pricing: false,
            seq_len: cfg.seq_len,
            state: RefCell::new(CycleState::default()),
        }
    }

    /// Price one dispatch at `scale ×` its table cost (floor: one cycle —
    /// even a maximally skipped tier occupies the module for a beat).
    fn charge(&self, artifact: &str, scale: f64, out_shape: &[usize]) -> anyhow::Result<Vec<usize>> {
        // The cost table's key doubles as the interned artifact name.
        let Some((name, cost)) = self.costs.get_key_value(artifact).map(|(k, v)| (*k, *v))
        else {
            bail!("cycle backend has no cost model for artifact '{artifact}'");
        };
        let cost = if scale < 1.0 { (cost * scale).max(1.0) } else { cost };
        let mut st = self.state.borrow_mut();
        if st.in_wave {
            st.wave_max = st.wave_max.max(cost);
        } else {
            st.cycles += cost;
        }
        st.dispatches += 1;
        st.trace.push(name);
        let e = st.per_artifact.entry(name).or_default();
        e.count += 1;
        e.cycles += cost;
        Ok(out_shape.to_vec())
    }

    /// Enable wave pricing (`max` per wave instead of `sum`).
    pub fn with_wave_pricing(mut self, on: bool) -> Self {
        self.wave_pricing = on;
        self
    }

    /// Divide the one-time input-load charge by `div` (ceiling).  A
    /// decode step uploads one activation row, not the whole `seq_len`
    /// prompt the default charge models.
    pub fn with_input_load_div(mut self, div: u64) -> Self {
        self.load_inputs = self.load_inputs.div_ceil(div.max(1));
        self
    }

    /// Drop the flat decoder-stack surcharge.  The surcharge approximates
    /// decoder cost when pricing an **encoder** program of a seq2seq
    /// topology; a prefill/decode-step program lowers the decoder layers
    /// for real, so pricing one with the surcharge on would double-count.
    pub fn without_decoder_surcharge(mut self) -> Self {
        self.dec_cycles = 0.0;
        self
    }

    /// The prediction for everything replayed so far (plus the one-time
    /// input load and any decoder surcharge).
    pub fn report(&self) -> CycleReport {
        let st = self.state.borrow();
        let total = self.load_inputs as f64 + st.cycles + self.dec_cycles;
        CycleReport {
            total_cycles: total.round() as u64,
            dispatches: st.dispatches,
            uploads: st.uploads,
            fetches: st.fetches,
            waves: st.waves,
            max_wave_cycles: st.max_wave.round() as u64,
            trace: st.trace.clone(),
            per_artifact: st.per_artifact.clone(),
            activation_hops: st.activation_hops,
            link_bytes: st.link_bytes,
            link_cycles: st.link_cycles.round() as u64,
        }
    }
}

impl FabricBackend for CycleBackend {
    type Buf = Vec<usize>;

    fn upload(&self, t: &Tensor) -> anyhow::Result<Vec<usize>> {
        self.state.borrow_mut().uploads += 1;
        Ok(t.shape.clone())
    }

    fn dispatch(
        &self,
        artifact: &str,
        _inputs: &[&Vec<usize>],
        out_shape: &[usize],
    ) -> anyhow::Result<Vec<usize>> {
        self.charge(artifact, 1.0, out_shape)
    }

    /// A fired attention tier of `t` rows prices at `(t / seq_len)²` of
    /// its table cost — the score/probability tile is `t × t` where the
    /// table charged `seq_len × seq_len`.  This is where the recovered
    /// padding waste of length-adaptive programs becomes visible in
    /// Table 2 and `BENCH_hotpath.json`.  Skipped tiers never reach the
    /// backend at all (the replay drops them), so they cost zero.
    fn dispatch_rows(
        &self,
        artifact: &str,
        inputs: &[&Vec<usize>],
        out_shape: &[usize],
        rows: Option<usize>,
    ) -> anyhow::Result<Vec<usize>> {
        match rows {
            Some(t) if t < self.seq_len && TIER_SCALED.contains(&artifact) => {
                let f = t as f64 / self.seq_len as f64;
                self.charge(artifact, (f * f).min(1.0), out_shape)
            }
            _ => self.dispatch(artifact, inputs, out_shape),
        }
    }

    fn fetch(&self, buf: &Vec<usize>) -> anyhow::Result<Tensor> {
        self.state.borrow_mut().fetches += 1;
        Ok(Tensor::zeros(buf.clone()))
    }

    fn wave_begin(&self, _wave: usize, _steps: usize) {
        if self.wave_pricing {
            let mut st = self.state.borrow_mut();
            st.in_wave = true;
            st.wave_max = 0.0;
        }
    }

    fn wave_end(&self) {
        if self.wave_pricing {
            let mut st = self.state.borrow_mut();
            st.cycles += st.wave_max;
            st.max_wave = st.max_wave.max(st.wave_max);
            st.in_wave = false;
            st.waves += 1;
        }
    }

    /// The sender pays the whole transfer: `bytes` of activation at
    /// [`LINK_BYTES_PER_CYCLE`], charged outside wave pricing (the link
    /// serializes against compute — a handoff is a pipeline bubble for
    /// this request; only overlapping *other* requests hides it).
    fn link_send(&self, bytes: usize, _boundary: usize) {
        let cost = (bytes as u64).div_ceil(LINK_BYTES_PER_CYCLE) as f64;
        let mut st = self.state.borrow_mut();
        st.cycles += cost;
        st.link_cycles += cost;
        st.activation_hops += 1;
        st.link_bytes += bytes as u64;
    }

    /// A recv is free: the peer's send already paid the wire time and the
    /// activation sits in the input host before replay begins.
    fn link_recv(&self, _bytes: usize, _boundary: usize) {}
}

/// Shape-only stand-ins for a prepared weight stack: every reference
/// resolves to the fabric-fixed panel shape of its kind.
pub struct ShapeWeights {
    mha_panel: Vec<usize>,
    qkv_panel: Vec<usize>,
    bias_dk: Vec<usize>,
    bias_qkv3: Vec<usize>,
    wo: Vec<usize>,
    vec_d: Vec<usize>,
    w1: Vec<usize>,
    vec_h: Vec<usize>,
    w2: Vec<usize>,
    dw_qkv: Vec<usize>,
    dw_proj: Vec<usize>,
    dw_ffn1: Vec<usize>,
    dw_ffn2: Vec<usize>,
}

impl ShapeWeights {
    pub fn new(fc: &FabricConstants) -> Self {
        ShapeWeights {
            mha_panel: vec![fc.ts_mha, fc.dk],
            qkv_panel: vec![fc.ts_mha, 3 * fc.dk],
            bias_dk: vec![fc.dk],
            bias_qkv3: vec![3 * fc.dk],
            wo: vec![fc.ts_ffn, fc.ts_ffn],
            vec_d: vec![fc.dmodel_max],
            w1: vec![fc.ts_ffn, fc.ffn_col],
            vec_h: vec![fc.hidden_max],
            w2: vec![fc.ffn_col, fc.ts_ffn],
            dw_qkv: vec![fc.dmodel_max, fc.dk],
            dw_proj: vec![fc.dmodel_max, fc.dmodel_max],
            dw_ffn1: vec![fc.dmodel_max, fc.hidden_max],
            dw_ffn2: vec![fc.hidden_max, fc.dmodel_max],
        }
    }
}

impl WeightSource<Vec<usize>> for ShapeWeights {
    fn weight(&self, r: &WeightRef) -> anyhow::Result<&Vec<usize>> {
        Ok(match r.kind {
            WeightKind::Wq
            | WeightKind::Wk
            | WeightKind::Wv
            | WeightKind::CWq
            | WeightKind::CWk
            | WeightKind::CWv => &self.mha_panel,
            WeightKind::QkvPacked => &self.qkv_panel,
            WeightKind::Bq
            | WeightKind::Bk
            | WeightKind::Bv
            | WeightKind::CBq
            | WeightKind::CBk
            | WeightKind::CBv => &self.bias_dk,
            WeightKind::BQkvPacked => &self.bias_qkv3,
            WeightKind::Wo | WeightKind::CWo => &self.wo,
            WeightKind::Bo
            | WeightKind::B2
            | WeightKind::G1
            | WeightKind::B1n
            | WeightKind::G2
            | WeightKind::B2n
            | WeightKind::CBo
            | WeightKind::CG
            | WeightKind::CBn => &self.vec_d,
            WeightKind::W1 => &self.w1,
            WeightKind::B1 => &self.vec_h,
            WeightKind::W2 => &self.w2,
            WeightKind::DWq | WeightKind::DWk | WeightKind::DWv | WeightKind::DCWq => &self.dw_qkv,
            WeightKind::DWo | WeightKind::DCWo => &self.dw_proj,
            WeightKind::DW1 => &self.dw_ffn1,
            WeightKind::DW2 => &self.dw_ffn2,
        })
    }
}

/// Replay an already-built program through the cycle backend with the
/// sequential (`sum`) pricing.  Needs no artifact set: buffers are
/// shapes, weights are shape stand-ins.  Wave-scheduled programs price
/// identically to their unscheduled originals here — the Table 2
/// baseline stays pinned to the analytical band regardless of opt level.
pub fn replay_program(prog: &TileProgram) -> anyhow::Result<CycleReport> {
    replay_priced(prog, false, prog.cfg.seq_len)
}

/// Replay a **wave-scheduled** program pricing each wave as `max` over
/// its members — the PE-array parallelism analog.  On an unscheduled
/// program this degenerates to [`replay_program`] (no waves, no hooks).
pub fn replay_program_waves(prog: &TileProgram) -> anyhow::Result<CycleReport> {
    replay_priced(prog, true, prog.cfg.seq_len)
}

/// [`replay_program`] at an explicit live row count: skippable tiers that
/// do not cover `live` are dropped (zero cycles) and the fired tier is
/// priced at its tier's row count — the length-adaptive request price.
/// On a non-skippable program this is exactly [`replay_program`].
pub fn replay_program_live(prog: &TileProgram, live: usize) -> anyhow::Result<CycleReport> {
    replay_priced(prog, false, live)
}

fn replay_priced(prog: &TileProgram, waves: bool, live: usize) -> anyhow::Result<CycleReport> {
    let backend = CycleBackend::new(&prog.cfg, &prog.fabric).with_wave_pricing(waves);
    let weights = ShapeWeights::new(&prog.fabric);
    let mut runtime = schedule::build_runtime(&backend, &prog.cfg, &prog.fabric)?;
    schedule::upload_tier_masks(&backend, &mut runtime, &prog.cfg, &prog.fabric, &prog.tier_mask_ids())?;
    let input = Tensor::zeros(vec![prog.fabric.sl_max, prog.fabric.dmodel_max]);
    schedule::replay_with_live(prog, &backend, &weights, &runtime, input, None, live)?;
    Ok(backend.report())
}

/// Replay any program — including decoder prefill / decode-step programs
/// with aux inputs, extern cache panels and exports — through the cycle
/// backend with the sequential pricing and **no** decoder surcharge (a
/// decoder program carries its real decoder dispatches, so the flat
/// surcharge of the encoder-side estimate would double-count).
pub fn replay_decoder_program(prog: &TileProgram) -> anyhow::Result<CycleReport> {
    replay_decoder_priced(prog, false, prog.cfg.seq_len)
}

/// [`replay_decoder_program`] at an explicit live row count — prices a
/// skippable **prefill** program for a prompt of `live` tokens (fired
/// self-attention tier at its tier's cost, skipped tiers at zero).
pub fn replay_decoder_program_live(prog: &TileProgram, live: usize) -> anyhow::Result<CycleReport> {
    replay_decoder_priced(prog, false, live)
}

/// [`replay_decoder_program`] with wave pricing: each wave of a
/// wave-scheduled prefill/step program costs `max` over its members, and
/// the report's `max_wave_cycles` carries the slowest wave — the
/// initiation-interval bound continuous-batching throughput models need
/// (`benches/decode.rs`).  On an unscheduled program this degenerates to
/// the sequential price.
pub fn replay_decoder_program_waves(prog: &TileProgram) -> anyhow::Result<CycleReport> {
    replay_decoder_priced(prog, true, prog.cfg.seq_len)
}

fn replay_decoder_priced(prog: &TileProgram, waves: bool, live: usize) -> anyhow::Result<CycleReport> {
    let mut backend = CycleBackend::new(&prog.cfg, &prog.fabric)
        .without_decoder_surcharge()
        .with_wave_pricing(waves);
    if prog.host_shapes[prog.input_host].first() == Some(&1) {
        // Single-row (decode-step) input: charge one row's AXI write.
        backend = backend.with_input_load_div(prog.cfg.seq_len as u64);
    }
    let weights = ShapeWeights::new(&prog.fabric);
    let mut runtime = schedule::build_runtime(&backend, &prog.cfg, &prog.fabric)?;
    schedule::upload_tier_masks(&backend, &mut runtime, &prog.cfg, &prog.fabric, &prog.tier_mask_ids())?;
    // Main + aux inputs as zero tensors of the program's declared shapes;
    // extern cache panels as bare shapes.
    let mut inputs = vec![Tensor::zeros(prog.host_shapes[prog.input_host].clone())];
    for h in &prog.aux_hosts {
        inputs.push(Tensor::zeros(prog.host_shapes[*h].clone()));
    }
    let extern_bufs: Vec<Vec<usize>> = prog.extern_shapes.clone();
    let externs: Vec<&Vec<usize>> = extern_bufs.iter().collect();
    schedule::replay_full_adaptive(prog, &backend, &weights, &runtime, inputs, &externs, None, live)?;
    Ok(backend.report())
}

/// Build + price the decoder **prefill** program for `(cfg, fc)` — the
/// whole-prompt cost of populating the KV cache (Table 2's "prefill" row).
pub fn estimate_prefill(cfg: &TnnConfig, fc: &FabricConstants) -> anyhow::Result<CycleReport> {
    let prog = ScheduleBuilder::new(*fc, *cfg)?.build_prefill();
    replay_decoder_program(&prog)
}

/// Build + price the **decode-step** program for `(cfg, fc)` — the
/// per-token marginal cost of KV-cached generation (Table 2's "per-token"
/// row).  The one-time input load the backend charges per replay is the
/// single-row AXI write of the step.
pub fn estimate_step(cfg: &TnnConfig, fc: &FabricConstants) -> anyhow::Result<CycleReport> {
    let prog = ScheduleBuilder::new(*fc, *cfg)?.build_step();
    replay_decoder_program(&prog)
}

/// Build the program for `(cfg, fc, flags)` and replay it for cycles —
/// the one-call schedule-grounded latency estimate.
pub fn estimate(
    cfg: &TnnConfig,
    fc: &FabricConstants,
    mode: AttentionMode,
    qkv_packed: bool,
    quantized: bool,
) -> anyhow::Result<CycleReport> {
    let prog = ScheduleBuilder::new(*fc, *cfg)?
        .mode(mode)
        .qkv_packed(qkv_packed)
        .quantized(quantized)
        .build();
    replay_program(&prog)
}

/// [`estimate`] through the optimizer: lower, run the pass pipeline at
/// `level` (against the full artifact inventory — the cycle backend
/// prices every fusable artifact), and wave-price the result.  This is
/// the "what the wave-scheduled replay is worth" number Table 2's
/// `replayed+waves` rows report.
pub fn estimate_opt(
    cfg: &TnnConfig,
    fc: &FabricConstants,
    mode: AttentionMode,
    qkv_packed: bool,
    quantized: bool,
    level: schedule::OptLevel,
) -> anyhow::Result<CycleReport> {
    let mut prog = ScheduleBuilder::new(*fc, *cfg)?
        .mode(mode)
        .qkv_packed(qkv_packed)
        .quantized(quantized)
        .build();
    schedule::optimize(&mut prog, level, &schedule::ArtifactInventory::assume_all())?;
    replay_program_waves(&prog)
}

/// The length-adaptive request price: lower the encoder program **at the
/// smallest covering bucket** of `rows` (skippable tiers on), optimize at
/// `level`, and replay at `live = rows`.  This is what the engine's
/// bucketed program cache serves, so it is the number Table 2's
/// per-bucket rows and `BENCH_hotpath.json` report against the dense
/// max-length [`estimate`].
pub fn estimate_adaptive(
    cfg: &TnnConfig,
    fc: &FabricConstants,
    rows: usize,
    level: schedule::OptLevel,
) -> anyhow::Result<CycleReport> {
    let bucket = schedule::covering_bucket(rows, cfg.seq_len);
    let cfg_b = TnnConfig { seq_len: bucket, ..*cfg };
    let mut prog = ScheduleBuilder::new(*fc, cfg_b)?.skippable(true).build();
    schedule::optimize(&mut prog, level, &schedule::ArtifactInventory::assume_all())?;
    replay_program_live(&prog, rows)
}

/// [`estimate_prefill`] for a prompt of `prompt_len` tokens through a
/// **skippable** prefill program: decoder-only topologies additionally
/// lower at the covering bucket; seq2seq prefill keeps the full-length
/// program (the cross-attention memory fence is the encoder's `seq_len`)
/// but still tier-skips its causal self-attention.
pub fn estimate_prefill_adaptive(
    cfg: &TnnConfig,
    fc: &FabricConstants,
    prompt_len: usize,
    level: schedule::OptLevel,
) -> anyhow::Result<CycleReport> {
    let bucket = if cfg.enc_layers == 0 {
        schedule::covering_bucket(prompt_len, cfg.seq_len)
    } else {
        cfg.seq_len
    };
    let cfg_b = TnnConfig { seq_len: bucket, ..*cfg };
    let mut prog = ScheduleBuilder::new(*fc, cfg_b)?.skippable(true).build_prefill();
    schedule::optimize(&mut prog, level, &schedule::ArtifactInventory::assume_all())?;
    replay_decoder_program_live(&prog, prompt_len)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::latency;

    fn fc() -> FabricConstants {
        FabricConstants::artifact_default()
    }

    fn rel_err(a: u64, b: u64) -> f64 {
        (a as f64 - b as f64).abs() / b as f64
    }

    #[test]
    fn schedule_replay_matches_analytical_within_table2_band() {
        // the acceptance band: the program-driven estimate must sit within
        // the Table 2 error band of the closed form (report gate: < 6%).
        let f = fc();
        let tiles = f.tile_config();
        for cfg in [
            TnnConfig::encoder(64, 768, 12, 12),
            TnnConfig::encoder(128, 768, 12, 12),
            TnnConfig::encoder(64, 512, 8, 12),
            TnnConfig::encoder(32, 256, 4, 2),
        ] {
            let est = estimate(&cfg, &f, AttentionMode::Split, false, false).unwrap();
            let ana = latency::model_latency(&cfg, &tiles);
            let err = rel_err(est.total_cycles, ana.total_cycles);
            assert!(
                err < 0.06,
                "{cfg}: replay={} analytical={} err={err:.4}",
                est.total_cycles,
                ana.total_cycles
            );
        }
    }

    #[test]
    fn schedule_replay_agrees_with_the_iteration_simulator() {
        // same schedule, same pricing primitives: the replayed total must
        // land on the simulator's (the costs are derived from it).
        let f = fc();
        let tiles = f.tile_config();
        for cfg in [TnnConfig::encoder(64, 768, 12, 12), TnnConfig::encoder(48, 128, 2, 3)] {
            let est = estimate(&cfg, &f, AttentionMode::Split, false, false).unwrap();
            let sim = super::super::simulate(&cfg, &tiles);
            let err = rel_err(est.total_cycles, sim.total_cycles);
            assert!(err < 0.005, "{cfg}: replay={} sim={}", est.total_cycles, sim.total_cycles);
        }
    }

    #[test]
    fn packed_and_fused_schedules_stay_in_band() {
        let f = fc();
        let tiles = f.tile_config();
        let cfg = TnnConfig::encoder(64, 512, 8, 4);
        let ana = latency::model_latency(&cfg, &tiles).total_cycles;
        for (mode, packed) in [
            (AttentionMode::Fused, false),
            (AttentionMode::Split, true),
            (AttentionMode::Fused, true),
        ] {
            let est = estimate(&cfg, &f, mode, packed, false).unwrap();
            let err = rel_err(est.total_cycles, ana);
            assert!(err < 0.06, "mode={mode:?} packed={packed}: err={err:.4}");
        }
    }

    #[test]
    fn trace_covers_every_dispatch_of_the_program() {
        let f = fc();
        let cfg = TnnConfig::encoder(32, 256, 4, 2);
        let prog = ScheduleBuilder::new(f, cfg).unwrap().build();
        let rep = replay_program(&prog).unwrap();
        assert_eq!(rep.dispatches as usize, prog.dispatch_count());
        assert_eq!(rep.trace.len(), prog.dispatch_count());
        assert_eq!(rep.trace, prog.dispatch_sequence());
        assert_eq!(rep.uploads as usize, prog.upload_count() + 10, "+10 runtime tensors");
        assert_eq!(rep.fetches as usize, prog.fetch_count());
    }

    #[test]
    fn quantized_schedule_costs_more() {
        let f = fc();
        let cfg = TnnConfig::encoder(64, 256, 4, 2);
        let plain = estimate(&cfg, &f, AttentionMode::Split, false, false).unwrap();
        let quant = estimate(&cfg, &f, AttentionMode::Split, false, true).unwrap();
        assert!(quant.total_cycles > plain.total_cycles);
        assert!(quant.per_artifact.contains_key("quantize"));
    }

    #[test]
    fn wave_pricing_lowers_the_estimate_for_multihead_topologies() {
        use crate::accel::schedule::{optimize, ArtifactInventory, OptLevel};
        let f = fc();
        for cfg in [
            TnnConfig::encoder(64, 768, 12, 4),
            TnnConfig::encoder(64, 512, 8, 2),
            TnnConfig::encoder(32, 256, 4, 2),
        ] {
            let mut prog = ScheduleBuilder::new(f, cfg).unwrap().build();
            let seq = replay_program(&prog).unwrap();
            optimize(&mut prog, OptLevel::O1, &ArtifactInventory::assume_all()).unwrap();
            // Sum pricing is invariant under the (bit-exact) reorder —
            // up to f64 accumulation order in the rounded total.
            let seq_opt = replay_program(&prog).unwrap();
            let drift = (seq.total_cycles as i64 - seq_opt.total_cycles as i64).abs();
            assert!(drift <= 2, "{cfg}: reorder changed the sequential price by {drift}");
            // …while wave pricing must strictly win: heads and FFN column
            // tiles overlap instead of serializing.
            let waved = replay_program_waves(&prog).unwrap();
            assert!(waved.waves > 0, "{cfg}: wave pricing must actually see waves");
            assert!(
                waved.total_cycles < seq.total_cycles,
                "{cfg}: waved={} sequential={}",
                waved.total_cycles,
                seq.total_cycles
            );
        }
    }

    #[test]
    fn wave_pricing_on_an_unscheduled_program_is_the_sequential_price() {
        let f = fc();
        let cfg = TnnConfig::encoder(32, 256, 4, 1);
        let prog = ScheduleBuilder::new(f, cfg).unwrap().build();
        let a = replay_program(&prog).unwrap();
        let b = replay_program_waves(&prog).unwrap();
        assert_eq!(a.total_cycles, b.total_cycles);
        assert_eq!(b.waves, 0);
    }

    #[test]
    fn fused_artifacts_price_as_the_sum_of_their_parts() {
        use crate::accel::schedule::OptLevel;
        // O2 fusion must leave the *sequential* estimate invariant: the
        // fused artifact costs exactly its components, so Table 2's band
        // tests hold at every opt level.
        let f = fc();
        let cfg = TnnConfig::encoder(64, 512, 8, 2);
        let plain = estimate(&cfg, &f, AttentionMode::Split, false, false).unwrap();
        let mut prog = ScheduleBuilder::new(f, cfg).unwrap().build();
        crate::accel::schedule::optimize(
            &mut prog,
            OptLevel::O2,
            &crate::accel::schedule::ArtifactInventory::assume_all(),
        )
        .unwrap();
        let fused = replay_program(&prog).unwrap();
        assert!(fused.dispatches < plain.dispatches, "fusion must reduce dispatches");
        let drift = (plain.total_cycles as i64 - fused.total_cycles as i64).abs();
        assert!(drift <= 2, "fusion changed the sequential price by {drift}");
        assert!(fused.per_artifact.contains_key("bias_residual_ln"));
    }

    #[test]
    fn decoder_layers_carry_the_simulator_surcharge() {
        let f = fc();
        let tiles = f.tile_config();
        let mut cfg = TnnConfig::encoder(64, 512, 8, 2);
        cfg.dec_layers = 2;
        let est = estimate(&cfg, &f, AttentionMode::Split, false, false).unwrap();
        let sim = super::super::simulate(&cfg, &tiles);
        assert!(rel_err(est.total_cycles, sim.total_cycles) < 0.005);
    }

    #[test]
    fn decode_step_is_strictly_cheaper_than_prefill() {
        let f = fc();
        for cfg in [
            crate::model::presets::gpt_small(64, 4),
            crate::model::presets::seq2seq_small(64, 2, 2),
            TnnConfig { dec_layers: 6, ..TnnConfig::encoder(64, 512, 8, 6) },
        ] {
            let pre = estimate_prefill(&cfg, &f).unwrap();
            let step = estimate_step(&cfg, &f).unwrap();
            assert!(step.dispatches < pre.dispatches, "{cfg}: {} vs {}", step.dispatches, pre.dispatches);
            assert!(step.uploads < pre.uploads, "{cfg}");
            assert!(
                step.total_cycles < pre.total_cycles / 4,
                "{cfg}: a cached step must be far cheaper ({} vs {})",
                step.total_cycles,
                pre.total_cycles
            );
            assert!(step.per_artifact.contains_key("kv_append"));
            assert!(step.per_artifact.contains_key("qk_row"));
        }
    }

    #[test]
    fn step_wave_replay_reports_the_initiation_interval_bound() {
        use crate::accel::schedule::{optimize, ArtifactInventory, OptLevel};
        let f = fc();
        let cfg = crate::model::presets::gpt_small(64, 4);
        let mut step = ScheduleBuilder::new(f, cfg).unwrap().build_step();
        optimize(&mut step, OptLevel::O1, &ArtifactInventory::assume_all()).unwrap();
        let seq = replay_decoder_program(&step).unwrap();
        assert_eq!(seq.max_wave_cycles, 0, "sequential pricing sees no waves");
        let waved = replay_decoder_program_waves(&step).unwrap();
        assert!(waved.waves > 0, "a wave-scheduled step program must replay in waves");
        // The slowest wave is one pipeline stage of the step: positive,
        // and strictly inside the whole step — otherwise back-to-back
        // independent steps could never overlap at all.
        assert!(waved.max_wave_cycles > 0);
        assert!(
            waved.max_wave_cycles < waved.total_cycles,
            "II bound {} must be a strict fraction of the step ({})",
            waved.max_wave_cycles,
            waved.total_cycles
        );
        assert!(waved.total_cycles <= seq.total_cycles, "wave pricing never costs more");
    }

    #[test]
    fn skippable_program_at_full_length_prices_like_the_dense_program() {
        use crate::accel::schedule::OptLevel;
        let f = fc();
        let cfg = TnnConfig::encoder(128, 256, 4, 2);
        let dense = estimate(&cfg, &f, AttentionMode::Split, false, false).unwrap();
        let skippable = ScheduleBuilder::new(f, cfg).unwrap().skippable(true).build();
        let full = replay_program_live(&skippable, cfg.seq_len).unwrap();
        // Only the top tier fires at full length, at the full table price:
        // same dispatch count, same total (mod f64 accumulation order).
        assert_eq!(full.dispatches, dense.dispatches);
        let drift = (full.total_cycles as i64 - dense.total_cycles as i64).abs();
        assert!(drift <= 2, "full-length adaptive drifted by {drift}");
        // …and the adaptive estimate at the top bucket is the same thing.
        let adaptive = estimate_adaptive(&cfg, &f, cfg.seq_len, OptLevel::O0).unwrap();
        assert_eq!(adaptive.dispatches, dense.dispatches);
    }

    #[test]
    fn short_requests_price_strictly_below_the_dense_maximum() {
        use crate::accel::schedule::OptLevel;
        let f = fc();
        for cfg in [TnnConfig::encoder(128, 256, 4, 2), TnnConfig::encoder(64, 512, 8, 4)] {
            let dense = estimate(&cfg, &f, AttentionMode::Split, false, false).unwrap();
            for level in [OptLevel::O0, OptLevel::O1, OptLevel::O2] {
                // The ISSUE acceptance bound: a request at ≤ seq_len/4
                // must price strictly below the dense max-length program.
                let quarter = estimate_adaptive(&cfg, &f, cfg.seq_len / 4, level).unwrap();
                assert!(
                    quarter.total_cycles < dense.total_cycles,
                    "{cfg} {level:?}: quarter={} dense={}",
                    quarter.total_cycles,
                    dense.total_cycles
                );
            }
        }
    }

    #[test]
    fn adaptive_estimates_are_monotone_in_request_length() {
        use crate::accel::schedule::OptLevel;
        let f = fc();
        let cfg = TnnConfig::encoder(128, 256, 4, 2);
        let mut last = 0;
        for rows in [16, 32, 64, 128] {
            let rep = estimate_adaptive(&cfg, &f, rows, OptLevel::O0).unwrap();
            assert!(
                rep.total_cycles >= last,
                "rows={rows}: {} < previous {last}",
                rep.total_cycles
            );
            last = rep.total_cycles;
        }
    }

    #[test]
    fn skipped_tiers_cost_zero_and_fired_tiers_scale_quadratically() {
        let f = fc();
        let cfg = TnnConfig::encoder(128, 256, 4, 1);
        let prog = ScheduleBuilder::new(f, cfg).unwrap().skippable(true).build();
        assert!(prog.predicated_dispatch_count() > 0);
        let dense = replay_program_live(&prog, cfg.seq_len).unwrap();
        let short = replay_program_live(&prog, 16).unwrap();
        // live=16 fires the bottom tier only — same dispatch count as the
        // dense replay (one chain either way), strictly fewer cycles.
        assert_eq!(short.dispatches, dense.dispatches);
        assert!(short.total_cycles < dense.total_cycles);
        // The fired qk tier prices at (16/128)² of the table cost.
        let qk_dense = dense.per_artifact.get("qk_scores").unwrap().cycles;
        let qk_short = short.per_artifact.get("qk_scores").unwrap().cycles;
        let expect = qk_dense / 64.0;
        assert!(
            (qk_short - expect).abs() <= qk_dense * 1e-9 + cfg.heads as f64,
            "qk_short={qk_short} expected≈{expect}"
        );
    }

    #[test]
    fn seq2seq_adaptive_prefill_skips_self_attention_but_not_cross() {
        use crate::accel::schedule::OptLevel;
        let f = fc();
        let cfg = crate::model::presets::seq2seq_small(64, 2, 2);
        let dense = estimate_prefill(&cfg, &f).unwrap();
        let short = estimate_prefill_adaptive(&cfg, &f, 16, OptLevel::O0).unwrap();
        assert!(short.total_cycles < dense.total_cycles);
        // The cross-attention chains stay dense: per layer per head one
        // cross qk at full price survives in the trace either way.
        let qk = short.per_artifact.get("qk_scores").unwrap().count;
        assert_eq!(qk as usize, cfg.dec_layers * cfg.heads * 2, "one self + one cross per head");
    }

    #[test]
    fn prefill_of_a_seq2seq_topology_prices_both_attention_flavors() {
        let f = fc();
        let cfg = crate::model::presets::seq2seq_small(64, 2, 2);
        let pre = estimate_prefill(&cfg, &f).unwrap();
        // self + cross chains both walk the split artifacts
        let qk = pre.per_artifact.get("qk_scores").unwrap().count;
        assert_eq!(qk as usize, cfg.dec_layers * cfg.heads * 2, "self + cross per head per layer");
        // decoder-only prefill has no cross chain
        let solo = crate::model::presets::gpt_small(64, 2);
        let ps = estimate_prefill(&solo, &f).unwrap();
        assert_eq!(
            ps.per_artifact.get("qk_scores").unwrap().count as usize,
            solo.dec_layers * solo.heads
        );
    }
}
