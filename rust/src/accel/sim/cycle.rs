//! The cycle backend: replays a [`TileProgram`] to predict fabric cycles.
//!
//! This is the AccelTran discipline — drive the cycle model from the
//! *same* instruction stream the real datapath executes — applied to
//! Table 2: instead of a second hand-maintained schedule inside
//! [`super::simulate`], the backend walks the program the PJRT executor
//! replays and prices every dispatch with the iteration-level loop-nest
//! models of [`super::pipeline`].
//!
//! Pricing maps substrate dispatches back onto hardware module timelines:
//! heads run in parallel on the fabric (one head's timeline is the
//! block's), so the `h` per-head dispatches of one module share that
//! module's cycles; weight-panel loads double-buffer against compute
//! ([`super::pipeline::double_buffered`]); and the host↔device shuffles of
//! the software substrate (panel re-assembly) cost nothing — on the
//! hardware those moves happen inside BRAM.  The one-time input load
//! (Algorithm 1) is charged per replay, not per upload.
//!
//! Buffers are bare shapes; numerics never happen here, which is what lets
//! cycle estimation run without an artifact set.

use std::cell::RefCell;
use std::collections::{BTreeMap, HashMap};

use anyhow::bail;

use crate::accel::latency::depths::{LOAD, STORE};
use crate::accel::schedule::{
    self, AttentionMode, FabricConstants, ScheduleBuilder, TileProgram, WeightKind, WeightRef,
    WeightSource,
};
use crate::model::TnnConfig;
use crate::runtime::{backend::FabricBackend, Tensor};

use super::pipeline::{nest, PipelinedLoop};

/// Per-artifact accounting for one replay.
#[derive(Debug, Clone, Copy, Default)]
pub struct ArtifactCycles {
    pub count: u64,
    pub cycles: f64,
}

/// The outcome of replaying a program through the cycle backend.
#[derive(Debug, Clone)]
pub struct CycleReport {
    /// Predicted fabric cycles for one request (input load + layer stack,
    /// decoder layers charged at the simulator's 1.6× encoder rate).
    pub total_cycles: u64,
    pub dispatches: u64,
    pub uploads: u64,
    pub fetches: u64,
    /// Artifact names in dispatch order — compared against the PJRT
    /// executor's trace of the identical program in the equivalence tests.
    pub trace: Vec<String>,
    pub per_artifact: BTreeMap<String, ArtifactCycles>,
}

impl CycleReport {
    pub fn ms_at(&self, freq_mhz: f64) -> f64 {
        self.total_cycles as f64 / (freq_mhz * 1e3)
    }
}

#[derive(Debug, Default)]
struct CycleState {
    cycles: f64,
    dispatches: u64,
    uploads: u64,
    fetches: u64,
    trace: Vec<String>,
    per_artifact: BTreeMap<String, ArtifactCycles>,
}

/// A [`FabricBackend`] whose buffers are bare shapes and whose dispatches
/// accrue predicted cycles from a per-artifact cost table derived from the
/// iteration-level simulator for one `(topology, fabric)` pair.
pub struct CycleBackend {
    costs: HashMap<&'static str, f64>,
    load_inputs: u64,
    /// Decoder-stack surcharge (1.6× an encoder layer, as in
    /// [`super::simulate`]), fixed at construction.
    dec_cycles: f64,
    state: RefCell<CycleState>,
}

impl CycleBackend {
    pub fn new(cfg: &TnnConfig, fc: &FabricConstants) -> Self {
        let tiles = fc.tile_config();
        let sim = super::simulate(cfg, &tiles);
        let l = &sim.layer;
        let h = cfg.heads as f64;
        let t_m = (cfg.d_model / fc.ts_mha) as f64;
        let t_f = (cfg.d_model / fc.ts_ffn) as f64;
        let t_h = (cfg.hidden / fc.ffn_col) as f64;
        let attn_tail = (l.score + l.softmax + l.sv) as f64;
        // int8 QDQ pass over the valid embedding prefix (not part of the
        // paper's fp16 timeline; only the quantized mode dispatches it).
        let qdq = nest(
            cfg.seq_len as u64,
            PipelinedLoop { depth: LOAD + 3 + STORE, ii: 1, trip: cfg.d_model as u64 },
        ) as f64;
        let costs = HashMap::from([
            ("mm_qkv", l.qkv_total as f64 / (3.0 * h * t_m)),
            ("mm_qkv_packed", l.qkv_total as f64 / (h * t_m)),
            ("bias_add_dk", l.bias_qkv as f64 / (3.0 * h)),
            ("bias_add_qkv", l.bias_qkv as f64 / h),
            ("qk_scores", l.score as f64 / h),
            ("softmax", l.softmax as f64 / h),
            ("sv", l.sv as f64 / h),
            ("attn_fused", attn_tail / h),
            ("attn_packed", attn_tail / h),
            ("mm_ffn1", l.ffn1_total as f64 / (t_f * t_f)),
            ("mm_ffn2", l.ffn2_total as f64 / (t_f * t_h)),
            ("mm_ffn3", l.ffn3_total as f64 / (t_f * t_h)),
            ("bias_add_d", l.bias_ffn1 as f64),
            ("bias_relu_h", l.bias_ffn2 as f64),
            ("residual_ln", l.ln1 as f64),
            ("quantize", qdq),
        ]);
        CycleBackend {
            costs,
            load_inputs: sim.load_inputs,
            dec_cycles: l.total() as f64 * 1.6 * cfg.dec_layers as f64,
            state: RefCell::new(CycleState::default()),
        }
    }

    /// The prediction for everything replayed so far (plus the one-time
    /// input load and any decoder surcharge).
    pub fn report(&self) -> CycleReport {
        let st = self.state.borrow();
        let total = self.load_inputs as f64 + st.cycles + self.dec_cycles;
        CycleReport {
            total_cycles: total.round() as u64,
            dispatches: st.dispatches,
            uploads: st.uploads,
            fetches: st.fetches,
            trace: st.trace.clone(),
            per_artifact: st.per_artifact.clone(),
        }
    }
}

impl FabricBackend for CycleBackend {
    type Buf = Vec<usize>;

    fn upload(&self, t: &Tensor) -> anyhow::Result<Vec<usize>> {
        self.state.borrow_mut().uploads += 1;
        Ok(t.shape.clone())
    }

    fn dispatch(
        &self,
        artifact: &str,
        _inputs: &[&Vec<usize>],
        out_shape: &[usize],
    ) -> anyhow::Result<Vec<usize>> {
        let Some(cost) = self.costs.get(artifact).copied() else {
            bail!("cycle backend has no cost model for artifact '{artifact}'");
        };
        let mut st = self.state.borrow_mut();
        st.cycles += cost;
        st.dispatches += 1;
        st.trace.push(artifact.to_string());
        let e = st.per_artifact.entry(artifact.to_string()).or_default();
        e.count += 1;
        e.cycles += cost;
        Ok(out_shape.to_vec())
    }

    fn fetch(&self, buf: &Vec<usize>) -> anyhow::Result<Tensor> {
        self.state.borrow_mut().fetches += 1;
        Ok(Tensor::zeros(buf.clone()))
    }
}

/// Shape-only stand-ins for a prepared weight stack: every reference
/// resolves to the fabric-fixed panel shape of its kind.
pub struct ShapeWeights {
    mha_panel: Vec<usize>,
    qkv_panel: Vec<usize>,
    bias_dk: Vec<usize>,
    bias_qkv3: Vec<usize>,
    wo: Vec<usize>,
    vec_d: Vec<usize>,
    w1: Vec<usize>,
    vec_h: Vec<usize>,
    w2: Vec<usize>,
}

impl ShapeWeights {
    pub fn new(fc: &FabricConstants) -> Self {
        ShapeWeights {
            mha_panel: vec![fc.ts_mha, fc.dk],
            qkv_panel: vec![fc.ts_mha, 3 * fc.dk],
            bias_dk: vec![fc.dk],
            bias_qkv3: vec![3 * fc.dk],
            wo: vec![fc.ts_ffn, fc.ts_ffn],
            vec_d: vec![fc.dmodel_max],
            w1: vec![fc.ts_ffn, fc.ffn_col],
            vec_h: vec![fc.hidden_max],
            w2: vec![fc.ffn_col, fc.ts_ffn],
        }
    }
}

impl WeightSource<Vec<usize>> for ShapeWeights {
    fn weight(&self, r: &WeightRef) -> anyhow::Result<&Vec<usize>> {
        Ok(match r.kind {
            WeightKind::Wq | WeightKind::Wk | WeightKind::Wv => &self.mha_panel,
            WeightKind::QkvPacked => &self.qkv_panel,
            WeightKind::Bq | WeightKind::Bk | WeightKind::Bv => &self.bias_dk,
            WeightKind::BQkvPacked => &self.bias_qkv3,
            WeightKind::Wo => &self.wo,
            WeightKind::Bo
            | WeightKind::B2
            | WeightKind::G1
            | WeightKind::B1n
            | WeightKind::G2
            | WeightKind::B2n => &self.vec_d,
            WeightKind::W1 => &self.w1,
            WeightKind::B1 => &self.vec_h,
            WeightKind::W2 => &self.w2,
        })
    }
}

/// Replay an already-built program through the cycle backend.  Needs no
/// artifact set: buffers are shapes, weights are shape stand-ins.
pub fn replay_program(prog: &TileProgram) -> anyhow::Result<CycleReport> {
    let backend = CycleBackend::new(&prog.cfg, &prog.fabric);
    let weights = ShapeWeights::new(&prog.fabric);
    let runtime = schedule::build_runtime(&backend, &prog.cfg, &prog.fabric)?;
    let input = Tensor::zeros(vec![prog.fabric.sl_max, prog.fabric.dmodel_max]);
    schedule::replay(prog, &backend, &weights, &runtime, input)?;
    Ok(backend.report())
}

/// Build the program for `(cfg, fc, flags)` and replay it for cycles —
/// the one-call schedule-grounded latency estimate.
pub fn estimate(
    cfg: &TnnConfig,
    fc: &FabricConstants,
    mode: AttentionMode,
    qkv_packed: bool,
    quantized: bool,
) -> anyhow::Result<CycleReport> {
    let prog = ScheduleBuilder::new(*fc, *cfg)?
        .mode(mode)
        .qkv_packed(qkv_packed)
        .quantized(quantized)
        .build();
    replay_program(&prog)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::latency;

    fn fc() -> FabricConstants {
        FabricConstants::artifact_default()
    }

    fn rel_err(a: u64, b: u64) -> f64 {
        (a as f64 - b as f64).abs() / b as f64
    }

    #[test]
    fn schedule_replay_matches_analytical_within_table2_band() {
        // the acceptance band: the program-driven estimate must sit within
        // the Table 2 error band of the closed form (report gate: < 6%).
        let f = fc();
        let tiles = f.tile_config();
        for cfg in [
            TnnConfig::encoder(64, 768, 12, 12),
            TnnConfig::encoder(128, 768, 12, 12),
            TnnConfig::encoder(64, 512, 8, 12),
            TnnConfig::encoder(32, 256, 4, 2),
        ] {
            let est = estimate(&cfg, &f, AttentionMode::Split, false, false).unwrap();
            let ana = latency::model_latency(&cfg, &tiles);
            let err = rel_err(est.total_cycles, ana.total_cycles);
            assert!(
                err < 0.06,
                "{cfg}: replay={} analytical={} err={err:.4}",
                est.total_cycles,
                ana.total_cycles
            );
        }
    }

    #[test]
    fn schedule_replay_agrees_with_the_iteration_simulator() {
        // same schedule, same pricing primitives: the replayed total must
        // land on the simulator's (the costs are derived from it).
        let f = fc();
        let tiles = f.tile_config();
        for cfg in [TnnConfig::encoder(64, 768, 12, 12), TnnConfig::encoder(48, 128, 2, 3)] {
            let est = estimate(&cfg, &f, AttentionMode::Split, false, false).unwrap();
            let sim = super::super::simulate(&cfg, &tiles);
            let err = rel_err(est.total_cycles, sim.total_cycles);
            assert!(err < 0.005, "{cfg}: replay={} sim={}", est.total_cycles, sim.total_cycles);
        }
    }

    #[test]
    fn packed_and_fused_schedules_stay_in_band() {
        let f = fc();
        let tiles = f.tile_config();
        let cfg = TnnConfig::encoder(64, 512, 8, 4);
        let ana = latency::model_latency(&cfg, &tiles).total_cycles;
        for (mode, packed) in [
            (AttentionMode::Fused, false),
            (AttentionMode::Split, true),
            (AttentionMode::Fused, true),
        ] {
            let est = estimate(&cfg, &f, mode, packed, false).unwrap();
            let err = rel_err(est.total_cycles, ana);
            assert!(err < 0.06, "mode={mode:?} packed={packed}: err={err:.4}");
        }
    }

    #[test]
    fn trace_covers_every_dispatch_of_the_program() {
        let f = fc();
        let cfg = TnnConfig::encoder(32, 256, 4, 2);
        let prog = ScheduleBuilder::new(f, cfg).unwrap().build();
        let rep = replay_program(&prog).unwrap();
        assert_eq!(rep.dispatches as usize, prog.dispatch_count());
        assert_eq!(rep.trace.len(), prog.dispatch_count());
        let want: Vec<String> =
            prog.dispatch_sequence().iter().map(|s| s.to_string()).collect();
        assert_eq!(rep.trace, want);
        assert_eq!(rep.uploads as usize, prog.upload_count() + 8, "+8 runtime tensors");
        assert_eq!(rep.fetches as usize, prog.fetch_count());
    }

    #[test]
    fn quantized_schedule_costs_more() {
        let f = fc();
        let cfg = TnnConfig::encoder(64, 256, 4, 2);
        let plain = estimate(&cfg, &f, AttentionMode::Split, false, false).unwrap();
        let quant = estimate(&cfg, &f, AttentionMode::Split, false, true).unwrap();
        assert!(quant.total_cycles > plain.total_cycles);
        assert!(quant.per_artifact.contains_key("quantize"));
    }

    #[test]
    fn decoder_layers_carry_the_simulator_surcharge() {
        let f = fc();
        let tiles = f.tile_config();
        let mut cfg = TnnConfig::encoder(64, 512, 8, 2);
        cfg.dec_layers = 2;
        let est = estimate(&cfg, &f, AttentionMode::Split, false, false).unwrap();
        let sim = super::super::simulate(&cfg, &tiles);
        assert!(rel_err(est.total_cycles, sim.total_cycles) < 0.005);
    }
}
