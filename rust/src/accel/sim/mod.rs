//! Cycle-level simulator of the ADAPTOR fabric — the "experimental" column
//! of Table 2 on this substrate.
//!
//! Where `accel::latency` evaluates the paper's closed-form equations, this
//! module *executes* the module schedule: every loop nest of Algorithms
//! 1–17 is simulated iteration by iteration ([`pipeline`]), double-buffered
//! load/compute overlap is an explicit two-engine timeline, and outer loops
//! pay the HLS control cycles the closed form ignores.  Agreement between
//! the two within a couple of percent reproduces the paper's validation
//! claim (≤1.8 % latency error, Table 2).
//!
//! [`cycle`] closes the loop with execution: it replays the *same*
//! `TileProgram` the PJRT engine runs, pricing each dispatch with this
//! module's loop-nest models, so schedule and simulation cannot drift.

pub mod cycle;
pub mod pipeline;
pub mod trace;

use super::latency::depths::*;
use super::tiling::TileConfig;
use crate::model::TnnConfig;
use pipeline::{double_buffered, nest, PipelinedLoop};
use trace::{Event, Trace};

/// Per-module simulated cycles for one encoder layer.
#[derive(Debug, Clone, Copy, Default)]
pub struct SimLayer {
    pub qkv_total: u64,
    /// One QKV tile visit (Table 2's "Attention Module (SA)" granularity).
    pub sa_visit: u64,
    /// One weight-panel load (Table 2's "Load Weights Unit (LWA)").
    pub lwa_visit: u64,
    pub bias_qkv: u64,
    pub score: u64,
    pub softmax: u64,
    pub sv: u64,
    pub ffn1_total: u64,
    /// One FFN pipelined pass over the hidden-side width (Table 2's "FFN
    /// Module (FFN1)" granularity).
    pub ffn_visit: u64,
    pub ln1: u64,
    pub ffn2_total: u64,
    pub ffn3_total: u64,
    pub ln2: u64,
    pub bias_ffn1: u64,
    pub bias_ffn2: u64,
    pub bias_ffn3: u64,
}

impl SimLayer {
    pub fn total(&self) -> u64 {
        self.qkv_total
            + self.bias_qkv
            + self.score
            + self.softmax
            + self.sv
            + self.ffn1_total
            + self.ln1
            + self.ffn2_total
            + self.ffn3_total
            + self.ln2
            + self.bias_ffn1
            + self.bias_ffn2
            + self.bias_ffn3
    }
}

/// Whole-model simulation result.
#[derive(Debug, Clone)]
pub struct SimReport {
    pub load_inputs: u64,
    pub layer: SimLayer,
    pub total_cycles: u64,
    pub trace: Trace,
}

impl SimReport {
    pub fn ms_at(&self, freq_mhz: f64) -> f64 {
        self.total_cycles as f64 / (freq_mhz * 1e3)
    }
}

/// Simulate one QKV tile visit's compute nest (Algorithm 9): outer SL
/// (pipeline off), middle d/h at II=1, inner tile-width unrolled into the
/// accumulation chain (depth = TS_MHA + extra).
fn sim_qkv_visit(cfg: &TnnConfig, tiles: &TileConfig) -> u64 {
    let inner = PipelinedLoop {
        depth: tiles.ts_mha as u64 + PD_MHA_EXTRA,
        ii: 1,
        trip: cfg.dk() as u64,
    };
    nest(cfg.seq_len as u64, inner)
}

/// Simulate one weight-panel load (Algorithm 2 shape).
fn sim_lwa_visit(cfg: &TnnConfig, tiles: &TileConfig) -> u64 {
    let inner = PipelinedLoop { depth: PD_L, ii: 1, trip: cfg.dk() as u64 };
    nest(tiles.ts_mha as u64, inner)
}

/// Simulate one head-input-panel load (Algorithm 1).
fn sim_lia_visit(cfg: &TnnConfig, tiles: &TileConfig) -> u64 {
    let width = (cfg.d_model / tiles.tiles_mha(cfg.d_model)).max(1) as u64;
    nest(cfg.seq_len as u64, PipelinedLoop { depth: PD_L, ii: 1, trip: width })
}

/// Simulate one FFN pipelined pass (Algorithms 13/14/10) over `width`
/// output columns at II_FFN.
fn sim_ffn_visit(cfg: &TnnConfig, width: u64) -> u64 {
    nest(cfg.seq_len as u64, PipelinedLoop { depth: PD_FFN, ii: II_FFN, trip: width })
}

/// Simulate an FFN weight-panel load.
fn sim_ffn_wload(rows: u64, cols: u64) -> u64 {
    nest(rows, PipelinedLoop { depth: PD_L, ii: 1, trip: cols })
}

/// Simulate the LN unit (Algorithm 8's four passes + residual).
fn sim_ln(cfg: &TnnConfig) -> u64 {
    let d = cfg.d_model as u64;
    let sl = cfg.seq_len as u64;
    let residual = nest(sl, PipelinedLoop { depth: PD_BA, ii: 1, trip: d });
    let mean = nest(sl, PipelinedLoop { depth: LOAD + 1 + STORE, ii: 2, trip: d });
    let var = nest(sl, PipelinedLoop { depth: LOAD + 2 + STORE, ii: 2, trip: d });
    let norm = nest(sl, PipelinedLoop { depth: LOAD + 2 + STORE + DIV + 3, ii: 1, trip: d });
    let affine = nest(sl, PipelinedLoop { depth: LOAD + 3 + STORE, ii: 1, trip: d });
    residual + mean + var + norm + affine
}

/// Simulate the full model.
pub fn simulate(cfg: &TnnConfig, tiles: &TileConfig) -> SimReport {
    let mut trace = Trace::new();
    let sl = cfg.seq_len as u64;
    let d = cfg.d_model as u64;
    let dk = cfg.dk() as u64;
    let hid = cfg.hidden as u64;
    let t_ffn = tiles.tiles_ffn(cfg.d_model) as u64;

    // One-time input load (Algorithm 1 over the full embedding width).
    let li = nest(sl, PipelinedLoop { depth: PD_L, ii: 1, trip: d });
    trace.push(Event::span("load_inputs", 0, li));

    // ---- attention (heads in parallel; one head's timeline is the block's)
    let visits = tiles.mha_tile_visits(cfg) as u64;
    let sa_visit = sim_qkv_visit(cfg, tiles);
    let lwa_visit = sim_lwa_visit(cfg, tiles);
    let lia_visit = sim_lia_visit(cfg, tiles);
    let (qkv_total, ..) = double_buffered(visits, lia_visit + lwa_visit, sa_visit);

    let bias_qkv = nest(sl, PipelinedLoop { depth: PD_BA, ii: 1, trip: dk });
    let score = nest(sl, PipelinedLoop { depth: dk, ii: 1, trip: sl });
    let softmax = nest(sl, PipelinedLoop { depth: LOAD + STORE, ii: 1, trip: sl })
        + nest(sl, PipelinedLoop { depth: EXP + LOAD + STORE, ii: 1, trip: sl })
        + nest(sl, PipelinedLoop { depth: DIV + LOAD + STORE, ii: 1, trip: sl });
    let sv = nest(dk, PipelinedLoop { depth: sl, ii: 1, trip: sl });

    // ---- FFN chain
    let w1 = (d / t_ffn).max(1);
    let wh = (hid / t_ffn).max(1);
    let ffn1_visits = tiles.ffn1_visits(cfg) as u64;
    let ffn23_visits = tiles.ffn23_visits(cfg) as u64;

    let ffn1_load = sim_ffn_wload(w1, w1) + nest(sl, PipelinedLoop { depth: PD_L, ii: 1, trip: w1 });
    let ffn1_visit = sim_ffn_visit(cfg, w1);
    let (ffn1_total, ..) = double_buffered(ffn1_visits, ffn1_load, ffn1_visit);

    let ffn2_load = sim_ffn_wload(w1, wh) + nest(sl, PipelinedLoop { depth: PD_L, ii: 1, trip: w1 });
    let ffn2_visit = sim_ffn_visit(cfg, wh);
    let (ffn2_total, ..) = double_buffered(ffn23_visits, ffn2_load, ffn2_visit);

    let ffn3_load = sim_ffn_wload(w1, wh) + nest(sl, PipelinedLoop { depth: PD_L, ii: 1, trip: wh });
    let ffn3_visit = sim_ffn_visit(cfg, w1);
    let (ffn3_total, ..) = double_buffered(ffn23_visits, ffn3_load, ffn3_visit);

    let ln = sim_ln(cfg);
    let bias_d = nest(sl, PipelinedLoop { depth: PD_BA, ii: 1, trip: d });
    let bias_h = nest(sl, PipelinedLoop { depth: PD_BA, ii: 1, trip: hid });

    let layer = SimLayer {
        qkv_total,
        sa_visit,
        lwa_visit,
        bias_qkv,
        score,
        softmax,
        sv,
        ffn1_total,
        ffn_visit: sim_ffn_visit(cfg, w1),
        ln1: ln,
        ffn2_total,
        ffn3_total,
        ln2: ln,
        bias_ffn1: bias_d,
        bias_ffn2: bias_h,
        bias_ffn3: bias_d,
    };

    let mut t = li;
    for l in 0..cfg.enc_layers {
        trace.push(Event::span(&format!("enc_layer_{l}"), t, layer.total()));
        t += layer.total();
    }
    for l in 0..cfg.dec_layers {
        let dec = (layer.total() as f64 * 1.6) as u64;
        trace.push(Event::span(&format!("dec_layer_{l}"), t, dec));
        t += dec;
    }

    SimReport { load_inputs: li, layer, total_cycles: t, trace }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::latency;
    use crate::model::presets;

    #[test]
    fn sim_matches_analytical_within_3pct_on_table2_configs() {
        // the paper's validation claim (≤1.8% latency error) — our two
        // independent implementations must agree comparably.
        for (sl, d, tm, tf) in [(64, 768, 64, 128), (128, 768, 64, 128), (64, 512, 64, 128)] {
            let cfg = TnnConfig::encoder(sl, d, 8, 12);
            let t = TileConfig::new(tm, tf);
            let sim = simulate(&cfg, &t);
            let ana = latency::model_latency(&cfg, &t);
            let err = (sim.total_cycles as f64 - ana.total_cycles as f64).abs()
                / ana.total_cycles as f64;
            assert!(err < 0.03, "sl={sl} d={d}: sim={} ana={} err={err:.4}",
                sim.total_cycles, ana.total_cycles);
        }
    }

    #[test]
    fn sa_visit_matches_analytical_within_3pct() {
        let cfg = TnnConfig::encoder(64, 768, 8, 12);
        let t = TileConfig::paper_optimum();
        let sim = simulate(&cfg, &t);
        let ana = latency::attention::qkv_tile(&cfg, &t);
        let err = (sim.layer.sa_visit as f64 - ana as f64).abs() / ana as f64;
        assert!(err < 0.03, "sim={} ana={ana}", sim.layer.sa_visit);
    }

    #[test]
    fn sim_is_not_identical_to_analytical() {
        // it must be an independent implementation: close but never equal
        // (control overhead vs tighter double-buffer overlap).
        let cfg = presets::paper_default();
        let t = TileConfig::paper_optimum();
        let sim = simulate(&cfg, &t);
        let ana = latency::model_latency(&cfg, &t);
        assert_ne!(sim.total_cycles, ana.total_cycles);
    }

    #[test]
    fn trace_covers_all_layers() {
        let cfg = presets::small_encoder(64, 4);
        let sim = simulate(&cfg, &TileConfig::paper_optimum());
        let spans = sim.trace.events.iter().filter(|e| e.name.starts_with("enc_layer")).count();
        assert_eq!(spans, 4);
    }

    #[test]
    fn decoder_layers_simulated_longer() {
        let t = TileConfig::paper_optimum();
        let enc = simulate(&TnnConfig::encoder(64, 512, 8, 2), &t);
        let mut cfg = TnnConfig::encoder(64, 512, 8, 0);
        cfg.dec_layers = 2;
        let dec = simulate(&cfg, &t);
        assert!(dec.total_cycles > enc.total_cycles);
    }

    #[test]
    fn more_tiles_more_cycles() {
        // smaller tiles → more visits → more pipeline fills and control.
        let cfg = presets::paper_default();
        let few = simulate(&cfg, &TileConfig::new(128, 192)).total_cycles;
        let many = simulate(&cfg, &TileConfig::new(32, 64)).total_cycles;
        assert!(many > few);
    }
}
