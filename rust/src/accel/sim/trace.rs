//! Simulation event trace — the substrate's answer to the paper's
//! AXI-TIMER instrumentation (§4): start/stop spans per module, renderable
//! as a text Gantt chart.

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Event {
    pub name: String,
    pub start: u64,
    pub cycles: u64,
}

impl Event {
    pub fn span(name: &str, start: u64, cycles: u64) -> Self {
        Event { name: name.to_string(), start, cycles }
    }

    pub fn end(&self) -> u64 {
        self.start + self.cycles
    }
}

#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub events: Vec<Event>,
}

impl Trace {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, e: Event) {
        self.events.push(e);
    }

    pub fn total_span(&self) -> u64 {
        self.events.iter().map(Event::end).max().unwrap_or(0)
    }

    /// Render a proportional text Gantt chart, `width` characters wide.
    pub fn gantt(&self, width: usize) -> String {
        let span = self.total_span().max(1) as f64;
        let mut out = String::new();
        for e in &self.events {
            let off = (e.start as f64 / span * width as f64) as usize;
            let len = ((e.cycles as f64 / span * width as f64) as usize).max(1);
            out.push_str(&format!(
                "{:<16} {}{} {} cc\n",
                e.name,
                " ".repeat(off.min(width)),
                "#".repeat(len.min(width.saturating_sub(off))),
                e.cycles
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn span_accounting() {
        let mut t = Trace::new();
        t.push(Event::span("a", 0, 10));
        t.push(Event::span("b", 10, 5));
        assert_eq!(t.total_span(), 15);
        assert_eq!(t.events[1].end(), 15);
    }

    #[test]
    fn gantt_renders_every_event() {
        let mut t = Trace::new();
        t.push(Event::span("load", 0, 100));
        t.push(Event::span("compute", 100, 300));
        let g = t.gantt(40);
        assert!(g.contains("load"));
        assert!(g.contains("compute"));
        assert!(g.lines().count() == 2);
    }

    #[test]
    fn empty_trace_is_harmless() {
        let t = Trace::new();
        assert_eq!(t.total_span(), 0);
        assert_eq!(t.gantt(10), "");
    }
}
