//! Roofline model (Fig 12): peak compute bound from the PE arrays, memory
//! bound from the AXI/HBM streaming model, and attained-performance points
//! per workload.
//!
//! The paper's compute bound is 0.053 TOPS — the *effective* peak of the
//! module pipeline (modules run sequentially, so the fabric's peak is the
//! busiest module's PE count, not the sum of all DSPs), and its memory
//! bound is the per-port weight-streaming rate (the "200 kB/s" axis label
//! is a typo for the per-element-per-cycle AXI stream; DESIGN.md §5).

use super::platform::Platform;
use super::tiling::TileConfig;
use crate::model::{ops, TnnConfig};

/// Effective peak compute (GOPS) of the synthesized fabric at `freq_mhz`:
/// the busiest processing module's MAC lanes × 2 ops × f.  With the paper's
/// default build the FFN2 module owns `hidden/T_ffn` lanes at II=2 and the
/// QKV modules `h·TS_mha·3/II` — the max of the module peaks.
pub fn peak_gops(cfg: &TnnConfig, tiles: &TileConfig, freq_mhz: f64) -> f64 {
    let t_ffn = tiles.tiles_ffn(cfg.d_model).max(1);
    let ffn_lanes = (cfg.hidden / t_ffn) as f64 / 2.0; // II=2
    let qkv_lanes = (cfg.heads * 3) as f64 * (cfg.dk() as f64).min(tiles.ts_mha as f64);
    let lanes = ffn_lanes.max(qkv_lanes / (tiles.tiles_mha(cfg.d_model) as f64));
    2.0 * lanes * freq_mhz / 1e3
}

/// Streaming (weight-load) bandwidth in bytes/s: one element per cycle per
/// loader port (Algorithms 1–6 are II=1 scalar streams), capped by the
/// platform's physical memory bandwidth.
pub fn stream_bytes_per_sec(platform: &Platform, freq_mhz: f64, bytes_per_elem: usize, ports: usize) -> f64 {
    let axi = freq_mhz * 1e6 * bytes_per_elem as f64 * ports as f64;
    axi.min(platform.memory.peak_bytes_per_sec())
}

/// One point on the roofline plot.
#[derive(Debug, Clone)]
pub struct RooflinePoint {
    pub name: String,
    /// Operational intensity, ops/byte.
    pub oi: f64,
    /// Attained GOPS (from the latency model at the build's frequency).
    pub attained_gops: f64,
    /// min(compute bound, oi × memory bound) — the ceiling at this OI.
    pub bound_gops: f64,
}

impl RooflinePoint {
    pub fn memory_bound(&self) -> bool {
        self.bound_gops < self.attained_gops.max(self.bound_gops) && {
            // bound_gops equals oi·BW when left of the ridge
            true
        }
    }
}

/// The full roofline: bounds plus one point per (name, cfg, attained GOPS).
#[derive(Debug, Clone)]
pub struct Roofline {
    pub peak_gops: f64,
    pub stream_gbps: f64,
    pub ridge_oi: f64,
    pub points: Vec<RooflinePoint>,
}

/// Build the roofline for a set of workloads on one synthesis.
pub fn roofline(
    platform: &Platform,
    tiles: &TileConfig,
    freq_mhz: f64,
    bytes_per_elem: usize,
    workloads: &[(&str, TnnConfig, f64)],
) -> Roofline {
    // Fabric peak: take the max over the workloads' effective peaks (the
    // fabric is sized by the synthesis maxima, not the runtime registers).
    let peak = workloads
        .iter()
        .map(|(_, c, _)| peak_gops(c, tiles, freq_mhz))
        .fold(0.0f64, f64::max);
    let bw = stream_bytes_per_sec(platform, freq_mhz, bytes_per_elem, 3);
    let mut points = Vec::new();
    for (name, cfg, attained) in workloads {
        let oi = ops::operational_intensity(cfg, bytes_per_elem);
        let bound = (oi * bw / 1e9).min(peak);
        points.push(RooflinePoint {
            name: name.to_string(),
            oi,
            attained_gops: *attained,
            bound_gops: bound,
        });
    }
    Roofline { peak_gops: peak, stream_gbps: bw / 1e9, ridge_oi: peak / (bw / 1e9), points }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::platform;
    use crate::model::presets;

    #[test]
    fn peak_is_same_order_as_paper_0_053_tops() {
        let cfg = presets::paper_default();
        let t = TileConfig::paper_optimum();
        let p = peak_gops(&cfg, &t, 200.0);
        // paper: 0.053 TOPS = 53 GOPS effective peak
        assert!(p > 25.0 && p < 210.0, "peak = {p}");
    }

    #[test]
    fn attained_never_exceeds_bound_for_model_latency() {
        let cfg = presets::bert_base(64);
        let t = TileConfig::paper_optimum();
        let lat = crate::accel::latency::model_latency(&cfg, &t);
        let attained = lat.gops_at(&cfg, 200.0);
        let r = roofline(&platform::u55c(), &t, 200.0, 4, &[("bert", cfg, attained)]);
        let pt = &r.points[0];
        assert!(
            pt.attained_gops <= pt.bound_gops * 1.15,
            "attained {} vs bound {}",
            pt.attained_gops,
            pt.bound_gops
        );
    }

    #[test]
    fn ridge_point_separates_regimes() {
        let cfg = presets::paper_default();
        let t = TileConfig::paper_optimum();
        let r = roofline(&platform::u55c(), &t, 200.0, 4, &[("bert", cfg, 30.0)]);
        assert!(r.ridge_oi > 0.0);
        // left of the ridge the bound is oi·bw
        let low_oi = r.ridge_oi / 10.0;
        assert!(low_oi * r.stream_gbps < r.peak_gops);
    }

    #[test]
    fn ddr_platform_has_lower_stream_bound_than_axi_when_capped() {
        // VC707 DDR3 (12.8 GB/s) cannot cap a 3-port 200 MHz f32 stream
        // (2.4 GB/s) — the AXI stream is the binding constraint, as the
        // paper's tiny memory bound implies.
        let v = stream_bytes_per_sec(&platform::vc707(), 200.0, 4, 3);
        assert!(v <= 12.8e9);
        assert!((v - 2.4e9).abs() < 1e6, "{v}");
    }

    #[test]
    fn quantization_moves_points_right() {
        let cfg = presets::bert_base(64);
        let oi32 = ops::operational_intensity(&cfg, 4);
        let oi8 = ops::operational_intensity(&cfg, 1);
        assert!(oi8 > oi32 * 3.9);
    }
}
