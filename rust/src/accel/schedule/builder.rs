//! Lowering: `(TnnConfig, FabricConstants, mode flags)` → [`TileProgram`].
//!
//! This is the code that used to live as imperative loop nests inside
//! `TileEngine::run_layer` (Algorithms 1–17 over the §3.9 tile schedules,
//! partial sums accumulating across column tiles per Fig 4a and 2-D tiles
//! per Fig 4b).  The builder emits exactly the artifact/operand sequence
//! the old engine dispatched — numerics are bit-identical — with one
//! scheduled improvement: each layer's residual operand references the
//! *device slot* of the previous layer's output instead of re-uploading
//! the full padded activation that was already resident (the fabric analog:
//! activations stay in BRAM between layers).

use super::{
    length_tiers, AttentionMode, FabricConstants, HostId, LivePred, Operand, RuntimeId, SlotId,
    Step, TileProgram, WeightKind, WeightRef,
};
use crate::accel::decode::ExternLayout;
use crate::model::TnnConfig;

/// Shorthand for a weight operand.
fn w(layer: usize, kind: WeightKind, row: usize, col: usize) -> Operand {
    Operand::Weight(WeightRef { layer, kind, row, col })
}

/// Builds a [`TileProgram`] for one topology on one fabric.
#[derive(Debug)]
pub struct ScheduleBuilder {
    fc: FabricConstants,
    cfg: TnnConfig,
    mode: AttentionMode,
    qkv_packed: bool,
    quantized: bool,
    skippable: bool,
    send_boundary: Option<usize>,
    recv_boundary: Option<usize>,
    steps: Vec<Step>,
    host_shapes: Vec<Vec<usize>>,
    n_slots: usize,
}

impl ScheduleBuilder {
    /// Validates `cfg` against the fabric constraints (the same checks the
    /// engine's `check_runtime_config` applies).
    pub fn new(fc: FabricConstants, cfg: TnnConfig) -> anyhow::Result<Self> {
        fc.check(&cfg).map_err(|e| anyhow::anyhow!(e))?;
        Ok(ScheduleBuilder {
            fc,
            cfg,
            mode: AttentionMode::Split,
            qkv_packed: false,
            quantized: false,
            skippable: false,
            send_boundary: None,
            recv_boundary: None,
            steps: Vec::new(),
            host_shapes: Vec::new(),
            n_slots: 0,
        })
    }

    pub fn mode(mut self, mode: AttentionMode) -> Self {
        self.mode = mode;
        self
    }

    pub fn qkv_packed(mut self, on: bool) -> Self {
        self.qkv_packed = on;
        self
    }

    pub fn quantized(mut self, on: bool) -> Self {
        self.quantized = on;
        self
    }

    /// Emit **skippable** attention chains: one copy per length tier of
    /// [`length_tiers`]`(seq_len)`, each behind a disjoint [`LivePred`]
    /// and fenced by that tier's mask, all converging on one shared
    /// output slot.  Replay fires exactly the tier covering the request's
    /// live row count; the rest are skipped (and priced at zero by the
    /// cycle backend).  Off by default — the lowering is then
    /// byte-identical to the legacy dense stream.
    pub fn skippable(mut self, on: bool) -> Self {
        self.skippable = on;
        self
    }

    /// Lower as a pipeline-shard **sender** over cut `boundary`: the
    /// stack's trailing fetch of the output activation becomes a
    /// [`Step::SendActivation`], so the replay return value is exactly
    /// the activation handed to the next shard's fabric.  Every shard of
    /// a chain except the tail sets this.
    pub fn send_activation(mut self, boundary: usize) -> Self {
        self.send_boundary = Some(boundary);
        self
    }

    /// Lower as a pipeline-shard **receiver** over cut `boundary`: a
    /// [`Step::RecvActivation`] of the input host is prepended, marking
    /// (and letting pricing backends charge) that the input activation
    /// arrives over the inter-fabric link rather than from the caller.
    /// Every shard of a chain except the head sets this.
    pub fn recv_activation(mut self, boundary: usize) -> Self {
        self.recv_boundary = Some(boundary);
        self
    }

    // ---- emission helpers ------------------------------------------------

    fn host(&mut self, shape: Vec<usize>) -> HostId {
        self.host_shapes.push(shape);
        self.host_shapes.len() - 1
    }

    fn slot(&mut self) -> SlotId {
        self.n_slots += 1;
        self.n_slots - 1
    }

    fn upload(&mut self, host: HostId) -> SlotId {
        let dst = self.slot();
        self.steps.push(Step::Upload { host, dst });
        dst
    }

    fn dispatch(
        &mut self,
        artifact: &'static str,
        args: Vec<Operand>,
        out_shape: Vec<usize>,
    ) -> SlotId {
        self.dispatch_pred(artifact, args, out_shape, None)
    }

    fn dispatch_pred(
        &mut self,
        artifact: &'static str,
        args: Vec<Operand>,
        out_shape: Vec<usize>,
        pred: Option<LivePred>,
    ) -> SlotId {
        let dst = self.slot();
        self.steps.push(Step::Dispatch { artifact, args, dst, out_shape, pred });
        dst
    }

    /// Predicated dispatch into a caller-chosen slot — how the tiers of
    /// one skippable chain share a single output slot (disjoint
    /// predicates: exactly one tier writes it per replay).
    fn dispatch_into(
        &mut self,
        artifact: &'static str,
        args: Vec<Operand>,
        dst: SlotId,
        out_shape: Vec<usize>,
        pred: Option<LivePred>,
    ) {
        self.steps.push(Step::Dispatch { artifact, args, dst, out_shape, pred });
    }

    /// The `(tier, predicate)` list of one skippable attention chain:
    /// predicates partition `(0, seq_len]`, so exactly one fires per
    /// request.  In dense mode (or when the grid degenerates to one
    /// tier) this is a single unpredicated entry — the legacy lowering.
    fn attn_tiers(&self) -> Vec<(usize, Option<LivePred>)> {
        if !self.skippable {
            return vec![(self.cfg.seq_len, None)];
        }
        let tiers = length_tiers(self.cfg.seq_len);
        if tiers.len() == 1 {
            return vec![(self.cfg.seq_len, None)];
        }
        let mut lo = 0usize;
        tiers
            .into_iter()
            .map(|t| {
                let pred = LivePred { lo, hi: t };
                lo = t;
                (t, Some(pred))
            })
            .collect()
    }

    /// The mask fencing attention at `tier` rows: the topology's own mask
    /// for the top tier (value-identical by construction), a
    /// [`RuntimeId::TierMask`] otherwise.
    fn tier_mask(&self, tier: usize, causal: bool) -> RuntimeId {
        if tier == self.cfg.seq_len {
            if causal {
                RuntimeId::CausalMask
            } else {
                RuntimeId::Mask
            }
        } else if causal {
            RuntimeId::TierCausalMask(tier as u16)
        } else {
            RuntimeId::TierMask(tier as u16)
        }
    }

    fn fetch(&mut self, src: SlotId, shape: Vec<usize>) -> HostId {
        let host = self.host(shape);
        self.steps.push(Step::Fetch { src, host });
        host
    }

    fn extract_upload(&mut self, src: HostId, c0: usize, width: usize) -> SlotId {
        let dst = self.host(vec![self.fc.sl_max, width]);
        self.steps.push(Step::ExtractPanel { src, c0, width, dst });
        self.upload(dst)
    }

    fn assemble(&mut self, src: HostId, dst: HostId, c0: usize) {
        self.steps.push(Step::AssemblePanel { src, dst, c0 });
    }

    /// One projection chain (Algorithm 9's per-head accumulation over the
    /// Fig 4a column tiles) followed by the bias add.
    fn project(
        &mut self,
        layer: usize,
        head: usize,
        x_panels: &[SlotId],
        wk: WeightKind,
        bk: WeightKind,
    ) -> SlotId {
        let out = vec![self.fc.sl_max, self.fc.dk];
        let mut acc = self.dispatch(
            "mm_qkv",
            vec![
                Operand::Slot(x_panels[0]),
                w(layer, wk, head, 0),
                Operand::Runtime(RuntimeId::ZeroDk),
            ],
            out.clone(),
        );
        for t in 1..x_panels.len() {
            acc = self.dispatch(
                "mm_qkv",
                vec![Operand::Slot(x_panels[t]), w(layer, wk, head, t), Operand::Slot(acc)],
                out.clone(),
            );
        }
        self.dispatch("bias_add_dk", vec![Operand::Slot(acc), w(layer, bk, head, 0)], out)
    }

    // ---- lowering --------------------------------------------------------

    /// Lower the whole encoder stack.
    pub fn build(mut self) -> TileProgram {
        let fc = self.fc;
        let cfg = self.cfg;
        let t_m = cfg.d_model / fc.ts_mha;
        let full = vec![fc.sl_max, fc.dmodel_max];

        // Algorithm 1: the padded input lands in host slot 0; the caller
        // writes it before replay.
        let input = self.host(full.clone());
        let mut x_host = input;
        let mut x_slot = self.upload(input);

        for layer in 0..cfg.enc_layers {
            // ---- MHA (Fig 2): input panels are shared across heads —
            // extract + upload once per tile.
            let x_panels: Vec<SlotId> =
                (0..t_m).map(|t| self.extract_upload(x_host, t * fc.ts_mha, fc.ts_mha)).collect();
            let attn = self.host(full.clone());
            if self.qkv_packed {
                // One dispatch per tile projects the head's Q|K|V
                // simultaneously (Algorithm 9's three MACs per cycle).
                let out3 = vec![fc.sl_max, 3 * fc.dk];
                for head in 0..cfg.heads {
                    let mut acc = self.dispatch(
                        "mm_qkv_packed",
                        vec![
                            Operand::Slot(x_panels[0]),
                            w(layer, WeightKind::QkvPacked, head, 0),
                            Operand::Runtime(RuntimeId::ZeroQkv3),
                        ],
                        out3.clone(),
                    );
                    for t in 1..t_m {
                        acc = self.dispatch(
                            "mm_qkv_packed",
                            vec![
                                Operand::Slot(x_panels[t]),
                                w(layer, WeightKind::QkvPacked, head, t),
                                Operand::Slot(acc),
                            ],
                            out3.clone(),
                        );
                    }
                    let qkv = self.dispatch(
                        "bias_add_qkv",
                        vec![Operand::Slot(acc), w(layer, WeightKind::BQkvPacked, head, 0)],
                        out3.clone(),
                    );
                    let o = self.slot();
                    for (tier, pred) in self.attn_tiers() {
                        let mask = self.tier_mask(tier, false);
                        self.dispatch_into(
                            "attn_packed",
                            vec![
                                Operand::Slot(qkv),
                                Operand::Runtime(mask),
                                Operand::Runtime(RuntimeId::Scale),
                            ],
                            o,
                            vec![fc.sl_max, fc.dk],
                            pred,
                        );
                    }
                    let oh = self.fetch(o, vec![fc.sl_max, fc.dk]);
                    self.assemble(oh, attn, head * fc.dk);
                }
            } else {
                for head in 0..cfg.heads {
                    let q = self.project(layer, head, &x_panels, WeightKind::Wq, WeightKind::Bq);
                    let k = self.project(layer, head, &x_panels, WeightKind::Wk, WeightKind::Bk);
                    let v = self.project(layer, head, &x_panels, WeightKind::Wv, WeightKind::Bv);
                    let o = match self.mode {
                        AttentionMode::Fused => {
                            let out = self.slot();
                            for (tier, pred) in self.attn_tiers() {
                                let mask = self.tier_mask(tier, false);
                                self.dispatch_into(
                                    "attn_fused",
                                    vec![
                                        Operand::Slot(q),
                                        Operand::Slot(k),
                                        Operand::Slot(v),
                                        Operand::Runtime(mask),
                                        Operand::Runtime(RuntimeId::Scale),
                                    ],
                                    out,
                                    vec![fc.sl_max, fc.dk],
                                    pred,
                                );
                            }
                            out
                        }
                        AttentionMode::Split => self.attn_chain_tiered(q, k, v, false),
                    };
                    let oh = self.fetch(o, vec![fc.sl_max, fc.dk]);
                    self.assemble(oh, attn, head * fc.dk);
                }
            }

            if self.quantized {
                // Per-tensor symmetric int8 QDQ on the attention output —
                // the scale is the program's only data-dependent value.
                let attn_slot = self.upload(attn);
                let scale = self.slot();
                self.steps.push(Step::CalibrateScale { src: attn, dst: scale });
                let q = self.dispatch(
                    "quantize",
                    vec![Operand::Slot(attn_slot), Operand::Slot(scale)],
                    full.clone(),
                );
                self.steps.push(Step::Fetch { src: q, host: attn });
            }

            // ---- FFN1_PM (output projection + first residual/LN; the
            // residual reads the previous layer's device-resident output —
            // no re-upload of the full padded activation), then the
            // FFN2/FFN3 chain + second residual/LN.  Shared with the
            // decoder prefill lowering.
            let (y_slot, y_host) = self.out_projection(
                layer,
                attn,
                x_slot,
                WeightKind::Wo,
                WeightKind::Bo,
                WeightKind::G1,
                WeightKind::B1n,
            );
            let (fin, fin_host) = self.ffn_block(layer, y_host, y_slot);
            x_host = fin_host;
            x_slot = fin;
        }

        self.finish(input, x_host, Vec::new(), Vec::new(), Vec::new())
    }

    /// Package the emitted stream into a finalized [`TileProgram`],
    /// applying any shard roles: a recv role prepends the boundary marker
    /// on the input host, a send role rewrites the stack's trailing fetch
    /// of the output activation into the boundary transfer.
    fn finish(
        mut self,
        input: HostId,
        output: HostId,
        aux_hosts: Vec<HostId>,
        extern_shapes: Vec<Vec<usize>>,
        export_slots: Vec<SlotId>,
    ) -> TileProgram {
        if let Some(boundary) = self.recv_boundary {
            self.steps.insert(0, Step::RecvActivation { host: input, boundary });
        }
        if let Some(boundary) = self.send_boundary {
            let hit = self.steps.iter_mut().rev().find_map(|s| match s {
                Step::Fetch { src, host } if *host == output => {
                    let (src, host) = (*src, *host);
                    *s = Step::SendActivation { src, host, boundary };
                    Some(())
                }
                _ => None,
            });
            assert!(hit.is_some(), "send-role program has no trailing fetch of the output host");
        }
        let mut prog = TileProgram {
            cfg: self.cfg,
            fabric: self.fc,
            steps: self.steps,
            host_shapes: self.host_shapes,
            n_slots: self.n_slots,
            input_host: input,
            aux_hosts,
            output_host: output,
            extern_shapes,
            export_slots,
            drops: Vec::new(),
            host_drops: Vec::new(),
            host_init: Vec::new(),
            waves: Vec::new(),
        };
        prog.finalize();
        prog
    }

    /// One split-attention chain over already-projected q/k/v slots.
    fn attn_chain(&mut self, q: SlotId, k: SlotId, v: SlotId, mask: RuntimeId) -> SlotId {
        let fc = self.fc;
        let s = self.dispatch(
            "qk_scores",
            vec![
                Operand::Slot(q),
                Operand::Slot(k),
                Operand::Runtime(mask),
                Operand::Runtime(RuntimeId::Scale),
            ],
            vec![fc.sl_max, fc.sl_max],
        );
        let p = self.dispatch("softmax", vec![Operand::Slot(s)], vec![fc.sl_max, fc.sl_max]);
        self.dispatch("sv", vec![Operand::Slot(p), Operand::Slot(v)], vec![fc.sl_max, fc.dk])
    }

    /// [`ScheduleBuilder::attn_chain`], once per length tier in skippable
    /// mode: every tier's `sv` converges on one shared output slot behind
    /// disjoint predicates.  Dense mode (single unpredicated tier)
    /// lowers exactly as the legacy chain.
    fn attn_chain_tiered(&mut self, q: SlotId, k: SlotId, v: SlotId, causal: bool) -> SlotId {
        let tiers = self.attn_tiers();
        if tiers.len() == 1 && tiers[0].1.is_none() {
            let mask = self.tier_mask(tiers[0].0, causal);
            return self.attn_chain(q, k, v, mask);
        }
        let fc = self.fc;
        let out = self.slot();
        for (tier, pred) in tiers {
            let mask = self.tier_mask(tier, causal);
            let s = self.dispatch_pred(
                "qk_scores",
                vec![
                    Operand::Slot(q),
                    Operand::Slot(k),
                    Operand::Runtime(mask),
                    Operand::Runtime(RuntimeId::Scale),
                ],
                vec![fc.sl_max, fc.sl_max],
                pred,
            );
            let p = self.dispatch_pred(
                "softmax",
                vec![Operand::Slot(s)],
                vec![fc.sl_max, fc.sl_max],
                pred,
            );
            self.dispatch_into(
                "sv",
                vec![Operand::Slot(p), Operand::Slot(v)],
                out,
                vec![fc.sl_max, fc.dk],
                pred,
            );
        }
        out
    }

    /// Output-projection block (the encoder's FFN1_PM shape): 2-D grid
    /// matmul of `src_host`'s panels against the `wo`/`bo` weights, then
    /// bias + residual LayerNorm against `res_slot` with the `g`/`b`
    /// affine pair.  Returns the normalized slot and its fetched host.
    #[allow(clippy::too_many_arguments)]
    fn out_projection(
        &mut self,
        layer: usize,
        src_host: HostId,
        res_slot: SlotId,
        wo: WeightKind,
        bo: WeightKind,
        g: WeightKind,
        b: WeightKind,
    ) -> (SlotId, HostId) {
        let fc = self.fc;
        let t_f = self.cfg.d_model / fc.ts_ffn;
        let full = vec![fc.sl_max, fc.dmodel_max];
        let panels: Vec<SlotId> =
            (0..t_f).map(|r| self.extract_upload(src_host, r * fc.ts_ffn, fc.ts_ffn)).collect();
        let proj = self.host(full.clone());
        for c in 0..t_f {
            let out = vec![fc.sl_max, fc.ts_ffn];
            let mut acc = self.dispatch(
                "mm_ffn1",
                vec![
                    Operand::Slot(panels[0]),
                    w(layer, wo, 0, c),
                    Operand::Runtime(RuntimeId::ZeroFfn),
                ],
                out.clone(),
            );
            for r in 1..t_f {
                acc = self.dispatch(
                    "mm_ffn1",
                    vec![Operand::Slot(panels[r]), w(layer, wo, r, c), Operand::Slot(acc)],
                    out.clone(),
                );
            }
            let h = self.fetch(acc, out);
            self.assemble(h, proj, c * fc.ts_ffn);
        }
        let proj_slot = self.upload(proj);
        let proj_b =
            self.dispatch("bias_add_d", vec![Operand::Slot(proj_slot), w(layer, bo, 0, 0)], full.clone());
        let y = self.dispatch(
            "residual_ln",
            vec![
                Operand::Slot(proj_b),
                Operand::Slot(res_slot),
                w(layer, g, 0, 0),
                w(layer, b, 0, 0),
                Operand::Runtime(RuntimeId::Dmask),
                Operand::Runtime(RuntimeId::Count),
            ],
            full.clone(),
        );
        let y_host = self.fetch(y, full);
        (y, y_host)
    }

    /// FFN2 → FFN3 chain + residual LayerNorm (the encoder's tail),
    /// reading `src_host` and residual-adding `res_slot`.
    fn ffn_block(&mut self, layer: usize, src_host: HostId, res_slot: SlotId) -> (SlotId, HostId) {
        let fc = self.fc;
        let cfg = self.cfg;
        let t_f = cfg.d_model / fc.ts_ffn;
        let t_h = cfg.hidden / fc.ffn_col;
        let full = vec![fc.sl_max, fc.dmodel_max];
        let hid_full = vec![fc.sl_max, fc.hidden_max];
        let y_panels: Vec<SlotId> =
            (0..t_f).map(|r| self.extract_upload(src_host, r * fc.ts_ffn, fc.ts_ffn)).collect();
        let hid = self.host(hid_full.clone());
        for c in 0..t_h {
            let out = vec![fc.sl_max, fc.ffn_col];
            let mut acc = self.dispatch(
                "mm_ffn2",
                vec![
                    Operand::Slot(y_panels[0]),
                    w(layer, WeightKind::W1, 0, c),
                    Operand::Runtime(RuntimeId::ZeroCol),
                ],
                out.clone(),
            );
            for r in 1..t_f {
                acc = self.dispatch(
                    "mm_ffn2",
                    vec![
                        Operand::Slot(y_panels[r]),
                        w(layer, WeightKind::W1, r, c),
                        Operand::Slot(acc),
                    ],
                    out.clone(),
                );
            }
            let h = self.fetch(acc, out);
            self.assemble(h, hid, c * fc.ffn_col);
        }
        let hid_slot = self.upload(hid);
        let hid_r = self.dispatch(
            "bias_relu_h",
            vec![Operand::Slot(hid_slot), w(layer, WeightKind::B1, 0, 0)],
            hid_full.clone(),
        );
        let hid_r_host = self.fetch(hid_r, hid_full);
        let h_panels: Vec<SlotId> = (0..t_h)
            .map(|r| self.extract_upload(hid_r_host, r * fc.ffn_col, fc.ffn_col))
            .collect();
        let out_h = self.host(full.clone());
        for c in 0..t_f {
            let out = vec![fc.sl_max, fc.ts_ffn];
            let mut acc = self.dispatch(
                "mm_ffn3",
                vec![
                    Operand::Slot(h_panels[0]),
                    w(layer, WeightKind::W2, 0, c),
                    Operand::Runtime(RuntimeId::ZeroFfn),
                ],
                out.clone(),
            );
            for r in 1..t_h {
                acc = self.dispatch(
                    "mm_ffn3",
                    vec![
                        Operand::Slot(h_panels[r]),
                        w(layer, WeightKind::W2, r, c),
                        Operand::Slot(acc),
                    ],
                    out.clone(),
                );
            }
            let hh = self.fetch(acc, out);
            self.assemble(hh, out_h, c * fc.ts_ffn);
        }
        let out_slot = self.upload(out_h);
        let out_b = self.dispatch(
            "bias_add_d",
            vec![Operand::Slot(out_slot), w(layer, WeightKind::B2, 0, 0)],
            full.clone(),
        );
        let fin = self.dispatch(
            "residual_ln",
            vec![
                Operand::Slot(out_b),
                Operand::Slot(res_slot),
                w(layer, WeightKind::G2, 0, 0),
                w(layer, WeightKind::B2n, 0, 0),
                Operand::Runtime(RuntimeId::Dmask),
                Operand::Runtime(RuntimeId::Count),
            ],
            full.clone(),
        );
        let fin_host = self.fetch(fin, full);
        (fin, fin_host)
    }

    /// Lower the decoder **prefill** program: the whole prompt through
    /// every decoder layer — masked (causal) self-attention, then (for
    /// seq2seq topologies) cross-attention against the encoder memory
    /// supplied as the program's one aux input host, then the FFN chain.
    /// Each layer's self K/V panels (and cross K/V, projected once from
    /// the memory) are **exported** to seed the device-resident KV cache;
    /// export order per layer: per head `[k, v]` for self, then per head
    /// `[k, v]` for cross — exactly `accel::decode::ExternLayout` order.
    ///
    /// Execution-mode flags (`mode`/`qkv_packed`/`quantized`) are ignored:
    /// decoder layers always lower as the split chain so the prefill and
    /// decode-step paths share numerics (see `opt::FuseAttention`'s causal
    /// gate).
    pub fn build_prefill(mut self) -> TileProgram {
        let fc = self.fc;
        let cfg = self.cfg;
        assert!(cfg.dec_layers > 0, "prefill lowering needs dec_layers > 0");
        let t_m = cfg.d_model / fc.ts_mha;
        let full = vec![fc.sl_max, fc.dmodel_max];
        let cross = cfg.enc_layers > 0;

        let input = self.host(full.clone());
        let mem_host = if cross { Some(self.host(full.clone())) } else { None };
        // Memory panels are layer-invariant: extract + upload once, share
        // across every layer's cross K/V projections.
        let mem_panels: Vec<SlotId> = match mem_host {
            Some(mh) => {
                (0..t_m).map(|t| self.extract_upload(mh, t * fc.ts_mha, fc.ts_mha)).collect()
            }
            None => Vec::new(),
        };

        let mut exports: Vec<SlotId> = Vec::new();
        let mut x_host = input;
        let mut x_slot = self.upload(input);

        for layer in 0..cfg.dec_layers {
            // ---- masked self-attention (causal mask fences the future).
            let x_panels: Vec<SlotId> =
                (0..t_m).map(|t| self.extract_upload(x_host, t * fc.ts_mha, fc.ts_mha)).collect();
            let attn = self.host(full.clone());
            for head in 0..cfg.heads {
                let q = self.project(layer, head, &x_panels, WeightKind::Wq, WeightKind::Bq);
                let k = self.project(layer, head, &x_panels, WeightKind::Wk, WeightKind::Bk);
                let v = self.project(layer, head, &x_panels, WeightKind::Wv, WeightKind::Bv);
                exports.push(k);
                exports.push(v);
                // Causal tiers fence rows *and* keys at the tier — exact
                // for any live prefix within the fired tier.
                let o = self.attn_chain_tiered(q, k, v, true);
                let oh = self.fetch(o, vec![fc.sl_max, fc.dk]);
                self.assemble(oh, attn, head * fc.dk);
            }
            let (y1, y1_host) = self.out_projection(
                layer,
                attn,
                x_slot,
                WeightKind::Wo,
                WeightKind::Bo,
                WeightKind::G1,
                WeightKind::B1n,
            );

            // ---- cross-attention against the encoder memory.
            let (res_slot, res_host) = if cross {
                let y_panels: Vec<SlotId> = (0..t_m)
                    .map(|t| self.extract_upload(y1_host, t * fc.ts_mha, fc.ts_mha))
                    .collect();
                let cattn = self.host(full.clone());
                for head in 0..cfg.heads {
                    let q = self.project(layer, head, &y_panels, WeightKind::CWq, WeightKind::CBq);
                    let ck =
                        self.project(layer, head, &mem_panels, WeightKind::CWk, WeightKind::CBk);
                    let cv =
                        self.project(layer, head, &mem_panels, WeightKind::CWv, WeightKind::CBv);
                    exports.push(ck);
                    exports.push(cv);
                    // Queries and memory keys are both fenced by the
                    // padding mask (no causality across the two streams).
                    // Never tiered: the memory fence must stay at the
                    // encoder's seq_len regardless of the prompt length.
                    let o = self.attn_chain(q, ck, cv, RuntimeId::Mask);
                    let oh = self.fetch(o, vec![fc.sl_max, fc.dk]);
                    self.assemble(oh, cattn, head * fc.dk);
                }
                self.out_projection(
                    layer,
                    cattn,
                    y1,
                    WeightKind::CWo,
                    WeightKind::CBo,
                    WeightKind::CG,
                    WeightKind::CBn,
                )
            } else {
                (y1, y1_host)
            };

            // ---- FFN chain + second (third, for seq2seq) residual/LN.
            let (fin, fin_host) = self.ffn_block(layer, res_host, res_slot);
            x_host = fin_host;
            x_slot = fin;
        }

        let aux = mem_host.into_iter().collect();
        self.finish(input, x_host, aux, Vec::new(), exports)
    }

    /// Lower the decoder **decode-step** program: one token row against
    /// the cached K/V.  Inputs: the main host is the token's embedding row
    /// `[1, DMODEL_MAX]`; aux hosts are the step-mask row `[1, SL_MAX]`
    /// (fences keys `> pos`) and the position scalar `[1]` (where
    /// `kv_append` writes the new K/V row).  Externs are the cache panels
    /// in `accel::decode::ExternLayout` order; exports are the appended
    /// self K/V panels (per layer, per head, `[k, v]`).
    ///
    /// The single-row datapath streams each full weight matrix in one
    /// dispatch (`dec_*_row` artifacts) instead of walking SL_MAX-row
    /// panel tiles, which is what makes a step strictly cheaper than
    /// re-running prefill.
    pub fn build_step(mut self) -> TileProgram {
        let fc = self.fc;
        let cfg = self.cfg;
        assert!(cfg.dec_layers > 0, "decode-step lowering needs dec_layers > 0");
        let cross = cfg.enc_layers > 0;
        let row = vec![1, fc.dmodel_max];
        let row_dk = vec![1, fc.dk];
        let row_sl = vec![1, fc.sl_max];
        let kv_shape = vec![fc.sl_max, fc.dk];

        let input = self.host(row.clone());
        let mask_host = self.host(row_sl.clone());
        let pos_host = self.host(vec![1]);

        // Extern table in `accel::decode::ExternLayout` order — the one
        // index authority shared with the KV cache.
        let layout = ExternLayout::of(&cfg);
        let extern_shapes: Vec<Vec<usize>> =
            (0..layout.total()).map(|_| kv_shape.clone()).collect();

        let mask_slot = self.upload(mask_host);
        let pos_slot = self.upload(pos_host);
        let mut x_slot = self.upload(input);
        let mut exports: Vec<SlotId> = Vec::new();

        for layer in 0..cfg.dec_layers {
            // ---- causal self-attention, one query row vs cached K/V.
            let attn_row = self.host(row.clone());
            for head in 0..cfg.heads {
                let q = self.dispatch(
                    "dec_qkv_row",
                    vec![
                        Operand::Slot(x_slot),
                        w(layer, WeightKind::DWq, head, 0),
                        w(layer, WeightKind::Bq, head, 0),
                    ],
                    row_dk.clone(),
                );
                let k_new = self.dispatch(
                    "dec_qkv_row",
                    vec![
                        Operand::Slot(x_slot),
                        w(layer, WeightKind::DWk, head, 0),
                        w(layer, WeightKind::Bk, head, 0),
                    ],
                    row_dk.clone(),
                );
                let v_new = self.dispatch(
                    "dec_qkv_row",
                    vec![
                        Operand::Slot(x_slot),
                        w(layer, WeightKind::DWv, head, 0),
                        w(layer, WeightKind::Bv, head, 0),
                    ],
                    row_dk.clone(),
                );
                let k_all = self.dispatch(
                    "kv_append",
                    vec![
                        Operand::Extern(layout.self_k(layer, head)),
                        Operand::Slot(k_new),
                        Operand::Slot(pos_slot),
                    ],
                    kv_shape.clone(),
                );
                let v_all = self.dispatch(
                    "kv_append",
                    vec![
                        Operand::Extern(layout.self_v(layer, head)),
                        Operand::Slot(v_new),
                        Operand::Slot(pos_slot),
                    ],
                    kv_shape.clone(),
                );
                exports.push(k_all);
                exports.push(v_all);
                let s = self.dispatch(
                    "qk_row",
                    vec![
                        Operand::Slot(q),
                        Operand::Slot(k_all),
                        Operand::Slot(mask_slot),
                        Operand::Runtime(RuntimeId::Scale),
                    ],
                    row_sl.clone(),
                );
                let p = self.dispatch("softmax_row", vec![Operand::Slot(s)], row_sl.clone());
                let o = self.dispatch(
                    "sv_row",
                    vec![Operand::Slot(p), Operand::Slot(v_all)],
                    row_dk.clone(),
                );
                let oh = self.fetch(o, row_dk.clone());
                self.assemble(oh, attn_row, head * fc.dk);
            }
            let a_slot = self.upload(attn_row);
            let proj = self.dispatch(
                "dec_proj_row",
                vec![
                    Operand::Slot(a_slot),
                    w(layer, WeightKind::DWo, 0, 0),
                    w(layer, WeightKind::Bo, 0, 0),
                ],
                row.clone(),
            );
            let y1 = self.dispatch(
                "residual_ln_row",
                vec![
                    Operand::Slot(proj),
                    Operand::Slot(x_slot),
                    w(layer, WeightKind::G1, 0, 0),
                    w(layer, WeightKind::B1n, 0, 0),
                    Operand::Runtime(RuntimeId::Dmask),
                    Operand::Runtime(RuntimeId::Count),
                ],
                row.clone(),
            );

            // ---- cross-attention against the (step-invariant) cached
            // memory K/V — no projections, no appends.
            let cur = if cross {
                let cattn_row = self.host(row.clone());
                for head in 0..cfg.heads {
                    let q = self.dispatch(
                        "dec_qkv_row",
                        vec![
                            Operand::Slot(y1),
                            w(layer, WeightKind::DCWq, head, 0),
                            w(layer, WeightKind::CBq, head, 0),
                        ],
                        row_dk.clone(),
                    );
                    let s = self.dispatch(
                        "qk_row",
                        vec![
                            Operand::Slot(q),
                            Operand::Extern(
                                layout.cross_k(layer, head).expect("cross gated above"),
                            ),
                            Operand::Runtime(RuntimeId::MemMaskRow),
                            Operand::Runtime(RuntimeId::Scale),
                        ],
                        row_sl.clone(),
                    );
                    let p = self.dispatch("softmax_row", vec![Operand::Slot(s)], row_sl.clone());
                    let o = self.dispatch(
                        "sv_row",
                        vec![
                            Operand::Slot(p),
                            Operand::Extern(
                                layout.cross_v(layer, head).expect("cross gated above"),
                            ),
                        ],
                        row_dk.clone(),
                    );
                    let oh = self.fetch(o, row_dk.clone());
                    self.assemble(oh, cattn_row, head * fc.dk);
                }
                let c_slot = self.upload(cattn_row);
                let cp = self.dispatch(
                    "dec_proj_row",
                    vec![
                        Operand::Slot(c_slot),
                        w(layer, WeightKind::DCWo, 0, 0),
                        w(layer, WeightKind::CBo, 0, 0),
                    ],
                    row.clone(),
                );
                self.dispatch(
                    "residual_ln_row",
                    vec![
                        Operand::Slot(cp),
                        Operand::Slot(y1),
                        w(layer, WeightKind::CG, 0, 0),
                        w(layer, WeightKind::CBn, 0, 0),
                        Operand::Runtime(RuntimeId::Dmask),
                        Operand::Runtime(RuntimeId::Count),
                    ],
                    row.clone(),
                )
            } else {
                y1
            };

            // ---- FFN, single row: bias+ReLU fused into dec_ffn1_row.
            let h1 = self.dispatch(
                "dec_ffn1_row",
                vec![
                    Operand::Slot(cur),
                    w(layer, WeightKind::DW1, 0, 0),
                    w(layer, WeightKind::B1, 0, 0),
                ],
                vec![1, fc.hidden_max],
            );
            let h2 = self.dispatch(
                "dec_ffn2_row",
                vec![
                    Operand::Slot(h1),
                    w(layer, WeightKind::DW2, 0, 0),
                    w(layer, WeightKind::B2, 0, 0),
                ],
                row.clone(),
            );
            x_slot = self.dispatch(
                "residual_ln_row",
                vec![
                    Operand::Slot(h2),
                    Operand::Slot(cur),
                    w(layer, WeightKind::G2, 0, 0),
                    w(layer, WeightKind::B2n, 0, 0),
                    Operand::Runtime(RuntimeId::Dmask),
                    Operand::Runtime(RuntimeId::Count),
                ],
                row.clone(),
            );
        }

        let out = self.fetch(x_slot, row);
        self.finish(input, out, vec![mask_host, pos_host], extern_shapes, exports)
    }
}
