//! Lowering: `(TnnConfig, FabricConstants, mode flags)` → [`TileProgram`].
//!
//! This is the code that used to live as imperative loop nests inside
//! `TileEngine::run_layer` (Algorithms 1–17 over the §3.9 tile schedules,
//! partial sums accumulating across column tiles per Fig 4a and 2-D tiles
//! per Fig 4b).  The builder emits exactly the artifact/operand sequence
//! the old engine dispatched — numerics are bit-identical — with one
//! scheduled improvement: each layer's residual operand references the
//! *device slot* of the previous layer's output instead of re-uploading
//! the full padded activation that was already resident (the fabric analog:
//! activations stay in BRAM between layers).

use super::{
    AttentionMode, FabricConstants, HostId, Operand, RuntimeId, SlotId, Step, TileProgram,
    WeightKind, WeightRef,
};
use crate::model::TnnConfig;

/// Shorthand for a weight operand.
fn w(layer: usize, kind: WeightKind, row: usize, col: usize) -> Operand {
    Operand::Weight(WeightRef { layer, kind, row, col })
}

/// Builds a [`TileProgram`] for one topology on one fabric.
#[derive(Debug)]
pub struct ScheduleBuilder {
    fc: FabricConstants,
    cfg: TnnConfig,
    mode: AttentionMode,
    qkv_packed: bool,
    quantized: bool,
    steps: Vec<Step>,
    host_shapes: Vec<Vec<usize>>,
    n_slots: usize,
}

impl ScheduleBuilder {
    /// Validates `cfg` against the fabric constraints (the same checks the
    /// engine's `check_runtime_config` applies).
    pub fn new(fc: FabricConstants, cfg: TnnConfig) -> anyhow::Result<Self> {
        fc.check(&cfg).map_err(|e| anyhow::anyhow!(e))?;
        Ok(ScheduleBuilder {
            fc,
            cfg,
            mode: AttentionMode::Split,
            qkv_packed: false,
            quantized: false,
            steps: Vec::new(),
            host_shapes: Vec::new(),
            n_slots: 0,
        })
    }

    pub fn mode(mut self, mode: AttentionMode) -> Self {
        self.mode = mode;
        self
    }

    pub fn qkv_packed(mut self, on: bool) -> Self {
        self.qkv_packed = on;
        self
    }

    pub fn quantized(mut self, on: bool) -> Self {
        self.quantized = on;
        self
    }

    // ---- emission helpers ------------------------------------------------

    fn host(&mut self, shape: Vec<usize>) -> HostId {
        self.host_shapes.push(shape);
        self.host_shapes.len() - 1
    }

    fn slot(&mut self) -> SlotId {
        self.n_slots += 1;
        self.n_slots - 1
    }

    fn upload(&mut self, host: HostId) -> SlotId {
        let dst = self.slot();
        self.steps.push(Step::Upload { host, dst });
        dst
    }

    fn dispatch(
        &mut self,
        artifact: &'static str,
        args: Vec<Operand>,
        out_shape: Vec<usize>,
    ) -> SlotId {
        let dst = self.slot();
        self.steps.push(Step::Dispatch { artifact, args, dst, out_shape });
        dst
    }

    fn fetch(&mut self, src: SlotId, shape: Vec<usize>) -> HostId {
        let host = self.host(shape);
        self.steps.push(Step::Fetch { src, host });
        host
    }

    fn extract_upload(&mut self, src: HostId, c0: usize, width: usize) -> SlotId {
        let dst = self.host(vec![self.fc.sl_max, width]);
        self.steps.push(Step::ExtractPanel { src, c0, width, dst });
        self.upload(dst)
    }

    fn assemble(&mut self, src: HostId, dst: HostId, c0: usize) {
        self.steps.push(Step::AssemblePanel { src, dst, c0 });
    }

    /// One projection chain (Algorithm 9's per-head accumulation over the
    /// Fig 4a column tiles) followed by the bias add.
    fn project(
        &mut self,
        layer: usize,
        head: usize,
        x_panels: &[SlotId],
        wk: WeightKind,
        bk: WeightKind,
    ) -> SlotId {
        let out = vec![self.fc.sl_max, self.fc.dk];
        let mut acc = self.dispatch(
            "mm_qkv",
            vec![
                Operand::Slot(x_panels[0]),
                w(layer, wk, head, 0),
                Operand::Runtime(RuntimeId::ZeroDk),
            ],
            out.clone(),
        );
        for t in 1..x_panels.len() {
            acc = self.dispatch(
                "mm_qkv",
                vec![Operand::Slot(x_panels[t]), w(layer, wk, head, t), Operand::Slot(acc)],
                out.clone(),
            );
        }
        self.dispatch("bias_add_dk", vec![Operand::Slot(acc), w(layer, bk, head, 0)], out)
    }

    // ---- lowering --------------------------------------------------------

    /// Lower the whole encoder stack.
    pub fn build(mut self) -> TileProgram {
        let fc = self.fc;
        let cfg = self.cfg;
        let t_m = cfg.d_model / fc.ts_mha;
        let t_f = cfg.d_model / fc.ts_ffn;
        let t_h = cfg.hidden / fc.ffn_col;
        let full = vec![fc.sl_max, fc.dmodel_max];
        let hid_full = vec![fc.sl_max, fc.hidden_max];

        // Algorithm 1: the padded input lands in host slot 0; the caller
        // writes it before replay.
        let input = self.host(full.clone());
        let mut x_host = input;
        let mut x_slot = self.upload(input);

        for layer in 0..cfg.enc_layers {
            // ---- MHA (Fig 2): input panels are shared across heads —
            // extract + upload once per tile.
            let x_panels: Vec<SlotId> =
                (0..t_m).map(|t| self.extract_upload(x_host, t * fc.ts_mha, fc.ts_mha)).collect();
            let attn = self.host(full.clone());
            if self.qkv_packed {
                // One dispatch per tile projects the head's Q|K|V
                // simultaneously (Algorithm 9's three MACs per cycle).
                let out3 = vec![fc.sl_max, 3 * fc.dk];
                for head in 0..cfg.heads {
                    let mut acc = self.dispatch(
                        "mm_qkv_packed",
                        vec![
                            Operand::Slot(x_panels[0]),
                            w(layer, WeightKind::QkvPacked, head, 0),
                            Operand::Runtime(RuntimeId::ZeroQkv3),
                        ],
                        out3.clone(),
                    );
                    for t in 1..t_m {
                        acc = self.dispatch(
                            "mm_qkv_packed",
                            vec![
                                Operand::Slot(x_panels[t]),
                                w(layer, WeightKind::QkvPacked, head, t),
                                Operand::Slot(acc),
                            ],
                            out3.clone(),
                        );
                    }
                    let qkv = self.dispatch(
                        "bias_add_qkv",
                        vec![Operand::Slot(acc), w(layer, WeightKind::BQkvPacked, head, 0)],
                        out3.clone(),
                    );
                    let o = self.dispatch(
                        "attn_packed",
                        vec![
                            Operand::Slot(qkv),
                            Operand::Runtime(RuntimeId::Mask),
                            Operand::Runtime(RuntimeId::Scale),
                        ],
                        vec![fc.sl_max, fc.dk],
                    );
                    let oh = self.fetch(o, vec![fc.sl_max, fc.dk]);
                    self.assemble(oh, attn, head * fc.dk);
                }
            } else {
                for head in 0..cfg.heads {
                    let q = self.project(layer, head, &x_panels, WeightKind::Wq, WeightKind::Bq);
                    let k = self.project(layer, head, &x_panels, WeightKind::Wk, WeightKind::Bk);
                    let v = self.project(layer, head, &x_panels, WeightKind::Wv, WeightKind::Bv);
                    let o = match self.mode {
                        AttentionMode::Fused => self.dispatch(
                            "attn_fused",
                            vec![
                                Operand::Slot(q),
                                Operand::Slot(k),
                                Operand::Slot(v),
                                Operand::Runtime(RuntimeId::Mask),
                                Operand::Runtime(RuntimeId::Scale),
                            ],
                            vec![fc.sl_max, fc.dk],
                        ),
                        AttentionMode::Split => {
                            let s = self.dispatch(
                                "qk_scores",
                                vec![
                                    Operand::Slot(q),
                                    Operand::Slot(k),
                                    Operand::Runtime(RuntimeId::Mask),
                                    Operand::Runtime(RuntimeId::Scale),
                                ],
                                vec![fc.sl_max, fc.sl_max],
                            );
                            let p = self.dispatch(
                                "softmax",
                                vec![Operand::Slot(s)],
                                vec![fc.sl_max, fc.sl_max],
                            );
                            self.dispatch(
                                "sv",
                                vec![Operand::Slot(p), Operand::Slot(v)],
                                vec![fc.sl_max, fc.dk],
                            )
                        }
                    };
                    let oh = self.fetch(o, vec![fc.sl_max, fc.dk]);
                    self.assemble(oh, attn, head * fc.dk);
                }
            }

            if self.quantized {
                // Per-tensor symmetric int8 QDQ on the attention output —
                // the scale is the program's only data-dependent value.
                let attn_slot = self.upload(attn);
                let scale = self.slot();
                self.steps.push(Step::CalibrateScale { src: attn, dst: scale });
                let q = self.dispatch(
                    "quantize",
                    vec![Operand::Slot(attn_slot), Operand::Slot(scale)],
                    full.clone(),
                );
                self.steps.push(Step::Fetch { src: q, host: attn });
            }

            // ---- FFN1_PM: output projection, 2-D tiles (Fig 4b),
            // column-then-row accumulation.
            let a_panels: Vec<SlotId> =
                (0..t_f).map(|r| self.extract_upload(attn, r * fc.ts_ffn, fc.ts_ffn)).collect();
            let proj = self.host(full.clone());
            for c in 0..t_f {
                let out = vec![fc.sl_max, fc.ts_ffn];
                let mut acc = self.dispatch(
                    "mm_ffn1",
                    vec![
                        Operand::Slot(a_panels[0]),
                        w(layer, WeightKind::Wo, 0, c),
                        Operand::Runtime(RuntimeId::ZeroFfn),
                    ],
                    out.clone(),
                );
                for r in 1..t_f {
                    acc = self.dispatch(
                        "mm_ffn1",
                        vec![
                            Operand::Slot(a_panels[r]),
                            w(layer, WeightKind::Wo, r, c),
                            Operand::Slot(acc),
                        ],
                        out.clone(),
                    );
                }
                let h = self.fetch(acc, out);
                self.assemble(h, proj, c * fc.ts_ffn);
            }
            let proj_slot = self.upload(proj);
            let proj_b = self.dispatch(
                "bias_add_d",
                vec![Operand::Slot(proj_slot), w(layer, WeightKind::Bo, 0, 0)],
                full.clone(),
            );
            // Residual reads the previous layer's device-resident output
            // (x_slot) — no re-upload of the full padded activation.
            let y_slot = self.dispatch(
                "residual_ln",
                vec![
                    Operand::Slot(proj_b),
                    Operand::Slot(x_slot),
                    w(layer, WeightKind::G1, 0, 0),
                    w(layer, WeightKind::B1n, 0, 0),
                    Operand::Runtime(RuntimeId::Dmask),
                    Operand::Runtime(RuntimeId::Count),
                ],
                full.clone(),
            );
            let y_host = self.fetch(y_slot, full.clone());

            // ---- FFN2_PM: d -> hidden with ReLU.
            let y_panels: Vec<SlotId> =
                (0..t_f).map(|r| self.extract_upload(y_host, r * fc.ts_ffn, fc.ts_ffn)).collect();
            let hid = self.host(hid_full.clone());
            for c in 0..t_h {
                let out = vec![fc.sl_max, fc.ffn_col];
                let mut acc = self.dispatch(
                    "mm_ffn2",
                    vec![
                        Operand::Slot(y_panels[0]),
                        w(layer, WeightKind::W1, 0, c),
                        Operand::Runtime(RuntimeId::ZeroCol),
                    ],
                    out.clone(),
                );
                for r in 1..t_f {
                    acc = self.dispatch(
                        "mm_ffn2",
                        vec![
                            Operand::Slot(y_panels[r]),
                            w(layer, WeightKind::W1, r, c),
                            Operand::Slot(acc),
                        ],
                        out.clone(),
                    );
                }
                let h = self.fetch(acc, out);
                self.assemble(h, hid, c * fc.ffn_col);
            }
            let hid_slot = self.upload(hid);
            let hid_r = self.dispatch(
                "bias_relu_h",
                vec![Operand::Slot(hid_slot), w(layer, WeightKind::B1, 0, 0)],
                hid_full.clone(),
            );
            let hid_r_host = self.fetch(hid_r, hid_full.clone());

            // ---- FFN3_PM: hidden -> d.
            let h_panels: Vec<SlotId> = (0..t_h)
                .map(|r| self.extract_upload(hid_r_host, r * fc.ffn_col, fc.ffn_col))
                .collect();
            let out_h = self.host(full.clone());
            for c in 0..t_f {
                let out = vec![fc.sl_max, fc.ts_ffn];
                let mut acc = self.dispatch(
                    "mm_ffn3",
                    vec![
                        Operand::Slot(h_panels[0]),
                        w(layer, WeightKind::W2, 0, c),
                        Operand::Runtime(RuntimeId::ZeroFfn),
                    ],
                    out.clone(),
                );
                for r in 1..t_h {
                    acc = self.dispatch(
                        "mm_ffn3",
                        vec![
                            Operand::Slot(h_panels[r]),
                            w(layer, WeightKind::W2, r, c),
                            Operand::Slot(acc),
                        ],
                        out.clone(),
                    );
                }
                let hh = self.fetch(acc, out);
                self.assemble(hh, out_h, c * fc.ts_ffn);
            }
            let out_slot = self.upload(out_h);
            let out_b = self.dispatch(
                "bias_add_d",
                vec![Operand::Slot(out_slot), w(layer, WeightKind::B2, 0, 0)],
                full.clone(),
            );
            let fin = self.dispatch(
                "residual_ln",
                vec![
                    Operand::Slot(out_b),
                    Operand::Slot(y_slot),
                    w(layer, WeightKind::G2, 0, 0),
                    w(layer, WeightKind::B2n, 0, 0),
                    Operand::Runtime(RuntimeId::Dmask),
                    Operand::Runtime(RuntimeId::Count),
                ],
                full.clone(),
            );
            x_host = self.fetch(fin, full.clone());
            x_slot = fin;
        }

        let mut prog = TileProgram {
            cfg,
            fabric: fc,
            steps: self.steps,
            host_shapes: self.host_shapes,
            n_slots: self.n_slots,
            input_host: input,
            output_host: x_host,
            drops: Vec::new(),
            host_drops: Vec::new(),
            host_init: Vec::new(),
            waves: Vec::new(),
        };
        prog.finalize();
        prog
    }
}
