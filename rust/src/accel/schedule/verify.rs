//! The **TileProgram static verifier**: multi-analysis checking of a
//! lowered (and possibly optimized) instruction stream, with structured,
//! location-carrying diagnostics.
//!
//! Every request the engine serves is a replay of a cached program, and
//! four optimizer passes rewrite those programs before they ever touch a
//! backend.  The verifier is the correctness substrate under that
//! machinery: it proves, per program, the invariants replay silently
//! assumes, so a builder or pass bug surfaces as one failed cache-miss
//! request (typed `ServeError::ProgramFailed`) instead of a panic or a
//! silent numeric corruption mid-stream.  Four analyses run over one walk
//! of the stream plus a wave pass:
//!
//! 1. **Def-before-use dataflow** over both operand namespaces — every
//!    `Dispatch` slot arg, `Fetch` src and panel-op src must be dominated
//!    by a def; values written and never read are flagged as leaks
//!    (warnings: replay tolerates them, they are wasted transfers).
//! 2. **Shape checking** — operand shapes are propagated symbolically
//!    (slot defs carry `out_shape`, weights/runtime tensors have
//!    fabric-fixed shapes) and checked against the
//!    [`ArtifactInventory`]'s manifest signatures where bound, plus
//!    manifest-free structural rules (fetch/host agreement, panel column
//!    bounds, `kv_append` panel shapes) that hold for any artifact set.
//!    Calibrated int8 scale slots may feed only the `quantize` artifact —
//!    the quantized and float families never mix in one chain.
//! 3. **Wave race detection** — intra-wave RAW/WAR/WAW conflicts over
//!    slots *and* hosts, on the same dependence model the scheduler used
//!    ([`opt::dependence_lists`]); `opt::validate_waves` is now a thin
//!    wrapper over this analysis.
//! 4. **Extern/export contract checking** — `Operand::Extern` cache
//!    panels are never read after the `kv_append` that advanced them,
//!    `export_slots` are defined exactly once and never recycled, and the
//!    per-kind `accel::decode::ExternLayout` ordering contract holds
//!    (extern/export counts, self-vs-cross panel regions, append→export
//!    position agreement).
//!
//! The verifier runs at three points: mandatorily at program-cache
//! insertion in `TileEngine` (zero per-request cost — once per topology),
//! after every optimizer pass in debug builds (`opt::Pipeline::run`), and
//! on demand via the `adaptor verify-programs` CLI sweep.

use std::collections::{HashMap, HashSet};
use std::fmt;

use crate::accel::decode::ExternLayout;

use super::opt::ArtifactInventory;
use super::{
    FabricConstants, HostId, LivePred, Operand, ProgramKind, RuntimeId, SlotId, Step,
    TileProgram, WeightKind,
};

/// How bad a diagnostic is.  `Error` means replay is (or may become)
/// incorrect; `Warning` means the program is legal but wasteful or
/// suspicious (e.g. a computed value nothing reads).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Severity {
    Warning,
    Error,
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Severity::Warning => "warning",
            Severity::Error => "error",
        })
    }
}

/// Which verifier rule produced a diagnostic.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Rule {
    /// A slot/host is read before any step (or the caller) wrote it, or
    /// an operand index is out of the program's declared tables.
    UseBeforeDef,
    /// A written value is never read (dead upload/dispatch/fetch).
    DeadWrite,
    /// A dispatched artifact is not in the bound artifact set.
    UnknownArtifact,
    /// A dispatch's operand count disagrees with the manifest signature.
    ArityMismatch,
    /// An operand or output shape disagrees with the manifest signature
    /// or with a structural shape rule (fetch target, panel bounds).
    ShapeMismatch,
    /// A calibrated int8 scale slot flows into a non-`quantize` artifact.
    QuantFamily,
    /// The wave partition itself is malformed (coverage/empty waves).
    WavePartition,
    /// Two steps of one wave are ordered by a RAW/WAR/WAW dependence.
    WaveRace,
    /// An `Operand::Extern` cache-panel rule is violated.
    ExternContract,
    /// An `export_slots` rule is violated.
    ExportContract,
    /// A skippable-dispatch rule is violated: a dispatch may read a slot
    /// only over live ranges its (possibly predicated) defs cover — a
    /// skipped dispatch must never define a slot consumed by an unskipped
    /// one.  Also covers malformed predicates (empty or out-of-range).
    SkipContract,
    /// A shard-transfer rule is violated: a program carries at most one
    /// `SendActivation` (writing its output host) and one
    /// `RecvActivation` (observing its input host), and across a chain
    /// every shard boundary must be covered exactly once by a send whose
    /// activation shape matches its peer recv (see
    /// [`verify_shard_chain`]).
    ShardContract,
}

impl fmt::Display for Rule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Rule::UseBeforeDef => "use-before-def",
            Rule::DeadWrite => "dead-write",
            Rule::UnknownArtifact => "unknown-artifact",
            Rule::ArityMismatch => "arity-mismatch",
            Rule::ShapeMismatch => "shape-mismatch",
            Rule::QuantFamily => "quant-family",
            Rule::WavePartition => "wave-partition",
            Rule::WaveRace => "wave-race",
            Rule::ExternContract => "extern-contract",
            Rule::ExportContract => "export-contract",
            Rule::SkipContract => "skip-contract",
            Rule::ShardContract => "shard-contract",
        })
    }
}

/// One verifier finding, anchored to the offending step where one exists
/// (`None` for whole-program properties like partition coverage).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    pub step: Option<usize>,
    pub severity: Severity,
    pub rule: Rule,
    pub message: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.step {
            Some(i) => write!(f, "step {i}: {}[{}]: {}", self.severity, self.rule, self.message),
            None => write!(f, "program: {}[{}]: {}", self.severity, self.rule, self.message),
        }
    }
}

/// Everything one verification run found.
#[derive(Debug, Clone, Default)]
pub struct VerifyReport {
    pub diagnostics: Vec<Diagnostic>,
}

impl VerifyReport {
    pub fn errors(&self) -> impl Iterator<Item = &Diagnostic> {
        self.diagnostics.iter().filter(|d| d.severity == Severity::Error)
    }

    pub fn warnings(&self) -> impl Iterator<Item = &Diagnostic> {
        self.diagnostics.iter().filter(|d| d.severity == Severity::Warning)
    }

    pub fn error_count(&self) -> usize {
        self.errors().count()
    }

    pub fn warning_count(&self) -> usize {
        self.warnings().count()
    }

    /// No error-severity findings (warnings allowed).
    pub fn is_clean(&self) -> bool {
        self.error_count() == 0
    }

    /// Whether any *error* carries `rule` — the mutation-corpus assertion.
    pub fn has_error(&self, rule: Rule) -> bool {
        self.errors().any(|d| d.rule == rule)
    }
}

/// A failed verification: the error-severity diagnostics, as a typed
/// `std::error::Error` so `anyhow` and `ServeError` can wrap it.
#[derive(Debug, Clone)]
pub struct VerifyError {
    pub diagnostics: Vec<Diagnostic>,
}

impl VerifyError {
    pub fn new(diagnostics: Vec<Diagnostic>) -> Self {
        VerifyError { diagnostics }
    }
}

impl fmt::Display for VerifyError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let errors: Vec<String> = self
            .diagnostics
            .iter()
            .filter(|d| d.severity == Severity::Error)
            .map(Diagnostic::to_string)
            .collect();
        write!(f, "program verification failed ({} error(s)): {}", errors.len(), errors.join("; "))
    }
}

impl std::error::Error for VerifyError {}

// ---- fabric-fixed operand shapes ----------------------------------------

/// Shape of a runtime tensor — mirrors `schedule::runtime_tensor` without
/// materializing the data (a unit test pins the two together).
pub fn runtime_shape(id: RuntimeId, fc: &FabricConstants) -> Vec<usize> {
    match id {
        RuntimeId::Mask
        | RuntimeId::CausalMask
        | RuntimeId::TierMask(_)
        | RuntimeId::TierCausalMask(_) => vec![fc.sl_max, fc.sl_max],
        RuntimeId::MemMaskRow => vec![1, fc.sl_max],
        RuntimeId::Scale | RuntimeId::Count => vec![1],
        RuntimeId::Dmask => vec![fc.dmodel_max],
        RuntimeId::ZeroDk => vec![fc.sl_max, fc.dk],
        RuntimeId::ZeroFfn => vec![fc.sl_max, fc.ts_ffn],
        RuntimeId::ZeroCol => vec![fc.sl_max, fc.ffn_col],
        RuntimeId::ZeroQkv3 => vec![fc.sl_max, 3 * fc.dk],
    }
}

/// Fabric-padded shape of a prepared weight tensor, per [`WeightKind`] —
/// what the register file uploads for each kind, and therefore what the
/// manifest signatures expect in the corresponding operand positions.
pub fn weight_shape(kind: WeightKind, fc: &FabricConstants) -> Vec<usize> {
    use WeightKind::*;
    match kind {
        Wq | Wk | Wv | CWq | CWk | CWv => vec![fc.ts_mha, fc.dk],
        Bq | Bk | Bv | CBq | CBk | CBv => vec![fc.dk],
        Wo | CWo => vec![fc.ts_ffn, fc.ts_ffn],
        Bo | B2 | CBo | G1 | B1n | G2 | B2n | CG | CBn => vec![fc.dmodel_max],
        W1 => vec![fc.ts_ffn, fc.ffn_col],
        B1 => vec![fc.hidden_max],
        W2 => vec![fc.ffn_col, fc.ts_ffn],
        QkvPacked => vec![fc.ts_mha, 3 * fc.dk],
        BQkvPacked => vec![3 * fc.dk],
        DWq | DWk | DWv | DCWq => vec![fc.dmodel_max, fc.dk],
        DWo | DCWo => vec![fc.dmodel_max, fc.dmodel_max],
        DW1 => vec![fc.dmodel_max, fc.hidden_max],
        DW2 => vec![fc.hidden_max, fc.dmodel_max],
    }
}

// ---- the stream walker ---------------------------------------------------

struct Analyzer<'a> {
    prog: &'a TileProgram,
    inventory: &'a ArtifactInventory,
    diags: Vec<Diagnostic>,
    /// Shape carried by the current def of each slot (`None`: unknown).
    slot_shape: HashMap<SlotId, Option<Vec<usize>>>,
    /// Slots whose current def is a `CalibrateScale` result.
    scale_slots: HashSet<SlotId>,
    /// Unread slot defs: slot → defining step.
    pending_slot: HashMap<SlotId, usize>,
    /// Live-range cover of the current def group per slot, as merged
    /// half-open `(lo, hi]` intervals.  An unpredicated def covers the
    /// full `(0, seq_len]`; disjoint-pred twin defs accumulate; an
    /// overlapping def starts a new group (legacy slot reuse).
    slot_cover: HashMap<SlotId, Vec<(usize, usize)>>,
    /// Hosts written so far (the caller pre-writes input/aux hosts).
    host_written: Vec<bool>,
    /// Current (possibly fetch-updated) shape of each host.
    host_cur: Vec<Vec<usize>>,
    /// Unread host writes: host → writing step.
    pending_host: HashMap<HostId, usize>,
    /// Extern panels consumed by a `kv_append`: index → appending step.
    consumed_extern: HashMap<usize, usize>,
    exported: HashSet<SlotId>,
    /// Times each exported slot id was written.
    export_defs: HashMap<SlotId, usize>,
    /// `(step, extern index, dst slot)` of every `kv_append`.
    kv_appends: Vec<(usize, usize, SlotId)>,
}

impl<'a> Analyzer<'a> {
    fn new(prog: &'a TileProgram, inventory: &'a ArtifactInventory) -> Self {
        let n_hosts = prog.host_shapes.len();
        let mut host_written = vec![false; n_hosts];
        if let Some(w) = host_written.get_mut(prog.input_host) {
            *w = true;
        }
        for h in &prog.aux_hosts {
            if let Some(w) = host_written.get_mut(*h) {
                *w = true;
            }
        }
        Analyzer {
            prog,
            inventory,
            diags: Vec::new(),
            slot_shape: HashMap::new(),
            scale_slots: HashSet::new(),
            pending_slot: HashMap::new(),
            slot_cover: HashMap::new(),
            host_written,
            host_cur: prog.host_shapes.clone(),
            pending_host: HashMap::new(),
            consumed_extern: HashMap::new(),
            exported: prog.export_slots.iter().copied().collect(),
            export_defs: HashMap::new(),
            kv_appends: Vec::new(),
        }
    }

    fn push(&mut self, step: Option<usize>, severity: Severity, rule: Rule, message: String) {
        self.diags.push(Diagnostic { step, severity, rule, message });
    }

    fn error(&mut self, step: usize, rule: Rule, message: String) {
        self.push(Some(step), Severity::Error, rule, message);
    }

    fn warn(&mut self, step: usize, rule: Rule, message: String) {
        self.push(Some(step), Severity::Warning, rule, message);
    }

    /// The live range a predicate selects, clamped to the topology
    /// (`None` — an unpredicated step — covers every live row count).
    fn live_range(&self, pred: Option<LivePred>) -> (usize, usize) {
        let seq = self.prog.cfg.seq_len;
        match pred {
            Some(p) => (p.lo, p.hi.min(seq)),
            None => (0, seq),
        }
    }

    /// Record a slot def under `pred`; returns whether the id was in range.
    fn def_slot(
        &mut self,
        s: SlotId,
        i: usize,
        shape: Option<Vec<usize>>,
        is_scale: bool,
        pred: Option<LivePred>,
    ) {
        if s >= self.prog.n_slots {
            self.error(
                i,
                Rule::UseBeforeDef,
                format!("writes slot {s}, but the program declares only {} slots", self.prog.n_slots),
            );
            return;
        }
        if let Some(p) = pred {
            if p.lo >= p.hi || p.hi > self.prog.cfg.seq_len {
                self.error(
                    i,
                    Rule::SkipContract,
                    format!(
                        "malformed predicate ({}, {}] — want lo < hi <= seq_len {}",
                        p.lo,
                        p.hi,
                        self.prog.cfg.seq_len
                    ),
                );
            }
        }
        let range = self.live_range(pred);
        let cover = self.slot_cover.get(&s).cloned().unwrap_or_default();
        // A predicated def disjoint from the slot's current cover is a
        // twin of a shared skippable output: it extends the def group
        // instead of overwriting the value.  Anything overlapping (or any
        // unpredicated def) starts a fresh group — legacy slot reuse.
        let disjoint_twin = !cover.is_empty()
            && pred.is_some()
            && !cover.iter().any(|&(l, h)| l < range.1 && range.0 < h);
        if disjoint_twin {
            if let (Some(new), Some(Some(prev))) = (&shape, self.slot_shape.get(&s)) {
                if new != prev {
                    self.error(
                        i,
                        Rule::ShapeMismatch,
                        format!(
                            "disjoint-pred twin defs of slot {s} disagree on shape ({prev:?} vs {new:?})"
                        ),
                    );
                }
            }
            let entry = self.slot_cover.entry(s).or_default();
            entry.push(range);
            entry.sort_unstable();
            let mut merged: Vec<(usize, usize)> = Vec::new();
            for r in entry.drain(..) {
                match merged.last_mut() {
                    Some(last) if r.0 <= last.1 => last.1 = last.1.max(r.1),
                    _ => merged.push(r),
                }
            }
            *entry = merged;
            // Exactly one twin fires per replay, so the group counts as
            // one pending def — never a dead overwrite of its siblings.
            self.pending_slot.insert(s, i);
        } else {
            if let Some(prev) = self.pending_slot.insert(s, i) {
                self.warn(
                    prev,
                    Rule::DeadWrite,
                    format!("slot {s} written at step {prev} is overwritten at step {i} without being read"),
                );
            }
            self.slot_cover.insert(s, vec![range]);
        }
        self.slot_shape.insert(s, shape);
        if is_scale {
            self.scale_slots.insert(s);
        } else {
            self.scale_slots.remove(&s);
        }
        if self.exported.contains(&s) {
            *self.export_defs.entry(s).or_default() += 1;
        }
    }

    /// Resolve a slot read under the reader's `pred`; returns the carried
    /// shape when the def is known (`None` on use-before-def or unknown
    /// shape).  The reader's live range must be inside the def group's
    /// cover — otherwise some live row count would make a fired reader
    /// consume a slot every def of which was skipped.
    fn read_slot(
        &mut self,
        s: SlotId,
        i: usize,
        what: &str,
        pred: Option<LivePred>,
    ) -> Option<Vec<usize>> {
        if s >= self.prog.n_slots {
            self.error(
                i,
                Rule::UseBeforeDef,
                format!("{what} reads slot {s}, but the program declares only {} slots", self.prog.n_slots),
            );
            return None;
        }
        self.pending_slot.remove(&s);
        match self.slot_shape.get(&s) {
            None => {
                self.error(
                    i,
                    Rule::UseBeforeDef,
                    format!("{what} reads slot {s} before any step writes it"),
                );
                None
            }
            Some(shape) => {
                let (lo, hi) = self.live_range(pred);
                let cover = self.slot_cover.get(&s).cloned().unwrap_or_default();
                if lo < hi && !cover.iter().any(|&(l, h)| l <= lo && hi <= h) {
                    self.error(
                        i,
                        Rule::SkipContract,
                        format!(
                            "{what} reads slot {s} over live rows ({lo}, {hi}], but its defs cover only {cover:?} — a skipped dispatch may not define a slot consumed by an unskipped one"
                        ),
                    );
                }
                shape.clone()
            }
        }
    }

    /// Resolve a host read; warns when nothing (program or caller) has
    /// written it yet — replay zero-materializes such hosts, so this is
    /// legal but almost always a builder bug.
    fn read_host(&mut self, h: HostId, i: usize, what: &str) -> Option<Vec<usize>> {
        if h >= self.host_cur.len() {
            self.error(
                i,
                Rule::UseBeforeDef,
                format!("{what} reads host {h}, but the program declares only {} hosts", self.host_cur.len()),
            );
            return None;
        }
        if !self.host_written[h] {
            self.warn(
                i,
                Rule::UseBeforeDef,
                format!("{what} reads host {h} before any write (replay sees zeros)"),
            );
        }
        self.pending_host.remove(&h);
        Some(self.host_cur[h].clone())
    }

    /// Record a host write.  `rmw` marks read-modify-write steps
    /// (`AssemblePanel`) that must not count the previous write as dead.
    fn write_host(&mut self, h: HostId, i: usize, rmw: bool) -> bool {
        if h >= self.host_cur.len() {
            self.error(
                i,
                Rule::UseBeforeDef,
                format!("writes host {h}, but the program declares only {} hosts", self.host_cur.len()),
            );
            return false;
        }
        if rmw {
            self.pending_host.remove(&h);
        }
        if let Some(prev) = self.pending_host.insert(h, i) {
            self.warn(
                prev,
                Rule::DeadWrite,
                format!("host {h} written at step {prev} is overwritten at step {i} without being read"),
            );
        }
        self.host_written[h] = true;
        true
    }

    /// Shape of one dispatch operand, with def-before-use, staleness and
    /// quant-family checks applied as a side effect.
    fn operand_shape(
        &mut self,
        artifact: &str,
        arg: &Operand,
        i: usize,
        pred: Option<LivePred>,
    ) -> Option<Vec<usize>> {
        match arg {
            Operand::Slot(s) => {
                let shape = self.read_slot(*s, i, &format!("dispatch '{artifact}'"), pred);
                if self.scale_slots.contains(s) && artifact != "quantize" {
                    self.error(
                        i,
                        Rule::QuantFamily,
                        format!(
                            "calibrated int8 scale slot {s} feeds '{artifact}' — scale slots may only feed 'quantize'"
                        ),
                    );
                }
                shape
            }
            Operand::Weight(w) => Some(weight_shape(w.kind, &self.prog.fabric)),
            Operand::Runtime(r) => Some(runtime_shape(*r, &self.prog.fabric)),
            Operand::Extern(e) => {
                if *e >= self.prog.extern_shapes.len() {
                    self.error(
                        i,
                        Rule::ExternContract,
                        format!(
                            "extern {e} out of range ({} extern buffers declared)",
                            self.prog.extern_shapes.len()
                        ),
                    );
                    return None;
                }
                if let Some(&j) = self.consumed_extern.get(e) {
                    self.error(
                        i,
                        Rule::ExternContract,
                        format!(
                            "extern {e} read at step {i} after the kv_append at step {j} advanced it — stale cache panel"
                        ),
                    );
                }
                Some(self.prog.extern_shapes[*e].clone())
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn dispatch(
        &mut self,
        artifact: &'static str,
        args: &[Operand],
        dst: SlotId,
        out_shape: &[usize],
        i: usize,
        pred: Option<LivePred>,
    ) {
        if !self.inventory.has(artifact) {
            self.warn(
                i,
                Rule::UnknownArtifact,
                format!("artifact '{artifact}' is not in the bound artifact set"),
            );
        }
        let sig = self.inventory.signature(artifact).cloned();
        if let Some(sig) = &sig {
            if args.len() != sig.inputs.len() {
                self.error(
                    i,
                    Rule::ArityMismatch,
                    format!(
                        "artifact '{artifact}' takes {} operands per the manifest, dispatch passes {}",
                        sig.inputs.len(),
                        args.len()
                    ),
                );
            }
        }
        for (j, arg) in args.iter().enumerate() {
            let shape = self.operand_shape(artifact, arg, i, pred);
            if let (Some(shape), Some(sig)) = (&shape, &sig) {
                if let Some(want) = sig.inputs.get(j) {
                    if shape != want {
                        self.error(
                            i,
                            Rule::ShapeMismatch,
                            format!(
                                "artifact '{artifact}' operand {j} has shape {shape:?}, manifest wants {want:?}"
                            ),
                        );
                    }
                }
            }
        }
        if let Some(want) = sig.as_ref().and_then(|s| s.outputs.first()) {
            if out_shape != want.as_slice() {
                self.error(
                    i,
                    Rule::ShapeMismatch,
                    format!(
                        "artifact '{artifact}' records out_shape {out_shape:?}, manifest wants {want:?}"
                    ),
                );
            }
        }
        if artifact == "kv_append" {
            match args.first() {
                Some(Operand::Extern(e)) => {
                    if let Some(panel) = self.prog.extern_shapes.get(*e).cloned() {
                        if out_shape != panel.as_slice() {
                            self.error(
                                i,
                                Rule::ExternContract,
                                format!(
                                    "kv_append out_shape {out_shape:?} must match extern {e} panel shape {panel:?}"
                                ),
                            );
                        }
                        self.kv_appends.push((i, *e, dst));
                        self.consumed_extern.insert(*e, i);
                    }
                }
                _ => self.error(
                    i,
                    Rule::ExternContract,
                    "kv_append's first operand must be an extern cache panel".to_string(),
                ),
            }
        }
        self.def_slot(dst, i, Some(out_shape.to_vec()), false, pred);
    }

    fn walk(&mut self) {
        let prog = self.prog;
        for (i, step) in prog.steps.iter().enumerate() {
            match step {
                Step::Upload { host, dst } => {
                    let shape = self.read_host(*host, i, "upload");
                    self.def_slot(*dst, i, shape, false, None);
                }
                Step::Dispatch { artifact, args, dst, out_shape, pred } => {
                    self.dispatch(*artifact, args, *dst, out_shape, i, *pred);
                }
                Step::Fetch { src, host } => {
                    let shape = self.read_slot(*src, i, "fetch", None);
                    if !self.write_host(*host, i, false) {
                        continue;
                    }
                    if let Some(shape) = shape {
                        if shape != self.prog.host_shapes[*host] {
                            self.error(
                                i,
                                Rule::ShapeMismatch,
                                format!(
                                    "fetch writes slot {src} (shape {shape:?}) into host {host} declared as {:?}",
                                    self.prog.host_shapes[*host]
                                ),
                            );
                        }
                        self.host_cur[*host] = shape;
                    }
                }
                Step::ExtractPanel { src, c0, width, dst } => {
                    let src_shape = self.read_host(*src, i, "extract-panel");
                    if let Some(src_shape) = &src_shape {
                        if src_shape.len() != 2 {
                            self.error(
                                i,
                                Rule::ShapeMismatch,
                                format!("extract-panel src host {src} has shape {src_shape:?}, want rank 2"),
                            );
                        } else if c0 + width > src_shape[1] {
                            self.error(
                                i,
                                Rule::ShapeMismatch,
                                format!(
                                    "extract-panel columns {c0}..{} exceed src host {src} width {}",
                                    c0 + width,
                                    src_shape[1]
                                ),
                            );
                        }
                    }
                    if !self.write_host(*dst, i, false) {
                        continue;
                    }
                    if let Some(src_shape) = &src_shape {
                        if src_shape.len() == 2 {
                            let want = vec![src_shape[0], *width];
                            if self.prog.host_shapes[*dst] != want {
                                self.error(
                                    i,
                                    Rule::ShapeMismatch,
                                    format!(
                                        "extract-panel dst host {dst} declared as {:?}, panel is {want:?}",
                                        self.prog.host_shapes[*dst]
                                    ),
                                );
                            }
                            self.host_cur[*dst] = want;
                        }
                    }
                }
                Step::AssemblePanel { src, dst, c0 } => {
                    let src_shape = self.read_host(*src, i, "assemble-panel");
                    if !self.write_host(*dst, i, true) {
                        continue;
                    }
                    let dst_shape = self.host_cur[*dst].clone();
                    if let Some(src_shape) = &src_shape {
                        if src_shape.len() == 2 && dst_shape.len() == 2 {
                            if c0 + src_shape[1] > dst_shape[1] || src_shape[0] > dst_shape[0] {
                                self.error(
                                    i,
                                    Rule::ShapeMismatch,
                                    format!(
                                        "assemble-panel writes {src_shape:?} at column {c0} of host {dst} shaped {dst_shape:?}"
                                    ),
                                );
                            }
                        }
                    }
                }
                Step::CalibrateScale { src, dst } => {
                    self.read_host(*src, i, "calibrate-scale");
                    self.def_slot(*dst, i, Some(vec![1]), true, None);
                }
                Step::SendActivation { src, host, .. } => {
                    // Fetch semantics plus link pricing: the activation is
                    // downloaded into `host`, which the chain driver hands
                    // to the peer shard's replay.
                    let shape = self.read_slot(*src, i, "send-activation", None);
                    if !self.write_host(*host, i, false) {
                        continue;
                    }
                    if let Some(shape) = shape {
                        if shape != self.prog.host_shapes[*host] {
                            self.error(
                                i,
                                Rule::ShapeMismatch,
                                format!(
                                    "send-activation writes slot {src} (shape {shape:?}) into host {host} declared as {:?}",
                                    self.prog.host_shapes[*host]
                                ),
                            );
                        }
                        self.host_cur[*host] = shape;
                    }
                }
                Step::RecvActivation { host, .. } => {
                    // The peer's activation was written into the input host
                    // by the chain driver before replay; the step itself
                    // only observes it (and prices the link).
                    self.read_host(*host, i, "recv-activation");
                }
            }
        }
        // Leaks: defs still unread at the end of the stream.
        let mut dead_slots: Vec<(usize, SlotId)> = self
            .pending_slot
            .iter()
            .filter(|(s, _)| !self.exported.contains(s))
            .map(|(s, i)| (*i, *s))
            .collect();
        dead_slots.sort_unstable();
        for (i, s) in dead_slots {
            self.warn(i, Rule::DeadWrite, format!("slot {s} written at step {i} is never read"));
        }
        let mut dead_hosts: Vec<(usize, HostId)> = self
            .pending_host
            .iter()
            .filter(|(h, _)| **h != self.prog.output_host)
            .map(|(h, i)| (*i, *h))
            .collect();
        dead_hosts.sort_unstable();
        for (i, h) in dead_hosts {
            self.warn(i, Rule::DeadWrite, format!("host {h} written at step {i} is never read"));
        }
    }

    /// Export-table rules that hold for every program kind.
    fn check_exports(&mut self) {
        let mut seen: HashSet<SlotId> = HashSet::new();
        for s in self.prog.export_slots.clone() {
            if !seen.insert(s) {
                self.push(
                    None,
                    Severity::Error,
                    Rule::ExportContract,
                    format!("export slot {s} is listed more than once"),
                );
                continue;
            }
            if s >= self.prog.n_slots {
                self.push(
                    None,
                    Severity::Error,
                    Rule::ExportContract,
                    format!("export slot {s} out of range ({} slots declared)", self.prog.n_slots),
                );
                continue;
            }
            match self.export_defs.get(&s).copied().unwrap_or(0) {
                0 => self.push(
                    None,
                    Severity::Error,
                    Rule::ExportContract,
                    format!("export slot {s} is never written — replay would hand back a freed buffer"),
                ),
                1 => {
                    // Replay hands exports back unconditionally, so an
                    // export must be defined at every live row count.
                    let seq = self.prog.cfg.seq_len;
                    let cover = self.slot_cover.get(&s).cloned().unwrap_or_default();
                    if !cover.iter().any(|&(l, h)| l == 0 && h >= seq) {
                        self.push(
                            None,
                            Severity::Error,
                            Rule::SkipContract,
                            format!(
                                "export slot {s} is defined only over live ranges {cover:?} — a short request would export a freed buffer"
                            ),
                        );
                    }
                }
                n => self.push(
                    None,
                    Severity::Error,
                    Rule::ExportContract,
                    format!(
                        "export slot {s} is written {n} times — its id was recycled despite being exported"
                    ),
                ),
            }
        }
    }

    /// Kind-specific extern/export layout contracts
    /// (`accel::decode::ExternLayout` is the index authority).
    fn check_kind(&mut self, kind: ProgramKind) {
        let prog = self.prog;
        let layout = ExternLayout::of(&prog.cfg);
        match kind {
            ProgramKind::Encoder => {
                if !prog.extern_shapes.is_empty() {
                    self.push(
                        None,
                        Severity::Error,
                        Rule::ExternContract,
                        format!(
                            "encoder program declares {} extern buffers, want 0",
                            prog.extern_shapes.len()
                        ),
                    );
                }
                if !prog.export_slots.is_empty() {
                    self.push(
                        None,
                        Severity::Error,
                        Rule::ExportContract,
                        format!("encoder program exports {} slots, want 0", prog.export_slots.len()),
                    );
                }
            }
            ProgramKind::Prefill => {
                if !prog.extern_shapes.is_empty() {
                    self.push(
                        None,
                        Severity::Error,
                        Rule::ExternContract,
                        format!(
                            "prefill program declares {} extern buffers, want 0",
                            prog.extern_shapes.len()
                        ),
                    );
                }
                if prog.export_slots.len() != layout.total() {
                    self.push(
                        None,
                        Severity::Error,
                        Rule::ExportContract,
                        format!(
                            "prefill exports {} K/V panels, ExternLayout wants {}",
                            prog.export_slots.len(),
                            layout.total()
                        ),
                    );
                }
            }
            ProgramKind::DecodeStep => {
                if prog.extern_shapes.len() != layout.total() {
                    self.push(
                        None,
                        Severity::Error,
                        Rule::ExternContract,
                        format!(
                            "decode-step declares {} extern buffers, ExternLayout wants {}",
                            prog.extern_shapes.len(),
                            layout.total()
                        ),
                    );
                }
                if prog.export_slots.len() != layout.step_exports() {
                    self.push(
                        None,
                        Severity::Error,
                        Rule::ExportContract,
                        format!(
                            "decode-step exports {} panels, ExternLayout wants {}",
                            prog.export_slots.len(),
                            layout.step_exports()
                        ),
                    );
                }
                let per = layout.per_layer();
                let appended: HashSet<SlotId> =
                    self.kv_appends.iter().map(|(_, _, dst)| *dst).collect();
                for (i, e, dst) in self.kv_appends.clone() {
                    if per == 0 {
                        continue;
                    }
                    let rem = e % per;
                    if rem >= 2 * layout.heads {
                        self.error(
                            i,
                            Rule::ExternContract,
                            format!(
                                "kv_append consumes cross-attention panel {e} — only self K/V panels are appended"
                            ),
                        );
                        continue;
                    }
                    let pos = ((e / per) * layout.heads + rem / 2) * 2 + rem % 2;
                    match prog.export_slots.get(pos) {
                        Some(&want) if want != dst => self.error(
                            i,
                            Rule::ExportContract,
                            format!(
                                "kv_append result slot {dst} for panel {e} should be export {pos}, which lists slot {want}"
                            ),
                        ),
                        _ => {}
                    }
                }
                for &s in &prog.export_slots {
                    if self.export_defs.get(&s).copied().unwrap_or(0) == 1 && !appended.contains(&s)
                    {
                        self.push(
                            None,
                            Severity::Error,
                            Rule::ExportContract,
                            format!("decode-step export slot {s} is not a kv_append result"),
                        );
                    }
                }
            }
        }
    }

    /// Per-program shard-transfer rules: at most one `SendActivation` and
    /// one `RecvActivation`, the send writing the output host (the replay
    /// return value IS the activation handed over the link) and the recv
    /// observing the input host (where the chain driver lands the peer's
    /// activation).  Cross-program boundary pairing is
    /// [`verify_shard_chain`]'s job.
    fn check_shard(&mut self) {
        let prog = self.prog;
        let sends: Vec<(usize, HostId)> = prog
            .steps
            .iter()
            .enumerate()
            .filter_map(|(i, s)| match s {
                Step::SendActivation { host, .. } => Some((i, *host)),
                _ => None,
            })
            .collect();
        let recvs: Vec<(usize, HostId)> = prog
            .steps
            .iter()
            .enumerate()
            .filter_map(|(i, s)| match s {
                Step::RecvActivation { host, .. } => Some((i, *host)),
                _ => None,
            })
            .collect();
        if sends.len() > 1 {
            self.push(
                None,
                Severity::Error,
                Rule::ShardContract,
                format!("{} send-activation steps — one shard covers at most one boundary", sends.len()),
            );
        }
        if recvs.len() > 1 {
            self.push(
                None,
                Severity::Error,
                Rule::ShardContract,
                format!("{} recv-activation steps — one shard covers at most one boundary", recvs.len()),
            );
        }
        for (i, host) in sends {
            if host != prog.output_host {
                self.error(
                    i,
                    Rule::ShardContract,
                    format!(
                        "send-activation writes host {host}, want the output host {} — the replay return value is the sent activation",
                        prog.output_host
                    ),
                );
            }
        }
        for (i, host) in recvs {
            if host != prog.input_host {
                self.error(
                    i,
                    Rule::ShardContract,
                    format!(
                        "recv-activation observes host {host}, want the input host {} — the peer's activation lands there",
                        prog.input_host
                    ),
                );
            }
        }
    }
}

// ---- the wave analysis ---------------------------------------------------

/// Wave-partition and intra-wave race diagnostics on the exact dependence
/// model the scheduler used.  Empty for an unscheduled program
/// (sequential semantics are trivially race-free).
pub fn wave_diagnostics(prog: &TileProgram) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    if prog.waves.is_empty() {
        return diags;
    }
    let covered = *prog.waves.last().unwrap();
    if covered != prog.steps.len() {
        diags.push(Diagnostic {
            step: None,
            severity: Severity::Error,
            rule: Rule::WavePartition,
            message: format!("wave partition covers {covered} of {} steps", prog.steps.len()),
        });
        return diags;
    }
    let mut wave_of = vec![0usize; prog.steps.len()];
    let mut start = 0usize;
    for (w, &end) in prog.waves.iter().enumerate() {
        if end <= start || end > prog.steps.len() {
            diags.push(Diagnostic {
                step: None,
                severity: Severity::Error,
                rule: Rule::WavePartition,
                message: format!("malformed wave {w} (runs {start}..{end})"),
            });
            return diags;
        }
        for slot in wave_of.iter_mut().take(end).skip(start) {
            *slot = w;
        }
        start = end;
    }
    let deps = super::opt::dependence_lists(prog);
    for (i, d) in deps.iter().enumerate() {
        for &j in d {
            if wave_of[j] >= wave_of[i] {
                diags.push(Diagnostic {
                    step: Some(i),
                    severity: Severity::Error,
                    rule: Rule::WaveRace,
                    message: format!(
                        "step {i} (wave {}) depends on step {j} (wave {}) — not strictly earlier",
                        wave_of[i], wave_of[j]
                    ),
                });
            }
        }
    }
    diags
}

// ---- entry points --------------------------------------------------------

/// Kind-agnostic verification: dataflow, shapes, waves and the generic
/// extern/export rules — everything that holds for any [`TileProgram`]
/// regardless of which program flavor it is.  This is what
/// `opt::Pipeline::run` checks after every pass in debug builds.
pub fn verify_structure(prog: &TileProgram, inventory: &ArtifactInventory) -> VerifyReport {
    let mut a = Analyzer::new(prog, inventory);
    a.walk();
    a.check_exports();
    a.check_shard();
    let mut diags = a.diags;
    diags.extend(wave_diagnostics(prog));
    VerifyReport { diagnostics: diags }
}

/// Full verification of one cached program: everything in
/// [`verify_structure`] plus the `kind`-specific
/// `accel::decode::ExternLayout` contracts.
pub fn verify(prog: &TileProgram, kind: ProgramKind, inventory: &ArtifactInventory) -> VerifyReport {
    let mut a = Analyzer::new(prog, inventory);
    a.walk();
    a.check_exports();
    a.check_shard();
    a.check_kind(kind);
    let mut diags = a.diags;
    diags.extend(wave_diagnostics(prog));
    VerifyReport { diagnostics: diags }
}

/// [`verify`] as a hard gate: `Err` when any error-severity diagnostic
/// exists — the program-cache insertion check.
pub fn verify_program(
    prog: &TileProgram,
    kind: ProgramKind,
    inventory: &ArtifactInventory,
) -> Result<VerifyReport, VerifyError> {
    let report = verify(prog, kind, inventory);
    if report.is_clean() {
        Ok(report)
    } else {
        Err(VerifyError::new(report.diagnostics))
    }
}

/// Cross-program verification of a K-shard pipeline chain, ordered head
/// to tail.  Boundary `b` is the cut between shard `b` and shard `b+1`;
/// the contract is:
///
/// * the head shard receives nothing (it takes the caller's input) and
///   the tail shard sends nothing (it returns to the caller);
/// * every interior shard `i` sends exactly boundary `i` and receives
///   exactly boundary `i-1` — each cut is covered exactly once;
/// * across each boundary the sender's activation shape (its output-host
///   shape) equals the receiver's input-host shape.  The IR is f32 end
///   to end, so shape agreement is dtype agreement.
///
/// Per-program rules (dataflow, at-most-one transfer each way, host
/// targeting) still come from [`verify`] / [`verify_structure`]; this
/// checks only the inter-program contract.  A single-program chain is
/// the monolithic case and must carry no transfers at all.
pub fn verify_shard_chain(chain: &[&TileProgram]) -> VerifyReport {
    let mut diags: Vec<Diagnostic> = Vec::new();
    let mut fail = |message: String| {
        diags.push(Diagnostic {
            step: None,
            severity: Severity::Error,
            rule: Rule::ShardContract,
            message,
        });
    };
    let k = chain.len();
    for (i, prog) in chain.iter().enumerate() {
        let sends = prog.send_boundaries();
        let recvs = prog.recv_boundaries();
        if i == 0 {
            if !recvs.is_empty() {
                fail(format!(
                    "head shard receives boundaries {recvs:?} — the chain head takes the caller's input"
                ));
            }
        } else if recvs != [i - 1] {
            fail(format!(
                "shard {i} receives boundaries {recvs:?}, want exactly [{}]",
                i - 1
            ));
        }
        if i + 1 == k {
            if !sends.is_empty() {
                fail(format!(
                    "tail shard sends boundaries {sends:?} — the chain tail returns to the caller"
                ));
            }
        } else if sends != [i] {
            fail(format!("shard {i} sends boundaries {sends:?}, want exactly [{i}]"));
        }
    }
    // Shape agreement across each cut: the sender's output host carries
    // the activation, the receiver's input host is where it lands.
    for b in 0..k.saturating_sub(1) {
        let (tx, rx) = (chain[b], chain[b + 1]);
        let sent = &tx.host_shapes[tx.output_host];
        let want = &rx.host_shapes[rx.input_host];
        if sent != want {
            fail(format!(
                "boundary {b}: shard {b} sends an activation shaped {sent:?}, shard {} expects {want:?}",
                b + 1
            ));
        }
    }
    VerifyReport { diagnostics: diags }
}

#[cfg(test)]
mod tests {
    use super::super::opt::{optimize, ArtifactInventory, OptLevel};
    use super::super::{
        FabricConstants, Operand, ProgramKind, ScheduleBuilder, Step, TileProgram,
    };
    use super::*;
    use crate::model::presets;

    fn fc() -> FabricConstants {
        FabricConstants::artifact_default()
    }

    fn inv() -> ArtifactInventory {
        ArtifactInventory::assume_all()
    }

    fn encoder(level: OptLevel) -> TileProgram {
        let mut p = ScheduleBuilder::new(fc(), presets::small_encoder(32, 2)).unwrap().build();
        optimize(&mut p, level, &inv()).unwrap();
        p
    }

    fn step_program() -> TileProgram {
        ScheduleBuilder::new(fc(), presets::gpt_small(32, 2)).unwrap().build_step()
    }

    const ALL_RUNTIME_IDS: [super::super::RuntimeId; 10] = {
        use super::super::RuntimeId::*;
        [Mask, CausalMask, MemMaskRow, Scale, Dmask, Count, ZeroDk, ZeroFfn, ZeroCol, ZeroQkv3]
    };

    #[test]
    fn runtime_shapes_match_the_materialized_tensors() {
        let cfg = presets::small_encoder(32, 1);
        let f = fc();
        for id in ALL_RUNTIME_IDS {
            assert_eq!(
                runtime_shape(id, &f),
                super::super::runtime_tensor(id, &cfg, &f).shape,
                "{id:?}"
            );
        }
    }

    #[test]
    fn optimized_encoder_programs_verify_clean() {
        for level in [OptLevel::O0, OptLevel::O1, OptLevel::O2] {
            let p = encoder(level);
            let report = verify(&p, ProgramKind::Encoder, &inv());
            assert!(
                report.is_clean(),
                "{level:?}: {:?}",
                report.errors().collect::<Vec<_>>()
            );
        }
    }

    #[test]
    fn swapped_slot_is_use_before_def() {
        let mut p = encoder(OptLevel::O0);
        // Replace the first dispatch's slot operand with a slot that is
        // only defined much later in the stream.
        let late = p
            .steps
            .iter()
            .rev()
            .find_map(|s| match s {
                Step::Dispatch { dst, .. } => Some(*dst),
                _ => None,
            })
            .unwrap();
        let corrupted = p
            .steps
            .iter_mut()
            .find_map(|s| match s {
                Step::Dispatch { args, .. } => args.iter_mut().find_map(|a| match a {
                    Operand::Slot(slot) => {
                        *slot = late;
                        Some(())
                    }
                    _ => None,
                }),
                _ => None,
            });
        assert!(corrupted.is_some());
        let report = verify(&p, ProgramKind::Encoder, &inv());
        assert!(report.has_error(Rule::UseBeforeDef));
        assert!(report.errors().any(|d| d.step.is_some()), "diagnostic must name a step");
    }

    #[test]
    fn forged_single_wave_partition_races() {
        let mut p = encoder(OptLevel::O1);
        p.waves = vec![p.steps.len()];
        let report = verify(&p, ProgramKind::Encoder, &inv());
        assert!(report.has_error(Rule::WaveRace));
    }

    #[test]
    fn partial_wave_coverage_is_flagged() {
        let mut p = encoder(OptLevel::O1);
        p.waves = vec![1];
        let report = verify(&p, ProgramKind::Encoder, &inv());
        assert!(report.has_error(Rule::WavePartition));
    }

    #[test]
    fn merging_adjacent_waves_races_a_member() {
        // ASAP scheduling guarantees every wave-k member depends on some
        // wave-(k-1) member, so claiming wave k's members into k-1 (the
        // "reordered wave member" corruption) must trip the race rule.
        let mut p = encoder(OptLevel::O1);
        assert!(p.waves.len() >= 2);
        let cut = p.waves.len() - 2;
        p.waves.remove(cut);
        let report = verify(&p, ProgramKind::Encoder, &inv());
        assert!(report.has_error(Rule::WaveRace));
        assert!(report.errors().any(|d| d.rule == Rule::WaveRace && d.step.is_some()));
    }

    #[test]
    fn decode_step_program_verifies_clean_at_all_levels() {
        for level in [OptLevel::O0, OptLevel::O1, OptLevel::O2] {
            let mut p = step_program();
            optimize(&mut p, level, &inv()).unwrap();
            let report = verify(&p, ProgramKind::DecodeStep, &inv());
            assert!(
                report.is_clean(),
                "{level:?}: {:?}",
                report.errors().collect::<Vec<_>>()
            );
        }
    }

    #[test]
    fn stale_extern_read_after_kv_append_is_flagged() {
        let mut p = step_program();
        let (idx, _) = p
            .steps
            .iter()
            .enumerate()
            .find_map(|(i, s)| match s {
                Step::Dispatch { artifact: "kv_append", args, .. } => match args.first() {
                    Some(Operand::Extern(e)) => Some((*e, i)),
                    _ => None,
                },
                _ => None,
            })
            .unwrap();
        // A late reader of the pre-append panel: stale by construction.
        let dst = p.n_slots;
        p.n_slots += 1;
        p.steps.push(Step::Dispatch {
            artifact: "qk_row",
            args: vec![Operand::Extern(idx)],
            dst,
            out_shape: vec![1, p.fabric.sl_max],
            pred: None,
        });
        let report = verify(&p, ProgramKind::DecodeStep, &inv());
        assert!(report.has_error(Rule::ExternContract));
    }

    #[test]
    fn scale_slot_into_non_quantize_artifact_is_flagged() {
        let f = fc();
        let mut p = ScheduleBuilder::new(f, presets::small_encoder(32, 1))
            .unwrap()
            .quantized(true)
            .build();
        // Redirect the quantize dispatch to a different artifact: the
        // calibrated scale now feeds a float-family kernel.
        let hit = p.steps.iter_mut().find_map(|s| match s {
            Step::Dispatch { artifact, .. } if *artifact == "quantize" => {
                *artifact = "softmax";
                Some(())
            }
            _ => None,
        });
        assert!(hit.is_some());
        let report = verify(&p, ProgramKind::Encoder, &inv());
        assert!(report.has_error(Rule::QuantFamily));
    }

    #[test]
    fn tier_mask_shapes_match_the_materialized_tensors() {
        let cfg = presets::small_encoder(32, 1);
        let f = fc();
        for id in [
            super::super::RuntimeId::TierMask(16),
            super::super::RuntimeId::TierCausalMask(16),
        ] {
            assert_eq!(
                runtime_shape(id, &f),
                super::super::runtime_tensor(id, &cfg, &f).shape,
                "{id:?}"
            );
        }
    }

    fn skippable_encoder(level: OptLevel) -> TileProgram {
        let mut p = ScheduleBuilder::new(fc(), presets::small_encoder(64, 2))
            .unwrap()
            .skippable(true)
            .build();
        optimize(&mut p, level, &inv()).unwrap();
        p
    }

    #[test]
    fn skippable_programs_verify_clean_at_all_levels() {
        for level in [OptLevel::O0, OptLevel::O1, OptLevel::O2] {
            let p = skippable_encoder(level);
            assert!(p.predicated_dispatch_count() > 0, "{level:?}: no tiers were emitted");
            let report = verify(&p, ProgramKind::Encoder, &inv());
            assert!(
                report.is_clean(),
                "{level:?}: {:?}",
                report.errors().collect::<Vec<_>>()
            );
        }
    }

    #[test]
    fn unpredicated_reader_of_a_tiered_slot_is_a_skip_contract_error() {
        let mut p = skippable_encoder(OptLevel::O0);
        // Strip the predicate from one tier's softmax: it now reads its
        // tier's qk_scores output unconditionally, but that def only
        // exists when the tier fires.
        let hit = p.steps.iter_mut().find_map(|s| match s {
            Step::Dispatch { artifact: "softmax", pred: pred @ Some(_), .. } => {
                *pred = None;
                Some(())
            }
            _ => None,
        });
        assert!(hit.is_some());
        let report = verify(&p, ProgramKind::Encoder, &inv());
        assert!(report.has_error(Rule::SkipContract));
    }

    #[test]
    fn cover_hole_in_a_shared_output_is_a_skip_contract_error() {
        let mut p = skippable_encoder(OptLevel::O0);
        // Shrink the top tier's predicate of one shared sv output: the
        // tiers no longer cover (0, seq_len], so the unpredicated fetch
        // downstream can read a slot no def produced.
        let hit = p.steps.iter_mut().find_map(|s| match s {
            Step::Dispatch { artifact: "sv", pred: Some(pr), .. } if pr.hi == 64 => {
                pr.hi = 48;
                Some(())
            }
            _ => None,
        });
        assert!(hit.is_some());
        let report = verify(&p, ProgramKind::Encoder, &inv());
        assert!(report.has_error(Rule::SkipContract));
    }

    #[test]
    fn malformed_predicate_is_flagged() {
        let mut p = skippable_encoder(OptLevel::O0);
        let hit = p.steps.iter_mut().find_map(|s| match s {
            Step::Dispatch { pred: Some(pr), .. } => {
                pr.lo = pr.hi; // empty live range
                Some(())
            }
            _ => None,
        });
        assert!(hit.is_some());
        let report = verify(&p, ProgramKind::Encoder, &inv());
        assert!(report.has_error(Rule::SkipContract));
    }

    /// A head/tail shard pair by step surgery: the head's trailing fetch
    /// of the output host becomes a boundary-0 send (exactly the
    /// builder's send lowering) and the tail gains a boundary-0 recv of
    /// its input host.  Unoptimized builds so the wave partition stays
    /// empty under mutation.
    fn sharded_pair() -> (TileProgram, TileProgram) {
        let mut head = ScheduleBuilder::new(fc(), presets::small_encoder(32, 1)).unwrap().build();
        let out = head.output_host;
        let replaced = head.steps.iter_mut().rev().find_map(|s| match s {
            Step::Fetch { src, host } if *host == out => {
                let (src, host) = (*src, *host);
                *s = Step::SendActivation { src, host, boundary: 0 };
                Some(())
            }
            _ => None,
        });
        assert!(replaced.is_some(), "no trailing fetch of the output host to convert");
        let mut tail = ScheduleBuilder::new(fc(), presets::small_encoder(32, 1)).unwrap().build();
        let input = tail.input_host;
        tail.steps.push(Step::RecvActivation { host: input, boundary: 0 });
        (head, tail)
    }

    #[test]
    fn sharded_pair_verifies_clean_and_the_chain_is_covered() {
        let (head, tail) = sharded_pair();
        for (name, p) in [("head", &head), ("tail", &tail)] {
            let report = verify(p, ProgramKind::Encoder, &inv());
            assert!(report.is_clean(), "{name}: {:?}", report.errors().collect::<Vec<_>>());
        }
        let report = verify_shard_chain(&[&head, &tail]);
        assert!(report.is_clean(), "{:?}", report.errors().collect::<Vec<_>>());
    }

    #[test]
    fn second_send_is_a_shard_contract_error() {
        let (mut head, _) = sharded_pair();
        let (src, host) = head
            .steps
            .iter()
            .find_map(|s| match s {
                Step::SendActivation { src, host, .. } => Some((*src, *host)),
                _ => None,
            })
            .unwrap();
        head.steps.push(Step::SendActivation { src, host, boundary: 1 });
        let report = verify(&head, ProgramKind::Encoder, &inv());
        assert!(report.has_error(Rule::ShardContract));
    }

    #[test]
    fn send_off_the_output_host_is_a_shard_contract_error() {
        let (mut head, _) = sharded_pair();
        let input = head.input_host;
        let hit = head.steps.iter_mut().find_map(|s| match s {
            Step::SendActivation { host, .. } => {
                *host = input;
                Some(())
            }
            _ => None,
        });
        assert!(hit.is_some());
        let report = verify(&head, ProgramKind::Encoder, &inv());
        assert!(report.has_error(Rule::ShardContract));
    }

    #[test]
    fn recv_off_the_input_host_is_a_shard_contract_error() {
        let (_, mut tail) = sharded_pair();
        let out = tail.output_host;
        let hit = tail.steps.iter_mut().find_map(|s| match s {
            Step::RecvActivation { host, .. } => {
                *host = out;
                Some(())
            }
            _ => None,
        });
        assert!(hit.is_some());
        let report = verify(&tail, ProgramKind::Encoder, &inv());
        assert!(report.has_error(Rule::ShardContract));
    }

    #[test]
    fn uncovered_boundary_is_a_shard_chain_error() {
        // Two plain programs: neither covers the cut between them.
        let a = ScheduleBuilder::new(fc(), presets::small_encoder(32, 1)).unwrap().build();
        let b = ScheduleBuilder::new(fc(), presets::small_encoder(32, 1)).unwrap().build();
        let report = verify_shard_chain(&[&a, &b]);
        assert!(report.has_error(Rule::ShardContract));
    }

    #[test]
    fn forged_boundary_number_is_a_shard_chain_error() {
        let (mut head, tail) = sharded_pair();
        let hit = head.steps.iter_mut().find_map(|s| match s {
            Step::SendActivation { boundary, .. } => {
                *boundary = 7;
                Some(())
            }
            _ => None,
        });
        assert!(hit.is_some());
        let report = verify_shard_chain(&[&head, &tail]);
        assert!(report.has_error(Rule::ShardContract));
    }

    #[test]
    fn peer_shape_disagreement_is_a_shard_chain_error() {
        let (head, mut tail) = sharded_pair();
        let input = tail.input_host;
        tail.host_shapes[input] = vec![1, 2];
        let report = verify_shard_chain(&[&head, &tail]);
        assert!(report.has_error(Rule::ShardContract));
    }

    #[test]
    fn single_program_chain_must_carry_no_transfers() {
        let (head, tail) = sharded_pair();
        assert!(verify_shard_chain(&[&head]).has_error(Rule::ShardContract));
        assert!(verify_shard_chain(&[&tail]).has_error(Rule::ShardContract));
        let plain = ScheduleBuilder::new(fc(), presets::small_encoder(32, 1)).unwrap().build();
        assert!(verify_shard_chain(&[&plain]).is_clean());
    }

    #[test]
    fn diagnostics_render_step_rule_and_severity() {
        let d = Diagnostic {
            step: Some(7),
            severity: Severity::Error,
            rule: Rule::WaveRace,
            message: "x".into(),
        };
        let s = d.to_string();
        assert!(s.contains("step 7"));
        assert!(s.contains("error"));
        assert!(s.contains("wave-race"));
        let e = VerifyError::new(vec![d]);
        assert!(e.to_string().contains("1 error(s)"));
    }
}
